//! The Transport subsystem: node-aware topology and link-class modeling
//! layered over the symmetric heap's one-sided put-signal transfers.
//!
//! The fabric used to be flat: every pair of ranks was one uniform link,
//! and the multi-node story (paper §F, Fig 17) lived in a closed-form
//! simulator formula. This module makes the hierarchy real:
//!
//! * [`Topology`] — which ranks share a node, which link class connects a
//!   pair, and which rank proxies a coalesced transfer into a node.
//! * [`Transport`] — the trait contract over one-sided put-signal
//!   transfers (see *Trait contract* below). [`SymmetricHeap`] is the
//!   intra-node implementation; [`NodeFabric`] is the node-aware one the
//!   engine actually runs on.
//! * [`InterNodeLink`] — NIC semantics for cross-node traffic: a bounded
//!   per-rank receive window (so incast overflow is a *measured* engine
//!   error, not a formula), cumulative per-link byte/transfer counters at
//!   the configured [`WirePrecision`], and an injectable latency +
//!   bandwidth delay for calibrated-simulation runs.
//! * [`NodeFabric::coalesced`] — the FSMoE-style two-level schedule's
//!   inter-node half: one aggregated transfer of the *unique* token rows
//!   bound for a remote node, delivered to a proxy rank which fans the
//!   per-tile payloads out intra-node via delegated writes.
//!
//! ## Trait contract
//!
//! Every [`Transport`] implementation must preserve the symmetric heap's
//! semantics (they are what make the engine's lock-free pass protocol
//! sound):
//!
//! * **Ordering.** `put_signal` copies the payload into the destination
//!   cell *before* release-storing the signal flag; `poll_epoch` is an
//!   acquire load. A consumer that observed a flag may read the payload
//!   data race-free. Transports may add latency but never reorder a
//!   payload after its own signal.
//! * **Signal semantics.** Flags carry `(pass epoch, valid rows)`; a poll
//!   for pass `n` treats any other generation as empty. Transports must
//!   deliver the writer's epoch tag unchanged (no global reset exists).
//! * **Validity.** Definition C.2 is enforced on the *logical* source:
//!   a write into `(coord.p, b = 1)` requires `coord.p == src` even when
//!   a proxy physically issues it ([`SymmetricHeap::put_signal_from`]),
//!   so Theorem 3.1's write-write conflict freedom survives the proxy
//!   hop — distinct logical sources still target disjoint cells.
//! * **Buffer bounds.** Intra-node transfers always succeed (the heap is
//!   the buffer). Inter-node transfers are admitted against a bounded
//!   per-destination receive window that resets each pass generation
//!   (safe because the engine's pass-start barrier serializes epochs
//!   end-to-end); exceeding [`CostModel::nic_buffer`] within one pass
//!   fails the transfer, and the engine reports the pass error — the
//!   measured analog of Fig 17's incast non-termination.
//! * **Accounting.** Bytes are counted per link class at the wire
//!   element width, with no double counting: a byte crosses either the
//!   NVLink class or the NIC class, exactly once.
//! * **Fault injection.** When the config schedules faults
//!   ([`FaultConfig`](crate::config::FaultConfig)), [`NodeFabric`] gates
//!   every transfer through a deterministic
//!   [`FaultPlan`](crate::fault::FaultPlan) *before* the payload moves:
//!   an injected failure delivers nothing (no flag, no bytes), exactly
//!   like a real NIC drop, and surfaces as an ordinary transfer error
//!   that poisons the pass. Chaos runs therefore exercise the production
//!   poison → retry → degrade machinery with zero engine changes. A dead
//!   proxy rank is routed around (the coalesced transfer falls back to
//!   the next alive rank on the destination node); the engine separately
//!   swaps in a degraded placement so traffic stops targeting the
//!   corpse.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Result};

use crate::config::{Config, CostModel, WirePrecision};
use crate::fabric::SymmetricHeap;
use crate::fault::FaultPlan;
use crate::layout::{Coord, LayoutDims};

/// The two link classes of the hierarchical fabric (paper §F: NVLink
/// within a node, NIC between nodes). Also the index into the per-class
/// counters (`NvLink = 0`, `Nic = 1`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LinkClass {
    /// Intra-node (NVLink-class) link, including a rank's self-loop.
    NvLink,
    /// Inter-node (NIC-class) link.
    Nic,
}

impl LinkClass {
    /// Stable counter index: `NvLink = 0`, `Nic = 1`.
    pub fn index(self) -> usize {
        match self {
            LinkClass::NvLink => 0,
            LinkClass::Nic => 1,
        }
    }
}

/// Latency / bandwidth / buffering of one link class, lifted from the
/// [`CostModel`] so the live transport and the analytic simulator price
/// traffic identically.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkParams {
    /// Per-message latency (seconds).
    pub latency: f64,
    /// Unidirectional bandwidth (bytes/s).
    pub bandwidth: f64,
    /// Receive buffering (bytes); `f64::INFINITY` for the heap-backed
    /// NVLink class, [`CostModel::nic_buffer`] for the NIC class.
    pub buffer: f64,
}

impl LinkParams {
    /// The cost model's parameters for one link class.
    pub fn from_cost(cost: &CostModel, class: LinkClass) -> Self {
        match class {
            LinkClass::NvLink => Self {
                latency: cost.intra_lat,
                bandwidth: cost.intra_bw,
                buffer: f64::INFINITY,
            },
            LinkClass::Nic => Self {
                latency: cost.inter_lat,
                bandwidth: cost.inter_bw,
                buffer: cost.nic_buffer,
            },
        }
    }
}

/// Node-aware rank topology: `ranks` spread evenly over nodes of
/// `ranks_per_node` ranks each (`Config::validate` guarantees the even
/// split).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Topology {
    pub ranks: usize,
    pub ranks_per_node: usize,
}

impl Topology {
    pub fn new(ranks: usize, ranks_per_node: usize) -> Self {
        debug_assert!(ranks_per_node > 0 && ranks % ranks_per_node == 0);
        Self { ranks, ranks_per_node }
    }

    pub fn from_config(cfg: &Config) -> Self {
        Self::new(cfg.system.ranks, cfg.system.ranks_per_node())
    }

    /// Number of nodes.
    pub fn nodes(&self) -> usize {
        self.ranks / self.ranks_per_node
    }

    /// Node hosting `rank`.
    pub fn node_of(&self, rank: usize) -> usize {
        rank / self.ranks_per_node
    }

    /// True if two ranks share a node (every rank shares with itself).
    pub fn same_node(&self, a: usize, b: usize) -> bool {
        self.node_of(a) == self.node_of(b)
    }

    /// Link class connecting two ranks (self-loops are NVLink-class).
    pub fn link_class(&self, a: usize, b: usize) -> LinkClass {
        if self.same_node(a, b) {
            LinkClass::NvLink
        } else {
            LinkClass::Nic
        }
    }

    /// Proxy rank on `dst_node` that receives `src`'s coalesced transfer
    /// and fans it out intra-node. Spread by `src % ranks_per_node` so
    /// concurrent sources land on *different* proxies — coalescing must
    /// not re-concentrate the incast it exists to relieve.
    pub fn proxy_of(&self, src: usize, dst_node: usize) -> usize {
        debug_assert!(dst_node < self.nodes());
        dst_node * self.ranks_per_node + src % self.ranks_per_node
    }
}

/// One-sided put-signal transport over the symmetric tensor layout. See
/// the module docs for the full contract (ordering, signal semantics,
/// validity, buffer bounds, accounting). [`SymmetricHeap`] implements the
/// flat intra-node case; [`NodeFabric`] the node-aware hierarchy.
pub trait Transport: Send + Sync {
    /// Layout geometry of the symmetric tensor.
    fn dims(&self) -> &LayoutDims;
    /// Wire element format payloads are stored/counted at.
    fn wire(&self) -> WirePrecision;
    /// True when reads can borrow cell memory without a decode copy.
    fn zero_copy(&self) -> bool;
    /// One-sided put + signal (Definition C.2 enforced; epoch-tagged).
    fn put_signal(
        &self,
        src: usize,
        dst: usize,
        coord: Coord,
        payload: &[f32],
        epoch: u32,
    ) -> Result<()>;
    /// Poll a flag for one pass generation (`Some(rows)` iff arrived).
    fn poll_epoch(&self, rank: usize, flag_idx: usize, epoch: u32) -> Option<usize>;
    /// Decode `rows` rows at `coord` into `out` (flag-acquire required).
    fn read_into(&self, rank: usize, coord: Coord, rows: usize, out: &mut [f32]);
    /// Zero-copy borrow of `rows` rows, when [`zero_copy`](Self::zero_copy).
    fn read_borrowed(&self, rank: usize, coord: Coord, rows: usize) -> Option<&[f32]>;
    /// (intra-node, inter-node) bytes received by `rank`, cumulative.
    fn bytes_in(&self, rank: usize) -> (u64, u64);
}

impl Transport for SymmetricHeap {
    fn dims(&self) -> &LayoutDims {
        SymmetricHeap::dims(self)
    }
    fn wire(&self) -> WirePrecision {
        SymmetricHeap::wire(self)
    }
    fn zero_copy(&self) -> bool {
        SymmetricHeap::zero_copy(self)
    }
    fn put_signal(
        &self,
        src: usize,
        dst: usize,
        coord: Coord,
        payload: &[f32],
        epoch: u32,
    ) -> Result<()> {
        SymmetricHeap::put_signal(self, src, dst, coord, payload, epoch)
    }
    fn poll_epoch(&self, rank: usize, flag_idx: usize, epoch: u32) -> Option<usize> {
        SymmetricHeap::poll_epoch(self, rank, flag_idx, epoch)
    }
    fn read_into(&self, rank: usize, coord: Coord, rows: usize, out: &mut [f32]) {
        SymmetricHeap::read_into(self, rank, coord, rows, out)
    }
    fn read_borrowed(&self, rank: usize, coord: Coord, rows: usize) -> Option<&[f32]> {
        SymmetricHeap::read_borrowed(self, rank, coord, rows)
    }
    fn bytes_in(&self, rank: usize) -> (u64, u64) {
        SymmetricHeap::bytes_in(self, rank)
    }
}

/// Per-destination NIC receive window for one pass generation: traffic of
/// pass `epoch` accumulates; a new generation resets the window (safe —
/// the engine's pass-start barrier serializes epochs end-to-end, so no
/// two generations' NIC traffic ever interleave at one destination).
struct RecvWindow {
    epoch: u32,
    bytes: u64,
}

/// Inter-node (NIC-class) link model: bounded receive buffering per
/// destination rank, cumulative byte/transfer counters at the configured
/// wire precision, and an optional injected latency + serialization delay
/// for calibrated-sim runs (`nic_delay` knob).
pub struct InterNodeLink {
    params: LinkParams,
    /// Inject `latency + bytes / bandwidth` of real sleep per transfer.
    delay: bool,
    windows: Vec<Mutex<RecvWindow>>,
    /// Cumulative NIC bytes received per rank (direct + coalesced).
    nic_bytes_in: Vec<AtomicU64>,
    /// Cumulative NIC transfers received per rank.
    nic_puts_in: Vec<AtomicU64>,
    /// The coalesced subset of `nic_bytes_in` — bytes that crossed the
    /// NIC inside an aggregated per-node transfer rather than a direct
    /// heap put. Kept separately because the heap's own per-class
    /// counters never see coalesced traffic (the fan-out writes are
    /// intra-node), so `NodeFabric::bytes_in` adds exactly this.
    coalesced_bytes_in: Vec<AtomicU64>,
}

impl InterNodeLink {
    pub fn new(ranks: usize, params: LinkParams, delay: bool) -> Self {
        Self {
            params,
            delay,
            windows: (0..ranks).map(|_| Mutex::new(RecvWindow { epoch: 0, bytes: 0 })).collect(),
            nic_bytes_in: (0..ranks).map(|_| AtomicU64::new(0)).collect(),
            nic_puts_in: (0..ranks).map(|_| AtomicU64::new(0)).collect(),
            coalesced_bytes_in: (0..ranks).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    pub fn params(&self) -> &LinkParams {
        &self.params
    }

    /// Admit `bytes` of pass-`epoch` traffic into `dst`'s receive window
    /// and account it. Fails — without delivering — when the window would
    /// exceed the NIC buffer: the measured incast overflow of Fig 17,
    /// surfaced to the caller as an engine pass error.
    pub fn deliver(&self, dst: usize, epoch: u32, bytes: u64, coalesced: bool) -> Result<()> {
        {
            let mut w = self.windows[dst].lock().unwrap();
            if w.epoch != epoch {
                w.epoch = epoch;
                w.bytes = 0;
            }
            let filled = w.bytes + bytes;
            if filled as f64 > self.params.buffer {
                bail!(
                    "NIC receive buffer overflow (incast) at rank {dst}: {filled} bytes \
                     in pass gen {epoch} exceed the {:.0}-byte receive window",
                    self.params.buffer
                );
            }
            w.bytes = filled;
        }
        self.nic_bytes_in[dst].fetch_add(bytes, Ordering::Relaxed);
        self.nic_puts_in[dst].fetch_add(1, Ordering::Relaxed);
        if coalesced {
            self.coalesced_bytes_in[dst].fetch_add(bytes, Ordering::Relaxed);
        }
        if self.delay {
            let secs = self.params.latency + bytes as f64 / self.params.bandwidth;
            std::thread::sleep(std::time::Duration::from_secs_f64(secs));
        }
        Ok(())
    }

    /// Cumulative NIC bytes received by `rank` (direct + coalesced).
    pub fn bytes_in(&self, rank: usize) -> u64 {
        self.nic_bytes_in[rank].load(Ordering::Relaxed)
    }

    /// Cumulative NIC transfers received by `rank`.
    pub fn puts_in(&self, rank: usize) -> u64 {
        self.nic_puts_in[rank].load(Ordering::Relaxed)
    }

    /// Cumulative coalesced NIC bytes received by `rank`.
    pub fn coalesced_bytes_in(&self, rank: usize) -> u64 {
        self.coalesced_bytes_in[rank].load(Ordering::Relaxed)
    }
}

/// The node-aware transport the engine runs on: the symmetric heap for
/// data movement and signaling, a [`Topology`] for link classing, and an
/// [`InterNodeLink`] modeling every cross-node hop. Intra-node transfers
/// go straight to the heap; inter-node transfers are first admitted
/// against the NIC's bounded receive window (and optionally delayed),
/// then land in the heap like any other one-sided write.
pub struct NodeFabric {
    heap: Arc<SymmetricHeap>,
    topo: Topology,
    link: InterNodeLink,
    /// Deterministic chaos schedule; `None` (the default) costs the hot
    /// path nothing but the branch.
    fault: Option<Arc<FaultPlan>>,
}

impl NodeFabric {
    /// Wrap a heap in the configuration's topology and NIC model.
    pub fn new(heap: Arc<SymmetricHeap>, cfg: &Config) -> Self {
        let topo = Topology::from_config(cfg);
        let link = InterNodeLink::new(
            cfg.system.ranks,
            LinkParams::from_cost(&cfg.cost, LinkClass::Nic),
            cfg.cost.nic_delay,
        );
        let fault = FaultPlan::from_config(&cfg.system.fault);
        Self { heap, topo, link, fault }
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    pub fn link(&self) -> &InterNodeLink {
        &self.link
    }

    /// The active fault-injection schedule, if the config enabled one.
    pub fn fault_plan(&self) -> Option<&Arc<FaultPlan>> {
        self.fault.as_ref()
    }

    /// The underlying symmetric heap (intra-node transport).
    pub fn heap(&self) -> &SymmetricHeap {
        &self.heap
    }

    /// Bytes of the symmetric tensor per rank at the wire width.
    pub fn bytes_per_rank(&self) -> usize {
        self.heap.bytes_per_rank()
    }

    /// Open one coalesced inter-node transfer: `unique_bytes` — the
    /// deduplicated token-row volume bound for `dst_node` — crosses the
    /// NIC **once**, into the receive window of `src`'s proxy rank on
    /// that node. The returned guard fans the per-tile payloads out
    /// intra-node via delegated writes that keep `src` as the logical
    /// writer (Definition C.2 checked against `src`, byte accounting
    /// against the proxy's NVLink-class links). Fails like any NIC
    /// delivery when the window would overflow (measured incast).
    pub fn coalesced(
        &self,
        src: usize,
        dst_node: usize,
        epoch: u32,
        unique_bytes: u64,
    ) -> Result<CoalescedXfer<'_>> {
        let mut proxy = self.topo.proxy_of(src, dst_node);
        if let Some(fp) = &self.fault {
            // A dead proxy is routed around: fall back to the first alive
            // rank on the destination node (degraded placement keeps the
            // *experts* off the corpse; the proxy role needs any live NIC
            // endpoint there).
            if fp.rank_dead(proxy, epoch) {
                let rpn = self.topo.ranks_per_node;
                proxy = (0..rpn)
                    .map(|i| dst_node * rpn + i)
                    .find(|&r| !fp.rank_dead(r, epoch))
                    .ok_or_else(|| {
                        anyhow!("coalesced transfer {src} -> node {dst_node}: node is all dead")
                    })?;
            }
            fp.admit(src, proxy, epoch, true)
                .map_err(|e| e.context(format!("coalesced transfer {src} -> node {dst_node}")))?;
        }
        self.link
            .deliver(proxy, epoch, unique_bytes, true)
            .map_err(|e| e.context(format!("coalesced transfer {src} -> node {dst_node}")))?;
        Ok(CoalescedXfer { fabric: self, src, proxy, epoch })
    }
}

impl Transport for NodeFabric {
    fn dims(&self) -> &LayoutDims {
        self.heap.dims()
    }
    fn wire(&self) -> WirePrecision {
        self.heap.wire()
    }
    fn zero_copy(&self) -> bool {
        self.heap.zero_copy()
    }
    /// Route one put over its link class: cross-node puts are admitted
    /// against the NIC receive window (and counted there) first, then
    /// delivered through the heap — whose own per-class counters record
    /// the same bytes under the NIC class, once.
    fn put_signal(
        &self,
        src: usize,
        dst: usize,
        coord: Coord,
        payload: &[f32],
        epoch: u32,
    ) -> Result<()> {
        let nic = self.topo.link_class(src, dst) == LinkClass::Nic;
        if let Some(fp) = &self.fault {
            // Injected faults fire before anything moves: a failed
            // transfer delivers no flag and counts no bytes, like a drop.
            fp.admit(src, dst, epoch, nic)?;
        }
        if nic {
            let bytes = (payload.len() * self.heap.wire().bytes()) as u64;
            self.link.deliver(dst, epoch, bytes, false)?;
        }
        self.heap.put_signal(src, dst, coord, payload, epoch)
    }
    fn poll_epoch(&self, rank: usize, flag_idx: usize, epoch: u32) -> Option<usize> {
        self.heap.poll_epoch(rank, flag_idx, epoch)
    }
    fn read_into(&self, rank: usize, coord: Coord, rows: usize, out: &mut [f32]) {
        self.heap.read_into(rank, coord, rows, out)
    }
    fn read_borrowed(&self, rank: usize, coord: Coord, rows: usize) -> Option<&[f32]> {
        self.heap.read_borrowed(rank, coord, rows)
    }
    /// (intra, inter) bytes received by `rank`: the heap's per-class
    /// split, plus the coalesced NIC bytes the heap never sees (their
    /// fan-out writes are NVLink-class by construction). Direct
    /// cross-node puts are counted by the heap's NIC class only — no
    /// byte is ever counted twice.
    fn bytes_in(&self, rank: usize) -> (u64, u64) {
        let (intra, inter) = self.heap.bytes_in(rank);
        (intra, inter + self.link.coalesced_bytes_in(rank))
    }
}

/// Guard for one coalesced inter-node transfer (the NIC hop already
/// admitted and accounted): [`put`](Self::put) fans individual tile
/// payloads out to their final destinations on the proxy's node.
pub struct CoalescedXfer<'a> {
    fabric: &'a NodeFabric,
    src: usize,
    proxy: usize,
    epoch: u32,
}

impl CoalescedXfer<'_> {
    /// The proxy rank this transfer landed on.
    pub fn proxy(&self) -> usize {
        self.proxy
    }

    /// Deliver one tile to `dst` on the proxy's node: a delegated write
    /// issued by the proxy with the original source as the logical
    /// writer, so flags, announcement indices and the combine protocol
    /// see exactly the coordinates a direct dispatch would have produced
    /// (bitwise-identical pass outputs between flat and hierarchical).
    pub fn put(&self, dst: usize, coord: Coord, payload: &[f32]) -> Result<()> {
        if !self.fabric.topo.same_node(self.proxy, dst) {
            bail!(
                "coalesced fan-out to rank {dst} off the proxy's node (proxy {})",
                self.proxy
            );
        }
        if let Some(fp) = &self.fabric.fault {
            // The intra-node fan-out hop rolls its own (src, dst) fault —
            // a dead final destination fails here even when the proxy hop
            // survived.
            fp.admit(self.src, dst, self.epoch, false)?;
        }
        self.fabric.heap.put_signal_from(self.proxy, self.src, dst, coord, payload, self.epoch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::encode_flag;

    fn topo() -> Topology {
        Topology::new(8, 4) // 2 nodes x 4 ranks
    }

    #[test]
    fn topology_nodes_and_locality() {
        let t = topo();
        assert_eq!(t.nodes(), 2);
        assert_eq!(t.node_of(3), 0);
        assert_eq!(t.node_of(4), 1);
        assert!(t.same_node(0, 3));
        assert!(t.same_node(5, 5), "self-loop is local");
        assert!(!t.same_node(3, 4));
        assert_eq!(t.link_class(1, 2), LinkClass::NvLink);
        assert_eq!(t.link_class(6, 6), LinkClass::NvLink);
        assert_eq!(t.link_class(0, 7), LinkClass::Nic);
        assert_eq!(LinkClass::NvLink.index(), 0);
        assert_eq!(LinkClass::Nic.index(), 1);
    }

    #[test]
    fn proxy_selection_spreads_sources() {
        let t = topo();
        // every proxy lives on the destination node
        for src in 0..t.ranks {
            for node in 0..t.nodes() {
                assert_eq!(t.node_of(t.proxy_of(src, node)), node);
            }
        }
        // distinct sources (mod ranks_per_node) land on distinct proxies:
        // coalescing must not re-concentrate the incast on one rank
        let proxies: Vec<usize> = (0..4).map(|src| t.proxy_of(src, 1)).collect();
        assert_eq!(proxies, vec![4, 5, 6, 7]);
        // and sources with equal local index share a proxy deterministically
        assert_eq!(t.proxy_of(0, 1), t.proxy_of(4, 1));
    }

    #[test]
    fn link_params_come_from_the_cost_model() {
        let cost = CostModel::h100_nvlink();
        let nic = LinkParams::from_cost(&cost, LinkClass::Nic);
        assert_eq!(nic.latency, cost.inter_lat);
        assert_eq!(nic.bandwidth, cost.inter_bw);
        assert_eq!(nic.buffer, cost.nic_buffer);
        let nv = LinkParams::from_cost(&cost, LinkClass::NvLink);
        assert_eq!(nv.latency, cost.intra_lat);
        assert_eq!(nv.bandwidth, cost.intra_bw);
        assert!(nv.buffer.is_infinite(), "the heap is the NVLink buffer");
    }

    #[test]
    fn recv_window_bounds_and_resets_per_epoch() {
        let params = LinkParams { latency: 0.0, bandwidth: 1e9, buffer: 100.0 };
        let link = InterNodeLink::new(2, params, false);
        link.deliver(0, 1, 60, false).unwrap();
        link.deliver(0, 1, 40, false).unwrap(); // exactly full is fine
        let err = link.deliver(0, 1, 1, false).unwrap_err();
        assert!(err.to_string().contains("incast"), "{err}");
        // a new pass generation opens a fresh window
        link.deliver(0, 2, 100, false).unwrap();
        // the other rank's window is independent
        link.deliver(1, 1, 100, false).unwrap();
        // cumulative counters saw only the delivered traffic
        assert_eq!(link.bytes_in(0), 200);
        assert_eq!(link.puts_in(0), 3);
        assert_eq!(link.coalesced_bytes_in(0), 0);
    }

    fn fabric(ranks: usize, nodes: usize) -> NodeFabric {
        let mut cfg = Config::preset("tiny").unwrap();
        cfg.set("ranks", &ranks.to_string()).unwrap();
        cfg.set("nodes", &nodes.to_string()).unwrap();
        let dims = LayoutDims { p: ranks, e_local: 1, c: 8, h: 4, bm: 4 };
        let heap = Arc::new(SymmetricHeap::new(dims, cfg.system.ranks_per_node()));
        NodeFabric::new(heap, &cfg)
    }

    #[test]
    fn node_fabric_routes_per_link_class() {
        let f = fabric(4, 2); // 2 nodes x 2 ranks
        let c = |p| Coord { p, r: 0, b: 1, e: 0, c: 0 };
        // intra-node put: no NIC involvement
        f.put_signal(1, 0, c(1), &[1.0; 8], 1).unwrap();
        assert_eq!(f.link().bytes_in(0), 0);
        // inter-node put: NIC window + counters, then the heap
        f.put_signal(2, 0, c(2), &[2.0; 8], 1).unwrap();
        assert_eq!(f.link().bytes_in(0), 32);
        assert_eq!(f.link().puts_in(0), 1);
        // bytes_in splits agree with the heap (no coalesced traffic here)
        assert_eq!(f.bytes_in(0), (32, 32));
        assert_eq!(f.heap().bytes_in(0), (32, 32));
        // payloads and flags arrive like any heap put
        let fidx = f.dims().flag_index(2, 0, 0, 0);
        assert_eq!(f.poll_epoch(0, fidx, 1), Some(2));
        let mut out = vec![0.0; 8];
        f.read_into(0, c(2), 2, &mut out);
        assert!(out.iter().all(|&v| v == 2.0));
    }

    #[test]
    fn nic_overflow_is_a_put_error_not_a_panic() {
        let mut cfg = Config::preset("tiny").unwrap();
        cfg.set("ranks", "4").unwrap();
        cfg.set("nodes", "2").unwrap();
        cfg.set("nic_buffer", "40").unwrap(); // one 8-elem f32 put = 32 B
        let dims = LayoutDims { p: 4, e_local: 1, c: 8, h: 4, bm: 4 };
        let heap = Arc::new(SymmetricHeap::new(dims, 2));
        let f = NodeFabric::new(heap, &cfg);
        let c = |p, slot: usize| Coord { p, r: 0, b: 1, e: 0, c: slot * 4 };
        f.put_signal(2, 0, c(2, 0), &[1.0; 8], 7).unwrap();
        let err = f.put_signal(3, 0, c(3, 0), &[1.0; 8], 7).unwrap_err();
        assert!(err.to_string().contains("incast"), "{err}");
        // the failed put delivered nothing: no flag, no counted bytes
        let fidx = f.dims().flag_index(3, 0, 0, 0);
        assert_eq!(f.poll_epoch(0, fidx, 7), None);
        assert_eq!(f.bytes_in(0).1, 32);
        // intra-node traffic is never NIC-bounded
        f.put_signal(1, 0, c(1, 0), &[1.0; 8], 7).unwrap();
        // and the next pass generation clears the window
        f.put_signal(3, 0, c(3, 0), &[1.0; 8], 8).unwrap();
    }

    #[test]
    fn coalesced_transfer_fans_out_with_logical_source() {
        let f = fabric(4, 2);
        // rank 0 coalesces 3 unique rows for node 1 (ranks 2, 3)
        let unique_bytes = 3 * 4 * 4; // rows x H x f32
        let x = f.coalesced(0, 1, 5, unique_bytes as u64).unwrap();
        assert_eq!(x.proxy(), 2, "node 1's proxy for src 0");
        // fan-out keeps coord.p = 0 (the logical source) — Definition C.2
        // holds against src even though the proxy physically writes
        let c0 = Coord { p: 0, r: 0, b: 1, e: 0, c: 0 };
        x.put(2, c0, &[3.0; 8]).unwrap();
        x.put(3, c0, &[4.0; 4]).unwrap();
        // a forged logical coordinate still fails
        let forged = Coord { p: 1, r: 0, b: 1, e: 0, c: 0 };
        assert!(x.put(3, forged, &[0.0; 4]).is_err());
        // fan-out off the proxy's node is rejected
        assert!(x.put(0, c0, &[0.0; 4]).is_err());
        // receivers see ordinary generation-tagged packets from rank 0
        let fidx = f.dims().flag_index(0, 0, 0, 0);
        assert_eq!(f.poll_epoch(2, fidx, 5), Some(2));
        assert_eq!(f.poll_epoch(3, fidx, 5), Some(1));
        // accounting: the NIC saw only the coalesced volume, on the
        // proxy; the fan-out bytes are NVLink-class on their receivers
        assert_eq!(f.link().coalesced_bytes_in(2), unique_bytes as u64);
        assert_eq!(f.bytes_in(2), (32, unique_bytes as u64));
        assert_eq!(f.bytes_in(3), (16, 0));
        assert_eq!(f.heap().bytes_in(2), (32, 0), "heap never double counts");
    }

    #[test]
    fn coalesced_respects_the_receive_window() {
        let mut cfg = Config::preset("tiny").unwrap();
        cfg.set("ranks", "4").unwrap();
        cfg.set("nodes", "2").unwrap();
        cfg.set("nic_buffer", "100").unwrap();
        let dims = LayoutDims { p: 4, e_local: 1, c: 8, h: 4, bm: 4 };
        let heap = Arc::new(SymmetricHeap::new(dims, 2));
        let f = NodeFabric::new(heap, &cfg);
        f.coalesced(0, 1, 1, 80).unwrap();
        let err = f.coalesced(0, 1, 1, 80).unwrap_err();
        assert!(err.to_string().contains("incast"), "{err}");
        // direct NIC puts share the same window as coalesced arrivals
        let c2 = Coord { p: 0, r: 0, b: 1, e: 0, c: 0 };
        assert!(f.put_signal(0, 2, c2, &[0.0; 8], 1).is_err());
    }

    fn chaos_fabric(
        ranks: usize,
        nodes: usize,
        knobs: &[(&str, &str)],
    ) -> NodeFabric {
        let mut cfg = Config::preset("tiny").unwrap();
        cfg.set("ranks", &ranks.to_string()).unwrap();
        cfg.set("nodes", &nodes.to_string()).unwrap();
        for (k, v) in knobs {
            cfg.set(k, v).unwrap();
        }
        let dims = LayoutDims { p: ranks, e_local: 1, c: 8, h: 4, bm: 4 };
        let heap = Arc::new(SymmetricHeap::new(dims, cfg.system.ranks_per_node()));
        NodeFabric::new(heap, &cfg)
    }

    #[test]
    fn injected_transient_fault_delivers_nothing() {
        let f = chaos_fabric(2, 1, &[("fault_transient_rate", "1.0")]);
        assert!(f.fault_plan().is_some());
        let c = Coord { p: 0, r: 0, b: 1, e: 0, c: 0 };
        let err = f.put_signal(0, 1, c, &[1.0; 8], 1).unwrap_err();
        assert!(crate::fault::is_transient(&format!("{err:#}")), "{err:#}");
        // nothing moved: no flag, no bytes
        let fidx = f.dims().flag_index(0, 0, 0, 0);
        assert_eq!(f.poll_epoch(1, fidx, 1), None);
        assert_eq!(f.bytes_in(1), (0, 0));
        assert_eq!(f.fault_plan().unwrap().faults_injected(), 1);
        // a default fabric builds no plan at all
        assert!(fabric(2, 1).fault_plan().is_none());
    }

    #[test]
    fn dead_rank_fails_transfers_both_ways_after_kill_epoch() {
        let f = chaos_fabric(2, 1, &[("fault_kill_rank", "1"), ("fault_kill_epoch", "3")]);
        let c = |p| Coord { p, r: 0, b: 1, e: 0, c: 0 };
        // alive before the kill epoch
        f.put_signal(0, 1, c(0), &[1.0; 8], 2).unwrap();
        // dead from epoch 3 on: as destination and as source
        let err = f.put_signal(0, 1, c(0), &[1.0; 8], 3).unwrap_err();
        assert!(crate::fault::is_dead_rank(&format!("{err:#}")), "{err:#}");
        let err = f.put_signal(1, 0, c(1), &[1.0; 8], 4).unwrap_err();
        assert!(crate::fault::is_dead_rank(&format!("{err:#}")), "{err:#}");
        // transfers not touching the corpse still work
        f.put_signal(0, 0, c(0), &[1.0; 8], 4).unwrap();
    }

    #[test]
    fn dead_proxy_falls_back_to_an_alive_rank() {
        // 2 nodes x 2 ranks; src 0's natural proxy on node 1 is rank 2 —
        // kill it and the coalesced transfer must land on rank 3 instead.
        let f = chaos_fabric(4, 2, &[("fault_kill_rank", "2"), ("fault_kill_epoch", "1")]);
        let x = f.coalesced(0, 1, 5, 64).unwrap();
        assert_eq!(x.proxy(), 3, "fell back to the alive rank on node 1");
        // fan-out to the live rank works; to the corpse it fails
        let c0 = Coord { p: 0, r: 0, b: 1, e: 0, c: 0 };
        x.put(3, c0, &[1.0; 4]).unwrap();
        let err = x.put(2, c0, &[1.0; 4]).unwrap_err();
        assert!(crate::fault::is_dead_rank(&format!("{err:#}")), "{err:#}");
        // the NIC accounting followed the fallback proxy
        assert_eq!(f.link().coalesced_bytes_in(3), 64);
        assert_eq!(f.link().coalesced_bytes_in(2), 0);
    }

    #[test]
    fn transport_trait_is_implemented_by_both_layers() {
        // generic over the trait: the same protocol runs on a bare heap
        // and on the node fabric
        fn roundtrip<T: Transport>(t: &T) {
            let coord = Coord { p: 0, r: 0, b: 1, e: 0, c: 0 };
            t.put_signal(0, 1, coord, &[1.5; 4], 9).unwrap();
            let fidx = t.dims().flag_index(0, 0, 0, 0);
            assert_eq!(t.poll_epoch(1, fidx, 9), Some(1));
            assert_eq!(t.poll_epoch(1, fidx, 8), None, "stale generation");
            let mut out = vec![0.0; 4];
            t.read_into(1, coord, 1, &mut out);
            assert_eq!(out, vec![1.5; 4]);
            if t.zero_copy() {
                assert_eq!(t.read_borrowed(1, coord, 1).unwrap(), &[1.5; 4]);
            }
            assert_eq!(t.wire(), WirePrecision::F32);
            assert_eq!(t.bytes_in(1), (16, 0), "self-node put is intra");
        }
        let dims = LayoutDims { p: 4, e_local: 1, c: 8, h: 4, bm: 4 };
        roundtrip(&SymmetricHeap::new(dims, 2));
        roundtrip(&fabric(4, 2));
        // epoch-delayed flag check via the raw encoding helper
        assert_eq!(encode_flag(9, 1) >> 32, 9);
    }
}
