//! `flashdmoe` — the launcher CLI.
//!
//! Subcommands:
//!   run        one distributed forward pass (real execution, multi-rank)
//!   baseline   bulk-synchronous forward on the same substrate
//!   sim        simulate a forward pass under any engine
//!   figures    regenerate every paper table/figure (same as cargo bench)
//!   straggler  Table 2 straggler study
//!   calibrate  measure tile-GEMM cost and report implied FLOP/s
//!   inspect    print config, layout and memory accounting
//!
//! Examples:
//!   flashdmoe run --preset default --backend xla --mode fused
//!   flashdmoe sim --engine fastermoe --ranks 8 --tokens 16384 --experts 64
//!   flashdmoe figures

use std::sync::Arc;

use anyhow::{bail, Result};

use flashdmoe::config::Config;
use flashdmoe::coordinator::{baseline, MoeEngine, TaskGraphMode};
use flashdmoe::expert::{generate_tokens, ModelParams};
use flashdmoe::harness;
use flashdmoe::runtime::{ArtifactStore, ComputeBackend, NativeBackend, XlaBackend};
use flashdmoe::sim::calibrate::apply_native_calibration;
use flashdmoe::sim::engines::{simulate, Engine};
use flashdmoe::util::args::Args;
use flashdmoe::util::stats::{fmt_bytes, fmt_time};
use flashdmoe::workload::{cluster_workload, Skew};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> String {
    "flashdmoe <run|baseline|sim|figures|straggler|calibrate|inspect> [options]\n\
     run `flashdmoe <cmd> --help` for per-command options"
        .to_string()
}

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        println!("{}", usage());
        return Ok(());
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "run" => cmd_run(rest),
        "baseline" => cmd_baseline(rest),
        "sim" => cmd_sim(rest),
        "figures" => cmd_figures(rest),
        "straggler" => cmd_straggler(rest),
        "calibrate" => cmd_calibrate(rest),
        "inspect" => cmd_inspect(rest),
        other => bail!("unknown command '{other}'\n{}", usage()),
    }
}

fn load_config(a: &Args) -> Result<Config> {
    let mut cfg = match a.get("config").as_str() {
        "" => Config::preset(&a.get("preset"))?,
        path => Config::from_file(path)?,
    };
    for kv in a.positionals() {
        if let Some((k, v)) = kv.split_once('=') {
            cfg.set(k, v)?;
        }
    }
    cfg.validate()?;
    Ok(cfg)
}

fn make_backend(cfg: &Config, which: &str, preset: &str) -> Result<Arc<dyn ComputeBackend>> {
    match which {
        "native" => Ok(Arc::new(NativeBackend::from_config(cfg))),
        "xla" => {
            let dir = ArtifactStore::default_dir();
            let store = ArtifactStore::load(&dir, preset)?;
            Ok(Arc::new(XlaBackend::new(store)))
        }
        other => bail!("unknown backend '{other}' (native|xla)"),
    }
}

fn cmd_run(argv: &[String]) -> Result<()> {
    let a = Args::new("flashdmoe run", "one distributed MoE forward pass (real execution)")
        .opt("preset", "default", "config preset (tiny/default/perf)")
        .opt("config", "", "KEY=VALUE config file (overrides preset)")
        .opt("backend", "native", "compute backend: native | xla")
        .opt("mode", "fused", "task graph: fused | split")
        .opt("passes", "3", "forward passes to run")
        .opt("seed", "42", "weights/tokens seed")
        .flag("verify", "cross-check against the monolithic PJRT reference")
        .parse(argv)?;
    let cfg = load_config(&a)?;
    let preset = a.get("preset");
    let backend = make_backend(&cfg, &a.get("backend"), &preset)?;
    let mode = match a.get("mode").as_str() {
        "fused" => TaskGraphMode::Fused,
        "split" => TaskGraphMode::Split,
        m => bail!("unknown mode '{m}'"),
    };
    let seed = a.get_usize("seed")? as u64;
    let params = Arc::new(ModelParams::generate(&cfg, seed));
    println!(
        "model: H={} D={} E={} k={} | {} params | ranks={} s_rank={} procs/rank={}",
        cfg.model.h,
        cfg.model.d,
        cfg.model.e,
        cfg.model.k,
        params.num_params(),
        cfg.system.ranks,
        cfg.system.s_rank,
        cfg.system.processors
    );
    // launch once: the actors stay resident across every pass below
    let engine = MoeEngine::start(cfg.clone(), params.clone(), backend, mode)?;
    println!("symmetric heap: {} per rank", fmt_bytes(engine.heap_bytes_per_rank()));
    let inputs: Vec<Vec<f32>> =
        (0..cfg.system.ranks).map(|r| generate_tokens(&cfg, seed, r)).collect();

    for _ in 0..a.get_usize("passes")? {
        let res = engine.submit(&inputs)?.wait()?;
        let m = &res.metrics;
        println!(
            "pass {}: {} | util {:.1}% | tasks {} | payload saved {:.1}% | dropped {}",
            m.epoch,
            fmt_time(m.wall_secs),
            m.utilization() * 100.0,
            m.ranks.iter().map(|r| r.total_tasks()).sum::<u32>(),
            m.ranks.iter().map(|r| r.payload_savings()).sum::<f64>() / m.ranks.len() as f64
                * 100.0,
            m.total_dropped(),
        );
    }
    let em = engine.metrics();
    println!(
        "engine: {} pass(es) served | {} launch(es) — {:.3} launches/pass | {} resident threads | steady-state util {:.1}%",
        em.passes,
        em.launches,
        em.launches_per_pass(),
        em.threads_spawned,
        em.steady_state_utilization(cfg.system.ranks * cfg.system.processors) * 100.0,
    );

    if a.get_bool("verify") {
        let dir = ArtifactStore::default_dir();
        let store = ArtifactStore::load(&dir, &preset)?;
        let mut a_all = Vec::new();
        for r in &inputs {
            a_all.extend_from_slice(r);
        }
        let want = store.run_moe_layer(&a_all, &params)?;
        let res = engine.submit(&inputs)?.wait()?;
        let got: Vec<f32> = res.outputs.concat();
        let err = flashdmoe::util::stats::max_abs_diff(&got, &want);
        println!("verify vs monolithic PJRT reference: max |Δ| = {err:.2e}");
        anyhow::ensure!(err < 1e-3, "distributed forward diverged from reference");
    }
    engine.shutdown();
    Ok(())
}

fn cmd_baseline(argv: &[String]) -> Result<()> {
    let a = Args::new("flashdmoe baseline", "bulk-synchronous forward (real execution)")
        .opt("preset", "default", "config preset")
        .opt("config", "", "config file")
        .opt("backend", "native", "native | xla")
        .opt("seed", "42", "seed")
        .parse(argv)?;
    let cfg = load_config(&a)?;
    let backend = make_backend(&cfg, &a.get("backend"), &a.get("preset"))?;
    let seed = a.get_usize("seed")? as u64;
    let params = Arc::new(ModelParams::generate(&cfg, seed));
    let inputs: Vec<Vec<f32>> =
        (0..cfg.system.ranks).map(|r| generate_tokens(&cfg, seed, r)).collect();
    let res = baseline::forward_sequential(&cfg, &params, &backend, &inputs)?;
    let m = &res.metrics;
    println!(
        "bulk-sync pass: {} | {} launches | {}/{} valid rows shipped | {} in barriers",
        fmt_time(m.wall_secs),
        m.launches,
        m.valid_rows,
        m.sent_rows,
        fmt_time(m.barrier_secs)
    );
    Ok(())
}

fn cmd_sim(argv: &[String]) -> Result<()> {
    let a = Args::new("flashdmoe sim", "simulate one forward pass under any engine")
        .opt("engine", "flash", "flash|fastermoe|comet|megatron-cutlass|megatron-te|deepspeed|deepep")
        .opt("ranks", "8", "world size")
        .opt("tokens", "8192", "tokens per rank")
        .opt("experts", "64", "total experts")
        .opt("skew", "zipf", "uniform|zipf|hot")
        .opt("seed", "42", "seed")
        .parse(argv)?;
    let engine = Engine::parse(&a.get("engine"))
        .ok_or_else(|| anyhow::anyhow!("unknown engine '{}'", a.get("engine")))?;
    let cfg = harness::paper_config(
        a.get_usize("ranks")?,
        a.get_usize("tokens")?,
        a.get_usize("experts")?,
    )?;
    let skew = Skew::parse(&a.get("skew")).ok_or_else(|| anyhow::anyhow!("bad skew"))?;
    let seed = a.get_usize("seed")? as u64;
    let wl = cluster_workload(&cfg, skew, seed);
    let r = simulate(&cfg, &wl, engine, seed)?;
    println!(
        "{}: latency {} | util {:.1}% | {} launches/rank | {} on wire | MIV {}{}",
        r.engine,
        fmt_time(r.latency),
        r.utilization * 100.0,
        r.launches_per_rank,
        fmt_bytes(r.bytes_on_wire),
        fmt_bytes(r.max_incast),
        if r.incast_overflow { " (OVERFLOW)" } else { "" }
    );
    Ok(())
}

fn cmd_figures(argv: &[String]) -> Result<()> {
    let a = Args::new("flashdmoe figures", "regenerate every paper table/figure")
        .opt("seed", "42", "seed")
        .parse(argv)?;
    let seed = a.get_usize("seed")? as u64;
    let (t1, _) = harness::table1();
    println!("{t1}");
    let (t2, _) = harness::table2(seed);
    println!("{t2}");
    let (t3, _) = harness::table3();
    println!("{t3}");
    for f in [
        harness::fig10(seed)?,
        harness::fig11(seed)?,
        harness::fig12(seed)?,
        harness::fig13(seed)?,
        harness::fig14(seed)?,
    ] {
        println!("{}", f.0);
    }
    // Fig 17 is measured on live engines over the Transport subsystem
    // (flat vs hierarchical dispatch, incast as an engine error).
    let (fig17, _) = harness::multinode_ab(seed)?;
    println!("{fig17}");
    // Fig 18 is measured on the live engine (not simulated): f32 vs
    // bf16/f16 wire formats on identical inputs, conformance asserted.
    let (fig18, _) = harness::precision_ab("tiny", 2, seed)?;
    println!("{fig18}");
    Ok(())
}

fn cmd_straggler(argv: &[String]) -> Result<()> {
    let a = Args::new("flashdmoe straggler", "Table 2 straggler delay study")
        .opt("seed", "42", "seed")
        .parse(argv)?;
    let (text, reports) = harness::table2(a.get_usize("seed")? as u64);
    println!("{text}");
    for r in &reports {
        println!(
            "{}: implied idle fraction at p95 = {:.0}%",
            r.platform.name,
            flashdmoe::sim::straggler::idle_fraction(r.summary.p95) * 100.0
        );
    }
    Ok(())
}

fn cmd_calibrate(argv: &[String]) -> Result<()> {
    let a = Args::new("flashdmoe calibrate", "measure tile cost, report implied FLOP/s")
        .opt("preset", "default", "config preset")
        .opt("iters", "20", "tile iterations")
        .parse(argv)?;
    let mut cfg = Config::preset(&a.get("preset"))?;
    let cal = apply_native_calibration(&mut cfg, a.get_usize("iters")?)?;
    println!(
        "backend={} ffn_tile={} implied={:.2} GFLOP/s/processor gate={}",
        cal.backend,
        fmt_time(cal.ffn_tile_secs),
        cal.flops_per_processor / 1e9,
        fmt_time(cal.gate_secs)
    );
    Ok(())
}

fn cmd_inspect(argv: &[String]) -> Result<()> {
    let a = Args::new("flashdmoe inspect", "print config, layout and memory accounting")
        .opt("preset", "default", "config preset")
        .opt("config", "", "config file")
        .parse(argv)?;
    let cfg = load_config(&a)?;
    let dims = flashdmoe::layout::LayoutDims::from_config(&cfg);
    println!("{cfg:#?}");
    println!(
        "layout: P={} E_local={} C={} H={} | L = {} ({} wire) | {} flags | {} tiles/expert",
        dims.p,
        dims.e_local,
        dims.c,
        dims.h,
        fmt_bytes(dims.bytes(cfg.system.wire.bytes() as f64)),
        cfg.system.wire.name(),
        dims.num_flags(),
        dims.tiles_per_expert()
    );
    println!(
        "L1 ffn_tile VMEM estimate: {} (vs ~16 MiB/core budget)",
        fmt_bytes(cfg.model.ffn_tile_vmem_bytes() as f64)
    );
    let rep = flashdmoe::layout::memory_report(
        cfg.system.s_total(),
        cfg.model.e,
        &cfg.model,
        cfg.system.ranks,
        cfg.system.wire,
    );
    println!(
        "memory: Size(L)={} bookkeeping={} total={}",
        fmt_bytes(rep.size_l),
        fmt_bytes(rep.bookkeeping),
        fmt_bytes(rep.total())
    );
    Ok(())
}
