//! Fig 10 — forward latency vs tokens/GPU at 4 and 8 GPUs, E=64,
//! FlashDMoE (fp32) vs fp16 baselines on the calibrated simulator.
//!
//! Serving mode (`SERVING=1`, used by CI): instead of the simulator
//! sweep, drive the real `MoeService` request-level front end with
//! open-loop Poisson traffic and emit `BENCH_pr4_serving.json`
//! (p50/p99 request latency, batch fill, queue depth, throughput;
//! `REQUESTS`/`RATE` env knobs). The single-launch contract is asserted
//! inside the harness.
fn main() {
    if std::env::var("SERVING").map(|v| v == "1").unwrap_or(false) {
        let requests: usize =
            std::env::var("REQUESTS").ok().and_then(|v| v.parse().ok()).unwrap_or(64);
        let rate: f64 =
            std::env::var("RATE").ok().and_then(|v| v.parse().ok()).unwrap_or(500.0);
        let (text, point) = flashdmoe::harness::serving_bench("tiny", requests, rate, 42).unwrap();
        println!("{text}");
        flashdmoe::harness::update_bench_json(
            "BENCH_pr4_serving.json",
            "serving",
            flashdmoe::harness::serving_json(&point),
        )
        .unwrap();
        println!("wrote BENCH_pr4_serving.json (serving section)");
        return;
    }
    let (text, pts) = flashdmoe::harness::fig10(42).unwrap();
    println!("{text}");
    let f = |e: &str| pts.iter().filter(|p| p.engine == e && p.x == 16384.0).map(|p| p.latency).fold(f64::MAX, f64::min);
    println!("speedup at 16K tokens: {:.2}x over Megatron-TE, {:.2}x over FasterMoE (paper: 4.6x / 2.6x at 4 GPUs, up to 6.4x at 8)",
        f("Megatron-TE") / f("FlashDMoE"), f("FasterMoE") / f("FlashDMoE"));
}
