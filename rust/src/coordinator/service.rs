//! `MoeService`: the request-level serving front end — a continuous
//! batcher resident in front of the persistent [`MoeEngine`].
//!
//! The paper's operator is "launch once, stay resident" precisely so a
//! serving batcher can pack the next batch while the current one runs;
//! this module is that batcher. Clients call
//! [`MoeService::enqueue`] with a *variable-length* token sequence and
//! get back a [`RequestHandle`]; a resident batcher thread admits
//! requests from a bounded queue (backpressure per
//! [`Backpressure`]), coalesces them into engine passes under a
//! [`BatchPolicy`] (`max_tokens` caps the pass, `max_delay` bounds how
//! long the oldest admitted request waits for co-travelers), round-robins
//! token rows across ranks into a variable-shape
//! [`PassInput`](super::engine::PassInput) — partially-filled passes
//! compute and ship only the rows that exist — and scatter-gathers pass
//! outputs back into per-request [`RequestResult`]s carrying queue-time
//! and end-to-end latency.
//!
//! Pipelining: the batcher keeps one pass in flight while it packs (and
//! submits) the next, exactly the double-buffered `submit`/`wait`
//! contract the engine exposes — so request admission, host packing and
//! engine compute overlap, and `EngineMetrics::launches` stays 1 for the
//! whole service lifetime.
//!
//! Correctness: an MoE layer is a per-token function (gate, top-k
//! experts, weighted combine, all per row), so batching arbitrary
//! requests together — and splitting an oversize request across passes
//! under [`OversizePolicy::Split`] — never changes any request's output
//! under `RoutingPolicy::Dropless`. (Under a `Capacity` policy, drops
//! depend on what else shares the pass; serve with dropless routing when
//! request-level conformance matters — the service tests do.)
//!
//! Replication: when the config enables a
//! [`ReplicationPolicy`](crate::config::ReplicationPolicy), the batcher
//! calls [`MoeEngine::rebalance`] at its quiet points (queue momentarily
//! drained, no pass in flight), so a long-running service adapts its
//! expert placement to hot experts between passes — outputs are
//! unaffected (the gate-side splitter keeps the combine fold identical).
//!
//! Multi-model: with `max_models > 1` the service front-end serves every
//! resident model of the engine's [`ModelRegistry`](crate::registry) —
//! [`MoeService::register_model`] / [`MoeService::register_delta`] add
//! models while serving, and [`RequestOpts::model`] routes each request.
//! The batcher stops coalescing at a model boundary (a pass never mixes
//! models), so every request's output is bitwise what a dedicated
//! single-model engine would produce.
//!
//! Shutdown ([`MoeService::shutdown`] or drop) stops admission
//! (`enqueue` returns [`ServiceError::ShuttingDown`]), drains every
//! already-queued and in-flight request, then shuts the engine down and
//! joins the batcher — no request is ever silently dropped; abandoning a
//! [`RequestHandle`] cancels its request instead of wedging the batcher.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::Config;
use crate::expert::ModelParams;
use crate::registry::{DeltaSet, ModelHandle, ModelId};
use crate::runtime::ComputeBackend;

use super::engine::{MoeEngine, PassHandle, PassInput};
use super::metrics::{EngineMetrics, ServiceMetrics};
use super::rank::TaskGraphMode;

/// What `enqueue` does when the bounded request queue is full.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backpressure {
    /// Fail fast with [`ServiceError::ServiceFull`] (open-loop clients).
    Reject,
    /// Block the caller until space frees up (closed-loop clients).
    Block,
}

/// What `enqueue` does with a request larger than `max_tokens`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OversizePolicy {
    /// Split the request into `<= max_tokens` chunks served over
    /// multiple passes; the handle completes when every chunk has (MoE
    /// is per-token, so splitting never changes the result).
    Split,
    /// Fail fast with [`ServiceError::TooLarge`].
    Reject,
}

/// Queue discipline for admission order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueueDiscipline {
    /// Strict arrival order.
    Fifo,
    /// Higher [`RequestOpts::priority`] admits first; FIFO within a
    /// priority level.
    Priority,
}

/// The batcher's knobs. Defaults come from
/// [`BatchPolicy::from_config`]: fill a whole engine pass
/// (`max_tokens = ranks × s_rank`, see
/// [`SystemConfig::max_batch_tokens`](crate::config::SystemConfig::max_batch_tokens)),
/// wait at most 2 ms for co-travelers, FIFO admission, a 256-request
/// queue that rejects when full, and oversize requests split.
#[derive(Clone, Debug)]
pub struct BatchPolicy {
    /// Max token rows coalesced into one engine pass. Must be
    /// `1..=ranks × s_rank` (a pass cannot hold more).
    pub max_tokens: usize,
    /// Max time the oldest admitted request waits for the batch to fill
    /// before the pass is submitted anyway.
    pub max_delay: Duration,
    /// Admission order.
    pub priority: QueueDiscipline,
    /// Bounded queue depth, in requests.
    pub queue_requests: usize,
    /// Behavior when the queue is full.
    pub on_full: Backpressure,
    /// Behavior for requests larger than `max_tokens`.
    pub oversize: OversizePolicy,
}

impl BatchPolicy {
    pub fn from_config(cfg: &Config) -> Self {
        Self {
            max_tokens: cfg.system.max_batch_tokens(),
            max_delay: Duration::from_millis(2),
            priority: QueueDiscipline::Fifo,
            queue_requests: 256,
            on_full: Backpressure::Reject,
            oversize: OversizePolicy::Split,
        }
    }
}

/// Per-request options.
#[derive(Clone, Copy, Debug, Default)]
pub struct RequestOpts {
    /// Admission priority under [`QueueDiscipline::Priority`] (higher
    /// admits first); ignored under FIFO.
    pub priority: i32,
    /// Which resident model serves this request (0 = the anchor model
    /// the service was started with; ids ≥ 1 come from
    /// [`MoeEngine::register_model`](super::engine::MoeEngine::register_model)
    /// / `register_delta` on the underlying engine). The batcher never
    /// mixes models in a pass: coalescing stops at a model boundary, so
    /// co-resident models ride separate passes and each request's output
    /// is bitwise what a dedicated single-model engine would produce. A
    /// request naming a model that is not resident at admission fails at
    /// submit, like any other engine refusal.
    pub model: ModelId,
    /// Client latency budget, measured from `enqueue`. A request whose
    /// budget has already expired when the batcher would admit it is
    /// failed ("deadline exceeded before admission") instead of being
    /// packed into a pass — under degraded capacity (a dead rank, passes
    /// retrying) this sheds doomed work so live requests keep their
    /// budgets. Counted in
    /// [`ServiceMetrics::deadline_misses`](super::metrics::ServiceMetrics::deadline_misses).
    /// `None` (the default) means no deadline.
    pub deadline: Option<Duration>,
}

/// Why `enqueue` refused a request. Everything here is a *client-side*
/// refusal — once a request is accepted it is always either served or
/// (only if its handle is dropped) cancelled.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServiceError {
    /// Zero-token requests carry no work.
    EmptyRequest,
    /// Flat token buffer is not a multiple of the embedding width H.
    RaggedRequest { len: usize, h: usize },
    /// Request exceeds `max_tokens` and the policy is
    /// [`OversizePolicy::Reject`].
    TooLarge { rows: usize, max_tokens: usize },
    /// Bounded queue full and the policy is [`Backpressure::Reject`].
    ServiceFull,
    /// The service is shutting down (or already shut down).
    ShuttingDown,
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServiceError::EmptyRequest => write!(f, "request has zero tokens"),
            ServiceError::RaggedRequest { len, h } => {
                write!(f, "request length {len} is not a multiple of H = {h}")
            }
            ServiceError::TooLarge { rows, max_tokens } => {
                write!(f, "request of {rows} rows exceeds max_tokens = {max_tokens}")
            }
            ServiceError::ServiceFull => write!(f, "request queue is full"),
            ServiceError::ShuttingDown => write!(f, "service is shutting down"),
        }
    }
}

impl std::error::Error for ServiceError {}

/// A completed request: output rows plus its serving timeline.
#[derive(Clone, Debug)]
pub struct RequestResult {
    /// (rows, H) row-major output, row i the MoE output of input row i.
    pub tokens: Vec<f32>,
    /// Token rows in the request.
    pub rows: usize,
    /// Enqueue → first admission into a pass.
    pub queue_secs: f64,
    /// Enqueue → completion (end-to-end request latency).
    pub latency_secs: f64,
    /// Engine passes this request spanned (1 unless split).
    pub passes: usize,
}

// ---------------------------------------------------------------------------
// internals
// ---------------------------------------------------------------------------

struct CellState {
    out: Vec<f32>,
    /// Chunks not yet fulfilled; the request completes at 0.
    remaining: usize,
    /// Earliest admission of any chunk.
    first_admitted: Option<Instant>,
    /// Stamped by the batcher the moment the last chunk lands, so a
    /// client that waits late still reads the true completion latency.
    completed_at: Option<Instant>,
    passes: usize,
    error: Option<String>,
    done: bool,
}

/// One request's completion cell, shared between its [`RequestHandle`]
/// and the batcher. Lock order: a cell lock is always taken *leaf-most*
/// (never while holding the queue lock and vice versa).
struct RequestCell {
    state: Mutex<CellState>,
    cv: Condvar,
    cancelled: AtomicBool,
    /// Metrics latch: each accepted request is claimed by exactly one of
    /// served / cancelled / failed, whatever races between a dropped
    /// handle, a purge, and an engine error (a cancelled split request
    /// whose other chunk rides a failing pass must not count twice).
    accounted: AtomicBool,
    enqueued_at: Instant,
    rows: usize,
}

impl RequestCell {
    /// Claim this request for one metrics bucket; true exactly once.
    fn claim(&self) -> bool {
        !self.accounted.swap(true, Ordering::AcqRel)
    }

    /// Fail the request; returns true iff this call transitioned it to
    /// done (completion/error visibility — metrics go through `claim`).
    fn fail(&self, msg: String) -> bool {
        let mut st = self.state.lock().unwrap();
        if st.done {
            return false;
        }
        st.error = Some(msg);
        st.done = true;
        st.completed_at = Some(Instant::now());
        self.cv.notify_all();
        true
    }
}

/// Handle to an accepted request. `wait()` blocks for the
/// [`RequestResult`]; dropping the handle unwaited cancels the request
/// (queued chunks are discarded at admission; a chunk already in flight
/// completes harmlessly and its result is discarded).
pub struct RequestHandle {
    cell: Arc<RequestCell>,
    waited: bool,
}

impl RequestHandle {
    /// Token rows in the request.
    pub fn rows(&self) -> usize {
        self.cell.rows
    }

    /// Block until the request completes and return its result.
    pub fn wait(mut self) -> Result<RequestResult> {
        self.waited = true;
        let cell = &*self.cell;
        let mut st = cell.state.lock().unwrap();
        while !st.done {
            st = cell.cv.wait(st).unwrap();
        }
        if let Some(e) = &st.error {
            anyhow::bail!("request failed: {e}");
        }
        let completed = st.completed_at.unwrap_or_else(Instant::now);
        Ok(RequestResult {
            tokens: std::mem::take(&mut st.out),
            rows: cell.rows,
            queue_secs: st
                .first_admitted
                .map(|t| t.duration_since(cell.enqueued_at).as_secs_f64())
                .unwrap_or(0.0),
            latency_secs: completed.duration_since(cell.enqueued_at).as_secs_f64(),
            passes: st.passes,
        })
    }
}

impl Drop for RequestHandle {
    fn drop(&mut self) {
        if !self.waited {
            self.cell.cancelled.store(true, Ordering::Release);
        }
    }
}

/// One `<= max_tokens` slice of a request, the unit the batcher admits.
struct Chunk {
    cell: Arc<RequestCell>,
    tokens: Vec<f32>,
    rows: usize,
    /// Row offset of this chunk in its request's output.
    out_offset: usize,
    priority: i32,
    /// Resident model serving this chunk — the batcher coalesces only
    /// same-model chunks into a pass.
    model: ModelId,
    /// Absolute admission deadline (`enqueued_at + RequestOpts::deadline`);
    /// every chunk of a request carries the same instant.
    deadline: Option<Instant>,
    /// Last chunk of its request (drives request-level queue accounting).
    last: bool,
}

struct QueueState {
    chunks: VecDeque<Chunk>,
    /// Requests with at least one chunk still queued (the bounded-depth
    /// unit).
    queued_requests: usize,
    /// False once shutdown begins; `enqueue` refuses from then on.
    accepting: bool,
    metrics: ServiceMetrics,
    /// Final engine metrics, published by the batcher as it exits.
    engine_metrics: Option<EngineMetrics>,
}

struct ServiceShared {
    h: usize,
    ranks: usize,
    policy: BatchPolicy,
    queue: Mutex<QueueState>,
    /// Batcher wakeups (new work / shutdown).
    work_cv: Condvar,
    /// Blocked enqueuers ([`Backpressure::Block`]) wait here for space.
    space_cv: Condvar,
}

/// A pass in flight on the engine, with everything needed to scatter its
/// outputs back to the requests that rode in it.
struct InFlight {
    handle: PassHandle,
    /// (chunk, base virtual-row offset) in admission order.
    chunks: Vec<(Chunk, usize)>,
    admitted_at: Instant,
}

/// Final report returned by [`MoeService::shutdown`].
#[derive(Clone, Debug)]
pub struct ServiceReport {
    pub service: ServiceMetrics,
    /// Engine-lifetime metrics; `launches == 1` for the whole service
    /// lifetime (the batcher starts the engine exactly once).
    pub engine: EngineMetrics,
}

/// The request-level serving API. See the module docs for the design;
/// the one-line version:
///
/// ```text
/// MoeService::start(cfg, params, backend, mode, policy)  // engine launched ONCE
///   -> enqueue(tokens, opts) -> RequestHandle             //  × N clients, concurrent
///   -> handle.wait()         -> RequestResult             //  per request
/// -> shutdown() / drop   // admission closed, queue drained, engine joined
/// ```
pub struct MoeService {
    shared: Arc<ServiceShared>,
    /// Shared with the batcher thread; the service handle uses it for
    /// model registration (epoch-fenced on the engine side, so it is
    /// safe concurrent with the batcher's passes). The engine shuts down
    /// when the last `Arc` drops — after the batcher has exited and
    /// published its final metrics.
    engine: Arc<MoeEngine>,
    batcher: Option<JoinHandle<()>>,
}

impl MoeService {
    /// Validate the policy, start the persistent engine (the single
    /// launch of the service lifetime) and spawn the resident batcher.
    pub fn start(
        cfg: Config,
        params: Arc<ModelParams>,
        backend: Arc<dyn ComputeBackend>,
        mode: TaskGraphMode,
        policy: BatchPolicy,
    ) -> Result<Self> {
        cfg.validate()?;
        anyhow::ensure!(policy.max_tokens > 0, "max_tokens must be positive");
        anyhow::ensure!(
            policy.max_tokens <= cfg.system.max_batch_tokens(),
            "max_tokens ({}) exceeds one pass's row capacity ({} = ranks x s_rank)",
            policy.max_tokens,
            cfg.system.max_batch_tokens()
        );
        anyhow::ensure!(policy.queue_requests > 0, "queue_requests must be positive");
        let engine = Arc::new(MoeEngine::start(cfg.clone(), params, backend, mode)?);
        let shared = Arc::new(ServiceShared {
            h: cfg.model.h,
            ranks: cfg.system.ranks,
            policy,
            queue: Mutex::new(QueueState {
                chunks: VecDeque::new(),
                queued_requests: 0,
                accepting: true,
                metrics: ServiceMetrics::default(),
                engine_metrics: None,
            }),
            work_cv: Condvar::new(),
            space_cv: Condvar::new(),
        });
        let batcher = {
            let shared = shared.clone();
            let engine = engine.clone();
            std::thread::Builder::new()
                .name("flash-batcher".into())
                .spawn(move || batcher_main(shared, engine))
                .expect("spawn service batcher")
        };
        Ok(Self { shared, engine, batcher: Some(batcher) })
    }

    /// Convenience: start with [`BatchPolicy::from_config`] defaults.
    pub fn with_defaults(
        cfg: Config,
        params: Arc<ModelParams>,
        backend: Arc<dyn ComputeBackend>,
        mode: TaskGraphMode,
    ) -> Result<Self> {
        let policy = BatchPolicy::from_config(&cfg);
        Self::start(cfg, params, backend, mode, policy)
    }

    /// Submit one request: a flat `(rows, H)` row-major token buffer,
    /// `rows >= 1`. Returns immediately with a [`RequestHandle`] (or an
    /// admission refusal — see [`ServiceError`]); the batcher coalesces
    /// the request into one or more engine passes per the
    /// [`BatchPolicy`].
    pub fn enqueue(
        &self,
        tokens: Vec<f32>,
        opts: RequestOpts,
    ) -> std::result::Result<RequestHandle, ServiceError> {
        let h = self.shared.h;
        let policy = &self.shared.policy;
        if tokens.is_empty() {
            self.count_rejected();
            return Err(ServiceError::EmptyRequest);
        }
        if tokens.len() % h != 0 {
            self.count_rejected();
            return Err(ServiceError::RaggedRequest { len: tokens.len(), h });
        }
        let rows = tokens.len() / h;
        if rows > policy.max_tokens && policy.oversize == OversizePolicy::Reject {
            self.count_rejected();
            return Err(ServiceError::TooLarge { rows, max_tokens: policy.max_tokens });
        }

        let mut q = self.shared.queue.lock().unwrap();
        loop {
            if !q.accepting {
                q.metrics.requests_rejected += 1;
                return Err(ServiceError::ShuttingDown);
            }
            if q.queued_requests < policy.queue_requests {
                break;
            }
            match policy.on_full {
                Backpressure::Reject => {
                    q.metrics.requests_rejected += 1;
                    return Err(ServiceError::ServiceFull);
                }
                Backpressure::Block => q = self.shared.space_cv.wait(q).unwrap(),
            }
        }

        let cell = Arc::new(RequestCell {
            state: Mutex::new(CellState {
                out: vec![0.0f32; rows * h],
                remaining: rows.div_ceil(policy.max_tokens),
                first_admitted: None,
                completed_at: None,
                passes: 0,
                error: None,
                done: false,
            }),
            cv: Condvar::new(),
            cancelled: AtomicBool::new(false),
            accounted: AtomicBool::new(false),
            enqueued_at: Instant::now(),
            rows,
        });
        // Chunk the request ([`OversizePolicy::Split`]; a request within
        // max_tokens is exactly one chunk — the dominant case, which
        // moves the caller's buffer instead of copying it) and insert
        // per the discipline.
        let insert = |q: &mut QueueState, chunk: Chunk| match policy.priority {
            QueueDiscipline::Fifo => q.chunks.push_back(chunk),
            QueueDiscipline::Priority => {
                // stable: after the last chunk with priority >= ours
                let pos = q
                    .chunks
                    .iter()
                    .position(|c| c.priority < chunk.priority)
                    .unwrap_or(q.chunks.len());
                q.chunks.insert(pos, chunk);
            }
        };
        let n_chunks = rows.div_ceil(policy.max_tokens);
        let deadline = opts.deadline.map(|d| cell.enqueued_at + d);
        if n_chunks == 1 {
            let chunk = Chunk {
                cell: cell.clone(),
                tokens,
                rows,
                out_offset: 0,
                priority: opts.priority,
                model: opts.model,
                deadline,
                last: true,
            };
            insert(&mut q, chunk);
        } else {
            for i in 0..n_chunks {
                let lo = i * policy.max_tokens;
                let hi = ((i + 1) * policy.max_tokens).min(rows);
                let chunk = Chunk {
                    cell: cell.clone(),
                    tokens: tokens[lo * h..hi * h].to_vec(),
                    rows: hi - lo,
                    out_offset: lo,
                    priority: opts.priority,
                    model: opts.model,
                    deadline,
                    last: i + 1 == n_chunks,
                };
                insert(&mut q, chunk);
            }
        }
        q.queued_requests += 1;
        q.metrics.requests_enqueued += 1;
        q.metrics.max_queue_depth = q.metrics.max_queue_depth.max(q.queued_requests);
        self.shared.work_cv.notify_all();
        Ok(RequestHandle { cell, waited: false })
    }

    fn count_rejected(&self) {
        self.shared.queue.lock().unwrap().metrics.requests_rejected += 1;
    }

    /// Snapshot of the cumulative service metrics.
    pub fn metrics(&self) -> ServiceMetrics {
        self.shared.queue.lock().unwrap().metrics.clone()
    }

    /// Register a full expert set as an additional resident model on the
    /// underlying engine (fingerprint-deduped against the shared packed
    /// cache; epoch-fenced, so safe while the batcher serves). Requests
    /// route to it via [`RequestOpts::model`].
    pub fn register_model(&self, params: Arc<ModelParams>) -> Result<ModelHandle> {
        self.engine.register_model(params)
    }

    /// Register a LoRA-style delta variant of resident model `base`: it
    /// shares the base's packed weights and costs only the delta bytes.
    pub fn register_delta(&self, base: ModelId, delta: Arc<DeltaSet>) -> Result<ModelHandle> {
        self.engine.register_delta(base, delta)
    }

    /// Evict a resident model (the anchor and depended-on models refuse).
    /// Queued requests naming the evicted model fail at submit.
    pub fn evict_model(&self, model: ModelId) -> Result<()> {
        self.engine.evict_model(model)
    }

    /// Total resident weight bytes across all models, shared packed
    /// regions counted once.
    pub fn resident_bytes(&self) -> usize {
        self.engine.resident_bytes()
    }

    /// Stop admission, drain every queued and in-flight request, shut the
    /// engine down and join the batcher. Also runs on drop; calling it
    /// explicitly returns the final [`ServiceReport`].
    pub fn shutdown(mut self) -> ServiceReport {
        self.shutdown_and_join();
        let q = self.shared.queue.lock().unwrap();
        ServiceReport {
            service: q.metrics.clone(),
            engine: q.engine_metrics.clone().unwrap_or_default(),
        }
    }

    fn shutdown_and_join(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.accepting = false;
            self.shared.work_cv.notify_all();
            self.shared.space_cv.notify_all();
        }
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
    }
}

impl Drop for MoeService {
    fn drop(&mut self) {
        self.shutdown_and_join();
    }
}

// ---------------------------------------------------------------------------
// the batcher thread
// ---------------------------------------------------------------------------

enum Admission {
    /// A coalesced batch ready to pack and submit.
    Batch(Vec<Chunk>),
    /// Queue empty with a pass still in flight: go collect it.
    Collect,
    /// Queue drained and admission closed: exit.
    Exit,
}

fn batcher_main(shared: Arc<ServiceShared>, engine: Arc<MoeEngine>) {
    let mut in_flight: Option<InFlight> = None;
    loop {
        match admit(&shared, in_flight.is_some()) {
            Admission::Batch(chunks) => {
                let admitted_at = Instant::now();
                // Deadline-aware admission: a request whose client budget
                // already expired is failed here, not packed — under
                // degraded capacity this sheds doomed work so requests
                // with live budgets keep theirs. (Cell locks are taken
                // with the queue lock released, per the lock order.)
                let (chunks, expired): (Vec<Chunk>, Vec<Chunk>) = chunks
                    .into_iter()
                    .partition(|c| c.deadline.map_or(true, |d| admitted_at < d));
                if !expired.is_empty() {
                    let missed = expired
                        .iter()
                        .filter(|c| {
                            c.cell.fail("deadline exceeded before admission".into());
                            c.cell.claim()
                        })
                        .count() as u64;
                    let mut q = shared.queue.lock().unwrap();
                    q.metrics.deadline_misses += missed;
                    q.metrics.requests_failed += missed;
                }
                if chunks.is_empty() {
                    continue;
                }
                let input = pack(&shared, &chunks);
                match engine.submit_pass(input) {
                    Ok(handle) => {
                        let mut base = 0usize;
                        let fly = InFlight {
                            handle,
                            chunks: chunks
                                .into_iter()
                                .map(|c| {
                                    let b = base;
                                    base += c.rows;
                                    (c, b)
                                })
                                .collect(),
                            admitted_at,
                        };
                        // pipelined: pass N stays in flight while pass
                        // N+1 was packed and submitted above
                        if let Some(prev) = in_flight.replace(fly) {
                            collect(&shared, prev);
                        }
                    }
                    Err(e) => {
                        let msg = format!("engine submit failed: {e:#}");
                        let failed = chunks
                            .iter()
                            .filter(|c| c.cell.fail(msg.clone()) && c.cell.claim())
                            .count() as u64;
                        let mut q = shared.queue.lock().unwrap();
                        q.metrics.passes_failed += 1;
                        q.metrics.requests_failed += failed;
                    }
                }
            }
            Admission::Collect => {
                if let Some(prev) = in_flight.take() {
                    collect(&shared, prev);
                }
                // Quiet point: the queue was empty and the last in-flight
                // pass just landed, so the engine has no assigned epochs —
                // the one place the batcher can swap the expert placement
                // (hot-expert replication, see `MoeEngine::rebalance`)
                // without stalling behind a running pass. A no-op unless
                // the config enables a `ReplicationPolicy`; an error here
                // keeps the old placement, which is always safe to serve.
                let _ = engine.rebalance();
            }
            Admission::Exit => {
                if let Some(prev) = in_flight.take() {
                    collect(&shared, prev);
                }
                break;
            }
        }
    }
    // Publish the engine's final accounting; the engine itself shuts
    // down (rank actors joined) when the service handle drops its
    // remaining `Arc`.
    let em = engine.metrics();
    drop(engine);
    shared.queue.lock().unwrap().engine_metrics = Some(em);
}

/// Drop cancelled chunks in place, keeping the request-level accounting
/// straight. Caller holds the queue lock.
fn purge_cancelled(shared: &ServiceShared, q: &mut QueueState) {
    let mut freed = false;
    let QueueState { chunks, queued_requests, metrics, .. } = q;
    chunks.retain(|c| {
        if !c.cell.cancelled.load(Ordering::Acquire) {
            return true;
        }
        if c.last {
            *queued_requests -= 1;
            if c.cell.claim() {
                metrics.requests_cancelled += 1;
            }
            freed = true;
        }
        false
    });
    if freed {
        shared.space_cv.notify_all();
    }
}

/// Admit the next batch: wait for work, then coalesce chunks until the
/// batch is full or the oldest waiter's `max_delay` expires.
fn admit(shared: &ServiceShared, have_in_flight: bool) -> Admission {
    let policy = &shared.policy;
    let mut q = shared.queue.lock().unwrap();
    'restart: loop {
        loop {
            purge_cancelled(shared, &mut q);
            if !q.chunks.is_empty() {
                break;
            }
            if have_in_flight {
                // Nothing to pack; the in-flight pass's requests are
                // waiting on the batcher's collect, which nothing else
                // performs.
                return Admission::Collect;
            }
            if !q.accepting {
                return Admission::Exit;
            }
            q = shared.work_cv.wait(q).unwrap();
        }

        let mut batch: Vec<Chunk> = Vec::new();
        let mut rows = 0usize;
        // A pass never mixes models: the batch's model is fixed by its
        // first admitted chunk, and coalescing stops at a model boundary
        // (the other model's chunks lead the *next* batch).
        let batch_model = q.chunks.front().unwrap().model;
        // The coalescing window closes max_delay after the oldest queued
        // chunk's *enqueue* (not admission), so a request's time-to-pass
        // is bounded even when traffic trickles.
        let deadline = q.chunks.front().unwrap().cell.enqueued_at + policy.max_delay;
        loop {
            // admit everything that fits right now (chunks are
            // <= max_tokens by construction, so an empty batch always
            // admits the front chunk)
            let mut model_boundary = false;
            while let Some(c) = q.chunks.front() {
                if c.cell.cancelled.load(Ordering::Acquire) {
                    purge_cancelled(shared, &mut q);
                    continue;
                }
                if c.model != batch_model {
                    model_boundary = true;
                    break;
                }
                if rows + c.rows > policy.max_tokens {
                    break;
                }
                let c = q.chunks.pop_front().unwrap();
                if c.last {
                    q.queued_requests -= 1;
                    shared.space_cv.notify_all();
                }
                rows += c.rows;
                batch.push(c);
            }
            if rows >= policy.max_tokens || !q.accepting {
                break; // full, or shutting down: don't dawdle
            }
            if model_boundary && !batch.is_empty() {
                break; // submit now; the next model's traffic must not wait on our window
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (qq, timeout) = shared.work_cv.wait_timeout(q, deadline - now).unwrap();
            q = qq;
            if timeout.timed_out() {
                break;
            }
        }
        // drop chunks whose requests were abandoned between admission
        // and packing (claiming each such request once, via its last
        // chunk — queue-depth accounting already happened at pop); an
        // all-cancelled batch restarts the wait
        batch.retain(|c| {
            let cancelled = c.cell.cancelled.load(Ordering::Acquire);
            if cancelled && c.last && c.cell.claim() {
                q.metrics.requests_cancelled += 1;
            }
            !cancelled
        });
        if batch.is_empty() {
            continue 'restart;
        }
        return Admission::Batch(batch);
    }
}

/// Pack a batch into a variable-shape pass: virtual row v (chunks
/// concatenated in admission order) goes to rank `v % ranks`, local row
/// `v / ranks` — round-robin, so per-rank loads differ by at most one
/// row and every rank's `s_r <= ceil(total / ranks) <= s_rank`.
fn pack(shared: &ServiceShared, batch: &[Chunk]) -> PassInput {
    let (h, ranks) = (shared.h, shared.ranks);
    let total: usize = batch.iter().map(|c| c.rows).sum();
    let counts: Vec<usize> =
        (0..ranks).map(|r| total / ranks + usize::from(r < total % ranks)).collect();
    let mut per_rank: Vec<Vec<f32>> =
        counts.iter().map(|&c| vec![0.0f32; c * h]).collect();
    let mut v = 0usize;
    for c in batch {
        for j in 0..c.rows {
            let (dst, pos) = (v % ranks, v / ranks);
            per_rank[dst][pos * h..(pos + 1) * h]
                .copy_from_slice(&c.tokens[j * h..(j + 1) * h]);
            v += 1;
        }
    }
    // `admit` never mixes models in a batch, so the first chunk's model
    // is the batch's model.
    PassInput::for_model(per_rank, batch.first().map_or(0, |c| c.model))
}

/// Collect one in-flight pass and scatter its outputs back to the
/// requests that rode in it (inverse of [`pack`]'s round-robin).
fn collect(shared: &ServiceShared, fly: InFlight) {
    let (h, ranks) = (shared.h, shared.ranks);
    let admitted_at = fly.admitted_at;
    match fly.handle.wait() {
        Ok(res) => {
            let mut served_requests = 0u64;
            let mut served_tokens = 0u64;
            for (c, base) in &fly.chunks {
                let mut st = c.cell.state.lock().unwrap();
                if st.done {
                    continue; // another chunk already failed the request
                }
                for j in 0..c.rows {
                    let v = base + j;
                    let (src, pos) = (v % ranks, v / ranks);
                    let row = &res.outputs[src][pos * h..(pos + 1) * h];
                    st.out[(c.out_offset + j) * h..(c.out_offset + j + 1) * h]
                        .copy_from_slice(row);
                }
                if st.first_admitted.is_none() {
                    st.first_admitted = Some(admitted_at);
                }
                st.passes += 1;
                st.remaining -= 1;
                if st.remaining == 0 {
                    st.done = true;
                    st.completed_at = Some(Instant::now());
                    if c.cell.claim() {
                        served_requests += 1;
                        served_tokens += c.cell.rows as u64;
                    }
                    c.cell.cv.notify_all();
                }
            }
            let mut q = shared.queue.lock().unwrap();
            q.metrics.passes += 1;
            q.metrics.batch_fill_sum += res.metrics.batch_fill();
            q.metrics.requests_served += served_requests;
            q.metrics.tokens_served += served_tokens;
        }
        Err(e) => {
            let msg = format!("engine pass failed: {e:#}");
            let failed = fly
                .chunks
                .iter()
                .filter(|(c, _)| c.cell.fail(msg.clone()) && c.cell.claim())
                .count() as u64;
            let mut q = shared.queue.lock().unwrap();
            q.metrics.passes_failed += 1;
            q.metrics.requests_failed += failed;
        }
    }
}
