//! Fault-tolerance conformance: deterministic fault injection at the
//! transport seam, transparent epoch-fenced pass retry, and
//! degraded-capacity operation after a permanent rank death.
//!
//! The headline contract: a pass that hits a *transient* injected fault
//! and is retried must produce **bitwise identical** outputs to the same
//! pass on a fault-free engine — the retry is a clean re-execution under
//! a fresh epoch, never a partial resume — across routing policies and
//! dispatch modes. A *permanent* rank death mid-run swaps in a degraded
//! placement at an epoch quiet point; the engine keeps serving, with the
//! dead rank's un-replicated experts explicitly accounted unavailable.
//! At the service level, the request ledger
//! (`enqueued == served + cancelled + failed`) must balance under
//! injected pass failures, split requests, and deadline shedding.

use std::sync::Arc;

use flashdmoe::config::Config;
use flashdmoe::coordinator::{BatchPolicy, MoeEngine, MoeService, RequestOpts, TaskGraphMode};
use flashdmoe::expert::ModelParams;
use flashdmoe::runtime::{ComputeBackend, NativeBackend};
use flashdmoe::util::prng::Rng;
use flashdmoe::workload::{skewed_tokens, Skew};

/// Small live-engine config; `ranks` must divide the tiny model's expert
/// count. `dispatch == "hierarchical"` splits the ranks over 2 nodes.
fn chaos_cfg(ranks: usize, policy: &str, dispatch: &str) -> Config {
    let mut cfg = Config::preset("tiny").unwrap();
    cfg.set("ranks", &ranks.to_string()).unwrap();
    cfg.set("tokens", "128").unwrap();
    cfg.set("routing_policy", policy).unwrap();
    if dispatch == "hierarchical" {
        cfg.set("nodes", "2").unwrap();
    }
    cfg.set("dispatch", dispatch).unwrap();
    cfg.validate().unwrap();
    cfg
}

/// The deterministic transient schedule: every cross-rank transfer of
/// pass epoch 2 fails, nothing else does; two retries of budget.
fn add_transient_window(cfg: &mut Config) {
    cfg.set("retry_limit", "2").unwrap();
    cfg.set("fault_seed", "42").unwrap();
    cfg.set("fault_transient_rate", "1.0").unwrap();
    cfg.set("fault_transient_from", "2").unwrap();
    cfg.set("fault_transient_until", "3").unwrap();
    cfg.validate().unwrap();
}

fn zipf_inputs(cfg: &Config, params: &ModelParams, seed: u64) -> Vec<Vec<f32>> {
    let (h, e) = (cfg.model.h, cfg.model.e);
    (0..cfg.system.ranks)
        .map(|r| {
            let mut rng = Rng::new(seed).fork(0xC4A0_0000 + r as u64);
            skewed_tokens(&params.wg, h, e, cfg.system.s_rank, Skew::Zipf, &mut rng)
        })
        .collect()
}

fn start(cfg: &Config, params: &Arc<ModelParams>) -> MoeEngine {
    let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(cfg));
    MoeEngine::start(cfg.clone(), params.clone(), backend, TaskGraphMode::Fused).unwrap()
}

fn assert_bitwise(a: &[Vec<f32>], b: &[Vec<f32>], what: &str) {
    for (r, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.len(), y.len(), "{what}: rank {r} output shape diverged");
        for (i, (p, q)) in x.iter().zip(y).enumerate() {
            assert_eq!(
                p.to_bits(),
                q.to_bits(),
                "{what}: rank {r} elem {i}: {p} != {q} (bitwise)"
            );
        }
    }
}

/// A transiently-faulted pass, after its transparent retry, must be
/// bitwise identical to the fault-free run — for every routing policy ×
/// dispatch mode, and across flat rank counts.
#[test]
fn transient_fault_retry_is_bitwise_identical() {
    let seed = 42;
    let mut cases: Vec<(usize, &str, &str)> = vec![(2, "dropless", "flat")];
    for policy in ["capacity:1.0", "dropless"] {
        for dispatch in ["flat", "hierarchical"] {
            cases.push((4, policy, dispatch));
        }
    }
    for (ranks, policy, dispatch) in cases {
        let clean_cfg = chaos_cfg(ranks, policy, dispatch);
        let mut fault_cfg = chaos_cfg(ranks, policy, dispatch);
        add_transient_window(&mut fault_cfg);
        let params = Arc::new(ModelParams::generate(&clean_cfg, seed));
        let inputs = zipf_inputs(&clean_cfg, &params, seed);
        let what = format!("{ranks} ranks, {policy}, {dispatch}");

        let clean = start(&clean_cfg, &params);
        let mut clean_outs = Vec::new();
        for _ in 0..3 {
            clean_outs.push(clean.submit(&inputs).unwrap().wait().unwrap().outputs);
        }
        clean.shutdown();

        let faulted = start(&fault_cfg, &params);
        for (pass, want) in clean_outs.iter().enumerate() {
            let res = faulted.submit(&inputs).unwrap().wait().unwrap_or_else(|e| {
                panic!("{what}: pass {} not recovered: {e:#}", pass + 1)
            });
            if pass == 1 {
                // epoch 2 is the faulted one; its wait() must have
                // resubmitted exactly once (epoch 3, outside the window)
                assert_eq!(res.metrics.retries, 1, "{what}: pass 2 retry count");
            } else {
                assert_eq!(res.metrics.retries, 0, "{what}: pass {} retried", pass + 1);
            }
            assert_bitwise(want, &res.outputs, &format!("{what}, pass {}", pass + 1));
        }
        let em = faulted.metrics();
        assert!(em.faults_injected >= 1, "{what}: no faults actually injected");
        assert_eq!(em.retries, 1, "{what}: engine retry ledger");
        faulted.shutdown();
    }
}

/// With the retry budget exhausted (or zero), the injected fault
/// surfaces to the caller as a pass error naming the fault — never a
/// wedge, never a silent wrong answer.
#[test]
fn retry_exhaustion_surfaces_the_fault() {
    let seed = 7;
    for limit in ["0", "2"] {
        let mut cfg = chaos_cfg(4, "dropless", "flat");
        cfg.set("retry_limit", limit).unwrap();
        cfg.set("fault_seed", "7").unwrap();
        cfg.set("fault_transient_rate", "1.0").unwrap();
        cfg.set("fault_transient_from", "1").unwrap();
        cfg.set("fault_transient_until", "0").unwrap(); // open-ended: every pass
        cfg.validate().unwrap();
        let params = Arc::new(ModelParams::generate(&cfg, seed));
        let inputs = zipf_inputs(&cfg, &params, seed);
        let engine = start(&cfg, &params);
        let err = engine.submit(&inputs).unwrap().wait().unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("injected transient fault"),
            "retry_limit={limit}: error lost the fault cause: {msg}"
        );
        // the engine is still alive and answers shape-valid errors, not wedges
        let err2 = engine.submit(&inputs).unwrap().wait().unwrap_err();
        assert!(format!("{err2:#}").contains("injected transient fault"));
        engine.shutdown();
    }
}

/// A permanent rank death mid-run: the next `wait()` swaps in the
/// degraded placement at the epoch quiet point and retries; replicas
/// keep the dead rank's hot experts servable, un-replicated experts are
/// explicitly accounted unavailable, the dead rank's submitted rows are
/// transparently repacked onto survivors — and the engine keeps serving.
#[test]
fn permanent_death_degrades_capacity_and_keeps_serving() {
    let seed = 42;
    let mut cfg = chaos_cfg(4, "dropless", "flat");
    // replicas so the dead rank's hot experts survive elsewhere
    cfg.set("replicate_top", "2").unwrap();
    cfg.set("replicas", "2").unwrap();
    cfg.set("replication_hysteresis", "1.2").unwrap();
    cfg.set("ewma_alpha", "0.5").unwrap();
    cfg.set("retry_limit", "2").unwrap();
    cfg.set("fault_seed", "42").unwrap();
    cfg.set("fault_kill_rank", "3").unwrap();
    cfg.set("fault_kill_epoch", "5").unwrap();
    cfg.validate().unwrap();
    let params = Arc::new(ModelParams::generate(&cfg, seed));
    // Half-filled passes: the degraded retry repacks the dead rank's
    // rows onto the survivors' *spare* capacity, so the pass must not
    // arrive full (a full pass over a dead rank is a legitimate
    // degraded-capacity error, tested implicitly by `repack_inputs`).
    let (h, e) = (cfg.model.h, cfg.model.e);
    let inputs: Vec<Vec<f32>> = (0..cfg.system.ranks)
        .map(|r| {
            let mut rng = Rng::new(seed).fork(0xC4A0_0000 + r as u64);
            skewed_tokens(&params.wg, h, e, cfg.system.s_rank / 2, Skew::Zipf, &mut rng)
        })
        .collect();
    let submit = |engine: &MoeEngine| {
        engine.submit_pass(flashdmoe::coordinator::PassInput::new(inputs.clone())).unwrap()
    };
    let engine = start(&cfg, &params);

    // epochs 1-3: warm the load tracker; rebalance installs replicas
    for _ in 0..3 {
        submit(&engine).wait().unwrap();
    }
    assert!(engine.rebalance().unwrap(), "Zipf skew must replicate");
    // epoch 4: last healthy pass
    submit(&engine).wait().unwrap();
    assert!(!engine.placement().degraded());

    // epoch 5: rank 3 is dead; wait() must degrade + retry transparently
    let res = submit(&engine)
        .wait()
        .expect("pass over the kill epoch must recover via degrade + retry");
    assert_eq!(res.metrics.retries, 1, "exactly one resubmission");
    let placement = engine.placement();
    assert!(placement.degraded(), "placement must be degraded after the kill");
    assert_eq!(placement.failed_ranks(), vec![3], "rank 3 is the corpse");
    assert_eq!(
        res.metrics.experts_unavailable,
        placement.unavailable_experts().len(),
        "pass metrics must account the placement's unavailable experts"
    );
    // the dead rank's submitted rows came back in submission shape
    assert_eq!(res.outputs[3].len(), inputs[3].len(), "repacked rows not restored");

    // the engine keeps serving degraded passes, first try, no retries
    for _ in 0..2 {
        let r = submit(&engine).wait().unwrap();
        assert_eq!(r.metrics.retries, 0, "degraded steady state must not retry");
        assert_eq!(r.outputs[3].len(), inputs[3].len());
    }
    let em = engine.metrics();
    assert!(em.degraded_passes >= 3, "retried + steady passes ran degraded");
    assert!(em.faults_injected >= 1);
    engine.shutdown();
}

/// Satellite (c): the request ledger balances under injected pass
/// failures — `enqueued == served + cancelled + failed` — including a
/// split request spanning a failing and succeeding pass, and an
/// abandoned handle racing the failure.
#[test]
fn service_ledger_balances_under_pass_failures() {
    let seed = 11;
    let mut cfg = chaos_cfg(4, "dropless", "flat");
    // pass epoch 2 fails, everything else succeeds; no retry budget, so
    // the failure surfaces to the requests that rode in it
    cfg.set("retry_limit", "0").unwrap();
    cfg.set("fault_seed", "11").unwrap();
    cfg.set("fault_transient_rate", "1.0").unwrap();
    cfg.set("fault_transient_from", "2").unwrap();
    cfg.set("fault_transient_until", "3").unwrap();
    cfg.validate().unwrap();
    let params = Arc::new(ModelParams::generate(&cfg, seed));
    let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(&cfg));
    let mut policy = BatchPolicy::from_config(&cfg);
    // one 32-row chunk fills a pass exactly, so a 96-row request spans
    // three passes — epochs 1, 2 (failing) and 3
    policy.max_tokens = 32;
    let service =
        MoeService::start(cfg.clone(), params.clone(), backend, TaskGraphMode::Fused, policy)
            .unwrap();
    let (h, e) = (cfg.model.h, cfg.model.e);
    let mut rng = Rng::new(seed);

    let split = service
        .enqueue(skewed_tokens(&params.wg, h, e, 96, Skew::Zipf, &mut rng), RequestOpts::default())
        .unwrap();
    let err = format!("{:#}", split.wait().unwrap_err());
    assert!(
        err.contains("injected transient fault"),
        "split request must fail with the injected fault, got: {err}"
    );

    // a later request rides a clean pass and is served
    let ok = service
        .enqueue(skewed_tokens(&params.wg, h, e, 8, Skew::Zipf, &mut rng), RequestOpts::default())
        .unwrap();
    assert_eq!(ok.wait().unwrap().rows, 8);

    // an abandoned handle is cancelled (or failed), never double-counted
    let abandoned = service
        .enqueue(skewed_tokens(&params.wg, h, e, 8, Skew::Zipf, &mut rng), RequestOpts::default())
        .unwrap();
    drop(abandoned);

    let report = service.shutdown();
    let s = &report.service;
    assert_eq!(s.requests_enqueued, 3);
    assert_eq!(s.requests_failed, 1, "exactly the split request failed");
    assert_eq!(
        s.requests_enqueued,
        s.requests_served + s.requests_cancelled + s.requests_failed,
        "ledger leak: {} != {} + {} + {}",
        s.requests_enqueued,
        s.requests_served,
        s.requests_cancelled,
        s.requests_failed
    );
    assert!(s.passes_failed >= 1, "the failing pass must be counted");
}

/// Deadline-aware admission: a request whose budget expired before the
/// batcher admits it is shed with a deadline error, counted once, and
/// the ledger still balances.
#[test]
fn expired_deadline_is_shed_at_admission() {
    let seed = 13;
    let cfg = chaos_cfg(4, "dropless", "flat");
    let params = Arc::new(ModelParams::generate(&cfg, seed));
    let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(&cfg));
    let service =
        MoeService::with_defaults(cfg.clone(), params.clone(), backend, TaskGraphMode::Fused)
            .unwrap();
    let (h, e) = (cfg.model.h, cfg.model.e);
    let mut rng = Rng::new(seed);

    let doomed = service
        .enqueue(
            skewed_tokens(&params.wg, h, e, 8, Skew::Zipf, &mut rng),
            RequestOpts { deadline: Some(std::time::Duration::ZERO), ..Default::default() },
        )
        .unwrap();
    let err = format!("{:#}", doomed.wait().unwrap_err());
    assert!(err.contains("deadline exceeded"), "wrong shed error: {err}");

    let fine = service
        .enqueue(
            skewed_tokens(&params.wg, h, e, 8, Skew::Zipf, &mut rng),
            RequestOpts {
                deadline: Some(std::time::Duration::from_secs(30)),
                ..Default::default()
            },
        )
        .unwrap();
    assert_eq!(fine.wait().unwrap().rows, 8, "a live budget must be served");

    let report = service.shutdown();
    let s = &report.service;
    assert_eq!(s.deadline_misses, 1);
    assert_eq!(s.requests_failed, 1, "the miss is also a failure, counted once");
    assert_eq!(
        s.requests_enqueued,
        s.requests_served + s.requests_cancelled + s.requests_failed
    );
}

/// Satellite (b): the watchdog is a config knob now — a short (but
/// comfortably sufficient) budget serves passes normally at test scale.
#[test]
fn watchdog_knob_works_at_test_scale() {
    let seed = 17;
    let mut cfg = chaos_cfg(2, "dropless", "flat");
    cfg.set("watchdog_secs", "30").unwrap();
    cfg.validate().unwrap();
    assert_eq!(cfg.system.watchdog_secs, 30);
    let params = Arc::new(ModelParams::generate(&cfg, seed));
    let inputs = zipf_inputs(&cfg, &params, seed);
    let engine = start(&cfg, &params);
    engine.submit(&inputs).unwrap().wait().unwrap();
    engine.shutdown();
}
