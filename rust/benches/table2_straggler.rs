//! Table 2 / Fig 15 — straggler delay within synchronous AllToAll
//! (commercial VM vs supercomputer jitter profiles).
fn main() {
    let (text, reports) = flashdmoe::harness::table2(42);
    println!("{text}");
    for r in &reports {
        println!(
            "{}: mean {:.2}x, max {:.2}x over {} steps",
            r.platform.name, r.summary.mean, r.summary.max, r.summary.n
        );
    }
}
