//! Fig 5a / Fig 11 — SM utilization during the forward pass
//! (T=8K, E=64, 2 GPUs), Nsight-style "SM active" metric.
fn main() {
    let (text, _) = flashdmoe::harness::fig11(42).unwrap();
    println!("{text}");
}
