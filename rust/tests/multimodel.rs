//! Multi-model residency conformance: one engine serving several models
//! must be **invisible** to each of them.
//!
//! The headline contract: a model's outputs on a co-resident engine are
//! bitwise identical to a dedicated single-model engine fed the same
//! inputs — across routing policies (capacity/dropless) and dispatch
//! modes (flat/hierarchical), under replication, and through injected
//! faults in *another* model's pass. The shared packed-weight cache is
//! audited through the backend's pack counter (a fingerprint dedup packs
//! nothing; a LoRA delta packs nothing and costs only its delta bytes),
//! and registration/eviction respect the registry's dependency guards.

use std::sync::Arc;

use flashdmoe::config::Config;
use flashdmoe::coordinator::{MoeEngine, PassInput, TaskGraphMode};
use flashdmoe::expert::ModelParams;
use flashdmoe::registry::DeltaSet;
use flashdmoe::runtime::{ComputeBackend, NativeBackend};
use flashdmoe::util::prng::Rng;
use flashdmoe::util::stats::max_abs_diff;
use flashdmoe::workload::{skewed_tokens, Skew};

/// 4 ranks over the tiny model; `max_models` resident-model slots.
fn mm_cfg(max_models: usize, policy: &str, dispatch: &str) -> Config {
    let mut cfg = Config::preset("tiny").unwrap();
    cfg.set("ranks", "4").unwrap();
    cfg.set("tokens", "128").unwrap();
    cfg.set("routing_policy", policy).unwrap();
    if dispatch == "hierarchical" {
        cfg.set("nodes", "2").unwrap();
    }
    cfg.set("dispatch", dispatch).unwrap();
    cfg.set("max_models", &max_models.to_string()).unwrap();
    cfg.validate().unwrap();
    cfg
}

/// Zipf-skewed tokens through `params`' gate, deterministic in
/// (seed, rank) — so model A and model B get *different* routing.
fn zipf_inputs(cfg: &Config, params: &ModelParams, seed: u64) -> Vec<Vec<f32>> {
    let (h, e) = (cfg.model.h, cfg.model.e);
    (0..cfg.system.ranks)
        .map(|r| {
            let mut rng = Rng::new(seed).fork(0x10DE_0000 + r as u64);
            skewed_tokens(&params.wg, h, e, cfg.system.s_rank, Skew::Zipf, &mut rng)
        })
        .collect()
}

fn start(cfg: &Config, params: &Arc<ModelParams>) -> MoeEngine {
    let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(cfg));
    MoeEngine::start(cfg.clone(), params.clone(), backend, TaskGraphMode::Fused).unwrap()
}

/// The tentpole contract: two co-resident models, each bitwise identical
/// to its own dedicated engine, for every routing policy × dispatch mode
/// — and the whole co-resident run costs exactly one launch.
#[test]
fn co_resident_models_are_bitwise_identical_to_dedicated_engines() {
    for policy in ["capacity", "dropless"] {
        for dispatch in ["flat", "hierarchical"] {
            let cfg = mm_cfg(2, policy, dispatch);
            let params_a = Arc::new(ModelParams::generate(&cfg, 71));
            let params_b = Arc::new(ModelParams::generate(&cfg, 72));
            let inputs_a = zipf_inputs(&cfg, &params_a, 301);
            let inputs_b = zipf_inputs(&cfg, &params_b, 302);

            // Dedicated single-model engines (the defaults: max_models=1).
            let solo_cfg = mm_cfg(1, policy, dispatch);
            let solo_a = start(&solo_cfg, &params_a);
            let ref_a = solo_a.submit(&inputs_a).unwrap().wait().unwrap();
            solo_a.shutdown();
            let solo_b = start(&solo_cfg, &params_b);
            let ref_b = solo_b.submit(&inputs_b).unwrap().wait().unwrap();
            solo_b.shutdown();

            // One engine, both models resident.
            let engine = start(&cfg, &params_a);
            let hb = engine.register_model(params_b.clone()).unwrap();
            assert_eq!(hb.id, 1);
            assert!(!hb.deduped, "independent weights must not dedup");
            // Interleave models across passes — the pass slots and heap
            // bands must keep them fully separate.
            for round in 0..2 {
                let ra = engine
                    .submit_pass(PassInput::for_model(inputs_a.clone(), 0))
                    .unwrap()
                    .wait()
                    .unwrap();
                let rb = engine
                    .submit_pass(PassInput::for_model(inputs_b.clone(), 1))
                    .unwrap()
                    .wait()
                    .unwrap();
                assert_eq!(ra.metrics.model, 0);
                assert_eq!(rb.metrics.model, 1);
                assert_eq!(
                    ra.outputs, ref_a.outputs,
                    "model A diverged from its dedicated engine \
                     ({policy}/{dispatch}, round {round})"
                );
                assert_eq!(
                    rb.outputs, ref_b.outputs,
                    "model B diverged from its dedicated engine \
                     ({policy}/{dispatch}, round {round})"
                );
            }
            let em = engine.metrics();
            assert_eq!(em.launches, 1, "co-residency must not relaunch");
            assert_eq!(em.model_registrations, 1);
            engine.shutdown();
        }
    }
}

/// Fingerprint dedup: registering content-identical weights packs
/// nothing (audited via the backend's pack counter), costs zero resident
/// bytes, and the deduped model's outputs are bitwise the anchor's.
#[test]
fn dedup_registration_shares_the_packed_cache() {
    let cfg = mm_cfg(2, "dropless", "flat");
    let params = Arc::new(ModelParams::generate(&cfg, 73));
    // Same content, separate allocation — the fingerprint must match.
    let clone = Arc::new((*params).clone());
    let native = Arc::new(NativeBackend::from_config(&cfg));
    let backend: Arc<dyn ComputeBackend> = native.clone();
    let engine =
        MoeEngine::start(cfg.clone(), params.clone(), backend, TaskGraphMode::Fused).unwrap();
    let packs_after_start = native.pack_count();
    assert_eq!(packs_after_start, cfg.model.e as u64);
    let bytes_before = engine.resident_bytes();

    let h = engine.register_model(clone).unwrap();
    assert!(h.deduped, "identical weights must fingerprint-dedup");
    assert_eq!(h.resident_bytes, 0, "a dedup adds no resident bytes");
    assert_eq!(
        native.pack_count(),
        packs_after_start,
        "a dedup registration must not touch the packed cache"
    );
    assert_eq!(engine.resident_bytes(), bytes_before);

    let inputs = zipf_inputs(&cfg, &params, 303);
    let r0 = engine.submit_pass(PassInput::for_model(inputs.clone(), 0)).unwrap().wait().unwrap();
    let r1 = engine.submit_pass(PassInput::for_model(inputs, 1)).unwrap().wait().unwrap();
    assert_eq!(r0.outputs, r1.outputs, "dedup serves the same function");
    engine.shutdown();
}

/// LoRA delta variant: packs nothing, costs only the delta bytes, and
/// matches a dedicated engine running the *materialized* weights
/// (W2 + A2·B2, b2 + db2) within f32 tolerance — while actually changing
/// the function relative to its base.
#[test]
fn delta_variant_matches_materialized_dedicated_engine() {
    let cfg = mm_cfg(2, "dropless", "flat");
    let base = Arc::new(ModelParams::generate(&cfg, 74));
    let delta = Arc::new(DeltaSet::generate(&cfg, 75, 2, 0.05));
    let inputs = zipf_inputs(&cfg, &base, 304);

    // Materialize base + delta into plain ModelParams: W2 += A2·B2
    // (A2 is (D, r), B2 is (r, H)), b2 += db2. Gate unchanged, so the
    // routing — and therefore the pass structure — is the base's.
    let (h, d) = (cfg.model.h, cfg.model.d);
    let mut mat = (*base).clone();
    for (ex, de) in mat.experts.iter_mut().zip(&delta.experts) {
        let r = delta.rank;
        for i in 0..d {
            for j in 0..h {
                let mut acc = 0.0f32;
                for k in 0..r {
                    acc += de.a2[i * r + k] * de.b2[k * h + j];
                }
                ex.w2[i * h + j] += acc;
            }
        }
        for (b, db) in ex.b2.iter_mut().zip(&de.db2) {
            *b += db;
        }
    }
    let solo = start(&mm_cfg(1, "dropless", "flat"), &Arc::new(mat));
    let reference = solo.submit(&inputs).unwrap().wait().unwrap();
    solo.shutdown();

    let native = Arc::new(NativeBackend::from_config(&cfg));
    let backend: Arc<dyn ComputeBackend> = native.clone();
    let engine =
        MoeEngine::start(cfg.clone(), base.clone(), backend, TaskGraphMode::Fused).unwrap();
    let packs = native.pack_count();
    let bytes_before = engine.resident_bytes();
    let hl = engine.register_delta(0, delta.clone()).unwrap();
    assert_eq!(hl.resident_bytes, delta.bytes());
    assert_eq!(native.pack_count(), packs, "a delta variant never repacks");
    assert_eq!(engine.resident_bytes(), bytes_before + delta.bytes());

    let rb = engine.submit_pass(PassInput::for_model(inputs.clone(), 0)).unwrap().wait().unwrap();
    let rl = engine.submit_pass(PassInput::for_model(inputs, 1)).unwrap().wait().unwrap();
    let drift = rl
        .outputs
        .iter()
        .zip(&reference.outputs)
        .map(|(a, b)| max_abs_diff(a, b))
        .fold(0.0f32, f32::max);
    assert!(
        drift <= 2e-4,
        "delta epilogue drifted {drift} from materialized weights"
    );
    let base_delta_gap = rl
        .outputs
        .iter()
        .zip(&rb.outputs)
        .map(|(a, b)| max_abs_diff(a, b))
        .fold(0.0f32, f32::max);
    assert!(base_delta_gap > 1e-3, "the delta must actually change the function");
    engine.shutdown();
}

/// Cross-model fault isolation: a transient fault injected into model
/// B's pass retries transparently — and model A's outputs, before and
/// after, are bitwise what a fault-free co-resident engine produces.
#[test]
fn fault_in_model_b_pass_retries_without_perturbing_model_a() {
    let mk = |faulted: bool| {
        let mut cfg = mm_cfg(2, "dropless", "flat");
        if faulted {
            // Every cross-rank transfer of pass epoch 2 fails; epoch 2
            // will be model B's first pass below.
            cfg.set("retry_limit", "2").unwrap();
            cfg.set("fault_seed", "42").unwrap();
            cfg.set("fault_transient_rate", "1.0").unwrap();
            cfg.set("fault_transient_from", "2").unwrap();
            cfg.set("fault_transient_until", "3").unwrap();
            cfg.validate().unwrap();
        }
        cfg
    };
    let cfg = mk(false);
    let params_a = Arc::new(ModelParams::generate(&cfg, 76));
    let params_b = Arc::new(ModelParams::generate(&cfg, 77));
    let inputs_a = zipf_inputs(&cfg, &params_a, 305);
    let inputs_b = zipf_inputs(&cfg, &params_b, 306);

    let run = |cfg: &Config| {
        let engine = start(cfg, &params_a);
        engine.register_model(params_b.clone()).unwrap();
        // epoch 1: A — epoch 2: B (faulted in the faulted arm, retried
        // under a fresh epoch) — then A again.
        let a1 = engine.submit_pass(PassInput::for_model(inputs_a.clone(), 0)).unwrap().wait();
        let b = engine.submit_pass(PassInput::for_model(inputs_b.clone(), 1)).unwrap().wait();
        let a2 = engine.submit_pass(PassInput::for_model(inputs_a.clone(), 0)).unwrap().wait();
        let em = engine.metrics();
        engine.shutdown();
        (a1.unwrap(), b.unwrap(), a2.unwrap(), em)
    };
    let (ca1, cb, ca2, cem) = run(&mk(false));
    let (fa1, fb, fa2, fem) = run(&mk(true));
    assert_eq!(cem.retries, 0, "clean arm must not retry");
    assert!(fem.retries > 0, "faulted arm must have retried B's pass");
    assert!(fem.faults_injected > 0, "fault plan must actually fire");
    assert_eq!(fb.outputs, cb.outputs, "B's retried pass must be bitwise clean");
    assert_eq!(fb.metrics.model, 1);
    assert_eq!(fa1.outputs, ca1.outputs, "A before the fault must be untouched");
    assert_eq!(fa2.outputs, ca2.outputs, "A after B's retry must be untouched");
    assert_eq!(ca1.outputs, ca2.outputs, "A is deterministic across passes");
}

/// Registration/eviction lifecycle: capacity limits, dependency guards
/// (anchor, delta base), slot reuse, and submit-after-evict refusal.
#[test]
fn registration_lifecycle_enforces_guards_and_reuses_slots() {
    let cfg = mm_cfg(3, "dropless", "flat");
    let params_a = Arc::new(ModelParams::generate(&cfg, 78));
    let params_b = Arc::new(ModelParams::generate(&cfg, 79));
    let params_c = Arc::new(ModelParams::generate(&cfg, 80));
    let delta = Arc::new(DeltaSet::generate(&cfg, 81, 2, 0.05));
    let engine = start(&cfg, &params_a);

    let hb = engine.register_model(params_b.clone()).unwrap();
    let hl = engine.register_delta(0, delta.clone()).unwrap();
    assert_eq!((hb.id, hl.id), (1, 2));
    // Capacity: 3 slots, all taken (anchor + 2).
    assert!(engine.register_model(params_c.clone()).is_err(), "no free slot");
    // Guards: the anchor is not evictable, and it is the delta's base.
    assert!(engine.evict_model(0).is_err());
    // Evict the delta, then its slot is reusable.
    engine.evict_model(hl.id).unwrap();
    assert!(
        engine
            .submit_pass(PassInput::for_model(zipf_inputs(&cfg, &params_a, 307), hl.id))
            .is_err(),
        "submitting to an evicted model must refuse"
    );
    let hc = engine.register_model(params_c).unwrap();
    assert_eq!(hc.id, 2, "freed slot is reused");
    assert_eq!(engine.resident_models(), vec![0, 1, 2]);
    let em = engine.metrics();
    assert_eq!(em.model_registrations, 3);
    assert_eq!(em.model_evictions, 1);
    engine.shutdown();
}

/// Per-model replication: a hot expert in model B replicates from B's
/// own tracker without touching model A's placement — and outputs stay
/// bitwise identical through the swap (the splitter contract, per model).
#[test]
fn rebalance_is_per_model_and_bitwise_transparent() {
    let mut cfg = mm_cfg(2, "dropless", "flat");
    cfg.set("replicate_top", "2").unwrap();
    cfg.set("replicas", "2").unwrap();
    cfg.set("replication_hysteresis", "1.2").unwrap();
    cfg.set("ewma_alpha", "0.5").unwrap();
    cfg.validate().unwrap();
    let params_a = Arc::new(ModelParams::generate(&cfg, 82));
    let params_b = Arc::new(ModelParams::generate(&cfg, 83));
    let inputs_b = zipf_inputs(&cfg, &params_b, 308);

    let engine = start(&cfg, &params_a);
    engine.register_model(params_b.clone()).unwrap();
    let placement_a_before = engine.placement();
    // Warm only model B: its tracker sees Zipf-hot experts, A's sees
    // nothing.
    let mut before = None;
    for _ in 0..3 {
        let r =
            engine.submit_pass(PassInput::for_model(inputs_b.clone(), 1)).unwrap().wait().unwrap();
        before.get_or_insert(r.outputs);
    }
    let swapped = engine.rebalance().unwrap();
    assert!(swapped, "Zipf-hot model B must trip a replication swap");
    assert!(
        engine.placement().same_locations(&placement_a_before),
        "model A's placement must not move on B's load"
    );
    let after =
        engine.submit_pass(PassInput::for_model(inputs_b.clone(), 1)).unwrap().wait().unwrap();
    assert_eq!(
        after.outputs,
        before.unwrap(),
        "B's outputs must be bitwise identical through its replication swap"
    );
    assert!(after.metrics.replica_hits() > 0, "replicas must actually serve");
    engine.shutdown();
}
