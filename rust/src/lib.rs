//! # FlashDMoE — distributed Mixture-of-Experts as one persistent engine
//!
//! Reproduction of *FlashDMoE: Fast Distributed MoE in a Single Kernel*
//! (NeurIPS 2025) as a three-layer Rust + JAX + Pallas system:
//!
//! * **L1/L2 (build time)** — Pallas tile kernels and the JAX MoE layer
//!   graph are AOT-lowered to HLO text artifacts (`make artifacts`).
//! * **L3 (this crate)** — the paper's system contribution: a
//!   persistent-kernel-style actor runtime (Scheduler / Subscriber /
//!   Processor per rank), the write-conflict-free symmetric tensor layout
//!   `L`, payload-efficient one-sided dispatch/combine, and a
//!   work-conserving in-kernel task scheduler. Artifacts are executed
//!   through the PJRT C API (`xla` crate, CPU client); Python never runs
//!   on the request path.
//!
//! The paper's central claim — the MoE operator is **one kernel, launched
//! once** (Table 1: 33–550 launches/layer in baselines vs 1 here) — is
//! the shape of the public API, and the front door is now *request
//! level*: a [`coordinator::MoeService`] owns a persistent
//! [`coordinator::MoeEngine`] (every rank's actor group launched exactly
//! once at `start`) and runs a resident continuous batcher over it.
//! Clients `enqueue` variable-length token sequences; the batcher admits
//! them from a bounded queue, coalesces them under a
//! [`coordinator::BatchPolicy`], round-robins rows across ranks into
//! **variable-shape engine passes** (`s_r ≤ s_rank` per rank — no padded
//! rows are ever computed or shipped), and scatter-gathers outputs back
//! per request. Each pass is an epoch-tagged `submit` that rings
//! doorbells on the resident actors (zero thread spawns, zero heap
//! resets — signal flags carry per-slot generation counters), and the
//! batcher keeps pass N+1 packed and submitted while pass N runs, so
//! `EngineMetrics::launches` stays 1 for the whole service lifetime.
//!
//! ## Routing policy: capacity vs dropless
//!
//! The gate supports two dispatch contracts via
//! [`config::RoutingPolicy`]:
//!
//! * `Capacity(f)` — the paper's fixed per-(source, expert) buffer
//!   `roundup(max(ceil(S_r·k/E·f), bM), bM)`; over-capacity (token,
//!   expert) pairs are **dropped**, so under a skewed gate the engine
//!   computes a different function than the dense reference.
//! * `Dropless` — MegaBlocks-style dropless MoE: the symmetric heap's
//!   per-(source, expert) slot region is sized to the worst case and
//!   dispatch ships **variable-length tile lists** sized to the actual
//!   routed counts (full tiles plus one partial tail, row counts in the
//!   signal flags), so no pair is ever dropped and no padded row ever
//!   travels. `PassMetrics::total_dropped()` reads 0 by contract, and the
//!   conformance suite (`rust/tests/properties.rs`) asserts engine output
//!   equals a dense per-token reference to 1e-5 under fuzzed skew.
//!
//! Select it per config: `cfg.set("routing_policy", "dropless")` (or
//! `"capacity:<factor>"`; `cfg.set("capacity_factor", f)` keeps working
//! and implies the capacity policy). Presets default to `Capacity(1.0)`
//! for drop-in compatibility; `harness::routing_policy_ab` and
//! `examples/expert_scaling.rs` A/B the two on the same inputs.
//!
//! ## Compute hot path: packed weights + work stealing
//!
//! Two knobs govern how a rank's processors chew through their tasks:
//!
//! * **`packed`** (default `true`, `cfg.set("packed", "false")` to A/B) —
//!   expert weights are re-laid into the BLIS-style NR-panel format
//!   exactly once at [`coordinator::MoeEngine::start`]
//!   ([`runtime::ComputeBackend::prepare`]); every FFN/GEMM task then
//!   streams cache-contiguous panels with bias+activation fused into the
//!   single output write-back (no zero-fill pass, no epilogue sweep — see
//!   `gemm.rs` for the layout diagram). The packed kernels replay the
//!   unpacked f32 accumulation order, so the toggle never changes output
//!   bits, and the backend's pack counter is flat across passes (audited
//!   by the engine tests: pack count == expert count per lifetime).
//! * **`processors`** — per-rank worker count. The ready queue behind
//!   them is a decentralized work-stealing pool (one deque per
//!   processor, owner-LIFO / thief-FIFO, parking only on global
//!   emptiness), so dispatch scales with cores instead of serializing on
//!   one queue lock; the subscriber lends a hand as a thief when its
//!   flag sweep idles. Per-pass `steals` / `max_queue_depth` metrics in
//!   [`coordinator::RankMetrics`] expose the pool's contention.
//!
//! `harness::gemm_backend_ab` (kernel-level) and `harness::hotpath_ab`
//! (engine-level) A/B the packed toggle; `cargo bench --bench
//! microbench_gemm` / `--bench fig11_sm_util` record both into
//! `BENCH_pr3_hotpath.json`, and CI's perf-smoke job fails if the packed
//! kernel ever regresses below the unpacked baseline.
//!
//! ## Wire precision vs compute precision
//!
//! [`config::WirePrecision`] (`cfg.set("wire_precision", "bf16")`, also
//! `"f16"`/`"f32"`) selects the element format of what actually crosses
//! the fabric: dispatch and combine payloads are quantized by
//! `SymmetricHeap::put_signal` on the way into a peer's inbox and
//! dequantized to f32 by `read_into` before any kernel touches them
//! (`crate::wire` owns the conversions). Compute — gate, expert GEMMs,
//! combine scaling and the deterministic fold — is f32 at every setting,
//! so the knob trades *transfer* bytes, never accumulation math:
//!
//! * **`F32`** (default): encode/decode is a byte copy; outputs are
//!   **bitwise identical** to the pre-wire-subsystem engine, and every
//!   existing guarantee (restart/schedule determinism, dense-reference
//!   conformance at 1e-5, Theorem 3.1 write disjointness) is unchanged.
//! * **`Bf16` / `F16`**: inbox cells, staging regions and the *measured*
//!   byte counters all halve — `PassMetrics::total_bytes` reads exactly
//!   `2·routed·H·2` bytes instead of `…·4` for the same routed rows, and
//!   `PassMetrics::payload_savings` credits the narrowing on top of
//!   dropped padding. Outputs remain bitwise deterministic across
//!   restarts, policies and processor counts (round-to-nearest-even has
//!   no schedule dependence), but match the dense f32 reference only to
//!   [`config::WirePrecision::conformance_tol`] (documented per format).
//!
//! The paper's Fig 18 (FP16 vs FP32) is reproduced **measured, not
//! modeled**: `harness::precision_ab` drives the same inputs through live
//! engines at each wire setting, asserts dense-reference conformance per
//! format, and reports measured bytes and pass latency; the engines test
//! asserts the exact 2× byte reduction on those points, `cargo bench
//! --bench fig18_fp16` records them into `BENCH_pr5_precision.json`, and
//! CI's perf-smoke gate independently fails if a 16-bit wire ever costs
//! ≥ 0.6× the f32 bytes. The legacy `elem_bytes` cost-model float is now
//! a deprecation shim over this knob (see `config.rs`).
//!
//! ## Multi-node topology: transport, hierarchical dispatch, incast
//!
//! The fabric is **node-aware**: [`transport::Topology`]
//! (`cfg.set("nodes", n)`) groups `ranks_per_node` consecutive ranks per
//! node, and every one-sided transfer goes through a
//! [`transport::NodeFabric`] that classifies each (src, dst) pair by
//! [`transport::LinkClass`] — `NvLink` (same node: the symmetric heap,
//! unbounded, as before) or `Nic` (cross-node: admitted against a
//! **bounded per-destination receive window** sized by
//! `cfg.set("nic_buffer", bytes)` and reset each pass generation). A put
//! the window rejects is a real engine error — the paper's §F incast
//! overflow as a *measured outcome*, not a formula: past ~2048
//! tokens/GPU on the `paper_multinode` preset the hottest receiver's
//! window overflows, the failing rank poisons the pass generation, and
//! every peer abandons the pass promptly instead of wedging.
//!
//! [`config::DispatchMode`] (`cfg.set("topology", "hier")`, default on
//! `paper_multinode`) selects **hierarchical dispatch**: each remote
//! node's *unique* token rows cross the NIC once, coalesced into a
//! single transfer to a proxy rank that fans the per-tile payloads out
//! intra-node via delegated writes preserving the logical source — so
//! announcements, flags, combine and the plan-order fold are untouched
//! and flat vs hierarchical outputs are **bitwise identical** (asserted
//! by the conformance tests). With top-k routing a token bound for two
//! experts on one remote node crosses once instead of twice, so
//! NIC-class bytes drop (`harness::multinode_ab` measures the split;
//! CI's perf-smoke gate fails if hierarchical ever moves more inter-node
//! bytes than flat). Per-pass metrics expose the locality split
//! (`PassMetrics::intra_bytes` / `inter_bytes`) and the measured Maximal
//! Incast Volume (`PassMetrics::miv_bytes` — the hottest receiver's
//! NIC-class bytes), with `announced_inter_bytes` as the declared upper
//! bound the property suite holds the measurement to. `cargo bench
//! --bench fig17_multinode` records the A/B into
//! `BENCH_pr6_multinode.json`; the remaining gap to real hardware is an
//! RDMA backend behind the same [`transport::Transport`] trait.
//!
//! ## Hot-expert replication: EWMA load-aware placement
//!
//! Routing skew is production reality: a hot expert serializes on its
//! owner rank while the others idle. The replication subsystem
//! (`crate::placement`; ROADMAP item 2, grounded in "Fast MoE Inference
//! via Predictive Prefetching and Expert Replication") turns the static
//! expert→rank map into a dynamic [`placement::Placement`]:
//!
//! * **Knobs** ([`config::ReplicationPolicy`], all through
//!   [`config::Config::set`]): `replicate_top=R` reserves `R` spare
//!   *replica slots* per rank and marks the top-R hottest experts
//!   eligible (`0`, the default, disables everything at zero overhead);
//!   `replicas` is the target copy count per hot expert;
//!   `replication_hysteresis` and `ewma_alpha` shape the tracker.
//! * **Tracking**: after every pass the engine folds the gate's
//!   *offered* per-expert load (pre capacity clamp —
//!   `PassMetrics::expert_offered`, which sums to `rows × k` even when
//!   the kept load saturates at capacity) into an EWMA
//!   ([`placement::LoadTracker`]).
//! * **Install**: [`coordinator::MoeEngine::rebalance`] runs the
//!   deterministic planner ([`placement::plan_replication`]) at a
//!   caller-chosen quiet point; placement changes are **epoch-fenced** —
//!   the engine blocks new submissions and waits for in-flight passes to
//!   drain before swapping the map — so no pass ever observes a
//!   placement change mid-flight. Packed-weight installs are cheap
//!   (`ComputeBackend::prepare` packed every expert at start; installs
//!   are accounted in `EngineMetrics::install_bytes`).
//! * **Splitting**: the gate shards a replicated expert's tokens across
//!   its serving locations by arrival index (`j % copies`), re-slotted
//!   densely per shard, with tiles still grouped by ascending expert id
//!   — so the plan-order combine fold is untouched and **replicated
//!   outputs are bitwise identical to static placement** (and conformant
//!   to the dense reference), asserted by `rust/tests/replication.rs`.
//!
//! `harness::replication_ab` drives live engines static-vs-replicated
//! under Zipf-skewed routing and the Poisson serving load:
//! `PassMetrics::hot_rank_busy_share` / `imbalance` quantify the balance
//! win, `replica_hits` proves replicas absorbed load, and `cargo bench
//! --bench table2_straggler` records the A/B into
//! `BENCH_pr7_replication.json` with a CI perf-smoke gate.
//!
//! ## Fault tolerance: poison → retry → degrade
//!
//! Production serving assumes failure (ROADMAP item 5). The robustness
//! ladder has three rungs, each building on the one below:
//!
//! * **Poison** (PR 6): a failed transfer stamps the pass generation as
//!   poisoned; every peer abandons the pass promptly instead of wedging,
//!   and the engine surfaces one pass error. The stamp is per *slot*
//!   (two epochs are in flight under double buffering), and the
//!   subscriber watchdog — `cfg.set("watchdog_secs", s)`, default 120 —
//!   bounds how long a wedged pass can survive undetected.
//! * **Retry** (`cfg.set("retry_limit", n)`, default 0 = fail fast):
//!   `PassHandle::wait` re-fences a poisoned pass at the epoch quiet
//!   point and resubmits the retained inputs under a fresh generation,
//!   with exponential backoff, transparently to [`coordinator::MoeService`]
//!   callers. Because pass outputs are deterministic, a transiently
//!   faulted pass that succeeds on retry is **bitwise identical** to a
//!   fault-free run (asserted across Capacity/Dropless × flat/
//!   hierarchical by `rust/tests/chaos.rs`). Retryable: injected
//!   transient faults, NIC incast overflow, peer-abandoned passes.
//! * **Degrade**: a *permanent* rank death (retrying cannot help) makes
//!   the retry driver swap in a degraded [`placement::Placement`] at the
//!   same quiet point: `fail_rank` reroutes every expert the corpse
//!   served to its surviving replicas — hot experts replicated by the
//!   subsystem above keep serving — and experts with no surviving copy
//!   are **explicitly accounted** (`PassMetrics::experts_unavailable`,
//!   their rows dropped with `RankMetrics::unavailable_rows`, never
//!   silently wrong). Token rows bound for the dead rank are repacked
//!   onto survivors' spare capacity for the pass and their outputs
//!   restored to the caller's shape, so the service keeps answering at
//!   reduced capacity instead of collapsing.
//!
//! Not recoverable: validation errors (they fail before an epoch is
//! assigned), compute panics inside a rank actor (the actor is gone),
//! and capacity exhaustion when the surviving ranks cannot hold a dead
//! rank's rows. Chaos is driven by the deterministic `crate::fault`
//! schedule (`fault_*` knobs) injected at the transport seam — zero
//! engine changes between a chaos run and production. On the service
//! side, [`coordinator::RequestOpts`]`::deadline` adds deadline-aware
//! admission: a request whose deadline passes while queued is shed
//! before it wastes a pass (`ServiceMetrics::deadline_misses`), with
//! priority ordering shedding best-effort traffic first.
//! `harness::chaos_ab` + `cargo bench --bench chaos_bench` record
//! availability and tail latency under a live fault schedule into
//! `BENCH_pr8_chaos.json` with a CI perf-smoke gate.
//!
//! ## Quickstart — serving requests
//!
//! The serving front door: start a [`coordinator::MoeService`], enqueue
//! variable-length requests from any number of client threads, wait on
//! each handle. The batcher does the rest — admission, coalescing,
//! variable-shape passes, scatter-gather — over one engine launch.
//!
//! ```no_run
//! use std::sync::Arc;
//! use flashdmoe::config::Config;
//! use flashdmoe::coordinator::{BatchPolicy, MoeService, RequestOpts, TaskGraphMode};
//! use flashdmoe::expert::ModelParams;
//! use flashdmoe::runtime::{ComputeBackend, NativeBackend};
//! use flashdmoe::util::prng::Rng;
//!
//! # fn main() -> anyhow::Result<()> {
//! let mut cfg = Config::preset("tiny")?;
//! cfg.set("routing_policy", "dropless")?; // request-level conformance
//! cfg.set("wire_precision", "bf16")?; // halve fabric bytes; compute stays f32
//! let params = Arc::new(ModelParams::generate(&cfg, 42));
//! let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(&cfg));
//!
//! // one launch for the service lifetime: engine + resident batcher
//! let policy = BatchPolicy::from_config(&cfg); // max_tokens, max_delay, queue knobs
//! let service = MoeService::start(cfg.clone(), params, backend, TaskGraphMode::Fused, policy)?;
//!
//! // requests are (rows, H) flat buffers of any length 1..=max_tokens
//! // (oversize requests split across passes under the default policy)
//! let mut rng = Rng::new(7);
//! let a = service.enqueue(rng.normal_vec(3 * cfg.model.h, 1.0), RequestOpts::default())
//!     .map_err(|e| anyhow::anyhow!("{e}"))?;
//! let b = service.enqueue(rng.normal_vec(40 * cfg.model.h, 1.0), RequestOpts::default())
//!     .map_err(|e| anyhow::anyhow!("{e}"))?;
//!
//! let ra = a.wait()?; // (3, H) outputs + queue-time / latency metrics
//! let rb = b.wait()?;
//! assert_eq!(ra.tokens.len(), 3 * cfg.model.h);
//! assert_eq!(rb.rows, 40);
//!
//! // shutdown (or drop) drains every in-flight request, then joins:
//! // the whole service lifetime cost exactly one launch
//! let report = service.shutdown();
//! assert_eq!(report.engine.launches, 1);
//! # Ok(())
//! # }
//! ```
//!
//! ## Operator embedding — the engine API
//!
//! Embedders that own their batching (a training loop, another serving
//! stack) drive the persistent [`coordinator::MoeEngine`] directly:
//! `start` launches the rank actors once; `submit` (fixed-shape) or
//! `submit_pass` (variable-shape [`coordinator::PassInput`], per-rank
//! rows `s_r ≤ s_rank`) rings the doorbells and returns a `PassHandle`;
//! `wait` collects. Submission is pipelined — pass N+1 may be submitted
//! before pass N is collected — and `PassMetrics::batch_fill` reports
//! how much of the pass's row capacity was used (1.0 on the fixed-shape
//! path, by contract).
//!
//! ```no_run
//! use std::sync::Arc;
//! use flashdmoe::config::Config;
//! use flashdmoe::coordinator::{MoeEngine, TaskGraphMode};
//! use flashdmoe::expert::{generate_tokens, ModelParams};
//! use flashdmoe::runtime::{ComputeBackend, NativeBackend};
//!
//! # fn main() -> anyhow::Result<()> {
//! let cfg = Config::preset("tiny")?;
//! let params = Arc::new(ModelParams::generate(&cfg, 42));
//! let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(&cfg));
//! let engine = MoeEngine::start(cfg.clone(), params, backend, TaskGraphMode::Fused)?;
//! let inputs: Vec<Vec<f32>> =
//!     (0..cfg.system.ranks).map(|r| generate_tokens(&cfg, 42, r)).collect();
//! let pass1 = engine.submit(&inputs)?;
//! let pass2 = engine.submit(&inputs)?; // pipelined
//! let out1 = pass1.wait()?;
//! assert_eq!((out1.metrics.batch_fill() * 100.0) as u32, 100);
//! # let _ = pass2.wait()?;
//! assert_eq!(engine.metrics().launches, 1);
//! engine.shutdown();
//! # Ok(())
//! # }
//! ```
//!
//! ## Multi-model residency — several expert sets, one launch
//!
//! A production deployment serves several models (or LoRA variants of
//! one base) — and one engine per model would forfeit exactly the
//! residency the paper buys. With `cfg.set("max_models", n)` the engine
//! reserves `n` per-model expert-slot bands in the symmetric heap at
//! start (default 1: byte-identical to the single-model layout), and the
//! fingerprinted [`registry::ModelRegistry`] then installs additional
//! expert sets at epoch-fenced quiet points — no restart, launches
//! stays 1:
//!
//! * [`coordinator::MoeEngine::register_model`] — a full expert set.
//!   Its content fingerprint (FNV-1a over every parameter bit) is
//!   checked against the resident models first: identical weights dedup
//!   to the already-packed cache entries (zero new packs, zero
//!   incremental bytes — audited via the backend's `pack_count()`);
//!   fresh weights are packed once into their own key region.
//! * [`coordinator::MoeEngine::register_delta`] — a LoRA-style
//!   [`registry::DeltaSet`] over a resident base: shares the base's
//!   packed panels, stores only the low-rank tensors, and applies the
//!   update in each FFN tile's *epilogue* — a resident variant costs
//!   delta bytes, never a repack.
//! * [`coordinator::MoeEngine::evict_model`] — frees the slot at the
//!   same quiet point (the anchor model 0 and any model others depend
//!   on are protected).
//!
//! Each model carries its own [`placement::Placement`] + EWMA
//! [`placement::LoadTracker`] (replication decisions are per-model), and
//! passes never mix models: [`coordinator::RequestOpts`]`::model` routes
//! a request, the batcher coalesces only same-model chunks, and
//! `PassMetrics::model` stamps the result. Cross-model isolation is
//! bitwise: a model's outputs co-resident with others equal its
//! dedicated single-model engine's exactly
//! (`rust/tests/multimodel.rs`), and a fault injected into one model's
//! pass retries without perturbing another's bits.
//!
//! ```no_run
//! use std::sync::Arc;
//! use flashdmoe::config::Config;
//! use flashdmoe::coordinator::{MoeEngine, PassInput, TaskGraphMode};
//! use flashdmoe::expert::{generate_tokens, ModelParams};
//! use flashdmoe::registry::DeltaSet;
//! use flashdmoe::runtime::{ComputeBackend, NativeBackend};
//!
//! # fn main() -> anyhow::Result<()> {
//! let mut cfg = Config::preset("tiny")?;
//! cfg.set("max_models", "3")?; // reserve two extra residency slots
//! let base = Arc::new(ModelParams::generate(&cfg, 42));
//! let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(&cfg));
//! let engine = MoeEngine::start(cfg.clone(), base, backend, TaskGraphMode::Fused)?;
//!
//! // a second full model (packed once) and a LoRA variant of the anchor
//! let other = engine.register_model(Arc::new(ModelParams::generate(&cfg, 7)))?;
//! let lora = engine.register_delta(0, Arc::new(DeltaSet::generate(&cfg, 9, 4, 0.05)))?;
//! println!("resident bytes: {}", engine.resident_bytes());
//!
//! let inputs: Vec<Vec<f32>> =
//!     (0..cfg.system.ranks).map(|r| generate_tokens(&cfg, 1, r)).collect();
//! let a = engine.submit_pass(PassInput::for_model(inputs.clone(), other.id))?;
//! let b = engine.submit_pass(PassInput::for_model(inputs, lora.id))?; // pipelined
//! let (ra, rb) = (a.wait()?, b.wait()?);
//! assert_eq!((ra.metrics.model, rb.metrics.model), (other.id, lora.id));
//! assert_eq!(engine.metrics().launches, 1); // still one launch
//! engine.shutdown();
//! # Ok(())
//! # }
//! ```
//!
//! ## Training — backward through the same engine
//!
//! The persistent engine is **differentiable** (ROADMAP item 3): with
//! `cfg.set("train", "on")`, every forward pass stashes its routing
//! decisions, gate probabilities and per-tile activations inside the
//! rank actors (the last few epochs; `coordinator::rank::STASH_CAP`),
//! and [`coordinator::MoeEngine::backward`] can then be issued for any
//! stashed forward **like any other pass**: output-gradients scatter to
//! the expert owners over the same one-sided wire (at the configured
//! [`config::WirePrecision`] — a 16-bit wire halves reverse bytes too),
//! `Dgrad`/`Wgrad` tile tasks run through the same work-stealing
//! scheduler, and input-gradients gather back through the combine cells,
//! with the epoch/flag/poison/retry machinery riding along unchanged.
//! Gradient folds happen in fixed plan order, so **wgrad is bitwise
//! deterministic** across restarts, processor counts and steal schedules
//! (asserted by `rust/tests/train.rs`); correctness is anchored to
//! `util::check::dense_reference_moe_grad` (1e-4 on an f32 wire) plus a
//! finite-difference suite across Capacity/Dropless × flat/hierarchical.
//!
//! The [`train`] module supplies the loop around it: [`train::GradStore`]
//! accumulation, [`train::Optimizer`] (SGD/momentum/Adam), and
//! [`train::Trainer`] — forward → backward → accumulate
//! (`grad_accum_steps`) → step → [`coordinator::MoeEngine::update_params`]
//! (an epoch-fenced weight swap; packed panels and XLA literals are
//! re-prepared). Knobs: `train`, `optimizer`, `lr`, `grad_accum_steps`,
//! `stash_activations` (see [`config::TrainConfig`]).
//!
//! ```no_run
//! use std::sync::Arc;
//! use flashdmoe::config::Config;
//! use flashdmoe::coordinator::{MoeEngine, TaskGraphMode};
//! use flashdmoe::expert::{generate_tokens, ModelParams};
//! use flashdmoe::runtime::{ComputeBackend, NativeBackend};
//! use flashdmoe::train::{Optimizer, Trainer};
//!
//! # fn main() -> anyhow::Result<()> {
//! let mut cfg = Config::preset("tiny")?;
//! cfg.set("train", "on")?;
//! let params = Arc::new(ModelParams::generate(&cfg, 42));
//! let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(&cfg));
//! let engine = MoeEngine::start(cfg.clone(), params, backend, TaskGraphMode::Fused)?;
//! let mut trainer = Trainer::new(engine, Optimizer::adam(1e-3))?;
//! let inputs: Vec<Vec<f32>> =
//!     (0..cfg.system.ranks).map(|r| generate_tokens(&cfg, 42, r)).collect();
//! let targets = inputs.clone(); // toy regression: reproduce the input
//! for step in 0..4 {
//!     let report = trainer.train_step(&inputs, &targets)?;
//!     println!("step {step}: loss {:.6} applied={}", report.loss, report.applied);
//! }
//! let trained = trainer.finish(); // shut down, keep the weights
//! # let _ = trained;
//! # Ok(())
//! # }
//! ```
//!
//! The multi-GPU fabric is simulated in-process (ranks = threads,
//! NVSHMEM `putmem_signal` = memcpy + release-store flag) and the paper's
//! evaluation figures are regenerated by a calibrated discrete-event
//! simulator (`sim`) driving the same routing/layout/task code as the
//! real execution path. See `DESIGN.md` for the substitution inventory.

pub mod util {
    pub mod args;
    pub mod check;
    pub mod json;
    pub mod prng;
    pub mod stats;
}

pub mod config;
pub mod wire;
pub mod gate;
pub mod registry;
pub mod placement;
pub mod layout;
pub mod task;
pub mod gemm;
pub mod expert;
pub mod fabric;
pub mod fault;
pub mod transport;
pub mod runtime;
pub mod coordinator;
pub mod train;
pub mod sim;
pub mod workload;
pub mod harness;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
