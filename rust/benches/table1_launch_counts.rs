//! Table 1 — kernel launches per single MoE layer pass (2 ranks, 32 local
//! experts). FlashDMoE = 1 persistent kernel; baselines modeled per
//! `Baseline::launch_model`, calibrated against the paper's Nsight counts.
//!
//! Table 1b — the same claim measured on the real execution path: a
//! resident `MoeEngine` (launched once, doorbell per pass) vs starting
//! and tearing the actor group down around every pass (the per-call
//! software "launch" the operator used to do). Reports steady-state
//! per-pass latency both ways and the amortized launch overhead.
//!
//! Env: `PASSES` (default 10) steady-state passes per arm.
fn main() {
    let (text, rows) = flashdmoe::harness::table1();
    println!("{text}");
    assert_eq!(rows[0].1, 1, "flash must be a single launch");

    let passes: usize = std::env::var("PASSES").ok().and_then(|v| v.parse().ok()).unwrap_or(10);
    let (text, p) = flashdmoe::harness::persistent_vs_respawn("tiny", passes, 42)
        .expect("persistent-vs-respawn microbench");
    println!("{text}");
    assert_eq!(p.persistent_launches, 1, "resident engine: one launch for all passes");
    assert_eq!(p.respawn_launches, passes as u64, "respawn shape: one launch per pass");
    assert!(
        p.respawn_threads >= p.persistent_threads,
        "respawning must spawn at least as many threads as launching once"
    );
}
