"""L2: the MoE layer compute graph in JAX, composed from the L1 Pallas kernels.

This is the *monolithic* (single-device) formulation of the layer — the same
math the distributed Rust coordinator computes across ranks. It exists for
two reasons:

  1. AOT artifact ``moe_layer``: the Rust integration tests execute it via
     PJRT and assert the distributed forward pass produces identical output.
  2. Build-time validation: pytest asserts this graph matches the numpy
     oracle in ``kernels.ref``.

All shapes are static (token dropping is expressed with masked scatters, as
in GShard), so the graph lowers cleanly to HLO text.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import combine as combine_k
from .kernels import ffn as ffn_k
from .kernels import gate as gate_k


def route_slots(idx: jax.Array, n_experts: int, capacity: int):
    """Slot index within the per-(rank, expert) buffer for each (token, k) pair.

    idx: (S_r, k) expert ids for one source rank's tokens. Slot order is
    token-major / k-minor arrival order (== the Rust gate and the numpy
    oracle). Returns (S_r, k) i32 slots; values >= capacity mean *dropped*.
    """
    s_r, k = idx.shape
    flat = idx.reshape(-1)  # (S_r*k,) in arrival order
    onehot = jax.nn.one_hot(flat, n_experts, dtype=jnp.int32)  # (S_r*k, E)
    # exclusive prefix count of earlier pairs routed to the same expert
    before = jnp.cumsum(onehot, axis=0) - onehot
    slots = jnp.take_along_axis(before, flat[:, None], axis=1)[:, 0]
    return slots.reshape(s_r, k)


@functools.partial(
    jax.jit, static_argnames=("k", "capacity", "s_rank", "bm")
)
def moe_layer(
    a: jax.Array,
    wg: jax.Array,
    w1: jax.Array,
    b1: jax.Array,
    w2: jax.Array,
    b2: jax.Array,
    *,
    k: int,
    capacity: int,
    s_rank: int,
    bm: int = 128,
) -> jax.Array:
    """Full MoE layer forward (gate -> dispatch -> expert FFN -> combine).

    a: (S_total, H) with tokens [r*s_rank, (r+1)*s_rank) belonging to source
    rank r (capacity applies per (rank, expert), mirroring the symmetric
    tensor layout's per-peer expert cells). Weights: wg (H, E); w1 (E, H, D);
    b1 (E, D); w2 (E, D, H); b2 (E, H). Returns (S_total, H) f32.
    """
    s_total, h = a.shape
    e_total = wg.shape[1]
    assert s_total % s_rank == 0
    n_ranks = s_total // s_rank

    # ---- gate (L1 kernel) + top-k routing --------------------------------
    scores = gate_k.gate_scores(a, wg, bm=bm)  # (S_total, E)
    idx, w = gate_k.topk_route(scores, k)  # (S_total, k)
    denom = jnp.sum(w, axis=-1, keepdims=True)  # combine normalizer, drops incl.

    # ---- per-rank capacity slotting ---------------------------------------
    slots = jnp.concatenate(
        [
            route_slots(idx[r * s_rank : (r + 1) * s_rank], e_total, capacity)
            for r in range(n_ranks)
        ],
        axis=0,
    )  # (S_total, k)
    kept = slots < capacity

    # ---- dispatch: scatter tokens into (E, n_ranks*capacity, H) -----------
    rank_of = jnp.repeat(jnp.arange(n_ranks), s_rank)[:, None]  # (S_total, 1)
    buf_rows = e_total * n_ranks * capacity
    flat_pos = idx * (n_ranks * capacity) + rank_of * capacity + slots
    flat_pos = jnp.where(kept, flat_pos, buf_rows)  # OOB -> dropped by scatter
    expert_in = (
        jnp.zeros((buf_rows, h), jnp.float32)
        .at[flat_pos.reshape(-1)]
        .set(jnp.repeat(a, k, axis=0), mode="drop")
    ).reshape(e_total, n_ranks * capacity, h)

    # ---- expert FFN (L1 fused kernel), one call per local expert ----------
    expert_out = jnp.stack(
        [
            ffn_k.ffn_block(expert_in[e], w1[e], b1[e], w2[e], b2[e], bm=bm)
            for e in range(e_total)
        ]
    ).reshape(buf_rows, h)

    # ---- combine: gather back + weighted accumulate (L1 kernel) -----------
    out = jnp.zeros((s_total, h), jnp.float32)
    for j in range(k):
        rows = jnp.where(kept[:, j], flat_pos[:, j], 0)
        gathered = expert_out[rows]  # (S_total, H)
        scale = jnp.where(kept[:, j], w[:, j] / denom[:, 0], 0.0)[:, None]
        out = combine_k.combine(out, gathered, scale, bm=bm)
    return out
