//! Multi-node scenario (paper §F / Fig 17), driven through the **live
//! engine** over the Transport subsystem: a node-aware config (4 nodes,
//! bounded NIC receive windows) runs real `MoeEngine` passes in both
//! dispatch modes. Latency and the Maximal Incast Volume are *measured*
//! (`PassMetrics::miv_bytes`); the paper's closed-form MIV stays as a
//! cross-check column; and the >2048-tokens/GPU incast failure shows up
//! as an engine-reported pass error, not a sim flag.
//!
//!     cargo run --release --example multinode_sim

use std::sync::Arc;

use flashdmoe::coordinator::{MoeEngine, TaskGraphMode};
use flashdmoe::expert::{generate_tokens, ModelParams};
use flashdmoe::harness::{miv_formula_bytes, multinode_config};
use flashdmoe::runtime::{ComputeBackend, NativeBackend};
use flashdmoe::util::stats::{fmt_bytes, fmt_time, Table};

fn main() -> anyhow::Result<()> {
    let seed = 42u64;
    println!("## Fig 17 — multi-node FlashDMoE, live engine (4 nodes, bounded NIC windows)\n");
    let base = multinode_config(256)?;
    let params = Arc::new(ModelParams::generate(&base, seed));
    let mut t = Table::new(&[
        "tokens/GPU",
        "mode",
        "latency",
        "MIV (measured)",
        "MIV (paper formula)",
        "inter/total bytes",
        "status",
    ]);
    for tokens in [256usize, 512, 1024, 2048, 4096] {
        for mode in ["flat", "hierarchical"] {
            let mut cfg = multinode_config(tokens)?;
            cfg.set("dispatch", mode)?;
            cfg.validate()?;
            let inputs: Vec<Vec<f32>> =
                (0..cfg.system.ranks).map(|r| generate_tokens(&cfg, seed, r)).collect();
            let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(&cfg));
            let engine =
                MoeEngine::start(cfg.clone(), params.clone(), backend, TaskGraphMode::Fused)?;
            let formula = miv_formula_bytes(&cfg, tokens);
            match engine.submit(&inputs)?.wait() {
                Ok(res) => {
                    let m = &res.metrics;
                    let total = m.intra_bytes() + m.inter_bytes();
                    t.row(&[
                        tokens.to_string(),
                        mode.to_string(),
                        fmt_time(m.wall_secs),
                        fmt_bytes(m.miv_bytes() as f64),
                        fmt_bytes(formula),
                        format!("{}%", m.inter_bytes() * 100 / total.max(1)),
                        "ok".to_string(),
                    ]);
                }
                Err(e) => {
                    // the paper's observed non-termination, surfaced as a
                    // real pass error by the poisoned-generation protocol
                    t.row(&[
                        tokens.to_string(),
                        mode.to_string(),
                        "-".into(),
                        "-".into(),
                        fmt_bytes(formula),
                        "-".into(),
                        "FAIL: NIC receive window overflow (incast)".into(),
                    ]);
                    println!("engine error at {tokens} tokens/GPU ({mode}): {e:#}\n");
                }
            }
            engine.shutdown();
        }
    }
    println!("{}", t.render());
    println!(
        "\nthe failure mode past 2048 tokens/GPU reproduces the paper's observed\n\
         non-termination: per-NIC ingress exceeds the bounded receive window\n\
         (cfg.cost.nic_buffer) in one pass generation. The overflow is raised\n\
         by the transport at put time, the failing rank poisons the pass, and\n\
         every peer abandons it promptly — an engine error, not a wedge.\n\
         Hierarchical dispatch coalesces each remote node's unique token rows\n\
         through one proxy rank, so its inter-node share sits below flat's at\n\
         every point while the outputs stay bitwise identical."
    );
    Ok(())
}
