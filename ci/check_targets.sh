#!/usr/bin/env bash
# Guard against silently-untested code: because sources live under
# `rust/` (no Cargo auto-discovery for tests/benches), a test or bench
# file that is not declared in Cargo.toml simply never runs — CI stays
# green while the file rots. This script fails if any file under
# `rust/tests/` or `rust/benches/` has no matching `path = "..."` entry
# in Cargo.toml (examples live in the conventional top-level `examples/`
# and ARE auto-discovered, so they need no declarations).
set -euo pipefail
cd "$(dirname "$0")/.."

fail=0
for f in rust/tests/*.rs rust/benches/*.rs; do
  [ -e "$f" ] || continue
  if ! grep -Fq "path = \"$f\"" Cargo.toml; then
    echo "ERROR: $f is not declared in Cargo.toml — it will never run in CI" >&2
    fail=1
  fi
done

# The reverse direction: every declared target must exist on disk, or
# `cargo build --all-targets` breaks for everyone.
while IFS= read -r p; do
  case "$p" in
    rust/tests/*|rust/benches/*)
      if [ ! -e "$p" ]; then
        echo "ERROR: Cargo.toml declares $p but the file does not exist" >&2
        fail=1
      fi
      ;;
  esac
done < <(sed -n 's/^path = "\(.*\)"$/\1/p' Cargo.toml)

if [ "$fail" -ne 0 ]; then
  exit 1
fi
echo "check_targets: every rust/tests and rust/benches file is declared in Cargo.toml"
