//! PJRT runtime: load the AOT HLO-text artifacts and execute them from the
//! Rust hot path, plus the interchangeable native backend.
//!
//! Python runs only at `make artifacts` time; this module gives the L3
//! coordinator a [`ComputeBackend`] with two implementations:
//!
//! * [`XlaBackend`] — compiles `artifacts/<preset>_*.hlo.txt` once on a
//!   PJRT CPU client and executes the L1 Pallas kernels per task. Expert
//!   weights are uploaded into cached [`xla::Literal`]s at construction so
//!   the per-task cost is one input copy + one execution.
//! * [`NativeBackend`] — the in-process blocked GEMM (`crate::gemm`),
//!   used by tests, the baselines, and anywhere artifacts are absent.
//!
//! Both backends implement identical math; `rust/tests/runtime_xla.rs`
//! asserts agreement to f32 tolerance.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::Config;
use crate::expert::{ExpertParams, ModelParams, PackedExpert};
use crate::gemm;
use crate::util::json::Json;

/// Shape/metadata of one compiled artifact (from `manifest.json`).
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub inputs: Vec<(String, Vec<usize>)>,
    pub outputs: Vec<(String, Vec<usize>)>,
}

/// One compiled HLO module on the PJRT client.
///
/// SAFETY(Send/Sync): the PJRT CPU client is thread-safe per the PJRT API
/// contract (executions may be issued concurrently from multiple threads);
/// the wrapper only exposes `&self` execution.
pub struct CompiledKernel {
    exe: xla::PjRtLoadedExecutable,
    pub meta: ArtifactMeta,
}

unsafe impl Send for CompiledKernel {}
unsafe impl Sync for CompiledKernel {}

impl CompiledKernel {
    /// Execute with f32 inputs; returns the flattened f32 outputs of the
    /// 1-tuple result (artifacts are lowered with `return_tuple=True`).
    pub fn run(&self, inputs: &[&[f32]]) -> Result<Vec<f32>> {
        if inputs.len() != self.meta.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.meta.name,
                self.meta.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, (name, dims)) in inputs.iter().zip(&self.meta.inputs) {
            literals.push(make_literal(data, dims).with_context(|| {
                format!("{}: building literal for input '{name}'", self.meta.name)
            })?);
        }
        self.run_literals(&literals)
    }

    /// Execute with pre-built literals (lets callers cache weight uploads).
    pub fn run_literals(&self, literals: &[xla::Literal]) -> Result<Vec<f32>> {
        let result = self
            .exe
            .execute::<xla::Literal>(literals)
            .with_context(|| format!("executing {}", self.meta.name))?;
        let lit = result[0][0].to_literal_sync()?;
        let out = lit.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Execute a multi-output artifact; returns each tuple element's f32s
    /// (e.g. `train_step`: loss + updated parameters).
    pub fn run_literals_tuple(&self, literals: &[xla::Literal]) -> Result<Vec<Vec<f32>>> {
        let result = self
            .exe
            .execute::<xla::Literal>(literals)
            .with_context(|| format!("executing {}", self.meta.name))?;
        let lit = result[0][0].to_literal_sync()?;
        lit.to_tuple()?.into_iter().map(|l| Ok(l.to_vec::<f32>()?)).collect()
    }
}

/// Build an f32 literal from a slice + dims.
pub fn make_literal(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    if n != data.len() {
        bail!("literal shape {dims:?} needs {n} elems, got {}", data.len());
    }
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        dims,
        bytes,
    )?)
}

/// Loads `manifest.json`, compiles one preset's artifacts on a PJRT CPU
/// client, and hands out [`CompiledKernel`]s.
pub struct ArtifactStore {
    pub preset: String,
    pub config: Config,
    kernels: HashMap<String, CompiledKernel>,
    /// Wall time spent compiling all artifacts (reported by the CLI).
    pub compile_secs: f64,
}

impl ArtifactStore {
    /// Default on-disk location (relative to the repo root / CWD).
    pub fn default_dir() -> PathBuf {
        std::env::var("FLASHDMOE_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// True if artifacts have been built (used to skip XLA tests cleanly).
    pub fn available(dir: &Path) -> bool {
        dir.join("manifest.json").exists()
    }

    pub fn load(dir: &Path, preset: &str) -> Result<Self> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                manifest_path.display()
            )
        })?;
        let manifest = Json::parse(&text)?;
        let entry = manifest
            .get("presets")?
            .opt(preset)
            .ok_or_else(|| anyhow!("preset '{preset}' not in manifest"))?;

        // shape config from the manifest is authoritative
        let c = entry.get("config")?;
        let mut config = Config::preset(preset).unwrap_or(Config::preset("default")?);
        for key in ["h", "d", "e", "k", "bm", "bn"] {
            config.set(key, &format!("{}", c.get(key)?.as_usize()?))?;
        }
        config.set("ranks", &format!("{}", c.get("ranks")?.as_usize()?))?;
        config.set("s_rank", &format!("{}", c.get("s_rank")?.as_usize()?))?;
        config.validate()?;
        let manifest_cap = c.get("capacity")?.as_usize()?;
        let computed = config.model.capacity(config.system.s_rank);
        if manifest_cap != computed {
            bail!("capacity mismatch: manifest {manifest_cap} vs config math {computed}");
        }

        let client = xla::PjRtClient::cpu()?;
        let start = std::time::Instant::now();
        let mut kernels = HashMap::new();
        for (name, art) in entry.get("artifacts")?.as_obj()? {
            let parse_io = |key: &str| -> Result<Vec<(String, Vec<usize>)>> {
                art.get(key)?
                    .as_arr()?
                    .iter()
                    .map(|io| {
                        let pair = io.as_arr()?;
                        Ok((pair[0].as_str()?.to_string(), pair[1].as_shape()?))
                    })
                    .collect()
            };
            let meta = ArtifactMeta {
                name: name.clone(),
                file: art.get("file")?.as_str()?.to_string(),
                inputs: parse_io("inputs")?,
                outputs: parse_io("outputs")?,
            };
            let path = dir.join(&meta.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", meta.name))?;
            kernels.insert(name.clone(), CompiledKernel { exe, meta });
        }
        Ok(Self {
            preset: preset.to_string(),
            config,
            kernels,
            compile_secs: start.elapsed().as_secs_f64(),
        })
    }

    pub fn kernel(&self, name: &str) -> Result<&CompiledKernel> {
        self.kernels
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not loaded"))
    }

    pub fn kernel_names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.kernels.keys().map(|s| s.as_str()).collect();
        v.sort_unstable();
        v
    }

    /// Execute the monolithic `moe_layer` reference over all ranks' tokens.
    pub fn run_moe_layer(&self, a: &[f32], params: &ModelParams) -> Result<Vec<f32>> {
        let k = self.kernel("moe_layer")?;
        let (w1, b1, w2, b2) = params.pack_for_artifact();
        k.run(&[a, &params.wg, &w1, &b1, &w2, &b2])
    }
}

// ---------------------------------------------------------------------------
// ComputeBackend
// ---------------------------------------------------------------------------

/// Tile-granular compute interface consumed by Processor actors. `scratch`
/// is caller-owned working memory (>= bm*d floats) so the hot path stays
/// allocation-free on the native backend.
pub trait ComputeBackend: Send + Sync {
    fn name(&self) -> &'static str;

    /// One-time weight preparation, invoked by `MoeEngine::start` (and any
    /// other long-lived owner) before the first pass. Backends that keep
    /// derived weight state — the native backend's packed panels, the XLA
    /// backend's uploaded literals — build it here, so steady-state passes
    /// do zero per-pass weight work. Default: no-op.
    fn prepare(&self, _params: &ModelParams) -> Result<()> {
        Ok(())
    }

    /// Re-run weight preparation against **updated** parameters (a
    /// training optimizer step installing new weights via
    /// `MoeEngine::update_params`). Backends with derived weight state
    /// must invalidate it first — stale packed panels would silently
    /// serve the old weights. Default: delegate to
    /// [`prepare`](Self::prepare) (correct for stateless backends).
    fn refresh(&self, params: &ModelParams) -> Result<()> {
        self.prepare(params)
    }

    /// Prepare an **additional** resident model's weights under a
    /// disjoint key band: expert `i` of `params` is cached as backend
    /// expert id `key_base + i`, so co-resident models never collide in
    /// the derived-weight cache (the engine's
    /// [`ModelRegistry`](crate::registry::ModelRegistry) assigns each
    /// model a unique `key_base` and hands tasks the shifted ids).
    /// Re-preparing an occupied band must *overwrite* it — an evicted
    /// model's stale panels silently serving a new registrant is the
    /// failure mode this contract exists to prevent. `key_base == 0` is
    /// the anchor model and delegates to [`prepare`](Self::prepare);
    /// backends without banded caches reject `key_base > 0`.
    fn prepare_model(&self, params: &ModelParams, key_base: usize) -> Result<()> {
        if key_base == 0 {
            return self.prepare(params);
        }
        bail!(
            "backend '{}' has no banded weight cache: cannot host a second \
             resident model (key_base {key_base})",
            self.name()
        )
    }

    /// True when this backend serves split-mode column tiles from its own
    /// packed weight cache (filled by [`prepare`](Self::prepare)), making
    /// caller-side `w1c`/`w2c` column copies dead weight — callers may
    /// then pass empty weight slices (bias slices are still consumed).
    /// Default: false.
    fn packed_split_tiles(&self) -> bool {
        false
    }

    /// True when [`ffn_tile`](Self::ffn_tile) leaves the post-activation
    /// hidden tile `relu(x·W1 + b1)` in `scratch[..rows*d]` on return.
    /// The training stash reads it straight out of scratch to avoid a
    /// recompute per backward tile; backends that answer `false` make
    /// the backward recompute the hidden tile from the stashed inputs
    /// instead. Default: false (the conservative answer).
    fn mid_in_scratch(&self) -> bool {
        false
    }

    /// softmax(A·Wg) for one rank's (s, H) tokens -> (s, E) scores.
    fn gate_scores(&self, a: &[f32], wg: &[f32], s: usize) -> Result<Vec<f32>>;

    /// Fused FFN over one (bm, H) tile of expert `ex` (`expert_id` is the
    /// *global* expert index, the key for backend-side weight caches).
    fn ffn_tile(
        &self,
        x: &[f32],
        ex: &ExpertParams,
        expert_id: usize,
        out: &mut [f32],
        scratch: &mut [f32],
    ) -> Result<()>;

    /// Split-mode GEMM0: relu(x·W1[:, col·bn..] + b1c) over one (bm, bn)
    /// tile. `w1c`/`b1c` carry the column slice for cache-less backends;
    /// backends with a packed cache resolve (expert_id, col) into their
    /// own panel run instead.
    fn gemm0_tile(
        &self,
        x: &[f32],
        w1c: &[f32],
        b1c: &[f32],
        out: &mut [f32],
        expert_id: usize,
        col: usize,
    ) -> Result<()>;

    /// Split-mode GEMM1: h·W2[:, col·bn..] + b2c over one (bm, bn) tile.
    fn gemm1_tile(
        &self,
        h: &[f32],
        w2c: &[f32],
        b2c: &[f32],
        out: &mut [f32],
        expert_id: usize,
        col: usize,
    ) -> Result<()>;
}

/// Pure-Rust backend over `crate::gemm`, in one of two modes:
///
/// * **packed** (`cfg.system.packed`, the default) — expert weights are
///   re-laid into the persistent NR-panel format exactly once (at
///   [`prepare`](ComputeBackend::prepare), or lazily on an expert's first
///   tile), and every FFN/GEMM task streams contiguous panels with the
///   epilogue fused into the single C write-back.
/// * **unpacked** — the original row-major blocked kernels; the A/B
///   baseline `harness::gemm_backend_ab` measures against.
///
/// `pack_count()` audits the packed contract: it equals the number of
/// distinct experts packed so far, and must stop growing after `prepare`
/// — steady-state passes never re-pack (asserted in the engine tests).
pub struct NativeBackend {
    pub h: usize,
    pub d: usize,
    pub e: usize,
    pub bm: usize,
    pub bn: usize,
    packed: bool,
    packs: AtomicU64,
    /// Per-global-expert packed weights, filled by `prepare` (or lazily).
    /// Read-mostly: after `prepare` every tile takes only the shared read
    /// lock (uncontended Arc clone) — the write lock exists solely for
    /// the lazy first-touch path, so the hot path this PR de-serializes
    /// never funnels through an exclusive backend lock.
    cache: RwLock<Vec<Option<Arc<PackedExpert>>>>,
}

impl NativeBackend {
    pub fn from_config(cfg: &Config) -> Self {
        Self::with_packed(cfg, cfg.system.packed)
    }

    /// Explicit-mode constructor for A/B comparisons.
    pub fn with_packed(cfg: &Config, packed: bool) -> Self {
        Self {
            h: cfg.model.h,
            d: cfg.model.d,
            e: cfg.model.e,
            bm: cfg.model.bm,
            bn: cfg.model.bn,
            packed,
            packs: AtomicU64::new(0),
            cache: RwLock::new(vec![None; cfg.model.e]),
        }
    }

    pub fn is_packed(&self) -> bool {
        self.packed
    }

    /// Experts packed so far (== distinct experts touched; flat after
    /// `prepare`, and flat across every steady-state pass).
    pub fn pack_count(&self) -> u64 {
        self.packs.load(Ordering::Relaxed)
    }

    /// Packed weights of `expert_id`, packing on first touch. Steady
    /// state (post-`prepare`) takes only the read lock.
    fn packed_expert(&self, expert_id: usize, ex: &ExpertParams) -> Arc<PackedExpert> {
        if let Some(pe) = self.cached_expert(expert_id) {
            return pe;
        }
        let mut cache = self.cache.write().unwrap();
        if cache.len() <= expert_id {
            cache.resize(expert_id + 1, None);
        }
        if let Some(pe) = &cache[expert_id] {
            return pe.clone(); // another thread packed it while we upgraded
        }
        let pe = Arc::new(ex.pack(self.h, self.d));
        self.packs.fetch_add(1, Ordering::Relaxed);
        cache[expert_id] = Some(pe.clone());
        pe
    }

    /// Cache lookup without packing (split-mode tiles have no
    /// `ExpertParams` in hand; `prepare` fills the cache for them).
    fn cached_expert(&self, expert_id: usize) -> Option<Arc<PackedExpert>> {
        self.cache.read().unwrap().get(expert_id).cloned().flatten()
    }

    /// True when split-mode column tiles can use the packed panels: the
    /// tile width must be a whole number of NR panels.
    fn packed_cols_ok(&self) -> bool {
        self.packed && self.bn % gemm::NR == 0
    }
}

impl NativeBackend {
    fn ensure_slice(len: usize, want: usize, what: &str, expert_id: usize) -> Result<()> {
        anyhow::ensure!(
            len == want,
            "{what}: no packed cache for expert {expert_id} and no usable weight slice \
             (got {len} floats, need {want}) — call prepare() or pass the column slice"
        );
        Ok(())
    }
}

impl ComputeBackend for NativeBackend {
    fn name(&self) -> &'static str {
        if self.packed {
            "native-packed"
        } else {
            "native"
        }
    }

    fn prepare(&self, params: &ModelParams) -> Result<()> {
        if self.packed {
            for (ex_id, ex) in params.experts.iter().enumerate() {
                let _ = self.packed_expert(ex_id, ex);
            }
        }
        Ok(())
    }

    /// Pack `params`' experts into the `[key_base, key_base + E)` band,
    /// eagerly and overwriting: a band once occupied by an evicted model
    /// must not leak its stale panels to a new registrant, so unlike
    /// `prepare` this never trusts an existing cache entry. Deduplicated
    /// registrations never reach here (the registry reuses the survivor's
    /// band), so every call counts `params.experts.len()` fresh packs.
    fn prepare_model(&self, params: &ModelParams, key_base: usize) -> Result<()> {
        if !self.packed {
            return Ok(()); // unpacked tiles read ExpertParams directly
        }
        let mut cache = self.cache.write().unwrap();
        let want = key_base + params.experts.len();
        if cache.len() < want {
            cache.resize(want, None);
        }
        for (i, ex) in params.experts.iter().enumerate() {
            cache[key_base + i] = Some(Arc::new(ex.pack(self.h, self.d)));
            self.packs.fetch_add(1, Ordering::Relaxed);
        }
        Ok(())
    }

    /// Drop every packed panel, then re-pack from the new weights. The
    /// pack counter keeps counting (each refresh re-packs every expert) —
    /// the "flat after prepare" audit only applies between weight swaps.
    fn refresh(&self, params: &ModelParams) -> Result<()> {
        {
            let mut cache = self.cache.write().unwrap();
            let len = cache.len();
            *cache = vec![None; len.max(params.experts.len())];
        }
        self.prepare(params)
    }

    fn packed_split_tiles(&self) -> bool {
        self.packed_cols_ok()
    }

    /// `gemm::ffn`/`ffn_packed` both compute the hidden tile into
    /// `scratch[..rows*d]` and leave it there — the stash contract.
    fn mid_in_scratch(&self) -> bool {
        true
    }

    fn gate_scores(&self, a: &[f32], wg: &[f32], s: usize) -> Result<Vec<f32>> {
        let mut logits = vec![0.0f32; s * self.e];
        gemm::gemm_bias(a, wg, None, &mut logits, s, self.h, self.e, gemm::Epilogue::Identity);
        crate::gate::softmax_rows(&mut logits, self.e);
        Ok(logits)
    }

    fn ffn_tile(
        &self,
        x: &[f32],
        ex: &ExpertParams,
        expert_id: usize,
        out: &mut [f32],
        scratch: &mut [f32],
    ) -> Result<()> {
        if self.packed {
            let pe = self.packed_expert(expert_id, ex);
            gemm::ffn_packed(
                x, &pe.w1, &pe.b1, &pe.w2, &pe.b2, out, scratch, self.bm, self.h, self.d,
            );
        } else {
            gemm::ffn(x, &ex.w1, &ex.b1, &ex.w2, &ex.b2, out, scratch, self.bm, self.h, self.d);
        }
        Ok(())
    }

    fn gemm0_tile(
        &self,
        x: &[f32],
        w1c: &[f32],
        b1c: &[f32],
        out: &mut [f32],
        expert_id: usize,
        col: usize,
    ) -> Result<()> {
        if self.packed_cols_ok() {
            if let Some(pe) = self.cached_expert(expert_id) {
                gemm::gemm_bias_packed_cols(
                    x,
                    &pe.w1,
                    col * self.bn,
                    self.bn,
                    Some(b1c),
                    out,
                    self.bn,
                    self.bm,
                    gemm::Epilogue::Relu,
                );
                return Ok(());
            }
        }
        Self::ensure_slice(w1c.len(), self.h * self.bn, "gemm0_tile", expert_id)?;
        gemm::gemm_bias(x, w1c, Some(b1c), out, self.bm, self.h, self.bn, gemm::Epilogue::Relu);
        Ok(())
    }

    fn gemm1_tile(
        &self,
        h: &[f32],
        w2c: &[f32],
        b2c: &[f32],
        out: &mut [f32],
        expert_id: usize,
        col: usize,
    ) -> Result<()> {
        if self.packed_cols_ok() {
            if let Some(pe) = self.cached_expert(expert_id) {
                gemm::gemm_bias_packed_cols(
                    h,
                    &pe.w2,
                    col * self.bn,
                    self.bn,
                    Some(b2c),
                    out,
                    self.bn,
                    self.bm,
                    gemm::Epilogue::Identity,
                );
                return Ok(());
            }
        }
        Self::ensure_slice(w2c.len(), self.d * self.bn, "gemm1_tile", expert_id)?;
        gemm::gemm_bias(h, w2c, Some(b2c), out, self.bm, self.d, self.bn, gemm::Epilogue::Identity);
        Ok(())
    }
}

/// XLA/PJRT backend executing the AOT Pallas kernels. Expert weight
/// literals are uploaded once at construction (keyed by expert id).
pub struct XlaBackend {
    store: ArtifactStore,
    /// Cached per-expert weight literals for `ffn_tile`: [w1, b1, w2, b2].
    weight_cache: Mutex<HashMap<usize, std::sync::Arc<Vec<xla::Literal>>>>,
    h: usize,
    d: usize,
    bm: usize,
    #[allow(dead_code)]
    bn: usize,
}

// SAFETY: see CompiledKernel; Literal reads are immutable post-upload.
unsafe impl Send for XlaBackend {}
unsafe impl Sync for XlaBackend {}

impl XlaBackend {
    pub fn new(store: ArtifactStore) -> Self {
        let m = &store.config.model;
        let (h, d, bm, bn) = (m.h, m.d, m.bm, m.bn);
        Self { store, weight_cache: Mutex::new(HashMap::new()), h, d, bm, bn }
    }

    pub fn store(&self) -> &ArtifactStore {
        &self.store
    }

    /// Pre-upload all expert weights (call once before timing).
    pub fn warm_weights(&self, params: &ModelParams) -> Result<()> {
        for e in 0..params.num_experts() {
            self.cached_weights(e, &params.experts[e])?;
        }
        Ok(())
    }

    fn cached_weights(
        &self,
        expert_id: usize,
        ex: &ExpertParams,
    ) -> Result<std::sync::Arc<Vec<xla::Literal>>> {
        let mut cache = self.weight_cache.lock().unwrap();
        if let Some(l) = cache.get(&expert_id) {
            return Ok(l.clone());
        }
        let lits = std::sync::Arc::new(vec![
            make_literal(&ex.w1, &[self.h, self.d])?,
            make_literal(&ex.b1, &[self.d])?,
            make_literal(&ex.w2, &[self.d, self.h])?,
            make_literal(&ex.b2, &[self.h])?,
        ]);
        cache.insert(expert_id, lits.clone());
        Ok(lits)
    }
}

impl ComputeBackend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    /// Pre-upload every expert's weight literals (the XLA analog of
    /// packing): steady-state passes then only copy activations.
    fn prepare(&self, params: &ModelParams) -> Result<()> {
        self.warm_weights(params)
    }

    /// Invalidate the uploaded weight literals before re-uploading —
    /// stale literals would keep serving the pre-update weights.
    fn refresh(&self, params: &ModelParams) -> Result<()> {
        self.weight_cache.lock().unwrap().clear();
        self.warm_weights(params)
    }

    /// Upload `params`' weight literals under the `[key_base, key_base+E)`
    /// id band, overwriting any stale entries an evicted model left there.
    fn prepare_model(&self, params: &ModelParams, key_base: usize) -> Result<()> {
        let mut cache = self.weight_cache.lock().unwrap();
        for (i, ex) in params.experts.iter().enumerate() {
            let lits = std::sync::Arc::new(vec![
                make_literal(&ex.w1, &[self.h, self.d])?,
                make_literal(&ex.b1, &[self.d])?,
                make_literal(&ex.w2, &[self.d, self.h])?,
                make_literal(&ex.b2, &[self.h])?,
            ]);
            cache.insert(key_base + i, lits);
        }
        Ok(())
    }

    fn gate_scores(&self, a: &[f32], wg: &[f32], s: usize) -> Result<Vec<f32>> {
        let k = self.store.kernel("gate")?;
        let expect = k.meta.inputs[0].1[0];
        if s != expect {
            bail!("gate artifact is shape-specialized to S={expect}, got {s}");
        }
        k.run(&[a, wg])
    }

    fn ffn_tile(
        &self,
        x: &[f32],
        ex: &ExpertParams,
        expert_id: usize,
        out: &mut [f32],
        _scratch: &mut [f32],
    ) -> Result<()> {
        let k = self.store.kernel("ffn_tile")?;
        let weights = self.cached_weights(expert_id, ex)?;
        let mut lits = Vec::with_capacity(5);
        lits.push(make_literal(x, &[self.bm, self.h])?);
        for w in weights.iter() {
            lits.push(w.clone());
        }
        let y = k.run_literals(&lits)?;
        out.copy_from_slice(&y);
        Ok(())
    }

    fn gemm0_tile(
        &self,
        x: &[f32],
        w1c: &[f32],
        b1c: &[f32],
        out: &mut [f32],
        _expert_id: usize,
        _col: usize,
    ) -> Result<()> {
        let k = self.store.kernel("gemm0_tile")?;
        let y = k.run(&[x, w1c, b1c])?;
        out.copy_from_slice(&y);
        Ok(())
    }

    fn gemm1_tile(
        &self,
        h: &[f32],
        w2c: &[f32],
        b2c: &[f32],
        out: &mut [f32],
        _expert_id: usize,
        _col: usize,
    ) -> Result<()> {
        let k = self.store.kernel("gemm1_tile")?;
        let y = k.run(&[h, w2c, b2c])?;
        out.copy_from_slice(&y);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::stats::max_abs_diff;

    #[test]
    fn native_gate_matches_gate_module() {
        let cfg = Config::preset("tiny").unwrap();
        let be = NativeBackend::from_config(&cfg);
        let mut rng = Rng::new(1);
        let s = 16;
        let a = rng.normal_vec(s * cfg.model.h, 1.0);
        let wg = rng.normal_vec(cfg.model.h * cfg.model.e, 1.0);
        let scores = be.gate_scores(&a, &wg, s).unwrap();
        let routing = crate::gate::gate_and_route(&a, &wg, s, &cfg.model, 32);
        assert!(max_abs_diff(&scores, &routing.scores) < 1e-5);
    }

    #[test]
    fn packed_backend_packs_each_expert_once_and_matches_unpacked() {
        let cfg = Config::preset("tiny").unwrap();
        let m = &cfg.model;
        let packed = NativeBackend::with_packed(&cfg, true);
        let unpacked = NativeBackend::with_packed(&cfg, false);
        assert!(packed.is_packed() && !unpacked.is_packed());
        assert_eq!(packed.name(), "native-packed");
        assert_eq!(packed.pack_count(), 0, "no packing before first touch");
        let mut rng = Rng::new(11);
        let ex = ExpertParams {
            w1: rng.normal_vec(m.h * m.d, 0.1),
            b1: rng.normal_vec(m.d, 0.1),
            w2: rng.normal_vec(m.d * m.h, 0.1),
            b2: rng.normal_vec(m.h, 0.1),
        };
        let x = rng.normal_vec(m.bm * m.h, 1.0);
        let mut scratch = vec![0.0; m.bm * m.d];
        let mut a = vec![0.0; m.bm * m.h];
        let mut b = vec![0.0; m.bm * m.h];
        for _ in 0..3 {
            packed.ffn_tile(&x, &ex, 2, &mut a, &mut scratch).unwrap();
        }
        assert_eq!(packed.pack_count(), 1, "repeated tiles reuse the one pack");
        unpacked.ffn_tile(&x, &ex, 2, &mut b, &mut scratch).unwrap();
        assert_eq!(unpacked.pack_count(), 0, "unpacked mode never packs");
        assert!(max_abs_diff(&a, &b) < 1e-3, "packed vs unpacked FFN tile");
        // prepare() packs every expert exactly once, idempotently
        let params = crate::expert::ModelParams::generate(&cfg, 3);
        let fresh = NativeBackend::with_packed(&cfg, true);
        fresh.prepare(&params).unwrap();
        assert_eq!(fresh.pack_count(), m.e as u64, "pack count == expert count");
        fresh.prepare(&params).unwrap();
        assert_eq!(fresh.pack_count(), m.e as u64, "prepare is idempotent");
    }

    #[test]
    fn prepare_model_bands_are_disjoint_and_overwriting() {
        let cfg = Config::preset("tiny").unwrap();
        let m = cfg.model.clone();
        let be = NativeBackend::with_packed(&cfg, true);
        let a = crate::expert::ModelParams::generate(&cfg, 1);
        let b = crate::expert::ModelParams::generate(&cfg, 2);
        be.prepare(&a).unwrap();
        be.prepare_model(&b, m.e).unwrap();
        assert_eq!(be.pack_count(), 2 * m.e as u64, "both bands packed");
        // tiles keyed into band 1 serve model B's weights, band 0 model A's
        let mut rng = Rng::new(4);
        let x = rng.normal_vec(m.bm * m.h, 1.0);
        let mut scratch = vec![0.0; m.bm * m.d];
        let (mut ya, mut yb, mut yref) =
            (vec![0.0; m.bm * m.h], vec![0.0; m.bm * m.h], vec![0.0; m.bm * m.h]);
        be.ffn_tile(&x, &a.experts[0], 0, &mut ya, &mut scratch).unwrap();
        be.ffn_tile(&x, &b.experts[0], m.e, &mut yb, &mut scratch).unwrap();
        let unpacked = NativeBackend::with_packed(&cfg, false);
        unpacked.ffn_tile(&x, &b.experts[0], 0, &mut yref, &mut scratch).unwrap();
        assert!(max_abs_diff(&yb, &yref) < 1e-3, "band 1 serves model B");
        assert!(max_abs_diff(&ya, &yb) > 1e-3, "bands hold different weights");
        // re-preparing an occupied band overwrites — no stale panels
        let c = crate::expert::ModelParams::generate(&cfg, 9);
        be.prepare_model(&c, m.e).unwrap();
        let mut yc = vec![0.0; m.bm * m.h];
        be.ffn_tile(&x, &c.experts[0], m.e, &mut yc, &mut scratch).unwrap();
        let mut ycref = vec![0.0; m.bm * m.h];
        unpacked.ffn_tile(&x, &c.experts[0], 0, &mut ycref, &mut scratch).unwrap();
        assert!(max_abs_diff(&yc, &ycref) < 1e-3, "band 1 re-registration overwrote");
        // unpacked backends accept any band as a no-op
        unpacked.prepare_model(&c, m.e).unwrap();
        assert_eq!(unpacked.pack_count(), 0);
    }

    #[test]
    fn native_ffn_tile_matches_split_tiles() {
        let cfg = Config::preset("tiny").unwrap();
        let m = &cfg.model;
        let be = NativeBackend::from_config(&cfg);
        let mut rng = Rng::new(2);
        let ex = ExpertParams {
            w1: rng.normal_vec(m.h * m.d, 0.1),
            b1: rng.normal_vec(m.d, 0.1),
            w2: rng.normal_vec(m.d * m.h, 0.1),
            b2: rng.normal_vec(m.h, 0.1),
        };
        let x = rng.normal_vec(m.bm * m.h, 1.0);
        let mut fused = vec![0.0; m.bm * m.h];
        let mut scratch = vec![0.0; m.bm * m.d];
        be.ffn_tile(&x, &ex, 0, &mut fused, &mut scratch).unwrap();

        // split path: all gemm0 column tiles, then all gemm1 column tiles
        let mut mid = vec![0.0; m.bm * m.d];
        for col in 0..m.d / m.bn {
            // slice W1 columns [col*bn, (col+1)*bn) out of row-major (h, d)
            let mut w1c = vec![0.0; m.h * m.bn];
            for r in 0..m.h {
                w1c[r * m.bn..(r + 1) * m.bn]
                    .copy_from_slice(&ex.w1[r * m.d + col * m.bn..r * m.d + (col + 1) * m.bn]);
            }
            let b1c = &ex.b1[col * m.bn..(col + 1) * m.bn];
            let mut out = vec![0.0; m.bm * m.bn];
            be.gemm0_tile(&x, &w1c, b1c, &mut out, 0, col).unwrap();
            for r in 0..m.bm {
                mid[r * m.d + col * m.bn..r * m.d + (col + 1) * m.bn]
                    .copy_from_slice(&out[r * m.bn..(r + 1) * m.bn]);
            }
        }
        let mut split = vec![0.0; m.bm * m.h];
        for col in 0..m.h / m.bn {
            let mut w2c = vec![0.0; m.d * m.bn];
            for r in 0..m.d {
                w2c[r * m.bn..(r + 1) * m.bn]
                    .copy_from_slice(&ex.w2[r * m.h + col * m.bn..r * m.h + (col + 1) * m.bn]);
            }
            let b2c = &ex.b2[col * m.bn..(col + 1) * m.bn];
            let mut out = vec![0.0; m.bm * m.bn];
            be.gemm1_tile(&mid, &w2c, b2c, &mut out, 0, col).unwrap();
            for r in 0..m.bm {
                split[r * m.h + col * m.bn..r * m.h + (col + 1) * m.bn]
                    .copy_from_slice(&out[r * m.bn..(r + 1) * m.bn]);
            }
        }
        assert!(max_abs_diff(&fused, &split) < 1e-3);
    }
}
