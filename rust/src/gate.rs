//! Gate: softmax top-k routing and the paper's routing tables.
//!
//! Produces `G_phi` (affinity scores, S×E) and `T_phi` (the routing table:
//! per (expert, capacity-slot) → (token, combine weight)), plus the
//! *payload-efficient dispatch plan* — the per-destination list of
//! non-empty tiles that actually travel (paper §1.1 "payload-efficient
//! communication": null-padded capacity slots never hit the wire).
//!
//! Numerics follow the contract in DESIGN.md §4 exactly (softmax with max
//! subtraction, ties to the lower expert index, token-order slot
//! assignment, drops beyond aligned capacity) so the Rust routing agrees
//! bit-for-tolerance with `ref.py` and the AOT `moe_layer` artifact.
//!
//! **NaN / tie-break contract.** The gate is total over arbitrary f32
//! input, including NaN and ±inf — a poisoned embedding row must never
//! panic a resident rank actor (it would wedge every peer on the
//! watchdog). Precisely:
//!
//! * [`softmax_rows`]: any row whose softmax is undefined — all `-inf`
//!   logits (sum 0), or a NaN/`+inf` logit (NaN sum) — falls back to the
//!   uniform distribution `1/E`, so the row still routes and its combine
//!   weights stay finite.
//! * [`topk_rows`]: comparison is [`f32::total_cmp`] with NaN explicitly
//!   sorted *last* (total order alone would rank positive NaN above
//!   `+inf`). Equal scores — including `-0.0` vs `+0.0`, which are
//!   normalized before comparison — tie toward the lower expert index,
//!   matching `jax.lax.top_k`. A row of fewer than `k` non-NaN scores
//!   still yields `k` indices (NaN-scored experts fill the tail).
//!
//! **Load accounting.** [`Routing`] carries two per-expert histograms:
//! `offered_load` counts every top-k (token, expert) pair *before* the
//! capacity clamp — the demand signal the replication EWMA tracker feeds
//! on (`Σ offered_load == s × k` under every policy) — while
//! `expert_load` counts kept routes only (what actually travels).
//!
//! **Replication.** [`dispatch_plan`] consults a [`Placement`] instead of
//! a static owner function: an expert with R serving locations (primary +
//! replicas, see `crate::placement`) has its routed tokens sharded
//! deterministically by arrival index (`j % R`) across the locations,
//! each shard re-slotted densely and tiled by bM. Tiles stay grouped by
//! ascending expert id, so the plan-order combine fold accumulates each
//! token's per-expert contributions in the same order as under static
//! placement — replication is bitwise-invisible to pass outputs.
//!
//! **Routing policy.** Under [`RoutingPolicy::Capacity`] the per-(source,
//! expert) buffer is fixed and over-capacity pairs are dropped, so a
//! skewed gate silently changes the computed function. Under
//! [`RoutingPolicy::Dropless`] (MegaBlocks-style dropless MoE via
//! variable-sized blocks) the caller passes the policy's worst-case
//! [`slot_capacity`](ModelConfig::slot_capacity) and no pair can ever
//! overflow: [`dispatch_plan`] builds a *variable-length* tile list per
//! expert sized to the actual routed counts — full bM tiles plus one
//! partially-filled tail tile, row counts carried in the signal flag —
//! so quality-preserving routing costs no padded traffic.
//!
//! [`RoutingPolicy::Capacity`]: crate::config::RoutingPolicy::Capacity
//! [`RoutingPolicy::Dropless`]: crate::config::RoutingPolicy::Dropless

use crate::config::ModelConfig;
use crate::placement::Placement;

/// One routed (token, expert) pair.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Route {
    /// Token index within the source rank's sequence.
    pub token: u32,
    /// Global expert id.
    pub expert: u32,
    /// Slot within the (source rank, expert) capacity buffer.
    pub slot: u32,
    /// Raw gate score g_{i,e}.
    pub weight: f32,
    /// Normalized combine weight g / C_i (drops included in C_i).
    pub combine_weight: f32,
}

/// Routing output for one rank's tokens.
#[derive(Clone, Debug)]
pub struct Routing {
    /// Gate scores G_phi, row-major (S, E).
    pub scores: Vec<f32>,
    /// Top-k expert ids per token, row-major (S, k).
    pub topk_idx: Vec<u32>,
    /// Top-k raw weights per token, row-major (S, k).
    pub topk_w: Vec<f32>,
    /// Kept (non-dropped) routes, in token-major / k-minor arrival order.
    pub routes: Vec<Route>,
    /// Number of dropped (over-capacity) pairs.
    pub dropped: usize,
    /// Tokens routed to each expert that were *kept* (post capacity
    /// clamp), length E — what actually travels.
    pub expert_load: Vec<u32>,
    /// Tokens the gate *offered* to each expert (kept + dropped), length
    /// E. Always sums to `s × k`; under `Capacity` routing this is the
    /// un-clamped demand signal the replication EWMA tracker consumes —
    /// `expert_load` saturates at capacity exactly when skew matters.
    pub offered_load: Vec<u32>,
    pub s: usize,
    pub e: usize,
    pub k: usize,
    pub capacity: usize,
}

impl Routing {
    /// Mean per-token Shannon entropy (nats) of the post-softmax gate
    /// distribution `scores` — the training loop's gate-collapse signal:
    /// `ln E` for a perfectly uniform gate, → 0 as the gate concentrates
    /// on single experts. 0.0 when no tokens were routed. Stamped into
    /// `RankMetrics::gate_entropy` by every forward pass.
    pub fn entropy(&self) -> f64 {
        if self.s == 0 {
            return 0.0;
        }
        let mut total = 0.0f64;
        for row in self.scores.chunks(self.e) {
            for &p in row {
                let p = p as f64;
                if p > 0.0 {
                    total -= p * p.ln();
                }
            }
        }
        total / self.s as f64
    }
}

/// Row softmax with max subtraction over logits (S, E), in place.
///
/// Total over arbitrary input (module-header contract): a row whose
/// softmax is undefined — all `-inf` (sum 0, which would make `inv`
/// infinite and the row NaN), or any NaN/`+inf` logit (NaN sum) — falls
/// back to the uniform distribution `1/E` instead of emitting NaN.
pub fn softmax_rows(logits: &mut [f32], e: usize) {
    debug_assert_eq!(logits.len() % e, 0);
    for row in logits.chunks_mut(e) {
        let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            sum += *v;
        }
        if sum > 0.0 && sum.is_finite() {
            let inv = 1.0 / sum;
            for v in row.iter_mut() {
                *v *= inv;
            }
        } else {
            // degenerate row: uniform fallback keeps routing total
            row.fill(1.0 / e as f32);
        }
    }
}

/// Top-k per row: descending score, ties broken toward the lower index
/// (matches `jax.lax.top_k`). Returns (indices, weights) both (S, k).
///
/// NaN-safe (module-header contract): comparison is [`f32::total_cmp`]
/// with NaN explicitly sorted last — `partial_cmp().unwrap()` here used
/// to panic the calling rank actor on a single NaN score, and raw
/// `total_cmp` would instead rank positive NaN *above* `+inf`. Signed
/// zeros are normalized (`-0.0 + 0.0 == +0.0`) so they still tie toward
/// the lower index as equal scores always have.
pub fn topk_rows(scores: &[f32], e: usize, k: usize) -> (Vec<u32>, Vec<f32>) {
    let s = scores.len() / e;
    let mut idx = Vec::with_capacity(s * k);
    let mut w = Vec::with_capacity(s * k);
    let mut order: Vec<u32> = Vec::with_capacity(e);
    for row in scores.chunks(e) {
        order.clear();
        order.extend(0..e as u32);
        // stable selection of the k best: full sort is fine, E <= 128
        order.sort_by(|&a, &b| {
            let (x, y) = (row[a as usize], row[b as usize]);
            match (x.is_nan(), y.is_nan()) {
                (false, false) => (y + 0.0).total_cmp(&(x + 0.0)).then(a.cmp(&b)),
                (true, true) => a.cmp(&b),
                (true, false) => std::cmp::Ordering::Greater, // NaN last
                (false, true) => std::cmp::Ordering::Less,
            }
        });
        for j in 0..k {
            idx.push(order[j]);
            w.push(row[order[j] as usize]);
        }
    }
    (idx, w)
}

/// Full gate for one rank: logits = A·Wg (row-major A: (S,H), Wg: (H,E)),
/// softmax, top-k, capacity slotting and drop accounting.
///
/// When the caller already has scores (e.g. computed by the AOT gate
/// artifact on the PJRT runtime), use [`route_from_scores`] instead.
pub fn gate_and_route(
    a: &[f32],
    wg: &[f32],
    s: usize,
    model: &ModelConfig,
    capacity: usize,
) -> Routing {
    let (h, e) = (model.h, model.e);
    debug_assert_eq!(a.len(), s * h);
    debug_assert_eq!(wg.len(), h * e);
    let mut logits = vec![0.0f32; s * e];
    // (S,H)x(H,E): E is small; simple loop ordering ikj for locality
    for i in 0..s {
        let ai = &a[i * h..(i + 1) * h];
        let li = &mut logits[i * e..(i + 1) * e];
        for (kk, &av) in ai.iter().enumerate() {
            let wrow = &wg[kk * e..(kk + 1) * e];
            for j in 0..e {
                li[j] += av * wrow[j];
            }
        }
    }
    softmax_rows(&mut logits, e);
    route_from_scores(logits, s, model, capacity)
}

/// Routing from precomputed softmax scores (S, E).
///
/// `s` is the *actual* row count of the pass — under the engine's
/// variable-shape `PassInput` path a rank may gate any `0..=s_rank`
/// rows (zero included: an expert-only rank routes nothing and the
/// result is an empty, drop-free routing). Capacity buffers are sized
/// by the caller from the static worst case, so fewer rows can only
/// mean fewer drops.
pub fn route_from_scores(
    scores: Vec<f32>,
    s: usize,
    model: &ModelConfig,
    capacity: usize,
) -> Routing {
    let (e, k) = (model.e, model.k);
    let (topk_idx, topk_w) = topk_rows(&scores, e, k);
    let mut counts = vec![0u32; e];
    let mut offered = vec![0u32; e];
    let mut routes = Vec::with_capacity(s * k);
    let mut dropped = 0usize;
    for i in 0..s {
        let denom: f32 = topk_w[i * k..(i + 1) * k].iter().sum();
        for j in 0..k {
            let expert = topk_idx[i * k + j];
            let weight = topk_w[i * k + j];
            // offered load counts the pair whether or not it is kept —
            // the capacity clamp below must not hide demand from the
            // replication tracker
            offered[expert as usize] += 1;
            let c = counts[expert as usize];
            if (c as usize) < capacity {
                counts[expert as usize] = c + 1;
                routes.push(Route {
                    token: i as u32,
                    expert,
                    slot: c,
                    weight,
                    combine_weight: weight / denom,
                });
            } else {
                dropped += 1;
            }
        }
    }
    Routing {
        scores,
        topk_idx,
        topk_w,
        routes,
        dropped,
        expert_load: counts,
        offered_load: offered,
        s,
        e,
        k,
        capacity,
    }
}

/// A contiguous tile of capacity slots destined for one expert — the unit
/// of payload-efficient dispatch. Only tiles with `rows > 0` travel.
#[derive(Clone, Debug, PartialEq)]
pub struct DispatchTile {
    /// Global expert id.
    pub expert: u32,
    /// Destination rank — the primary owner of `expert`, or a rank
    /// hosting one of its replicas.
    pub dst: u32,
    /// Destination-local expert slot on `dst`: the owned slot
    /// (`expert % e_local`) when `dst` is the primary, or a replica slot
    /// (`>= e_local`) bound to `expert` by the [`Placement`]. This is the
    /// `e` coordinate of every heap write for this tile.
    pub dslot: u32,
    /// Tile index within the (rank, expert-slot) capacity buffer
    /// (shard slot / bM).
    pub tile: u32,
    /// Valid rows in this tile (1..=bM); the rest is *in-place* padding on
    /// the receiver — it never hits the wire.
    pub rows: u32,
    /// Token ids (within the source rank) occupying rows 0..rows.
    pub tokens: Vec<u32>,
    /// Normalized combine weight g/C_i per row (the T_phi payload the
    /// combine round applies when this tile's result returns).
    pub weights: Vec<f32>,
}

/// The per-rank dispatch plan: the exact set of tiles that travel.
#[derive(Clone, Debug)]
pub struct DispatchPlan {
    pub tiles: Vec<DispatchTile>,
    /// Bytes that would travel under padded (capacity-sized) dispatch.
    pub padded_rows: usize,
    /// Valid rows actually sent.
    pub sent_rows: usize,
    /// Routed rows whose expert has **no** serving location (its primary
    /// rank failed with no surviving replica — see
    /// [`Placement::fail_rank`]). These rows are skipped, not shipped:
    /// degraded capacity is explicit, never a silent wedge.
    pub unavailable_rows: usize,
    /// Distinct location-less experts this plan skipped rows for.
    pub unavailable_experts: usize,
}

impl DispatchPlan {
    /// Payload efficiency: fraction of padded traffic avoided.
    pub fn savings(&self) -> f64 {
        if self.padded_rows == 0 {
            return 0.0;
        }
        1.0 - self.sent_rows as f64 / self.padded_rows as f64
    }
}

/// Build the dispatch plan from a routing table; `placement` maps each
/// global expert to its serving locations and `bm` is the tile height.
///
/// The tile list is **variable-length per expert**: slots are assigned
/// densely in arrival order (0..load), so expert `e`'s tiles are exactly
/// `ceil(load_e / bM)` chunks — every tile full except a possibly
/// partially-filled tail, whose row count travels in the signal flag.
/// Nothing here assumes the fixed `capacity / bM` tile count of the
/// Capacity policy, which is what makes the same plan builder serve
/// `Dropless` routing unchanged. Experts with zero routed tokens produce
/// no traffic at all (payload efficiency).
///
/// **Replica splitting.** An expert with `R > 1` serving locations has
/// its routed tokens sharded deterministically: arrival index `j` goes to
/// location `j % R` (the placement's location order — primary first,
/// replicas in install order), and each shard is re-slotted densely
/// (`j / R`) before tiling, so every destination still sees dense,
/// bM-aligned tile regions. Shards are emitted consecutively under their
/// expert — the plan stays grouped by ascending expert id — so the
/// plan-order combine fold adds each token's per-expert contributions in
/// exactly the static-placement order: replication never changes a pass
/// output bit.
pub fn dispatch_plan(routing: &Routing, bm: usize, placement: &Placement) -> DispatchPlan {
    let e = routing.e;
    let mut tiles: Vec<DispatchTile> = Vec::new();
    // group routes by expert; routes are already slot-ordered per expert
    // because slots are assigned densely in arrival order.
    let mut by_expert: Vec<Vec<&Route>> = vec![Vec::new(); e];
    for r in &routing.routes {
        by_expert[r.expert as usize].push(r);
    }
    let mut sent_rows = 0usize;
    let mut active_regions = 0usize;
    let mut unavailable_rows = 0usize;
    let mut unavailable_experts = 0usize;
    let mut shard: Vec<&Route> = Vec::new();
    for (ex, rs) in by_expert.iter().enumerate() {
        if rs.is_empty() {
            continue; // payload efficiency: inactive expert, no traffic
        }
        let locs = placement.locations(ex);
        let n = locs.len();
        if n == 0 {
            // degraded placement: the expert's primary rank failed with
            // no surviving replica. Its rows cannot be served anywhere —
            // skip them and account the loss explicitly.
            unavailable_rows += rs.len();
            unavailable_experts += 1;
            continue;
        }
        for (li, &(dst, dslot)) in locs.iter().enumerate() {
            shard.clear();
            if n == 1 {
                shard.extend(rs.iter().copied());
            } else {
                shard.extend(
                    rs.iter().enumerate().filter(|(j, _)| j % n == li).map(|(_, r)| *r),
                );
            }
            if shard.is_empty() {
                continue; // fewer routed tokens than locations
            }
            active_regions += 1;
            for (t, chunk) in shard.chunks(bm).enumerate() {
                if n == 1 {
                    debug_assert_eq!(chunk[0].slot as usize, t * bm, "slots dense per expert");
                }
                let tokens: Vec<u32> = chunk.iter().map(|r| r.token).collect();
                let weights: Vec<f32> = chunk.iter().map(|r| r.combine_weight).collect();
                sent_rows += tokens.len();
                tiles.push(DispatchTile {
                    expert: ex as u32,
                    dst,
                    dslot,
                    tile: t as u32,
                    rows: tokens.len() as u32,
                    tokens,
                    weights,
                });
            }
        }
    }
    DispatchPlan {
        tiles,
        // padded baseline: capacity-sized dispatch ships the full slot
        // region of every active (expert, location) pair
        padded_rows: active_regions * routing.capacity,
        sent_rows,
        unavailable_rows,
        unavailable_experts,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::Placement;
    use crate::util::prng::Rng;

    fn model(e: usize, k: usize, bm: usize) -> ModelConfig {
        ModelConfig {
            h: 16,
            d: 32,
            e,
            k,
            bm,
            bn: 8,
            policy: crate::config::RoutingPolicy::Capacity(1.0),
        }
    }

    #[test]
    fn softmax_rows_normalizes() {
        let mut x = vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0];
        softmax_rows(&mut x, 3);
        for row in x.chunks(3) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
            assert!(row.windows(2).all(|w| w[0] <= w[1]), "monotone logits stay ordered");
        }
    }

    #[test]
    fn topk_tie_breaks_low_index() {
        let scores = vec![0.25f32; 4];
        let (idx, w) = topk_rows(&scores, 4, 2);
        assert_eq!(idx, vec![0, 1]);
        assert_eq!(w, vec![0.25, 0.25]);
    }

    #[test]
    fn topk_orders_descending() {
        let scores = vec![0.1, 0.5, 0.2, 0.2];
        let (idx, _) = topk_rows(&scores, 4, 3);
        assert_eq!(idx, vec![1, 2, 3]);
    }

    #[test]
    fn slots_are_arrival_ordered_and_capacity_respected() {
        let m = model(2, 1, 4);
        // all tokens to expert 0 via extreme scores
        let s = 10;
        let mut scores = Vec::new();
        for _ in 0..s {
            scores.extend([0.9f32, 0.1]);
        }
        let routing = route_from_scores(scores, s, &m, 4);
        assert_eq!(routing.routes.len(), 4, "capacity 4 keeps 4");
        assert_eq!(routing.dropped, 6);
        for (i, r) in routing.routes.iter().enumerate() {
            assert_eq!(r.slot as usize, i);
            assert_eq!(r.token as usize, i, "first-come tokens keep slots");
        }
    }

    #[test]
    fn entropy_spans_uniform_to_onehot() {
        let m = model(4, 2, 64);
        // uniform gate: entropy is exactly ln(E) per token
        let uniform = route_from_scores(vec![0.25f32; 2 * 4], 2, &m, 64);
        assert!((uniform.entropy() - (4.0f64).ln()).abs() < 1e-6);
        // one-hot gate: zero entropy (0·ln 0 terms are skipped, not NaN)
        let onehot = route_from_scores(vec![1.0f32, 0.0, 0.0, 0.0], 1, &m, 64);
        assert_eq!(onehot.entropy(), 0.0);
        // skewed sits strictly between
        let skewed = route_from_scores(vec![0.7f32, 0.1, 0.1, 0.1], 1, &m, 64);
        assert!(skewed.entropy() > 0.0 && skewed.entropy() < (4.0f64).ln());
        // no tokens, no entropy
        let empty = route_from_scores(Vec::new(), 0, &m, 64);
        assert_eq!(empty.entropy(), 0.0);
    }

    #[test]
    fn combine_weights_normalize_over_full_topk() {
        let m = model(4, 2, 64);
        let scores = vec![0.4f32, 0.3, 0.2, 0.1];
        let routing = route_from_scores(scores, 1, &m, 64);
        let total: f32 = routing.routes.iter().map(|r| r.combine_weight).sum();
        assert!((total - 1.0).abs() < 1e-6);
        assert!((routing.routes[0].combine_weight - 0.4 / 0.7).abs() < 1e-6);
    }

    #[test]
    fn gate_and_route_matches_manual_softmax() {
        let m = model(4, 2, 8);
        let mut rng = Rng::new(5);
        let s = 8;
        let a = rng.normal_vec(s * m.h, 1.0);
        let wg = rng.normal_vec(m.h * m.e, 1.0);
        let r = gate_and_route(&a, &wg, s, &m, 8);
        // every row of scores sums to 1
        for row in r.scores.chunks(m.e) {
            assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        }
        assert_eq!(r.routes.len() + r.dropped, s * m.k);
    }

    #[test]
    fn dispatch_plan_is_payload_efficient() {
        let m = model(4, 1, 4);
        // tokens 0..3 -> expert 0; token 4 -> expert 2; expert 1,3 inactive
        let mut scores = Vec::new();
        for _ in 0..4 {
            scores.extend([0.7f32, 0.1, 0.1, 0.1]);
        }
        scores.extend([0.1f32, 0.1, 0.7, 0.1]);
        let routing = route_from_scores(scores, 5, &m, 8);
        let plan = dispatch_plan(&routing, 4, &Placement::balanced(4, 2, 0));
        // expert0: tile0 full (4 rows); expert2: tile0 1 row. 2 tiles total.
        assert_eq!(plan.tiles.len(), 2);
        assert_eq!(plan.sent_rows, 5);
        assert_eq!(plan.padded_rows, 16, "2 active experts x capacity 8");
        assert!(plan.savings() > 0.6);
        assert!(plan.tiles.iter().all(|t| t.rows > 0));
        // inactive experts generate zero traffic
        assert!(plan.tiles.iter().all(|t| t.expert != 1 && t.expert != 3));
    }

    #[test]
    fn dropless_plan_builds_variable_tile_lists() {
        let mut m = model(2, 1, 4);
        m.policy = crate::config::RoutingPolicy::Dropless;
        // 10 tokens, all to expert 0: dropless keeps every pair
        let s = 10;
        let mut scores = Vec::new();
        for _ in 0..s {
            scores.extend([0.9f32, 0.1]);
        }
        let cap = m.slot_capacity(s); // roundup(10, 4) = 12
        assert_eq!(cap, 12);
        let routing = route_from_scores(scores, s, &m, cap);
        assert_eq!(routing.dropped, 0, "dropless keeps all pairs");
        assert_eq!(routing.routes.len(), s);
        let plan = dispatch_plan(&routing, m.bm, &Placement::balanced(2, 1, 0));
        // variable tile list: two full tiles + one partially-filled tail
        assert_eq!(plan.tiles.len(), 3);
        assert_eq!(
            plan.tiles.iter().map(|t| t.rows).collect::<Vec<_>>(),
            vec![4, 4, 2],
            "last tile partially filled"
        );
        assert_eq!(plan.tiles.iter().map(|t| t.tile).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(plan.sent_rows, s, "only valid rows travel");
        assert_eq!(plan.padded_rows, cap, "one active expert x slot region");
    }

    #[test]
    fn zero_and_partial_row_passes_route_cleanly() {
        // the variable-shape engine path gates whatever rows exist; zero
        // rows is an empty, drop-free routing with an empty plan
        let m = model(4, 2, 4);
        let r0 = route_from_scores(Vec::new(), 0, &m, 8);
        assert_eq!(r0.routes.len(), 0);
        assert_eq!(r0.dropped, 0);
        assert!(r0.expert_load.iter().all(|&l| l == 0));
        assert!(r0.offered_load.iter().all(|&l| l == 0));
        let p0 = dispatch_plan(&r0, m.bm, &Placement::balanced(4, 2, 0));
        assert!(p0.tiles.is_empty());
        assert_eq!(p0.sent_rows, 0);
        // partial rows: the plan covers exactly the routed pairs of the
        // rows that exist — nothing padded up to any static batch shape
        let mut rng = Rng::new(77);
        let rows = 5; // deliberately not a bM multiple
        let scores = {
            let mut s = rng.normal_vec(rows * m.e, 1.0);
            crate::gate::softmax_rows(&mut s, m.e);
            s
        };
        let r = route_from_scores(scores, rows, &m, 64);
        assert_eq!(r.routes.len() + r.dropped, rows * m.k);
        let p = dispatch_plan(&r, m.bm, &Placement::balanced(4, 2, 0));
        let covered: usize = p.tiles.iter().map(|t| t.tokens.len()).sum();
        assert_eq!(covered, r.routes.len());
        assert_eq!(p.sent_rows, r.routes.len(), "only existing rows travel");
    }

    #[test]
    fn dispatch_tiles_cover_all_kept_routes_once() {
        let m = model(8, 2, 4);
        let mut rng = Rng::new(9);
        let s = 64;
        let a = rng.normal_vec(s * m.h, 1.0);
        let wg = rng.normal_vec(m.h * m.e, 1.0);
        let routing = gate_and_route(&a, &wg, s, &m, 8);
        let plan = dispatch_plan(&routing, 4, &Placement::balanced(8, 2, 0));
        let covered: usize = plan.tiles.iter().map(|t| t.tokens.len()).sum();
        assert_eq!(covered, routing.routes.len());
    }

    #[test]
    fn degraded_placement_accounts_unavailable_rows() {
        let m = model(4, 1, 4);
        // tokens 0..3 -> expert 2 (owner rank 1), token 4 -> expert 0
        let mut scores = Vec::new();
        for _ in 0..4 {
            scores.extend([0.1f32, 0.1, 0.7, 0.1]);
        }
        scores.extend([0.7f32, 0.1, 0.1, 0.1]);
        let routing = route_from_scores(scores, 5, &m, 8);
        let mut p = Placement::balanced(4, 2, 0);
        p.fail_rank(1); // experts 2, 3 lose their only location
        let plan = dispatch_plan(&routing, 4, &p);
        assert_eq!(plan.unavailable_rows, 4, "expert 2's rows skipped");
        assert_eq!(plan.unavailable_experts, 1, "only active orphans count");
        assert_eq!(plan.sent_rows, 1, "expert 0's row still travels");
        assert!(plan.tiles.iter().all(|t| t.dst != 1), "no tile targets the corpse");
        // a replica revives the expert: every row travels again
        let mut p2 = Placement::balanced(4, 2, 1);
        p2.add_replica(2, 0).unwrap();
        p2.fail_rank(1);
        let plan2 = dispatch_plan(&routing, 4, &p2);
        assert_eq!(plan2.unavailable_rows, 0);
        assert_eq!(plan2.sent_rows, 5);
    }

    #[test]
    fn topk_handles_nan_scores_without_panicking() {
        // one NaN among finite scores: finite scores rank, NaN sorts last
        let scores = vec![f32::NAN, 0.5, 0.1, 0.2];
        let (idx, w) = topk_rows(&scores, 4, 2);
        assert_eq!(idx, vec![1, 3]);
        assert_eq!(w, vec![0.5, 0.2]);
        // NaN beyond +inf in total order must still sort last
        let scores = vec![f32::NAN, f32::INFINITY, f32::NEG_INFINITY];
        let (idx, _) = topk_rows(&scores, 3, 3);
        assert_eq!(idx, vec![1, 2, 0], "NaN after every non-NaN, +inf first");
        // all-NaN row: k indices still come back (low indices first)
        let scores = vec![f32::NAN; 4];
        let (idx, w) = topk_rows(&scores, 4, 2);
        assert_eq!(idx, vec![0, 1]);
        assert!(w.iter().all(|v| v.is_nan()));
        // signed zeros tie toward the lower index like any equal scores
        let scores = vec![-0.0f32, 0.0, -1.0];
        let (idx, _) = topk_rows(&scores, 3, 2);
        assert_eq!(idx, vec![0, 1], "-0.0 == +0.0 ties break low");
    }

    #[test]
    fn softmax_degenerate_rows_fall_back_to_uniform() {
        let e = 4;
        // row 0: all -inf (sum would be 0); row 1: NaN logit; row 2: +inf
        // logit (NaN after max subtraction); row 3: healthy
        let mut x = vec![
            f32::NEG_INFINITY, f32::NEG_INFINITY, f32::NEG_INFINITY, f32::NEG_INFINITY,
            f32::NAN, 1.0, 2.0, 3.0,
            f32::INFINITY, 0.0, 0.0, 0.0,
            1.0, 2.0, 3.0, 4.0,
        ];
        softmax_rows(&mut x, e);
        for (i, row) in x.chunks(e).enumerate() {
            assert!(row.iter().all(|v| v.is_finite()), "row {i} finite: {row:?}");
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-6, "row {i} sums to 1");
        }
        for row in x.chunks(e).take(3) {
            assert!(row.iter().all(|&v| v == 0.25), "degenerate rows uniform");
        }
        assert!(x[12..].windows(2).all(|w| w[0] < w[1]), "healthy row untouched");
    }

    #[test]
    fn offered_load_counts_drops_kept_load_saturates() {
        let m = model(2, 1, 4);
        // 10 tokens all offered to expert 0, capacity 4
        let mut scores = Vec::new();
        for _ in 0..10 {
            scores.extend([0.9f32, 0.1]);
        }
        let r = route_from_scores(scores, 10, &m, 4);
        assert_eq!(r.expert_load, vec![4, 0], "kept load clamps at capacity");
        assert_eq!(r.offered_load, vec![10, 0], "offered load sees demand");
        assert_eq!(r.offered_load.iter().sum::<u32>() as usize, 10 * m.k);
        assert_eq!(
            r.offered_load.iter().sum::<u32>(),
            r.expert_load.iter().sum::<u32>() + r.dropped as u32
        );
    }

    #[test]
    fn replicated_plan_splits_deterministically_and_stays_expert_grouped() {
        let mut m = model(4, 1, 4);
        m.policy = crate::config::RoutingPolicy::Dropless;
        // 10 tokens to expert 0, 3 to expert 2
        let mut scores = Vec::new();
        for _ in 0..10 {
            scores.extend([0.7f32, 0.1, 0.1, 0.1]);
        }
        for _ in 0..3 {
            scores.extend([0.1f32, 0.1, 0.7, 0.1]);
        }
        let cap = m.slot_capacity(13);
        let routing = route_from_scores(scores, 13, &m, cap);
        // 2 ranks, e_local 2, one replica slot per rank; replicate expert
        // 0 (owned by rank 0) onto rank 1
        let mut p = Placement::balanced(4, 2, 1);
        let slot = p.add_replica(0, 1).unwrap();
        assert_eq!(slot, 2, "first replica slot sits just past e_local");
        let plan = dispatch_plan(&routing, m.bm, &p);
        // expert 0 splits 5/5 across (rank0, slot0) and (rank1, slot2):
        // arrival j -> location j % 2, re-slotted densely -> 2 tiles of
        // (4,1) rows each; expert 2 stays whole on its owner
        let e0: Vec<_> = plan.tiles.iter().filter(|t| t.expert == 0).collect();
        assert_eq!(e0.len(), 4);
        assert_eq!(
            e0.iter().map(|t| (t.dst, t.dslot, t.tile, t.rows)).collect::<Vec<_>>(),
            vec![(0, 0, 0, 4), (0, 0, 1, 1), (1, 2, 0, 4), (1, 2, 1, 1)]
        );
        // primary shard takes even arrivals, replica shard odd arrivals
        assert_eq!(e0[0].tokens, vec![0, 2, 4, 6]);
        assert_eq!(e0[2].tokens, vec![1, 3, 5, 7]);
        // plan stays grouped by ascending expert id (combine-fold order)
        let experts: Vec<u32> = plan.tiles.iter().map(|t| t.expert).collect();
        let mut sorted = experts.clone();
        sorted.sort_unstable();
        assert_eq!(experts, sorted, "tiles grouped by ascending expert");
        // every kept route travels exactly once
        let covered: usize = plan.tiles.iter().map(|t| t.tokens.len()).sum();
        assert_eq!(covered, routing.routes.len());
        assert_eq!(plan.sent_rows, 13);
        // deterministic: same routing + placement -> identical plan
        let plan2 = dispatch_plan(&routing, m.bm, &p);
        assert_eq!(plan.tiles, plan2.tiles);
    }
}
