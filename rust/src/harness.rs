//! Experiment harness: one runner per paper table/figure.
//!
//! Each runner builds the paper's workload, drives the simulator (and,
//! where applicable, the real coordinator), and returns both a printable
//! markdown table and structured rows so `rust/tests/engines.rs` and the
//! benches can assert the paper's *shape* (who wins, by roughly what
//! factor, where the crossovers are). EXPERIMENTS.md records the
//! paper-vs-measured comparison produced by `cargo bench`.

use std::sync::Arc;

use anyhow::Result;

use crate::config::{Config, RoutingPolicy, WirePrecision};
use crate::coordinator::{BatchPolicy, MoeEngine, MoeService, RequestOpts, TaskGraphMode};
use crate::expert::{generate_tokens, ModelParams};
use crate::gemm;
use crate::layout;
use crate::runtime::{ComputeBackend, NativeBackend};
use crate::sim::engines::{simulate, Baseline, Engine};
use crate::sim::straggler;
use crate::util::check::dense_reference_moe;
use crate::util::json::{self, Json};
use crate::util::prng::Rng;
use crate::util::stats::{fmt_bytes, fmt_time, max_abs_diff, percentile, summarize, Table};
use crate::workload::{cluster_workload, skewed_tokens, ArrivalProcess, Skew};

/// Engines compared in the latency/throughput figures.
pub fn figure_engines() -> Vec<Engine> {
    vec![
        Engine::Flash,
        Engine::Baseline(Baseline::FasterMoe),
        Engine::Baseline(Baseline::MegatronCutlass),
        Engine::Baseline(Baseline::MegatronTe),
        Engine::Baseline(Baseline::Comet),
    ]
}

/// Paper-testbed config with overrides.
pub fn paper_config(ranks: usize, s_rank: usize, experts: usize) -> Result<Config> {
    let mut cfg = Config::preset("paper_h100x8")?;
    cfg.set("ranks", &ranks.to_string())?;
    cfg.set("tokens", &s_rank.to_string())?;
    cfg.set("experts", &experts.to_string())?;
    cfg.validate()?;
    Ok(cfg)
}

/// One (engine, x) measurement.
#[derive(Clone, Debug)]
pub struct Point {
    pub engine: &'static str,
    pub x: f64,
    pub latency: f64,
    pub utilization: f64,
    pub bytes: f64,
    pub launches: usize,
    pub overflow: bool,
}

fn sweep(
    engines: &[Engine],
    xs: &[usize],
    mut cfg_of: impl FnMut(usize) -> Result<Config>,
    seed: u64,
) -> Result<Vec<Point>> {
    let mut out = Vec::new();
    for &x in xs {
        let cfg = cfg_of(x)?;
        let wl = cluster_workload(&cfg, Skew::Zipf, seed ^ x as u64);
        for &engine in engines {
            let r = simulate(&cfg, &wl, engine, seed)?;
            out.push(Point {
                engine: r.engine,
                x: x as f64,
                latency: r.latency,
                utilization: r.utilization,
                bytes: r.bytes_on_wire,
                launches: r.launches_per_rank,
                overflow: r.incast_overflow,
            });
        }
    }
    Ok(out)
}

/// Order-preserving unique (Vec::dedup only collapses consecutive runs).
fn unique<T: PartialEq + Copy>(items: impl Iterator<Item = T>) -> Vec<T> {
    let mut out: Vec<T> = Vec::new();
    for it in items {
        if !out.contains(&it) {
            out.push(it);
        }
    }
    out
}

fn render_latency_table(title: &str, xlabel: &str, points: &[Point]) -> String {
    let xs: Vec<f64> = unique(points.iter().map(|p| p.x));
    let engines: Vec<&str> = unique(points.iter().map(|p| p.engine));
    let mut headers = vec![xlabel];
    headers.extend(engines.iter().copied());
    let mut t = Table::new(&headers);
    for &x in &xs {
        let mut row = vec![format!("{x}")];
        for &e in &engines {
            let p = points.iter().find(|p| p.x == x && p.engine == e).unwrap();
            row.push(if p.overflow {
                format!("{} (incast!)", fmt_time(p.latency))
            } else {
                fmt_time(p.latency)
            });
        }
        t.row(&row);
    }
    format!("## {title}\n\n{}", t.render())
}

// ---------------------------------------------------------------------------
// Table 1: kernel launch counts
// ---------------------------------------------------------------------------

pub fn table1() -> (String, Vec<(&'static str, usize)>) {
    // Paper setting: 2 ranks, 32 local experts each.
    let rows: Vec<(&'static str, usize)> = std::iter::once(("FlashDMoE", 1))
        .chain(
            [
                Baseline::Comet,
                Baseline::MegatronCutlass,
                Baseline::MegatronTe,
                Baseline::DeepEp,
                Baseline::DeepSpeed,
            ]
            .into_iter()
            .map(|b| (b.name(), b.launch_model().count(64, 2))),
        )
        .collect();
    let mut t = Table::new(&["Works", "Launched GPU Ops (paper)", "Launched GPU Ops (ours)"]);
    let paper = [1usize, 33, 85, 261, 432, 550];
    for ((name, ours), paper) in rows.iter().zip(paper) {
        t.row(&[name.to_string(), paper.to_string(), ours.to_string()]);
    }
    (format!("## Table 1 — kernel launches per layer pass\n\n{}", t.render()), rows)
}

// ---------------------------------------------------------------------------
// Table 1b: persistent engine vs per-pass respawn (real execution)
// ---------------------------------------------------------------------------

/// One steady-state comparison point between the persistent `MoeEngine`
/// and the per-call actor-respawn shape the operator had before it
/// (launch the actor group, run one pass, tear it down — the software
/// analog of a per-pass kernel launch).
#[derive(Clone, Debug)]
pub struct PersistencePoint {
    pub passes: usize,
    /// Steady-state per-pass wall p50 on the resident engine (post-warmup).
    pub persistent_p50: f64,
    /// Per-pass wall p50 when the engine is started and torn down around
    /// every pass.
    pub respawn_p50: f64,
    /// Launch-equivalent counts over the run: 1 vs one per pass.
    pub persistent_launches: u64,
    pub respawn_launches: u64,
    /// Threads spawned over the run: constant vs linear in passes.
    pub persistent_threads: u64,
    pub respawn_threads: u64,
}

impl PersistencePoint {
    /// Amortized per-pass overhead the respawn shape pays for bring-up
    /// (thread spawn + heap alloc + weight slicing), by difference.
    pub fn amortized_launch_overhead(&self) -> f64 {
        self.respawn_p50 - self.persistent_p50
    }
}

/// Measure steady-state pass latency of a resident [`MoeEngine`] against
/// per-pass engine respawn on the real (native-backend) execution path.
pub fn persistent_vs_respawn(
    preset: &str,
    passes: usize,
    seed: u64,
) -> Result<(String, PersistencePoint)> {
    let cfg = Config::preset(preset)?;
    let params = Arc::new(ModelParams::generate(&cfg, seed));
    let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(&cfg));
    let inputs: Vec<Vec<f32>> =
        (0..cfg.system.ranks).map(|r| generate_tokens(&cfg, seed, r)).collect();

    // persistent arm: launch once, measure steady-state passes
    let engine =
        MoeEngine::start(cfg.clone(), params.clone(), backend.clone(), TaskGraphMode::Fused)?;
    engine.submit(&inputs)?.wait()?; // warmup
    let mut persist = Vec::with_capacity(passes);
    for _ in 0..passes {
        let t0 = std::time::Instant::now();
        engine.submit(&inputs)?.wait()?;
        persist.push(t0.elapsed().as_secs_f64());
    }
    let em = engine.metrics();
    let (persistent_launches, persistent_threads) = (em.launches, em.threads_spawned);
    engine.shutdown();

    // respawn arm: bring the actor group up and tear it down every pass
    let mut respawn = Vec::with_capacity(passes);
    let mut respawn_launches = 0u64;
    let mut respawn_threads = 0u64;
    for _ in 0..passes {
        let t0 = std::time::Instant::now();
        let one =
            MoeEngine::start(cfg.clone(), params.clone(), backend.clone(), TaskGraphMode::Fused)?;
        one.submit(&inputs)?.wait()?;
        let m = one.metrics();
        respawn_launches += m.launches;
        respawn_threads += m.threads_spawned;
        one.shutdown();
        respawn.push(t0.elapsed().as_secs_f64());
    }

    let point = PersistencePoint {
        passes,
        persistent_p50: summarize(&persist).p50,
        respawn_p50: summarize(&respawn).p50,
        persistent_launches,
        respawn_launches,
        persistent_threads,
        respawn_threads,
    };
    let mut t = Table::new(&["operator shape", "p50 / pass", "launches", "threads spawned", "spawns / pass"]);
    t.row(&[
        "persistent MoeEngine".into(),
        fmt_time(point.persistent_p50),
        point.persistent_launches.to_string(),
        point.persistent_threads.to_string(),
        "0".into(),
    ]);
    t.row(&[
        "respawn per pass".into(),
        fmt_time(point.respawn_p50),
        point.respawn_launches.to_string(),
        point.respawn_threads.to_string(),
        format!("{:.0}", point.respawn_threads as f64 / passes as f64),
    ]);
    let text = format!(
        "## Table 1b — persistent engine vs per-pass respawn ({preset}, {passes} steady-state passes)\n\n{}\namortized launch overhead paid by the respawn shape: {} per pass\n",
        t.render(),
        fmt_time(point.amortized_launch_overhead().max(0.0)),
    );
    Ok((text, point))
}

// ---------------------------------------------------------------------------
// Routing policy A/B: dropless vs fixed capacity (real execution)
// ---------------------------------------------------------------------------

/// One routing-policy arm measured on the real engine.
#[derive(Clone, Debug)]
pub struct PolicyPoint {
    pub policy: &'static str,
    /// Over-capacity (token, expert) pairs dropped in the measured pass
    /// (must be 0 for the dropless arm).
    pub dropped: usize,
    /// Fraction of padded dispatch traffic avoided.
    pub payload_savings: f64,
    /// Dispatch tiles shipped across all ranks.
    pub tiles_sent: usize,
    pub wall_secs: f64,
    /// Symmetric-heap bytes per rank (the memory cost of the policy).
    pub heap_bytes: f64,
}

/// A/B the routing policies on the real (native-backend) engine: same
/// preset, same seed, same inputs — only the dispatch contract changes.
/// `Capacity` arms may drop over-capacity pairs (computing a different
/// function under skew); the `Dropless` arm must report zero drops while
/// shipping only the rows that actually routed.
pub fn routing_policy_ab(preset: &str, seed: u64) -> Result<(String, Vec<PolicyPoint>)> {
    let arms: [(&'static str, RoutingPolicy); 3] = [
        ("capacity f=1.0", RoutingPolicy::Capacity(1.0)),
        ("capacity f=2.0", RoutingPolicy::Capacity(2.0)),
        ("dropless", RoutingPolicy::Dropless),
    ];
    let mut points = Vec::new();
    let mut t = Table::new(&["policy", "dropped", "payload saved", "tiles", "wall", "heap/rank"]);
    for (name, policy) in arms {
        let mut cfg = Config::preset(preset)?;
        cfg.model.policy = policy;
        cfg.validate()?;
        let params = Arc::new(ModelParams::generate(&cfg, seed));
        let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(&cfg));
        let inputs: Vec<Vec<f32>> =
            (0..cfg.system.ranks).map(|r| generate_tokens(&cfg, seed, r)).collect();
        let engine =
            MoeEngine::start(cfg.clone(), params, backend, TaskGraphMode::Fused)?;
        engine.submit(&inputs)?.wait()?; // warmup
        let res = engine.submit(&inputs)?.wait()?;
        let m = &res.metrics;
        let p = PolicyPoint {
            policy: name,
            dropped: m.total_dropped(),
            payload_savings: m.payload_savings(),
            tiles_sent: m.ranks.iter().map(|r| r.tiles_sent).sum(),
            wall_secs: m.wall_secs,
            heap_bytes: engine.heap_bytes_per_rank(),
        };
        t.row(&[
            p.policy.to_string(),
            p.dropped.to_string(),
            format!("{:.1}%", p.payload_savings * 100.0),
            p.tiles_sent.to_string(),
            fmt_time(p.wall_secs),
            fmt_bytes(p.heap_bytes),
        ]);
        points.push(p);
        engine.shutdown();
    }
    Ok((
        format!("## Routing policy A/B — dropless vs fixed capacity ({preset})\n\n{}", t.render()),
        points,
    ))
}

// ---------------------------------------------------------------------------
// PR-3 hot path: packed vs unpacked GEMM, work-stealing contention stats
// ---------------------------------------------------------------------------

/// One (m, k, n) point of the packed-vs-unpacked GEMM sweep.
#[derive(Clone, Debug)]
pub struct GemmAbPoint {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub unpacked_gflops: f64,
    pub packed_gflops: f64,
    /// One-time packing cost (amortized to zero over an engine lifetime).
    pub pack_secs: f64,
}

impl GemmAbPoint {
    pub fn speedup(&self) -> f64 {
        if self.unpacked_gflops == 0.0 {
            return 0.0;
        }
        self.packed_gflops / self.unpacked_gflops
    }
}

/// Kernel-level A/B: the unpacked row-major GEMM vs the packed
/// persistent-weight GEMM on identical inputs, per shape. Weights are
/// packed once outside the timed loop — exactly the engine's contract
/// (pack at `MoeEngine::start`, never per pass) — and the one-time cost
/// is reported alongside. Numeric agreement is asserted, not assumed.
pub fn gemm_backend_ab(
    shapes: &[(usize, usize, usize)],
    iters: usize,
) -> (String, Vec<GemmAbPoint>) {
    let iters = iters.max(1);
    let mut points = Vec::new();
    let mut t =
        Table::new(&["shape (m,k,n)", "unpacked GFLOP/s", "packed GFLOP/s", "speedup", "pack cost"]);
    for &(m, k, n) in shapes {
        let mut rng = Rng::new(0x9EA5 ^ (m * 31 + k * 7 + n) as u64);
        let a = rng.normal_vec(m * k, 1.0);
        let b = rng.normal_vec(k * n, 0.1);
        let bias = rng.normal_vec(n, 0.1);
        let flops = 2.0 * m as f64 * k as f64 * n as f64;

        let mut c0 = vec![0.0f32; m * n];
        gemm::gemm_bias(&a, &b, Some(&bias), &mut c0, m, k, n, gemm::Epilogue::Relu); // warmup
        let t0 = std::time::Instant::now();
        for _ in 0..iters {
            gemm::gemm_bias(&a, &b, Some(&bias), &mut c0, m, k, n, gemm::Epilogue::Relu);
        }
        let unpacked_secs = t0.elapsed().as_secs_f64() / iters as f64;

        let tp = std::time::Instant::now();
        let bp = gemm::PackedWeights::pack(&b, k, n);
        let pack_secs = tp.elapsed().as_secs_f64();
        let mut c1 = vec![0.0f32; m * n];
        gemm::gemm_bias_packed(&a, &bp, Some(&bias), &mut c1, m, gemm::Epilogue::Relu); // warmup
        let t1 = std::time::Instant::now();
        for _ in 0..iters {
            gemm::gemm_bias_packed(&a, &bp, Some(&bias), &mut c1, m, gemm::Epilogue::Relu);
        }
        let packed_secs = t1.elapsed().as_secs_f64() / iters as f64;

        let diff = max_abs_diff(&c0, &c1);
        assert!(diff < 1e-3, "packed diverged from unpacked at ({m},{k},{n}): {diff}");

        let p = GemmAbPoint {
            m,
            k,
            n,
            unpacked_gflops: flops / unpacked_secs / 1e9,
            packed_gflops: flops / packed_secs / 1e9,
            pack_secs,
        };
        t.row(&[
            format!("{m}x{k}x{n}"),
            format!("{:.2}", p.unpacked_gflops),
            format!("{:.2}", p.packed_gflops),
            format!("{:.2}x", p.speedup()),
            fmt_time(p.pack_secs),
        ]);
        points.push(p);
    }
    (
        format!("## GEMM backend A/B — packed persistent-weight vs unpacked\n\n{}", t.render()),
        points,
    )
}

/// One arm of the engine-level hot-path A/B.
#[derive(Clone, Debug)]
pub struct HotPathPoint {
    pub packed: bool,
    /// Steady-state per-pass wall p50.
    pub wall_p50: f64,
    /// Mean processor utilization of the last measured pass.
    pub utilization: f64,
    /// Work-stealing contention stats of the last measured pass,
    /// aggregated over ranks.
    pub steals: u32,
    pub max_queue_depth: usize,
    /// Experts packed over the whole run (0 for the unpacked arm; must
    /// equal the expert count — never grow with passes — for the packed
    /// arm).
    pub pack_count: u64,
    /// Effective FFN GFLOP/s over the measured passes (valid rows only).
    pub gflops: f64,
}

/// Engine-level A/B of the compute hot path: same preset, same seed, same
/// inputs — only `packed` flips. Reports steady-state latency, processor
/// utilization and the work-stealing pool's contention stats, and audits
/// the pack-once contract (pack count flat across passes). Both arms'
/// outputs are asserted numerically equal.
pub fn hotpath_ab(preset: &str, passes: usize, seed: u64) -> Result<(String, Vec<HotPathPoint>)> {
    let passes = passes.max(1);
    let mut points = Vec::new();
    let mut reference: Option<Vec<Vec<f32>>> = None;
    let mut t = Table::new(&[
        "backend",
        "p50 / pass",
        "GFLOP/s",
        "util",
        "steals",
        "max depth",
        "packs",
    ]);
    for packed in [false, true] {
        let mut cfg = Config::preset(preset)?;
        cfg.set("packed", if packed { "true" } else { "false" })?;
        let params = Arc::new(ModelParams::generate(&cfg, seed));
        let native = Arc::new(NativeBackend::from_config(&cfg));
        let backend: Arc<dyn ComputeBackend> = native.clone();
        let inputs: Vec<Vec<f32>> =
            (0..cfg.system.ranks).map(|r| generate_tokens(&cfg, seed, r)).collect();
        let engine =
            MoeEngine::start(cfg.clone(), params, backend, TaskGraphMode::Fused)?;
        let packs_after_start = native.pack_count();
        engine.submit(&inputs)?.wait()?; // warmup
        let mut walls = Vec::with_capacity(passes);
        let mut last = None;
        let mut flops_done = 0.0f64;
        for _ in 0..passes {
            let t0 = std::time::Instant::now();
            let res = engine.submit(&inputs)?.wait()?;
            walls.push(t0.elapsed().as_secs_f64());
            flops_done += res
                .metrics
                .ranks
                .iter()
                .map(|r| cfg.model.ffn_flops(r.sent_rows))
                .sum::<f64>();
            last = Some(res);
        }
        let last = last.expect("at least one pass");
        anyhow::ensure!(
            native.pack_count() == packs_after_start,
            "{preset}: steady-state passes re-packed weights ({} -> {})",
            packs_after_start,
            native.pack_count()
        );
        match &reference {
            None => reference = Some(last.outputs.clone()),
            Some(want) => {
                for (r, (g, w)) in last.outputs.iter().zip(want).enumerate() {
                    let diff = max_abs_diff(g, w);
                    anyhow::ensure!(
                        diff < 1e-3,
                        "rank {r}: packed arm diverged from unpacked arm by {diff}"
                    );
                }
            }
        }
        let wall_sum: f64 = walls.iter().sum();
        let p = HotPathPoint {
            packed,
            wall_p50: summarize(&walls).p50,
            utilization: last.metrics.utilization(),
            steals: last.metrics.ranks.iter().map(|r| r.steals).sum(),
            max_queue_depth: last.metrics.ranks.iter().map(|r| r.max_queue_depth).max().unwrap_or(0),
            pack_count: native.pack_count(),
            gflops: if wall_sum > 0.0 { flops_done / wall_sum / 1e9 } else { 0.0 },
        };
        t.row(&[
            if packed { "native-packed".into() } else { "native".to_string() },
            fmt_time(p.wall_p50),
            format!("{:.2}", p.gflops),
            format!("{:.1}%", p.utilization * 100.0),
            p.steals.to_string(),
            p.max_queue_depth.to_string(),
            p.pack_count.to_string(),
        ]);
        points.push(p);
        engine.shutdown();
    }
    Ok((
        format!(
            "## Hot-path A/B — packed backend + work-stealing pool ({preset}, {passes} passes)\n\n{}",
            t.render()
        ),
        points,
    ))
}

/// Read-modify-write one top-level section of a JSON report file (the
/// benches each own a section of `BENCH_pr3_hotpath.json`; a corrupt or
/// missing file is replaced rather than failing the bench).
pub fn update_bench_json(path: &str, section: &str, value: Json) -> Result<()> {
    let mut root = std::fs::read_to_string(path)
        .ok()
        .and_then(|text| Json::parse(&text).ok())
        .unwrap_or_else(|| Json::Obj(Default::default()));
    if !matches!(root, Json::Obj(_)) {
        root = Json::Obj(Default::default());
    }
    if let Json::Obj(map) = &mut root {
        map.insert(section.to_string(), value);
    }
    std::fs::write(path, json::to_string(&root))?;
    Ok(())
}

/// JSON rows for [`gemm_backend_ab`] points.
pub fn gemm_ab_json(points: &[GemmAbPoint]) -> Json {
    Json::Arr(
        points
            .iter()
            .map(|p| {
                json::obj(vec![
                    ("m", json::num(p.m as f64)),
                    ("k", json::num(p.k as f64)),
                    ("n", json::num(p.n as f64)),
                    ("unpacked_gflops", json::num(p.unpacked_gflops)),
                    ("packed_gflops", json::num(p.packed_gflops)),
                    ("speedup", json::num(p.speedup())),
                    ("pack_secs", json::num(p.pack_secs)),
                ])
            })
            .collect(),
    )
}

/// JSON rows for [`hotpath_ab`] points.
pub fn hotpath_json(points: &[HotPathPoint]) -> Json {
    Json::Arr(
        points
            .iter()
            .map(|p| {
                json::obj(vec![
                    ("backend", json::s(if p.packed { "native-packed" } else { "native" })),
                    ("wall_p50", json::num(p.wall_p50)),
                    ("gflops", json::num(p.gflops)),
                    ("utilization", json::num(p.utilization)),
                    ("steals", json::num(p.steals as f64)),
                    ("max_queue_depth", json::num(p.max_queue_depth as f64)),
                    ("pack_count", json::num(p.pack_count as f64)),
                ])
            })
            .collect(),
    )
}

// ---------------------------------------------------------------------------
// PR-4 serving: request-level latency through the MoeService batcher
// ---------------------------------------------------------------------------

/// One serving-mode measurement on the real `MoeService` (request-level
/// front end over the persistent engine).
#[derive(Clone, Debug)]
pub struct ServingPoint {
    pub requests: usize,
    /// Open-loop arrival rate driven (requests/second).
    pub rate: f64,
    /// Request latency percentiles (enqueue → completion), seconds.
    pub latency_p50: f64,
    pub latency_p99: f64,
    /// Median queue time (enqueue → first admission), seconds.
    pub queue_p50: f64,
    /// Mean per-pass row fill achieved by the batcher.
    pub batch_fill: f64,
    /// Peak bounded-queue depth (requests).
    pub max_queue_depth: usize,
    /// Engine passes the batcher submitted.
    pub passes: u64,
    /// Tokens served per wall second.
    pub throughput: f64,
    /// Engine launch count over the service lifetime (must be 1).
    pub launches: u64,
}

/// Drive the serving front end with open-loop Poisson traffic: `rate`
/// requests/second of `8..=s_rank/2`-row requests, served by a
/// `MoeService` under dropless routing (request outputs independent of
/// co-batching), and report request-level latency, fill and queue
/// pressure. The single engine launch over the run is asserted, not
/// assumed.
pub fn serving_bench(
    preset: &str,
    requests: usize,
    rate: f64,
    seed: u64,
) -> Result<(String, ServingPoint)> {
    let mut cfg = Config::preset(preset)?;
    cfg.set("routing_policy", "dropless")?;
    cfg.validate()?;
    let params = Arc::new(ModelParams::generate(&cfg, seed));
    let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(&cfg));
    let policy = BatchPolicy::from_config(&cfg);
    let service =
        MoeService::start(cfg.clone(), params, backend, TaskGraphMode::Fused, policy)?;

    let h = cfg.model.h;
    let mut rng = Rng::new(seed ^ 0x5E47);
    let arrivals = ArrivalProcess::Poisson { rate }.arrivals(
        requests,
        (8, (cfg.system.s_rank / 2).max(8)),
        &mut rng,
    )?;

    let t0 = std::time::Instant::now();
    let mut handles = Vec::with_capacity(requests);
    for a in &arrivals {
        // open loop: hold to the arrival clock, never to completions
        let due = std::time::Duration::from_secs_f64(a.at);
        if let Some(wait) = due.checked_sub(t0.elapsed()) {
            std::thread::sleep(wait);
        }
        let tokens = rng.normal_vec(a.tokens * h, 1.0);
        handles.push(
            service
                .enqueue(tokens, RequestOpts::default())
                .map_err(|e| anyhow::anyhow!("enqueue failed: {e}"))?,
        );
    }
    let mut latencies = Vec::with_capacity(requests);
    let mut queue_times = Vec::with_capacity(requests);
    let mut tokens_served = 0usize;
    for hdl in handles {
        let res = hdl.wait()?;
        tokens_served += res.rows;
        latencies.push(res.latency_secs);
        queue_times.push(res.queue_secs);
    }
    let wall = t0.elapsed().as_secs_f64();
    let report = service.shutdown();
    anyhow::ensure!(
        report.engine.launches == 1,
        "service lifetime must cost exactly one launch, saw {}",
        report.engine.launches
    );

    let lat = summarize(&latencies);
    let qt = summarize(&queue_times);
    let point = ServingPoint {
        requests,
        rate,
        latency_p50: lat.p50,
        latency_p99: lat.p99,
        queue_p50: qt.p50,
        batch_fill: report.service.mean_batch_fill(),
        max_queue_depth: report.service.max_queue_depth,
        passes: report.service.passes,
        throughput: if wall > 0.0 { tokens_served as f64 / wall } else { 0.0 },
        launches: report.engine.launches,
    };
    let mut t = Table::new(&["metric", "value"]);
    t.row(&["requests".into(), point.requests.to_string()]);
    t.row(&["arrival rate".into(), format!("{:.0} req/s (Poisson)", point.rate)]);
    t.row(&["latency p50".into(), fmt_time(point.latency_p50)]);
    t.row(&["latency p99".into(), fmt_time(point.latency_p99)]);
    t.row(&["queue-time p50".into(), fmt_time(point.queue_p50)]);
    t.row(&["batch fill".into(), format!("{:.1}%", point.batch_fill * 100.0)]);
    t.row(&["peak queue depth".into(), point.max_queue_depth.to_string()]);
    t.row(&["engine passes".into(), format!("{} ({} launch)", point.passes, point.launches)]);
    t.row(&["throughput".into(), format!("{:.0} tokens/s", point.throughput)]);
    Ok((
        format!(
            "## Serving — request-level latency through MoeService ({preset}, {requests} requests)\n\n{}",
            t.render()
        ),
        point,
    ))
}

/// JSON row for a [`serving_bench`] point (`BENCH_pr4_serving.json`).
pub fn serving_json(p: &ServingPoint) -> Json {
    json::obj(vec![
        ("requests", json::num(p.requests as f64)),
        ("rate_rps", json::num(p.rate)),
        ("latency_p50", json::num(p.latency_p50)),
        ("latency_p99", json::num(p.latency_p99)),
        ("queue_p50", json::num(p.queue_p50)),
        ("batch_fill", json::num(p.batch_fill)),
        ("max_queue_depth", json::num(p.max_queue_depth as f64)),
        ("passes", json::num(p.passes as f64)),
        ("throughput_tokens_per_sec", json::num(p.throughput)),
        ("launches", json::num(p.launches as f64)),
    ])
}

// ---------------------------------------------------------------------------
// PR-7 replication: hot-expert replication A/B — live engines, Zipf skew
// ---------------------------------------------------------------------------

/// One arm of the replication A/B (static block placement vs EWMA-driven
/// hot-expert replication), every number measured from live passes.
#[derive(Clone, Debug)]
pub struct ReplicationPoint {
    /// `"static"` or `"replicated"`.
    pub arm: &'static str,
    /// Steady-state per-pass wall p50 after the (possible) rebalance.
    pub wall_p50: f64,
    /// Hottest rank's share of total busy time in the last measured pass
    /// — the load-concentration number replication exists to shrink.
    pub hot_rank_busy_share: f64,
    /// max/mean busy-time imbalance of the last measured pass.
    pub imbalance: f64,
    /// Rows served by replica slots (0 on the static arm).
    pub replica_hits: u64,
    /// Placement version the measured passes ran under.
    pub placement_version: u64,
    /// Replica installs the rebalance performed, and the packed-weight
    /// bytes it booked for them.
    pub replica_installs: u64,
    pub install_bytes: u64,
    /// Request-level latency through `MoeService` under open-loop
    /// Poisson traffic of the same Zipf-skewed tokens.
    pub serving_p50: f64,
    pub serving_p99: f64,
    pub serving_throughput: f64,
}

/// CI-sized replication config: the `tiny` model over 4 ranks (2 owned
/// experts per rank) under dropless routing, so the dense per-token
/// reference is the oracle for both arms. The replicated arm turns the
/// policy on: top-2 hottest experts, 2 copies each, a low enter
/// threshold (the Zipf-1.1 favorite carries ~40% of top-1 mass, far past
/// 1.2× mean) and a fast EWMA so three warm passes converge.
pub fn replication_config(replicated: bool) -> Result<Config> {
    let mut cfg = Config::preset("tiny")?;
    cfg.set("ranks", "4")?;
    cfg.set("tokens", "256")?;
    cfg.set("routing_policy", "dropless")?;
    if replicated {
        cfg.set("replicate_top", "2")?;
        cfg.set("replicas", "2")?;
        cfg.set("replication_hysteresis", "1.2")?;
        cfg.set("ewma_alpha", "0.5")?;
    }
    cfg.validate()?;
    Ok(cfg)
}

/// Drive one arm's serving front end with open-loop Poisson traffic of
/// Zipf-skewed requests and report (p50, p99, tokens/s). The batcher
/// rebalances at its own quiet points, so the replicated arm's placement
/// adapts mid-run exactly as a production service would.
fn replication_serving(
    cfg: &Config,
    params: &Arc<ModelParams>,
    seed: u64,
) -> Result<(f64, f64, f64)> {
    let (requests, rate) = (32usize, 300.0f64);
    let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(cfg));
    let policy = BatchPolicy::from_config(cfg);
    let service =
        MoeService::start(cfg.clone(), params.clone(), backend, TaskGraphMode::Fused, policy)?;
    let (h, e) = (cfg.model.h, cfg.model.e);
    let mut rng = Rng::new(seed ^ 0x7E97_5E47);
    let arrivals = ArrivalProcess::Poisson { rate }.arrivals(requests, (8, 64), &mut rng)?;
    let t0 = std::time::Instant::now();
    let mut handles = Vec::with_capacity(requests);
    for a in &arrivals {
        let due = std::time::Duration::from_secs_f64(a.at);
        if let Some(wait) = due.checked_sub(t0.elapsed()) {
            std::thread::sleep(wait);
        }
        let tokens = skewed_tokens(&params.wg, h, e, a.tokens, Skew::Zipf, &mut rng);
        handles.push(
            service
                .enqueue(tokens, RequestOpts::default())
                .map_err(|e| anyhow::anyhow!("enqueue failed: {e}"))?,
        );
    }
    let mut latencies = Vec::with_capacity(requests);
    let mut tokens_served = 0usize;
    for hdl in handles {
        let res = hdl.wait()?;
        tokens_served += res.rows;
        latencies.push(res.latency_secs);
    }
    let wall = t0.elapsed().as_secs_f64();
    service.shutdown();
    let lat = summarize(&latencies);
    Ok((lat.p50, lat.p99, if wall > 0.0 { tokens_served as f64 / wall } else { 0.0 }))
}

/// Static placement vs EWMA-driven hot-expert replication on **live
/// engines**: same model params, same Zipf-skewed inputs through the real
/// gate — only the [`ReplicationPolicy`](crate::config::ReplicationPolicy)
/// changes. Per arm: warm passes feed the load tracker, one explicit
/// [`MoeEngine::rebalance`] at the inter-pass quiet point, then measured
/// passes. Asserted here (correctness, both arms): zero drops, outputs
/// within the f32 conformance bound of the dense per-token reference,
/// and the replicated arm's outputs **bitwise identical** to the static
/// arm's — the deterministic gate-side splitter preserves the combine
/// fold exactly. The replicated arm must actually replicate (rebalance
/// returns true, replica rows observed). The hot-rank-busy-share and
/// serving-p99 *improvement* claims are gated by the bench's PERF_SMOKE
/// check, not here, so the CI gate stays a real check.
pub fn replication_ab(seed: u64) -> Result<(String, Vec<ReplicationPoint>)> {
    let (warm, passes) = (3usize, 4usize);
    let base = replication_config(false)?;
    // weights depend only on model dims + seed — shared by both arms
    let params = Arc::new(ModelParams::generate(&base, seed));
    let (h, e) = (base.model.h, base.model.e);
    // Zipf-skewed tokens through the production gate, per rank,
    // deterministic in (seed, rank) — identical for both arms
    let inputs: Vec<Vec<f32>> = (0..base.system.ranks)
        .map(|r| {
            let mut rng = Rng::new(seed).fork(0x7E97_0000 + r as u64);
            skewed_tokens(&params.wg, h, e, base.system.s_rank, Skew::Zipf, &mut rng)
        })
        .collect();

    let mut points: Vec<ReplicationPoint> = Vec::new();
    let mut reference: Option<Vec<Vec<f32>>> = None;
    let mut t = Table::new(&[
        "arm",
        "p50 / pass",
        "hot-rank busy share",
        "imbalance",
        "replica rows",
        "installs",
        "serving p50",
        "serving p99",
    ]);
    for replicated in [false, true] {
        let cfg = replication_config(replicated)?;
        let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(&cfg));
        let engine =
            MoeEngine::start(cfg.clone(), params.clone(), backend, TaskGraphMode::Fused)?;
        // warm passes: converge the EWMA tracker (and the usual caches)
        for _ in 0..warm {
            engine.submit(&inputs)?.wait()?;
        }
        let changed = engine.rebalance()?;
        anyhow::ensure!(
            changed == replicated,
            "rebalance under Zipf skew: expected changed={replicated}, got {changed}"
        );
        let mut walls = Vec::with_capacity(passes);
        let mut last = None;
        for _ in 0..passes {
            let t0 = std::time::Instant::now();
            let res = engine.submit(&inputs)?.wait()?;
            walls.push(t0.elapsed().as_secs_f64());
            last = Some(res);
        }
        let res = last.expect("at least one pass");
        let m = &res.metrics;
        anyhow::ensure!(m.total_dropped() == 0, "dropless arm dropped pairs");
        if replicated {
            anyhow::ensure!(
                m.replica_hits() > 0,
                "replicated arm served no rows from replica slots"
            );
            anyhow::ensure!(m.placement_version > 0, "measured passes ran pre-rebalance");
        }
        // conformance: both arms vs the dense f32 per-token oracle
        let tol = cfg.system.wire.conformance_tol() as f64;
        for (r, out) in res.outputs.iter().enumerate() {
            let want = dense_reference_moe(&cfg, &params, &inputs[r]);
            let diff = max_abs_diff(out, &want) as f64;
            anyhow::ensure!(
                diff < tol,
                "{}: rank {r} err {diff} exceeds dense-reference tolerance {tol}",
                if replicated { "replicated" } else { "static" }
            );
        }
        // replication must not change a single output bit
        match &reference {
            None => reference = Some(res.outputs.clone()),
            Some(want) => {
                for (r, (a, b)) in want.iter().zip(&res.outputs).enumerate() {
                    anyhow::ensure!(a.len() == b.len(), "rank {r}: output shape diverged");
                    for (i, (x, y)) in a.iter().zip(b).enumerate() {
                        anyhow::ensure!(
                            x.to_bits() == y.to_bits(),
                            "rank {r} elem {i}: static {x} != replicated {y} (bitwise)"
                        );
                    }
                }
            }
        }
        let em = engine.metrics();
        engine.shutdown();
        let (serving_p50, serving_p99, serving_throughput) =
            replication_serving(&cfg, &params, seed)?;
        let p = ReplicationPoint {
            arm: if replicated { "replicated" } else { "static" },
            wall_p50: summarize(&walls).p50,
            hot_rank_busy_share: m.hot_rank_busy_share(),
            imbalance: m.imbalance(),
            replica_hits: m.replica_hits(),
            placement_version: m.placement_version,
            replica_installs: em.replica_installs,
            install_bytes: em.install_bytes,
            serving_p50,
            serving_p99,
            serving_throughput,
        };
        t.row(&[
            p.arm.to_string(),
            fmt_time(p.wall_p50),
            format!("{:.1}%", p.hot_rank_busy_share * 100.0),
            format!("{:.2}x", p.imbalance),
            p.replica_hits.to_string(),
            format!("{} ({})", p.replica_installs, fmt_bytes(p.install_bytes as f64)),
            fmt_time(p.serving_p50),
            fmt_time(p.serving_p99),
        ]);
        points.push(p);
    }
    Ok((
        format!(
            "## Replication A/B — EWMA hot-expert replication vs static placement (Zipf skew)\n\n{}",
            t.render()
        ),
        points,
    ))
}

/// JSON rows for [`replication_ab`] points (`BENCH_pr7_replication.json`).
pub fn replication_json(points: &[ReplicationPoint]) -> Json {
    Json::Arr(
        points
            .iter()
            .map(|p| {
                json::obj(vec![
                    ("arm", json::s(p.arm)),
                    ("wall_p50", json::num(p.wall_p50)),
                    ("hot_rank_busy_share", json::num(p.hot_rank_busy_share)),
                    ("imbalance", json::num(p.imbalance)),
                    ("replica_hits", json::num(p.replica_hits as f64)),
                    ("placement_version", json::num(p.placement_version as f64)),
                    ("replica_installs", json::num(p.replica_installs as f64)),
                    ("install_bytes", json::num(p.install_bytes as f64)),
                    ("serving_p50", json::num(p.serving_p50)),
                    ("serving_p99", json::num(p.serving_p99)),
                    ("serving_throughput", json::num(p.serving_throughput)),
                ])
            })
            .collect(),
    )
}

// ---------------------------------------------------------------------------
// Table 2 / Fig 15: straggler delay
// ---------------------------------------------------------------------------

pub fn table2(seed: u64) -> (String, Vec<straggler::StragglerReport>) {
    let reports = vec![
        straggler::run(straggler::commercial_vm(), seed),
        straggler::run(straggler::supercomputer(), seed),
    ];
    let mut t = Table::new(&["System", "#Nodes", "#GPUs", "Median", "p95", "paper median", "paper p95"]);
    let paper = [(3.1, 11.4), (1.09, 1.32)];
    for (r, (pm, pp)) in reports.iter().zip(paper) {
        t.row(&[
            r.platform.name.to_string(),
            r.platform.nodes.to_string(),
            r.platform.gpus.to_string(),
            format!("{:.2}x", r.summary.p50),
            format!("{:.2}x", r.summary.p95),
            format!("{pm}x"),
            format!("{pp}x"),
        ]);
    }
    (format!("## Table 2 — straggler delay in synchronous AllToAll\n\n{}", t.render()), reports)
}

// ---------------------------------------------------------------------------
// Table 3: memory overhead
// ---------------------------------------------------------------------------

pub fn table3() -> (String, Vec<layout::MemoryReport>) {
    // Paper Table 3: H such that a token is 4KB (H=1024, fp32), bM=128.
    let model = crate::config::ModelConfig {
        h: 1024,
        d: 2048,
        e: 16,
        k: 1,
        bm: 128,
        bn: 64,
        policy: crate::config::RoutingPolicy::Capacity(1.0),
    };
    let mut reports = Vec::new();
    let mut t = Table::new(&["Tokens", "Experts", "EC", "max(bM,EC)", "Size(L) MB", "Bookkeeping MB", "Total MB"]);
    for tokens in [4096usize, 8192, 16384] {
        for experts in [16usize, 32, 64, 128] {
            let mut m = model.clone();
            m.e = experts;
            // fp32 wire for parity with the paper's Table 3 columns;
            // `memory_report(…, WirePrecision::Bf16)` halves Size(L)
            let r = layout::memory_report(tokens, experts, &m, 8, WirePrecision::F32);
            t.row(&[
                format!("{}K", tokens / 1024),
                experts.to_string(),
                r.ec.to_string(),
                r.c_aligned.to_string(),
                format!("{:.2}", r.size_l / (1024.0 * 1024.0)),
                format!("{:.2}", r.bookkeeping / (1024.0 * 1024.0)),
                format!("{:.2}", r.total() / (1024.0 * 1024.0)),
            ]);
            reports.push(r);
        }
    }
    (format!("## Table 3 — memory overhead of the symmetric tensor L\n\n{}", t.render()), reports)
}

// ---------------------------------------------------------------------------
// Fig 10: forward latency vs tokens/GPU (4 and 8 ranks)
// ---------------------------------------------------------------------------

pub fn fig10(seed: u64) -> Result<(String, Vec<Point>)> {
    let tokens = [1024usize, 2048, 4096, 8192, 16384];
    let mut all = Vec::new();
    let mut text = String::new();
    for ranks in [4usize, 8] {
        let pts = sweep(&figure_engines(), &tokens, |t| paper_config(ranks, t, 64), seed)?;
        text.push_str(&render_latency_table(
            &format!("Fig 10 — forward latency vs tokens/GPU ({ranks} GPUs, E=64)"),
            "tokens/GPU",
            &pts,
        ));
        text.push('\n');
        all.extend(pts);
    }
    Ok((text, all))
}

// ---------------------------------------------------------------------------
// Fig 5a / Fig 11: SM utilization
// ---------------------------------------------------------------------------

pub fn fig11(seed: u64) -> Result<(String, Vec<Point>)> {
    // Paper: T=8K, E=64, 2 GPUs.
    let engines: Vec<Engine> = vec![
        Engine::Flash,
        Engine::Baseline(Baseline::MegatronTe),
        Engine::Baseline(Baseline::Comet),
        Engine::Baseline(Baseline::DeepEp),
        Engine::Baseline(Baseline::FasterMoe),
    ];
    let pts = sweep(&engines, &[8192], |t| paper_config(2, t, 64), seed)?;
    let paper = [
        ("FlashDMoE", 93.17),
        ("Megatron-TE", 59.11),
        ("COMET", 42.31),
        ("Megatron+DeepEP", 13.55),
        ("FasterMoE", 9.67),
    ];
    let mut t = Table::new(&["System", "SM util (ours)", "SM util (paper)"]);
    for (name, paper_util) in paper {
        let p = pts.iter().find(|p| p.engine == name).unwrap();
        t.row(&[
            name.to_string(),
            format!("{:.1}%", p.utilization * 100.0),
            format!("{paper_util:.1}%"),
        ]);
    }
    Ok((format!("## Fig 11 — SM utilization (T=8K, E=64, 2 GPUs)\n\n{}", t.render()), pts))
}

// ---------------------------------------------------------------------------
// Fig 12: overlap efficiency (weak scaling)
// ---------------------------------------------------------------------------

pub fn fig12(seed: u64) -> Result<(String, Vec<Point>)> {
    let ranks = [2usize, 4, 8];
    let pts = sweep(&figure_engines(), &ranks, |r| paper_config(r, 8192, 64), seed)?;
    let engines: Vec<&str> = unique(pts.iter().map(|p| p.engine));
    let mut t = Table::new(&["GPUs", "FlashDMoE", "FasterMoE", "Megatron-CUTLASS", "Megatron-TE", "COMET"]);
    for &r in &ranks {
        let mut row = vec![r.to_string()];
        for e in &engines {
            let t2 = pts.iter().find(|p| p.x == 2.0 && p.engine == *e).unwrap().latency;
            let tn = pts.iter().find(|p| p.x == r as f64 && p.engine == *e).unwrap().latency;
            row.push(format!("{:.2}", t2 / tn));
        }
        t.row(&row);
    }
    Ok((
        format!("## Fig 12 — overlap efficiency O_e = T(2)/T(N), weak scaling (T=8K/GPU)\n\n{}", t.render()),
        pts,
    ))
}

// ---------------------------------------------------------------------------
// Fig 13: throughput scaling
// ---------------------------------------------------------------------------

pub fn fig13(seed: u64) -> Result<(String, Vec<Point>)> {
    let ranks = [2usize, 4, 8];
    let pts = sweep(&figure_engines(), &ranks, |r| paper_config(r, 16384, 64), seed)?;
    let engines: Vec<&str> = unique(pts.iter().map(|p| p.engine));
    let mut t = Table::new(&["GPUs", "FlashDMoE", "FasterMoE", "Megatron-CUTLASS", "Megatron-TE", "COMET"]);
    for &r in &ranks {
        let mut row = vec![r.to_string()];
        for e in &engines {
            let p = pts.iter().find(|p| p.x == r as f64 && p.engine == *e).unwrap();
            let mtoks = 16384.0 * r as f64 / p.latency / 1e6;
            row.push(format!("{mtoks:.2} MTok/s"));
        }
        t.row(&row);
    }
    Ok((format!("## Fig 13 — throughput vs GPUs (T=16K/GPU, E=64)\n\n{}", t.render()), pts))
}

// ---------------------------------------------------------------------------
// Fig 14: expert scalability
// ---------------------------------------------------------------------------

pub fn fig14(seed: u64) -> Result<(String, Vec<Point>)> {
    let experts = [8usize, 16, 32, 64, 128];
    let mut all = Vec::new();
    let mut text = String::new();
    for ranks in [4usize, 8] {
        let pts = sweep(&figure_engines(), &experts, |e| paper_config(ranks, 16384, e), seed)?;
        text.push_str(&render_latency_table(
            &format!("Fig 14 — forward latency vs #experts ({ranks} GPUs, T=16K)"),
            "experts",
            &pts,
        ));
        text.push('\n');
        all.extend(pts);
    }
    Ok((text, all))
}

// ---------------------------------------------------------------------------
// Fig 17: multi-node MIV / incast — measured on the live engine over the
// Transport subsystem (replaces the old closed-form sim sweep)
// ---------------------------------------------------------------------------

/// One (dispatch mode, tokens/GPU) arm of the multi-node A/B, every
/// number measured from live `MoeEngine` passes over the `NodeFabric`.
#[derive(Clone, Debug)]
pub struct MultinodePoint {
    /// `"flat"` or `"hierarchical"` (`DispatchMode::name`).
    pub mode: &'static str,
    pub tokens_per_gpu: usize,
    /// Steady-state per-pass wall p50 (0.0 on an overflow arm).
    pub wall_p50: f64,
    /// NVLink-class bytes of one pass, summed over ranks.
    pub intra_bytes: u64,
    /// NIC-class bytes of one pass, summed over ranks — the quantity
    /// hierarchical dispatch exists to shrink.
    pub inter_bytes: u64,
    /// NIC bytes the ranks declared before moving them; `inter_bytes <=
    /// announced` is the incast bound (asserted by the property suite).
    pub announced_inter_bytes: u64,
    /// Measured Maximal Incast Volume: the hottest receiver's NIC-class
    /// bytes (`PassMetrics::miv_bytes`).
    pub miv_bytes: u64,
    /// Paper §F closed-form MIV estimate, kept as a cross-check column.
    /// Dispatch-only, so the measured value (which also counts combine
    /// returns) sits near 2× this on a balanced gate.
    pub miv_formula: f64,
    /// The pass failed with a NIC receive-window overflow — the paper's
    /// incast failure as an *engine-reported error*, not a sim flag.
    pub overflow: bool,
}

/// Paper §F closed-form Maximal Incast Volume (dispatch-only): every
/// remote source ships its `k·T/E` rows per expert straight at the
/// hottest owner. Retained purely to cross-check the measured
/// `PassMetrics::miv_bytes` — the live number is the reported one.
pub fn miv_formula_bytes(cfg: &Config, tokens: usize) -> f64 {
    let n_rg = (cfg.system.ranks - cfg.system.ranks_per_node()) as f64;
    tokens as f64 / cfg.model.e as f64
        * cfg.system.wire.bytes() as f64
        * cfg.model.h as f64
        * cfg.model.k as f64
        * n_rg
}

/// CI-sized multi-node config: the `paper_multinode` *shape* (4 nodes,
/// k=2 over enough experts per node that coalescing has duplicates to
/// remove) with H/D/bM shrunk so live engines fit a test budget, and the
/// NIC receive window scaled with them so the incast cliff stays where
/// the paper puts it — past 2048 tokens/GPU, the window fits a
/// 2048-token pass's worst-case inbound (~1.6 MB here) and a 4096-token
/// pass (~3 MB) overflows it.
pub fn multinode_config(tokens: usize) -> Result<Config> {
    let mut cfg = Config::preset("paper_multinode")?;
    cfg.set("h", "64")?;
    cfg.set("d", "128")?;
    cfg.set("bm", "16")?;
    cfg.set("bn", "16")?;
    cfg.set("ranks", "8")?; // 4 nodes × 2 ranks, 2 experts/rank
    cfg.set("processors", "2")?;
    cfg.set("nic_buffer", &(2u64 * 1024 * 1024).to_string())?;
    cfg.set("tokens", &tokens.to_string())?;
    cfg.validate()?;
    Ok(cfg)
}

/// Flat vs hierarchical dispatch through **live engines** on the same
/// multi-node config, params and inputs — only `DispatchMode` changes.
/// Per tokens/GPU point: warmup + measured passes per arm, latency p50,
/// the intra/inter byte split, measured MIV (with the §F formula as a
/// cross-check column), and the incast overflow past 2048 tokens/GPU as
/// an engine-reported pass error. Where both arms complete, their
/// outputs are asserted **bitwise identical** — the proxy hop preserves
/// the logical source, so the combine fold never sees a difference. The
/// hier-moves-fewer-inter-bytes claim is asserted by the bench's
/// PERF_SMOKE gate, not here, so the CI gate stays a real check.
pub fn multinode_ab(seed: u64) -> Result<(String, Vec<MultinodePoint>)> {
    let tokens = [256usize, 512, 1024, 2048, 4096];
    let passes = 2;
    let base = multinode_config(tokens[0])?;
    // weights depend only on model dims + seed — shared by every arm
    let params = Arc::new(ModelParams::generate(&base, seed));
    let mut points: Vec<MultinodePoint> = Vec::new();
    let mut t = Table::new(&[
        "Tokens/GPU",
        "mode",
        "p50 / pass",
        "intra bytes",
        "inter bytes",
        "MIV (measured)",
        "MIV (§F formula)",
        "Status",
    ]);
    for &tok in &tokens {
        let mut outputs: Vec<Option<Vec<Vec<f32>>>> = Vec::new();
        for mode in ["flat", "hierarchical"] {
            let mut cfg = multinode_config(tok)?;
            cfg.set("dispatch", mode)?;
            cfg.validate()?;
            let inputs: Vec<Vec<f32>> =
                (0..cfg.system.ranks).map(|r| generate_tokens(&cfg, seed, r)).collect();
            let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(&cfg));
            let engine =
                MoeEngine::start(cfg.clone(), params.clone(), backend, TaskGraphMode::Fused)?;
            let mut point = MultinodePoint {
                mode: cfg.system.dispatch.name(),
                tokens_per_gpu: tok,
                wall_p50: 0.0,
                intra_bytes: 0,
                inter_bytes: 0,
                announced_inter_bytes: 0,
                miv_bytes: 0,
                miv_formula: miv_formula_bytes(&cfg, tok),
                overflow: false,
            };
            let mut last = None;
            let mut walls = Vec::with_capacity(passes);
            match engine.submit(&inputs)?.wait() {
                Err(e) => {
                    // the paper's incast failure, reported by the engine
                    anyhow::ensure!(
                        format!("{e:#}").contains("incast"),
                        "multi-node pass failed for a non-incast reason: {e:#}"
                    );
                    point.overflow = true;
                }
                Ok(_) => {
                    for _ in 0..passes {
                        let t0 = std::time::Instant::now();
                        let res = engine.submit(&inputs)?.wait()?;
                        walls.push(t0.elapsed().as_secs_f64());
                        last = Some(res);
                    }
                }
            }
            if let Some(res) = last {
                let m = &res.metrics;
                point.wall_p50 = summarize(&walls).p50;
                point.intra_bytes = m.intra_bytes();
                point.inter_bytes = m.inter_bytes();
                point.announced_inter_bytes = m.announced_inter_bytes();
                point.miv_bytes = m.miv_bytes();
                anyhow::ensure!(
                    point.inter_bytes <= point.announced_inter_bytes,
                    "{mode} @ {tok} tok/GPU: measured inter bytes {} exceed announced {}",
                    point.inter_bytes,
                    point.announced_inter_bytes
                );
                outputs.push(Some(res.outputs));
            } else {
                outputs.push(None);
            }
            t.row(&[
                tok.to_string(),
                point.mode.to_string(),
                if point.overflow { "-".into() } else { fmt_time(point.wall_p50) },
                fmt_bytes(point.intra_bytes as f64),
                fmt_bytes(point.inter_bytes as f64),
                fmt_bytes(point.miv_bytes as f64),
                fmt_bytes(point.miv_formula),
                if point.overflow { "FAIL (incast overflow)".into() } else { "ok".into() },
            ]);
            points.push(point);
            engine.shutdown();
        }
        // two-level dispatch must not change a single output bit
        if let (Some(flat), Some(hier)) = (&outputs[0], &outputs[1]) {
            for (r, (a, b)) in flat.iter().zip(hier).enumerate() {
                anyhow::ensure!(a.len() == b.len(), "rank {r}: output shape diverged");
                for (i, (x, y)) in a.iter().zip(b).enumerate() {
                    anyhow::ensure!(
                        x.to_bits() == y.to_bits(),
                        "rank {r} elem {i}: flat {x} != hierarchical {y} (bitwise)"
                    );
                }
            }
        }
    }
    Ok((
        format!(
            "## Fig 17 — multi-node A/B, measured on live engines (flat vs hierarchical)\n\n{}",
            t.render()
        ),
        points,
    ))
}

/// JSON rows for [`multinode_ab`] points (`BENCH_pr6_multinode.json`).
pub fn multinode_json(points: &[MultinodePoint]) -> Json {
    Json::Arr(
        points
            .iter()
            .map(|p| {
                json::obj(vec![
                    ("mode", json::s(p.mode)),
                    ("tokens_per_gpu", json::num(p.tokens_per_gpu as f64)),
                    ("wall_p50", json::num(p.wall_p50)),
                    ("intra_bytes", json::num(p.intra_bytes as f64)),
                    ("inter_bytes", json::num(p.inter_bytes as f64)),
                    ("announced_inter_bytes", json::num(p.announced_inter_bytes as f64)),
                    ("miv_bytes", json::num(p.miv_bytes as f64)),
                    ("miv_formula", json::num(p.miv_formula)),
                    ("overflow", Json::Bool(p.overflow)),
                ])
            })
            .collect(),
    )
}

// ---------------------------------------------------------------------------
// Fig 18: wire precision A/B — measured on the live engine, not modeled
// ---------------------------------------------------------------------------

/// One wire-precision arm measured on the real engine (replaces the
/// old analytic fig18: every number here comes out of a live pass).
#[derive(Clone, Debug)]
pub struct PrecisionPoint {
    pub wire: WirePrecision,
    /// Measured one-sided bytes of one steady-state pass at this wire
    /// width (from the heap's byte counters, not a formula).
    pub wire_bytes: u64,
    /// Byte-granular payload savings vs the padded-fp32 baseline
    /// (dropped padding + narrowing; `PassMetrics::payload_savings`).
    pub payload_savings: f64,
    /// Steady-state per-pass wall p50.
    pub wall_p50: f64,
    /// Max |engine - dense f32 reference| over all ranks' outputs.
    pub max_abs_err: f64,
    /// The documented conformance bound the error was checked against.
    pub tolerance: f64,
    /// Symmetric-heap bytes per rank (halves on a 16-bit wire).
    pub heap_bytes: f64,
}

/// A/B the wire formats on the real (native-backend) engine: same
/// preset, same seed, same inputs — only `wire_precision` changes.
/// Dropless routing makes the dense per-token reference the oracle for
/// every arm: conformance at each format's documented tolerance is
/// asserted here. The gate runs on the submitted f32 tokens, so routing
/// is identical across arms and the 16-bit arms should measure exactly
/// half the f32 wire bytes — the measured `wire_bytes` are *reported*,
/// and the byte-ratio checks live in the callers (the engines test
/// asserts the exact 2×; the `fig18_fp16` PERF_SMOKE gate independently
/// fails CI at ≥ 0.6×), so the CI gate is a real check rather than dead
/// code behind a stricter internal assert.
pub fn precision_ab(
    preset: &str,
    passes: usize,
    seed: u64,
) -> Result<(String, Vec<PrecisionPoint>)> {
    let passes = passes.max(1);
    let arms = [WirePrecision::F32, WirePrecision::Bf16, WirePrecision::F16];
    // weights and tokens depend only on model dims + seed, not on the
    // wire setting — generate once and share across all three arms
    let mut base = Config::preset(preset)?;
    base.set("routing_policy", "dropless")?; // dense-ref conformance holds
    base.validate()?;
    let params = Arc::new(ModelParams::generate(&base, seed));
    let inputs: Vec<Vec<f32>> =
        (0..base.system.ranks).map(|r| generate_tokens(&base, seed, r)).collect();
    let mut points: Vec<PrecisionPoint> = Vec::new();
    let mut f32_bytes: Option<u64> = None;
    let mut t = Table::new(&[
        "wire",
        "bytes / pass (measured)",
        "vs fp32",
        "payload saved",
        "p50 / pass",
        "max |err| vs dense ref",
        "heap/rank",
    ]);
    for wire in arms {
        let mut cfg = base.clone();
        cfg.set("wire_precision", wire.name())?;
        cfg.validate()?;
        let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(&cfg));
        let engine = MoeEngine::start(cfg.clone(), params.clone(), backend, TaskGraphMode::Fused)?;
        engine.submit(&inputs)?.wait()?; // warmup
        let mut walls = Vec::with_capacity(passes);
        let mut last = None;
        for _ in 0..passes {
            let t0 = std::time::Instant::now();
            let res = engine.submit(&inputs)?.wait()?;
            walls.push(t0.elapsed().as_secs_f64());
            last = Some(res);
        }
        let res = last.expect("at least one pass");
        let bytes = res.metrics.total_bytes();
        anyhow::ensure!(res.metrics.total_dropped() == 0, "dropless arm dropped pairs");

        // conformance: measured outputs vs the dense f32 per-token oracle
        let mut max_err = 0.0f64;
        for (r, out) in res.outputs.iter().enumerate() {
            let want = dense_reference_moe(&cfg, &params, &inputs[r]);
            let diff = max_abs_diff(out, &want) as f64;
            anyhow::ensure!(
                diff < wire.conformance_tol() as f64,
                "{} wire: rank {r} err {diff} exceeds documented tolerance {}",
                wire.name(),
                wire.conformance_tol()
            );
            max_err = max_err.max(diff);
        }

        // identical routing across arms (the gate sees the f32 tokens),
        // so bytes scale exactly with the element width — reported here,
        // asserted by the callers (exact 2× in the engines test, < 0.6×
        // in the bench's PERF_SMOKE gate)
        if f32_bytes.is_none() {
            f32_bytes = Some(bytes);
        }

        let p = PrecisionPoint {
            wire,
            wire_bytes: bytes,
            payload_savings: res.metrics.payload_savings(),
            wall_p50: summarize(&walls).p50,
            max_abs_err: max_err,
            tolerance: wire.conformance_tol() as f64,
            heap_bytes: engine.heap_bytes_per_rank(),
        };
        t.row(&[
            wire.name().to_string(),
            fmt_bytes(p.wire_bytes as f64),
            format!("{:.2}x", p.wire_bytes as f64 / f32_bytes.unwrap() as f64),
            format!("{:.1}%", p.payload_savings * 100.0),
            fmt_time(p.wall_p50),
            format!("{:.2e} (tol {:.0e})", p.max_abs_err, p.tolerance),
            fmt_bytes(p.heap_bytes),
        ]);
        points.push(p);
        engine.shutdown();
    }
    Ok((
        format!(
            "## Fig 18 — wire precision A/B, measured on the live engine ({preset}, {passes} passes)\n\n{}",
            t.render()
        ),
        points,
    ))
}

/// JSON rows for [`precision_ab`] points (`BENCH_pr5_precision.json`).
pub fn precision_json(points: &[PrecisionPoint]) -> Json {
    Json::Arr(
        points
            .iter()
            .map(|p| {
                json::obj(vec![
                    ("wire", json::s(p.wire.name())),
                    ("wire_bytes", json::num(p.wire_bytes as f64)),
                    ("payload_savings", json::num(p.payload_savings)),
                    ("wall_p50", json::num(p.wall_p50)),
                    ("max_abs_err", json::num(p.max_abs_err)),
                    ("tolerance", json::num(p.tolerance)),
                    ("heap_bytes_per_rank", json::num(p.heap_bytes)),
                ])
            })
            .collect(),
    )
}

// ---------------------------------------------------------------------------
// PR-8 chaos: fault injection, pass-level retry, degraded-capacity serving
// ---------------------------------------------------------------------------

/// One arm of the chaos A/B — the same open-loop serving workload with
/// the deterministic fault schedule off (`"clean"`) or on (`"faulted"`).
/// Every number is measured from a live `MoeService` run.
#[derive(Clone, Debug)]
pub struct ChaosPoint {
    /// `"clean"` or `"faulted"`.
    pub arm: &'static str,
    pub requests: usize,
    pub served: u64,
    pub failed: u64,
    pub deadline_misses: u64,
    /// served / enqueued — the serving availability under the schedule.
    pub availability: f64,
    /// Request latency percentiles (enqueue → completion), seconds.
    pub latency_p50: f64,
    pub latency_p99: f64,
    pub latency_p999: f64,
    /// Pass resubmissions the engine performed transparently.
    pub retries: u64,
    /// Passes that ran under a degraded (dead-rank) placement.
    pub degraded_passes: u64,
    /// Faults the plan actually injected at the transport seam.
    pub faults_injected: u64,
    /// Tokens served per wall second.
    pub throughput: f64,
}

/// CI-sized chaos config: the replication shape (`tiny`, 4 ranks,
/// dropless, hot-expert replicas so a dead rank's hot experts survive
/// elsewhere) plus a retry budget. The faulted arm adds the
/// deterministic schedule: every cross-rank transfer of pass epoch 2
/// fails transiently (the window `[2, 3)` at rate 1.0), and rank 3 dies
/// permanently at epoch 6 — so one retry rides out the transient, and
/// the permanent death exercises the epoch-fenced degraded-placement
/// swap mid-run.
pub fn chaos_config(faulted: bool) -> Result<Config> {
    let mut cfg = replication_config(true)?;
    cfg.set("retry_limit", "2")?;
    if faulted {
        cfg.set("fault_seed", "42")?;
        cfg.set("fault_transient_rate", "1.0")?;
        cfg.set("fault_transient_from", "2")?;
        cfg.set("fault_transient_until", "3")?;
        cfg.set("fault_kill_rank", "3")?;
        cfg.set("fault_kill_epoch", "6")?;
    }
    cfg.validate()?;
    Ok(cfg)
}

/// Drive one arm's serving front end with open-loop Poisson traffic and
/// report (success latencies, wall seconds, tokens served, final report).
/// Request failures are tolerated here (the A/B asserts on the counts),
/// so a mid-run fault surfaces as accounting, not a harness error. Every
/// request carries a generous deadline so the deadline-admission path is
/// exercised without shedding under the test schedule.
fn chaos_serving(
    cfg: &Config,
    params: &Arc<ModelParams>,
    seed: u64,
    requests: usize,
    rate: f64,
) -> Result<(Vec<f64>, f64, usize, crate::coordinator::ServiceReport)> {
    let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(cfg));
    // Small passes (max_tokens 64 vs the 8..=64-row requests) so the run
    // spans enough epochs to cross the kill epoch deterministically.
    let mut policy = BatchPolicy::from_config(cfg);
    policy.max_tokens = 64;
    let service =
        MoeService::start(cfg.clone(), params.clone(), backend, TaskGraphMode::Fused, policy)?;
    let (h, e) = (cfg.model.h, cfg.model.e);
    let mut rng = Rng::new(seed ^ 0xC4A0_5E47);
    let arrivals = ArrivalProcess::Poisson { rate }.arrivals(requests, (8, 64), &mut rng)?;
    let opts = RequestOpts {
        deadline: Some(std::time::Duration::from_secs(30)),
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let mut handles = Vec::with_capacity(requests);
    for a in &arrivals {
        let due = std::time::Duration::from_secs_f64(a.at);
        if let Some(wait) = due.checked_sub(t0.elapsed()) {
            std::thread::sleep(wait);
        }
        let tokens = skewed_tokens(&params.wg, h, e, a.tokens, Skew::Zipf, &mut rng);
        handles.push(
            service
                .enqueue(tokens, opts)
                .map_err(|e| anyhow::anyhow!("enqueue failed: {e}"))?,
        );
    }
    let mut latencies = Vec::with_capacity(requests);
    let mut tokens_served = 0usize;
    for hdl in handles {
        if let Ok(res) = hdl.wait() {
            tokens_served += res.rows;
            latencies.push(res.latency_secs);
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let report = service.shutdown();
    Ok((latencies, wall, tokens_served, report))
}

/// Clean vs faulted serving on **live engines**: the same params and
/// Zipf traffic, only the [`FaultConfig`](crate::config::FaultConfig)
/// schedule changes. Asserted here (both arms are correctness gates):
/// the clean arm serves everything with zero retries and zero injected
/// faults; the faulted arm *actually* injects faults, retries at least
/// one pass, swaps to a degraded placement after the kill epoch, and —
/// the availability claim — still serves every accepted request
/// (`served == enqueued`: transparent retry plus replica routing, no
/// wedge, no silent drop). The p99/p999-degradation-vs-clean numbers are
/// reported for the bench's PERF_SMOKE gate, not asserted here.
pub fn chaos_ab(seed: u64) -> Result<(String, Vec<ChaosPoint>)> {
    let (requests, rate) = (40usize, 400.0f64);
    let base = chaos_config(false)?;
    // weights depend only on model dims + seed — shared by both arms
    let params = Arc::new(ModelParams::generate(&base, seed));
    let mut points = Vec::new();
    let mut t = Table::new(&[
        "arm",
        "served",
        "failed",
        "availability",
        "p50",
        "p99",
        "p99.9",
        "retries",
        "degraded passes",
        "faults injected",
    ]);
    for faulted in [false, true] {
        let cfg = chaos_config(faulted)?;
        let (latencies, wall, tokens_served, report) =
            chaos_serving(&cfg, &params, seed, requests, rate)?;
        let s = &report.service;
        let em = &report.engine;
        anyhow::ensure!(em.launches == 1, "service lifetime must cost one launch");
        anyhow::ensure!(
            s.requests_enqueued == s.requests_served + s.requests_cancelled + s.requests_failed,
            "accounting leak: {} enqueued != {} served + {} cancelled + {} failed",
            s.requests_enqueued,
            s.requests_served,
            s.requests_cancelled,
            s.requests_failed
        );
        if faulted {
            anyhow::ensure!(em.faults_injected > 0, "faulted arm injected no faults");
            anyhow::ensure!(em.retries > 0, "faulted arm performed no pass retries");
            anyhow::ensure!(
                em.degraded_passes > 0,
                "faulted arm never ran a degraded pass after the kill epoch"
            );
        } else {
            anyhow::ensure!(em.faults_injected == 0, "clean arm injected faults");
            anyhow::ensure!(em.retries == 0, "clean arm retried passes");
        }
        anyhow::ensure!(
            s.requests_served == s.requests_enqueued,
            "{} arm dropped requests: served {} of {} (failed {}, deadline misses {})",
            if faulted { "faulted" } else { "clean" },
            s.requests_served,
            s.requests_enqueued,
            s.requests_failed,
            s.deadline_misses
        );
        let mut sorted = latencies.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p = ChaosPoint {
            arm: if faulted { "faulted" } else { "clean" },
            requests,
            served: s.requests_served,
            failed: s.requests_failed,
            deadline_misses: s.deadline_misses,
            availability: if s.requests_enqueued > 0 {
                s.requests_served as f64 / s.requests_enqueued as f64
            } else {
                0.0
            },
            latency_p50: percentile(&sorted, 0.50),
            latency_p99: percentile(&sorted, 0.99),
            latency_p999: percentile(&sorted, 0.999),
            retries: em.retries,
            degraded_passes: em.degraded_passes,
            faults_injected: em.faults_injected,
            throughput: if wall > 0.0 { tokens_served as f64 / wall } else { 0.0 },
        };
        t.row(&[
            p.arm.to_string(),
            p.served.to_string(),
            p.failed.to_string(),
            format!("{:.1}%", p.availability * 100.0),
            fmt_time(p.latency_p50),
            fmt_time(p.latency_p99),
            fmt_time(p.latency_p999),
            p.retries.to_string(),
            p.degraded_passes.to_string(),
            p.faults_injected.to_string(),
        ]);
        points.push(p);
    }
    Ok((
        format!(
            "## Chaos A/B — fault injection, transparent retry, degraded-capacity serving\n\n{}",
            t.render()
        ),
        points,
    ))
}

/// JSON rows for [`chaos_ab`] points (`BENCH_pr8_chaos.json`).
pub fn chaos_json(points: &[ChaosPoint]) -> Json {
    Json::Arr(
        points
            .iter()
            .map(|p| {
                json::obj(vec![
                    ("arm", json::s(p.arm)),
                    ("requests", json::num(p.requests as f64)),
                    ("served", json::num(p.served as f64)),
                    ("failed", json::num(p.failed as f64)),
                    ("deadline_misses", json::num(p.deadline_misses as f64)),
                    ("availability", json::num(p.availability)),
                    ("latency_p50", json::num(p.latency_p50)),
                    ("latency_p99", json::num(p.latency_p99)),
                    ("latency_p999", json::num(p.latency_p999)),
                    ("retries", json::num(p.retries as f64)),
                    ("degraded_passes", json::num(p.degraded_passes as f64)),
                    ("faults_injected", json::num(p.faults_injected as f64)),
                    ("throughput_tokens_per_sec", json::num(p.throughput)),
                ])
            })
            .collect(),
    )
}

// ---------------------------------------------------------------------------
// PR 10: multi-model residency
// ---------------------------------------------------------------------------

/// Per-model serving measurement under a Zipf multi-model trace — one
/// row per resident model.
#[derive(Clone, Debug)]
pub struct MultiModelPoint {
    /// Resident model id (0 = anchor).
    pub model: usize,
    /// `"anchor"`, `"base"` (independent weights) or `"lora"` (delta
    /// variant of model 0).
    pub kind: &'static str,
    /// Requests the trace routed to this model.
    pub requests: usize,
    pub served: u64,
    /// Request latency percentiles (enqueue → completion), seconds.
    pub latency_p50: f64,
    pub latency_p99: f64,
}

/// Residency accounting for the co-resident engine vs N dedicated
/// engines — the memory side of the multi-model claim.
#[derive(Clone, Debug)]
pub struct MultiModelResidency {
    /// `resident_bytes()` of the one engine serving all three models.
    pub co_resident_bytes: usize,
    /// What three dedicated single-model engines would hold (the LoRA
    /// variant materialized as a full independent model).
    pub dedicated_bytes: usize,
    /// Incremental bytes the LoRA registration actually added.
    pub lora_incremental_bytes: usize,
    /// Bytes of one full independent pack (a whole model's parameters) —
    /// the figure the LoRA increment must beat.
    pub full_pack_bytes: usize,
}

/// Serving config for the multi-model A/B: three resident-model slots,
/// dropless routing (request outputs independent of pass co-travelers).
pub fn multimodel_config() -> Result<Config> {
    let mut cfg = Config::preset("tiny")?;
    cfg.set("ranks", "4")?;
    cfg.set("tokens", "256")?;
    cfg.set("routing_policy", "dropless")?;
    cfg.set("max_models", "3")?;
    cfg.validate()?;
    Ok(cfg)
}

/// Three models co-resident on **one live engine** — the anchor, an
/// independent base, and a LoRA delta variant of the anchor — served
/// through the request front end under a Zipf-skewed multi-model trace
/// (model 0 hottest, the real multi-tenant shape). Asserted here: one
/// launch for the whole run, every accepted request served, and the
/// delta variant costs only its delta bytes (`resident_bytes` audits the
/// shared packed cache). Per-model p50/p99 and the co-resident vs
/// dedicated byte comparison are returned for the bench JSON; the bench's
/// PERF_SMOKE gate fails if the LoRA increment reaches a full pack.
pub fn multimodel_ab(
    seed: u64,
) -> Result<(String, Vec<MultiModelPoint>, MultiModelResidency)> {
    let requests = 60usize;
    let cfg = multimodel_config()?;
    let params0 = Arc::new(ModelParams::generate(&cfg, seed));
    let params1 = Arc::new(ModelParams::generate(&cfg, seed ^ 0xB45E));
    let delta = Arc::new(crate::registry::DeltaSet::generate(&cfg, seed ^ 0x10A4, 2, 0.05));
    let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(&cfg));
    let policy = BatchPolicy::from_config(&cfg);
    let service =
        MoeService::start(cfg.clone(), params0.clone(), backend, TaskGraphMode::Fused, policy)?;
    let hb = service.register_model(params1.clone())?;
    anyhow::ensure!(hb.id == 1 && !hb.deduped, "independent base must pack fresh as model 1");
    let hl = service.register_delta(0, delta.clone())?;
    anyhow::ensure!(hl.id == 2, "delta variant must land in slot 2");
    anyhow::ensure!(
        hl.resident_bytes == delta.bytes(),
        "delta residency must cost exactly the delta bytes"
    );

    // Zipf multi-model trace: write it out and replay it through the
    // same trace machinery a CLI `trace:<path>` run uses.
    let trace = crate::workload::zipf_model_trace(requests, 300.0, (8, 32), 3, 1.2, seed);
    let path = std::env::temp_dir().join(format!("flashdmoe_multimodel_{seed}.trace"));
    std::fs::write(&path, trace)?;
    let mut rng = Rng::new(seed ^ 0x3D0E_15E4);
    let arrivals = ArrivalProcess::Trace(path.display().to_string())
        .arrivals(requests, (8, 32), &mut rng)?;
    let _ = std::fs::remove_file(&path);

    let h = cfg.model.h;
    let t0 = std::time::Instant::now();
    let mut handles = Vec::with_capacity(requests);
    for a in &arrivals {
        let due = std::time::Duration::from_secs_f64(a.at);
        if let Some(wait) = due.checked_sub(t0.elapsed()) {
            std::thread::sleep(wait);
        }
        let tokens = rng.normal_vec(a.tokens * h, 1.0);
        let opts = RequestOpts { model: a.model, priority: a.priority, ..Default::default() };
        handles.push((
            a.model,
            service
                .enqueue(tokens, opts)
                .map_err(|e| anyhow::anyhow!("enqueue failed: {e}"))?,
        ));
    }
    let mut lat: Vec<Vec<f64>> = vec![Vec::new(); 3];
    for (model, hdl) in handles {
        let res = hdl.wait()?;
        lat[model].push(res.latency_secs);
    }
    let co_resident_bytes = service.resident_bytes();
    let report = service.shutdown();
    anyhow::ensure!(
        report.engine.launches == 1,
        "three co-resident models must still cost one launch, saw {}",
        report.engine.launches
    );
    anyhow::ensure!(
        report.service.requests_served == requests as u64,
        "dropped requests: served {} of {requests}",
        report.service.requests_served
    );
    anyhow::ensure!(
        report.engine.model_registrations == 2,
        "expected 2 model registrations, saw {}",
        report.engine.model_registrations
    );

    let full_pack_bytes = params0.num_params() * std::mem::size_of::<f32>();
    anyhow::ensure!(
        co_resident_bytes == 2 * full_pack_bytes + delta.bytes(),
        "resident-bytes audit: engine reports {co_resident_bytes}, expected \
         2 full packs + the delta ({})",
        2 * full_pack_bytes + delta.bytes()
    );
    let residency = MultiModelResidency {
        co_resident_bytes,
        dedicated_bytes: 3 * full_pack_bytes,
        lora_incremental_bytes: hl.resident_bytes,
        full_pack_bytes,
    };

    let kinds = ["anchor", "base", "lora"];
    let mut points = Vec::new();
    let mut t = Table::new(&["model", "kind", "requests", "p50", "p99"]);
    for (m, l) in lat.iter().enumerate() {
        let mut sorted = l.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p = MultiModelPoint {
            model: m,
            kind: kinds[m],
            requests: l.len(),
            served: l.len() as u64,
            latency_p50: if sorted.is_empty() { 0.0 } else { percentile(&sorted, 0.50) },
            latency_p99: if sorted.is_empty() { 0.0 } else { percentile(&sorted, 0.99) },
        };
        t.row(&[
            m.to_string(),
            p.kind.to_string(),
            p.requests.to_string(),
            fmt_time(p.latency_p50),
            fmt_time(p.latency_p99),
        ]);
        points.push(p);
    }
    // Zipf s=1.2 over 3 models: the anchor must dominate the trace.
    anyhow::ensure!(
        points[0].requests > points[1].requests + points[2].requests,
        "Zipf trace should send most traffic to model 0"
    );
    let md = format!(
        "## Multi-model residency — 3 models, one engine, Zipf trace\n\n{}\n\
         Resident bytes: co-resident {} vs {} for 3 dedicated engines \
         (LoRA increment {} vs full pack {}).\n",
        t.render(),
        fmt_bytes(residency.co_resident_bytes as f64),
        fmt_bytes(residency.dedicated_bytes as f64),
        fmt_bytes(residency.lora_incremental_bytes as f64),
        fmt_bytes(residency.full_pack_bytes as f64),
    );
    Ok((md, points, residency))
}

/// JSON for [`multimodel_ab`] (`BENCH_pr10_multimodel.json`).
pub fn multimodel_json(points: &[MultiModelPoint], res: &MultiModelResidency) -> Json {
    json::obj(vec![
        (
            "models",
            Json::Arr(
                points
                    .iter()
                    .map(|p| {
                        json::obj(vec![
                            ("model", json::num(p.model as f64)),
                            ("kind", json::s(p.kind)),
                            ("requests", json::num(p.requests as f64)),
                            ("served", json::num(p.served as f64)),
                            ("latency_p50", json::num(p.latency_p50)),
                            ("latency_p99", json::num(p.latency_p99)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("co_resident_bytes", json::num(res.co_resident_bytes as f64)),
        ("dedicated_bytes", json::num(res.dedicated_bytes as f64)),
        ("lora_incremental_bytes", json::num(res.lora_incremental_bytes as f64)),
        ("full_pack_bytes", json::num(res.full_pack_bytes as f64)),
    ])
}
