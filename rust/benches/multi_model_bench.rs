//! Multi-model residency — **measured on a live engine**: the anchor, an
//! independent base, and a LoRA delta variant co-resident on one engine,
//! served through the request front end under a Zipf-skewed multi-model
//! trace (model 0 hottest, the multi-tenant shape). Correctness is
//! asserted inside the harness (one launch, every request served, the
//! shared packed cache audited); this bench reports the per-model
//! latency cost of co-residency and the memory story — resident bytes of
//! the one engine vs three dedicated engines.
//!
//! Emits `BENCH_pr10_multimodel.json` (section `multimodel_ab`) for the
//! CI artifact upload. With `PERF_SMOKE=1` the run FAILS unless
//! (a) the LoRA variant's incremental resident bytes are strictly below
//! a full independent pack — the whole point of sharing the base's
//! packed panels — and (b) co-residency actually undercuts N dedicated
//! engines, so the gate cannot pass on a registry that quietly
//! materializes every variant.
//!
//!     cargo bench --bench multi_model_bench
fn main() {
    let (text, pts, res) = flashdmoe::harness::multimodel_ab(42).unwrap();
    println!("{text}");

    flashdmoe::harness::update_bench_json(
        "BENCH_pr10_multimodel.json",
        "multimodel_ab",
        flashdmoe::harness::multimodel_json(&pts, &res),
    )
    .unwrap();
    println!("wrote BENCH_pr10_multimodel.json (section multimodel_ab)");

    let perf_smoke = std::env::var("PERF_SMOKE").map(|v| v == "1").unwrap_or(false);
    if perf_smoke {
        let mut failed = false;
        if res.lora_incremental_bytes >= res.full_pack_bytes {
            eprintln!(
                "PERF_SMOKE FAIL: LoRA increment {} >= a full independent pack {} — \
                 the variant is not sharing its base's packed weights",
                res.lora_incremental_bytes, res.full_pack_bytes
            );
            failed = true;
        }
        if res.co_resident_bytes >= res.dedicated_bytes {
            eprintln!(
                "PERF_SMOKE FAIL: co-resident {} >= {} for 3 dedicated engines",
                res.co_resident_bytes, res.dedicated_bytes
            );
            failed = true;
        }
        if !failed {
            println!(
                "PERF_SMOKE ok: LoRA increment {} of a full pack ({:.1}%), \
                 co-resident {} vs dedicated {} ({:.1}% saved)",
                res.lora_incremental_bytes,
                100.0 * res.lora_incremental_bytes as f64 / res.full_pack_bytes as f64,
                res.co_resident_bytes,
                res.dedicated_bytes,
                100.0 * (1.0 - res.co_resident_bytes as f64 / res.dedicated_bytes as f64),
            );
        }
        if failed {
            std::process::exit(1);
        }
    }
}
