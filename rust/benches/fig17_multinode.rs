//! Fig 17 — multi-node latency + Maximal Incast Volume; reproduces the
//! paper's >2048-token incast failure mode.
fn main() {
    let (text, _) = flashdmoe::harness::fig17(42).unwrap();
    println!("{text}");
}
