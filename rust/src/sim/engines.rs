//! The three scheduling engines over virtual time.
//!
//! All engines replay identical routing tables (from `crate::workload`),
//! identical FLOP counts and identical link parameters — the *only*
//! difference is the schedule structure, which is precisely the paper's
//! claim surface:
//!
//! * [`Engine::Flash`] — persistent kernel: tile tasks are scheduled the
//!   instant their one-sided transfer lands; payload-efficient dispatch;
//!   a single kernel launch; no barriers.
//! * Sequential baselines (Megatron-LM CUTLASS/TE, DeepSpeedMoE,
//!   Megatron+DeepEP) — bulk-synchronous phases with barriers, padded
//!   collectives, per-phase kernel launches, and computation over null
//!   (padded) rows.
//! * Overlap baselines (FasterMoE, Comet) — chunked collectives pipelined
//!   against expert compute, but with per-chunk kernel launches and
//!   phase-boundary synchronization.
//!
//! Launch-count models per baseline are calibrated against the paper's
//! Table 1 (2 ranks × 32 local experts); see [`Baseline::launch_model`].

use anyhow::Result;

use crate::config::Config;
use crate::util::prng::Rng;
use crate::workload::RankWorkload;

use super::resources::{LinkSet, ProcPool};

/// Baseline systems from the paper's evaluation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Baseline {
    MegatronCutlass,
    MegatronTe,
    DeepSpeed,
    DeepEp,
    FasterMoe,
    Comet,
}

/// Scheduling engine selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    Flash,
    Baseline(Baseline),
}

impl Engine {
    pub fn name(&self) -> &'static str {
        match self {
            Engine::Flash => "FlashDMoE",
            Engine::Baseline(b) => b.name(),
        }
    }

    pub fn parse(s: &str) -> Option<Engine> {
        Some(match s {
            "flash" => Engine::Flash,
            "megatron-cutlass" => Engine::Baseline(Baseline::MegatronCutlass),
            "megatron-te" => Engine::Baseline(Baseline::MegatronTe),
            "deepspeed" => Engine::Baseline(Baseline::DeepSpeed),
            "deepep" => Engine::Baseline(Baseline::DeepEp),
            "fastermoe" => Engine::Baseline(Baseline::FasterMoe),
            "comet" => Engine::Baseline(Baseline::Comet),
            _ => return None,
        })
    }
}

/// Launch-count model: launches/rank = fixed + per_expert·E_total +
/// per_peer·P. The per-expert term scales with *total* experts because
/// the frameworks' routing/permute/metadata kernels iterate the global
/// expert set regardless of placement (this is what makes their Fig 14
/// expert-scaling superlinear and their Fig 12 weak scaling flat-to-worse
/// rather than improving as E_local shrinks).
#[derive(Clone, Copy, Debug)]
pub struct LaunchModel {
    pub fixed: f64,
    pub per_expert: f64,
    pub per_peer: f64,
}

impl LaunchModel {
    pub fn count(&self, e_total: usize, ranks: usize) -> usize {
        (self.fixed + self.per_expert * e_total as f64 + self.per_peer * ranks as f64)
            .round() as usize
    }
}

impl Baseline {
    pub fn name(&self) -> &'static str {
        match self {
            Baseline::MegatronCutlass => "Megatron-CUTLASS",
            Baseline::MegatronTe => "Megatron-TE",
            Baseline::DeepSpeed => "DeepSpeedMoE",
            Baseline::DeepEp => "Megatron+DeepEP",
            Baseline::FasterMoe => "FasterMoE",
            Baseline::Comet => "COMET",
        }
    }

    /// Calibrated against Table 1 (2 ranks, 64 total experts): Comet 33,
    /// Megatron-CUTLASS 85, Megatron-TE 261, DeepEP 432, DeepSpeed 550.
    pub fn launch_model(&self) -> LaunchModel {
        match self {
            Baseline::MegatronCutlass => LaunchModel { fixed: 13.0, per_expert: 1.0, per_peer: 4.0 },
            Baseline::MegatronTe => LaunchModel { fixed: 29.0, per_expert: 3.5, per_peer: 4.0 },
            Baseline::DeepSpeed => LaunchModel { fixed: 22.0, per_expert: 8.0, per_peer: 8.0 },
            Baseline::DeepEp => LaunchModel { fixed: 16.0, per_expert: 6.0, per_peer: 16.0 },
            Baseline::FasterMoe => LaunchModel { fixed: 9.0, per_expert: 2.0, per_peer: 6.0 },
            Baseline::Comet => LaunchModel { fixed: 23.0, per_expert: 0.125, per_peer: 1.0 },
        }
    }

    /// True for the chunked-overlap engines (FasterMoE, Comet).
    pub fn overlaps(&self) -> bool {
        matches!(self, Baseline::FasterMoe | Baseline::Comet)
    }

    /// Compute-inflation: extra elementwise/cast passes per expert GEMM
    /// (TE's many small ops; DeepSpeed's per-expert scatter kernels).
    pub fn compute_inflation(&self) -> f64 {
        match self {
            Baseline::MegatronTe => 1.5,
            Baseline::DeepSpeed => 1.3,
            Baseline::DeepEp => 1.1,
            Baseline::Comet => 1.4, // fine-grained fusion trades GEMM efficiency
            _ => 1.0,
        }
    }

    /// Does this system's collective run as SM kernels (NCCL) — counting
    /// as SM-active in Nsight's metric — or over DMA/proxy engines
    /// (cudaMemcpyPeerAsync, IBGDA) that leave SMs idle?
    pub fn comm_is_sm_active(&self) -> bool {
        matches!(self, Baseline::MegatronCutlass | Baseline::MegatronTe)
    }

    /// Per-chunk host synchronization multiplier for the overlap engines
    /// (FasterMoE's CPU-side smart scheduling blocks between chunks; Comet
    /// fuses more aggressively).
    pub fn chunk_sync_factor(&self) -> f64 {
        match self {
            Baseline::FasterMoe => 4.0,
            _ => 1.0,
        }
    }

    /// Concurrent compute streams for the overlap engines: FasterMoE runs
    /// one chunk kernel at a time; Comet's fine-grained fusion keeps
    /// several tiles in flight.
    pub fn streams(&self) -> usize {
        match self {
            Baseline::Comet => 2,
            _ => 1,
        }
    }

    /// Fraction of the launch-gap window in which *some* warp is resident
    /// (back-to-back tiny elementwise/cast kernels): Nsight's SM-active
    /// metric counts those as busy even though no useful GEMM runs.
    /// Megatron's dense stream of small ops reads as active; DeepSpeed /
    /// DeepEP's per-expert host-synced dispatch leaves genuinely empty
    /// gaps (the paper's Fig 5 trace).
    pub fn gap_residency(&self) -> f64 {
        match self {
            Baseline::MegatronTe => 0.5,
            Baseline::MegatronCutlass => 0.5,
            Baseline::DeepSpeed => 0.05,
            Baseline::DeepEp => 0.1,
            _ => 0.0,
        }
    }
}

/// Result of one simulated forward pass.
#[derive(Clone, Debug)]
pub struct SimReport {
    pub engine: &'static str,
    /// Forward latency (virtual seconds, max over ranks).
    pub latency: f64,
    /// Mean processor ("SM") utilization across ranks.
    pub utilization: f64,
    /// Kernel launches per rank.
    pub launches_per_rank: usize,
    /// Bytes moved across the fabric.
    pub bytes_on_wire: f64,
    /// Worst per-NIC ingress volume during the pass (MIV).
    pub max_incast: f64,
    /// True if MIV exceeded the NIC buffer (the Fig 17 failure mode).
    pub incast_overflow: bool,
}

/// Simulate one forward pass under the chosen engine.
pub fn simulate(cfg: &Config, wl: &[RankWorkload], engine: Engine, seed: u64) -> Result<SimReport> {
    anyhow::ensure!(wl.len() == cfg.system.ranks, "workload/rank mismatch");
    let rep = match engine {
        Engine::Flash => sim_flash(cfg, wl, seed),
        Engine::Baseline(b) => {
            // Paper desiderata (§4.1): every baseline runs FP16 while
            // FlashDMoE runs FP32 — reproduce the same handicap.
            let mut bcfg = cfg.clone();
            bcfg.cost.elem_bytes = bcfg.cost.elem_bytes.min(2.0);
            if b.overlaps() {
                sim_overlap(&bcfg, wl, b, seed)
            } else {
                sim_sequential(&bcfg, wl, b, seed)
            }
        }
    };
    Ok(rep)
}

struct Ctx {
    ranks: usize,
    e_local: usize,
    procs: usize,
    flops: f64,           // per-processor FLOP/s (dtype-adjusted)
    launch: f64,
    tile_bytes_row: f64,  // bytes of one token row on the wire
    ffn_tile_flops: f64,  // FLOPs of one (bM,H) fused FFN tile
    combine_tile_flops: f64,
    gate_secs: f64,       // gate kernel time (whole rank, all procs)
    capacity: usize,
    bm: usize,
}

impl Ctx {
    fn new(cfg: &Config) -> Self {
        let m = &cfg.model;
        let s = &cfg.system;
        let c = &cfg.cost;
        // fp16 doubles effective math throughput and halves payload bytes
        let dtype_speedup = 4.0 / c.elem_bytes;
        let flops = c.flops_per_processor * dtype_speedup;
        Self {
            ranks: s.ranks,
            e_local: cfg.local_experts(),
            procs: s.processors,
            flops,
            launch: c.launch_overhead,
            tile_bytes_row: m.h as f64 * c.elem_bytes,
            ffn_tile_flops: m.ffn_flops(m.bm),
            combine_tile_flops: 2.0 * m.bm as f64 * m.h as f64,
            gate_secs: m.gate_flops(s.s_rank) / (flops * s.processors as f64),
            // policy-aware: the padded-collective baselines ship whatever
            // slot region the routing policy implies (worst case under
            // `Dropless`), while the flash engine's payload-efficient
            // dispatch only ever pays for actual rows
            capacity: m.slot_capacity(s.s_rank),
            bm: m.bm,
        }
    }
}

fn links(cfg: &Config) -> LinkSet {
    LinkSet::new(
        cfg.cost.intra_bw,
        cfg.cost.intra_lat,
        cfg.cost.inter_bw,
        cfg.cost.inter_lat,
        cfg.system.ranks_per_node(),
    )
}

fn jitters(cfg: &Config, seed: u64, n: usize, scale: f64) -> Vec<f64> {
    let mut rng = Rng::new(seed ^ 0x1317);
    (0..n).map(|_| rng.lognormal(0.0, cfg.cost.jitter_sigma * scale)).collect()
}

/// Bulk-synchronous straggler tax on one barrier-delimited phase: the phase
/// completes when the *slowest* participant does, so it stretches by the
/// max of P lognormal jitters — growing with world size (§2.1 / Table 2).
/// Collectives jitter harder than plain kernels (3x the base sigma).
fn phase_tax(rng: &mut Rng, ranks: usize, sigma: f64) -> f64 {
    (0..ranks).map(|_| rng.lognormal(0.0, 3.0 * sigma)).fold(1.0, f64::max)
}

// ---------------------------------------------------------------------------
// FlashDMoE engine
// ---------------------------------------------------------------------------

fn sim_flash(cfg: &Config, wl: &[RankWorkload], seed: u64) -> SimReport {
    let x = Ctx::new(cfg);
    let mut link = links(cfg);
    let mut pools: Vec<ProcPool> = (0..x.ranks).map(|_| ProcPool::new(x.procs)).collect();
    // Mild per-rank jitter on the single kernel start: no barrier amplifies it.
    let jit = jitters(cfg, seed, x.ranks, 0.3);

    let mut bytes = 0.0;
    let mut finish = vec![0.0f64; x.ranks];
    // Gate runs in-kernel on each rank (one launch each, the only launch).
    let gate_done: Vec<f64> = (0..x.ranks).map(|r| x.launch + x.gate_secs * jit[r]).collect();
    for (r, g) in gate_done.iter().enumerate() {
        finish[r] = *g;
    }

    // Phase A: one-sided dispatch transfers (payload-efficient rows only).
    let mut arrivals: Vec<(f64, usize, usize, f64)> = Vec::new();
    for (src, w) in wl.iter().enumerate() {
        for t in &w.plan.tiles {
            let b = t.rows as f64 * x.tile_bytes_row;
            bytes += b;
            let arrive = link.transfer(src as u32, t.dst as u32, b, gate_done[src]);
            arrivals.push((arrive, src, t.dst as usize, b));
        }
    }
    // Phase B: FFN tile tasks start the moment their packet lands —
    // process in global arrival order (the subscriber decodes reactively).
    arrivals.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut ffn_done: Vec<(f64, usize, usize, f64)> = arrivals
        .into_iter()
        .map(|(arrive, src, dst, b)| {
            (pools[dst].run(arrive, x.ffn_tile_flops / x.flops), src, dst, b)
        })
        .collect();
    // Phase C: one-sided combine write-backs in completion order.
    ffn_done.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut backs: Vec<(f64, usize)> = ffn_done
        .into_iter()
        .map(|(done, src, dst, b)| {
            bytes += b;
            (link.transfer(dst as u32, src as u32, b, done), src)
        })
        .collect();
    // Phase D: combine tasks on the origin rank, in arrival order.
    backs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    for (back, src) in backs {
        let cmb_done = pools[src].run(back, x.combine_tile_flops / x.flops);
        finish[src] = finish[src].max(cmb_done);
    }
    let latency = finish.iter().copied().fold(0.0, f64::max);
    // Paper-style SM-active utilization: the persistent kernel keeps warps
    // resident on every SM from launch until its rank finishes, so a rank
    // is "active" for finish_r / makespan (stragglers shave the tail).
    let util = finish.iter().map(|f| f / latency).sum::<f64>() / x.ranks as f64;
    let _ = &pools; // busy accounting retained for the strict-efficiency view
    let miv = link.max_incast();
    SimReport {
        engine: "FlashDMoE",
        latency,
        utilization: util,
        launches_per_rank: 1,
        bytes_on_wire: bytes,
        max_incast: miv,
        incast_overflow: miv > cfg.cost.nic_buffer,
    }
}

// ---------------------------------------------------------------------------
// Bulk-synchronous baselines (Megatron-LM, DeepSpeed, DeepEP)
// ---------------------------------------------------------------------------

fn sim_sequential(cfg: &Config, wl: &[RankWorkload], b: Baseline, seed: u64) -> SimReport {
    let x = Ctx::new(cfg);
    let mut link = links(cfg);
    let jit = jitters(cfg, seed, x.ranks, 1.0);
    let lm = b.launch_model();
    let launches = lm.count(cfg.model.e, x.ranks);
    // apportion the launch budget over the five phases
    let phase_launch = launches as f64 / 5.0 * x.launch;
    let infl = b.compute_inflation();

    let mut bytes = 0.0;
    let mut busy = vec![0.0f64; x.ranks];
    let mut trng = Rng::new(seed ^ 0x7A57);
    let sigma = cfg.cost.jitter_sigma;

    // phase 1: gate, then a barrier (stragglers bite here)
    let t1 = (0..x.ranks)
        .map(|r| {
            busy[r] += x.gate_secs * x.procs as f64;
            phase_launch + x.gate_secs * jit[r]
        })
        .fold(0.0, f64::max)
        * phase_tax(&mut trng, x.ranks, sigma)
        + cfg.cost.barrier_cost;

    // phase 2: padded dispatch AllToAll (active experts ship full capacity)
    let mut t2 = t1;
    for (src, w) in wl.iter().enumerate() {
        let mut active = vec![false; cfg.model.e];
        for t in &w.plan.tiles {
            active[t.expert as usize] = true;
        }
        let start = t1 + phase_launch * jit[src];
        for (e, on) in active.iter().enumerate() {
            if !on {
                continue;
            }
            let dst = cfg.owner_of(e) as u32;
            let bsz = x.capacity as f64 * x.tile_bytes_row; // padded!
            bytes += bsz;
            t2 = t2.max(link.transfer(src as u32, dst, bsz, start));
        }
    }
    t2 = t1 + (t2 - t1) * phase_tax(&mut trng, x.ranks, sigma) + cfg.cost.barrier_cost;

    // phase 3: expert FFN over the full padded buffers (null rows computed)
    let padded_rows_per_expert = x.ranks * x.capacity;
    let t3 = (0..x.ranks)
        .map(|r| {
            let flops = x.e_local as f64
                * (padded_rows_per_expert as f64 / x.bm as f64)
                * x.ffn_tile_flops
                * infl;
            busy[r] += flops / x.flops;
            t2 + phase_launch + flops / (x.flops * x.procs as f64) * jit[r]
        })
        .fold(0.0, f64::max);
    let t3 = t2 + (t3 - t2) * phase_tax(&mut trng, x.ranks, sigma) + cfg.cost.barrier_cost;

    // phase 4: padded combine AllToAll back
    let mut t4 = t3;
    for (src, w) in wl.iter().enumerate() {
        let mut active = vec![false; cfg.model.e];
        for t in &w.plan.tiles {
            active[t.expert as usize] = true;
        }
        for (e, on) in active.iter().enumerate() {
            if !on {
                continue;
            }
            let owner = cfg.owner_of(e) as u32;
            let bsz = x.capacity as f64 * x.tile_bytes_row;
            bytes += bsz;
            let start = t3 + phase_launch * jit[owner as usize];
            t4 = t4.max(link.transfer(owner, src as u32, bsz, start));
        }
    }
    t4 = t3 + (t4 - t3) * phase_tax(&mut trng, x.ranks, sigma) + cfg.cost.barrier_cost;

    // phase 5: combine scale
    let latency = (0..x.ranks)
        .map(|r| {
            let flops =
                wl[r].plan.sent_rows as f64 / x.bm as f64 * x.combine_tile_flops;
            busy[r] += flops / x.flops;
            t4 + phase_launch + flops / (x.flops * x.procs as f64) * jit[r]
        })
        .fold(0.0, f64::max);

    // Paper-style SM-active utilization: SMs are active while a compute
    // kernel is resident (gate, FFN, scale) *and* during NCCL collectives
    // (NCCL send/recv run as SM kernels); launch gaps and barriers are
    // idle time.
    // SM-resident collective time for NCCL engines: the pure wire time of
    // this rank's padded a2a volume, both rounds (NCCL send/recv kernels
    // occupy SMs for exactly the transfer duration).
    let coll_time = if b.comm_is_sm_active() {
        let max_active = wl
            .iter()
            .map(|w| {
                let mut active = vec![false; cfg.model.e];
                for t in &w.plan.tiles {
                    active[t.expert as usize] = true;
                }
                active.iter().filter(|a| **a).count()
            })
            .max()
            .unwrap_or(0);
        2.0 * max_active as f64 * x.capacity as f64 * x.tile_bytes_row / cfg.cost.intra_bw
    } else {
        0.0
    };
    let gap_resident = launches as f64 * x.launch * b.gap_residency();
    let util = (0..x.ranks)
        .map(|r| {
            let active = x.gate_secs * jit[r]
                + coll_time
                + gap_resident
                + x.e_local as f64
                    * (padded_rows_per_expert as f64 / x.bm as f64)
                    * x.ffn_tile_flops
                    * infl
                    / (x.flops * x.procs as f64)
                + wl[r].plan.sent_rows as f64 / x.bm as f64 * x.combine_tile_flops
                    / (x.flops * x.procs as f64);
            (active / latency).min(1.0)
        })
        .sum::<f64>()
        / x.ranks as f64;
    let _ = &busy;
    let miv = link.max_incast();
    SimReport {
        engine: b.name(),
        latency,
        utilization: util,
        launches_per_rank: launches,
        bytes_on_wire: bytes,
        max_incast: miv,
        incast_overflow: miv > cfg.cost.nic_buffer,
    }
}

// ---------------------------------------------------------------------------
// Chunked-overlap baselines (FasterMoE, Comet)
// ---------------------------------------------------------------------------

fn sim_overlap(cfg: &Config, wl: &[RankWorkload], b: Baseline, seed: u64) -> SimReport {
    let x = Ctx::new(cfg);
    let mut link = links(cfg);
    // Chunk kernels serialize on each GPU's compute stream(s) (each kernel
    // uses the whole device): pool slots = streams, task duration =
    // flops / (per-SM flops × SM count ÷ streams).
    let streams = b.streams();
    let mut pools: Vec<ProcPool> = (0..x.ranks).map(|_| ProcPool::new(streams)).collect();
    let jit = jitters(cfg, seed, x.ranks, 1.0);
    let lm = b.launch_model();
    let launches = lm.count(cfg.model.e, x.ranks);
    // chunk-granular launch + host-sync cost between chunk kernels
    let chunk_launch = x.launch * b.chunk_sync_factor();
    let infl = b.compute_inflation();

    let mut bytes = 0.0;
    let mut finish = vec![0.0f64; x.ranks];
    let gate_done: Vec<f64> = (0..x.ranks)
        .map(|r| 3.0 * x.launch + x.gate_secs * jit[r])
        .collect();
    let t_gate = gate_done.iter().copied().fold(0.0, f64::max) + cfg.cost.barrier_cost;

    // chunk = one (src, expert) padded capacity slab; compute overlaps
    // arrival but pays a launch per chunk. Simulated in global event order
    // (arrivals, then completions) to avoid source-order bias.
    let mut arrivals: Vec<(f64, usize, usize)> = Vec::new();
    for (src, w) in wl.iter().enumerate() {
        let mut active = vec![false; cfg.model.e];
        for t in &w.plan.tiles {
            active[t.expert as usize] = true;
        }
        for (e, on) in active.iter().enumerate() {
            if !on {
                continue;
            }
            let dst = cfg.owner_of(e);
            let bsz = x.capacity as f64 * x.tile_bytes_row; // still padded
            bytes += bsz;
            arrivals.push((link.transfer(src as u32, dst as u32, bsz, t_gate), src, dst));
        }
    }
    arrivals.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut dones: Vec<(f64, usize, usize)> = arrivals
        .into_iter()
        .map(|(arrive, src, dst)| {
            // whole-chunk expert kernel (capacity rows incl. null padding)
            // on the destination's compute stream; streams share the device.
            // The launch/host-sync gap *occupies* the stream — that is the
            // Fig 5 idle-gap pathology.
            let flops = (x.capacity as f64 / x.bm as f64) * x.ffn_tile_flops * infl;
            let dur = flops / (x.flops * x.procs as f64 / streams as f64);
            (pools[dst].run_gapped(arrive, chunk_launch, dur), src, dst)
        })
        .collect();
    dones.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut backs: Vec<(f64, usize)> = dones
        .into_iter()
        .map(|(done, src, dst)| {
            let bsz = x.capacity as f64 * x.tile_bytes_row;
            bytes += bsz;
            (link.transfer(dst as u32, src as u32, bsz, done + chunk_launch), src)
        })
        .collect();
    backs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    for (back, src) in backs {
        let dur = x.combine_tile_flops / (x.flops * x.procs as f64);
        let cmb = pools[src].run_gapped(back, chunk_launch, dur);
        finish[src] = finish[src].max(cmb);
    }
    // operator-boundary sync (these systems still join phases at the end):
    // the slowest rank's chunk pipeline gates everyone (straggler tax)
    let mut trng = Rng::new(seed ^ 0x7A57);
    let raw = finish.iter().copied().fold(0.0, f64::max);
    let latency = t_gate
        + (raw - t_gate).max(0.0) * phase_tax(&mut trng, x.ranks, cfg.cost.jitter_sigma)
        + cfg.cost.barrier_cost;
    // Paper-style SM-active utilization: union of chunk-kernel residency
    // (gaps between chunk arrivals are idle SM time).
    let util = pools
        .iter()
        .enumerate()
        .map(|(r, p)| ((p.active_union() + x.gate_secs * jit[r]) / latency).min(1.0))
        .sum::<f64>()
        / x.ranks as f64;
    let miv = link.max_incast();
    SimReport {
        engine: b.name(),
        latency,
        utilization: util,
        launches_per_rank: launches,
        bytes_on_wire: bytes,
        max_incast: miv,
        incast_overflow: miv > cfg.cost.nic_buffer,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{cluster_workload, Skew};

    fn run(engine: Engine, preset: &str) -> SimReport {
        let cfg = Config::preset(preset).unwrap();
        let wl = cluster_workload(&cfg, Skew::Uniform, 42);
        simulate(&cfg, &wl, engine, 7).unwrap()
    }

    #[test]
    fn flash_beats_sequential_latency() {
        let flash = run(Engine::Flash, "default");
        let seq = run(Engine::Baseline(Baseline::MegatronCutlass), "default");
        assert!(
            flash.latency < seq.latency,
            "flash {} vs megatron {}",
            flash.latency,
            seq.latency
        );
    }

    #[test]
    fn flash_has_one_launch_and_higher_utilization() {
        let flash = run(Engine::Flash, "default");
        assert_eq!(flash.launches_per_rank, 1);
        for b in [Baseline::MegatronCutlass, Baseline::FasterMoe, Baseline::DeepSpeed] {
            let r = run(Engine::Baseline(b), "default");
            assert!(r.launches_per_rank > 10, "{}: {}", r.engine, r.launches_per_rank);
            assert!(
                flash.utilization > r.utilization,
                "flash {} <= {} {}",
                flash.utilization,
                r.engine,
                r.utilization
            );
        }
    }

    #[test]
    fn table1_launch_counts_match_paper() {
        // Table 1 config: 2 ranks, 32 local experts
        let expect = [
            (Baseline::Comet, 33),
            (Baseline::MegatronCutlass, 85),
            (Baseline::MegatronTe, 261),
            (Baseline::DeepEp, 432),
            (Baseline::DeepSpeed, 550),
        ];
        for (b, want) in expect {
            let got = b.launch_model().count(64, 2);
            let tol = (want as f64 * 0.1) as usize; // within 10% of the paper
            assert!(
                got.abs_diff(want) <= tol,
                "{}: modeled {got}, paper {want}",
                b.name()
            );
        }
    }

    #[test]
    fn payload_efficiency_shows_on_wire() {
        let flash = run(Engine::Flash, "default");
        let seq = run(Engine::Baseline(Baseline::MegatronCutlass), "default");
        assert!(
            flash.bytes_on_wire <= seq.bytes_on_wire,
            "flash ships less: {} vs {}",
            flash.bytes_on_wire,
            seq.bytes_on_wire
        );
    }

    #[test]
    fn multinode_incast_is_tracked() {
        let cfg = Config::preset("paper_multinode").unwrap();
        let wl = cluster_workload(&cfg, Skew::Uniform, 1);
        let rep = simulate(&cfg, &wl, Engine::Flash, 1).unwrap();
        assert!(rep.max_incast > 0.0, "multinode must hit NICs");
    }

    #[test]
    fn simulation_is_deterministic() {
        let a = run(Engine::Flash, "tiny");
        let b = run(Engine::Flash, "tiny");
        assert_eq!(a.latency, b.latency);
        assert_eq!(a.bytes_on_wire, b.bytes_on_wire);
    }
}
