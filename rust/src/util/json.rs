//! Minimal JSON reader/writer (serde is unavailable offline).
//!
//! Covers exactly what this crate needs: parsing `artifacts/manifest.json`
//! (objects, arrays, strings, numbers, bools, null) and emitting metric /
//! experiment dumps. Not a general-purpose JSON library — but it is a
//! complete, recursive-descent parser for the JSON grammar.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing bytes at offset {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (looking up '{key}')"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a non-negative integer: {n}");
        }
        Ok(n as usize)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    /// `[1,2,3]` -> Vec<usize>, the manifest's shape encoding.
    pub fn as_shape(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at {}, found '{}'", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            m.insert(k, self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // surrogate pairs unsupported (not present in our data)
                            s.push(char::from_u32(cp).ok_or_else(|| anyhow!("bad \\u"))?);
                        }
                        _ => bail!("bad escape at {}", self.i),
                    }
                }
                _ => {
                    // collect the full UTF-8 sequence
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

// ---------------------------------------------------------------------------
// Writer: a small builder for emitting metric / experiment JSON dumps.
// ---------------------------------------------------------------------------

/// Incremental JSON writer with pretty-printing.
pub struct JsonWriter {
    out: String,
}

impl Default for JsonWriter {
    fn default() -> Self {
        Self::new()
    }
}

impl JsonWriter {
    pub fn new() -> Self {
        Self { out: String::new() }
    }

    pub fn write(&mut self, v: &Json) -> &str {
        self.emit(v, 0);
        &self.out
    }

    fn emit(&mut self, v: &Json, indent: usize) {
        match v {
            Json::Null => self.out.push_str("null"),
            Json::Bool(b) => self.out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(self.out, "{}", *n as i64);
                } else {
                    let _ = write!(self.out, "{n}");
                }
            }
            Json::Str(s) => {
                self.out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => self.out.push_str("\\\""),
                        '\\' => self.out.push_str("\\\\"),
                        '\n' => self.out.push_str("\\n"),
                        '\t' => self.out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(self.out, "\\u{:04x}", c as u32);
                        }
                        c => self.out.push(c),
                    }
                }
                self.out.push('"');
            }
            Json::Arr(items) => {
                self.out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        self.out.push_str(", ");
                    }
                    self.emit(item, indent);
                }
                self.out.push(']');
            }
            Json::Obj(m) => {
                self.out.push_str("{\n");
                let pad = "  ".repeat(indent + 1);
                for (i, (k, val)) in m.iter().enumerate() {
                    if i > 0 {
                        self.out.push_str(",\n");
                    }
                    let _ = write!(self.out, "{pad}\"{k}\": ");
                    self.emit(val, indent + 1);
                }
                self.out.push('\n');
                self.out.push_str(&"  ".repeat(indent));
                self.out.push('}');
            }
        }
    }
}

/// Convenience: serialize a value to a pretty string.
pub fn to_string(v: &Json) -> String {
    let mut w = JsonWriter::new();
    w.write(v).to_string()
}

/// Convenience constructors for building objects in test/metric code.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let text = r#"{"format": 1, "presets": {"tiny": {"config": {"h": 64, "cf": 1.0},
            "artifacts": {"gate": {"file": "tiny_gate.hlo.txt", "inputs": [["a", [128, 64]]]}}}}}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("format").unwrap().as_usize().unwrap(), 1);
        let gate = v
            .get("presets").unwrap()
            .get("tiny").unwrap()
            .get("artifacts").unwrap()
            .get("gate").unwrap();
        assert_eq!(gate.get("file").unwrap().as_str().unwrap(), "tiny_gate.hlo.txt");
        let shape = gate.get("inputs").unwrap().as_arr().unwrap()[0].as_arr().unwrap()[1]
            .as_shape()
            .unwrap();
        assert_eq!(shape, vec![128, 64]);
    }

    #[test]
    fn parse_escapes_and_negatives() {
        let v = Json::parse(r#"{"s": "a\nb\"c", "n": -2.5e2, "b": [true, false, null]}"#).unwrap();
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "a\nb\"c");
        assert_eq!(v.get("n").unwrap().as_f64().unwrap(), -250.0);
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn writer_roundtrips() {
        let v = obj(vec![
            ("name", s("fig10")),
            ("rows", Json::Arr(vec![num(1.0), num(2.5)])),
            ("ok", Json::Bool(true)),
        ]);
        let text = to_string(&v);
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{oops}").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn parses_unicode() {
        let v = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café ☕");
    }
}
