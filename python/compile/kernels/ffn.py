"""L1 Pallas kernels: expert FFN GEMMs with fused epilogues.

These are the paper's Processor compute tasks (§3.1):

  t1 = (M, ·, relu):   C1 <- relu(A @ W1 + b1)      — ``gemm0``
  t2 = (M, ·, id):     C2 <- C1 @ W2 + b2           — ``gemm1``
  fused FFN block:     C  <- relu(A@W1+b1)@W2 + b2  — ``ffn_block``

Tiling follows the paper's (bM, bN) = (128, 64) task granularity: ``gemm0``
and ``gemm1`` produce one (bM, bN) output tile per grid step with the full
K dimension VMEM-resident (K = H or D; at the default config a tile's VMEM
footprint is (bM*K + K*bN + bM*bN) * 4B — see DESIGN.md §9). ``ffn_block``
is the fused per-tile task used by the coordinator's ``fused`` task-graph
mode: one grid step per (bM, H) token tile, both weight matrices resident.

Epilogues (activation, bias add) are applied to the accumulator registers
before the single write-back — this is exactly the paper's fused-task
formulation F_t(A,B,C,D) = phi(A*B + D).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gemm_epilogue_kernel(x_ref, w_ref, b_ref, out_ref, *, activation: str):
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc = acc + b_ref[...]
    if activation == "relu":
        acc = jnp.maximum(acc, 0.0)
    out_ref[...] = acc


def _tiled_gemm(x, w, b, bm: int, bn: int, activation: str):
    m, kdim = x.shape
    k2, n = w.shape
    assert kdim == k2, f"K mismatch {kdim} vs {k2}"
    assert m % bm == 0 and n % bn == 0, f"({m},{n}) not tileable by ({bm},{bn})"
    kernel = functools.partial(_gemm_epilogue_kernel, activation=activation)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, kdim), lambda i, j: (i, 0)),
            pl.BlockSpec((kdim, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), w.astype(jnp.float32), b.reshape(1, -1).astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def gemm0(x: jax.Array, w1: jax.Array, b1: jax.Array, bm: int = 128, bn: int = 64):
    """Task t1: relu(x @ W1 + b1). x: (M, H), W1: (H, D) -> (M, D)."""
    return _tiled_gemm(x, w1, b1, bm, bn, "relu")


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def gemm1(h: jax.Array, w2: jax.Array, b2: jax.Array, bm: int = 128, bn: int = 64):
    """Task t2: h @ W2 + b2. h: (M, D), W2: (D, H) -> (M, H)."""
    return _tiled_gemm(h, w2, b2, bm, bn, "identity")


def _ffn_block_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, out_ref):
    h = jnp.dot(x_ref[...], w1_ref[...], preferred_element_type=jnp.float32)
    h = jnp.maximum(h + b1_ref[...], 0.0)
    y = jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32)
    out_ref[...] = y + b2_ref[...]


@functools.partial(jax.jit, static_argnames=("bm",))
def ffn_block(x, w1, b1, w2, b2, bm: int = 128):
    """Fused per-tile FFN: relu(x@W1+b1)@W2+b2 over (bm, H) token tiles.

    x: (M, H); W1: (H, D); W2: (D, H). M must be a multiple of bm. The
    intermediate (bm, D) activation never leaves VMEM — the two MXU matmuls
    and both epilogues fuse into one task, which is the coordinator's
    ``fused`` task-graph mode unit of work.
    """
    m, hdim = x.shape
    _, d = w1.shape
    assert m % bm == 0, f"M={m} not a multiple of bm={bm}"
    return pl.pallas_call(
        _ffn_block_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, hdim), lambda i: (i, 0)),
            pl.BlockSpec((hdim, d), lambda i: (0, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((d, hdim), lambda i: (0, 0)),
            pl.BlockSpec((1, hdim), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, hdim), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, hdim), jnp.float32),
        interpret=True,
    )(
        x.astype(jnp.float32),
        w1.astype(jnp.float32),
        b1.reshape(1, -1).astype(jnp.float32),
        w2.astype(jnp.float32),
        b2.reshape(1, -1).astype(jnp.float32),
    )
