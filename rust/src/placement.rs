//! Dynamic expert placement: who serves which expert, and the EWMA
//! load tracker + planner that decide it (ROADMAP item 2, grounded in
//! "Fast MoE Inference via Predictive Prefetching and Expert
//! Replication", PAPERS.md).
//!
//! The static mapping — expert `e` lives on rank `e / e_local`, slot
//! `e % e_local` — is the [`Placement`] every engine starts with. A
//! [`ReplicationPolicy`](crate::config::ReplicationPolicy) additionally
//! reserves `replica_slots` expert slots per rank (heap regions, signal
//! flags and announcement lanes sized at engine start, exactly like owned
//! slots), and the planner may *bind* such a slot to a hot foreign expert
//! between passes — after which the gate's dispatch plan shards that
//! expert's tokens across its serving locations (see
//! [`dispatch_plan`](crate::gate::dispatch_plan)).
//!
//! Determinism: every decision here is a pure function of the observed
//! pass metrics and the policy (ties broken by id), so two engines fed
//! the same pass sequence install identical replicas — which is what lets
//! the replication conformance tests demand bitwise-identical outputs
//! across restarts.
//!
//! Multi-model residency (`max_models > 1`): each resident model owns its
//! **own** `Placement` and EWMA tracker — the registry entry carries them
//! (see [`crate::registry::ModelEntry`]) — because a hot expert in one
//! model says nothing about another's load. Slot indices here stay
//! model-relative (`0..e_local+replica_slots`); the rank actors shift a
//! pass's dispatch plan by the model's heap band base, so this module
//! never needs to know which band a model occupies.

use anyhow::{bail, Result};

use crate::config::{Config, ReplicationPolicy};

/// The expert→locations map consulted by the gate (`dispatch_plan`), the
/// rank actors (announce / dispatch / combine / execute) and the
/// bulk-synchronous baseline.
///
/// Slot addressing on a rank: slots `0..e_local` are the rank's *owned*
/// experts (`slot s` ⇒ global expert `rank·e_local + s`, immutable);
/// slots `e_local..e_local+replica_slots` are *replica* slots, unbound
/// until the planner installs an expert into one. Each expert's location
/// list starts with its primary owner and appends replicas in install
/// order — the order the gate's splitter shards by, so it is part of the
/// determinism contract.
#[derive(Clone, Debug, PartialEq)]
pub struct Placement {
    e: usize,
    ranks: usize,
    e_local: usize,
    replica_slots: usize,
    /// Serving locations per expert: `(rank, slot)`, primary first.
    locations: Vec<Vec<(u32, u32)>>,
    /// Per (rank, replica slot) bound global expert.
    bound: Vec<Option<u32>>,
    /// Ranks marked permanently failed by [`fail_rank`](Self::fail_rank):
    /// they serve no locations and the planner never targets them.
    failed: Vec<bool>,
    /// Bumped on every mutation; pass metrics stamp it for telemetry.
    version: u64,
}

impl Placement {
    /// The static block placement: expert `e` on rank `e / e_local`, no
    /// replicas installed, `replica_slots` spare slots per rank.
    pub fn balanced(e: usize, ranks: usize, replica_slots: usize) -> Self {
        assert!(ranks >= 1 && e % ranks == 0, "E={e} must divide over {ranks} ranks");
        let e_local = e / ranks;
        let locations = (0..e)
            .map(|ex| vec![((ex / e_local) as u32, (ex % e_local) as u32)])
            .collect();
        Self {
            e,
            ranks,
            e_local,
            replica_slots,
            locations,
            bound: vec![None; ranks * replica_slots],
            failed: vec![false; ranks],
            version: 0,
        }
    }

    /// Static placement for a config, with the policy's replica slots.
    pub fn from_config(cfg: &Config) -> Self {
        Self::balanced(cfg.model.e, cfg.system.ranks, cfg.replica_slots())
    }

    pub fn num_experts(&self) -> usize {
        self.e
    }

    pub fn ranks(&self) -> usize {
        self.ranks
    }

    /// Owned expert slots per rank (excludes replica slots).
    pub fn e_local(&self) -> usize {
        self.e_local
    }

    /// Total addressable expert slots per rank: owned + replica. This is
    /// the `E` dimension of the symmetric heap layout under replication.
    pub fn e_slots(&self) -> usize {
        self.e_local + self.replica_slots
    }

    pub fn replica_slots(&self) -> usize {
        self.replica_slots
    }

    /// Primary owner of `expert` (the static `Config::owner_of`).
    pub fn owner_of(&self, expert: usize) -> usize {
        expert / self.e_local
    }

    /// Serving locations of `expert`: primary first, replicas in install
    /// order. Never empty under healthy operation; empty exactly for an
    /// expert whose primary rank [failed](Self::fail_rank) with no
    /// surviving replica — such an expert is *unavailable* and the gate
    /// accounts its rows instead of dispatching them.
    pub fn locations(&self, expert: usize) -> &[(u32, u32)] {
        &self.locations[expert]
    }

    /// Global expert served from `slot` on `rank`: owned slots always
    /// resolve; replica slots resolve only while bound.
    pub fn expert_on(&self, rank: usize, slot: usize) -> Option<usize> {
        if slot < self.e_local {
            return Some(rank * self.e_local + slot);
        }
        let j = slot - self.e_local;
        if j >= self.replica_slots {
            return None;
        }
        self.bound[rank * self.replica_slots + j].map(|e| e as usize)
    }

    /// Slot serving `expert` on `rank`, if any.
    pub fn slot_on(&self, rank: usize, expert: usize) -> Option<usize> {
        self.locations[expert]
            .iter()
            .find(|(r, _)| *r as usize == rank)
            .map(|(_, s)| *s as usize)
    }

    /// True iff any expert currently has more than one serving location.
    pub fn has_replicas(&self) -> bool {
        self.locations.iter().any(|l| l.len() > 1)
    }

    /// Experts with more than one serving location, ascending.
    pub fn replicated_experts(&self) -> Vec<usize> {
        (0..self.e).filter(|&ex| self.locations[ex].len() > 1).collect()
    }

    /// Mutation counter (0 for a fresh static placement).
    pub fn version(&self) -> u64 {
        self.version
    }

    /// True when both placements serve every expert from the same
    /// location list (version aside).
    pub fn same_locations(&self, other: &Placement) -> bool {
        self.locations == other.locations
    }

    /// Bind a replica of `expert` into the lowest free replica slot of
    /// `rank`. Errors if the rank already serves the expert or has no
    /// free slot. Returns the destination-local slot index.
    pub fn add_replica(&mut self, expert: usize, rank: usize) -> Result<u32> {
        if expert >= self.e || rank >= self.ranks {
            bail!("replica target out of range: expert {expert}, rank {rank}");
        }
        if self.failed[rank] {
            bail!("rank {rank} is marked failed; it cannot host replicas");
        }
        if self.slot_on(rank, expert).is_some() {
            bail!("rank {rank} already serves expert {expert}");
        }
        let base = rank * self.replica_slots;
        let Some(j) = (0..self.replica_slots).find(|&j| self.bound[base + j].is_none()) else {
            bail!("rank {rank} has no free replica slot (of {})", self.replica_slots);
        };
        self.bound[base + j] = Some(expert as u32);
        let slot = (self.e_local + j) as u32;
        self.locations[expert].push((rank as u32, slot));
        self.version += 1;
        Ok(slot)
    }

    /// Unbind the replica of `expert` on `rank` (primary locations are
    /// immutable). Returns true if a replica was removed.
    pub fn remove_replica(&mut self, expert: usize, rank: usize) -> bool {
        let locs = &mut self.locations[expert];
        let Some(i) = locs[1..]
            .iter()
            .position(|(r, _)| *r as usize == rank)
            .map(|i| i + 1)
        else {
            return false;
        };
        let (_, slot) = locs.remove(i);
        let j = slot as usize - self.e_local;
        self.bound[rank * self.replica_slots + j] = None;
        self.version += 1;
        true
    }

    /// Remove every replica of `expert`.
    pub fn drop_replicas(&mut self, expert: usize) {
        while self.locations[expert].len() > 1 {
            let (rank, _) = self.locations[expert][1];
            self.remove_replica(expert, rank as usize);
        }
    }

    /// Mark `rank` permanently failed: every location it serves (primary
    /// and replica) is removed, its replica-slot bindings are released,
    /// and the planner will never target it again. Idempotent. Returns
    /// the experts left with **no** serving location — the degraded
    /// capacity the caller must account for (the engine surfaces it as
    /// `PassMetrics::experts_unavailable`).
    ///
    /// This is the epoch-fenced half of failure handling: the engine only
    /// installs the degraded placement between passes, exactly like a
    /// replication rebalance.
    pub fn fail_rank(&mut self, rank: usize) -> Vec<usize> {
        if rank < self.ranks && !self.failed[rank] {
            self.failed[rank] = true;
            for locs in &mut self.locations {
                locs.retain(|(r, _)| *r as usize != rank);
            }
            for j in 0..self.replica_slots {
                self.bound[rank * self.replica_slots + j] = None;
            }
            self.version += 1;
        }
        self.unavailable_experts()
    }

    /// Has `rank` been marked permanently failed?
    pub fn is_failed(&self, rank: usize) -> bool {
        self.failed.get(rank).copied().unwrap_or(false)
    }

    /// True iff any rank has been marked failed (the placement routes
    /// around at least one corpse).
    pub fn degraded(&self) -> bool {
        self.failed.iter().any(|&f| f)
    }

    /// Ranks marked failed, ascending.
    pub fn failed_ranks(&self) -> Vec<usize> {
        (0..self.ranks).filter(|&r| self.failed[r]).collect()
    }

    /// Experts with no serving location at all (primary dead, no replica
    /// survived), ascending. Empty under healthy operation.
    pub fn unavailable_experts(&self) -> Vec<usize> {
        (0..self.e).filter(|&ex| self.locations[ex].is_empty()).collect()
    }

    /// Predicted load share landing on `rank` under this placement, given
    /// per-expert EWMA loads: each expert's load divides evenly over its
    /// serving locations (which is exactly what the `j % R` splitter
    /// does).
    pub fn rank_load(&self, expert_ewma: &[f64], rank: usize) -> f64 {
        let mut acc = 0.0;
        for (ex, locs) in self.locations.iter().enumerate() {
            if locs.iter().any(|(r, _)| *r as usize == rank) {
                acc += expert_ewma.get(ex).copied().unwrap_or(0.0) / locs.len() as f64;
            }
        }
        acc
    }
}

// ---------------------------------------------------------------------------
// EWMA load tracking
// ---------------------------------------------------------------------------

/// Exponentially-weighted moving averages of per-expert *offered* load
/// (rows/pass, pre capacity clamp) and per-rank busy time, fed one
/// [`PassMetrics`](crate::coordinator::PassMetrics) observation at a
/// time. The first observation seeds the averages directly so a cold
/// tracker converges in one pass.
#[derive(Clone, Debug)]
pub struct LoadTracker {
    alpha: f64,
    expert: Vec<f64>,
    rank_busy: Vec<f64>,
    passes: u64,
}

impl LoadTracker {
    pub fn new(e: usize, ranks: usize, alpha: f64) -> Self {
        let alpha = if alpha.is_finite() { alpha.clamp(1e-3, 1.0) } else { 0.3 };
        Self { alpha, expert: vec![0.0; e], rank_busy: vec![0.0; ranks], passes: 0 }
    }

    /// Fold one pass's per-expert offered loads and per-rank busy seconds
    /// into the averages.
    pub fn observe(&mut self, offered: &[u64], busy_secs: &[f64]) {
        debug_assert_eq!(offered.len(), self.expert.len());
        let a = if self.passes == 0 { 1.0 } else { self.alpha };
        for (ew, &x) in self.expert.iter_mut().zip(offered) {
            *ew = a * x as f64 + (1.0 - a) * *ew;
        }
        for (rb, &x) in self.rank_busy.iter_mut().zip(busy_secs) {
            *rb = a * x + (1.0 - a) * *rb;
        }
        self.passes += 1;
    }

    /// EWMA offered load per expert (rows/pass).
    pub fn expert_load(&self) -> &[f64] {
        &self.expert
    }

    /// EWMA busy seconds per rank.
    pub fn rank_busy(&self) -> &[f64] {
        &self.rank_busy
    }

    pub fn mean_load(&self) -> f64 {
        if self.expert.is_empty() {
            return 0.0;
        }
        self.expert.iter().sum::<f64>() / self.expert.len() as f64
    }

    pub fn passes(&self) -> u64 {
        self.passes
    }
}

// ---------------------------------------------------------------------------
// the planner
// ---------------------------------------------------------------------------

/// Compute the desired placement for the next pass: keep justified
/// replicas, tear down stale ones, and replicate the top-R hottest
/// experts onto the most underloaded ranks.
///
/// Thresholds form a hysteresis band: an expert *enters* replication at
/// `EWMA ≥ hysteresis × mean` and *exits* only below `hysteresis/2 ×
/// mean`, so borderline experts don't flap a replica in and out every
/// pass. Target ranks are chosen by ascending predicted load
/// ([`Placement::rank_load`]) with ties to the lower rank id — fully
/// deterministic given the same observation stream.
pub fn plan_replication(
    policy: &ReplicationPolicy,
    tracker: &LoadTracker,
    current: &Placement,
) -> Placement {
    let mut next = current.clone();
    if !policy.enabled() || tracker.passes() == 0 {
        return next;
    }
    let ewma = tracker.expert_load();
    let mean = tracker.mean_load();
    if mean <= 0.0 {
        return next;
    }
    let enter = policy.hysteresis * mean;
    let exit = 0.5 * policy.hysteresis * mean;

    // hottest eligible experts: load >= enter threshold, top_r of them
    let mut hot: Vec<usize> = (0..next.num_experts()).filter(|&ex| ewma[ex] >= enter).collect();
    hot.sort_by(|&a, &b| ewma[b].total_cmp(&ewma[a]).then(a.cmp(&b)));
    hot.truncate(policy.top_r);

    // tear down replicas that no longer pay for themselves
    for ex in next.replicated_experts() {
        if !hot.contains(&ex) && ewma[ex] < exit {
            next.drop_replicas(ex);
        }
    }

    // grow hot experts toward the target copy count, most-loaded first
    let target = policy.replicas.min(next.ranks()).max(1);
    for &ex in &hot {
        while next.locations(ex).len() < target {
            let candidate = (0..next.ranks())
                .filter(|&r| !next.is_failed(r))
                .filter(|&r| next.slot_on(r, ex).is_none())
                .filter(|&r| {
                    // a free replica slot must exist on the candidate
                    (next.e_local()..next.e_slots())
                        .any(|s| next.expert_on(r, s).is_none())
                })
                .min_by(|&a, &b| {
                    next.rank_load(ewma, a)
                        .total_cmp(&next.rank_load(ewma, b))
                        .then(a.cmp(&b))
                });
            let Some(rank) = candidate else { break };
            if next.add_replica(ex, rank).is_err() {
                break;
            }
        }
    }
    next
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_placement_matches_block_ownership() {
        let p = Placement::balanced(8, 4, 0);
        assert_eq!(p.e_local(), 2);
        assert_eq!(p.e_slots(), 2);
        for ex in 0..8 {
            assert_eq!(p.owner_of(ex), ex / 2);
            assert_eq!(p.locations(ex), &[((ex / 2) as u32, (ex % 2) as u32)]);
        }
        for r in 0..4 {
            for s in 0..2 {
                assert_eq!(p.expert_on(r, s), Some(r * 2 + s));
            }
            assert_eq!(p.expert_on(r, 2), None, "no replica slots configured");
        }
        assert!(!p.has_replicas());
    }

    #[test]
    fn replicas_bind_resolve_and_unbind() {
        let mut p = Placement::balanced(8, 4, 2);
        assert_eq!(p.e_slots(), 4);
        let v0 = p.version();
        let slot = p.add_replica(0, 3).unwrap();
        assert_eq!(slot, 2, "lowest free replica slot");
        assert!(p.version() > v0);
        assert_eq!(p.expert_on(3, 2), Some(0));
        assert_eq!(p.slot_on(3, 0), Some(2));
        assert_eq!(p.locations(0), &[(0, 0), (3, 2)]);
        assert!(p.has_replicas());
        assert_eq!(p.replicated_experts(), vec![0]);
        // second replica on the same rank takes the next slot
        let s2 = p.add_replica(5, 3).unwrap();
        assert_eq!(s2, 3);
        // duplicates and exhaustion refuse loudly
        assert!(p.add_replica(0, 3).is_err(), "rank already serves expert 0");
        assert!(p.add_replica(0, 0).is_err(), "owner already serves expert 0");
        assert!(p.add_replica(1, 3).is_err(), "no free slot left on rank 3");
        assert!(p.remove_replica(0, 3));
        assert!(!p.remove_replica(0, 3), "already removed");
        assert_eq!(p.expert_on(3, 2), None);
        // freed slot is reusable
        assert_eq!(p.add_replica(1, 3).unwrap(), 2);
    }

    #[test]
    fn rank_load_splits_over_locations() {
        let mut p = Placement::balanced(4, 2, 1);
        let ewma = vec![10.0, 2.0, 1.0, 1.0];
        assert_eq!(p.rank_load(&ewma, 0), 12.0);
        assert_eq!(p.rank_load(&ewma, 1), 2.0);
        p.add_replica(0, 1).unwrap();
        assert_eq!(p.rank_load(&ewma, 0), 7.0, "hot expert halves over 2 copies");
        assert_eq!(p.rank_load(&ewma, 1), 7.0);
    }

    #[test]
    fn tracker_seeds_then_smooths() {
        let mut t = LoadTracker::new(2, 1, 0.5);
        t.observe(&[10, 0], &[1.0]);
        assert_eq!(t.expert_load(), &[10.0, 0.0], "first observation seeds");
        t.observe(&[0, 10], &[2.0]);
        assert_eq!(t.expert_load(), &[5.0, 5.0]);
        assert_eq!(t.rank_busy(), &[1.5]);
        assert_eq!(t.mean_load(), 5.0);
        assert_eq!(t.passes(), 2);
    }

    #[test]
    fn planner_replicates_hot_and_tears_down_cold() {
        let policy = ReplicationPolicy {
            top_r: 1,
            replicas: 2,
            hysteresis: 1.5,
            ewma_alpha: 1.0,
        };
        let mut tracker = LoadTracker::new(4, 2, policy.ewma_alpha);
        let p0 = Placement::balanced(4, 2, 1);
        // skewed: expert 0 takes most offered load
        tracker.observe(&[90, 2, 4, 4], &[0.9, 0.1]);
        let p1 = plan_replication(&policy, &tracker, &p0);
        assert_eq!(p1.locations(0).len(), 2, "hot expert replicated");
        let (rank, slot) = p1.locations(0)[1];
        assert_eq!(rank, 1, "replica lands on the underloaded rank");
        assert_eq!(slot as usize, p1.e_local());
        // planner is deterministic and stable under unchanged load
        let p1b = plan_replication(&policy, &tracker, &p0);
        assert!(p1.same_locations(&p1b));
        let p2 = plan_replication(&policy, &tracker, &p1);
        assert!(p2.same_locations(&p1), "no churn when load is steady");
        // load flattens far below the exit threshold -> replica removed
        for _ in 0..3 {
            tracker.observe(&[25, 25, 25, 25], &[0.5, 0.5]);
        }
        let p3 = plan_replication(&policy, &tracker, &p1);
        assert!(!p3.has_replicas(), "cold expert torn down");
        // disabled policy never mutates
        let off = ReplicationPolicy::default();
        assert!(!off.enabled());
        let p4 = plan_replication(&off, &tracker, &p1);
        assert!(p4.same_locations(&p1));
    }

    #[test]
    fn fail_rank_evicts_locations_and_reports_unavailable() {
        let mut p = Placement::balanced(8, 4, 1);
        // replicate expert 4 (owned by rank 2) onto rank 0, so rank 2's
        // death leaves expert 4 served and expert 5 orphaned
        p.add_replica(4, 0).unwrap();
        let v0 = p.version();
        assert!(!p.degraded());
        let lost = p.fail_rank(2);
        assert_eq!(lost, vec![5], "expert 5 had no replica");
        assert!(p.is_failed(2) && p.degraded());
        assert_eq!(p.failed_ranks(), vec![2]);
        assert!(p.version() > v0);
        assert_eq!(p.locations(4), &[(0, 2)], "replica survives as sole location");
        assert!(p.locations(5).is_empty(), "orphaned expert serves nowhere");
        assert_eq!(p.slot_on(2, 4), None);
        assert_eq!(p.unavailable_experts(), vec![5]);
        // idempotent: same report, no version churn
        let v1 = p.version();
        assert_eq!(p.fail_rank(2), vec![5]);
        assert_eq!(p.version(), v1);
        // a failed rank refuses new replicas
        assert!(p.add_replica(0, 2).is_err());
        // surviving ranks still accept them (revives the orphan)
        p.add_replica(5, 1).unwrap();
        assert!(p.unavailable_experts().is_empty());
    }

    #[test]
    fn fail_rank_releases_replica_bindings() {
        let mut p = Placement::balanced(4, 2, 1);
        // rank 1 hosts a replica of expert 0; rank 1 then dies
        p.add_replica(0, 1).unwrap();
        assert_eq!(p.locations(0).len(), 2);
        let lost = p.fail_rank(1);
        assert_eq!(lost, vec![2, 3], "rank 1's owned experts orphan");
        assert_eq!(p.locations(0), &[(0, 0)], "replica on the corpse is gone");
        assert_eq!(p.expert_on(1, 2), None, "binding released");
    }

    #[test]
    fn planner_never_targets_failed_ranks() {
        let policy = ReplicationPolicy {
            top_r: 1,
            replicas: 3,
            hysteresis: 1.5,
            ewma_alpha: 1.0,
        };
        let mut tracker = LoadTracker::new(4, 2, 1.0);
        tracker.observe(&[90, 2, 4, 4], &[0.9, 0.1]);
        // kill the least-loaded rank: without the filter the planner
        // would pick it as the first replica target
        let mut p0 = Placement::balanced(4, 2, 1);
        p0.fail_rank(1);
        let p1 = plan_replication(&policy, &tracker, &p0);
        assert!(
            !p1.locations(0).iter().any(|(r, _)| *r == 1),
            "no replica may land on the failed rank: {:?}",
            p1.locations(0)
        );
        assert!(p1.is_failed(1), "failure state survives planning");
    }

    #[test]
    fn planner_respects_hysteresis_band() {
        let policy = ReplicationPolicy {
            top_r: 1,
            replicas: 2,
            hysteresis: 1.5,
            ewma_alpha: 1.0,
        };
        let mut tracker = LoadTracker::new(4, 2, 1.0);
        // expert 0 hot: mean 25, enter = 37.5
        tracker.observe(&[70, 10, 10, 10], &[0.0, 0.0]);
        let p1 = plan_replication(&policy, &tracker, &Placement::balanced(4, 2, 1));
        assert!(p1.has_replicas());
        // cooled into the band (exit = 18.75 < 30 < 37.5): replica stays
        tracker.observe(&[30, 23, 23, 24], &[0.0, 0.0]);
        let p2 = plan_replication(&policy, &tracker, &p1);
        assert!(p2.has_replicas(), "inside the band: no teardown");
        // fully cold (below exit): torn down
        tracker.observe(&[5, 31, 32, 32], &[0.0, 0.0]);
        let p3 = plan_replication(&policy, &tracker, &p2);
        assert!(!p3.has_replicas());
    }
}
