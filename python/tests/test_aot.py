"""AOT path: manifest consistency and HLO-text artifact sanity.

Builds the tiny preset into a temp dir (fast), then checks that every
artifact exists, is plain-parsable HLO text, and that manifest shapes obey
the config math the Rust side relies on.
"""

import json
import math
import os

import pytest

from compile import aot
from compile.kernels.ref import expert_capacity


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    entry = aot.build_preset("tiny", aot.PRESETS["tiny"], str(out))
    return str(out), entry


def test_all_artifacts_written(built):
    out, entry = built
    expected = {
        "gate", "ffn_block", "ffn_tile", "gemm0_tile",
        "gemm1_tile", "combine_tile", "moe_layer", "train_step",
    }
    assert set(entry["artifacts"]) == expected
    for art in entry["artifacts"].values():
        path = os.path.join(out, art["file"])
        assert os.path.getsize(path) > 1000
        head = open(path).read(200)
        assert head.startswith("HloModule"), head


def test_manifest_config_math(built):
    _, entry = built
    cfg = entry["config"]
    assert cfg["s_total"] == cfg["ranks"] * cfg["s_rank"]
    assert cfg["capacity"] == expert_capacity(
        cfg["s_rank"], cfg["e"], cfg["k"], cfg["capacity_factor"], cfg["bm"]
    )
    assert cfg["capacity"] % cfg["bm"] == 0
    arts = entry["artifacts"]
    h, d, e, bm, bn = cfg["h"], cfg["d"], cfg["e"], cfg["bm"], cfg["bn"]
    c_buf = cfg["ranks"] * cfg["capacity"]
    assert arts["gate"]["inputs"][0][1] == [cfg["s_rank"], h]
    assert arts["gate"]["outputs"][0][1] == [cfg["s_rank"], e]
    assert arts["ffn_block"]["inputs"][0][1] == [c_buf, h]
    assert arts["ffn_tile"]["inputs"][0][1] == [bm, h]
    assert arts["gemm0_tile"]["outputs"][0][1] == [bm, bn]
    assert arts["gemm1_tile"]["inputs"][0][1] == [bm, d]
    assert arts["combine_tile"]["outputs"][0][1] == [bm, h]
    assert arts["moe_layer"]["inputs"][0][1] == [cfg["s_total"], h]
    assert arts["moe_layer"]["outputs"][0][1] == [cfg["s_total"], h]


def test_hlo_text_has_no_64bit_id_problem(built):
    """Interchange must be text (parser reassigns ids) — never a proto dump."""
    out, entry = built
    path = os.path.join(out, entry["artifacts"]["moe_layer"]["file"])
    text = open(path).read()
    assert "ENTRY" in text and "ROOT" in text


def test_presets_are_tileable():
    for name, cfg in aot.PRESETS.items():
        assert cfg["s_rank"] % cfg["bm"] == 0, name
        assert cfg["d"] % cfg["bn"] == 0, name
        assert cfg["h"] % cfg["bn"] == 0, name
