//! Quickstart: start the persistent MoE engine, submit epoch-tagged
//! forward passes, collect results, shut down.
//!
//!     cargo run --release --example quickstart
//!
//! Uses the native compute backend so it works without `make artifacts`;
//! pass `--backend xla` (after `make artifacts`) to execute the AOT
//! Pallas kernels through PJRT instead.

use std::sync::Arc;

use flashdmoe::config::Config;
use flashdmoe::coordinator::{MoeEngine, TaskGraphMode};
use flashdmoe::expert::{generate_tokens, ModelParams};
use flashdmoe::runtime::{ArtifactStore, ComputeBackend, NativeBackend, XlaBackend};
use flashdmoe::util::stats::{fmt_bytes, fmt_time};

fn main() -> anyhow::Result<()> {
    let use_xla = std::env::args().any(|a| a == "--backend=xla" || a == "xla");

    // 1. Configuration: model shapes + system topology (presets mirror the
    //    AOT manifest; every knob is overridable, see `Config::set`).
    //    `cfg.set("wire_precision", "bf16")?` would halve the fabric
    //    payload bytes and the symmetric-heap footprint — dispatch/combine
    //    tiles quantize to 16 bits at the heap boundary while every GEMM
    //    still computes in f32 (see the crate docs' wire-precision
    //    section; f32, the default, is bitwise-transparent).
    let cfg = Config::preset("default")?;
    println!(
        "config: H={} D={} E={} top-{} | {} ranks x {} tokens, {} processors/rank | {} wire",
        cfg.model.h, cfg.model.d, cfg.model.e, cfg.model.k,
        cfg.system.ranks, cfg.system.s_rank, cfg.system.processors,
        cfg.system.wire.name(),
    );

    // 2. Parameters: deterministic, expert-keyed (any rank or the
    //    monolithic reference reproduces any expert without communication).
    let params = Arc::new(ModelParams::generate(&cfg, 42));
    println!("params: {} ({} experts)", params.num_params(), params.num_experts());

    // 3. Compute backend: native GEMM — on the packed persistent-weight
    //    hot path by default (weights re-laid into cache-contiguous NR
    //    panels once at engine start; `cfg.set("packed", "false")` A/Bs
    //    the unpacked baseline) — or the AOT Pallas kernels via PJRT.
    let backend: Arc<dyn ComputeBackend> = if use_xla {
        let store = ArtifactStore::load(&ArtifactStore::default_dir(), "default")?;
        println!("xla backend: compiled {} artifacts in {}", store.kernel_names().len(),
            fmt_time(store.compile_secs));
        Arc::new(XlaBackend::new(store))
    } else {
        let native = NativeBackend::from_config(&cfg);
        println!("native backend: {} (packed={})", native.name(), native.is_packed());
        Arc::new(native)
    };

    // 4. The engine. Started ONCE: every rank's subscriber + processor
    //    actors come up resident and park on doorbells (and the backend
    //    packs its weights — the only weight work of the lifetime). The
    //    `processors` knob sizes each rank's work-stealing pool: one
    //    deque per worker, idle workers steal, nobody serializes on a
    //    central queue lock. Fused mode = one FFN task per tile; Split
    //    mode = the paper's GEMM0->GEMM1 chain.
    let engine = MoeEngine::start(cfg.clone(), params, backend, TaskGraphMode::Fused)?;
    println!("symmetric heap L: {} per rank", fmt_bytes(engine.heap_bytes_per_rank()));

    // 5. Per-rank token batches (each rank owns its own sequence — DDP+EP).
    let inputs: Vec<Vec<f32>> =
        (0..cfg.system.ranks).map(|r| generate_tokens(&cfg, 42, r)).collect();

    // 6. Forward passes: epoch-tagged submissions onto the resident
    //    actors. submit() returns immediately with a PassHandle; wait()
    //    collects the outputs. Submitting pass N+1 before waiting pass N
    //    pipelines host work against engine compute (see examples/serve.rs).
    for _ in 0..3 {
        let handle = engine.submit(&inputs)?;
        let out = handle.wait()?;
        let m = &out.metrics;
        println!(
            "pass {}: {:>9} | util {:>5.1}% | {} tiles sent | payload saved {:.1}% | {} steals",
            m.epoch,
            fmt_time(m.wall_secs),
            m.utilization() * 100.0,
            m.ranks.iter().map(|r| r.tiles_sent).sum::<usize>(),
            m.ranks.iter().map(|r| r.payload_savings()).sum::<f64>()
                / m.ranks.len() as f64 * 100.0,
            m.ranks.iter().map(|r| r.steals).sum::<u32>(),
        );
        // outputs[r] is rank r's (S_r, H) output matrix
        assert_eq!(out.outputs.len(), cfg.system.ranks);
        assert_eq!(out.outputs[0].len(), cfg.system.s_rank * cfg.model.h);
    }

    // 7. Lifecycle accounting: the operator was "launched" exactly once,
    //    no matter how many passes ran.
    let em = engine.metrics();
    println!(
        "engine: {} passes | {} launch | {} resident threads",
        em.passes, em.launches, em.threads_spawned
    );
    assert_eq!(em.launches, 1);

    // 8. Shutdown: drain, park, join — no leaked threads (drop does the
    //    same implicitly).
    engine.shutdown();
    println!("ok");
    Ok(())
}
