//! The L3 coordinator — the paper's system contribution, exposed as a
//! **persistent engine**.
//!
//! Each rank runs a "persistent kernel": one OS/subscriber/scheduler
//! context plus N processor workers that are launched **once** at
//! [`MoeEngine::start`] and stay resident — parked on doorbells — for the
//! engine's whole lifetime. Actors exchange tile-granular task
//! descriptors through a work-conserving ready queue; ranks exchange
//! tiles through the write-conflict-free symmetric heap with one-sided
//! put+signal (`crate::fabric`), every transfer stamped with the pass
//! epoch (per-slot generation counters — no global reset, no collective,
//! no bulk-synchronous barrier anywhere on the data path).
//!
//! Engine lifecycle (the only launch is the first line):
//!
//! ```text
//! MoeEngine::start(cfg, params, backend, mode)   // actors launched ONCE
//!     engine.submit(&inputs)? -> PassHandle       // epoch-tagged pass N
//!     engine.submit(&next)?   -> PassHandle       // pass N+1, pipelined
//!     handle.wait()?          -> ForwardResult    // collect pass N
//!     ... × as many passes as you like: zero thread spawns, launch
//!         count stays 1 (EngineMetrics::launches)
//! engine.shutdown()  // or drop — actors drained, parked threads joined
//! ```
//!
//! Module map (mirrors Fig. 6, plus the engine front end):
//! * [`engine`]    — the public persistent [`MoeEngine`]: epoch-tagged
//!   `submit`/`wait`, double-buffered pass slots, shutdown/join.
//! * [`scheduler`] — the per-processor work-stealing ready pool +
//!   interrupt plumbing (Alg. 3), reusable across passes (`stop_all`
//!   parks a pass, `reopen` re-arms).
//! * [`rank`]      — one rank's resident actor group: subscriber decode
//!   loop (Alg. 4), processor execution loop (Alg. 2), dispatch (Alg. 1).
//! * [`moe`]       — [`DistributedMoE`], the original one-call operator
//!   API kept as a thin shim over a non-pipelined engine.
//! * [`baseline`]  — a real-execution bulk-synchronous baseline
//!   (Megatron/DeepSpeed-shaped) over the same substrate, for measured
//!   comparisons and numeric cross-checks.
//! * [`metrics`]   — per-rank / per-pass / engine-lifetime accounting
//!   (SM-utilization analog, Table 1's launch count).

pub mod baseline;
pub mod engine;
pub mod metrics;
pub mod moe;
pub mod rank;
pub mod scheduler;

pub use engine::{ForwardResult, MoeEngine, PassHandle};
pub use metrics::{EngineMetrics, PassMetrics, RankMetrics};
pub use moe::DistributedMoE;
pub use rank::TaskGraphMode;
