//! Fig 13 — throughput (MTokens/s) vs GPU count, T=16K/GPU, E=64.
fn main() {
    let (text, pts) = flashdmoe::harness::fig13(42).unwrap();
    println!("{text}");
    let flash8 = pts.iter().find(|p| p.engine == "FlashDMoE" && p.x == 8.0).unwrap();
    println!("FlashDMoE at 8 GPUs: {:.1} MTok/s (paper: 17.7 MTok/s on real H100s)",
        16384.0 * 8.0 / flash8.latency / 1e6);
}
