//! The reduced-precision wire format: f32 ⇄ {f32, f16, bf16} conversion
//! at the symmetric-heap boundary.
//!
//! Dispatch and combine payloads are *quantized* to the configured
//! [`WirePrecision`] when they enter the heap (`SymmetricHeap::put_signal`
//! encodes) and *dequantized* back to f32 when a consumer reads them
//! (`SymmetricHeap::read_into` decodes). Expert GEMMs, gate math and the
//! combine fold all run in f32 — wire precision changes what crosses the
//! fabric, never how the compute kernels accumulate.
//!
//! Guarantees, relied on by the engine test suite:
//!
//! * **F32 is a bitwise no-op.** `encode_into`/`decode_into` at
//!   [`WirePrecision::F32`] are little-endian byte copies, so an F32
//!   engine produces bit-identical outputs to one that predates the wire
//!   subsystem — including NaN payloads and `-0.0` signs.
//! * **Conversions are deterministic and order-free.** Both 16-bit
//!   formats use IEEE round-to-nearest-even per element, so reduced
//!   passes stay bitwise reproducible across restarts, schedules and
//!   processor counts (the combine fold already fixes the f32 reduction
//!   order).
//! * **Round-trip error is bounded.** For finite inputs in the format's
//!   normal range, `|roundtrip(x) - x| <= |x| * 2^-(m+1)` with `m` stored
//!   mantissa bits (7 for bf16, 10 for f16). NaN stays NaN (quieted),
//!   ±Inf and signed zero are preserved, f16 subnormals round with
//!   absolute error ≤ 2^-25, and quantization is monotone — all
//!   property-tested below.

use crate::config::WirePrecision;

// ---------------------------------------------------------------------------
// bf16 (bfloat16: 1 sign, 8 exponent, 7 mantissa — f32's top half)
// ---------------------------------------------------------------------------

/// f32 → bf16 code unit, round-to-nearest-even.
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // keep the sign + a quiet NaN payload; truncation alone could
        // zero the mantissa and turn a signalling NaN into Inf
        return ((bits >> 16) as u16) | 0x0040;
    }
    // RNE: add 0x7FFF plus the parity of the kept LSB, then truncate.
    // Carries propagate into the exponent correctly (e.g. f32::MAX
    // rounds to +Inf, as IEEE RNE requires).
    let round_bit = (bits >> 16) & 1;
    ((bits.wrapping_add(0x7FFF + round_bit)) >> 16) as u16
}

/// bf16 code unit → f32 (exact: bf16 ⊂ f32).
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

// ---------------------------------------------------------------------------
// f16 (IEEE 754 binary16: 1 sign, 5 exponent, 10 mantissa)
// ---------------------------------------------------------------------------

/// f32 → f16 code unit, round-to-nearest-even, with gradual underflow
/// (subnormal halves) and overflow to ±Inf.
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x007F_FFFF;
    if exp == 0xFF {
        // Inf stays Inf; NaN becomes a quiet NaN with the sign kept
        return if man != 0 { sign | 0x7E00 } else { sign | 0x7C00 };
    }
    let e = exp - 127; // unbiased
    if e > 15 {
        return sign | 0x7C00; // overflow -> Inf
    }
    if e >= -14 {
        // normal half: keep 10 mantissa bits, RNE on the dropped 13.
        // A mantissa carry bumps the exponent field, which also handles
        // values just under 2^16 rounding up to Inf.
        let base = (((e + 15) as u32) << 10) | (man >> 13);
        let rem = man & 0x1FFF;
        let round = ((rem > 0x1000) || (rem == 0x1000 && (base & 1) == 1)) as u32;
        return sign | (base + round) as u16;
    }
    if e < -25 {
        return sign; // underflow to signed zero
    }
    // subnormal half: value = m_h * 2^-24; shift the full significand
    // (implicit bit restored) down with RNE. e == -25 rounds to either
    // zero or the minimum subnormal.
    let sig = man | 0x0080_0000;
    let shift = (-e - 1) as u32; // in 14..=24
    let base = sig >> shift;
    let rem = sig & ((1u32 << shift) - 1);
    let half = 1u32 << (shift - 1);
    let round = ((rem > half) || (rem == half && (base & 1) == 1)) as u32;
    sign | (base + round) as u16
}

/// f16 code unit → f32 (exact: every binary16 value is an f32).
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x03FF) as u32;
    let bits = if exp == 0x1F {
        sign | 0x7F80_0000 | (man << 13) // Inf / NaN (payload widened)
    } else if exp == 0 {
        if man == 0 {
            sign // signed zero
        } else {
            // subnormal: value = man * 2^-24; normalize into an f32
            let p = 31 - man.leading_zeros(); // highest set bit, 0..=9
            let e = p as i32 - 24; // unbiased f32 exponent
            let m32 = (man << (23 - p)) & 0x007F_FFFF; // drop implicit bit
            sign | (((e + 127) as u32) << 23) | m32
        }
    } else {
        sign | ((exp + 112) << 23) | (man << 13) // bias 15 -> 127
    };
    f32::from_bits(bits)
}

// ---------------------------------------------------------------------------
// Payload encode / decode (the SymmetricHeap boundary)
// ---------------------------------------------------------------------------

/// Quantize one f32 through the wire format and back (the value a
/// receiver observes). Identity at `F32`.
pub fn quantize(p: WirePrecision, x: f32) -> f32 {
    match p {
        WirePrecision::F32 => x,
        WirePrecision::F16 => f16_to_f32(f32_to_f16(x)),
        WirePrecision::Bf16 => bf16_to_f32(f32_to_bf16(x)),
    }
}

/// Encode an f32 payload into wire code units (little-endian bytes).
/// `dst.len()` must be exactly `src.len() * p.bytes()`.
pub fn encode_into(p: WirePrecision, src: &[f32], dst: &mut [u8]) {
    debug_assert_eq!(dst.len(), src.len() * p.bytes());
    match p {
        WirePrecision::F32 => {
            for (x, b) in src.iter().zip(dst.chunks_exact_mut(4)) {
                b.copy_from_slice(&x.to_le_bytes());
            }
        }
        WirePrecision::F16 => {
            for (x, b) in src.iter().zip(dst.chunks_exact_mut(2)) {
                b.copy_from_slice(&f32_to_f16(*x).to_le_bytes());
            }
        }
        WirePrecision::Bf16 => {
            for (x, b) in src.iter().zip(dst.chunks_exact_mut(2)) {
                b.copy_from_slice(&f32_to_bf16(*x).to_le_bytes());
            }
        }
    }
}

/// Decode wire code units back into f32. `src.len()` must be exactly
/// `dst.len() * p.bytes()`. Bitwise inverse of [`encode_into`] at `F32`.
pub fn decode_into(p: WirePrecision, src: &[u8], dst: &mut [f32]) {
    debug_assert_eq!(src.len(), dst.len() * p.bytes());
    match p {
        WirePrecision::F32 => {
            for (b, x) in src.chunks_exact(4).zip(dst.iter_mut()) {
                *x = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
            }
        }
        WirePrecision::F16 => {
            for (b, x) in src.chunks_exact(2).zip(dst.iter_mut()) {
                *x = f16_to_f32(u16::from_le_bytes([b[0], b[1]]));
            }
        }
        WirePrecision::Bf16 => {
            for (b, x) in src.chunks_exact(2).zip(dst.iter_mut()) {
                *x = bf16_to_f32(u16::from_le_bytes([b[0], b[1]]));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    const REDUCED: [WirePrecision; 2] = [WirePrecision::Bf16, WirePrecision::F16];

    /// Stored mantissa bits of a reduced format (RNE error is 2^-(m+1)).
    fn mantissa_bits(p: WirePrecision) -> i32 {
        match p {
            WirePrecision::Bf16 => 7,
            WirePrecision::F16 => 10,
            WirePrecision::F32 => 23,
        }
    }

    #[test]
    fn exactly_representable_values_roundtrip_bitwise() {
        // small integers, powers of two and their sums fit 7 mantissa bits
        let exact = [0.0f32, -0.0, 1.0, -1.0, 2.5, -3.0, 96.0, 0.15625, 1024.0, -0.5];
        for p in REDUCED {
            for &x in &exact {
                let rt = quantize(p, x);
                assert_eq!(rt.to_bits(), x.to_bits(), "{p:?}: {x} must roundtrip exactly");
            }
        }
        // f32 wire is a bitwise identity for everything, NaN payloads included
        for x in [f32::NAN, -f32::NAN, f32::INFINITY, -0.0, 1e-42, f32::MAX] {
            assert_eq!(quantize(WirePrecision::F32, x).to_bits(), x.to_bits());
        }
    }

    #[test]
    fn roundtrip_error_is_bounded_in_the_normal_range() {
        let mut rng = Rng::new(0xB16);
        for p in REDUCED {
            let bound = 2.0f32.powi(-(mantissa_bits(p) + 1));
            for _ in 0..20_000 {
                // |x| in [mag, 2*mag] with mag in 2^-14 .. 2^14: inside the
                // shared *normal* range of both formats (f16 subnormals
                // have an absolute, not relative, bound — tested below)
                let mag = 2.0f32.powi(rng.below(29) as i32 - 14);
                let frac = 1.0 + rng.range_f64(0.0, 1.0) as f32;
                let sign = if rng.below(2) == 0 { 1.0f32 } else { -1.0 };
                let x = sign * frac * mag;
                let err = (quantize(p, x) - x).abs();
                assert!(
                    err <= x.abs() * bound,
                    "{p:?}: |{x}| roundtrip err {err} exceeds rel bound {bound}"
                );
            }
        }
    }

    #[test]
    fn nan_inf_and_signed_zero_are_preserved() {
        for p in REDUCED {
            assert!(quantize(p, f32::NAN).is_nan(), "{p:?}: NaN must stay NaN");
            assert!(quantize(p, -f32::NAN).is_nan());
            assert_eq!(quantize(p, f32::INFINITY), f32::INFINITY);
            assert_eq!(quantize(p, f32::NEG_INFINITY), f32::NEG_INFINITY);
            assert_eq!(quantize(p, 0.0).to_bits(), 0.0f32.to_bits());
            assert_eq!(quantize(p, -0.0).to_bits(), (-0.0f32).to_bits());
        }
    }

    #[test]
    fn f16_overflow_saturates_to_inf_and_bf16_covers_f32_range() {
        // beyond 65504 (+ half an ulp) the f16 wire carries Inf
        assert_eq!(quantize(WirePrecision::F16, 65504.0), 65504.0);
        assert_eq!(quantize(WirePrecision::F16, 65505.0), 65504.0, "rounds back down");
        assert_eq!(quantize(WirePrecision::F16, 1e6), f32::INFINITY);
        assert_eq!(quantize(WirePrecision::F16, -1e6), f32::NEG_INFINITY);
        // bf16 shares f32's exponent range: huge magnitudes stay finite
        let big = 1e38f32;
        let rt = quantize(WirePrecision::Bf16, big);
        assert!(rt.is_finite() && (rt - big).abs() <= big * 2.0f32.powi(-8));
        // f32::MAX sits above bf16::MAX + ulp/2, so RNE carries to Inf
        assert_eq!(quantize(WirePrecision::Bf16, f32::MAX), f32::INFINITY);
    }

    #[test]
    fn f16_subnormals_round_with_bounded_absolute_error() {
        let min_sub = 2.0f32.powi(-24);
        assert_eq!(quantize(WirePrecision::F16, min_sub), min_sub, "min subnormal exact");
        assert_eq!(quantize(WirePrecision::F16, -min_sub), -min_sub);
        // halfway below the min subnormal ties to even -> zero
        assert_eq!(quantize(WirePrecision::F16, min_sub / 2.0), 0.0);
        // 1.5 * 2^-24 ties between 1*2^-24 and 2*2^-24 -> even (2*2^-24)
        assert_eq!(quantize(WirePrecision::F16, 1.5 * min_sub), 2.0 * min_sub);
        let mut rng = Rng::new(0x5B);
        for _ in 0..5_000 {
            let x = (rng.range_f64(-1.0, 1.0) as f32) * 2.0f32.powi(-15);
            let err = (quantize(WirePrecision::F16, x) - x).abs();
            assert!(err <= 2.0f32.powi(-25), "subnormal abs err {err} at {x}");
        }
    }

    #[test]
    fn quantization_is_monotone() {
        let mut rng = Rng::new(0x303);
        for p in REDUCED {
            let mut xs: Vec<f32> = (0..4_000)
                .map(|_| {
                    let mag = 2.0f32.powi(rng.below(60) as i32 - 30);
                    (rng.range_f64(-1.0, 1.0) as f32) * mag
                })
                .collect();
            xs.extend_from_slice(&[0.0, -0.0, 2.0f32.powi(-24), -2.0f32.powi(-24)]);
            xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let q: Vec<f32> = xs.iter().map(|&x| quantize(p, x)).collect();
            for w in q.windows(2) {
                assert!(w[0] <= w[1], "{p:?}: quantization reordered {} > {}", w[0], w[1]);
            }
        }
    }

    #[test]
    fn encode_decode_matches_scalar_quantize() {
        let mut rng = Rng::new(0xE2C);
        let src: Vec<f32> = (0..257).map(|_| rng.range_f64(-8.0, 8.0) as f32).collect();
        for p in [WirePrecision::F32, WirePrecision::Bf16, WirePrecision::F16] {
            let mut bytes = vec![0u8; src.len() * p.bytes()];
            encode_into(p, &src, &mut bytes);
            let mut out = vec![0.0f32; src.len()];
            decode_into(p, &bytes, &mut out);
            for (&x, &y) in src.iter().zip(&out) {
                assert_eq!(y.to_bits(), quantize(p, x).to_bits(), "{p:?} buffer vs scalar");
            }
        }
    }
}
