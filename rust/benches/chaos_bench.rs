//! Chaos A/B — fault-tolerant serving, **measured on live engines**:
//! the same open-loop Zipf serving workload with the deterministic fault
//! schedule off ("clean") or on ("faulted": every transfer of pass
//! epoch 2 fails transiently, rank 3 dies permanently at epoch 6).
//! Correctness is asserted inside the harness (both arms serve every
//! accepted request; the faulted arm actually injects, retries, and
//! degrades); this bench reports the *cost* of surviving — availability,
//! p50/p99/p99.9 request latency, retry and degraded-pass counts.
//!
//! Emits `BENCH_pr8_chaos.json` (section `chaos_ab`) for the CI artifact
//! upload. With `PERF_SMOKE=1` the run FAILS unless the faulted arm
//! (a) kept availability at 100% — retry plus degraded-capacity routing
//! must hide the whole schedule from clients — and (b) actually paid for
//! it (injected faults, at least one retry, at least one degraded pass),
//! so the gate cannot pass vacuously on a schedule that never fired.
//!
//!     cargo bench --bench chaos_bench
fn main() {
    let (text, pts) = flashdmoe::harness::chaos_ab(42).unwrap();
    println!("{text}");

    flashdmoe::harness::update_bench_json(
        "BENCH_pr8_chaos.json",
        "chaos_ab",
        flashdmoe::harness::chaos_json(&pts),
    )
    .unwrap();
    println!("wrote BENCH_pr8_chaos.json (section chaos_ab)");

    let perf_smoke = std::env::var("PERF_SMOKE").map(|v| v == "1").unwrap_or(false);
    if perf_smoke {
        let mut failed = false;
        let clean = pts.iter().find(|p| p.arm == "clean");
        let faulted = pts.iter().find(|p| p.arm == "faulted");
        let (Some(clean), Some(faulted)) = (clean, faulted) else {
            eprintln!("PERF_SMOKE FAIL: missing an arm in the chaos A/B");
            std::process::exit(1);
        };
        for (arm, p) in [("clean", clean), ("faulted", faulted)] {
            if p.availability < 1.0 {
                eprintln!(
                    "PERF_SMOKE FAIL: {arm} arm availability {:.3} < 1.0 \
                     ({} served, {} failed, {} deadline misses)",
                    p.availability, p.served, p.failed, p.deadline_misses
                );
                failed = true;
            }
        }
        // the schedule must have actually fired — otherwise the
        // availability check above is vacuous
        if faulted.faults_injected == 0 || faulted.retries == 0 || faulted.degraded_passes == 0 {
            eprintln!(
                "PERF_SMOKE FAIL: fault schedule never fired (faults {}, retries {}, \
                 degraded passes {})",
                faulted.faults_injected, faulted.retries, faulted.degraded_passes
            );
            failed = true;
        }
        if !failed {
            println!(
                "PERF_SMOKE ok: faulted arm served {}/{} (p99 {:.1}x clean, p99.9 {:.1}x), \
                 {} retries, {} degraded passes, {} faults injected",
                faulted.served,
                faulted.requests,
                faulted.latency_p99 / clean.latency_p99.max(1e-9),
                faulted.latency_p999 / clean.latency_p999.max(1e-9),
                faulted.retries,
                faulted.degraded_passes,
                faulted.faults_injected
            );
        }
        if failed {
            std::process::exit(1);
        }
    }
}
