//! Native in-process BLAS: cache-blocked f32 GEMM with fused epilogues.
//!
//! This is the paper's "in-device BLAS" substrate (they built it on
//! CUTLASS; here it is a register-blocked CPU kernel). It backs the
//! `ComputeBackend::Native` path used by tests, the baselines and the
//! perf pass; the XLA/PJRT path executes the same math via the AOT
//! Pallas artifacts, and both must agree to f32 tolerance.
//!
//! Layout: all matrices row-major. The hot loop is an (MR x NR) register
//! tile over a K-panel, the standard micro-kernel shape; the epilogue
//! (bias + activation) is fused into the write-back exactly like the
//! paper's task formulation F_t(A,B,C,D) = phi(A*B + D).

/// Fused epilogue selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Epilogue {
    /// C = A·B + bias
    Identity,
    /// C = relu(A·B + bias)
    Relu,
}

/// Register tile height/width of the micro-kernel. NR=16 maps one
/// accumulator row to a ZMM register (AVX-512) or two YMMs; MR=8 gives
/// 8 accumulator rows + loaded B row within the 32-register budget.
const MR: usize = 8;
const NR: usize = 16;
/// K-panel blocking (fits MR+NR panels in L1 comfortably).
const KC: usize = 256;

/// C(m,n) = phi(A(m,k)·B(k,n) + bias(n)), row-major, C overwritten.
pub fn gemm_bias(
    a: &[f32],
    b: &[f32],
    bias: Option<&[f32]>,
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    epilogue: Epilogue,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if let Some(bv) = bias {
        debug_assert_eq!(bv.len(), n);
    }
    c.fill(0.0);
    // K-blocked accumulation into C, epilogue applied after the last panel.
    let mut k0 = 0;
    while k0 < k {
        let kb = KC.min(k - k0);
        macro_kernel(a, b, c, m, k, n, k0, kb);
        k0 += kb;
    }
    finish(c, bias, m, n, epilogue);
}

/// Accumulate C += A[:, k0..k0+kb]·B[k0..k0+kb, :].
fn macro_kernel(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize, k0: usize, kb: usize) {
    let mut i = 0;
    while i < m {
        let mb = MR.min(m - i);
        let mut j = 0;
        while j < n {
            let nb = NR.min(n - j);
            if mb == MR && nb == NR {
                micro_kernel_full(a, b, c, k, n, i, j, k0, kb);
            } else {
                micro_kernel_edge(a, b, c, k, n, i, j, k0, kb, mb, nb);
            }
            j += NR;
        }
        i += MR;
    }
}

/// Full MRxNR register tile; the compiler autovectorizes the NR lane.
#[inline]
fn micro_kernel_full(a: &[f32], b: &[f32], c: &mut [f32], k: usize, n: usize, i: usize, j: usize, k0: usize, kb: usize) {
    let mut acc = [[0.0f32; NR]; MR];
    for p in k0..k0 + kb {
        let brow = &b[p * n + j..p * n + j + NR];
        for (r, accr) in acc.iter_mut().enumerate() {
            let av = a[(i + r) * k + p];
            for (x, &bv) in accr.iter_mut().zip(brow) {
                *x += av * bv;
            }
        }
    }
    for (r, accr) in acc.iter().enumerate() {
        let crow = &mut c[(i + r) * n + j..(i + r) * n + j + NR];
        for (cv, &x) in crow.iter_mut().zip(accr) {
            *cv += x;
        }
    }
}

/// Edge tile (partial MR/NR).
#[inline]
fn micro_kernel_edge(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    k: usize,
    n: usize,
    i: usize,
    j: usize,
    k0: usize,
    kb: usize,
    mb: usize,
    nb: usize,
) {
    for r in 0..mb {
        for col in 0..nb {
            let mut acc = 0.0f32;
            for p in k0..k0 + kb {
                acc += a[(i + r) * k + p] * b[p * n + j + col];
            }
            c[(i + r) * n + j + col] += acc;
        }
    }
}

/// Epilogue: bias add + activation over the finished accumulator.
fn finish(c: &mut [f32], bias: Option<&[f32]>, m: usize, n: usize, epilogue: Epilogue) {
    for row in 0..m {
        let crow = &mut c[row * n..(row + 1) * n];
        if let Some(bv) = bias {
            for (cv, &b) in crow.iter_mut().zip(bv) {
                *cv += b;
            }
        }
        if epilogue == Epilogue::Relu {
            for cv in crow.iter_mut() {
                if *cv < 0.0 {
                    *cv = 0.0;
                }
            }
        }
    }
}

/// Expert FFN over a row block: relu(x·W1 + b1)·W2 + b2, returning (rows, h).
/// `scratch` must hold rows*d floats (the caller reuses it across tasks to
/// keep the hot path allocation-free).
pub fn ffn(
    x: &[f32],
    w1: &[f32],
    b1: &[f32],
    w2: &[f32],
    b2: &[f32],
    out: &mut [f32],
    scratch: &mut [f32],
    rows: usize,
    h: usize,
    d: usize,
) {
    debug_assert!(scratch.len() >= rows * d);
    gemm_bias(x, w1, Some(b1), &mut scratch[..rows * d], rows, h, d, Epilogue::Relu);
    gemm_bias(&scratch[..rows * d], w2, Some(b2), out, rows, d, h, Epilogue::Identity);
}

/// Combine task t3: out[r] += scale[r] * x[r] over (rows, h) tiles.
pub fn combine_accumulate(out: &mut [f32], x: &[f32], scale: &[f32], rows: usize, h: usize) {
    debug_assert_eq!(x.len(), rows * h);
    debug_assert!(scale.len() >= rows);
    for r in 0..rows {
        let s = scale[r];
        if s == 0.0 {
            continue;
        }
        let orow = &mut out[r * h..(r + 1) * h];
        let xrow = &x[r * h..(r + 1) * h];
        for (o, &v) in orow.iter_mut().zip(xrow) {
            *o += s * v;
        }
    }
}

/// Naive reference GEMM (tests compare blocked vs naive).
pub fn gemm_naive(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for p in 0..k {
                acc += a[i * k + p] * b[p * n + j];
            }
            c[i * n + j] = acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::stats::max_abs_diff;

    fn rand_mat(rng: &mut Rng, n: usize) -> Vec<f32> {
        rng.normal_vec(n, 1.0)
    }

    #[test]
    fn blocked_matches_naive_over_shapes() {
        let mut rng = Rng::new(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (4, 8, 8), (17, 33, 9), (128, 64, 96), (65, 256, 31)] {
            let a = rand_mat(&mut rng, m * k);
            let b = rand_mat(&mut rng, k * n);
            let mut c0 = vec![0.0; m * n];
            let mut c1 = vec![0.0; m * n];
            gemm_naive(&a, &b, &mut c0, m, k, n);
            gemm_bias(&a, &b, None, &mut c1, m, k, n, Epilogue::Identity);
            assert!(max_abs_diff(&c0, &c1) < 1e-3, "({m},{k},{n})");
        }
    }

    #[test]
    fn bias_and_relu_epilogues() {
        let mut rng = Rng::new(2);
        let (m, k, n) = (8, 16, 8);
        let a = rand_mat(&mut rng, m * k);
        let b = rand_mat(&mut rng, k * n);
        let bias = rand_mat(&mut rng, n);
        let mut c = vec![0.0; m * n];
        gemm_bias(&a, &b, Some(&bias), &mut c, m, k, n, Epilogue::Relu);
        let mut want = vec![0.0; m * n];
        gemm_naive(&a, &b, &mut want, m, k, n);
        for i in 0..m {
            for j in 0..n {
                let v = (want[i * n + j] + bias[j]).max(0.0);
                assert!((c[i * n + j] - v).abs() < 1e-3);
            }
        }
        assert!(c.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn ffn_matches_composition() {
        let mut rng = Rng::new(3);
        let (rows, h, d) = (32, 24, 40);
        let x = rand_mat(&mut rng, rows * h);
        let w1 = rand_mat(&mut rng, h * d);
        let b1 = rand_mat(&mut rng, d);
        let w2 = rand_mat(&mut rng, d * h);
        let b2 = rand_mat(&mut rng, h);
        let mut out = vec![0.0; rows * h];
        let mut scratch = vec![0.0; rows * d];
        ffn(&x, &w1, &b1, &w2, &b2, &mut out, &mut scratch, rows, h, d);
        // compose manually
        let mut mid = vec![0.0; rows * d];
        gemm_bias(&x, &w1, Some(&b1), &mut mid, rows, h, d, Epilogue::Relu);
        let mut want = vec![0.0; rows * h];
        gemm_bias(&mid, &w2, Some(&b2), &mut want, rows, d, h, Epilogue::Identity);
        assert_eq!(out, want);
    }

    #[test]
    fn combine_accumulates_scaled_rows() {
        let mut out = vec![1.0f32; 2 * 3];
        let x = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        combine_accumulate(&mut out, &x, &[2.0, 0.0], 2, 3);
        assert_eq!(out, vec![3.0, 5.0, 7.0, 1.0, 1.0, 1.0]);
    }
}
