//! The symmetric tensor layout `L ∈ R^{P×R×B×E×C×H}` (paper §3.2).
//!
//! Every rank allocates an identical ("symmetric", in the PGAS sense) heap
//! of tile cells indexed by
//!
//! * `P` — peer (source) rank,
//! * `R` — communication round (0 = dispatch, 1 = combine),
//! * `B` — staging buffer (0 = local outgoing stage, 1 = remote inbox),
//! * `E` — local expert slot,
//! * `C` — capacity slot (aligned to bM; see in-place padding, §3.2.1),
//! * `H` — embedding lane.
//!
//! The index validity rules of Definition C.2 make all one-sided writes
//! write-write conflict-free (Theorem 3.1): an inter-device write from
//! rank `p_s` may only target `p* == p_s, b == 1`, so distinct sources can
//! never collide; intra-device staging (`b == 0`) is rank-private. This
//! module owns the index math, the validity checks (property-tested in
//! `rust/tests/properties.rs`), and the Table 3 memory accounting.

use crate::config::{Config, ModelConfig, WirePrecision};

/// Number of communication rounds r (dispatch, combine).
pub const ROUNDS: usize = 2;
/// Staging buffers per round (outgoing, incoming).
pub const BUFFERS: usize = 2;

/// Geometry of the symmetric tensor on one rank.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LayoutDims {
    /// Expert-parallel world size P.
    pub p: usize,
    /// Local expert *slots* E on this rank: the owned experts plus any
    /// replica slots reserved by the replication policy
    /// (`Config::replica_slots`). Replica slots are addressed, sized,
    /// flagged and validated exactly like owned slots; whether one is
    /// *bound* to an expert in a given pass is the
    /// `crate::placement::Placement`'s business, not the layout's.
    pub e_local: usize,
    /// Aligned per-(peer, expert) slot-region size C (multiple of bM).
    /// Under `RoutingPolicy::Capacity` this is the fixed expert capacity;
    /// under `Dropless` it is the worst-case `roundup(S_r, bM)` region,
    /// of which a pass only ever touches the tiles its dispatch plan
    /// actually announced (variable tile-slot usage — the heap no longer
    /// assumes `capacity / bM` occupied tiles per source).
    pub c: usize,
    /// Embedding dimension H.
    pub h: usize,
    /// Tile height bM (C % bM == 0).
    pub bm: usize,
}

/// A fully-specified cell coordinate (one capacity slot's row of H floats
/// lives at each (p, r, b, e, c)).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Coord {
    pub p: usize,
    pub r: usize,
    pub b: usize,
    pub e: usize,
    pub c: usize,
}

impl LayoutDims {
    pub fn from_config(cfg: &Config) -> Self {
        Self {
            p: cfg.system.ranks,
            // replica slots ride along in the expert dimension, so every
            // downstream offset/flag/byte computation — and the
            // write-validity rules — cover them with no special cases.
            // Multi-model residency (`max_models` > 1) partitions the
            // expert dimension into per-model bands of
            // `local_experts() + replica_slots()` slots each: model `m`
            // owns slots `[m·band, (m+1)·band)`, so co-resident models
            // share one symmetric heap without sharing any cell (the
            // write-validity rules then isolate models for free). With
            // the default `max_models == 1` this is byte-identical to
            // the single-model layout.
            e_local: (cfg.local_experts() + cfg.replica_slots()) * cfg.system.max_models,
            c: cfg.model.slot_capacity(cfg.system.s_rank),
            h: cfg.model.h,
            bm: cfg.model.bm,
        }
    }

    /// Total f32 elements of L on one rank.
    pub fn elems(&self) -> usize {
        self.p * ROUNDS * BUFFERS * self.e_local * self.c * self.h
    }

    /// Bytes of L on one rank at `elem_bytes` per scalar.
    pub fn bytes(&self, elem_bytes: f64) -> f64 {
        self.elems() as f64 * elem_bytes
    }

    /// Flat element offset of a coordinate's row start.
    pub fn offset(&self, i: Coord) -> usize {
        debug_assert!(self.in_bounds(i), "{i:?} out of bounds for {self:?}");
        ((((i.p * ROUNDS + i.r) * BUFFERS + i.b) * self.e_local + i.e) * self.c + i.c) * self.h
    }

    /// Flat *flag* index for a (p, r, e, tile) signal. One flag guards one
    /// tile (bM capacity slots) per round per peer per local expert.
    pub fn flag_index(&self, p: usize, r: usize, e: usize, tile: usize) -> usize {
        debug_assert!(tile < self.tiles_per_expert());
        ((p * ROUNDS + r) * self.e_local + e) * self.tiles_per_expert() + tile
    }

    /// Number of signal flags on one rank.
    pub fn num_flags(&self) -> usize {
        self.p * ROUNDS * self.e_local * self.tiles_per_expert()
    }

    pub fn tiles_per_expert(&self) -> usize {
        self.c / self.bm
    }

    /// True iff a source routing `rows` token rows to a single expert
    /// fits one (peer, expert) slot region of this layout — the
    /// invariant the engine's variable-shape *dropless* passes rely on:
    /// the region is sized once from the static worst case
    /// (`roundup(s_rank, bM)`), and any pass with `s_r ≤ s_rank` rows
    /// needs at most `roundup(s_r, bM) ≤ C` slots, so partially-filled
    /// passes reuse the resident heap unchanged. (Under a `Capacity`
    /// policy the gate's drop rule bounds occupancy instead.)
    pub fn fits_source_rows(&self, rows: usize) -> bool {
        rows.div_ceil(self.bm) * self.bm <= self.c
    }

    pub fn in_bounds(&self, i: Coord) -> bool {
        i.p < self.p && i.r < ROUNDS && i.b < BUFFERS && i.e < self.e_local && i.c < self.c
    }
}

/// A one-sided write against the symmetric layout: `src` writes rows
/// `[coord.c, coord.c + rows)` of `(coord)` on rank `dst`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Write {
    pub src: usize,
    pub dst: usize,
    pub coord: Coord,
    pub rows: usize,
}

/// Definition C.2: validity of an index coordinate for a write.
///
/// 1. Inter-device writes (including self-loops) require `coord.p == src`
///    and `b == 1` (the destination's inbox for that source).
/// 2. `b == 0` (staging) writes require `src == dst` (rank-private).
pub fn write_is_valid(w: &Write, dims: &LayoutDims) -> bool {
    if !dims.in_bounds(w.coord) || w.rows == 0 || w.coord.c + w.rows > dims.c {
        return false;
    }
    match w.coord.b {
        1 => w.coord.p == w.src,
        0 => w.src == w.dst,
        _ => false,
    }
}

/// Do two writes touch an overlapping memory segment on the same rank?
pub fn writes_overlap(a: &Write, b: &Write) -> bool {
    a.dst == b.dst
        && a.coord.p == b.coord.p
        && a.coord.r == b.coord.r
        && a.coord.b == b.coord.b
        && a.coord.e == b.coord.e
        && a.coord.c < b.coord.c + b.rows
        && b.coord.c < a.coord.c + a.rows
}

/// Theorem 3.1 predicate: two *distinct-source, valid* writes never
/// overlap. (`rust/tests/properties.rs` fuzzes this with random write sets;
/// the unit tests below cover the proof's two cases.)
pub fn conflict_free(a: &Write, b: &Write, dims: &LayoutDims) -> bool {
    if !write_is_valid(a, dims) || !write_is_valid(b, dims) {
        return true; // invalid writes are rejected upstream, not conflicts
    }
    if a.src == b.src {
        return true; // same source: program order, not a conflict (Case 1)
    }
    !writes_overlap(a, b)
}

// ---------------------------------------------------------------------------
// Table 3 memory accounting
// ---------------------------------------------------------------------------

/// Memory overhead report for one rank (paper Table 3).
#[derive(Clone, Debug)]
pub struct MemoryReport {
    pub tokens: usize,
    pub experts: usize,
    /// Wire element format the report was computed at.
    pub wire: WirePrecision,
    /// Raw expert capacity EC before alignment.
    pub ec: usize,
    /// Aligned capacity max(bM, EC) rounded to bM.
    pub c_aligned: usize,
    /// Size of the symmetric tensor L in bytes (at the wire width).
    pub size_l: f64,
    /// Bookkeeping bytes: flags, routing tables, task descriptors, queues.
    pub bookkeeping: f64,
}

impl MemoryReport {
    pub fn total(&self) -> f64 {
        self.size_l + self.bookkeeping
    }
}

/// Compute the Table 3 row for a configuration at the configured wire
/// element width. `tokens` is the *total* token count T of the table
/// (per-GPU sequence in the paper's setup); EC = T/E · f as in the
/// paper's table (k is folded into f there). `WirePrecision::F32`
/// reproduces the paper's fp32 columns; the 16-bit formats halve every
/// element-width-derived line of the *modeled device footprint* (L,
/// scores, activation staging — the paper's FP16 setup stages 16-bit
/// elements throughout) while flags, routing tables and task descriptors
/// — which carry ids and counts, not elements — keep their fixed sizes.
/// Of these, only `size_l` is also this CPU reproduction's measured
/// allocation (the symmetric heap genuinely shrinks); its compute-side
/// score/staging copies stay f32 at every wire setting.
pub fn memory_report(
    tokens: usize,
    experts: usize,
    model: &ModelConfig,
    world: usize,
    wire: WirePrecision,
) -> MemoryReport {
    let wb = wire.bytes() as f64;
    let ec = (tokens as f64 / experts as f64 * model.capacity_factor()).ceil() as usize;
    let c_aligned = ec.max(model.bm).div_ceil(model.bm) * model.bm;
    // L holds E_total cells across the P peers (P * E_local == E):
    let e_local = experts.div_ceil(world);
    let dims = LayoutDims { p: world, e_local, c: c_aligned, h: model.h, bm: model.bm };
    let size_l = dims.bytes(wb);

    // Bookkeeping. The structure inventory mirrors this implementation
    // (flags, T_phi, descriptors are its actual width-free id/count
    // structures); the element-bearing lines are sized for the *modeled
    // device kernel* at the configured element width — the paper's FP16
    // configuration stages FP16 scores and activations. (This CPU
    // reproduction itself keeps all compute-side copies f32 regardless
    // of the wire knob; its measured f32 score/staging buffers live
    // outside this Table-3 model.)
    //  * signal flags (8B each, dispatch+combine rounds) — width-free
    //  * routing table T_phi: (token id, weight) per capacity slot —
    //    width-free (a u32 id + an f32 combine weight)
    //  * gate scores G_phi: S x E elements at the element width
    //  * task descriptors: 128B (cache line, Fig 16) per tile task bound
    //  * intermediate GEMM0 staging: one (C, D) activation buffer per local
    //    expert (the fused path's VMEM-resident analog kept in global
    //    mem), at the element width
    let flags = (dims.num_flags() * 8) as f64;
    let t_phi = (world * e_local * c_aligned * 8) as f64;
    let g_phi = (tokens * experts) as f64 * wb;
    let tile_tasks = world * e_local * dims.tiles_per_expert() * (1 + model.d / model.bn.max(1));
    let descriptors = (tile_tasks * 128) as f64;
    let gemm0_stage = (e_local * world * c_aligned * model.d) as f64 * wb;
    MemoryReport {
        tokens,
        experts,
        wire,
        ec,
        c_aligned,
        size_l,
        bookkeeping: flags + t_phi + g_phi + descriptors + gemm0_stage,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> LayoutDims {
        LayoutDims { p: 4, e_local: 2, c: 64, h: 8, bm: 32 }
    }

    #[test]
    fn offsets_are_unique_and_dense() {
        let d = dims();
        let mut seen = std::collections::HashSet::new();
        for p in 0..d.p {
            for r in 0..ROUNDS {
                for b in 0..BUFFERS {
                    for e in 0..d.e_local {
                        for c in 0..d.c {
                            let off = d.offset(Coord { p, r, b, e, c });
                            assert_eq!(off % d.h, 0);
                            assert!(seen.insert(off), "duplicate offset {off}");
                            assert!(off + d.h <= d.elems());
                        }
                    }
                }
            }
        }
        assert_eq!(seen.len() * d.h, d.elems(), "offsets tile L exactly");
    }

    #[test]
    fn validity_rules_definition_c2() {
        let d = dims();
        // inter-device write: p must equal src, b must be 1
        let good = Write { src: 2, dst: 0, coord: Coord { p: 2, r: 0, b: 1, e: 0, c: 0 }, rows: 32 };
        assert!(write_is_valid(&good, &d));
        let wrong_p = Write { coord: Coord { p: 1, ..good.coord }, ..good };
        assert!(!write_is_valid(&wrong_p, &d));
        let wrong_b = Write { coord: Coord { b: 0, ..good.coord }, ..good };
        assert!(!write_is_valid(&wrong_b, &d), "b=0 from remote src is invalid");
        // staging write must be rank-private
        let stage = Write { src: 3, dst: 3, coord: Coord { p: 0, r: 1, b: 0, e: 1, c: 32 }, rows: 32 };
        assert!(write_is_valid(&stage, &d));
        // self-looping inter-device write is fine (p == src, b == 1)
        let selfw = Write { src: 3, dst: 3, coord: Coord { p: 3, r: 0, b: 1, e: 0, c: 0 }, rows: 1 };
        assert!(write_is_valid(&selfw, &d));
        // overflow rows
        let over = Write { rows: 64, coord: Coord { c: 32, ..good.coord }, ..good };
        assert!(!write_is_valid(&over, &d));
    }

    #[test]
    fn theorem_3_1_cases() {
        let d = dims();
        // Case 2: distinct sources -> distinct p coordinate -> no overlap
        let w1 = Write { src: 1, dst: 0, coord: Coord { p: 1, r: 0, b: 1, e: 0, c: 0 }, rows: 64 };
        let w2 = Write { src: 2, dst: 0, coord: Coord { p: 2, r: 0, b: 1, e: 0, c: 0 }, rows: 64 };
        assert!(conflict_free(&w1, &w2, &d));
        // overlapping coords from distinct sources would conflict, but
        // validity forbids them: w3 forges p=1 while src=2
        let w3 = Write { src: 2, dst: 0, coord: Coord { p: 1, r: 0, b: 1, e: 0, c: 0 }, rows: 64 };
        assert!(!write_is_valid(&w3, &d));
        // same source, same cell: Case 1 (program order)
        assert!(conflict_free(&w1, &w1, &d));
    }

    #[test]
    fn variable_row_passes_fit_the_static_slot_region() {
        // dropless sizing: c = roundup(s_rank, bM); every s_r <= s_rank fits
        let m = ModelConfig {
            h: 8,
            d: 8,
            e: 4,
            k: 2,
            bm: 32,
            bn: 8,
            policy: crate::config::RoutingPolicy::Dropless,
        };
        let s_rank = 130;
        let d = LayoutDims { p: 2, e_local: 2, c: m.slot_capacity(s_rank), h: 8, bm: 32 };
        for rows in [0usize, 1, 31, 32, 33, 64, 129, 130] {
            assert!(d.fits_source_rows(rows), "{rows} rows must fit c={}", d.c);
        }
        assert!(!d.fits_source_rows(s_rank + 31), "beyond s_rank may overflow");
    }

    #[test]
    fn max_models_scales_the_expert_dimension() {
        let mut cfg = crate::config::Config::preset("tiny").unwrap();
        let one = LayoutDims::from_config(&cfg);
        cfg.set("max_models", "3").unwrap();
        let three = LayoutDims::from_config(&cfg);
        let band = cfg.local_experts() + cfg.replica_slots();
        assert_eq!(one.e_local, band, "max_models=1 is the legacy layout");
        assert_eq!(three.e_local, 3 * band, "one band per resident model slot");
        assert_eq!(three.elems(), 3 * one.elems());
        // bands are disjoint: model m's slots are [m*band, (m+1)*band)
        for m in 0..3 {
            for e in 0..band {
                assert!(three.in_bounds(Coord { p: 0, r: 0, b: 0, e: m * band + e, c: 0 }));
            }
        }
        assert!(!three.in_bounds(Coord { p: 0, r: 0, b: 0, e: 3 * band, c: 0 }));
    }

    #[test]
    fn flag_indices_unique() {
        let d = dims();
        let mut seen = std::collections::HashSet::new();
        for p in 0..d.p {
            for r in 0..ROUNDS {
                for e in 0..d.e_local {
                    for t in 0..d.tiles_per_expert() {
                        assert!(seen.insert(d.flag_index(p, r, e, t)));
                    }
                }
            }
        }
        assert_eq!(seen.len(), d.num_flags());
    }

    #[test]
    fn size_l_matches_paper_4x_rule() {
        // Paper: Size(L) ~= 4 * Size(T) when S/E >= bM. H=1024 f32 makes a
        // token 4KB — Table 3's Size(T) convention.
        let m = ModelConfig {
            h: 1024,
            d: 2048,
            e: 16,
            k: 1,
            bm: 128,
            bn: 64,
            policy: crate::config::RoutingPolicy::Capacity(1.0),
        };
        let rep = memory_report(4096, 16, &m, 8, WirePrecision::F32);
        let size_t = 4096.0 * 1024.0 * 4.0;
        assert_eq!(rep.ec, 256);
        assert_eq!(rep.c_aligned, 256);
        assert!((rep.size_l / size_t - 4.0).abs() < 1e-9, "got {}x", rep.size_l / size_t);
        // otherwise: 4 * bM*E/S * Size(T)
        let rep2 = memory_report(4096, 64, &m, 8, WirePrecision::F32);
        assert_eq!(rep2.c_aligned, 128); // EC=64 -> clamped to bM
        let expect = 4.0 * (128.0 * 64.0 / 4096.0) * size_t;
        assert!((rep2.size_l - expect).abs() < 1.0, "{} vs {expect}", rep2.size_l);
    }

    #[test]
    fn memory_total_grows_predictably() {
        let m = ModelConfig {
            h: 1024,
            d: 2048,
            e: 16,
            k: 1,
            bm: 128,
            bn: 64,
            policy: crate::config::RoutingPolicy::Capacity(1.0),
        };
        let r4k = memory_report(4096, 16, &m, 8, WirePrecision::F32);
        let r8k = memory_report(8192, 16, &m, 8, WirePrecision::F32);
        // doubling tokens doubles L
        assert!((r8k.size_l / r4k.size_l - 2.0).abs() < 1e-9);
        assert!(r8k.total() > r4k.total());
    }

    #[test]
    fn memory_report_tracks_the_wire_width() {
        let m = ModelConfig {
            h: 1024,
            d: 2048,
            e: 16,
            k: 1,
            bm: 128,
            bn: 64,
            policy: crate::config::RoutingPolicy::Capacity(1.0),
        };
        let r32 = memory_report(4096, 16, &m, 8, WirePrecision::F32);
        for wire in [WirePrecision::Bf16, WirePrecision::F16] {
            let r16 = memory_report(4096, 16, &m, 8, wire);
            assert_eq!(r16.wire, wire);
            // every element-width-derived line halves exactly
            assert!((r32.size_l / r16.size_l - 2.0).abs() < 1e-9, "{wire:?} Size(L)");
            // bookkeeping shrinks (scores + activation staging halve) but
            // not by a full 2x: flags, T_phi and descriptors are
            // width-free id/count structures
            assert!(r16.bookkeeping < r32.bookkeeping, "{wire:?} bookkeeping");
            let fixed_floor = (LayoutDims {
                p: 8,
                e_local: 2,
                c: r32.c_aligned,
                h: m.h,
                bm: m.bm,
            }
            .num_flags()
                * 8) as f64;
            assert!(r32.bookkeeping - r16.bookkeeping < r32.bookkeeping / 2.0);
            assert!(r16.bookkeeping > fixed_floor, "width-free lines survive");
            assert!(r16.total() < r32.total());
        }
    }
}
