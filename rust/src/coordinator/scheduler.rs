//! The Scheduler actor (paper Alg. 3): a work-conserving ready queue.
//!
//! The paper's scheduler warp sweeps doorbells and signals processor
//! blocks; the CPU analog is a blocking MPMC queue — processors park on a
//! condvar when idle and are woken the instant work exists, which is
//! exactly the work-conservation property (no processor idles while the
//! queue is non-empty). `stop_all` is the scheduler's interrupt broadcast
//! (Alg. 3 lines 33–34).
//!
//! Queues are resident: one `TaskQueue` serves a rank for the whole
//! engine lifetime. `stop_all` ends one pass (processors drain and park);
//! [`TaskQueue::reopen`] re-arms the queue for the next pass without
//! reallocating or re-spawning anything.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU32, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};

use crate::task::Task;

/// Blocking ready queue shared by one rank's actors.
pub struct TaskQueue {
    inner: Mutex<QueueState>,
    cv: Condvar,
    pushed: AtomicU32,
    popped: AtomicU32,
    /// High-water mark of queue depth (scheduling pressure metric).
    max_depth: AtomicUsize,
}

struct QueueState {
    tasks: VecDeque<Task>,
    stopped: bool,
}

impl Default for TaskQueue {
    fn default() -> Self {
        Self::new()
    }
}

impl TaskQueue {
    pub fn new() -> Self {
        Self {
            inner: Mutex::new(QueueState { tasks: VecDeque::new(), stopped: false }),
            cv: Condvar::new(),
            pushed: AtomicU32::new(0),
            popped: AtomicU32::new(0),
            max_depth: AtomicUsize::new(0),
        }
    }

    /// Enqueue one ready task and wake one parked processor.
    pub fn push(&self, t: Task) {
        let mut st = self.inner.lock().unwrap();
        st.tasks.push_back(t);
        let depth = st.tasks.len();
        drop(st);
        self.pushed.fetch_add(1, Ordering::Relaxed);
        self.max_depth.fetch_max(depth, Ordering::Relaxed);
        self.cv.notify_one();
    }

    /// Enqueue a batch (single lock acquisition) and wake enough workers.
    pub fn push_batch(&self, ts: impl IntoIterator<Item = Task>) {
        let mut st = self.inner.lock().unwrap();
        let mut n = 0u32;
        for t in ts {
            st.tasks.push_back(t);
            n += 1;
        }
        let depth = st.tasks.len();
        drop(st);
        if n == 0 {
            return;
        }
        self.pushed.fetch_add(n, Ordering::Relaxed);
        self.max_depth.fetch_max(depth, Ordering::Relaxed);
        if n == 1 {
            self.cv.notify_one();
        } else {
            self.cv.notify_all();
        }
    }

    /// Blocking pop; returns `None` only after `stop_all` with an empty
    /// queue (processors drain remaining work before exiting).
    pub fn pop(&self) -> Option<Task> {
        let mut st = self.inner.lock().unwrap();
        loop {
            if let Some(t) = st.tasks.pop_front() {
                self.popped.fetch_add(1, Ordering::Relaxed);
                return Some(t);
            }
            if st.stopped {
                return None;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Non-blocking pop (used by the subscriber's help-out path).
    pub fn try_pop(&self) -> Option<Task> {
        let mut st = self.inner.lock().unwrap();
        let t = st.tasks.pop_front();
        if t.is_some() {
            self.popped.fetch_add(1, Ordering::Relaxed);
        }
        t
    }

    /// Interrupt broadcast: wake everyone; pops drain then return `None`.
    pub fn stop_all(&self) {
        self.inner.lock().unwrap().stopped = true;
        self.cv.notify_all();
    }

    /// Re-arm a stopped queue for the next pass. The caller must have
    /// observed all consumers park (the rank actor waits for its
    /// processors' pass-done latch before reopening). Resets the per-pass
    /// depth high-water mark; push/pop totals stay cumulative.
    pub fn reopen(&self) {
        let mut st = self.inner.lock().unwrap();
        debug_assert!(st.tasks.is_empty(), "reopening a queue with undrained tasks");
        st.stopped = false;
        drop(st);
        self.max_depth.store(0, Ordering::Relaxed);
    }

    pub fn counts(&self) -> (u32, u32) {
        (self.pushed.load(Ordering::Relaxed), self.popped.load(Ordering::Relaxed))
    }

    pub fn max_depth(&self) -> usize {
        self.max_depth.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::task::{Task, TaskType};
    use std::sync::Arc;

    fn task(seq: u32) -> Task {
        Task { task_type: TaskType::FusedFfn, peer: 0, expert: 0, tile: 0, col: 0, rows: 1, seq }
    }

    #[test]
    fn fifo_order_single_consumer() {
        let q = TaskQueue::new();
        for i in 0..5 {
            q.push(task(i));
        }
        for i in 0..5 {
            assert_eq!(q.pop().unwrap().seq, i);
        }
        q.stop_all();
        assert!(q.pop().is_none());
    }

    #[test]
    fn every_task_consumed_exactly_once_under_contention() {
        let q = Arc::new(TaskQueue::new());
        let n_tasks = 10_000u32;
        let consumed = Arc::new(AtomicU32::new(0));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let q = q.clone();
            let consumed = consumed.clone();
            handles.push(std::thread::spawn(move || {
                let mut seen = Vec::new();
                while let Some(t) = q.pop() {
                    seen.push(t.seq);
                    consumed.fetch_add(1, Ordering::Relaxed);
                }
                seen
            }));
        }
        for i in 0..n_tasks {
            q.push(task(i));
        }
        q.stop_all();
        let mut all: Vec<u32> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..n_tasks).collect::<Vec<_>>(), "each task exactly once");
        let (pushed, popped) = q.counts();
        assert_eq!(pushed, n_tasks);
        assert_eq!(popped, n_tasks);
    }

    #[test]
    fn stop_drains_pending_work() {
        let q = TaskQueue::new();
        q.push_batch((0..3).map(task));
        q.stop_all();
        // all 3 must still be deliverable post-stop
        assert!(q.pop().is_some());
        assert!(q.pop().is_some());
        assert!(q.pop().is_some());
        assert!(q.pop().is_none());
    }

    #[test]
    fn reopen_rearms_a_stopped_queue() {
        let q = TaskQueue::new();
        q.push(task(0));
        q.stop_all();
        assert!(q.pop().is_some(), "drain before park");
        assert!(q.pop().is_none(), "pass 1 over");
        q.reopen();
        q.push(task(1));
        assert_eq!(q.pop().unwrap().seq, 1, "pass 2 delivers");
        assert_eq!(q.max_depth(), 1, "depth high-water is per pass");
        q.stop_all();
        assert!(q.pop().is_none());
    }

    #[test]
    fn max_depth_tracks_pressure() {
        let q = TaskQueue::new();
        q.push_batch((0..7).map(task));
        assert_eq!(q.max_depth(), 7);
    }
}
