"""L1 correctness: every Pallas kernel vs the pure-numpy oracle.

Hypothesis sweeps shapes (and value distributions); each kernel must match
``ref.py`` to f32 tolerance across the sweep.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import combine as combine_k
from compile.kernels import ffn as ffn_k
from compile.kernels import gate as gate_k
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


def rnd(rng, *shape, scale=1.0):
    return (rng.normal(size=shape) * scale).astype(np.float32)


@given(
    tiles=st.integers(1, 4),
    bm=st.sampled_from([8, 16, 32]),
    h=st.sampled_from([8, 32, 64]),
    e=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_gate_scores_matches_ref(tiles, bm, h, e, seed):
    rng = np.random.default_rng(seed)
    a, wg = rnd(rng, tiles * bm, h), rnd(rng, h, e)
    got = np.asarray(gate_k.gate_scores(jnp.array(a), jnp.array(wg), bm=bm))
    np.testing.assert_allclose(got, ref.ref_gate(a, wg), rtol=1e-5, atol=1e-5)
    # scores are a row distribution
    np.testing.assert_allclose(got.sum(axis=-1), 1.0, rtol=1e-5)


@given(
    mt=st.integers(1, 3),
    nt=st.integers(1, 3),
    bm=st.sampled_from([8, 32]),
    bn=st.sampled_from([8, 32]),
    kdim=st.sampled_from([16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_gemm0_matches_ref(mt, nt, bm, bn, kdim, seed):
    rng = np.random.default_rng(seed)
    x, w, b = rnd(rng, mt * bm, kdim), rnd(rng, kdim, nt * bn), rnd(rng, nt * bn)
    got = np.asarray(ffn_k.gemm0(jnp.array(x), jnp.array(w), jnp.array(b), bm=bm, bn=bn))
    np.testing.assert_allclose(got, ref.ref_gemm0(x, w, b), rtol=1e-4, atol=1e-4)
    assert (got >= 0).all(), "relu epilogue must clamp"


@given(
    mt=st.integers(1, 3),
    bm=st.sampled_from([8, 32]),
    bn=st.sampled_from([8, 32]),
    kdim=st.sampled_from([16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_gemm1_matches_ref(mt, bm, bn, kdim, seed):
    rng = np.random.default_rng(seed)
    x, w, b = rnd(rng, mt * bm, kdim), rnd(rng, kdim, bn), rnd(rng, bn)
    got = np.asarray(ffn_k.gemm1(jnp.array(x), jnp.array(w), jnp.array(b), bm=bm, bn=bn))
    np.testing.assert_allclose(got, ref.ref_gemm1(x, w, b), rtol=1e-4, atol=1e-4)


@given(
    mt=st.integers(1, 4),
    bm=st.sampled_from([8, 32]),
    h=st.sampled_from([16, 64]),
    d=st.sampled_from([16, 128]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_ffn_block_matches_ref(mt, bm, h, d, seed):
    rng = np.random.default_rng(seed)
    x = rnd(rng, mt * bm, h)
    w1, b1, w2, b2 = rnd(rng, h, d), rnd(rng, d), rnd(rng, d, h), rnd(rng, h)
    got = np.asarray(
        ffn_k.ffn_block(*map(jnp.array, (x, w1, b1, w2, b2)), bm=bm)
    )
    np.testing.assert_allclose(got, ref.ref_ffn(x, w1, b1, w2, b2), rtol=1e-3, atol=1e-3)


@given(
    mt=st.integers(1, 4),
    bm=st.sampled_from([8, 32]),
    h=st.sampled_from([16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_combine_matches_ref(mt, bm, h, seed):
    rng = np.random.default_rng(seed)
    acc, x, s = rnd(rng, mt * bm, h), rnd(rng, mt * bm, h), rnd(rng, mt * bm, 1)
    got = np.asarray(combine_k.combine(*map(jnp.array, (acc, x, s)), bm=bm))
    np.testing.assert_allclose(got, ref.ref_combine(acc, x, s), rtol=1e-5, atol=1e-6)


def test_combine_zero_scale_is_identity():
    rng = np.random.default_rng(0)
    acc, x = rnd(rng, 32, 16), rnd(rng, 32, 16)
    s = np.zeros((32, 1), np.float32)
    got = np.asarray(combine_k.combine(*map(jnp.array, (acc, x, s)), bm=32))
    np.testing.assert_array_equal(got, acc)


def test_ffn_block_equals_split_gemms():
    """Fused task mode must equal the paper's split GEMM0->GEMM1 chain."""
    rng = np.random.default_rng(3)
    x = rnd(rng, 64, 32)
    w1, b1, w2, b2 = rnd(rng, 32, 48), rnd(rng, 48), rnd(rng, 48, 32), rnd(rng, 32)
    fused = np.asarray(ffn_k.ffn_block(*map(jnp.array, (x, w1, b1, w2, b2)), bm=32))
    h = ffn_k.gemm0(jnp.array(x), jnp.array(w1), jnp.array(b1), bm=32, bn=16)
    split = np.asarray(ffn_k.gemm1(h, jnp.array(w2), jnp.array(b2), bm=32, bn=16))
    np.testing.assert_allclose(fused, split, rtol=1e-4, atol=1e-4)


@given(
    s=st.sampled_from([16, 64]),
    e=st.sampled_from([4, 8]),
    k=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_topk_matches_ref(s, e, k, seed):
    rng = np.random.default_rng(seed)
    scores = ref.ref_gate(rnd(rng, s, 16), rnd(rng, 16, e))
    idx, w = gate_k.topk_route(jnp.array(scores), k)
    ridx, rw = ref.ref_topk(scores, k)
    np.testing.assert_array_equal(np.asarray(idx), ridx)
    np.testing.assert_allclose(np.asarray(w), rw, rtol=1e-6)


def test_topk_tie_break_lower_index():
    scores = np.array([[0.25, 0.25, 0.25, 0.25]], np.float32)
    idx, _ = gate_k.topk_route(jnp.array(scores), 2)
    assert list(np.asarray(idx)[0]) == [0, 1]
    ridx, _ = ref.ref_topk(scores, 2)
    assert list(ridx[0]) == [0, 1]
