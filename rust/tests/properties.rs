//! Property-based tests (via `util::check::forall`) over the paper's key
//! invariants: Theorem 3.1 write-conflict freedom, gate/capacity/routing
//! invariants, scheduler work conservation, task-bound termination, and
//! the `RoutingPolicy::Dropless` conformance contract (engine output ==
//! dense per-token reference, zero drops, full weight-mass preservation).

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;

use flashdmoe::config::{
    Config, CostModel, DispatchMode, FaultConfig, ModelConfig, ReplicationPolicy, RoutingPolicy,
    SystemConfig, TrainConfig, WirePrecision,
};
use flashdmoe::coordinator::scheduler::TaskQueue;
use flashdmoe::coordinator::{MoeEngine, TaskGraphMode};
use flashdmoe::expert::{generate_tokens, ModelParams};
use flashdmoe::gate::{dispatch_plan, route_from_scores};
use flashdmoe::layout::{conflict_free, write_is_valid, Coord, LayoutDims, Write, BUFFERS, ROUNDS};
use flashdmoe::placement::Placement;
use flashdmoe::runtime::{ComputeBackend, NativeBackend};
use flashdmoe::task::{Task, TaskBound, TaskType};
use flashdmoe::util::check::{dense_reference_moe, forall, Gen};
use flashdmoe::util::prng::Rng;
use flashdmoe::util::stats::max_abs_diff;

// ---------------------------------------------------------------------------
// Theorem 3.1: random *valid* writes from distinct sources never overlap
// ---------------------------------------------------------------------------

fn random_dims(g: &mut Gen) -> LayoutDims {
    let bm = g.choose(&[2usize, 4, 8]);
    LayoutDims {
        p: g.int(1, 8),
        e_local: g.int(1, 4),
        c: bm * g.int(1, 4),
        h: g.int(1, 16),
        bm,
    }
}

fn random_valid_write(g: &mut Gen, dims: &LayoutDims) -> Write {
    // generate writes *per the validity rules* (Definition C.2)
    let src = g.int(0, dims.p - 1);
    let inter = g.int(0, 1) == 1;
    let (p, b, dst) = if inter {
        (src, 1, g.int(0, dims.p - 1))
    } else {
        (g.int(0, dims.p - 1), 0, src)
    };
    let tile = g.int(0, dims.tiles_per_expert() - 1);
    let rows = g.int(1, dims.bm);
    Write {
        src,
        dst,
        coord: Coord {
            p,
            r: g.int(0, ROUNDS - 1),
            b,
            e: g.int(0, dims.e_local - 1),
            c: tile * dims.bm,
        },
        rows,
    }
}

#[test]
fn theorem_3_1_random_valid_writes_are_conflict_free() {
    forall(
        0xC0FFEE,
        500,
        |g| {
            let dims = random_dims(g);
            let writes: Vec<Write> =
                (0..g.int(2, 20)).map(|_| random_valid_write(g, &dims)).collect();
            (dims, writes)
        },
        |(dims, writes)| {
            for w in writes {
                if !write_is_valid(w, dims) {
                    return Err(format!("generator produced invalid write {w:?}"));
                }
            }
            for (i, a) in writes.iter().enumerate() {
                for b in &writes[i + 1..] {
                    if a.src != b.src && !conflict_free(a, b, dims) {
                        return Err(format!("conflict between {a:?} and {b:?}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn forged_writes_are_always_rejected() {
    forall(
        0xBAD,
        500,
        |g| {
            let dims = random_dims(g);
            let mut w = random_valid_write(g, &dims);
            // forge: claim another peer's slot on a remote write
            w.coord.b = 1;
            w.coord.p = (w.src + 1 + g.int(0, dims.p.saturating_sub(1))) % dims.p.max(2);
            (dims, w)
        },
        |(dims, w)| {
            if w.coord.p != w.src && write_is_valid(w, dims) {
                return Err(format!("forged write accepted: {w:?}"));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Gate invariants
// ---------------------------------------------------------------------------

fn random_routing(g: &mut Gen) -> (ModelConfig, usize, Vec<f32>, usize) {
    let e = g.choose(&[2usize, 4, 8, 16]);
    let k = 1 + g.int(0, (e - 1).min(3));
    let bm = g.choose(&[2usize, 4, 8]);
    let s = bm * g.int(1, 16);
    let capacity = bm * g.int(1, 8);
    let model = ModelConfig { h: 4, d: 8, e, k, bm, bn: 4, policy: RoutingPolicy::Capacity(1.0) };
    let mut rng = Rng::new(g.int(0, u32::MAX as usize) as u64);
    let mut scores = rng.normal_vec(s * e, 1.0);
    flashdmoe::gate::softmax_rows(&mut scores, e);
    (model, s, scores, capacity)
}

#[test]
fn routing_invariants_hold() {
    forall(
        0x9A7E,
        300,
        |g| random_routing(g),
        |(model, s, scores, capacity)| {
            let r = route_from_scores(scores.clone(), *s, model, *capacity);
            // (1) kept + dropped == S*k
            if r.routes.len() + r.dropped != s * model.k {
                return Err("kept+dropped != S*k".into());
            }
            // (2) per-expert loads never exceed capacity
            for (e, &load) in r.expert_load.iter().enumerate() {
                if load as usize > *capacity {
                    return Err(format!("expert {e} over capacity: {load}"));
                }
            }
            // (3) slots within an expert are unique and dense 0..load
            for e in 0..model.e {
                let mut slots: Vec<u32> = r
                    .routes
                    .iter()
                    .filter(|x| x.expert as usize == e)
                    .map(|x| x.slot)
                    .collect();
                slots.sort_unstable();
                for (i, s2) in slots.iter().enumerate() {
                    if *s2 as usize != i {
                        return Err(format!("expert {e} slots not dense: {slots:?}"));
                    }
                }
            }
            // (4) combine weights of a token's kept routes never exceed 1
            let mut per_token = vec![0.0f32; *s];
            for x in &r.routes {
                per_token[x.token as usize] += x.combine_weight;
            }
            if per_token.iter().any(|w| *w > 1.0 + 1e-4) {
                return Err("combine weights exceed 1".into());
            }
            Ok(())
        },
    );
}

#[test]
fn dispatch_plan_partitions_routes() {
    forall(
        0xD15,
        200,
        |g| random_routing(g),
        |(model, s, scores, capacity)| {
            let r = route_from_scores(scores.clone(), *s, model, *capacity);
            let plan = dispatch_plan(&r, model.bm, &Placement::balanced(model.e, 2, 0));
            let covered: usize = plan.tiles.iter().map(|t| t.tokens.len()).sum();
            if covered != r.routes.len() {
                return Err(format!("plan covers {covered}, routes {}", r.routes.len()));
            }
            if plan.sent_rows > plan.padded_rows {
                return Err("sent more than padded?".into());
            }
            for t in &plan.tiles {
                if t.rows == 0 || t.rows as usize > model.bm {
                    return Err(format!("bad tile rows {}", t.rows));
                }
                if t.tokens.len() != t.weights.len() {
                    return Err("tokens/weights arity mismatch".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn offered_load_sums_to_s_times_k_under_both_policies() {
    // The skew-telemetry contract: `offered_load` counts every (token,
    // expert) pair *before* the capacity clamp, so it always sums to S·k
    // and decomposes as kept + dropped per expert — under Capacity (where
    // kept saturates) and Dropless (where offered == kept) alike.
    forall(
        0x0FFE,
        300,
        |g| {
            let (model, s, scores, capacity) = random_routing(g);
            let dropless = g.int(0, 1) == 1;
            (model, s, scores, capacity, dropless)
        },
        |(model, s, scores, capacity, dropless)| {
            let mut m = model.clone();
            let cap = if *dropless {
                m.policy = RoutingPolicy::Dropless;
                m.slot_capacity(*s)
            } else {
                *capacity
            };
            let r = route_from_scores(scores.clone(), *s, &m, cap);
            let offered: u64 = r.offered_load.iter().map(|&x| x as u64).sum();
            if offered != (s * m.k) as u64 {
                return Err(format!("offered sums to {offered}, want {}", s * m.k));
            }
            let kept: u64 = r.expert_load.iter().map(|&x| x as u64).sum();
            if kept + r.dropped as u64 != offered {
                return Err(format!(
                    "kept {kept} + dropped {} != offered {offered}",
                    r.dropped
                ));
            }
            for e in 0..m.e {
                if r.offered_load[e] < r.expert_load[e] {
                    return Err(format!(
                        "expert {e}: offered {} below kept {}",
                        r.offered_load[e], r.expert_load[e]
                    ));
                }
            }
            if *dropless && r.offered_load != r.expert_load {
                return Err("dropless: offered must equal kept".into());
            }
            Ok(())
        },
    );
}

#[test]
fn gate_survives_nan_and_inf_scores() {
    // The NaN/Inf fuzz: arbitrary non-finite garbage in the raw gate
    // logits must never panic (`topk_rows` used to die on
    // `partial_cmp().unwrap()`), and routing must still offer every
    // token's full top-k fan-out — non-finite rows fall back to uniform
    // scores rather than vanishing.
    forall(
        0xFA7A1,
        300,
        |g| {
            let e = g.choose(&[2usize, 4, 8]);
            let k = 1 + g.int(0, e - 1);
            let bm = g.choose(&[2usize, 4]);
            let s = bm * g.int(1, 8);
            let mut rng = Rng::new(g.int(0, u32::MAX as usize) as u64);
            let mut logits = rng.normal_vec(s * e, 1.0);
            // poison a random subset with the full non-finite menagerie
            let n_poison = g.int(0, logits.len());
            for _ in 0..n_poison {
                let i = g.int(0, logits.len() - 1);
                logits[i] = *g.choose(&[
                    f32::NAN,
                    f32::INFINITY,
                    f32::NEG_INFINITY,
                    -0.0,
                    f32::MAX,
                ]);
            }
            let model = ModelConfig {
                h: 4,
                d: 8,
                e,
                k,
                bm,
                bn: 4,
                policy: RoutingPolicy::Dropless,
            };
            (model, s, logits)
        },
        |(model, s, logits)| {
            // softmax_rows + route_from_scores is the engine's gate path;
            // catch_unwind would mask the panic location, so just call it —
            // a panic here fails the property outright.
            let mut scores = logits.clone();
            flashdmoe::gate::softmax_rows(&mut scores, model.e);
            if scores.iter().any(|v| !v.is_finite()) {
                return Err("softmax left non-finite scores".into());
            }
            let cap = model.slot_capacity(*s);
            let r = route_from_scores(scores, *s, model, cap);
            let offered: u64 = r.offered_load.iter().map(|&x| x as u64).sum();
            if offered != (s * model.k) as u64 {
                return Err(format!(
                    "poisoned gate offered {offered}, want {} — rows went missing",
                    s * model.k
                ));
            }
            if r.dropped != 0 {
                return Err(format!("dropless dropped {}", r.dropped));
            }
            // every token keeps its k routes with finite combine weights
            let mut per_token = vec![0usize; *s];
            for x in &r.routes {
                per_token[x.token as usize] += 1;
                if !x.combine_weight.is_finite() {
                    return Err(format!("non-finite combine weight on token {}", x.token));
                }
            }
            if per_token.iter().any(|&n| n != model.k) {
                return Err("a token lost part of its top-k fan-out".into());
            }
            // and the dispatch plan still covers everything
            let plan = dispatch_plan(&r, model.bm, &Placement::balanced(model.e, 1, 0));
            let covered: usize = plan.tiles.iter().map(|t| t.tokens.len()).sum();
            if covered != r.routes.len() {
                return Err(format!("plan covers {covered} of {}", r.routes.len()));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Dropless conformance: zero drops, weight mass preserved, dense-equal
// ---------------------------------------------------------------------------

#[test]
fn dropless_routing_keeps_every_pair_and_all_weight_mass() {
    forall(
        0xD801,
        300,
        |g| random_routing(g),
        |(model, s, scores, _)| {
            let mut m = model.clone();
            m.policy = RoutingPolicy::Dropless;
            let cap = m.slot_capacity(*s);
            let r = route_from_scores(scores.clone(), *s, &m, cap);
            if r.dropped != 0 {
                return Err(format!("dropless routing dropped {}", r.dropped));
            }
            if r.routes.len() != s * m.k {
                return Err(format!("kept {} of {} pairs", r.routes.len(), s * m.k));
            }
            // every token's combine weight mass is fully preserved
            let mut per_token = vec![0.0f32; *s];
            for x in &r.routes {
                per_token[x.token as usize] += x.combine_weight;
            }
            if let Some(w) = per_token.iter().find(|w| (**w - 1.0).abs() > 1e-4) {
                return Err(format!("token weight mass {w} != 1"));
            }
            // the variable tile list covers every pair exactly once, full
            // tiles followed by one partially-filled tail per expert
            let plan = dispatch_plan(&r, m.bm, &Placement::balanced(m.e, 2, 0));
            let covered: usize = plan.tiles.iter().map(|t| t.tokens.len()).sum();
            if covered != r.routes.len() {
                return Err(format!("plan covers {covered}, routes {}", r.routes.len()));
            }
            for (e, load) in r.expert_load.iter().enumerate() {
                let ntiles =
                    plan.tiles.iter().filter(|t| t.expert as usize == e).count();
                if ntiles != (*load as usize).div_ceil(m.bm) {
                    return Err(format!(
                        "expert {e}: load {load} but {ntiles} tiles (bm {})",
                        m.bm
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn dropless_engine_matches_dense_reference_under_fuzzed_skew() {
    // End-to-end conformance: under `Dropless`, a real engine pass over
    // fuzzed (ranks × experts × skewed gate) configurations must compute
    // the same function as the dense per-token reference MoE — every
    // routed token's weight mass preserved — and report zero drops.
    // Engine-spawning cases are heavier than pure-math properties, so the
    // fleet is small; shapes stay tiny to keep the suite fast.
    forall(
        0xD802,
        6,
        |g| {
            let ranks = g.choose(&[1usize, 2]);
            let e = ranks * g.choose(&[2usize, 4]);
            let k = 1 + g.int(0, (e - 1).min(2));
            let bm = g.choose(&[4usize, 8]);
            let s_rank = bm * g.int(1, 4);
            let seed = g.int(0, 1 << 16) as u64;
            (ranks, e, k, bm, s_rank, seed)
        },
        |&(ranks, e, k, bm, s_rank, seed)| {
            let cfg = Config {
                model: ModelConfig { h: 8, d: 8, e, k, bm, bn: 4, policy: RoutingPolicy::Dropless },
                system: SystemConfig {
                    ranks,
                    nodes: 1,
                    s_rank,
                    processors: 2,
                    packed: true,
                    wire: WirePrecision::F32,
                    dispatch: DispatchMode::Flat,
                    replication: ReplicationPolicy::default(),
                    watchdog_secs: 120,
                    retry_limit: 0,
                    fault: FaultConfig::default(),
                    train: TrainConfig::default(),
                },
                cost: CostModel::h100_nvlink(),
            };
            cfg.validate().map_err(|err| err.to_string())?;
            let params = Arc::new(ModelParams::generate(&cfg, seed));
            let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(&cfg));
            // skew the gate: bias every token along one embedding lane so
            // routing concentrates on a few experts — the regime where the
            // Capacity policy would drop and change the function
            let inputs: Vec<Vec<f32>> = (0..ranks)
                .map(|r| {
                    let mut v = generate_tokens(&cfg, seed, r);
                    for x in v.iter_mut().step_by(cfg.model.h) {
                        *x += 2.5;
                    }
                    v
                })
                .collect();
            let engine =
                MoeEngine::start(cfg.clone(), params.clone(), backend, TaskGraphMode::Fused)
                    .map_err(|err| err.to_string())?;
            let res = engine.forward(&inputs).map_err(|err| err.to_string())?;
            if res.metrics.total_dropped() != 0 {
                return Err(format!("dropless pass dropped {}", res.metrics.total_dropped()));
            }
            for (r, out) in res.outputs.iter().enumerate() {
                let want = dense_reference_moe(&cfg, &params, &inputs[r]);
                let diff = max_abs_diff(out, &want);
                if diff > 1e-5 {
                    return Err(format!("rank {r}: engine vs dense reference diff {diff}"));
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Packed GEMM: equal to the naive reference over randomized shapes
// ---------------------------------------------------------------------------

#[test]
fn packed_gemm_equals_naive_over_randomized_shapes() {
    use flashdmoe::gemm::{
        gemm_bias_packed, gemm_bias_packed_cols, gemm_naive, Epilogue, PackedWeights, MR, NR,
    };
    // Shapes deliberately straddle the register-tile and panel boundaries:
    // m around MR multiples, n around NR multiples, k crossing KC — every
    // edge-tile path in the packed kernel gets exercised. Equality is
    // exact (not within-tolerance): the packed kernel replays the naive
    // k-ascending accumulation order per output element.
    forall(
        0x9ACC,
        200,
        |g| {
            let m = g.int(1, 3 * MR + 2);
            let k = g.int(1, 300); // > KC/2 sometimes; a few cross 256
            let n = g.int(1, 3 * NR + 2);
            let seed = g.int(0, u32::MAX as usize) as u64;
            let with_bias = g.int(0, 1) == 1;
            let relu = g.int(0, 1) == 1;
            (m, k, n, seed, with_bias, relu)
        },
        |&(m, k, n, seed, with_bias, relu)| {
            let mut rng = Rng::new(seed);
            let a = rng.normal_vec(m * k, 1.0);
            let b = rng.normal_vec(k * n, 1.0);
            let bias = rng.normal_vec(n, 1.0);
            let bp = PackedWeights::pack(&b, k, n);
            let epi = if relu { Epilogue::Relu } else { Epilogue::Identity };
            // reference: naive GEMM + explicit epilogue
            let mut want = vec![0.0f32; m * n];
            gemm_naive(&a, &b, &mut want, m, k, n);
            for i in 0..m {
                for j in 0..n {
                    let mut v = want[i * n + j];
                    if with_bias {
                        v += bias[j];
                    }
                    if relu && v < 0.0 {
                        v = 0.0;
                    }
                    want[i * n + j] = v;
                }
            }
            // packed full-width, into a poisoned C (single-write-back proof)
            let mut got = vec![f32::NAN; m * n];
            gemm_bias_packed(&a, &bp, with_bias.then_some(&bias[..]), &mut got, m, epi);
            if got != want {
                return Err(format!("packed != naive at ({m},{k},{n})"));
            }
            // packed NR-aligned column slices must reproduce their columns
            let mut col0 = 0;
            while col0 < n {
                let width = NR.min(n - col0);
                let mut tile = vec![f32::NAN; m * width];
                gemm_bias_packed_cols(
                    &a,
                    &bp,
                    col0,
                    width,
                    with_bias.then_some(&bias[col0..col0 + width]),
                    &mut tile,
                    width,
                    m,
                    epi,
                );
                for r in 0..m {
                    if tile[r * width..(r + 1) * width] != want[r * n + col0..r * n + col0 + width]
                    {
                        return Err(format!("col slice {col0} mismatch at ({m},{k},{n})"));
                    }
                }
                col0 += width;
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Scheduler: work conservation & exactly-once delivery under contention
// ---------------------------------------------------------------------------

#[test]
fn scheduler_delivers_exactly_once_under_random_schedules() {
    // Work-stealing pool: random worker counts, random mixes of external
    // (round-robin) pushes, owner-local pushes and subscriber steals —
    // every task must be delivered exactly once, then the pool drains.
    forall(
        0x5C4ED,
        40,
        |g| (g.int(1, 8), g.int(0, 500), g.int(0, 3)),
        |&(workers, n_tasks, style)| {
            let q = Arc::new(TaskQueue::new(workers));
            let delivered = Arc::new(AtomicU32::new(0));
            let handles: Vec<_> = (0..workers)
                .map(|slot| {
                    let q = q.clone();
                    let delivered = delivered.clone();
                    std::thread::spawn(move || {
                        while q.pop(slot).is_some() {
                            delivered.fetch_add(1, Ordering::Relaxed);
                        }
                    })
                })
                .collect();
            let mk = |i: usize| Task {
                task_type: TaskType::Combine,
                peer: 0,
                expert: 0,
                tile: 0,
                col: 0,
                rows: 1,
                seq: i as u32,
            };
            match style {
                0 => {
                    for i in 0..n_tasks {
                        q.push(mk(i));
                    }
                }
                1 => q.push_batch((0..n_tasks).map(mk)),
                // adversarial: everything lands on one deque; delivery
                // relies on stealing
                _ => q.push_batch_local(0, (0..n_tasks).map(mk)),
            }
            // the producer side may also help out as a thief
            let mut stolen = 0usize;
            if style == 2 {
                while q.steal().is_some() {
                    stolen += 1;
                    delivered.fetch_add(1, Ordering::Relaxed);
                }
            }
            q.stop_all();
            for h in handles {
                h.join().unwrap();
            }
            let got = delivered.load(Ordering::Relaxed) as usize;
            if got != n_tasks {
                return Err(format!("delivered {got} of {n_tasks} (stole {stolen})"));
            }
            let (pushed, popped) = q.counts();
            if pushed != popped {
                return Err(format!("pushed {pushed} != popped {popped}"));
            }
            Ok(())
        },
    );
}

#[test]
fn task_bound_terminates_iff_finalized_and_complete() {
    forall(
        0x7B0,
        300,
        |g| {
            let adds: Vec<u32> = (0..g.int(1, 10)).map(|_| g.int(0, 50) as u32).collect();
            let finalize_at = g.int(0, adds.len());
            (adds, finalize_at)
        },
        |(adds, finalize_at)| {
            let tb = TaskBound::new();
            let mut total = 0u32;
            for (i, &n) in adds.iter().enumerate() {
                if i == *finalize_at {
                    tb.finalize();
                }
                tb.add(n);
                total += n;
                if tb.done() && total > tb.progress().0 {
                    return Err("done before all work completed".into());
                }
                tb.complete(n);
            }
            if *finalize_at >= adds.len() {
                if tb.done() {
                    return Err("done without finalize".into());
                }
                tb.finalize();
            }
            if !tb.done() {
                return Err(format!("not done after {total} completions"));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Layout offsets: random coordinates map to disjoint rows
// ---------------------------------------------------------------------------

#[test]
fn layout_offsets_are_injective() {
    forall(
        0x0FF5,
        200,
        |g| {
            let dims = random_dims(g);
            let coords: Vec<Coord> = (0..g.int(2, 30))
                .map(|_| Coord {
                    p: g.int(0, dims.p - 1),
                    r: g.int(0, ROUNDS - 1),
                    b: g.int(0, BUFFERS - 1),
                    e: g.int(0, dims.e_local - 1),
                    c: g.int(0, dims.c - 1),
                })
                .collect();
            (dims, coords)
        },
        |(dims, coords)| {
            for (i, a) in coords.iter().enumerate() {
                for b in &coords[i + 1..] {
                    if a != b && dims.offset(*a) == dims.offset(*b) {
                        return Err(format!("offset collision: {a:?} vs {b:?}"));
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Incast bound: measured inter-node bytes never exceed the announced volume
// ---------------------------------------------------------------------------

#[test]
fn measured_inter_node_bytes_never_exceed_announced_volume() {
    // Per pass and per rank, the dispatch loop announces its inter-node
    // volume up front (per-tile bytes in flat mode, per-node coalesced
    // unique bytes + combine returns in both). The NIC receive windows
    // admit traffic against exactly that promise, so the *measured*
    // inter-class byte counters must stay at or below the announced sum —
    // over fuzzed token counts, top-k fan-outs and both dispatch modes.
    // Engine-spawning cases are heavy (8 ranks x 4 nodes), so the fleet
    // is small.
    forall(
        0x1CA57,
        4,
        |g| {
            let tokens = g.choose(&[32usize, 48, 64]);
            let hier = g.int(0, 1) == 1;
            let k = g.choose(&[1usize, 2]);
            let seed = g.int(0, 1 << 16) as u64;
            (tokens, hier, k, seed)
        },
        |&(tokens, hier, k, seed)| {
            let mut cfg =
                flashdmoe::harness::multinode_config(tokens).map_err(|e| e.to_string())?;
            cfg.set("dispatch", if hier { "hier" } else { "flat" })
                .map_err(|e| e.to_string())?;
            cfg.set("k", &k.to_string()).map_err(|e| e.to_string())?;
            cfg.validate().map_err(|e| e.to_string())?;
            let params = Arc::new(ModelParams::generate(&cfg, seed));
            let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(&cfg));
            let inputs: Vec<Vec<f32>> =
                (0..cfg.system.ranks).map(|r| generate_tokens(&cfg, seed, r)).collect();
            let engine =
                MoeEngine::start(cfg.clone(), params.clone(), backend, TaskGraphMode::Fused)
                    .map_err(|e| e.to_string())?;
            for pass in 0..2 {
                let res = engine.forward(&inputs).map_err(|e| e.to_string())?;
                let m = &res.metrics;
                if m.inter_bytes() > m.announced_inter_bytes() {
                    return Err(format!(
                        "pass {pass} ({tokens} tok, hier={hier}, k={k}): measured inter {} \
                         exceeds announced {}",
                        m.inter_bytes(),
                        m.announced_inter_bytes()
                    ));
                }
                // the measured MIV is a max over ranks of the same counters,
                // so it is bounded by the pass-wide inter sum
                if m.miv_bytes() > m.inter_bytes() {
                    return Err(format!(
                        "pass {pass}: MIV {} exceeds total inter bytes {}",
                        m.miv_bytes(),
                        m.inter_bytes()
                    ));
                }
                if cfg.system.dispatch.is_hierarchical() && m.inter_bytes() == 0 {
                    return Err("hierarchical pass moved zero inter-node bytes?".into());
                }
            }
            Ok(())
        },
    );
}
