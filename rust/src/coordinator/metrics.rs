//! Per-rank, per-pass and engine-lifetime metrics: the measured analogs
//! of the paper's evaluation quantities (SM utilization, latency, payload
//! efficiency, and — for the persistent engine — Table 1's launch count).
//!
//! Four granularities:
//! * [`RankMetrics`]    — one rank, one pass (busy/idle, tasks, traffic).
//! * [`PassMetrics`]    — one epoch-tagged pass across all ranks,
//!   including the pass's *fill*: passes submitted through the
//!   variable-shape [`PassInput`] path may run with `s_r < s_rank` rows
//!   on some ranks, and [`PassMetrics::batch_fill`] reports how much of
//!   the engine's row capacity the pass actually used (1.0 by contract
//!   for the legacy fixed-shape `submit`).
//! * [`EngineMetrics`]  — cumulative over a [`MoeEngine`] lifetime:
//!   passes served, steady-state busy/wall, resident thread census, and
//!   the launch-equivalent count, which is exactly 1 — the actors are
//!   launched once at `MoeEngine::start` and every subsequent pass is a
//!   doorbell ring, not a launch.
//! * [`ServiceMetrics`] — cumulative over a [`MoeService`] lifetime:
//!   request admission/rejection/cancellation counts, tokens served,
//!   mean pass fill, and the peak request-queue depth.
//!
//! [`MoeEngine`]: super::engine::MoeEngine
//! [`PassInput`]: super::engine::PassInput
//! [`MoeService`]: super::service::MoeService

use crate::config::WirePrecision;

/// Fraction of a padded baseline avoided (0.0 when the baseline is
/// empty). Unit-agnostic: callers pass rows (padding-only savings) or
/// bytes (padding + wire-narrowing savings).
fn savings(sent: usize, padded: usize) -> f64 {
    if padded == 0 {
        return 0.0;
    }
    1.0 - sent as f64 / padded as f64
}

/// Metrics for one rank over one forward pass.
#[derive(Clone, Debug, Default)]
pub struct RankMetrics {
    /// Sum of processor task-execution time (seconds) across workers.
    pub busy_secs: f64,
    /// Rank wall time for the pass.
    pub wall_secs: f64,
    /// Processor workers on this rank.
    pub processors: usize,
    /// Token rows this rank was submitted for the pass (`s_r`). Equal to
    /// `s_rank` on the fixed-shape path; possibly smaller — or zero, for
    /// a rank that only serves its experts — under a variable-shape
    /// [`PassInput`](super::engine::PassInput) pass.
    pub rows_in: usize,
    /// Tasks executed, by kind.
    pub ffn_tasks: u32,
    pub gemm_tasks: u32,
    pub combine_tasks: u32,
    /// Backward data-gradient tile tasks (`Dgrad0`/`Dgrad1`) executed on
    /// this rank — 0 for a forward pass.
    pub dgrad_tasks: u32,
    /// Backward weight-gradient tile tasks (`Wgrad0`/`Wgrad1`) executed
    /// on this rank — 0 for a forward pass.
    pub wgrad_tasks: u32,
    /// Mean per-token entropy (nats) of this rank's post-softmax gate
    /// distribution, over the rows it routed — the load-balance health
    /// signal a training loop watches for gate collapse. 0.0 for a rank
    /// that routed nothing (and for backward passes, which do not gate).
    pub gate_entropy: f64,
    /// Dispatch tiles this rank sent.
    pub tiles_sent: usize,
    /// Valid rows sent vs rows a padded implementation would send.
    pub sent_rows: usize,
    pub padded_rows: usize,
    /// Over-capacity (token, expert) pairs dropped by the gate.
    pub dropped: usize,
    /// One-sided bytes received, split by locality, **measured at the
    /// configured wire element width** (2 bytes/elem on a 16-bit wire).
    /// `local` is NVLink-class (same-node) traffic; `remote` is NIC-class
    /// (cross-node) traffic, including coalesced hierarchical-dispatch
    /// transfers landing at this rank as a proxy.
    pub bytes_in_local: u64,
    pub bytes_in_remote: u64,
    /// NIC bytes this rank *declared* for the pass before moving them:
    /// outbound dispatch volume (per-tile in flat mode; per-remote-node
    /// unique rows in hierarchical mode) plus the combine returns its
    /// cross-node tiles pull back in. Summed over ranks it upper-bounds
    /// the pass's measured inter-node bytes — the incast-bound property.
    pub announced_inter_bytes: u64,
    /// Peak ready-pool depth (scheduling pressure).
    pub max_queue_depth: usize,
    /// Cross-deque task migrations in the work-stealing pool this pass
    /// (includes the subscriber's help-out steals) — the queue-contention
    /// stat: high steals mean the round-robin deal was imbalanced or a
    /// processor ran dry while a peer was backed up.
    pub steals: u32,
    /// Tokens this rank's gate *offered* to each global expert (kept +
    /// dropped, length E) — the pre-clamp demand histogram the
    /// replication EWMA tracker consumes. Empty for a rank that routed
    /// nothing.
    pub expert_offered: Vec<u64>,
    /// Tokens this rank's gate kept (post capacity clamp) per global
    /// expert, length E. Empty for a rank that routed nothing.
    pub expert_kept: Vec<u64>,
    /// FFN rows this rank executed out of *replica* slots (slots bound by
    /// the placement rather than owned) — the replica-hit counter: > 0
    /// means replication actually absorbed load here.
    pub replica_rows: u64,
    /// Routed rows this rank's gate had to skip because their expert has
    /// no serving location (its rank failed un-replicated) — the
    /// per-rank degraded-capacity loss, explicit instead of silent.
    pub unavailable_rows: u64,
}

impl RankMetrics {
    /// Processor-utilization analog of the paper's SM utilization: the
    /// fraction of processor-seconds spent executing tasks.
    pub fn utilization(&self) -> f64 {
        if self.wall_secs == 0.0 || self.processors == 0 {
            return 0.0;
        }
        (self.busy_secs / (self.wall_secs * self.processors as f64)).min(1.0)
    }

    pub fn total_tasks(&self) -> u32 {
        self.ffn_tasks + self.gemm_tasks + self.combine_tasks + self.dgrad_tasks + self.wgrad_tasks
    }

    /// Fraction of padded dispatch traffic avoided, in *rows* (the
    /// padding-only view; a rank doesn't know the wire width). The
    /// byte-granular view that also credits wire narrowing is
    /// [`PassMetrics::payload_savings`].
    pub fn payload_savings(&self) -> f64 {
        savings(self.sent_rows, self.padded_rows)
    }
}

/// Metrics for one whole forward pass.
#[derive(Clone, Debug, Default)]
pub struct PassMetrics {
    /// The pass epoch this result belongs to (1-based submission order;
    /// also the generation tag stamped into the symmetric heap's flags).
    pub epoch: u64,
    /// Resident model the pass ran against (see
    /// [`ModelRegistry`](crate::registry::ModelRegistry)): 0 is the
    /// engine's anchor model; ids > 0 are models registered at runtime.
    /// A pass never mixes models — every row of the pass belongs to this
    /// one id, and its tiles lived in this model's band of the symmetric
    /// heap.
    pub model: usize,
    /// End-to-end wall time (max over ranks; the paper's forward latency).
    pub wall_secs: f64,
    /// Token rows actually submitted across ranks (Σ `rows_in`).
    pub rows_submitted: usize,
    /// Row capacity of one engine pass (`ranks × s_rank`).
    pub rows_capacity: usize,
    /// Wire element format the pass ran under (stamps the byte counters:
    /// `bytes_in_*` are measured at this width).
    pub wire: WirePrecision,
    /// Version of the [`Placement`](crate::placement::Placement) the pass
    /// ran under (0 = the static block placement; bumps on every replica
    /// install/teardown).
    pub placement_version: u64,
    /// Times this pass was resubmitted after a poisoned attempt before
    /// succeeding (0 on the common fault-free path). The *successful*
    /// attempt's metrics are what the rest of this struct reports.
    pub retries: u32,
    /// Experts with no serving location during this pass (max over
    /// ranks — every rank sees the same degraded placement). > 0 marks a
    /// degraded pass: some routed rows were skipped, not computed.
    pub experts_unavailable: usize,
    /// This pass was a **backward** (gradient) pass: its byte counters
    /// measure *reverse*-path traffic (output-grad scatter + input-grad
    /// gather), not forward dispatch/combine — see
    /// [`forward_bytes`](Self::forward_bytes) /
    /// [`reverse_bytes`](Self::reverse_bytes).
    pub backward: bool,
    pub ranks: Vec<RankMetrics>,
}

impl PassMetrics {
    /// Fraction of the engine's per-pass row capacity this pass used.
    /// Exactly 1.0 for the legacy fixed-shape `submit` path (asserted by
    /// the engine tests); `< 1.0` for a partially-filled variable-shape
    /// pass — the serving batcher's fill quality, surfaced per pass.
    pub fn batch_fill(&self) -> f64 {
        if self.rows_capacity == 0 {
            return 0.0;
        }
        self.rows_submitted as f64 / self.rows_capacity as f64
    }

    /// Mean processor utilization across ranks.
    pub fn utilization(&self) -> f64 {
        if self.ranks.is_empty() {
            return 0.0;
        }
        self.ranks.iter().map(|r| r.utilization()).sum::<f64>() / self.ranks.len() as f64
    }

    /// Tokens/s over the pass (throughput, Fig 13's metric).
    pub fn throughput(&self, total_tokens: usize) -> f64 {
        if self.wall_secs == 0.0 {
            return 0.0;
        }
        total_tokens as f64 / self.wall_secs
    }

    /// Measured one-sided bytes moved across the fabric this pass, at the
    /// configured wire width (split by locality in the per-rank metrics).
    pub fn total_bytes(&self) -> u64 {
        self.ranks.iter().map(|r| r.bytes_in_local + r.bytes_in_remote).sum()
    }

    /// Forward-path bytes of this pass: [`total_bytes`](Self::total_bytes)
    /// for a forward, 0 for a backward. Keeps Table 3-style forward
    /// accounting truthful when training passes share the engine.
    pub fn forward_bytes(&self) -> u64 {
        if self.backward {
            0
        } else {
            self.total_bytes()
        }
    }

    /// Reverse-path (gradient) bytes of this pass: `total_bytes` for a
    /// backward, 0 for a forward. A 16-bit wire halves these exactly like
    /// the forward payload — asserted by the `train_bench` perf gate.
    pub fn reverse_bytes(&self) -> u64 {
        if self.backward {
            self.total_bytes()
        } else {
            0
        }
    }

    /// Row-weighted mean gate entropy (nats) across ranks (see
    /// [`RankMetrics::gate_entropy`]); 0.0 when no rows were routed.
    pub fn gate_entropy(&self) -> f64 {
        let rows: usize = self.ranks.iter().map(|r| r.rows_in).sum();
        if rows == 0 {
            return 0.0;
        }
        self.ranks.iter().map(|r| r.gate_entropy * r.rows_in as f64).sum::<f64>() / rows as f64
    }

    /// [`total_bytes`](Self::total_bytes) under its wire-format name,
    /// paired with the precision that produced it — the measured quantity
    /// behind the Fig 18 A/B (`harness::precision_ab`).
    pub fn wire_bytes(&self) -> (WirePrecision, u64) {
        (self.wire, self.total_bytes())
    }

    /// What the same routed rows would have cost on a 4-byte f32 wire:
    /// the denominator of the payload-narrowing factor. Derived by
    /// re-scaling the measured bytes from the wire width to 4 bytes/elem;
    /// the division must be exact (measured bytes are always
    /// `rows × H × wire.bytes()`), and a truncating remainder would
    /// silently skew the Fig 18 narrowing ratio — so divisibility is
    /// asserted rather than assumed.
    pub fn fp32_equiv_bytes(&self) -> u64 {
        let bytes = self.total_bytes();
        let wb = self.wire.bytes() as u64;
        debug_assert_eq!(
            bytes % wb,
            0,
            "measured bytes {bytes} not divisible by wire width {wb} — a transfer \
             accounted at the wrong granularity would corrupt the fp32-equivalent ratio"
        );
        bytes / wb * 4
    }

    pub fn total_dropped(&self) -> usize {
        self.ranks.iter().map(|r| r.dropped).sum()
    }

    /// Pass-wide *offered* load per global expert: the element-wise sum of
    /// every rank's pre-clamp demand histogram
    /// ([`RankMetrics::expert_offered`]). Sums to `rows_submitted × k`;
    /// this is the observation the replication EWMA tracker folds in
    /// after each pass.
    pub fn expert_offered(&self) -> Vec<u64> {
        let e = self.ranks.iter().map(|r| r.expert_offered.len()).max().unwrap_or(0);
        let mut out = vec![0u64; e];
        for r in &self.ranks {
            for (o, &x) in out.iter_mut().zip(&r.expert_offered) {
                *o += x;
            }
        }
        out
    }

    /// Pass-wide *kept* load per global expert (post capacity clamp).
    pub fn expert_kept(&self) -> Vec<u64> {
        let e = self.ranks.iter().map(|r| r.expert_kept.len()).max().unwrap_or(0);
        let mut out = vec![0u64; e];
        for r in &self.ranks {
            for (o, &x) in out.iter_mut().zip(&r.expert_kept) {
                *o += x;
            }
        }
        out
    }

    /// Busy-time imbalance across ranks: max rank busy-seconds over the
    /// mean (1.0 = perfectly balanced; the straggler factor replication
    /// exists to shrink). 0.0 when nothing ran.
    pub fn imbalance(&self) -> f64 {
        if self.ranks.is_empty() {
            return 0.0;
        }
        let mean = self.ranks.iter().map(|r| r.busy_secs).sum::<f64>() / self.ranks.len() as f64;
        if mean <= 0.0 {
            return 0.0;
        }
        self.ranks.iter().map(|r| r.busy_secs).fold(0.0, f64::max) / mean
    }

    /// The hottest rank's share of total busy time this pass, in
    /// `[1/ranks, 1.0]` — `1/ranks` is perfect balance, `1.0` means one
    /// rank did all the work (the serialized-hot-expert regime). This is
    /// the replication A/B's primary balance metric: unlike wall-clock it
    /// is immune to scheduler noise. 0.0 when nothing ran.
    pub fn hot_rank_busy_share(&self) -> f64 {
        let total: f64 = self.ranks.iter().map(|r| r.busy_secs).sum();
        if total <= 0.0 {
            return 0.0;
        }
        self.ranks.iter().map(|r| r.busy_secs).fold(0.0, f64::max) / total
    }

    /// FFN rows served out of replica slots this pass, summed over ranks
    /// (> 0 iff installed replicas actually absorbed load).
    pub fn replica_hits(&self) -> u64 {
        self.ranks.iter().map(|r| r.replica_rows).sum()
    }

    /// Routed rows skipped because their expert had no serving location
    /// this pass, summed over ranks — the degraded-capacity loss
    /// (`> 0` iff `experts_unavailable > 0` and demand actually hit an
    /// orphaned expert).
    pub fn unavailable_rows(&self) -> u64 {
        self.ranks.iter().map(|r| r.unavailable_rows).sum()
    }

    /// Intra-node (NVLink-class) bytes moved this pass, summed over ranks.
    pub fn intra_bytes(&self) -> u64 {
        self.ranks.iter().map(|r| r.bytes_in_local).sum()
    }

    /// Inter-node (NIC-class) bytes moved this pass, summed over ranks —
    /// the quantity hierarchical dispatch exists to shrink.
    pub fn inter_bytes(&self) -> u64 {
        self.ranks.iter().map(|r| r.bytes_in_remote).sum()
    }

    /// NIC bytes the ranks *declared* before moving them (see
    /// [`RankMetrics::announced_inter_bytes`]); `inter_bytes() <= this`
    /// is the pass-level incast bound asserted by the property suite.
    pub fn announced_inter_bytes(&self) -> u64 {
        self.ranks.iter().map(|r| r.announced_inter_bytes).sum()
    }

    /// Measured Maximal Incast Volume: the largest NIC-class byte count
    /// any single rank *received* this pass — the paper's §F quantity as
    /// a live engine outcome instead of a closed-form estimate. The rank
    /// with the maximum is the incast hotspot whose NIC receive window
    /// overflows first as tokens/GPU grows (Fig 17).
    pub fn miv_bytes(&self) -> u64 {
        self.ranks.iter().map(|r| r.bytes_in_remote).max().unwrap_or(0)
    }

    /// Pass-wide payload savings in **bytes** against the padded *fp32*
    /// baseline: credits both dropped padding (rows that never travel)
    /// and wire narrowing (each traveling element at `wire.bytes()`
    /// instead of 4). On an f32 wire this reduces to the row-granular
    /// fraction; on a 16-bit wire a fully-padded pass still reports 0.5.
    /// Under `RoutingPolicy::Dropless` the padded baseline is the
    /// policy's worst-case slot region, so savings read high exactly when
    /// the gate is balanced — and [`total_dropped`](Self::total_dropped)
    /// must read 0 regardless of skew (asserted by the conformance suite).
    /// For a [`backward`](Self::backward) pass the same ratio describes
    /// the reverse path (grad rows sent vs the padded baseline), so
    /// forward Table 3 numbers stay untainted — aggregate via
    /// [`forward_bytes`](Self::forward_bytes) /
    /// [`reverse_bytes`](Self::reverse_bytes) when mixing pass kinds.
    pub fn payload_savings(&self) -> f64 {
        let sent: usize = self.ranks.iter().map(|r| r.sent_rows).sum();
        let padded: usize = self.ranks.iter().map(|r| r.padded_rows).sum();
        savings(sent * self.wire.bytes(), padded * WirePrecision::F32.bytes())
    }
}

/// Cumulative metrics over one persistent engine's lifetime.
#[derive(Clone, Debug, Default)]
pub struct EngineMetrics {
    /// Launch-equivalent count: how many times actor groups were brought
    /// up. Exactly 1 per engine lifetime (Table 1's FlashDMoE row) — a
    /// steady-state pass rings doorbells instead of launching.
    pub launches: u64,
    /// Forward passes served (wait()-collected) so far.
    pub passes: u64,
    /// Backward (gradient) passes served so far — training traffic rides
    /// the same engine but is counted separately so forward-throughput
    /// numbers stay comparable across serving and training runs.
    pub backward_passes: u64,
    /// Cumulative one-sided bytes moved by *forward* passes, at the wire
    /// width (Table 3's measured traffic).
    pub forward_bytes: u64,
    /// Cumulative one-sided bytes moved by *backward* passes (output-grad
    /// scatter + input-grad gather) — the reverse-wire volume, split out
    /// so payload-efficiency figures never mix directions.
    pub reverse_bytes: u64,
    /// OS threads ever spawned by this engine (rank actors + resident
    /// processors). Constant after `start`; a growing value would mean a
    /// pass is respawning workers, which the engine never does.
    pub threads_spawned: u64,
    /// Cumulative processor busy seconds across all ranks and passes.
    pub busy_secs: f64,
    /// Cumulative pass wall seconds (sum of per-pass maxima).
    pub wall_secs: f64,
    /// Replica installs performed by `MoeEngine::rebalance` over the
    /// engine's life (each one epoch-fenced between passes).
    pub replica_installs: u64,
    /// Replica removals performed by `rebalance`.
    pub replica_removals: u64,
    /// Packed-weight bytes copied by replica installs (modeled from the
    /// packed expert size; the in-process backend shares one packed
    /// cache, so this counts what a multi-device install would ship).
    pub install_bytes: u64,
    /// Pass resubmissions driven by the retry loop (transient faults and
    /// freshly-detected rank deaths), summed over the engine's life.
    pub retries: u64,
    /// Passes that completed with at least one unavailable expert —
    /// served under degraded capacity rather than failed.
    pub degraded_passes: u64,
    /// Faults the injection schedule actually fired (transient drops +
    /// dead-endpoint rejections), mirrored from the transport's
    /// [`FaultPlan`](crate::fault::FaultPlan) counter at snapshot time.
    pub faults_injected: u64,
    /// Models registered into the engine's
    /// [`ModelRegistry`](crate::registry::ModelRegistry) over its life
    /// (base registrations + delta registrations; each is epoch-fenced
    /// like a rebalance). The anchor model the engine started with is
    /// not counted.
    pub model_registrations: u64,
    /// Models evicted from the registry over the engine's life.
    pub model_evictions: u64,
}

impl EngineMetrics {
    /// Steady-state processor utilization over the engine's life so far:
    /// busy processor-seconds over available processor-seconds, with
    /// `workers` = total resident processors across ranks.
    pub fn steady_state_utilization(&self, workers: usize) -> f64 {
        if self.wall_secs == 0.0 || workers == 0 {
            return 0.0;
        }
        (self.busy_secs / (self.wall_secs * workers as f64)).min(1.0)
    }

    /// Launch overhead amortization: launches per pass served. Tends to
    /// zero for a persistent engine; equals 1 for launch-per-call designs.
    pub fn launches_per_pass(&self) -> f64 {
        if self.passes == 0 {
            return self.launches as f64;
        }
        self.launches as f64 / self.passes as f64
    }
}

/// Cumulative metrics over one [`MoeService`](super::service::MoeService)
/// lifetime — the request-level view in front of the engine's pass-level
/// accounting.
#[derive(Clone, Debug, Default)]
pub struct ServiceMetrics {
    /// Requests accepted into the bounded queue.
    pub requests_enqueued: u64,
    /// Requests fully served (all token rows returned to their handle).
    pub requests_served: u64,
    /// Requests refused at `enqueue` (`ServiceFull`, zero tokens,
    /// oversize under the `Reject` policy, or shutdown).
    pub requests_rejected: u64,
    /// Requests whose handle was dropped before completion; their queued
    /// work is discarded at admission so abandoned requests never occupy
    /// a pass.
    pub requests_cancelled: u64,
    /// Requests failed by an engine submit/pass error (their handles
    /// observe the error). Accepted requests satisfy
    /// `enqueued == served + cancelled + failed`.
    pub requests_failed: u64,
    /// Token rows served through completed requests.
    pub tokens_served: u64,
    /// Engine passes the batcher completed successfully.
    pub passes: u64,
    /// Batches whose engine submit or pass errored (their requests are
    /// counted in `requests_failed`, and contribute no fill).
    pub passes_failed: u64,
    /// Σ over *successful* passes of `PassMetrics::batch_fill` (mean =
    /// `batch_fill_sum / passes`; see [`mean_batch_fill`](Self::mean_batch_fill)).
    pub batch_fill_sum: f64,
    /// Peak depth of the bounded request queue.
    pub max_queue_depth: usize,
    /// Requests shed because their [`RequestOpts::deadline`] expired
    /// before their tokens were admitted into a pass (each also counts in
    /// `requests_failed` — its handle observes the deadline error).
    ///
    /// [`RequestOpts::deadline`]: super::service::RequestOpts::deadline
    pub deadline_misses: u64,
}

impl ServiceMetrics {
    /// Mean per-pass row fill achieved by the batcher (0.0 before the
    /// first pass).
    pub fn mean_batch_fill(&self) -> f64 {
        if self.passes == 0 {
            return 0.0;
        }
        self.batch_fill_sum / self.passes as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn utilization_bounds() {
        let m = RankMetrics {
            busy_secs: 2.0,
            wall_secs: 1.0,
            processors: 4,
            ..Default::default()
        };
        assert!((m.utilization() - 0.5).abs() < 1e-12);
        let idle = RankMetrics { wall_secs: 1.0, processors: 4, ..Default::default() };
        assert_eq!(idle.utilization(), 0.0);
    }

    #[test]
    fn payload_savings() {
        let m = RankMetrics { sent_rows: 25, padded_rows: 100, ..Default::default() };
        assert!((m.payload_savings() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn pass_throughput() {
        let p = PassMetrics { wall_secs: 0.5, ..Default::default() };
        assert_eq!(p.throughput(1000), 2000.0);
    }

    #[test]
    fn batch_fill_tracks_submitted_rows() {
        let full = PassMetrics { rows_submitted: 256, rows_capacity: 256, ..Default::default() };
        assert_eq!(full.batch_fill(), 1.0, "fixed-shape passes fill exactly");
        let partial = PassMetrics { rows_submitted: 64, rows_capacity: 256, ..Default::default() };
        assert!((partial.batch_fill() - 0.25).abs() < 1e-12);
        assert_eq!(PassMetrics::default().batch_fill(), 0.0, "no capacity, no fill");
    }

    #[test]
    fn service_metrics_mean_fill() {
        let m = ServiceMetrics { passes: 4, batch_fill_sum: 3.0, ..Default::default() };
        assert!((m.mean_batch_fill() - 0.75).abs() < 1e-12);
        assert_eq!(ServiceMetrics::default().mean_batch_fill(), 0.0);
    }

    #[test]
    fn pass_payload_savings_aggregates_ranks() {
        // default wire (F32): byte savings reduce to the row fraction
        let p = PassMetrics {
            ranks: vec![
                RankMetrics { sent_rows: 10, padded_rows: 50, ..Default::default() },
                RankMetrics { sent_rows: 15, padded_rows: 50, ..Default::default() },
            ],
            ..Default::default()
        };
        assert_eq!(p.wire, WirePrecision::F32);
        assert!((p.payload_savings() - 0.75).abs() < 1e-12);
        assert_eq!(PassMetrics::default().payload_savings(), 0.0);
    }

    #[test]
    fn pass_payload_savings_credits_wire_narrowing() {
        // a 16-bit wire halves every traveling element vs the padded-fp32
        // baseline: 25 rows at 2 B/elem over 100 padded rows at 4 B/elem
        let p = PassMetrics {
            wire: WirePrecision::Bf16,
            ranks: vec![
                RankMetrics { sent_rows: 10, padded_rows: 50, ..Default::default() },
                RankMetrics { sent_rows: 15, padded_rows: 50, ..Default::default() },
            ],
            ..Default::default()
        };
        assert!((p.payload_savings() - 0.875).abs() < 1e-12);
        // even a fully-padded 16-bit pass saves the narrowing factor
        let full = PassMetrics {
            wire: WirePrecision::F16,
            ranks: vec![RankMetrics { sent_rows: 50, padded_rows: 50, ..Default::default() }],
            ..Default::default()
        };
        assert!((full.payload_savings() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn wire_bytes_and_fp32_equivalents() {
        let p = PassMetrics {
            wire: WirePrecision::Bf16,
            ranks: vec![RankMetrics {
                bytes_in_local: 96,
                bytes_in_remote: 32,
                ..Default::default()
            }],
            ..Default::default()
        };
        assert_eq!(p.wire_bytes(), (WirePrecision::Bf16, 128));
        assert_eq!(p.fp32_equiv_bytes(), 256, "same rows on an f32 wire");
        let f = PassMetrics {
            ranks: vec![RankMetrics { bytes_in_local: 128, ..Default::default() }],
            ..Default::default()
        };
        assert_eq!(f.fp32_equiv_bytes(), f.total_bytes(), "f32 wire is its own baseline");
    }

    #[test]
    fn locality_split_and_measured_miv() {
        let p = PassMetrics {
            ranks: vec![
                RankMetrics {
                    bytes_in_local: 100,
                    bytes_in_remote: 40,
                    announced_inter_bytes: 48,
                    ..Default::default()
                },
                RankMetrics {
                    bytes_in_local: 60,
                    bytes_in_remote: 90,
                    announced_inter_bytes: 90,
                    ..Default::default()
                },
            ],
            ..Default::default()
        };
        assert_eq!(p.intra_bytes(), 160);
        assert_eq!(p.inter_bytes(), 130);
        assert_eq!(p.announced_inter_bytes(), 138);
        assert_eq!(p.miv_bytes(), 90, "MIV is the hottest receiver, not the sum");
        assert!(p.inter_bytes() <= p.announced_inter_bytes(), "incast bound");
        assert_eq!(PassMetrics::default().miv_bytes(), 0);
    }

    #[test]
    fn expert_load_and_balance_aggregations() {
        let p = PassMetrics {
            ranks: vec![
                RankMetrics {
                    busy_secs: 3.0,
                    expert_offered: vec![10, 2, 0, 0],
                    expert_kept: vec![8, 2, 0, 0],
                    replica_rows: 0,
                    ..Default::default()
                },
                RankMetrics {
                    busy_secs: 1.0,
                    expert_offered: vec![5, 1, 1, 1],
                    expert_kept: vec![4, 1, 1, 1],
                    replica_rows: 6,
                    ..Default::default()
                },
            ],
            ..Default::default()
        };
        assert_eq!(p.expert_offered(), vec![15, 3, 1, 1]);
        assert_eq!(p.expert_kept(), vec![12, 3, 1, 1]);
        // busy: max 3.0, mean 2.0, total 4.0
        assert!((p.imbalance() - 1.5).abs() < 1e-12);
        assert!((p.hot_rank_busy_share() - 0.75).abs() < 1e-12);
        assert_eq!(p.replica_hits(), 6);
        // a routing-only rank (empty histograms) aggregates harmlessly
        let empty = PassMetrics::default();
        assert!(empty.expert_offered().is_empty());
        assert_eq!(empty.imbalance(), 0.0);
        assert_eq!(empty.hot_rank_busy_share(), 0.0);
        assert_eq!(empty.replica_hits(), 0);
    }

    #[test]
    fn backward_flag_splits_byte_directions() {
        let fwd = PassMetrics {
            ranks: vec![RankMetrics { bytes_in_local: 128, ..Default::default() }],
            ..Default::default()
        };
        assert!(!fwd.backward);
        assert_eq!(fwd.forward_bytes(), 128);
        assert_eq!(fwd.reverse_bytes(), 0);
        let bwd = PassMetrics { backward: true, ..fwd.clone() };
        assert_eq!(bwd.forward_bytes(), 0);
        assert_eq!(bwd.reverse_bytes(), 128);
        assert_eq!(bwd.total_bytes(), fwd.total_bytes(), "direction split, same measure");
    }

    #[test]
    fn gate_entropy_is_row_weighted() {
        let p = PassMetrics {
            ranks: vec![
                RankMetrics { rows_in: 30, gate_entropy: 1.0, ..Default::default() },
                RankMetrics { rows_in: 10, gate_entropy: 0.2, ..Default::default() },
            ],
            ..Default::default()
        };
        assert!((p.gate_entropy() - 0.8).abs() < 1e-12);
        assert_eq!(PassMetrics::default().gate_entropy(), 0.0, "no rows, no entropy");
    }

    #[test]
    fn total_tasks_counts_backward_kinds() {
        let m = RankMetrics {
            ffn_tasks: 2,
            gemm_tasks: 3,
            combine_tasks: 4,
            dgrad_tasks: 5,
            wgrad_tasks: 6,
            ..Default::default()
        };
        assert_eq!(m.total_tasks(), 20);
    }

    #[test]
    fn engine_metrics_amortize_launches() {
        let m = EngineMetrics {
            launches: 1,
            passes: 50,
            threads_spawned: 10,
            busy_secs: 30.0,
            wall_secs: 10.0,
            ..Default::default()
        };
        assert!((m.launches_per_pass() - 0.02).abs() < 1e-12);
        assert!((m.steady_state_utilization(6) - 0.5).abs() < 1e-12);
        let fresh = EngineMetrics { launches: 1, ..Default::default() };
        assert_eq!(fresh.launches_per_pass(), 1.0);
        assert_eq!(fresh.steady_state_utilization(8), 0.0);
    }
}
