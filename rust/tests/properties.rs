//! Property-based tests (via `util::check::forall`) over the paper's key
//! invariants: Theorem 3.1 write-conflict freedom, gate/capacity/routing
//! invariants, scheduler work conservation, and task-bound termination.

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;

use flashdmoe::config::ModelConfig;
use flashdmoe::coordinator::scheduler::TaskQueue;
use flashdmoe::gate::{dispatch_plan, route_from_scores};
use flashdmoe::layout::{conflict_free, write_is_valid, Coord, LayoutDims, Write, BUFFERS, ROUNDS};
use flashdmoe::task::{Task, TaskBound, TaskType};
use flashdmoe::util::check::{forall, Gen};
use flashdmoe::util::prng::Rng;

// ---------------------------------------------------------------------------
// Theorem 3.1: random *valid* writes from distinct sources never overlap
// ---------------------------------------------------------------------------

fn random_dims(g: &mut Gen) -> LayoutDims {
    let bm = g.choose(&[2usize, 4, 8]);
    LayoutDims {
        p: g.int(1, 8),
        e_local: g.int(1, 4),
        c: bm * g.int(1, 4),
        h: g.int(1, 16),
        bm,
    }
}

fn random_valid_write(g: &mut Gen, dims: &LayoutDims) -> Write {
    // generate writes *per the validity rules* (Definition C.2)
    let src = g.int(0, dims.p - 1);
    let inter = g.int(0, 1) == 1;
    let (p, b, dst) = if inter {
        (src, 1, g.int(0, dims.p - 1))
    } else {
        (g.int(0, dims.p - 1), 0, src)
    };
    let tile = g.int(0, dims.tiles_per_expert() - 1);
    let rows = g.int(1, dims.bm);
    Write {
        src,
        dst,
        coord: Coord {
            p,
            r: g.int(0, ROUNDS - 1),
            b,
            e: g.int(0, dims.e_local - 1),
            c: tile * dims.bm,
        },
        rows,
    }
}

#[test]
fn theorem_3_1_random_valid_writes_are_conflict_free() {
    forall(
        0xC0FFEE,
        500,
        |g| {
            let dims = random_dims(g);
            let writes: Vec<Write> =
                (0..g.int(2, 20)).map(|_| random_valid_write(g, &dims)).collect();
            (dims, writes)
        },
        |(dims, writes)| {
            for w in writes {
                if !write_is_valid(w, dims) {
                    return Err(format!("generator produced invalid write {w:?}"));
                }
            }
            for (i, a) in writes.iter().enumerate() {
                for b in &writes[i + 1..] {
                    if a.src != b.src && !conflict_free(a, b, dims) {
                        return Err(format!("conflict between {a:?} and {b:?}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn forged_writes_are_always_rejected() {
    forall(
        0xBAD,
        500,
        |g| {
            let dims = random_dims(g);
            let mut w = random_valid_write(g, &dims);
            // forge: claim another peer's slot on a remote write
            w.coord.b = 1;
            w.coord.p = (w.src + 1 + g.int(0, dims.p.saturating_sub(1))) % dims.p.max(2);
            (dims, w)
        },
        |(dims, w)| {
            if w.coord.p != w.src && write_is_valid(w, dims) {
                return Err(format!("forged write accepted: {w:?}"));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Gate invariants
// ---------------------------------------------------------------------------

fn random_routing(g: &mut Gen) -> (ModelConfig, usize, Vec<f32>, usize) {
    let e = g.choose(&[2usize, 4, 8, 16]);
    let k = 1 + g.int(0, (e - 1).min(3));
    let bm = g.choose(&[2usize, 4, 8]);
    let s = bm * g.int(1, 16);
    let capacity = bm * g.int(1, 8);
    let model = ModelConfig { h: 4, d: 8, e, k, bm, bn: 4, capacity_factor: 1.0 };
    let mut rng = Rng::new(g.int(0, u32::MAX as usize) as u64);
    let mut scores = rng.normal_vec(s * e, 1.0);
    flashdmoe::gate::softmax_rows(&mut scores, e);
    (model, s, scores, capacity)
}

#[test]
fn routing_invariants_hold() {
    forall(
        0x9A7E,
        300,
        |g| random_routing(g),
        |(model, s, scores, capacity)| {
            let r = route_from_scores(scores.clone(), *s, model, *capacity);
            // (1) kept + dropped == S*k
            if r.routes.len() + r.dropped != s * model.k {
                return Err("kept+dropped != S*k".into());
            }
            // (2) per-expert loads never exceed capacity
            for (e, &load) in r.expert_load.iter().enumerate() {
                if load as usize > *capacity {
                    return Err(format!("expert {e} over capacity: {load}"));
                }
            }
            // (3) slots within an expert are unique and dense 0..load
            for e in 0..model.e {
                let mut slots: Vec<u32> = r
                    .routes
                    .iter()
                    .filter(|x| x.expert as usize == e)
                    .map(|x| x.slot)
                    .collect();
                slots.sort_unstable();
                for (i, s2) in slots.iter().enumerate() {
                    if *s2 as usize != i {
                        return Err(format!("expert {e} slots not dense: {slots:?}"));
                    }
                }
            }
            // (4) combine weights of a token's kept routes never exceed 1
            let mut per_token = vec![0.0f32; *s];
            for x in &r.routes {
                per_token[x.token as usize] += x.combine_weight;
            }
            if per_token.iter().any(|w| *w > 1.0 + 1e-4) {
                return Err("combine weights exceed 1".into());
            }
            Ok(())
        },
    );
}

#[test]
fn dispatch_plan_partitions_routes() {
    forall(
        0xD15,
        200,
        |g| random_routing(g),
        |(model, s, scores, capacity)| {
            let r = route_from_scores(scores.clone(), *s, model, *capacity);
            let plan = dispatch_plan(&r, model.bm, |e| e % 3);
            let covered: usize = plan.tiles.iter().map(|t| t.tokens.len()).sum();
            if covered != r.routes.len() {
                return Err(format!("plan covers {covered}, routes {}", r.routes.len()));
            }
            if plan.sent_rows > plan.padded_rows {
                return Err("sent more than padded?".into());
            }
            for t in &plan.tiles {
                if t.rows == 0 || t.rows as usize > model.bm {
                    return Err(format!("bad tile rows {}", t.rows));
                }
                if t.tokens.len() != t.weights.len() {
                    return Err("tokens/weights arity mismatch".into());
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Scheduler: work conservation & exactly-once delivery under contention
// ---------------------------------------------------------------------------

#[test]
fn scheduler_delivers_exactly_once_under_random_schedules() {
    forall(
        0x5C4ED,
        40,
        |g| (g.int(1, 8), g.int(0, 500)),
        |&(workers, n_tasks)| {
            let q = Arc::new(TaskQueue::new());
            let delivered = Arc::new(AtomicU32::new(0));
            let handles: Vec<_> = (0..workers)
                .map(|_| {
                    let q = q.clone();
                    let delivered = delivered.clone();
                    std::thread::spawn(move || {
                        while q.pop().is_some() {
                            delivered.fetch_add(1, Ordering::Relaxed);
                        }
                    })
                })
                .collect();
            for i in 0..n_tasks {
                q.push(Task {
                    task_type: TaskType::Combine,
                    peer: 0,
                    expert: 0,
                    tile: 0,
                    col: 0,
                    rows: 1,
                    seq: i as u32,
                });
            }
            q.stop_all();
            for h in handles {
                h.join().unwrap();
            }
            let got = delivered.load(Ordering::Relaxed) as usize;
            if got != n_tasks {
                return Err(format!("delivered {got} of {n_tasks}"));
            }
            let (pushed, popped) = q.counts();
            if pushed != popped {
                return Err(format!("pushed {pushed} != popped {popped}"));
            }
            Ok(())
        },
    );
}

#[test]
fn task_bound_terminates_iff_finalized_and_complete() {
    forall(
        0x7B0,
        300,
        |g| {
            let adds: Vec<u32> = (0..g.int(1, 10)).map(|_| g.int(0, 50) as u32).collect();
            let finalize_at = g.int(0, adds.len());
            (adds, finalize_at)
        },
        |(adds, finalize_at)| {
            let tb = TaskBound::new();
            let mut total = 0u32;
            for (i, &n) in adds.iter().enumerate() {
                if i == *finalize_at {
                    tb.finalize();
                }
                tb.add(n);
                total += n;
                if tb.done() && total > tb.progress().0 {
                    return Err("done before all work completed".into());
                }
                tb.complete(n);
            }
            if *finalize_at >= adds.len() {
                if tb.done() {
                    return Err("done without finalize".into());
                }
                tb.finalize();
            }
            if !tb.done() {
                return Err(format!("not done after {total} completions"));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Layout offsets: random coordinates map to disjoint rows
// ---------------------------------------------------------------------------

#[test]
fn layout_offsets_are_injective() {
    forall(
        0x0FF5,
        200,
        |g| {
            let dims = random_dims(g);
            let coords: Vec<Coord> = (0..g.int(2, 30))
                .map(|_| Coord {
                    p: g.int(0, dims.p - 1),
                    r: g.int(0, ROUNDS - 1),
                    b: g.int(0, BUFFERS - 1),
                    e: g.int(0, dims.e_local - 1),
                    c: g.int(0, dims.c - 1),
                })
                .collect();
            (dims, coords)
        },
        |(dims, coords)| {
            for (i, a) in coords.iter().enumerate() {
                for b in &coords[i + 1..] {
                    if a != b && dims.offset(*a) == dims.offset(*b) {
                        return Err(format!("offset collision: {a:?} vs {b:?}"));
                    }
                }
            }
            Ok(())
        },
    );
}
