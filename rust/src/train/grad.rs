//! Gradient containers: per-expert and whole-model gradient stores with
//! a fixed, documented tensor order so every fold over them (engine
//! merge, accumulation across micro-batches, optimizer state updates)
//! is deterministic by construction.

use crate::expert::{ExpertParams, ModelParams};

/// Gradients of one expert's FFN parameters; shapes mirror
/// [`ExpertParams`] exactly (`w1`: (H, D) row-major, `b1`: (D,),
/// `w2`: (D, H) row-major, `b2`: (H,)).
#[derive(Clone, Debug, PartialEq)]
pub struct ExpertGrad {
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    pub w2: Vec<f32>,
    pub b2: Vec<f32>,
}

impl ExpertGrad {
    pub fn zeros(h: usize, d: usize) -> Self {
        Self { w1: vec![0.0; h * d], b1: vec![0.0; d], w2: vec![0.0; d * h], b2: vec![0.0; h] }
    }

    /// self += other, element-wise, in field order (w1, b1, w2, b2).
    pub fn add_assign(&mut self, other: &ExpertGrad) {
        add_into(&mut self.w1, &other.w1);
        add_into(&mut self.b1, &other.b1);
        add_into(&mut self.w2, &other.w2);
        add_into(&mut self.b2, &other.b2);
    }
}

fn add_into(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d += s;
    }
}

/// Gradients of the whole MoE layer: the gate matrix plus every expert.
/// Tensor traversal order is fixed — `wg` first, then experts ascending
/// by global id, each in (w1, b1, w2, b2) field order — and shared by
/// [`GradStore::tensors`], [`ModelParams`]' traversal in the optimizer,
/// and the engine's per-rank merge, so parameter/gradient/optimizer-state
/// triples always line up and accumulate in one deterministic order.
#[derive(Clone, Debug, PartialEq)]
pub struct GradStore {
    /// d/dWg, (H, E) row-major — mirrors `ModelParams::wg`.
    pub wg: Vec<f32>,
    /// Per-global-expert FFN gradients, index == global expert id.
    pub experts: Vec<ExpertGrad>,
    pub h: usize,
    pub d: usize,
}

impl GradStore {
    pub fn zeros(h: usize, d: usize, e: usize) -> Self {
        let experts = (0..e).map(|_| ExpertGrad::zeros(h, d)).collect();
        Self { wg: vec![0.0; h * e], experts, h, d }
    }

    pub fn zeros_like(params: &ModelParams) -> Self {
        Self::zeros(params.h, params.d, params.experts.len())
    }

    pub fn num_experts(&self) -> usize {
        self.experts.len()
    }

    /// self += other (shapes must match), in the fixed tensor order.
    pub fn add_assign(&mut self, other: &GradStore) {
        debug_assert_eq!(self.experts.len(), other.experts.len());
        add_into(&mut self.wg, &other.wg);
        for (g, o) in self.experts.iter_mut().zip(&other.experts) {
            g.add_assign(o);
        }
    }

    /// Scale every gradient by `s` (e.g. 1/accum_steps averaging).
    pub fn scale(&mut self, s: f32) {
        for t in self.tensors_mut() {
            for v in t.iter_mut() {
                *v *= s;
            }
        }
    }

    /// Reset to zero in place (between accumulation windows).
    pub fn zero(&mut self) {
        for t in self.tensors_mut() {
            t.fill(0.0);
        }
    }

    /// Sum of squared elements (grad-norm diagnostics in the train loop).
    pub fn sq_norm(&self) -> f64 {
        self.tensors().iter().flat_map(|t| t.iter()).map(|&v| (v as f64) * (v as f64)).sum()
    }

    /// All tensors in the fixed traversal order (see the type docs).
    pub fn tensors(&self) -> Vec<&[f32]> {
        let mut out: Vec<&[f32]> = vec![&self.wg];
        for g in &self.experts {
            out.push(&g.w1);
            out.push(&g.b1);
            out.push(&g.w2);
            out.push(&g.b2);
        }
        out
    }

    /// Mutable counterpart of [`tensors`](Self::tensors), same order.
    pub fn tensors_mut(&mut self) -> Vec<&mut Vec<f32>> {
        let mut out: Vec<&mut Vec<f32>> = vec![&mut self.wg];
        for g in &mut self.experts {
            out.push(&mut g.w1);
            out.push(&mut g.b1);
            out.push(&mut g.w2);
            out.push(&mut g.b2);
        }
        out
    }
}

/// [`ModelParams`] tensors in the *same* traversal order as
/// [`GradStore::tensors`] — the zip the optimizer steps over.
pub fn param_tensors_mut(params: &mut ModelParams) -> Vec<&mut Vec<f32>> {
    let mut out: Vec<&mut Vec<f32>> = vec![&mut params.wg];
    for ex in &mut params.experts {
        let ExpertParams { w1, b1, w2, b2 } = ex;
        out.push(w1);
        out.push(b1);
        out.push(w2);
        out.push(b2);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traversal_orders_line_up() {
        let cfg = crate::config::Config::preset("tiny").unwrap();
        let mut params = ModelParams::generate(&cfg, 1);
        let g = GradStore::zeros_like(&params);
        let gt = g.tensors();
        let pt = param_tensors_mut(&mut params);
        assert_eq!(gt.len(), pt.len());
        assert_eq!(gt.len(), 1 + 4 * cfg.model.e);
        for (a, b) in gt.iter().zip(&pt) {
            assert_eq!(a.len(), b.len(), "shape mismatch in traversal");
        }
    }

    #[test]
    fn add_scale_zero_roundtrip() {
        let mut a = GradStore::zeros(2, 3, 2);
        let mut b = GradStore::zeros(2, 3, 2);
        a.wg[0] = 1.0;
        a.experts[1].b2[1] = 4.0;
        b.wg[0] = 2.0;
        b.experts[1].b2[1] = 0.5;
        a.add_assign(&b);
        assert_eq!(a.wg[0], 3.0);
        assert_eq!(a.experts[1].b2[1], 4.5);
        a.scale(2.0);
        assert_eq!(a.wg[0], 6.0);
        assert!(a.sq_norm() > 0.0);
        a.zero();
        assert_eq!(a.sq_norm(), 0.0);
    }
}
