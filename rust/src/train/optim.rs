//! Optimizers over [`ModelParams`]: plain/momentum SGD and Adam, both
//! stepping the fixed tensor traversal shared with [`GradStore`] so the
//! update order (and therefore every parameter bit) is deterministic.

use crate::expert::ModelParams;

use super::grad::{param_tensors_mut, GradStore};

/// First-order optimizer. State tensors (`vel`, `m`, `v`) are lazily
/// allocated [`GradStore`]s on the first step, so constructing an
/// optimizer is free and shape-agnostic.
#[derive(Clone, Debug)]
pub enum Optimizer {
    Sgd {
        lr: f32,
        /// 0.0 = plain SGD; otherwise classical momentum.
        momentum: f32,
        vel: Option<GradStore>,
    },
    Adam {
        lr: f32,
        beta1: f32,
        beta2: f32,
        eps: f32,
        /// Step count for bias correction (increments per `step`).
        t: u64,
        m: Option<GradStore>,
        v: Option<GradStore>,
    },
}

impl Optimizer {
    pub fn sgd(lr: f32) -> Self {
        Optimizer::Sgd { lr, momentum: 0.0, vel: None }
    }

    pub fn sgd_momentum(lr: f32, momentum: f32) -> Self {
        Optimizer::Sgd { lr, momentum, vel: None }
    }

    /// Adam with the conventional defaults (β1=0.9, β2=0.999, ε=1e-8).
    pub fn adam(lr: f32) -> Self {
        Optimizer::Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8, t: 0, m: None, v: None }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Optimizer::Sgd { .. } => "sgd",
            Optimizer::Adam { .. } => "adam",
        }
    }

    pub fn lr(&self) -> f32 {
        match self {
            Optimizer::Sgd { lr, .. } | Optimizer::Adam { lr, .. } => *lr,
        }
    }

    /// Apply one update: `params -= f(grads)`. Panics (debug) on shape
    /// mismatch; tensors are zipped in the shared traversal order.
    pub fn step(&mut self, params: &mut ModelParams, grads: &GradStore) {
        match self {
            Optimizer::Sgd { lr, momentum, vel } => {
                let lr = *lr;
                let mu = *momentum;
                if mu == 0.0 {
                    for (p, g) in param_tensors_mut(params).into_iter().zip(grads.tensors()) {
                        for (pv, &gv) in p.iter_mut().zip(g) {
                            *pv -= lr * gv;
                        }
                    }
                } else {
                    let vel = vel.get_or_insert_with(|| GradStore::zeros_like(params));
                    for ((p, g), v) in param_tensors_mut(params)
                        .into_iter()
                        .zip(grads.tensors())
                        .zip(vel.tensors_mut())
                    {
                        for ((pv, &gv), vv) in p.iter_mut().zip(g).zip(v.iter_mut()) {
                            *vv = mu * *vv + gv;
                            *pv -= lr * *vv;
                        }
                    }
                }
            }
            Optimizer::Adam { lr, beta1, beta2, eps, t, m, v } => {
                let (lr, b1, b2, eps) = (*lr, *beta1, *beta2, *eps);
                *t += 1;
                let bc1 = 1.0 - b1.powi(*t as i32);
                let bc2 = 1.0 - b2.powi(*t as i32);
                let m = m.get_or_insert_with(|| GradStore::zeros_like(params));
                let v = v.get_or_insert_with(|| GradStore::zeros_like(params));
                for (((p, g), mt), vt) in param_tensors_mut(params)
                    .into_iter()
                    .zip(grads.tensors())
                    .zip(m.tensors_mut())
                    .zip(v.tensors_mut())
                {
                    for (((pv, &gv), mv), vv) in
                        p.iter_mut().zip(g).zip(mt.iter_mut()).zip(vt.iter_mut())
                    {
                        *mv = b1 * *mv + (1.0 - b1) * gv;
                        *vv = b2 * *vv + (1.0 - b2) * gv * gv;
                        let mhat = *mv / bc1;
                        let vhat = *vv / bc2;
                        *pv -= lr * mhat / (vhat.sqrt() + eps);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_params() -> ModelParams {
        let cfg = crate::config::Config::preset("tiny").unwrap();
        ModelParams::generate(&cfg, 7)
    }

    #[test]
    fn sgd_moves_against_the_gradient() {
        let mut params = tiny_params();
        let before = params.wg[0];
        let mut g = GradStore::zeros_like(&params);
        g.wg[0] = 2.0;
        let mut opt = Optimizer::sgd(0.5);
        opt.step(&mut params, &g);
        assert_eq!(params.wg[0], before - 1.0);
    }

    #[test]
    fn momentum_accumulates_velocity() {
        let mut params = tiny_params();
        let before = params.experts[0].b1[0];
        let mut g = GradStore::zeros_like(&params);
        g.experts[0].b1[0] = 1.0;
        let mut opt = Optimizer::sgd_momentum(0.1, 0.9);
        opt.step(&mut params, &g); // v=1.0, p -= 0.1
        opt.step(&mut params, &g); // v=1.9, p -= 0.19
        let moved = before - params.experts[0].b1[0];
        assert!((moved - 0.29).abs() < 1e-6, "momentum compounding, moved {moved}");
    }

    #[test]
    fn adam_first_step_is_lr_sized() {
        // with bias correction, step 1 moves ~lr·sign(g) regardless of |g|
        let mut params = tiny_params();
        let before = params.experts[1].b2[3];
        let mut g = GradStore::zeros_like(&params);
        g.experts[1].b2[3] = 1e-3;
        let mut opt = Optimizer::adam(0.01);
        opt.step(&mut params, &g);
        let moved = before - params.experts[1].b2[3];
        assert!((moved - 0.01).abs() < 1e-4, "bias-corrected first step, moved {moved}");
        assert_eq!(opt.name(), "adam");
        assert_eq!(opt.lr(), 0.01);
    }

    #[test]
    fn zero_grad_is_a_noop_for_sgd() {
        let mut params = tiny_params();
        let snapshot = params.wg.clone();
        let g = GradStore::zeros_like(&params);
        let mut opt = Optimizer::sgd(1.0);
        opt.step(&mut params, &g);
        assert_eq!(params.wg, snapshot);
    }
}
