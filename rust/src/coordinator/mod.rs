//! The L3 coordinator — the paper's system contribution, exposed as a
//! **persistent engine**.
//!
//! Each rank runs a "persistent kernel": one OS/subscriber/scheduler
//! context plus N processor workers that are launched **once** at
//! [`MoeEngine::start`] and stay resident — parked on doorbells — for the
//! engine's whole lifetime. Actors exchange tile-granular task
//! descriptors through a work-conserving ready queue; ranks exchange
//! tiles through the write-conflict-free symmetric heap with one-sided
//! put+signal, addressed via the node-aware transport layer
//! (`crate::transport::NodeFabric` over `crate::fabric`), every transfer
//! stamped with the pass epoch (per-slot generation counters — no global
//! reset, no collective, no bulk-synchronous barrier anywhere on the
//! data path). On multi-node topologies the dispatch loop can coalesce
//! each remote node's unique token rows into one NIC transfer through a
//! proxy rank (`DispatchMode::Hierarchical`), and a failed transfer —
//! e.g. a bounded NIC receive window overflowing under incast — poisons
//! the pass generation so every rank abandons that pass promptly as an
//! engine error instead of wedging on the watchdog.
//!
//! Engine lifecycle (the only launch is the first line):
//!
//! ```text
//! MoeEngine::start(cfg, params, backend, mode)   // actors launched ONCE
//!     engine.submit(&inputs)? -> PassHandle       // epoch-tagged pass N
//!     engine.submit(&next)?   -> PassHandle       // pass N+1, pipelined
//!     handle.wait()?          -> ForwardResult    // collect pass N
//!     ... × as many passes as you like: zero thread spawns, launch
//!         count stays 1 (EngineMetrics::launches)
//! engine.shutdown()  // or drop — actors drained, parked threads joined
//! ```
//!
//! Multi-model residency: with `max_models > 1` one engine serves
//! several models from the same resident actors and symmetric heap. The
//! [`ModelRegistry`](crate::registry) fingerprints registered weights
//! (content-identical models share one packed-cache region; LoRA-style
//! [`DeltaSet`](crate::registry::DeltaSet) variants share their base's
//! panels and cost only the delta bytes), each model owns a disjoint
//! band of heap expert slots, and every pass — [`PassInput::model`],
//! [`RequestOpts::model`] — serves exactly one model. Registration,
//! eviction, replication rebalancing and degraded-placement swaps are
//! all epoch-fenced per-model mutations at the same quiet points.
//!
//! Module map (mirrors Fig. 6, plus the serving front end):
//! * [`service`]   — the request-level [`MoeService`]: a resident
//!   continuous batcher over the engine — `enqueue` variable-length
//!   requests, bounded-queue backpressure, coalescing under a
//!   [`BatchPolicy`], round-robin row packing into variable-shape
//!   passes, scatter-gather back to per-request results.
//! * [`engine`]    — the persistent [`MoeEngine`] underneath: epoch-tagged
//!   `submit`/`submit_pass`/`wait`, double-buffered pass slots,
//!   variable-shape [`PassInput`] passes, the epoch-fenced
//!   `rebalance` quiet point that installs hot-expert replicas between
//!   passes (EWMA load tracker + `crate::placement`), shutdown/join.
//! * [`scheduler`] — the per-processor work-stealing ready pool +
//!   interrupt plumbing (Alg. 3), reusable across passes (`stop_all`
//!   parks a pass, `reopen` re-arms).
//! * [`rank`]      — one rank's resident actor group: subscriber decode
//!   loop (Alg. 4), processor execution loop (Alg. 2), dispatch (Alg. 1,
//!   flat or node-coalesced hierarchical), pass poisoning on transport
//!   failure.
//! * [`moe`]       — [`DistributedMoE`], the original one-call operator
//!   API kept as a thin shim over a non-pipelined engine.
//! * [`baseline`]  — a real-execution bulk-synchronous baseline
//!   (Megatron/DeepSpeed-shaped) over the same substrate, for measured
//!   comparisons and numeric cross-checks.
//! * [`metrics`]   — per-rank / per-pass / engine-lifetime / service
//!   accounting (SM-utilization analog, Table 1's launch count, batch
//!   fill).

pub mod baseline;
pub mod engine;
pub mod metrics;
pub mod moe;
pub mod rank;
pub mod scheduler;
pub mod service;

pub use baseline::{forward_sequential, forward_sequential_placed, BaselineResult};
pub use engine::{BackwardResult, ForwardResult, MoeEngine, PassHandle, PassInput};
pub use metrics::{EngineMetrics, PassMetrics, RankMetrics, ServiceMetrics};
pub use moe::DistributedMoE;
pub use rank::TaskGraphMode;
pub use service::{
    BatchPolicy, Backpressure, MoeService, OversizePolicy, QueueDiscipline, RequestHandle,
    RequestOpts, RequestResult, ServiceError, ServiceReport,
};
