//! Synthetic workload generation: token routing distributions that drive
//! both the real coordinator (via actual gate scores) and the simulator
//! (via replayed routing tables).
//!
//! MoE token→expert distributions are *not* uniform in practice (the paper
//! cites BlackMamba [36]); the generators below produce uniform, zipf-
//! skewed and hot-expert distributions so payload efficiency, capacity
//! drops and load imbalance are all exercised.

use crate::config::{Config, ModelConfig};
use crate::gate::{dispatch_plan, route_from_scores, DispatchPlan, Routing};
use crate::util::prng::Rng;

/// Routing skew shape.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Skew {
    /// Experts drawn ~uniformly (well-balanced router).
    Uniform,
    /// Zipf(s≈1.1) over experts (realistic long-tail imbalance).
    Zipf,
    /// A handful of experts take most tokens (pathological hot spot).
    Hot,
}

impl Skew {
    pub fn parse(s: &str) -> Option<Skew> {
        match s {
            "uniform" => Some(Skew::Uniform),
            "zipf" => Some(Skew::Zipf),
            "hot" => Some(Skew::Hot),
            _ => None,
        }
    }
}

/// One rank's replayable routing workload.
#[derive(Clone, Debug)]
pub struct RankWorkload {
    pub routing: Routing,
    pub plan: DispatchPlan,
}

/// Synthesize gate *scores* (not tokens) with the requested skew, then
/// route them through the production gate/capacity/dispatch code — the
/// simulator replays exactly what the real coordinator would do.
pub fn synth_routing(
    model: &ModelConfig,
    s_rank: usize,
    capacity: usize,
    skew: Skew,
    rng: &mut Rng,
) -> Routing {
    let e = model.e;
    let mut scores = vec![0.0f32; s_rank * e];
    for row in scores.chunks_mut(e) {
        // favored expert by skew; logits = noise + bias toward favorite
        let fav = match skew {
            Skew::Uniform => rng.below(e),
            Skew::Zipf => rng.zipf(e, 1.1),
            Skew::Hot => {
                if rng.f64() < 0.7 {
                    rng.below((e / 8).max(1))
                } else {
                    rng.below(e)
                }
            }
        };
        for (j, v) in row.iter_mut().enumerate() {
            *v = rng.normal_f32(0.0, 1.0) + if j == fav { 3.0 } else { 0.0 };
        }
    }
    crate::gate::softmax_rows(&mut scores, e);
    route_from_scores(scores, s_rank, model, capacity)
}

/// Build the full per-rank workload set for a config.
pub fn cluster_workload(cfg: &Config, skew: Skew, seed: u64) -> Vec<RankWorkload> {
    let capacity = cfg.model.slot_capacity(cfg.system.s_rank);
    let base = Rng::new(seed);
    (0..cfg.system.ranks)
        .map(|r| {
            let mut rng = base.fork(r as u64 + 0x50);
            let routing = synth_routing(&cfg.model, cfg.system.s_rank, capacity, skew, &mut rng);
            let plan = dispatch_plan(&routing, cfg.model.bm, |e| cfg.owner_of(e));
            RankWorkload { routing, plan }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    #[test]
    fn uniform_loads_are_balanced() {
        let cfg = Config::preset("default").unwrap();
        let cap = cfg.model.capacity(cfg.system.s_rank);
        let mut rng = Rng::new(1);
        let r = synth_routing(&cfg.model, cfg.system.s_rank, cap, Skew::Uniform, &mut rng);
        let max = *r.expert_load.iter().max().unwrap() as f64;
        let min = *r.expert_load.iter().min().unwrap() as f64;
        assert!(max / min.max(1.0) < 4.0, "uniform skew too high: {max}/{min}");
    }

    #[test]
    fn hot_skew_concentrates_and_drops() {
        let cfg = Config::preset("default").unwrap();
        let cap = cfg.model.capacity(cfg.system.s_rank);
        let mut rng = Rng::new(2);
        let hot = synth_routing(&cfg.model, cfg.system.s_rank, cap, Skew::Hot, &mut rng);
        let uni = synth_routing(&cfg.model, cfg.system.s_rank, cap, Skew::Uniform, &mut rng);
        assert!(hot.dropped > uni.dropped, "hot skew should overflow capacity");
        let hot_max = *hot.expert_load.iter().max().unwrap();
        let uni_max = *uni.expert_load.iter().max().unwrap();
        assert!(hot_max >= uni_max);
    }

    #[test]
    fn workload_is_deterministic() {
        let cfg = Config::preset("tiny").unwrap();
        let a = cluster_workload(&cfg, Skew::Zipf, 7);
        let b = cluster_workload(&cfg, Skew::Zipf, 7);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.plan.tiles, y.plan.tiles);
        }
    }

    #[test]
    fn plans_cover_routes() {
        let cfg = Config::preset("tiny").unwrap();
        for skew in [Skew::Uniform, Skew::Zipf, Skew::Hot] {
            for w in cluster_workload(&cfg, skew, 3) {
                let covered: usize = w.plan.tiles.iter().map(|t| t.tokens.len()).sum();
                assert_eq!(covered, w.routing.routes.len());
            }
        }
    }
}
