"""L2 extension: training support (the paper's §5 future-work item).

STUB STATUS: this AOT path is *not* the training implementation anymore.
PR 9 moved training into the Rust engine itself — ``rust/src/train/``
(autograd tape, ``Optimizer``, ``Trainer``) runs Dgrad/Wgrad tile tasks
through the persistent work-stealing scheduler with reverse-wire gradient
transfers, and ``examples/train_loop.rs`` now drives that path natively
(no PJRT artifact required). This module remains as the build-time
cross-check half: a differentiable JAX MoE formulation whose gradients
can be compared against ``util::check::dense_reference_moe_grad`` (the
Rust oracle the engine is conformance-tested against), and an AOT
``train_step`` artifact for environments with a real PJRT runtime.

The differentiable graph uses the pure-jnp formulation (`moe_layer_jnp`)
rather than the Pallas kernels: interpret-mode Pallas is not reliably
differentiable, and the two formulations are asserted equal by pytest, so
gradients are taken through identical math.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .model import route_slots


def moe_layer_jnp(a, wg, w1, b1, w2, b2, *, k: int, capacity: int):
    """Differentiable single-shard MoE layer (same math as model.moe_layer
    with s_rank == S; see DESIGN.md §4 for the shared numerics contract)."""
    s, h = a.shape
    e = wg.shape[1]
    scores = jax.nn.softmax(a @ wg, axis=-1)
    # iterative arg-max top-k (ties -> lower index), matching gate.topk_route
    masked = scores
    idxs, ws = [], []
    for _ in range(k):
        idx = jnp.argmax(masked, axis=-1)
        w = jnp.take_along_axis(masked, idx[:, None], axis=-1)[:, 0]
        idxs.append(idx.astype(jnp.int32))
        ws.append(w)
        masked = masked.at[jnp.arange(s), idx].set(-jnp.inf)
    idx = jnp.stack(idxs, axis=-1)  # (S, k)
    w = jnp.stack(ws, axis=-1)
    denom = jnp.sum(w, axis=-1, keepdims=True)

    slots = route_slots(idx, e, capacity)
    kept = slots < capacity
    buf_rows = e * capacity
    flat_pos = idx * capacity + slots
    flat_pos = jnp.where(kept, flat_pos, buf_rows)
    expert_in = (
        jnp.zeros((buf_rows, h), jnp.float32)
        .at[flat_pos.reshape(-1)]
        .set(jnp.repeat(a, k, axis=0), mode="drop")
    ).reshape(e, capacity, h)

    hidden = jax.nn.relu(jnp.einsum("ech,ehd->ecd", expert_in, w1) + b1[:, None, :])
    expert_out = (jnp.einsum("ecd,edh->ech", hidden, w2) + b2[:, None, :]).reshape(
        buf_rows, h
    )

    out = jnp.zeros((s, h), jnp.float32)
    for j in range(k):
        rows = jnp.where(kept[:, j], flat_pos[:, j], 0)
        gathered = expert_out[rows]
        scale = jnp.where(kept[:, j], w[:, j] / denom[:, 0], 0.0)[:, None]
        out = out + scale * gathered
    return out


def init_params(rng_key, h: int, d: int, e: int):
    """MoE layer + linear readout parameters (pytree as a flat dict)."""
    ks = jax.random.split(rng_key, 7)
    s = 0.1
    return {
        "wg": jax.random.normal(ks[0], (h, e)) * 1.0,
        "w1": jax.random.normal(ks[1], (e, h, d)) * s,
        "b1": jnp.zeros((e, d)),
        "w2": jax.random.normal(ks[2], (e, d, h)) * s,
        "b2": jnp.zeros((e, h)),
        "head_w": jax.random.normal(ks[3], (h, 1)) * s,
        "head_b": jnp.zeros((1,)),
    }


PARAM_ORDER = ["wg", "w1", "b1", "w2", "b2", "head_w", "head_b"]


def loss_fn(params, x, y, *, k: int, capacity: int):
    h = moe_layer_jnp(
        x, params["wg"], params["w1"], params["b1"], params["w2"], params["b2"],
        k=k, capacity=capacity,
    )
    pred = h @ params["head_w"] + params["head_b"]
    return jnp.mean((pred - y) ** 2)


@functools.partial(jax.jit, static_argnames=("k", "capacity", "lr"))
def train_step(params, x, y, *, k: int, capacity: int, lr: float):
    """One SGD step; returns (loss, updated params)."""
    loss, grads = jax.value_and_grad(loss_fn)(params, x, y, k=k, capacity=capacity)
    new = {name: params[name] - lr * grads[name] for name in params}
    return loss, new


def train_step_flat(flat_params, x, y, *, h, d, e, k, capacity, lr):
    """Flat-argument wrapper for AOT lowering (PJRT takes positional args)."""
    params = dict(zip(PARAM_ORDER, flat_params))
    loss, new = train_step(params, x, y, k=k, capacity=capacity, lr=lr)
    return (loss,) + tuple(new[name] for name in PARAM_ORDER)
