//! Fig 18 — FP16 vs FP32: wire bytes and the shared-memory instruction
//! model behind the paper's observed 2x smem instruction count.
fn main() {
    let (text, _) = flashdmoe::harness::fig18(42).unwrap();
    println!("{text}");
}
