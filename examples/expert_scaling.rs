//! Expert-scalability scenario (the paper's §4.6 motivation, run for real):
//! sweep the expert count on the *real* coordinator at a small scale and
//! on the calibrated simulator at paper scale, and show the flash design's
//! flat latency vs the launch-bound baselines. Closes with a routing
//! policy A/B: fixed-capacity dispatch (drops under skew) vs dropless
//! variable-capacity dispatch (zero drops, same payload efficiency).
//!
//!     cargo run --release --example expert_scaling

use std::sync::Arc;

use flashdmoe::config::Config;
use flashdmoe::coordinator::{baseline, MoeEngine, TaskGraphMode};
use flashdmoe::expert::{generate_tokens, ModelParams};
use flashdmoe::runtime::{ComputeBackend, NativeBackend};
use flashdmoe::sim::engines::{simulate, Baseline, Engine};
use flashdmoe::util::stats::{fmt_time, Table};
use flashdmoe::workload::{cluster_workload, Skew};

fn main() -> anyhow::Result<()> {
    // ---- real execution at small scale -------------------------------------
    println!("## real coordinator (native backend, 4 ranks, 512 tokens/rank)\n");
    let mut t = Table::new(&["experts", "flash fwd", "bulk-sync fwd", "flash tiles", "payload saved"]);
    for e in [4usize, 8, 16, 32] {
        let mut cfg = Config::preset("default")?;
        cfg.set("experts", &e.to_string())?;
        cfg.validate()?;
        let params = Arc::new(ModelParams::generate(&cfg, 7));
        let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(&cfg));
        let inputs: Vec<Vec<f32>> =
            (0..cfg.system.ranks).map(|r| generate_tokens(&cfg, 7, r)).collect();
        let engine =
            MoeEngine::start(cfg.clone(), params.clone(), backend.clone(), TaskGraphMode::Fused)?;
        let _ = engine.submit(&inputs)?.wait()?; // warmup
        let flash = engine.submit(&inputs)?.wait()?;
        let base = baseline::forward_sequential(&cfg, &params, &backend, &inputs)?;
        t.row(&[
            e.to_string(),
            fmt_time(flash.metrics.wall_secs),
            fmt_time(base.metrics.wall_secs),
            flash.metrics.ranks.iter().map(|r| r.tiles_sent).sum::<usize>().to_string(),
            format!(
                "{:.1}%",
                flash.metrics.ranks.iter().map(|r| r.payload_savings()).sum::<f64>()
                    / cfg.system.ranks as f64 * 100.0
            ),
        ]);
    }
    println!("{}", t.render());

    // ---- calibrated simulation at paper scale (Fig 14) ----------------------
    println!("\n## simulator at paper scale (8 ranks, 16K tokens/rank)\n");
    let mut t = Table::new(&["experts", "FlashDMoE", "Megatron-TE", "FasterMoE", "TE/flash"]);
    for e in [8usize, 16, 32, 64, 128] {
        let cfg = flashdmoe::harness::paper_config(8, 16384, e)?;
        let wl = cluster_workload(&cfg, Skew::Zipf, 42);
        let flash = simulate(&cfg, &wl, Engine::Flash, 42)?;
        let te = simulate(&cfg, &wl, Engine::Baseline(Baseline::MegatronTe), 42)?;
        let fm = simulate(&cfg, &wl, Engine::Baseline(Baseline::FasterMoe), 42)?;
        t.row(&[
            e.to_string(),
            fmt_time(flash.latency),
            fmt_time(te.latency),
            fmt_time(fm.latency),
            format!("{:.2}x", te.latency / flash.latency),
        ]);
    }
    println!("{}", t.render());
    println!("flash stays flat; per-expert kernel launches make the baselines superlinear.");

    // ---- routing policy A/B: capacity vs dropless (real engine) -------------
    let (text, points) = flashdmoe::harness::routing_policy_ab("tiny", 7)?;
    println!("\n{text}");
    let dropless = points.iter().find(|p| p.policy == "dropless").unwrap();
    assert_eq!(dropless.dropped, 0, "dropless must never drop");
    println!(
        "dropless keeps every routed pair ({} dropped) at {:.1}% payload savings; \
         capacity arms trade dropped tokens for a smaller heap.",
        dropless.dropped,
        dropless.payload_savings * 100.0
    );
    Ok(())
}
