"""Training extension: the differentiable jnp MoE must match the Pallas
formulation, and SGD on the train_step graph must actually learn."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model, train
from compile.kernels import ref


def test_moe_layer_jnp_matches_pallas_formulation():
    rng = np.random.default_rng(0)
    h, d, e, k, bm, s = 32, 64, 4, 2, 16, 128
    cap = ref.expert_capacity(s, e, k, 1.0, bm)
    a = rng.normal(size=(s, h)).astype(np.float32)
    wg = rng.normal(size=(h, e)).astype(np.float32)
    w1 = (rng.normal(size=(e, h, d)) * 0.1).astype(np.float32)
    b1 = (rng.normal(size=(e, d)) * 0.1).astype(np.float32)
    w2 = (rng.normal(size=(e, d, h)) * 0.1).astype(np.float32)
    b2 = (rng.normal(size=(e, h)) * 0.1).astype(np.float32)
    got = train.moe_layer_jnp(*map(jnp.array, (a, wg, w1, b1, w2, b2)), k=k, capacity=cap)
    want = model.moe_layer(
        *map(jnp.array, (a, wg, w1, b1, w2, b2)), k=k, capacity=cap, s_rank=s, bm=bm
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-3)


def test_train_step_reduces_loss():
    h, d, e, k = 16, 32, 4, 2
    s = 64
    cap = ref.expert_capacity(s, e, k, 1.0, 8)
    key = jax.random.PRNGKey(0)
    params = train.init_params(key, h, d, e)
    x = jax.random.normal(jax.random.PRNGKey(1), (s, h))
    wt = jax.random.normal(jax.random.PRNGKey(2), (h, 1)) * 0.5
    y = jnp.tanh(x @ wt)
    losses = []
    for _ in range(80):
        loss, params = train.train_step(params, x, y, k=k, capacity=cap, lr=0.1)
        losses.append(float(loss))
    assert losses[-1] < 0.4 * losses[0], f"no learning: {losses[0]} -> {losses[-1]}"
    assert all(np.isfinite(losses)), "loss diverged"


def test_train_step_flat_roundtrip():
    h, d, e, k = 16, 32, 4, 1
    s = 32
    cap = ref.expert_capacity(s, e, k, 1.0, 8)
    params = train.init_params(jax.random.PRNGKey(3), h, d, e)
    flat = tuple(params[n] for n in train.PARAM_ORDER)
    x = jax.random.normal(jax.random.PRNGKey(4), (s, h))
    y = jnp.zeros((s, 1))
    out = train.train_step_flat(flat, x, y, h=h, d=d, e=e, k=k, capacity=cap, lr=0.1)
    assert len(out) == 1 + len(train.PARAM_ORDER)
    for new, name in zip(out[1:], train.PARAM_ORDER):
        assert new.shape == params[name].shape
