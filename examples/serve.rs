//! Serving-style driver: a request router + dynamic batcher in front of
//! the distributed MoE operator — the shape a deployment embeds (vLLM-ish
//! front end, FlashDMoE back end). Synthetic clients submit variable-size
//! requests; the batcher packs them into fixed (S_r, H) rank batches
//! (padding tracked), runs the fused forward, and reports per-request
//! latency percentiles and sustained throughput.
//!
//!     cargo run --release --example serve

use std::collections::VecDeque;
use std::sync::Arc;

use flashdmoe::config::Config;
use flashdmoe::coordinator::{DistributedMoE, TaskGraphMode};
use flashdmoe::expert::ModelParams;
use flashdmoe::runtime::{ComputeBackend, NativeBackend};
use flashdmoe::util::prng::Rng;
use flashdmoe::util::stats::{fmt_time, summarize, Table};

struct Request {
    id: usize,
    tokens: usize,
    submitted: std::time::Instant,
}

fn main() -> anyhow::Result<()> {
    let n_requests: usize = std::env::var("REQUESTS").ok().and_then(|v| v.parse().ok()).unwrap_or(64);
    let cfg = Config::preset("tiny")?;
    let params = Arc::new(ModelParams::generate(&cfg, 42));
    let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(&cfg));
    let moe = DistributedMoE::new(cfg.clone(), params, backend, TaskGraphMode::Fused)?;

    let (s_rank, h, ranks) = (cfg.system.s_rank, cfg.model.h, cfg.system.ranks);
    let batch_capacity = s_rank * ranks;
    println!(
        "serving: batch capacity {} tokens ({} ranks x {}), H={}",
        batch_capacity, ranks, s_rank, h
    );

    // synthetic open-loop arrivals: requests of 8..256 tokens
    let mut rng = Rng::new(7);
    let mut queue: VecDeque<Request> = (0..n_requests)
        .map(|id| Request { id, tokens: 8 + rng.below(249), submitted: std::time::Instant::now() })
        .collect();

    let mut latencies = Vec::new();
    let mut batches = 0usize;
    let mut served_tokens = 0usize;
    let mut padded_tokens = 0usize;
    let t0 = std::time::Instant::now();
    while !queue.is_empty() {
        // dynamic batching: greedily pack whole requests into the batch
        let mut batch: Vec<Request> = Vec::new();
        let mut used = 0usize;
        while let Some(r) = queue.front() {
            if used + r.tokens > batch_capacity {
                break;
            }
            used += r.tokens;
            batch.push(queue.pop_front().unwrap());
        }
        anyhow::ensure!(!batch.is_empty(), "request larger than batch capacity");

        // pack token embeddings (synthetic) into per-rank inputs
        let mut flat = rng.normal_vec(batch_capacity * h, 1.0);
        // zero the padding region so it's visibly inert
        for v in flat[used * h..].iter_mut() {
            *v = 0.0;
        }
        let inputs: Vec<Vec<f32>> =
            (0..ranks).map(|r| flat[r * s_rank * h..(r + 1) * s_rank * h].to_vec()).collect();
        let out = moe.forward(&inputs)?;
        batches += 1;
        served_tokens += used;
        padded_tokens += batch_capacity - used;
        let now = std::time::Instant::now();
        for r in &batch {
            latencies.push(now.duration_since(r.submitted).as_secs_f64());
        }
        drop(out);
    }
    let wall = t0.elapsed().as_secs_f64();

    let s = summarize(&latencies);
    let mut t = Table::new(&["metric", "value"]);
    t.row(&["requests".into(), n_requests.to_string()]);
    t.row(&["batches".into(), batches.to_string()]);
    t.row(&["tokens served".into(), served_tokens.to_string()]);
    t.row(&["batch fill".into(), format!("{:.1}%", served_tokens as f64 / (served_tokens + padded_tokens) as f64 * 100.0)]);
    t.row(&["throughput".into(), format!("{:.0} tokens/s", served_tokens as f64 / wall)]);
    t.row(&["latency p50".into(), fmt_time(s.p50)]);
    t.row(&["latency p95".into(), fmt_time(s.p95)]);
    t.row(&["latency max".into(), fmt_time(s.max)]);
    println!("{}", t.render());
    println!("serve OK");
    Ok(())
}
