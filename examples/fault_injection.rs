//! Fault-injection example: deterministic chaos at the transport seam,
//! transparent pass retry, and degraded-capacity operation.
//!
//! The engine is launched once with a deterministic
//! [`FaultConfig`](flashdmoe::config::FaultConfig) schedule: every
//! cross-rank transfer of pass epoch 2 fails transiently, and rank 3
//! dies permanently at epoch 5. The example shows the three recovery
//! behaviors end to end:
//!
//! 1. the transient pass is retried transparently inside
//!    `PassHandle::wait` and its outputs are **bitwise identical** to a
//!    fault-free engine's;
//! 2. the permanent death swaps in a degraded placement at an epoch
//!    quiet point — hot-expert replicas keep the corpse's hot experts
//!    servable, un-replicated experts are explicitly accounted
//!    unavailable — and the engine keeps serving;
//! 3. the fault/retry/degrade ledger is visible in the engine metrics.
//!
//!     cargo run --release --example fault_injection

use std::sync::Arc;

use flashdmoe::config::Config;
use flashdmoe::coordinator::{MoeEngine, PassInput, TaskGraphMode};
use flashdmoe::expert::ModelParams;
use flashdmoe::runtime::{ComputeBackend, NativeBackend};
use flashdmoe::util::prng::Rng;
use flashdmoe::util::stats::Table;
use flashdmoe::workload::{skewed_tokens, Skew};

fn config(faulted: bool) -> anyhow::Result<Config> {
    let mut cfg = Config::preset("tiny")?;
    cfg.set("ranks", "4")?;
    cfg.set("tokens", "128")?;
    cfg.set("routing_policy", "dropless")?;
    // replicas so the dead rank's hot experts survive elsewhere
    cfg.set("replicate_top", "2")?;
    cfg.set("replicas", "2")?;
    cfg.set("replication_hysteresis", "1.2")?;
    cfg.set("ewma_alpha", "0.5")?;
    cfg.set("retry_limit", "2")?;
    if faulted {
        cfg.set("fault_seed", "42")?;
        cfg.set("fault_transient_rate", "1.0")?;
        cfg.set("fault_transient_from", "2")?; // pass epoch 2 fails...
        cfg.set("fault_transient_until", "3")?; // ...and only epoch 2
        cfg.set("fault_kill_rank", "3")?;
        cfg.set("fault_kill_epoch", "5")?; // rank 3 dies at epoch 5
    }
    cfg.validate()?;
    Ok(cfg)
}

fn main() -> anyhow::Result<()> {
    let seed = 42u64;
    let base = config(false)?;
    let params = Arc::new(ModelParams::generate(&base, seed));
    // Half-filled passes, so the degraded retry has spare capacity to
    // repack the dead rank's rows onto the survivors.
    let (h, e) = (base.model.h, base.model.e);
    let inputs: Vec<Vec<f32>> = (0..base.system.ranks)
        .map(|r| {
            let mut rng = Rng::new(seed).fork(0xC4A0_0000 + r as u64);
            skewed_tokens(&params.wg, h, e, base.system.s_rank / 2, Skew::Zipf, &mut rng)
        })
        .collect();

    // fault-free reference run: 2 passes
    let clean = {
        let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(&base));
        let engine = MoeEngine::start(base.clone(), params.clone(), backend, TaskGraphMode::Fused)?;
        let mut outs = Vec::new();
        for _ in 0..2 {
            outs.push(engine.submit_pass(PassInput::new(inputs.clone()))?.wait()?.outputs);
        }
        engine.shutdown();
        outs
    };

    let cfg = config(true)?;
    let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(&cfg));
    let engine = MoeEngine::start(cfg.clone(), params.clone(), backend, TaskGraphMode::Fused)?;

    // epoch 1: clean. epoch 2: every transfer faulted -> one transparent
    // retry, outputs bitwise identical to the fault-free run.
    for (pass, want) in clean.iter().enumerate() {
        let res = engine.submit_pass(PassInput::new(inputs.clone()))?.wait()?;
        for (r, (a, b)) in want.iter().zip(&res.outputs).enumerate() {
            for (i, (x, y)) in a.iter().zip(b).enumerate() {
                anyhow::ensure!(
                    x.to_bits() == y.to_bits(),
                    "pass {}, rank {r} elem {i}: clean {x} != faulted {y}",
                    pass + 1
                );
            }
        }
        println!(
            "pass {}: ok, retries={} (bitwise identical to fault-free run)",
            pass + 1,
            res.metrics.retries
        );
    }

    // epochs 3-4: warm the load tracker, install hot-expert replicas
    engine.submit_pass(PassInput::new(inputs.clone()))?.wait()?;
    engine.submit_pass(PassInput::new(inputs.clone()))?.wait()?;
    let replicated = engine.rebalance()?;
    println!("rebalance before the kill: replicas installed = {replicated}");

    // epoch 5: rank 3 is dead. wait() fences, degrades the placement,
    // repacks the corpse's rows onto survivors, and retries.
    let res = engine.submit_pass(PassInput::new(inputs.clone()))?.wait()?;
    let placement = engine.placement();
    println!(
        "kill epoch: recovered with retries={}, failed ranks {:?}, {} expert(s) unavailable",
        res.metrics.retries,
        placement.failed_ranks(),
        placement.unavailable_experts().len()
    );
    anyhow::ensure!(placement.degraded(), "placement must be degraded after the kill");

    // steady state: the engine keeps serving on surviving capacity
    let steady = engine.submit_pass(PassInput::new(inputs.clone()))?.wait()?;
    anyhow::ensure!(steady.metrics.retries == 0, "steady degraded pass must not retry");

    let em = engine.metrics();
    engine.shutdown();
    let mut t = Table::new(&["metric", "value"]);
    t.row(&["passes".into(), em.passes.to_string()]);
    t.row(&["retries".into(), em.retries.to_string()]);
    t.row(&["degraded passes".into(), em.degraded_passes.to_string()]);
    t.row(&["faults injected".into(), em.faults_injected.to_string()]);
    t.row(&["launches".into(), em.launches.to_string()]);
    println!("{}", t.render());

    anyhow::ensure!(em.retries >= 2, "transient + kill each cost one retry");
    anyhow::ensure!(em.degraded_passes >= 2, "kill retry + steady pass ran degraded");
    anyhow::ensure!(em.faults_injected >= 1, "the schedule must actually inject");
    println!("fault_injection OK");
    Ok(())
}
