//! Straggler study (paper §2.1, Table 2, Fig 15): how much idle time does
//! bulk-synchronous AllToAll leave on the table, and what does obviating
//! the barrier reclaim? Plus the live-engine counterpart: under Zipf
//! routing skew the rank hosting the hot expert *is* the straggler, and
//! EWMA-driven hot-expert replication (`MoeEngine::rebalance`) spreads
//! that load across replica slots without changing any output bit.
//!
//!     cargo run --release --example straggler_study

use flashdmoe::sim::straggler::{self, idle_fraction, Platform};
use flashdmoe::util::stats::Table;

fn main() {
    println!("## Table 2 — straggler delay within synchronous AllToAll\n");
    let platforms = [straggler::commercial_vm(), straggler::supercomputer()];
    let paper = [(3.1, 11.4), (1.09, 1.32)];
    let mut t = Table::new(&["System", "#GPUs", "steps", "median (paper)", "p95 (paper)", "p95 idle"]);
    let mut reports = Vec::new();
    for (p, (pm, pp)) in platforms.into_iter().zip(paper) {
        let rep = straggler::run(p, 42);
        t.row(&[
            p.name.to_string(),
            p.gpus.to_string(),
            p.steps.to_string(),
            format!("{:.2}x ({pm}x)", rep.summary.p50),
            format!("{:.2}x ({pp}x)", rep.summary.p95),
            format!("{:.0}%", idle_fraction(rep.summary.p95) * 100.0),
        ]);
        reports.push(rep);
    }
    println!("{}", t.render());

    // Fig 15 — the raw delay distribution as an ASCII histogram
    println!("\n## Fig 15 — delay distribution (commercial VM)\n");
    let ratios = &reports[0].ratios;
    let buckets = [1.0, 1.5, 2.0, 3.0, 5.0, 8.0, 12.0, f64::INFINITY];
    let mut counts = vec![0usize; buckets.len()];
    for &r in ratios {
        let i = buckets.iter().position(|&b| r < b).unwrap_or(buckets.len() - 1);
        counts[i] += 1;
    }
    for (i, c) in counts.iter().enumerate() {
        let label = if i == 0 {
            "< 1.0x ".to_string()
        } else if buckets[i].is_infinite() {
            format!(">= {:.1}x", buckets[i - 1])
        } else {
            format!("{:.1}-{:.1}x", buckets[i - 1], buckets[i])
        };
        let bar = "#".repeat(c * 60 / ratios.len().max(1));
        println!("{label:>10} | {bar} {c}");
    }

    // sensitivity: world size amplifies the straggler tax
    println!("\n## sensitivity — straggler tax vs world size (sigma = VM)\n");
    let mut t = Table::new(&["GPUs", "median", "p95"]);
    for gpus in [2usize, 4, 8, 16, 32] {
        let rep = straggler::run(
            Platform {
                name: "vm",
                nodes: 1,
                gpus,
                sigma: 0.38,
                tail_prob: 0.04,
                tail_scale: 4.0,
                steps: 1000,
            },
            7,
        );
        t.row(&[
            gpus.to_string(),
            format!("{:.2}x", rep.summary.p50),
            format!("{:.2}x", rep.summary.p95),
        ]);
    }
    println!("{}", t.render());
    println!("more participants -> worse max/min ratio -> more idle time at the barrier;");
    println!("FlashDMoE has no barrier, so this tax is structural, not incidental.");

    // live engines: the self-inflicted straggler (hot expert under Zipf
    // skew) and what replication reclaims — measured, not simulated
    println!("\n## live engines — hot-expert replication vs static placement\n");
    let (text, pts) = flashdmoe::harness::replication_ab(42).expect("replication A/B");
    println!("{text}");
    for p in &pts {
        println!(
            "{:>10}: hot-rank busy share {:.1}%, imbalance {:.2}x, replica rows {}",
            p.arm,
            p.hot_rank_busy_share * 100.0,
            p.imbalance,
            p.replica_hits
        );
    }
    println!("\nsame inputs, same weights, bitwise-identical outputs — only the placement moved.");
}
