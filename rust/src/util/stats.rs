//! Small statistics helpers: percentiles, summaries and fixed-width tables
//! for the bench harness (Table 2 / Fig 15 style delay distributions,
//! latency summaries, utilization reports).

/// Summary statistics over a sample.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

/// Percentile with linear interpolation (q in [0, 1]); matches numpy's
/// default 'linear' method so python-side cross-checks agree.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of empty sample");
    debug_assert!(sorted.windows(2).all(|w| w[0] <= w[1]), "input must be sorted");
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

pub fn summarize(xs: &[f64]) -> Summary {
    assert!(!xs.is_empty());
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = sorted.len();
    let mean = sorted.iter().sum::<f64>() / n as f64;
    let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        p50: percentile(&sorted, 0.50),
        p95: percentile(&sorted, 0.95),
        p99: percentile(&sorted, 0.99),
        max: sorted[n - 1],
    }
}

/// Render a markdown table (used by every bench to print paper-style rows).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {c:<w$} |"));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }
}

/// Format seconds adaptively (ns/µs/ms/s).
pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.1}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{secs:.3}s")
    }
}

/// Format bytes adaptively.
pub fn fmt_bytes(b: f64) -> String {
    if b < 1024.0 {
        format!("{b:.0}B")
    } else if b < 1024.0 * 1024.0 {
        format!("{:.1}KB", b / 1024.0)
    } else if b < 1024.0 * 1024.0 * 1024.0 {
        format!("{:.2}MB", b / (1024.0 * 1024.0))
    } else {
        format!("{:.2}GB", b / (1024.0 * 1024.0 * 1024.0))
    }
}

/// max |a - b| over two equal-length slices (test helper).
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "length mismatch {} vs {}", a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        assert!((percentile(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_sane() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 100.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.max, 100.0);
        assert_eq!(s.p50, 3.0);
        assert!(s.p95 > s.p50);
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new(&["Works", "Launched GPU Ops"]);
        t.row(&["FlashDMoE".into(), "1".into()]);
        let out = t.render();
        assert!(out.contains("| FlashDMoE"));
        assert!(out.lines().count() == 3);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_time(0.0015), "1.50ms");
        assert_eq!(fmt_bytes(2048.0), "2.0KB");
    }
}
