//! API-compatible stub of the `xla` PJRT bindings used by
//! `flashdmoe::runtime`. It exists so the workspace builds (and the
//! native-backend paths run) on machines without the XLA C libraries:
//! literal construction works for real, while anything that needs an
//! actual PJRT runtime (`PjRtClient::cpu`, compilation, execution)
//! returns a descriptive error. Replace this path dependency with the
//! real bindings to execute the AOT HLO artifacts.

use std::path::Path;

/// Stub error: everything that would touch PJRT reports through this.
#[derive(Debug, Clone)]
pub struct Error(String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unsupported<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what} requires a real PJRT runtime; this build uses the offline `xla` stub \
         (vendor/xla) — swap it for the real bindings to run AOT artifacts"
    )))
}

/// Element dtypes the runtime constructs literals with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
}

/// Host-side literal: shape + raw bytes. Construction is real (callers
/// cache weight literals before any execution is attempted); consumption
/// paths are only reachable after a successful execution, which the stub
/// never produces.
#[derive(Clone, Debug)]
pub struct Literal {
    elem: ElementType,
    dims: Vec<usize>,
    bytes: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        elem: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        Ok(Literal { elem, dims: dims.to_vec(), bytes: data.to_vec() })
    }

    pub fn element_type(&self) -> ElementType {
        self.elem
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn raw_bytes(&self) -> &[u8] {
        &self.bytes
    }

    pub fn to_vec<T: Copy>(&self) -> Result<Vec<T>> {
        unsupported("Literal::to_vec")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unsupported("Literal::to_tuple")
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        unsupported("Literal::to_tuple1")
    }
}

/// Parsed HLO module text (never constructed by the stub).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        unsupported(&format!(
            "HloModuleProto::from_text_file({})",
            path.as_ref().display()
        ))
    }
}

/// An XLA computation handle.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation(())
    }
}

/// A PJRT client. The stub has no runtime, so `cpu()` fails up front —
/// callers gate on artifact availability before reaching this.
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unsupported("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unsupported("PjRtClient::compile")
    }
}

/// A compiled executable (never constructed by the stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unsupported("PjRtLoadedExecutable::execute")
    }
}

/// A device buffer handle (never constructed by the stub).
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unsupported("PjRtBuffer::to_literal_sync")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literals_build_offline() {
        let data = [0u8; 16];
        let l = Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 2], &data)
            .unwrap();
        assert_eq!(l.dims(), &[2, 2]);
        assert_eq!(l.raw_bytes().len(), 16);
        assert_eq!(l.element_type(), ElementType::F32);
    }

    #[test]
    fn runtime_paths_error_descriptively() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("PJRT"), "{e}");
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
