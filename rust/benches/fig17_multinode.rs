//! Fig 17 — multi-node A/B, **measured on live engines** over the
//! Transport subsystem (the old closed-form sim sweep is gone): flat vs
//! hierarchical dispatch on the same node-aware config, params and
//! inputs, reporting per-pass latency vs tokens/GPU, the intra/inter
//! byte split, the *measured* Maximal Incast Volume (the paper's §F
//! formula stays as a cross-check column), and the >2048-tokens/GPU
//! incast overflow as an engine-reported pass error. Bitwise equality of
//! flat vs hierarchical outputs is asserted inside the harness.
//!
//! Emits `BENCH_pr6_multinode.json` (section `multinode_ab`) for the CI
//! artifact upload. With `PERF_SMOKE=1` the run FAILS if hierarchical
//! dispatch ever moves *more* inter-node bytes than flat dispatch at the
//! same tokens/GPU — the harness only reports the split (it asserts
//! output equality and the incast bound, not the byte ordering), so this
//! gate is the live CI check that coalescing actually pays.
//!
//!     cargo bench --bench fig17_multinode
fn main() {
    let (text, pts) = flashdmoe::harness::multinode_ab(42).unwrap();
    println!("{text}");

    flashdmoe::harness::update_bench_json(
        "BENCH_pr6_multinode.json",
        "multinode_ab",
        flashdmoe::harness::multinode_json(&pts),
    )
    .unwrap();
    println!("wrote BENCH_pr6_multinode.json (section multinode_ab)");

    let perf_smoke = std::env::var("PERF_SMOKE").map(|v| v == "1").unwrap_or(false);
    if perf_smoke {
        let mut failed = false;
        let mut compared = 0;
        for f in pts.iter().filter(|p| p.mode == "flat" && !p.overflow) {
            let Some(h) = pts
                .iter()
                .find(|p| p.mode == "hierarchical" && p.tokens_per_gpu == f.tokens_per_gpu)
            else {
                continue;
            };
            if h.overflow {
                continue;
            }
            compared += 1;
            if h.inter_bytes > f.inter_bytes {
                eprintln!(
                    "PERF_SMOKE FAIL: hierarchical moved {} inter-node bytes vs flat {} \
                     at {} tokens/GPU (coalescing must not add NIC traffic)",
                    h.inter_bytes, f.inter_bytes, f.tokens_per_gpu
                );
                failed = true;
            } else {
                println!(
                    "PERF_SMOKE ok: {} tokens/GPU inter bytes {:.3}x flat (MIV {:.3}x)",
                    f.tokens_per_gpu,
                    h.inter_bytes as f64 / f.inter_bytes.max(1) as f64,
                    h.miv_bytes as f64 / f.miv_bytes.max(1) as f64,
                );
            }
        }
        // an A/B with nothing to compare must not pass silently
        if compared == 0 {
            eprintln!("PERF_SMOKE FAIL: no comparable (flat, hierarchical) point pairs");
            failed = true;
        }
        // the incast cliff must exist: the top of the sweep overflows
        if !pts.iter().any(|p| p.overflow) {
            eprintln!("PERF_SMOKE FAIL: no point overflowed the NIC receive window");
            failed = true;
        }
        if failed {
            std::process::exit(1);
        }
    }
}
