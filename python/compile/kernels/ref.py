"""Pure-jnp / numpy reference oracle for every FlashDMoE compute operator.

This module is the single source of numerical truth shared by

  * the Pallas kernels (L1)  — pytest asserts kernel == ref,
  * the JAX model graph (L2) — pytest asserts model == ref_moe_forward,
  * the Rust coordinator (L3) — the monolithic ``moe_layer`` HLO artifact
    (built from the L2 graph) is executed via PJRT and compared against the
    distributed Rust forward pass.

Numerics contract (DESIGN.md §4):

  * gate: row softmax over E logits (max-subtracted, f32), top-k by score,
    ties broken toward the lower expert index (== ``jax.lax.top_k``).
  * combine: h_i = sum_k (g_ik / C_i) * h_i^k with C_i = sum_k g_ik over the
    token's top-k *regardless of drops*; dropped experts contribute zero.
  * capacity: per (source rank, expert); slot order = token index order;
    a routed pair is dropped when its slot index >= capacity.
  * FFN: relu(x @ W1 + b1) @ W2 + b2, all f32.
"""

from __future__ import annotations

import math

import numpy as np


def softmax(x: np.ndarray) -> np.ndarray:
    """Row softmax, numerically stable, f32."""
    x = x.astype(np.float32)
    m = x.max(axis=-1, keepdims=True)
    e = np.exp(x - m)
    return e / e.sum(axis=-1, keepdims=True)


def ref_gate(a: np.ndarray, wg: np.ndarray) -> np.ndarray:
    """Gate scores G_phi in R^{S x E}: softmax(A @ Wg)."""
    logits = a.astype(np.float32) @ wg.astype(np.float32)
    return softmax(logits)


def ref_topk(scores: np.ndarray, k: int):
    """Top-k experts per token by score, ties -> lower expert index.

    Returns (indices, weights), both (S, k). Matches jax.lax.top_k ordering
    (descending value, ascending index among equals).
    """
    # argsort on index-ordered array with a stable sort gives exactly
    # lax.top_k tie-breaking.
    order = np.argsort(-scores, axis=-1, kind="stable")
    idx = order[:, :k]
    w = np.take_along_axis(scores, idx, axis=-1)
    return idx.astype(np.int32), w.astype(np.float32)


def ref_ffn(x: np.ndarray, w1, b1, w2, b2) -> np.ndarray:
    """Position-wise expert FFN: relu(x@W1+b1)@W2+b2 (paper eq. 1)."""
    h = np.maximum(x.astype(np.float32) @ w1.astype(np.float32) + b1, 0.0)
    return h @ w2.astype(np.float32) + b2


def ref_gemm0(x: np.ndarray, w1, b1) -> np.ndarray:
    """First FFN GEMM with fused ReLU epilogue (task t1)."""
    return np.maximum(x.astype(np.float32) @ w1.astype(np.float32) + b1, 0.0)


def ref_gemm1(h: np.ndarray, w2, b2) -> np.ndarray:
    """Second FFN GEMM with identity epilogue (task t2)."""
    return h.astype(np.float32) @ w2.astype(np.float32) + b2


def ref_combine(acc: np.ndarray, x: np.ndarray, scale: np.ndarray) -> np.ndarray:
    """Expert-combine task t3: acc + scale * x (Hadamard-accumulate)."""
    return acc.astype(np.float32) + scale.astype(np.float32) * x.astype(np.float32)


def expert_capacity(s_rank: int, n_experts: int, k: int, factor: float, bm: int) -> int:
    """Aligned per-(source rank, expert) capacity (paper §3.2.1).

    raw = ceil(S_r * k / E * factor), then upscaled to max(raw, bM) and
    rounded up to a multiple of bM so remote tile reads are aligned.
    """
    raw = math.ceil(s_rank * k / n_experts * factor)
    cap = max(raw, bm)
    return ((cap + bm - 1) // bm) * bm


def ref_route(scores: np.ndarray, k: int, capacity: int, s_rank: int):
    """Routing tables for all tokens, capacity applied per (source rank, expert).

    Args:
      scores: (S_total, E) gate scores; tokens [r*s_rank, (r+1)*s_rank) belong
        to source rank r.
      capacity: aligned per-(rank, expert) capacity.

    Returns:
      idx:  (S_total, k) int32 expert ids.
      w:    (S_total, k) f32 raw gate weights.
      slot: (S_total, k) int32 slot within the (rank, expert) buffer, or -1
        when the pair was dropped (over capacity).
    """
    s_total, _ = scores.shape
    idx, w = ref_topk(scores, k)
    slot = np.full((s_total, k), -1, dtype=np.int32)
    n_ranks = s_total // s_rank
    for r in range(n_ranks):
        counts: dict[int, int] = {}
        for i in range(r * s_rank, (r + 1) * s_rank):
            for j in range(k):
                e = int(idx[i, j])
                c = counts.get(e, 0)
                if c < capacity:
                    slot[i, j] = c
                    counts[e] = c + 1
    return idx, w, slot


def ref_moe_forward(
    a: np.ndarray,
    wg: np.ndarray,
    w1: np.ndarray,
    b1: np.ndarray,
    w2: np.ndarray,
    b2: np.ndarray,
    k: int,
    capacity: int,
    s_rank: int | None = None,
) -> np.ndarray:
    """Full MoE layer oracle (gate -> route/drop -> expert FFN -> combine).

    a: (S_total, H); wg: (H, E); w1: (E, H, D); b1: (E, D); w2: (E, D, H);
    b2: (E, H). capacity is per (source rank, expert); s_rank defaults to
    S_total (single rank).
    """
    s_total, h = a.shape
    if s_rank is None:
        s_rank = s_total
    scores = ref_gate(a, wg)
    idx, w, slot = ref_route(scores, k, capacity, s_rank)

    out = np.zeros((s_total, h), dtype=np.float32)
    # Per-token denominator over the full top-k (drops included).
    denom = w.sum(axis=-1)
    for i in range(s_total):
        for j in range(k):
            if slot[i, j] < 0:
                continue  # dropped: contributes zero
            e = int(idx[i, j])
            y = ref_ffn(a[i : i + 1], w1[e], b1[e], w2[e], b2[e])
            out[i] += (w[i, j] / denom[i]) * y[0]
    return out
