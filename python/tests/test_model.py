"""L2 correctness: the full MoE layer graph vs the numpy oracle,
including capacity-drop and multi-rank sharding semantics."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def make_weights(rng, h, d, e):
    return (
        (rng.normal(size=(h, e))).astype(np.float32),
        (rng.normal(size=(e, h, d)) * 0.1).astype(np.float32),
        (rng.normal(size=(e, d)) * 0.1).astype(np.float32),
        (rng.normal(size=(e, d, h)) * 0.1).astype(np.float32),
        (rng.normal(size=(e, h)) * 0.1).astype(np.float32),
    )


def run_both(a, wg, w1, b1, w2, b2, k, cap, s_rank, bm):
    got = np.asarray(
        model.moe_layer(
            *map(jnp.array, (a, wg, w1, b1, w2, b2)),
            k=k, capacity=cap, s_rank=s_rank, bm=bm,
        )
    )
    want = ref.ref_moe_forward(a, wg, w1, b1, w2, b2, k, cap, s_rank)
    return got, want


@given(
    ranks=st.sampled_from([1, 2, 4]),
    e=st.sampled_from([4, 8]),
    k=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=12, deadline=None)
def test_moe_layer_matches_oracle(ranks, e, k, seed):
    rng = np.random.default_rng(seed)
    h, d, bm, s_rank = 32, 64, 16, 64
    a = rng.normal(size=(ranks * s_rank, h)).astype(np.float32)
    wg, w1, b1, w2, b2 = make_weights(rng, h, d, e)
    cap = ref.expert_capacity(s_rank, e, k, 1.0, bm)
    got, want = run_both(a, wg, w1, b1, w2, b2, k, cap, s_rank, bm)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_moe_layer_with_forced_drops():
    """Skew the gate so one expert overflows capacity; drops must match."""
    rng = np.random.default_rng(7)
    h, d, e, k, bm, s_rank = 32, 64, 4, 2, 16, 64
    wg, w1, b1, w2, b2 = make_weights(rng, h, d, e)
    wg[:, 0] += 3.0  # strongly bias expert 0 -> overflow
    a = rng.normal(size=(2 * s_rank, h)).astype(np.float32)
    cap = bm  # minimum capacity, guarantees drops on expert 0
    scores = ref.ref_gate(a, wg)
    _, _, slot = ref.ref_route(scores, k, cap, s_rank)
    assert (slot < 0).any(), "test requires at least one dropped pair"
    got, want = run_both(a, wg, w1, b1, w2, b2, k, cap, s_rank, bm)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_moe_layer_single_expert_is_plain_ffn():
    """E=1, k=1, ample capacity: the layer degenerates to one dense FFN."""
    rng = np.random.default_rng(9)
    h, d, bm, s = 32, 64, 16, 128
    wg, w1, b1, w2, b2 = make_weights(rng, h, d, 1)
    a = rng.normal(size=(s, h)).astype(np.float32)
    got = np.asarray(
        model.moe_layer(
            *map(jnp.array, (a, wg, w1, b1, w2, b2)),
            k=1, capacity=s, s_rank=s, bm=bm,
        )
    )
    np.testing.assert_allclose(
        got, ref.ref_ffn(a, w1[0], b1[0], w2[0], b2[0]), rtol=1e-3, atol=1e-3
    )


def test_route_slots_are_contiguous_per_expert():
    """Slots for each (rank, expert) group must be 0..n-1 in arrival order."""
    rng = np.random.default_rng(11)
    idx = rng.integers(0, 4, size=(32, 2)).astype(np.int32)
    slots = np.asarray(model.route_slots(jnp.array(idx), 4, 1 << 30))
    for e in range(4):
        got = slots.reshape(-1)[idx.reshape(-1) == e]
        np.testing.assert_array_equal(got, np.arange(len(got)))


@given(
    s_rank=st.sampled_from([16, 64, 512]),
    e=st.sampled_from([4, 16, 64]),
    k=st.sampled_from([1, 2]),
    f=st.sampled_from([0.5, 1.0, 1.25]),
    bm=st.sampled_from([16, 128]),
)
@settings(max_examples=40, deadline=None)
def test_capacity_invariants(s_rank, e, k, f, bm):
    cap = ref.expert_capacity(s_rank, e, k, f, bm)
    assert cap % bm == 0, "in-place padding alignment (paper 3.2.1)"
    assert cap >= bm
    assert cap >= min(int(np.ceil(s_rank * k / e * f)), cap)
