//! Discrete-event simulator: regenerates the paper's evaluation figures on
//! a calibrated cost model, replaying the *same* routing tables the real
//! coordinator produces.
//!
//! Why a simulator: the paper's testbed is 8×H100 + NVLink (+ 4-node A100
//! with 25 GB/s NICs). The structural claims — overlap, payload
//! efficiency, launch-overhead elimination, straggler sensitivity — are
//! properties of the *schedule*, which the engines below reproduce
//! faithfully over virtual time: the flash engine schedules tile tasks
//! the moment their one-sided transfer lands; the sequential engine
//! inserts bulk-synchronous barriers and padded payloads; the overlap
//! engine pipelines chunked collectives against compute with per-chunk
//! launches. Compute costs are calibrated from measured tile-GEMM times
//! ([`calibrate`]); communication follows bytes/bandwidth + latency on
//! per-directed-link queues.

pub mod calibrate;
pub mod engines;
pub mod resources;
pub mod straggler;

pub use engines::{simulate, Engine, SimReport};
