//! Real-execution bulk-synchronous baseline (the Megatron/DeepSpeed shape
//! the paper compares against): the same gate/routing/expert math as the
//! flash coordinator, but structured as a sequence of "kernel launches"
//! separated by global barriers, with *padded* all-to-all payloads.
//!
//! Phases (each barrier-delimited, each counted as kernel launches):
//!   1. gate (1 launch/rank)
//!   2. dispatch AllToAll — every active (rank, expert) pair ships its full
//!      capacity buffer, padding included (no payload efficiency)
//!   3. expert FFN — one grouped-GEMM launch per local expert
//!   4. combine AllToAll — full capacity buffers back
//!   5. combine/scale (1 launch/rank)
//!
//! Numerics are identical to the flash path (same routing contract), which
//! `rust/tests/integration.rs` asserts; the point of this module is a
//! measured apples-to-apples latency/launch-count/payload comparison on
//! the same substrate, and a second numeric witness for the coordinator.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Barrier};

use anyhow::{Context, Result};

use crate::config::Config;
use crate::expert::ModelParams;
use crate::gate::{dispatch_plan, route_from_scores};
use crate::placement::Placement;
use crate::runtime::ComputeBackend;

/// Metrics of one bulk-synchronous pass.
#[derive(Clone, Debug, Default)]
pub struct BaselineMetrics {
    pub wall_secs: f64,
    /// Logical kernel launches across all ranks (Table 1's comparison).
    pub launches: usize,
    /// Rows shipped over the (emulated) wire, padding included.
    pub sent_rows: usize,
    /// Valid rows among them.
    pub valid_rows: usize,
    /// Time spent inside barriers (exposed, non-overlapped communication).
    pub barrier_secs: f64,
}

/// Output of the baseline forward.
pub struct BaselineResult {
    pub outputs: Vec<Vec<f32>>,
    pub metrics: BaselineMetrics,
}

/// Bulk-synchronous MoE forward over the same substrate as the flash
/// path, under the static block placement (`Placement::from_config`).
pub fn forward_sequential(
    cfg: &Config,
    params: &Arc<ModelParams>,
    backend: &Arc<dyn ComputeBackend>,
    inputs: &[Vec<f32>],
) -> Result<BaselineResult> {
    forward_sequential_placed(cfg, params, backend, inputs, &Placement::from_config(cfg))
}

/// Bulk-synchronous MoE forward under an explicit expert→location
/// [`Placement`] — the replication-aware variant the conformance tests
/// drive against a replicated engine. Tokens of a replicated expert are
/// sharded across its serving slots by the same deterministic gate-side
/// splitter as the flash path (`dispatch_plan`), so outputs stay bitwise
/// identical to the static-placement baseline.
pub fn forward_sequential_placed(
    cfg: &Config,
    params: &Arc<ModelParams>,
    backend: &Arc<dyn ComputeBackend>,
    inputs: &[Vec<f32>],
    placement: &Placement,
) -> Result<BaselineResult> {
    let ranks = cfg.system.ranks;
    anyhow::ensure!(inputs.len() == ranks);
    let m = cfg.model.clone();
    let (s_rank, h, d) = (cfg.system.s_rank, cfg.model.h, cfg.model.d);
    // Policy-aware slab size: the fixed capacity under `Capacity`, the
    // worst-case slot region under `Dropless` — a padded bulk-synchronous
    // implementation must ship whatever region guarantees zero drops, so
    // the baseline keeps matching the flash path's function in both modes
    // (and pays dearly for it on the wire, which is the point).
    let capacity = cfg.model.slot_capacity(s_rank);
    // Expert *slots* per rank: owned block plus (possibly bound) replica
    // slots — the exchange slabs cover both with no special cases.
    let e_slots = cfg.local_experts() + placement.replica_slots();

    let barrier = Barrier::new(ranks);
    let launches = AtomicUsize::new(0);
    let sent_rows = AtomicUsize::new(0);
    let valid_rows = AtomicUsize::new(0);
    let barrier_nanos = AtomicU64::new(0);

    // Exchange buffers: expert_in[owner][src][e_loc] is a (capacity, H)
    // padded slab — the bulk-synchronous AllToAll always ships all of it.
    let expert_in: Vec<Vec<Vec<std::sync::Mutex<Vec<f32>>>>> = (0..ranks)
        .map(|_| {
            (0..ranks)
                .map(|_| (0..e_slots).map(|_| std::sync::Mutex::new(vec![0.0f32; capacity * h])).collect())
                .collect()
        })
        .collect();
    let combine_back: Vec<Vec<Vec<std::sync::Mutex<Vec<f32>>>>> = (0..ranks)
        .map(|_| {
            (0..ranks)
                .map(|_| (0..e_slots).map(|_| std::sync::Mutex::new(vec![0.0f32; capacity * h])).collect())
                .collect()
        })
        .collect();

    let sync = |nanos: &AtomicU64| {
        let t = std::time::Instant::now();
        barrier.wait();
        nanos.fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
    };

    let t0 = std::time::Instant::now();
    let outputs: Vec<Vec<f32>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..ranks)
            .map(|rank| {
                let a = &inputs[rank];
                let expert_in = &expert_in;
                let combine_back = &combine_back;
                let launches = &launches;
                let sent_rows = &sent_rows;
                let valid_rows = &valid_rows;
                let barrier_nanos = &barrier_nanos;
                let m = &m;
                let backend = backend.clone();
                let params = params.clone();
                let cfg = cfg.clone();
                scope.spawn(move || -> Result<Vec<f32>> {
                    // phase 1: gate (one launch)
                    let scores = backend.gate_scores(a, &params.wg, s_rank)?;
                    launches.fetch_add(1, Ordering::Relaxed);
                    let routing = route_from_scores(scores, s_rank, m, capacity);
                    let plan = dispatch_plan(&routing, m.bm, placement);
                    sync(barrier_nanos);

                    // phase 2: padded dispatch AllToAll — ships every active
                    // (dst rank, dst slot) capacity slab in full (one
                    // "launch" per peer, the collective's chunked sends). A
                    // replicated expert occupies one slab per serving
                    // location; the plan already sharded its tokens.
                    let mut active = vec![false; ranks * e_slots];
                    for t in &plan.tiles {
                        active[t.dst as usize * e_slots + t.dslot as usize] = true;
                    }
                    for dst in 0..ranks {
                        for sl in 0..e_slots {
                            if !active[dst * e_slots + sl] {
                                continue;
                            }
                            let mut slab = expert_in[dst][rank][sl].lock().unwrap();
                            slab.fill(0.0);
                            for t in plan
                                .tiles
                                .iter()
                                .filter(|t| t.dst as usize == dst && t.dslot as usize == sl)
                            {
                                for (row, &tok) in t.tokens.iter().enumerate() {
                                    let slot = t.tile as usize * m.bm + row;
                                    slab[slot * h..(slot + 1) * h].copy_from_slice(
                                        &a[tok as usize * h..(tok as usize + 1) * h],
                                    );
                                }
                                valid_rows.fetch_add(t.rows as usize, Ordering::Relaxed);
                            }
                            sent_rows.fetch_add(capacity, Ordering::Relaxed);
                        }
                    }
                    launches.fetch_add(ranks, Ordering::Relaxed); // NCCL send/recv chunks
                    sync(barrier_nanos);

                    // phase 3: expert FFN — one grouped launch per *bound*
                    // expert slot over the full padded (ranks*capacity, H)
                    // buffer; unbound replica slots hold no expert and run
                    // nothing
                    let mut scratch = vec![0.0f32; m.bm * d];
                    let mut expert_out: Vec<Vec<f32>> = Vec::with_capacity(e_slots);
                    for e_loc in 0..e_slots {
                        let Some(global_e) = placement.expert_on(rank, e_loc) else {
                            expert_out.push(Vec::new());
                            continue;
                        };
                        let mut out = vec![0.0f32; ranks * capacity * h];
                        for src in 0..ranks {
                            let slab = expert_in[rank][src][e_loc].lock().unwrap();
                            for tile in 0..capacity / m.bm {
                                let x = &slab[tile * m.bm * h..(tile + 1) * m.bm * h];
                                let dst = &mut out[(src * capacity + tile * m.bm) * h
                                    ..(src * capacity + (tile + 1) * m.bm) * h];
                                backend.ffn_tile(
                                    x,
                                    &params.experts[global_e],
                                    global_e,
                                    dst,
                                    &mut scratch,
                                )?;
                            }
                        }
                        expert_out.push(out);
                        launches.fetch_add(1, Ordering::Relaxed);
                    }
                    sync(barrier_nanos);

                    // phase 4: padded combine AllToAll back to sources
                    for e_loc in 0..e_slots {
                        if expert_out[e_loc].is_empty() {
                            continue; // unbound replica slot
                        }
                        for src in 0..ranks {
                            let mut slab = combine_back[src][rank][e_loc].lock().unwrap();
                            slab.copy_from_slice(
                                &expert_out[e_loc][src * capacity * h..(src + 1) * capacity * h],
                            );
                            sent_rows.fetch_add(capacity, Ordering::Relaxed);
                        }
                    }
                    launches.fetch_add(ranks, Ordering::Relaxed);
                    sync(barrier_nanos);

                    // phase 5: combine/scale (one launch) — keyed by the
                    // (serving rank, serving slot) each tile dispatched to
                    let mut out = vec![0.0f32; s_rank * h];
                    for t in &plan.tiles {
                        let slab =
                            combine_back[rank][t.dst as usize][t.dslot as usize].lock().unwrap();
                        for (row, (&tok, &w)) in t.tokens.iter().zip(&t.weights).enumerate() {
                            let slot = t.tile as usize * m.bm + row;
                            let src = &slab[slot * h..(slot + 1) * h];
                            let dst = &mut out[tok as usize * h..(tok as usize + 1) * h];
                            for (o, &v) in dst.iter_mut().zip(src) {
                                *o += w * v;
                            }
                        }
                    }
                    launches.fetch_add(1, Ordering::Relaxed);
                    Ok(out)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|hd| hd.join().expect("baseline rank panicked"))
            .collect::<Result<Vec<_>>>()
    })
    .context("baseline forward")?;

    Ok(BaselineResult {
        outputs,
        metrics: BaselineMetrics {
            wall_secs: t0.elapsed().as_secs_f64(),
            launches: launches.load(Ordering::Relaxed),
            sent_rows: sent_rows.load(Ordering::Relaxed),
            valid_rows: valid_rows.load(Ordering::Relaxed),
            barrier_secs: barrier_nanos.load(Ordering::Relaxed) as f64 * 1e-9
                / cfg.system.ranks as f64,
        },
    })
}
