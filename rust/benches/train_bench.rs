//! Training-step bench, measured on the live engine: forward-only pass
//! time vs a full forward+backward training step (Dgrad/Wgrad tile tasks
//! through the same work-stealing pool), and the reverse-wire gradient
//! bytes per wire format.
//!
//! Emits `BENCH_pr9_training.json` (section `training`) for the CI
//! artifact upload. With `PERF_SMOKE=1` the run FAILS unless the 16-bit
//! wire measures < 0.6x the f32 wire's *reverse* (gradient) bytes — the
//! live CI check that gradient traffic respects the wire-precision knob;
//! the exact-2x assertion lives in `rust/tests/train.rs`.
//!
//!     PRESET=tiny PASSES=5 cargo bench --bench train_bench

use std::sync::Arc;
use std::time::Instant;

use flashdmoe::config::{Config, WirePrecision};
use flashdmoe::coordinator::{MoeEngine, TaskGraphMode};
use flashdmoe::expert::{generate_tokens, ModelParams};
use flashdmoe::runtime::{ComputeBackend, NativeBackend};
use flashdmoe::util::json::{self, Json};
use flashdmoe::util::prng::Rng;
use flashdmoe::util::stats::{fmt_bytes, fmt_time, percentile, Table};

struct Arm {
    wire: WirePrecision,
    fwd_p50: f64,
    step_p50: f64,
    forward_bytes: u64,
    reverse_bytes: u64,
}

fn run_arm(preset: &str, wire: WirePrecision, passes: usize) -> anyhow::Result<Arm> {
    let mut cfg = Config::preset(preset)?;
    cfg.set("train", "on")?;
    cfg.set("routing_policy", "dropless")?; // identical routing across arms
    cfg.set("wire_precision", wire.name())?;
    cfg.validate()?;
    let params = Arc::new(ModelParams::generate(&cfg, 42));
    let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(&cfg));
    let engine = MoeEngine::start(cfg.clone(), params, backend, TaskGraphMode::Fused)?;
    let inputs: Vec<Vec<f32>> =
        (0..cfg.system.ranks).map(|r| generate_tokens(&cfg, 42, r)).collect();

    // warmup + a dy shaped like the outputs
    let warm = engine.submit(&inputs)?.wait()?;
    let mut rng = Rng::new(7);
    let dy: Vec<Vec<f32>> = warm.outputs.iter().map(|o| rng.normal_vec(o.len(), 1.0)).collect();
    engine.backward(warm.metrics.epoch, &dy)?;

    let mut fwd_times = Vec::with_capacity(passes);
    let mut step_times = Vec::with_capacity(passes);
    let mut forward_bytes = 0u64;
    let mut reverse_bytes = 0u64;
    for _ in 0..passes {
        let t0 = Instant::now();
        let fwd = engine.submit(&inputs)?.wait()?;
        fwd_times.push(t0.elapsed().as_secs_f64());

        let t1 = Instant::now();
        let fwd2 = engine.submit(&inputs)?.wait()?;
        let bwd = engine.backward(fwd2.metrics.epoch, &dy)?;
        step_times.push(t1.elapsed().as_secs_f64());
        forward_bytes = fwd.metrics.forward_bytes();
        reverse_bytes = bwd.metrics.reverse_bytes();
    }
    fwd_times.sort_by(f64::total_cmp);
    step_times.sort_by(f64::total_cmp);
    Ok(Arm {
        wire,
        fwd_p50: percentile(&fwd_times, 0.50),
        step_p50: percentile(&step_times, 0.50),
        forward_bytes,
        reverse_bytes,
    })
}

fn main() {
    let preset = std::env::var("PRESET").unwrap_or_else(|_| "tiny".to_string());
    let passes = std::env::var("PASSES").ok().and_then(|v| v.parse().ok()).unwrap_or(5);

    let arms: Vec<Arm> = [WirePrecision::F32, WirePrecision::Bf16]
        .iter()
        .map(|&w| run_arm(&preset, w, passes).unwrap())
        .collect();

    let mut table =
        Table::new(&["wire", "fwd p50", "fwd+bwd p50", "bwd overhead", "fwd bytes", "rev bytes"]);
    for a in &arms {
        table.row(&[
            a.wire.name().to_string(),
            fmt_time(a.fwd_p50),
            fmt_time(a.step_p50),
            format!("{:.2}x", a.step_p50 / a.fwd_p50),
            fmt_bytes(a.forward_bytes as f64),
            fmt_bytes(a.reverse_bytes as f64),
        ]);
    }
    println!("training step ({preset}, {passes} passes/arm)\n{}", table.render());

    let rows = Json::Arr(
        arms.iter()
            .map(|a| {
                json::obj(vec![
                    ("wire", json::s(a.wire.name())),
                    ("fwd_p50_s", json::num(a.fwd_p50)),
                    ("step_p50_s", json::num(a.step_p50)),
                    ("bwd_overhead", json::num(a.step_p50 / a.fwd_p50)),
                    ("forward_bytes", json::num(a.forward_bytes as f64)),
                    ("reverse_bytes", json::num(a.reverse_bytes as f64)),
                ])
            })
            .collect(),
    );
    flashdmoe::harness::update_bench_json("BENCH_pr9_training.json", "training", rows).unwrap();
    println!("wrote BENCH_pr9_training.json (section training)");

    let perf_smoke = std::env::var("PERF_SMOKE").map(|v| v == "1").unwrap_or(false);
    if perf_smoke {
        let f32_rev = arms[0].reverse_bytes as f64;
        let mut failed = f32_rev <= 0.0;
        if failed {
            eprintln!("PERF_SMOKE FAIL: f32 arm measured zero reverse bytes");
        }
        for a in arms.iter().filter(|a| a.wire.is_reduced()) {
            let ratio = a.reverse_bytes as f64 / f32_rev;
            if ratio >= 0.6 {
                eprintln!(
                    "PERF_SMOKE FAIL: {} wire moved {:.2}x the fp32 reverse bytes (must be < 0.6x)",
                    a.wire.name(),
                    ratio
                );
                failed = true;
            } else {
                println!(
                    "PERF_SMOKE ok: {} reverse bytes {:.2}x fp32 — gradient traffic \
                     respects the wire-precision knob",
                    a.wire.name(),
                    ratio
                );
            }
        }
        if failed {
            std::process::exit(1);
        }
    }
}
