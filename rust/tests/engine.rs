//! Persistent-engine lifecycle tests: launch-once accounting, pipelined
//! epoch-tagged submission, bitwise pass determinism, shim equivalence,
//! and clean shutdown (no leaked resident threads across repeated
//! construct/drop cycles).

use std::sync::Arc;

use flashdmoe::config::{Config, RoutingPolicy, WirePrecision};
use flashdmoe::coordinator::{baseline, DistributedMoE, MoeEngine, PassInput, TaskGraphMode};
use flashdmoe::expert::{generate_tokens, ModelParams};
use flashdmoe::harness::multinode_config;
use flashdmoe::runtime::{ComputeBackend, NativeBackend};
use flashdmoe::util::check::dense_reference_moe;
use flashdmoe::util::prng::Rng;
use flashdmoe::util::stats::max_abs_diff;

fn setup(preset: &str, seed: u64) -> (Config, Arc<ModelParams>, Arc<dyn ComputeBackend>, Vec<Vec<f32>>) {
    let cfg = Config::preset(preset).unwrap();
    let params = Arc::new(ModelParams::generate(&cfg, seed));
    let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(&cfg));
    let inputs: Vec<Vec<f32>> =
        (0..cfg.system.ranks).map(|r| generate_tokens(&cfg, seed, r)).collect();
    (cfg, params, backend, inputs)
}

fn start(cfg: &Config, params: &Arc<ModelParams>, backend: &Arc<dyn ComputeBackend>, mode: TaskGraphMode) -> MoeEngine {
    MoeEngine::start(cfg.clone(), params.clone(), backend.clone(), mode).unwrap()
}

#[test]
fn steady_state_passes_spawn_zero_threads_and_one_launch() {
    let (cfg, params, backend, inputs) = setup("tiny", 42);
    let engine = start(&cfg, &params, &backend, TaskGraphMode::Fused);
    // the full resident census exists before any pass runs:
    // one subscriber + `processors` workers per rank
    let resident = (cfg.system.ranks * (1 + cfg.system.processors)) as u64;
    assert_eq!(engine.metrics().threads_spawned, resident);
    let after_one = {
        engine.submit(&inputs).unwrap().wait().unwrap();
        engine.metrics()
    };
    for _ in 0..4 {
        engine.submit(&inputs).unwrap().wait().unwrap();
    }
    let after_five = engine.metrics();
    assert_eq!(after_one.threads_spawned, resident, "pass 1 spawned threads");
    assert_eq!(after_five.threads_spawned, resident, "steady state spawned threads");
    assert_eq!(after_five.launches, 1, "launch-equivalent count over the lifetime");
    assert_eq!(after_five.passes, 5);
    assert!(after_five.launches_per_pass() < 1.0);
    engine.shutdown();
}

#[test]
fn submit_wait_matches_forward_shim_and_independent_witness_bitwise() {
    // acceptance: back-to-back submit/wait passes must reproduce the
    // one-call DistributedMoE path on the tiny preset, bit for bit.
    // Since the shim now routes through the same engine, the real
    // referee is the bulk-synchronous baseline: an independent schedule
    // over the same substrate whose combine reduction also runs in
    // dispatch-plan order with the same `w*v` → `+=` f32 ops per token,
    // so agreement must be exact, not within-tolerance.
    let (cfg, params, backend, inputs) = setup("tiny", 7);
    let witness = baseline::forward_sequential(&cfg, &params, &backend, &inputs).unwrap();
    let moe = DistributedMoE::new(cfg.clone(), params.clone(), backend.clone(), TaskGraphMode::Fused)
        .unwrap();
    let shim = moe.forward(&inputs).unwrap();
    let engine = start(&cfg, &params, &backend, TaskGraphMode::Fused);
    for pass in 0..3 {
        let got = engine.submit(&inputs).unwrap().wait().unwrap();
        for (r, (g, w)) in got.outputs.iter().zip(&witness.outputs).enumerate() {
            assert_eq!(g, w, "pass {pass}, rank {r}: engine diverged from bulk-sync witness");
        }
        for (r, (g, w)) in got.outputs.iter().zip(&shim.outputs).enumerate() {
            assert_eq!(g, w, "pass {pass}, rank {r}: engine diverged from forward() shim");
        }
    }
}

#[test]
fn passes_are_bitwise_deterministic_across_engines_and_modes() {
    // the deterministic combine fold makes outputs independent of
    // scheduling: same inputs => identical bits, engine to engine,
    // whatever the processor count
    let (cfg, params, backend, inputs) = setup("tiny", 21);
    let mut cfg1 = cfg.clone();
    cfg1.set("processors", "1").unwrap();
    let mut cfg8 = cfg.clone();
    cfg8.set("processors", "8").unwrap();
    let a = start(&cfg1, &params, &backend, TaskGraphMode::Fused)
        .forward(&inputs)
        .unwrap();
    let b = start(&cfg8, &params, &backend, TaskGraphMode::Fused)
        .forward(&inputs)
        .unwrap();
    for (x, y) in a.outputs.iter().zip(&b.outputs) {
        assert_eq!(x, y, "processor count changed output bits");
    }
    // and within one engine, repeated passes are bitwise stable
    let engine = start(&cfg8, &params, &backend, TaskGraphMode::Fused);
    let first = engine.submit(&inputs).unwrap().wait().unwrap();
    for _ in 0..3 {
        let again = engine.submit(&inputs).unwrap().wait().unwrap();
        for (x, y) in first.outputs.iter().zip(&again.outputs) {
            assert_eq!(x, y, "repeated pass changed output bits");
        }
    }
}

#[test]
fn golden_determinism_across_restarts_modes_and_policies() {
    // same seed + config => bitwise-identical ForwardResult outputs across
    // engine restarts, in both routing policies and both task-graph modes.
    // Fused and Split also agree bitwise with each other: the native
    // kernels accumulate every output element in the same ascending
    // reduction order whether the weights are column-sliced or not, and
    // the combine fold is dispatch-plan-ordered in both modes.
    let (cfg0, params, backend, inputs) = setup("tiny", 47);
    for policy in [RoutingPolicy::Capacity(1.0), RoutingPolicy::Dropless] {
        let mut cfg = cfg0.clone();
        cfg.model.policy = policy;
        cfg.validate().unwrap();
        let mut golden: Option<Vec<Vec<f32>>> = None;
        for mode in [TaskGraphMode::Fused, TaskGraphMode::Split] {
            let a = start(&cfg, &params, &backend, mode).forward(&inputs).unwrap();
            let b = start(&cfg, &params, &backend, mode).forward(&inputs).unwrap();
            for (r, (x, y)) in a.outputs.iter().zip(&b.outputs).enumerate() {
                assert_eq!(x, y, "{policy:?}/{mode:?}: restart changed rank {r} output bits");
            }
            if let Some(g) = &golden {
                for (r, (x, y)) in g.iter().zip(&a.outputs).enumerate() {
                    assert_eq!(
                        x, y,
                        "{policy:?}/{mode:?}: rank {r} diverged from the fused golden"
                    );
                }
            } else {
                golden = Some(a.outputs);
            }
        }
    }
}

#[test]
fn packed_backend_packs_once_per_expert_for_the_engine_lifetime() {
    // acceptance: per-pass weight-packing work is zero after
    // `MoeEngine::start` — the pack count equals the expert count right
    // after start and never grows, no matter how many passes run
    let cfg = Config::preset("tiny").unwrap();
    assert!(cfg.system.packed, "packed is the default hot path");
    let params = Arc::new(ModelParams::generate(&cfg, 61));
    let native = Arc::new(NativeBackend::from_config(&cfg));
    let backend: Arc<dyn ComputeBackend> = native.clone();
    let inputs: Vec<Vec<f32>> =
        (0..cfg.system.ranks).map(|r| generate_tokens(&cfg, 61, r)).collect();
    assert_eq!(native.pack_count(), 0, "no packing before start");
    for mode in [TaskGraphMode::Fused, TaskGraphMode::Split] {
        let engine = MoeEngine::start(cfg.clone(), params.clone(), backend.clone(), mode).unwrap();
        assert_eq!(
            native.pack_count(),
            cfg.model.e as u64,
            "pack count == expert count after start ({mode:?})"
        );
        for _ in 0..3 {
            engine.submit(&inputs).unwrap().wait().unwrap();
        }
        assert_eq!(
            native.pack_count(),
            cfg.model.e as u64,
            "steady-state passes must never re-pack ({mode:?})"
        );
        engine.shutdown();
    }
}

#[test]
fn packed_engine_is_bitwise_deterministic_across_restarts_and_policies() {
    // acceptance: the packed backend preserves the PR 1 combine-order
    // guarantee — same seed + config => bitwise-identical outputs across
    // engine restarts, under both routing policies and any processor
    // count; and the packed kernels reproduce the unpacked outputs on
    // these shapes (identical f32 accumulation order).
    let (cfg0, params, _, inputs) = setup("tiny", 67);
    for policy in [RoutingPolicy::Capacity(1.0), RoutingPolicy::Dropless] {
        let mut cfg = cfg0.clone();
        cfg.model.policy = policy;
        cfg.set("packed", "true").unwrap();
        cfg.validate().unwrap();
        let run = |cfg: &Config, processors: usize| {
            let mut cfg = cfg.clone();
            cfg.set("processors", &processors.to_string()).unwrap();
            let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(&cfg));
            MoeEngine::start(cfg, params.clone(), backend, TaskGraphMode::Fused)
                .unwrap()
                .forward(&inputs)
                .unwrap()
        };
        let a = run(&cfg, 4);
        let b = run(&cfg, 4); // restart, fresh backend + fresh packing
        let c = run(&cfg, 1); // scheduling degenerate case
        for (r, (x, y)) in a.outputs.iter().zip(&b.outputs).enumerate() {
            assert_eq!(x, y, "{policy:?}: restart changed rank {r} output bits");
        }
        for (r, (x, y)) in a.outputs.iter().zip(&c.outputs).enumerate() {
            assert_eq!(x, y, "{policy:?}: processor count changed rank {r} output bits");
        }
        // packed vs unpacked: tiny's K fits one KC chunk, so even the
        // accumulation grouping matches and the arms agree exactly
        let mut un = cfg.clone();
        un.set("packed", "false").unwrap();
        let d = run(&un, 4);
        for (r, (x, y)) in a.outputs.iter().zip(&d.outputs).enumerate() {
            let diff = max_abs_diff(x, y);
            assert!(diff < 1e-5, "{policy:?}: packed vs unpacked rank {r} diff {diff}");
        }
    }
}

#[test]
fn out_of_order_wait_with_dropless_max_skew_reuses_variable_tile_slots() {
    // Engine configured Dropless; pass 1 routes normally, pass 2 is
    // maximally skewed (every token of every rank -> global expert 0), so
    // expert 0's variable tile-slot region goes from lightly to fully
    // occupied across back-to-back epochs. Waiting out of order (pass 2
    // first) exercises slot reuse under pipelined collection.
    let mut cfg = Config::preset("tiny").unwrap();
    cfg.set("routing_policy", "dropless").unwrap();
    cfg.set("k", "1").unwrap();
    cfg.validate().unwrap();
    let (h, e) = (cfg.model.h, cfg.model.e);
    // gate weights whose column 0 is all ones (rest zero): all-positive
    // inputs make expert 0 the argmax for every token
    let mut params = ModelParams::generate(&cfg, 53);
    let mut wg = vec![0.0f32; h * e];
    for row in wg.chunks_mut(e) {
        row[0] = 1.0;
    }
    params.wg = wg;
    let params = Arc::new(params);
    let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(&cfg));
    let normal: Vec<Vec<f32>> =
        (0..cfg.system.ranks).map(|r| generate_tokens(&cfg, 53, r)).collect();
    let skewed: Vec<Vec<f32>> =
        (0..cfg.system.ranks).map(|_| vec![1.0f32; cfg.system.s_rank * h]).collect();

    let engine = start(&cfg, &params, &backend, TaskGraphMode::Fused);
    let h1 = engine.submit(&normal).unwrap();
    let h2 = engine.submit(&skewed).unwrap();
    // collect out of order: the maximally-skewed pass (N+1) first
    let r2 = h2.wait().unwrap();
    let r1 = h1.wait().unwrap();
    assert_eq!((r1.metrics.epoch, r2.metrics.epoch), (1, 2));

    // the skewed pass keeps everything: zero drops, and each source ships
    // its whole batch to expert 0 as s_rank/bM full tiles
    assert_eq!(r2.metrics.total_dropped(), 0, "dropless must not drop under max skew");
    let tiles: usize = r2.metrics.ranks.iter().map(|r| r.tiles_sent).sum();
    assert_eq!(
        tiles,
        cfg.system.ranks * (cfg.system.s_rank / cfg.model.bm),
        "each source ships its whole batch to one expert"
    );
    // both passes match fresh-engine references bitwise (epoch isolation)
    for (inputs, got) in [(&normal, &r1), (&skewed, &r2)] {
        let want = start(&cfg, &params, &backend, TaskGraphMode::Fused).forward(inputs).unwrap();
        for (r, (g, w)) in got.outputs.iter().zip(&want.outputs).enumerate() {
            assert_eq!(g, w, "rank {r}: pipelined pass diverged from fresh engine");
        }
    }
    // and the skewed pass equals the dense per-token reference (the
    // Capacity policy would have dropped most of these tokens)
    for (r, out) in r2.outputs.iter().enumerate() {
        let want = dense_reference_moe(&cfg, &params, &skewed[r]);
        let diff = max_abs_diff(out, &want);
        assert!(diff < 1e-5, "rank {r}: skewed dropless pass vs dense reference diff {diff}");
    }
}

#[test]
fn pipelined_submission_overlaps_and_preserves_outputs() {
    let (cfg, params, backend, _) = setup("tiny", 11);
    let engine = start(&cfg, &params, &backend, TaskGraphMode::Fused);
    // three distinct input sets, each with a known fresh-engine reference
    let batches: Vec<Vec<Vec<f32>>> = (0..3)
        .map(|seed| {
            (0..cfg.system.ranks).map(|r| generate_tokens(&cfg, 100 + seed, r)).collect()
        })
        .collect();
    let want: Vec<_> = batches
        .iter()
        .map(|b| start(&cfg, &params, &backend, TaskGraphMode::Fused).forward(b).unwrap())
        .collect();

    // submit all three before collecting any: the third submit drains
    // pass 1 into the parking buffer (slots are double-buffered)
    let h1 = engine.submit(&batches[0]).unwrap();
    let h2 = engine.submit(&batches[1]).unwrap();
    let h3 = engine.submit(&batches[2]).unwrap();
    assert_eq!((h1.epoch(), h2.epoch(), h3.epoch()), (1, 2, 3));
    let r1 = h1.wait().unwrap();
    let r2 = h2.wait().unwrap();
    let r3 = h3.wait().unwrap();
    for (got, want) in [&r1, &r2, &r3].into_iter().zip(&want) {
        for (g, w) in got.outputs.iter().zip(&want.outputs) {
            assert_eq!(g, w, "pipelined pass diverged from fresh-engine reference");
        }
    }
    assert_eq!(r1.metrics.epoch, 1);
    assert_eq!(r3.metrics.epoch, 3);
    assert_eq!(engine.metrics().passes, 3);
}

#[test]
fn waits_may_complete_out_of_order() {
    let (cfg, params, backend, inputs) = setup("tiny", 13);
    let engine = start(&cfg, &params, &backend, TaskGraphMode::Fused);
    let h1 = engine.submit(&inputs).unwrap();
    let h2 = engine.submit(&inputs).unwrap();
    let r2 = h2.wait().unwrap();
    let r1 = h1.wait().unwrap();
    assert_eq!(r1.metrics.epoch, 1);
    assert_eq!(r2.metrics.epoch, 2);
    for (x, y) in r1.outputs.iter().zip(&r2.outputs) {
        assert_eq!(x, y);
    }
}

#[test]
fn dropped_handles_do_not_wedge_later_submits() {
    let (cfg, params, backend, inputs) = setup("tiny", 17);
    let engine = start(&cfg, &params, &backend, TaskGraphMode::Fused);
    for _ in 0..4 {
        // submit and deliberately discard the handle: the drop path must
        // free the pass slot or later submits would stall forever
        let _ = engine.submit(&inputs).unwrap();
    }
    let last = engine.submit(&inputs).unwrap().wait().unwrap();
    assert_eq!(last.metrics.epoch, 5);
}

#[test]
fn construct_and_drop_engines_in_a_loop_joins_cleanly() {
    // drop/shutdown satellite: resident actors must be joined on drop —
    // a leak would either hang this test (join deadlock) or blow up the
    // thread count across 8 lifecycles x 2 modes
    let (cfg, params, backend, inputs) = setup("tiny", 23);
    for mode in [TaskGraphMode::Fused, TaskGraphMode::Split] {
        for i in 0..8 {
            let engine = start(&cfg, &params, &backend, mode);
            if i % 2 == 0 {
                engine.submit(&inputs).unwrap().wait().unwrap();
            }
            // half the engines are dropped idle, half mid-lifecycle;
            // explicit shutdown and implicit drop both must join
            if i % 3 == 0 {
                engine.shutdown();
            } // else: Drop
        }
    }
}

#[test]
fn handles_survive_engine_shutdown_for_submitted_passes() {
    let (cfg, params, backend, inputs) = setup("tiny", 29);
    let engine = start(&cfg, &params, &backend, TaskGraphMode::Fused);
    let reference = engine.submit(&inputs).unwrap().wait().unwrap();
    let handle = engine.submit(&inputs).unwrap();
    engine.shutdown(); // drains the submitted pass before joining
    let late = handle.wait().unwrap();
    for (x, y) in reference.outputs.iter().zip(&late.outputs) {
        assert_eq!(x, y);
    }
}

#[test]
fn split_mode_engine_matches_fused_engine() {
    let (cfg, params, backend, inputs) = setup("tiny", 31);
    let fused = start(&cfg, &params, &backend, TaskGraphMode::Fused).forward(&inputs).unwrap();
    let engine = start(&cfg, &params, &backend, TaskGraphMode::Split);
    for _ in 0..2 {
        let split = engine.submit(&inputs).unwrap().wait().unwrap();
        for (f, s) in fused.outputs.iter().zip(&split.outputs) {
            let max = f
                .iter()
                .zip(s)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(max < 1e-3, "split engine diverged from fused: {max}");
        }
        let gemm: u32 = split.metrics.ranks.iter().map(|r| r.gemm_tasks).sum();
        assert!(gemm > 0, "split mode must run Gemm0/Gemm1 tasks");
    }
}

#[test]
fn bad_submissions_are_rejected_without_poisoning_the_engine() {
    let (cfg, params, backend, inputs) = setup("tiny", 37);
    let engine = start(&cfg, &params, &backend, TaskGraphMode::Fused);
    // wrong arity
    let short = inputs[..cfg.system.ranks - 1].to_vec();
    assert!(engine.submit(&short).is_err());
    // wrong per-rank length
    let bad_len: Vec<Vec<f32>> = (0..cfg.system.ranks).map(|_| vec![0.0f32; 3]).collect();
    assert!(engine.submit(&bad_len).is_err());
    // the engine still serves good passes afterwards
    let ok = engine.submit(&inputs).unwrap().wait().unwrap();
    assert_eq!(ok.outputs.len(), cfg.system.ranks);
}

#[test]
fn legacy_fixed_shape_passes_report_full_batch_fill() {
    // satellite: the fixed-shape `submit` path is exactly full by
    // construction — batch_fill == 1.0, rows accounting to match
    let (cfg, params, backend, inputs) = setup("tiny", 43);
    let engine = start(&cfg, &params, &backend, TaskGraphMode::Fused);
    for _ in 0..3 {
        let res = engine.submit(&inputs).unwrap().wait().unwrap();
        assert_eq!(res.metrics.batch_fill(), 1.0, "legacy path must fill exactly");
        assert_eq!(res.metrics.rows_submitted, cfg.system.ranks * cfg.system.s_rank);
        assert_eq!(res.metrics.rows_capacity, cfg.system.max_batch_tokens());
        for (r, rm) in res.metrics.ranks.iter().enumerate() {
            assert_eq!(rm.rows_in, cfg.system.s_rank, "rank {r} rows_in");
        }
    }
}

/// Property-test a variable-shape pass (fuzzed per-rank row counts,
/// zero included) for one (policy, wire precision) pair: outputs have
/// the submitted shapes, metrics carry the actual rows, transfer bytes
/// scale with routed rows at the **configured wire element width** (no
/// padded-row traffic, no hardcoded 4-byte floats), and — whenever the
/// gate dropped nothing — outputs equal the dense per-token reference
/// within the format's documented tolerance.
fn check_variable_shape_pass(policy: RoutingPolicy, wire: WirePrecision, seed: u64) {
    let mut cfg = Config::preset("tiny").unwrap();
    cfg.model.policy = policy;
    cfg.set("wire_precision", wire.name()).unwrap();
    cfg.validate().unwrap();
    let params = Arc::new(ModelParams::generate(&cfg, seed));
    let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(&cfg));
    let engine =
        MoeEngine::start(cfg.clone(), params.clone(), backend, TaskGraphMode::Fused).unwrap();
    let (h, k) = (cfg.model.h, cfg.model.k);
    let mut rng = Rng::new(seed);
    for case in 0..6 {
        // fuzz s_r in 0..=s_rank per rank; keep at least one nonempty rank
        let rows: Vec<usize> = (0..cfg.system.ranks)
            .map(|_| rng.below(cfg.system.s_rank + 1))
            .collect();
        let rows = if rows.iter().all(|&r| r == 0) { vec![1; cfg.system.ranks] } else { rows };
        let per_rank: Vec<Vec<f32>> =
            rows.iter().map(|&r| rng.normal_vec(r * h, 1.0)).collect();
        let res = engine.submit_pass(PassInput::new(per_rank.clone())).unwrap().wait().unwrap();

        // shapes and fill accounting follow the submitted rows
        let total: usize = rows.iter().sum();
        assert_eq!(res.metrics.rows_submitted, total, "case {case}: rows_submitted");
        assert!(res.metrics.batch_fill() <= 1.0);
        assert_eq!(
            res.metrics.batch_fill(),
            total as f64 / cfg.system.max_batch_tokens() as f64
        );
        for (r, out) in res.outputs.iter().enumerate() {
            assert_eq!(out.len(), rows[r] * h, "case {case}: rank {r} output shape");
        }

        // payload metrics reflect actual routed rows: every dispatched
        // row comes back exactly once as a combine row, so total heap
        // traffic is 2 × routed × H × wire.bytes() — nothing padded
        // travels, and the byte count follows the precision knob (a
        // 16-bit wire measures exactly half the fp32 bytes)
        let routed: usize = res.metrics.ranks.iter().map(|m| m.sent_rows).sum();
        assert!(routed <= total * k, "case {case}: routed beyond top-k");
        assert_eq!(
            res.metrics.total_bytes(),
            (2 * routed * h * wire.bytes()) as u64,
            "case {case}: wire bytes must derive from the {wire:?} element width"
        );
        assert_eq!(res.metrics.wire, wire, "case {case}: pass metrics carry the wire format");
        assert_eq!(
            res.metrics.fp32_equiv_bytes(),
            (2 * routed * h * 4) as u64,
            "case {case}: fp32-equivalent baseline"
        );
        if policy.is_dropless() {
            assert_eq!(res.metrics.total_dropped(), 0, "case {case}: dropless dropped");
            assert_eq!(routed, total * k, "case {case}: dropless keeps all pairs");
        }

        // conformance: with zero drops the pass equals the dense
        // per-token reference within the wire format's documented
        // tolerance (1e-5 on the exact f32 wire; loosened for 16-bit)
        if res.metrics.total_dropped() == 0 {
            for (r, out) in res.outputs.iter().enumerate() {
                if rows[r] == 0 {
                    continue;
                }
                let want = dense_reference_moe(&cfg, &params, &per_rank[r]);
                let diff = max_abs_diff(out, &want);
                assert!(
                    diff < wire.conformance_tol(),
                    "case {case}: rank {r} ({} rows, {wire:?}) diff {diff} vs dense reference",
                    rows[r]
                );
            }
        }
    }
}

#[test]
fn variable_shape_passes_capacity_policy() {
    check_variable_shape_pass(RoutingPolicy::Capacity(1.0), WirePrecision::F32, 0x51AE);
}

#[test]
fn variable_shape_passes_dropless_policy() {
    check_variable_shape_pass(RoutingPolicy::Dropless, WirePrecision::F32, 0x51AF);
}

#[test]
fn variable_shape_passes_bf16_wire_halve_measured_bytes() {
    // the byte assert inside is 2·routed·H·2 — the measured halving
    check_variable_shape_pass(RoutingPolicy::Dropless, WirePrecision::Bf16, 0x51B0);
}

#[test]
fn variable_shape_passes_f16_wire_halve_measured_bytes() {
    check_variable_shape_pass(RoutingPolicy::Dropless, WirePrecision::F16, 0x51B1);
}

#[test]
fn variable_shape_split_mode_matches_dense_reference() {
    // the Split task graph (Gemm0→Gemm1 chains) must also carry dynamic
    // row counts end to end
    let mut cfg = Config::preset("tiny").unwrap();
    cfg.model.policy = RoutingPolicy::Dropless;
    cfg.validate().unwrap();
    let params = Arc::new(ModelParams::generate(&cfg, 59));
    let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(&cfg));
    let engine =
        MoeEngine::start(cfg.clone(), params.clone(), backend, TaskGraphMode::Split).unwrap();
    let h = cfg.model.h;
    let mut rng = Rng::new(60);
    let rows = [37usize, 0, 101, 5][..cfg.system.ranks.min(4)].to_vec();
    let per_rank: Vec<Vec<f32>> = rows.iter().map(|&r| rng.normal_vec(r * h, 1.0)).collect();
    let res = engine.submit_pass(PassInput::new(per_rank.clone())).unwrap().wait().unwrap();
    for (r, out) in res.outputs.iter().enumerate() {
        assert_eq!(out.len(), rows[r] * h);
        if rows[r] > 0 {
            let want = dense_reference_moe(&cfg, &params, &per_rank[r]);
            let diff = max_abs_diff(out, &want);
            assert!(diff < 1e-3, "rank {r}: split-mode variable pass diff {diff}");
        }
    }
}

/// Bit-pattern equality for f32 buffers: unlike `assert_eq!` on `f32`
/// values, this catches −0.0 vs 0.0 and NaN-payload changes — the exact
/// edge cases the F32 wire documents as preserved.
fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: element {i} bit pattern");
    }
}

#[test]
fn f32_wire_passes_stay_bitwise_identical_across_restarts_and_policies() {
    // regression guard for the wire subsystem: at `WirePrecision::F32`
    // the encode/decode pair is a byte copy, so outputs must be bitwise
    // identical to a config that never touched the knob — across engine
    // restarts and under both routing policies. The pre-existing
    // determinism guarantee must not erode.
    let (cfg0, params, backend, inputs) = setup("tiny", 83);
    for policy in [RoutingPolicy::Capacity(1.0), RoutingPolicy::Dropless] {
        let mut cfg = cfg0.clone();
        cfg.model.policy = policy;
        cfg.validate().unwrap();
        // baseline: the knob left at its default
        let golden = start(&cfg, &params, &backend, TaskGraphMode::Fused)
            .forward(&inputs)
            .unwrap();
        // explicit f32 wire, fresh engine per run (restart × 2)
        let mut cfg_wire = cfg.clone();
        cfg_wire.set("wire_precision", "f32").unwrap();
        assert_eq!(cfg_wire.system.wire, WirePrecision::F32);
        for restart in 0..2 {
            let got = start(&cfg_wire, &params, &backend, TaskGraphMode::Fused)
                .forward(&inputs)
                .unwrap();
            assert_eq!(got.metrics.wire, WirePrecision::F32);
            for (r, (g, w)) in got.outputs.iter().zip(&golden.outputs).enumerate() {
                assert_bits_eq(
                    g,
                    w,
                    &format!("{policy:?} restart {restart}: f32 wire, rank {r}"),
                );
            }
        }
    }
}

#[test]
fn reduced_precision_wire_matches_dense_reference_and_stays_deterministic() {
    // engine-level conformance at the loosened 16-bit tolerance, plus:
    // reduced passes are still bitwise deterministic across restarts
    // (round-to-nearest-even has no schedule dependence), and the
    // quantization genuinely happened (outputs differ from the f32 arm).
    let mut cfg = Config::preset("tiny").unwrap();
    cfg.set("routing_policy", "dropless").unwrap();
    cfg.validate().unwrap();
    let params = Arc::new(ModelParams::generate(&cfg, 89));
    let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(&cfg));
    let inputs: Vec<Vec<f32>> =
        (0..cfg.system.ranks).map(|r| generate_tokens(&cfg, 89, r)).collect();
    let exact = start(&cfg, &params, &backend, TaskGraphMode::Fused).forward(&inputs).unwrap();
    for wire in [WirePrecision::Bf16, WirePrecision::F16] {
        let mut cfg_w = cfg.clone();
        cfg_w.set("wire_precision", wire.name()).unwrap();
        let a = start(&cfg_w, &params, &backend, TaskGraphMode::Fused).forward(&inputs).unwrap();
        let b = start(&cfg_w, &params, &backend, TaskGraphMode::Fused).forward(&inputs).unwrap();
        assert_eq!(a.metrics.wire, wire);
        assert_eq!(a.metrics.total_dropped(), 0);
        let mut any_diff = false;
        for (r, out) in a.outputs.iter().enumerate() {
            // restart-determinism holds at reduced precision too
            assert_bits_eq(out, &b.outputs[r], &format!("{wire:?} restart, rank {r}"));
            // conformance vs the dense f32 oracle, loosened per format
            let want = dense_reference_moe(&cfg_w, &params, &inputs[r]);
            let diff = max_abs_diff(out, &want);
            assert!(
                diff < wire.conformance_tol(),
                "{wire:?}: rank {r} err {diff} exceeds {}",
                wire.conformance_tol()
            );
            any_diff |= out != &exact.outputs[r];
        }
        assert!(any_diff, "{wire:?}: outputs identical to f32 — quantization is a no-op?");
        // 16-bit wire halves the heap and the per-pass measured bytes
        assert_eq!(
            a.metrics.total_bytes() * 2,
            exact.metrics.total_bytes(),
            "{wire:?}: measured wire bytes must halve for identical routing"
        );
    }
}

#[test]
fn concurrent_submitters_interleave_without_wedging() {
    // satellite: the slot-drain wait no longer holds the epoch lock, so
    // concurrent submitters (the service batcher's world) make progress
    // and every pass still returns the right output
    let (cfg, params, backend, inputs) = setup("tiny", 71);
    let reference = start(&cfg, &params, &backend, TaskGraphMode::Fused)
        .forward(&inputs)
        .unwrap();
    let engine = Arc::new(start(&cfg, &params, &backend, TaskGraphMode::Fused));
    let mut threads = Vec::new();
    for t in 0..4 {
        let engine = engine.clone();
        let inputs = inputs.clone();
        let want: Vec<Vec<f32>> = reference.outputs.clone();
        threads.push(std::thread::spawn(move || {
            for pass in 0..5 {
                let got = engine.submit(&inputs).unwrap().wait().unwrap();
                for (r, (g, w)) in got.outputs.iter().zip(&want).enumerate() {
                    assert_eq!(g, w, "thread {t} pass {pass} rank {r} diverged");
                }
            }
        }));
    }
    for th in threads {
        th.join().unwrap();
    }
    let em = engine.metrics();
    assert_eq!(em.passes, 20);
    assert_eq!(em.launches, 1);
}

#[test]
fn epoch_tags_isolate_back_to_back_heterogeneous_passes() {
    // different routing every pass: stale generation flags from pass N
    // must be invisible to pass N+1 (no global heap reset exists anymore)
    let (cfg, params, backend, _) = setup("tiny", 41);
    let engine = start(&cfg, &params, &backend, TaskGraphMode::Fused);
    for seed in [1u64, 2, 3, 4] {
        let inputs: Vec<Vec<f32>> =
            (0..cfg.system.ranks).map(|r| generate_tokens(&cfg, seed, r)).collect();
        let got = engine.submit(&inputs).unwrap().wait().unwrap();
        let want = start(&cfg, &params, &backend, TaskGraphMode::Fused).forward(&inputs).unwrap();
        for (g, w) in got.outputs.iter().zip(&want.outputs) {
            assert_eq!(g, w, "seed {seed}: resident-engine pass leaked state");
        }
    }
}

#[test]
fn hierarchical_dispatch_is_conformant_across_policies_and_wires() {
    // Tentpole conformance on a 4-node topology: two-level coalesced
    // dispatch only changes the transfer path — the plan, the logical
    // write coordinates and the plan-order combine fold are untouched —
    // so hierarchical outputs must equal flat outputs *bit for bit*, per
    // routing policy and wire format; and whenever the gate dropped
    // nothing, both must match the dense per-token oracle at the wire's
    // documented tolerance.
    for policy in [RoutingPolicy::Capacity(1.0), RoutingPolicy::Dropless] {
        for wire in [WirePrecision::F32, WirePrecision::Bf16] {
            let mut cfg = multinode_config(48).unwrap();
            cfg.model.policy = policy;
            cfg.set("wire_precision", wire.name()).unwrap();
            cfg.validate().unwrap();
            assert!(cfg.system.dispatch.is_hierarchical(), "preset default");
            let params = Arc::new(ModelParams::generate(&cfg, 0x6E0D));
            let inputs: Vec<Vec<f32>> =
                (0..cfg.system.ranks).map(|r| generate_tokens(&cfg, 0x6E0D, r)).collect();
            let run = |cfg: &Config| {
                let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(cfg));
                MoeEngine::start(cfg.clone(), params.clone(), backend, TaskGraphMode::Fused)
                    .unwrap()
                    .forward(&inputs)
                    .unwrap()
            };
            let mut flat_cfg = cfg.clone();
            flat_cfg.set("dispatch", "flat").unwrap();
            let flat = run(&flat_cfg);
            let hier = run(&cfg);
            for (r, (f, h)) in flat.outputs.iter().zip(&hier.outputs).enumerate() {
                assert_bits_eq(
                    f,
                    h,
                    &format!("{policy:?}/{wire:?} rank {r}: flat vs hierarchical"),
                );
            }
            if hier.metrics.total_dropped() == 0 {
                for (r, out) in hier.outputs.iter().enumerate() {
                    let want = dense_reference_moe(&cfg, &params, &inputs[r]);
                    let diff = max_abs_diff(out, &want);
                    assert!(
                        diff < wire.conformance_tol(),
                        "{policy:?}/{wire:?} rank {r}: diff {diff} vs dense reference"
                    );
                }
            } else {
                assert!(
                    matches!(policy, RoutingPolicy::Capacity(_)),
                    "dropless must not drop on the multi-node config"
                );
            }
        }
    }
}

#[test]
fn multinode_hierarchical_restarts_stay_bitwise_deterministic() {
    // The restart-determinism guarantee survives the Transport subsystem:
    // same seed + multi-node hierarchical config => bitwise-identical
    // outputs across engine lifetimes, and repeated passes within one
    // resident engine are bitwise stable too (proxy fan-out introduces no
    // schedule dependence — the combine fold stays dispatch-plan-ordered).
    let cfg = multinode_config(64).unwrap();
    assert!(cfg.system.nodes > 1 && cfg.system.dispatch.is_hierarchical());
    let params = Arc::new(ModelParams::generate(&cfg, 0x17A2));
    let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(&cfg));
    let inputs: Vec<Vec<f32>> =
        (0..cfg.system.ranks).map(|r| generate_tokens(&cfg, 0x17A2, r)).collect();
    let a = start(&cfg, &params, &backend, TaskGraphMode::Fused).forward(&inputs).unwrap();
    let b = start(&cfg, &params, &backend, TaskGraphMode::Fused).forward(&inputs).unwrap();
    for (r, (x, y)) in a.outputs.iter().zip(&b.outputs).enumerate() {
        assert_bits_eq(x, y, &format!("multi-node restart, rank {r}"));
    }
    let engine = start(&cfg, &params, &backend, TaskGraphMode::Fused);
    for pass in 0..2 {
        let again = engine.submit(&inputs).unwrap().wait().unwrap();
        for (r, (x, y)) in a.outputs.iter().zip(&again.outputs).enumerate() {
            assert_bits_eq(x, y, &format!("multi-node resident pass {pass}, rank {r}"));
        }
    }
}
