//! Fig 14 — forward latency vs total expert count (T=16K/GPU) at 4 and
//! 8 GPUs: flash stays flat, launch-bound baselines grow superlinearly.
fn main() {
    let (text, _) = flashdmoe::harness::fig14(42).unwrap();
    println!("{text}");
}
