//! Simulated hardware resources: processor (SM) pools and directed links.
//!
//! Both are "next-free-time" resources over virtual seconds — the standard
//! building blocks of an event-driven network/compute simulator.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

/// A pool of identical processor slots (the rank's SMs). Tasks are placed
/// on the earliest-free slot; busy time is accumulated for the
/// SM-utilization metric.
pub struct ProcPool {
    free_at: BinaryHeap<Reverse<u64>>, // virtual nanos per slot
    pub busy_nanos: u64,
    slots: usize,
    /// Task-resident intervals, for the paper-style "SM active" metric
    /// (an SM counts as active whenever any warp is in flight).
    intervals: Vec<(u64, u64)>,
}

/// Virtual seconds <-> nanos (the heap needs Ord; f64 isn't).
pub fn to_nanos(secs: f64) -> u64 {
    (secs * 1e9).round() as u64
}

pub fn to_secs(nanos: u64) -> f64 {
    nanos as f64 * 1e-9
}

impl ProcPool {
    pub fn new(slots: usize) -> Self {
        let mut free_at = BinaryHeap::with_capacity(slots);
        for _ in 0..slots {
            free_at.push(Reverse(0));
        }
        Self { free_at, busy_nanos: 0, slots, intervals: Vec::new() }
    }

    /// Schedule a task that becomes ready at `ready` and runs `dur`
    /// seconds; returns its completion time.
    pub fn run(&mut self, ready: f64, dur: f64) -> f64 {
        self.run_gapped(ready, 0.0, dur)
    }

    /// Schedule a task preceded by a host-side gap (launch/sync) that
    /// occupies the slot but does NOT count as device-active time — the
    /// Fig 5 launch-gap pathology. Returns the completion time.
    pub fn run_gapped(&mut self, ready: f64, gap: f64, dur: f64) -> f64 {
        let Reverse(free) = self.free_at.pop().expect("pool has slots");
        let start = free.max(to_nanos(ready)) + to_nanos(gap);
        let dur_n = to_nanos(dur);
        let done = start + dur_n;
        self.busy_nanos += dur_n;
        self.intervals.push((start, done));
        self.free_at.push(Reverse(done));
        to_secs(done)
    }

    /// Length of the union of task-resident intervals (seconds): the
    /// paper-style "SM active" time — the device counts as active whenever
    /// at least one kernel/task is resident, regardless of slot count.
    pub fn active_union(&self) -> f64 {
        if self.intervals.is_empty() {
            return 0.0;
        }
        let mut iv = self.intervals.clone();
        iv.sort_unstable();
        let mut total = 0u64;
        let (mut lo, mut hi) = iv[0];
        for &(s, e) in &iv[1..] {
            if s > hi {
                total += hi - lo;
                lo = s;
                hi = e;
            } else {
                hi = hi.max(e);
            }
        }
        total += hi - lo;
        to_secs(total)
    }

    /// Time at which every slot is idle.
    pub fn drain_time(&self) -> f64 {
        to_secs(self.free_at.iter().map(|Reverse(t)| *t).max().unwrap_or(0))
    }

    pub fn slots(&self) -> usize {
        self.slots
    }

    /// Busy fraction up to `makespan`.
    pub fn utilization(&self, makespan: f64) -> f64 {
        if makespan <= 0.0 {
            return 0.0;
        }
        (to_secs(self.busy_nanos) / (makespan * self.slots as f64)).min(1.0)
    }
}

/// Shared per-GPU link ports: a transfer occupies both the source's egress
/// port and the destination's ingress port for bytes/bandwidth (all-to-all
/// traffic from one GPU shares its NVLink/NIC budget — per-pair dedicated
/// links would overestimate aggregate fabric bandwidth by P×). Intra-node
/// (NVLink) and inter-node (NIC) ports are separate resources with their
/// own bandwidth/latency; per-destination NIC ingress bytes are tracked
/// for incast accounting (Fig 17).
pub struct LinkSet {
    /// (rank, port) -> next-free virtual nanos; port 0=NVLink, 1=NIC.
    egress: HashMap<(u32, u8), u64>,
    ingress: HashMap<(u32, u8), u64>,
    pub intra_bw: f64,
    pub intra_lat: f64,
    pub inter_bw: f64,
    pub inter_lat: f64,
    ranks_per_node: usize,
    /// Bytes received from *remote* nodes, per destination rank.
    pub nic_ingress: HashMap<u32, f64>,
}

impl LinkSet {
    pub fn new(
        intra_bw: f64,
        intra_lat: f64,
        inter_bw: f64,
        inter_lat: f64,
        ranks_per_node: usize,
    ) -> Self {
        Self {
            egress: HashMap::new(),
            ingress: HashMap::new(),
            intra_bw,
            intra_lat,
            inter_bw,
            inter_lat,
            ranks_per_node,
            nic_ingress: HashMap::new(),
        }
    }

    pub fn same_node(&self, a: u32, b: u32) -> bool {
        (a as usize) / self.ranks_per_node == (b as usize) / self.ranks_per_node
    }

    /// Issue a transfer at `ready`; returns delivery time.
    pub fn transfer(&mut self, src: u32, dst: u32, bytes: f64, ready: f64) -> f64 {
        if src == dst {
            return ready; // loopback DMA is effectively free at this scale
        }
        let (bw, lat, port) = if self.same_node(src, dst) {
            (self.intra_bw, self.intra_lat, 0u8)
        } else {
            *self.nic_ingress.entry(dst).or_insert(0.0) += bytes;
            (self.inter_bw, self.inter_lat, 1u8)
        };
        let eg = self.egress.entry((src, port)).or_insert(0);
        let ig = self.ingress.entry((dst, port)).or_insert(0);
        let start = (*eg).max(*ig).max(to_nanos(ready));
        let done = start + to_nanos(bytes / bw);
        *eg = done;
        *ig = done;
        to_secs(done) + lat
    }

    /// Worst per-NIC ingress volume (the paper's Maximal Incast Volume).
    pub fn max_incast(&self) -> f64 {
        self.nic_ingress.values().copied().fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_runs_tasks_in_parallel_up_to_slots() {
        let mut p = ProcPool::new(2);
        let d1 = p.run(0.0, 1.0);
        let d2 = p.run(0.0, 1.0);
        let d3 = p.run(0.0, 1.0);
        assert_eq!(d1, 1.0);
        assert_eq!(d2, 1.0);
        assert_eq!(d3, 2.0, "third task waits for a slot");
        assert!((p.utilization(2.0) - 0.75).abs() < 1e-9);
    }

    #[test]
    fn pool_respects_ready_time() {
        let mut p = ProcPool::new(1);
        let done = p.run(5.0, 1.0);
        assert_eq!(done, 6.0);
    }

    #[test]
    fn links_share_per_gpu_ports() {
        let mut l = LinkSet::new(100.0, 0.0, 10.0, 0.0, 4);
        // two 100-byte transfers out of rank 0: serialized on its egress
        let a = l.transfer(0, 1, 100.0, 0.0);
        let b = l.transfer(0, 2, 100.0, 0.0);
        assert_eq!(a, 1.0);
        assert_eq!(b, 2.0);
        // opposite direction uses different egress+ingress ports
        let c = l.transfer(3, 0, 100.0, 0.0);
        assert_eq!(c, 1.0);
        // converging on one ingress also serializes
        let d = l.transfer(2, 1, 100.0, 0.0);
        assert_eq!(d, 2.0, "rank 1 ingress already busy until t=1");
    }

    #[test]
    fn inter_node_uses_nic_and_tracks_incast() {
        let mut l = LinkSet::new(100.0, 0.0, 10.0, 0.5, 2);
        // ranks 0,1 node 0; ranks 2,3 node 1
        let t = l.transfer(0, 2, 10.0, 0.0);
        assert!((t - 1.5).abs() < 1e-9, "10B at 10B/s + 0.5 lat, got {t}");
        assert_eq!(l.max_incast(), 10.0);
        l.transfer(1, 2, 5.0, 0.0);
        assert_eq!(l.max_incast(), 15.0);
        // loopback free
        assert_eq!(l.transfer(3, 3, 1e9, 2.0), 2.0);
    }
}
