//! Fig 10 — forward latency vs tokens/GPU at 4 and 8 GPUs, E=64,
//! FlashDMoE (fp32) vs fp16 baselines on the calibrated simulator.
fn main() {
    let (text, pts) = flashdmoe::harness::fig10(42).unwrap();
    println!("{text}");
    let f = |e: &str| pts.iter().filter(|p| p.engine == e && p.x == 16384.0).map(|p| p.latency).fold(f64::MAX, f64::min);
    println!("speedup at 16K tokens: {:.2}x over Megatron-TE, {:.2}x over FasterMoE (paper: 4.6x / 2.6x at 4 GPUs, up to 6.4x at 8)",
        f("Megatron-TE") / f("FlashDMoE"), f("FasterMoE") / f("FlashDMoE"));
}
