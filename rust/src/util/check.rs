//! Proptest-style randomized property checking (proptest is unavailable
//! offline), plus the conformance oracle the checks compare against.
//!
//! `forall(seed, cases, gen, prop)` runs `prop` over `cases` random inputs
//! drawn by `gen`; on failure it retries with progressively simpler inputs
//! (re-drawing with a shrunken "size" hint) and reports the smallest
//! reproducing seed so failures are replayable.
//!
//! [`dense_reference_moe`] is a dense, per-token, drop-free MoE forward —
//! the function a `RoutingPolicy::Dropless` engine pass must equal (the
//! conformance suite in `rust/tests/properties.rs` asserts agreement to
//! 1e-5 under fuzzed shapes and skews).

use crate::config::Config;
use crate::expert::ModelParams;
use crate::train::GradStore;
use crate::util::prng::Rng;

/// Dense per-token reference MoE over one rank's (S, H) tokens: gate via
/// softmax(a·Wg), top-k with ties to the lower expert, then for every
/// routed pair the full expert FFN applied to the single token row,
/// combined with weights normalized over the token's top-k mass. No
/// capacity, no drops, no tiling — every routed (token, expert) pair's
/// weight mass is preserved by construction, which is exactly the
/// contract `RoutingPolicy::Dropless` promises. Accumulation runs in the
/// same reduction order as the blocked GEMM kernels (ascending over the
/// shared dimension, bias after), so agreement with the engine is tight.
pub fn dense_reference_moe(cfg: &Config, params: &ModelParams, a: &[f32]) -> Vec<f32> {
    let m = &cfg.model;
    let (h, d, e, k) = (m.h, m.d, m.e, m.k);
    let s = a.len() / h;
    debug_assert_eq!(a.len(), s * h);
    // gate: logits = a·Wg, softmax rows, top-k (same contract as gate.rs)
    let mut scores = vec![0.0f32; s * e];
    for i in 0..s {
        let ai = &a[i * h..(i + 1) * h];
        for j in 0..e {
            let mut acc = 0.0f32;
            for (p, &av) in ai.iter().enumerate() {
                acc += av * params.wg[p * e + j];
            }
            scores[i * e + j] = acc;
        }
    }
    crate::gate::softmax_rows(&mut scores, e);
    let (idx, w) = crate::gate::topk_rows(&scores, e, k);

    let mut out = vec![0.0f32; s * h];
    let mut mid = vec![0.0f32; d];
    let mut y = vec![0.0f32; h];
    for i in 0..s {
        let ai = &a[i * h..(i + 1) * h];
        let denom: f32 = w[i * k..(i + 1) * k].iter().sum();
        for j in 0..k {
            let ex = &params.experts[idx[i * k + j] as usize];
            // mid = relu(a_i·W1 + b1)
            for (c, mv) in mid.iter_mut().enumerate() {
                let mut acc = 0.0f32;
                for (p, &av) in ai.iter().enumerate() {
                    acc += av * ex.w1[p * d + c];
                }
                acc += ex.b1[c];
                *mv = if acc < 0.0 { 0.0 } else { acc };
            }
            // y = mid·W2 + b2
            for (c, yv) in y.iter_mut().enumerate() {
                let mut acc = 0.0f32;
                for (p, &mv) in mid.iter().enumerate() {
                    acc += mv * ex.w2[p * h + c];
                }
                *yv = acc + ex.b2[c];
            }
            let cw = w[i * k + j] / denom;
            for (o, &yv) in out[i * h..(i + 1) * h].iter_mut().zip(&y) {
                *o += cw * yv;
            }
        }
    }
    out
}

/// Dense per-token reference MoE *backward* over one rank's (S, H)
/// tokens: given upstream gradients `dy` (S, H) w.r.t. the layer output,
/// returns the input gradients dX (S, H) and the parameter gradients
/// accumulated into a fresh [`GradStore`]. Mirrors
/// [`dense_reference_moe`]'s math exactly — same gate, same normalized
/// combine weights c_j = w_j / Σw — and backpropagates through all of it,
/// including the gate: gradients flow into the selected top-k
/// probabilities (straight-through w.r.t. the non-differentiable
/// selection itself, the standard MoE convention), then through the
/// softmax into Wg and the input. Multi-rank callers invoke this once
/// per rank and fold the stores with [`GradStore::add_assign`].
pub fn dense_reference_moe_grad(
    cfg: &Config,
    params: &ModelParams,
    a: &[f32],
    dy: &[f32],
) -> (Vec<f32>, GradStore) {
    let m = &cfg.model;
    let (h, d, e, k) = (m.h, m.d, m.e, m.k);
    let s = a.len() / h;
    debug_assert_eq!(a.len(), s * h);
    debug_assert_eq!(dy.len(), s * h);
    // forward gate replay (identical to dense_reference_moe)
    let mut scores = vec![0.0f32; s * e];
    for i in 0..s {
        let ai = &a[i * h..(i + 1) * h];
        for j in 0..e {
            let mut acc = 0.0f32;
            for (p, &av) in ai.iter().enumerate() {
                acc += av * params.wg[p * e + j];
            }
            scores[i * e + j] = acc;
        }
    }
    crate::gate::softmax_rows(&mut scores, e);
    let (idx, w) = crate::gate::topk_rows(&scores, e, k);

    let mut grads = GradStore::zeros_like(params);
    let mut dx = vec![0.0f32; s * h];
    let mut mid = vec![0.0f32; d];
    let mut y = vec![0.0f32; h];
    let mut dyt = vec![0.0f32; h];
    let mut dmid = vec![0.0f32; d];
    let mut dc = vec![0.0f32; k];
    let mut dlogits = vec![0.0f32; e];
    for i in 0..s {
        let ai = &a[i * h..(i + 1) * h];
        let dyi = &dy[i * h..(i + 1) * h];
        let denom: f32 = w[i * k..(i + 1) * k].iter().sum();
        for j in 0..k {
            let ex_id = idx[i * k + j] as usize;
            let ex = &params.experts[ex_id];
            // forward expert replay: mid = relu(a_i·W1 + b1), y = mid·W2 + b2
            for (c, mv) in mid.iter_mut().enumerate() {
                let mut acc = 0.0f32;
                for (p, &av) in ai.iter().enumerate() {
                    acc += av * ex.w1[p * d + c];
                }
                acc += ex.b1[c];
                *mv = if acc < 0.0 { 0.0 } else { acc };
            }
            for (c, yv) in y.iter_mut().enumerate() {
                let mut acc = 0.0f32;
                for (p, &mv) in mid.iter().enumerate() {
                    acc += mv * ex.w2[p * h + c];
                }
                *yv = acc + ex.b2[c];
            }
            let cw = w[i * k + j] / denom;
            // dL/dc_j = <dy_i, y_j> (combine weight grad, pre-normalization)
            let mut acc = 0.0f32;
            for (&dv, &yv) in dyi.iter().zip(&y) {
                acc += dv * yv;
            }
            dc[j] = acc;
            // grad into the expert output: dy_t = c_j · dy_i
            for (t, &dv) in dyt.iter_mut().zip(dyi) {
                *t = cw * dv;
            }
            // dmid = (dy_t·W2ᵀ) ⊙ relu'(mid);  dW2 += mid ⊗ dy_t;  db2 += dy_t
            let g = &mut grads.experts[ex_id];
            for (p, dmv) in dmid.iter_mut().enumerate() {
                let mut acc = 0.0f32;
                for (c, &tv) in dyt.iter().enumerate() {
                    acc += tv * ex.w2[p * h + c];
                }
                *dmv = if mid[p] > 0.0 { acc } else { 0.0 };
            }
            for (p, &mv) in mid.iter().enumerate() {
                for (c, &tv) in dyt.iter().enumerate() {
                    g.w2[p * h + c] += mv * tv;
                }
            }
            for (bv, &tv) in g.b2.iter_mut().zip(&dyt) {
                *bv += tv;
            }
            // dW1 += a_i ⊗ dmid;  db1 += dmid;  dx_i += dmid·W1ᵀ
            for (p, &av) in ai.iter().enumerate() {
                for (c, &dmv) in dmid.iter().enumerate() {
                    g.w1[p * d + c] += av * dmv;
                }
            }
            for (bv, &dmv) in g.b1.iter_mut().zip(&dmid) {
                *bv += dmv;
            }
            for (p, xv) in dx[i * h..(i + 1) * h].iter_mut().enumerate() {
                let mut acc = 0.0f32;
                for (c, &dmv) in dmid.iter().enumerate() {
                    acc += dmv * ex.w1[p * d + c];
                }
                *xv += acc;
            }
        }
        // gate backward: c_j = w_j/S ⇒ dw_t = (dc_t − Σ_u c_u·dc_u)/S on
        // the selected probs, then softmax backward over the full E row
        let mut gsum = 0.0f32;
        for j in 0..k {
            gsum += (w[i * k + j] / denom) * dc[j];
        }
        dlogits.iter_mut().for_each(|v| *v = 0.0);
        // dp (nonzero only on topk), folded straight into softmax backward:
        // dlogit_v = p_v·(dp_v − Σ_u dp_u·p_u)
        let mut dp_dot_p = 0.0f32;
        for j in 0..k {
            let dp = (dc[j] - gsum) / denom;
            dlogits[idx[i * k + j] as usize] = dp;
            dp_dot_p += dp * scores[i * e + idx[i * k + j] as usize];
        }
        for v in 0..e {
            let pv = scores[i * e + v];
            dlogits[v] = pv * (dlogits[v] - dp_dot_p);
        }
        // dWg += a_i ⊗ dlogits;  dx_i += dlogits·Wgᵀ
        for (p, &av) in ai.iter().enumerate() {
            for (j, &dl) in dlogits.iter().enumerate() {
                grads.wg[p * e + j] += av * dl;
            }
        }
        for (p, xv) in dx[i * h..(i + 1) * h].iter_mut().enumerate() {
            let mut acc = 0.0f32;
            for (j, &dl) in dlogits.iter().enumerate() {
                acc += dl * params.wg[p * e + j];
            }
            *xv += acc;
        }
    }
    (dx, grads)
}

/// Context handed to generators; `size` shrinks during failure minimization.
pub struct Gen<'a> {
    pub rng: &'a mut Rng,
    pub size: usize,
}

impl<'a> Gen<'a> {
    /// Integer in [lo, hi], biased toward smaller values as size shrinks.
    pub fn int(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        let span = (hi - lo + 1).min(self.size.max(1));
        lo + self.rng.below(span)
    }

    /// Pick one element of a slice.
    pub fn choose<T: Copy>(&mut self, xs: &[T]) -> T {
        xs[self.rng.below(xs.len())]
    }

    /// Uniform f64 in [lo, hi).
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    /// Vector with generated length in [0, max_len].
    pub fn vec<T>(&mut self, max_len: usize, mut f: impl FnMut(&mut Self) -> T) -> Vec<T> {
        let n = self.int(0, max_len);
        (0..n).map(|_| f(self)).collect()
    }
}

/// Outcome of a forall run.
#[derive(Debug)]
pub struct Failure {
    pub case_seed: u64,
    pub message: String,
    pub shrunk_size: usize,
}

/// Run `prop` over `cases` random inputs. Panics with a replayable report on
/// the first falsified case (after attempting size-based shrinking).
pub fn forall<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Gen) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let base = Rng::new(seed);
    for case in 0..cases {
        let case_seed = seed ^ ((case as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = base.fork(case_seed);
        let mut g = Gen { rng: &mut rng, size: usize::MAX };
        let input = gen(&mut g);
        if let Err(msg) = prop(&input) {
            let failure = shrink(case_seed, &mut gen, &mut prop).unwrap_or(Failure {
                case_seed,
                message: msg,
                shrunk_size: usize::MAX,
            });
            panic!(
                "property falsified (case {case}, replay seed {:#x}, size {}):\n  {}\n  original input: {:?}",
                failure.case_seed, failure.shrunk_size, failure.message, input
            );
        }
    }
}

/// Try progressively smaller `size` hints to find a simpler failing case.
fn shrink<T>(
    case_seed: u64,
    gen: &mut impl FnMut(&mut Gen) -> T,
    prop: &mut impl FnMut(&T) -> Result<(), String>,
) -> Option<Failure> {
    let mut best: Option<Failure> = None;
    for size in [2usize, 4, 8, 16, 64, 256] {
        for attempt in 0..50u64 {
            let s = case_seed ^ (size as u64) ^ (attempt << 32);
            let mut rng = Rng::new(s);
            let mut g = Gen { rng: &mut rng, size };
            let input = gen(&mut g);
            if let Err(message) = prop(&input) {
                best = Some(Failure { case_seed: s, message, shrunk_size: size });
                break;
            }
        }
        if best.is_some() {
            break;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_is_silent() {
        forall(
            1,
            200,
            |g| (g.int(0, 100), g.int(0, 100)),
            |(a, b)| {
                if a + b >= *a.max(b) {
                    Ok(())
                } else {
                    Err("math broke".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property falsified")]
    fn failing_property_panics_with_seed() {
        forall(
            2,
            200,
            |g| g.int(0, 1000),
            |n| if *n < 990 { Ok(()) } else { Err(format!("{n} too big")) },
        );
    }

    #[test]
    fn generators_respect_bounds() {
        let mut rng = Rng::new(3);
        let mut g = Gen { rng: &mut rng, size: usize::MAX };
        for _ in 0..1000 {
            let v = g.int(5, 10);
            assert!((5..=10).contains(&v));
        }
    }
}
