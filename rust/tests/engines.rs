//! Shape assertions over the simulator harness: every paper table/figure
//! must reproduce its qualitative result (who wins, ordering, crossovers)
//! — the quantitative rows are printed by `cargo bench` into
//! bench_output.txt and recorded in EXPERIMENTS.md.

use flashdmoe::harness;
use flashdmoe::sim::straggler;

const SEED: u64 = 42;

fn latency(points: &[harness::Point], engine: &str, x: f64) -> f64 {
    points
        .iter()
        .find(|p| p.engine == engine && p.x == x)
        .unwrap_or_else(|| panic!("missing point {engine}@{x}"))
        .latency
}

#[test]
fn table1_flash_is_single_launch_and_counts_match_paper() {
    let (_, rows) = harness::table1();
    assert_eq!(rows[0], ("FlashDMoE", 1));
    let paper = [("COMET", 33), ("Megatron-CUTLASS", 85), ("Megatron-TE", 261),
                 ("Megatron+DeepEP", 432), ("DeepSpeedMoE", 550)];
    for ((name, ours), (pname, want)) in rows[1..].iter().zip(paper) {
        assert_eq!(*name, pname);
        assert!(
            ours.abs_diff(want) * 10 <= want,
            "{name}: {ours} vs paper {want} (>10% off)"
        );
    }
}

#[test]
fn table2_straggler_bands() {
    let (_, reports) = harness::table2(SEED);
    let vm = &reports[0].summary;
    let sc = &reports[1].summary;
    // paper: VM 3.1x median / 11.4x p95; supercomputer 1.09x / 1.32x
    assert!(vm.p50 > 2.0 && vm.p50 < 4.5, "vm median {}", vm.p50);
    assert!(vm.p95 > 7.0 && vm.p95 < 18.0, "vm p95 {}", vm.p95);
    assert!(sc.p50 > 1.0 && sc.p50 < 1.2, "sc median {}", sc.p50);
    assert!(sc.p95 > 1.1 && sc.p95 < 1.6, "sc p95 {}", sc.p95);
    // idle fraction at vm p95 must be dominant (the Fig 4 motivation)
    assert!(straggler::idle_fraction(vm.p95) > 0.8);
}

#[test]
fn table3_memory_shape() {
    let (_, reports) = harness::table3();
    // paper row (4K, 16): Size(L) = 64 MB exactly (MiB convention)
    let r = reports.iter().find(|r| r.tokens == 4096 && r.experts == 16).unwrap();
    assert!((r.size_l / (1024.0 * 1024.0) - 64.0).abs() < 0.01, "{}", r.size_l);
    // paper row (16K, 16): 256 MB
    let r = reports.iter().find(|r| r.tokens == 16384 && r.experts == 16).unwrap();
    assert!((r.size_l / (1024.0 * 1024.0) - 256.0).abs() < 0.1);
    // capacity clamped to bM keeps Size(L) flat when EC < bM (4K: 32 vs 64 experts)
    let r32 = reports.iter().find(|r| r.tokens == 4096 && r.experts == 32).unwrap();
    let r64 = reports.iter().find(|r| r.tokens == 4096 && r.experts == 64).unwrap();
    assert_eq!(r32.c_aligned, 128);
    assert_eq!(r64.c_aligned, 128);
    assert!(r64.size_l > r32.size_l, "more experts, more cells");
    // totals modest & predictable: doubling tokens doubles L
    let r8k = reports.iter().find(|r| r.tokens == 8192 && r.experts == 16).unwrap();
    let r4k = reports.iter().find(|r| r.tokens == 4096 && r.experts == 16).unwrap();
    assert!((r8k.size_l / r4k.size_l - 2.0).abs() < 1e-9);
}

#[test]
fn fig10_flash_wins_latency_at_every_token_count() {
    let (_, pts) = harness::fig10(SEED).unwrap();
    for &tokens in &[1024.0, 2048.0, 4096.0, 8192.0, 16384.0] {
        let flash = latency(&pts, "FlashDMoE", tokens);
        for b in ["FasterMoE", "Megatron-CUTLASS", "Megatron-TE"] {
            let bl = latency(&pts, b, tokens);
            assert!(flash < bl, "{b}@{tokens}: flash {flash} vs {bl}");
        }
    }
    // the paper's headline: several-x speedup at 16K
    let flash = latency(&pts, "FlashDMoE", 16384.0);
    let worst = ["FasterMoE", "Megatron-CUTLASS", "Megatron-TE"]
        .iter()
        .map(|b| latency(&pts, b, 16384.0))
        .fold(0.0f64, f64::max);
    assert!(worst / flash > 2.0, "speedup only {:.2}x", worst / flash);
}

#[test]
fn fig11_utilization_ordering_matches_paper() {
    let (_, pts) = harness::fig11(SEED).unwrap();
    let util = |name: &str| pts.iter().find(|p| p.engine == name).unwrap().utilization;
    let flash = util("FlashDMoE");
    let te = util("Megatron-TE");
    let comet = util("COMET");
    let deepep = util("Megatron+DeepEP");
    let fastermoe = util("FasterMoE");
    assert!(flash > 0.85, "flash util {flash}");
    assert!(flash > te && te > comet && comet > deepep && deepep > fastermoe,
        "ordering broken: {flash:.2} {te:.2} {comet:.2} {deepep:.2} {fastermoe:.2}");
    assert!(fastermoe < 0.2, "fastermoe {fastermoe}");
    // paper: flash is ~9x FasterMoE
    assert!(flash / fastermoe > 5.0);
}

#[test]
fn fig12_overlap_efficiency_flash_stays_near_one() {
    let (_, pts) = harness::fig12(SEED).unwrap();
    let oe = |e: &str, n: f64| latency(&pts, e, 2.0) / latency(&pts, e, n);
    // flash: near-flat weak scaling
    assert!(oe("FlashDMoE", 8.0) > 0.8, "flash O_e(8) = {}", oe("FlashDMoE", 8.0));
    // paper: flash up to ~4x better overlap efficiency at 8 GPUs
    for b in ["Megatron-CUTLASS", "Megatron-TE"] {
        assert!(
            oe("FlashDMoE", 8.0) > oe(b, 8.0),
            "flash O_e must beat {b}"
        );
    }
}

#[test]
fn fig13_throughput_scales_and_wins() {
    let (_, pts) = harness::fig13(SEED).unwrap();
    let thr = |e: &str, n: f64| 16384.0 * n / latency(&pts, e, n);
    // flash throughput grows with GPUs
    assert!(thr("FlashDMoE", 8.0) > 1.8 * thr("FlashDMoE", 2.0));
    // and beats every baseline at 8 GPUs by a healthy factor
    for b in ["FasterMoE", "Megatron-CUTLASS", "Megatron-TE"] {
        assert!(thr("FlashDMoE", 8.0) > thr(b, 8.0), "{b}");
    }
    assert!(thr("FlashDMoE", 8.0) / thr("FasterMoE", 8.0) > 2.0);
}

#[test]
fn fig14_flash_stays_flat_in_experts() {
    let (_, pts) = harness::fig14(SEED).unwrap();
    let flash_8 = latency(&pts, "FlashDMoE", 8.0);
    let flash_128 = latency(&pts, "FlashDMoE", 128.0);
    assert!(
        flash_128 / flash_8 < 2.0,
        "flash must stay near-flat: {flash_8} -> {flash_128}"
    );
    // baselines superlinear from launch overhead (per-expert kernels)
    let te_8 = latency(&pts, "Megatron-TE", 8.0);
    let te_128 = latency(&pts, "Megatron-TE", 128.0);
    assert!(te_128 / te_8 > flash_128 / flash_8, "TE must degrade faster");
    // paper: up to ~6x at 128 experts
    assert!(
        latency(&pts, "Megatron-TE", 128.0) / flash_128 > 2.0,
        "win at 128 experts too small"
    );
}

#[test]
fn fig17_incast_failure_appears_past_threshold() {
    // Measured, not closed-form: multinode_ab drives live engines over
    // the Transport subsystem in both dispatch modes (and asserts
    // flat/hier bitwise output equality + the incast byte bound
    // internally — the shape claims are asserted HERE on its points).
    let (_, pts) = harness::multinode_ab(SEED).unwrap();
    let small_ok = pts.iter().filter(|p| p.tokens_per_gpu <= 2048).all(|p| !p.overflow);
    let big_fails = pts.iter().any(|p| p.tokens_per_gpu > 2048 && p.overflow);
    assert!(small_ok, "token counts <= 2048/GPU must not overflow the NIC window");
    assert!(big_fails, "the paper's >2048-token incast failure must reproduce as an engine error");
    for mode in ["flat", "hierarchical"] {
        let surviving: Vec<_> =
            pts.iter().filter(|p| p.mode == mode && !p.overflow).collect();
        assert!(!surviving.is_empty(), "{mode}: no surviving points");
        for p in &surviving {
            // measured MIV is a real engine quantity on every live point
            assert!(p.miv_bytes > 0, "{mode}@{}: MIV must be measured", p.tokens_per_gpu);
            // and the incast bound holds: measured inter <= announced
            assert!(
                p.inter_bytes <= p.announced_inter_bytes,
                "{mode}@{}: inter {} > announced {}",
                p.tokens_per_gpu,
                p.inter_bytes,
                p.announced_inter_bytes
            );
        }
    }
    // the tentpole's payoff: coalescing strictly reduces NIC bytes at
    // k=2 (duplicate remote-node rows cross once) on every live point
    for f in pts.iter().filter(|p| p.mode == "flat" && !p.overflow) {
        let h = pts
            .iter()
            .find(|p| p.mode == "hierarchical" && p.tokens_per_gpu == f.tokens_per_gpu)
            .unwrap();
        assert!(
            h.inter_bytes < f.inter_bytes,
            "@{} tokens/GPU: hierarchical {} must move fewer NIC bytes than flat {}",
            f.tokens_per_gpu,
            h.inter_bytes,
            f.inter_bytes
        );
        assert!(
            h.miv_bytes <= f.miv_bytes,
            "@{} tokens/GPU: hierarchical MIV must not exceed flat's",
            f.tokens_per_gpu
        );
    }
}

#[test]
fn fig18_reduced_precision_halves_measured_wire_bytes() {
    // Measured, not modeled: precision_ab runs live engines per wire
    // format (it asserts dense-reference conformance internally; the
    // byte-ratio claims are asserted HERE, on the reported points —
    // this is the exact-2x check, and the bench's PERF_SMOKE gate is
    // the independent looser one).
    use flashdmoe::config::WirePrecision;
    let (_, pts) = harness::precision_ab("tiny", 1, SEED).unwrap();
    let fp32 = pts.iter().find(|p| p.wire == WirePrecision::F32).unwrap();
    assert!(fp32.max_abs_err < 1e-5, "f32 wire must stay on the exact path");
    for wire in [WirePrecision::Bf16, WirePrecision::F16] {
        let p = pts.iter().find(|p| p.wire == wire).unwrap();
        assert_eq!(p.wire_bytes * 2, fp32.wire_bytes, "{wire:?} measured halving");
        assert!(p.max_abs_err < p.tolerance, "{wire:?} conformance");
        assert!(
            (fp32.heap_bytes / p.heap_bytes - 2.0).abs() < 1e-9,
            "{wire:?} heap footprint halves"
        );
        // narrowing shows up in the savings metric on top of padding
        assert!(p.payload_savings > fp32.payload_savings, "{wire:?} savings credit");
    }
}
