//! Table 1 — kernel launches per single MoE layer pass (2 ranks, 32 local
//! experts). FlashDMoE = 1 persistent kernel; baselines modeled per
//! `Baseline::launch_model`, calibrated against the paper's Nsight counts.
fn main() {
    let (text, rows) = flashdmoe::harness::table1();
    println!("{text}");
    assert_eq!(rows[0].1, 1, "flash must be a single launch");
}
