//! Serving-style driver: a request router + dynamic batcher in front of
//! the persistent MoE engine — the shape a deployment embeds (vLLM-ish
//! front end, FlashDMoE back end). Synthetic clients submit variable-size
//! requests; the batcher packs them into fixed (S_r, H) rank batches
//! (padding tracked) and drives the engine with **pipelined submission**:
//! while pass N runs on the resident actors, the batcher packs and
//! submits batch N+1, so host-side packing is hidden behind engine
//! compute. Reports per-request latency percentiles, sustained
//! throughput, batch fill, and the achieved pack/compute overlap.
//!
//!     cargo run --release --example serve

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use flashdmoe::config::Config;
use flashdmoe::coordinator::{MoeEngine, PassHandle, TaskGraphMode};
use flashdmoe::expert::ModelParams;
use flashdmoe::runtime::{ComputeBackend, NativeBackend};
use flashdmoe::util::prng::Rng;
use flashdmoe::util::stats::{fmt_time, summarize, Table};

struct Request {
    tokens: usize,
    submitted: Instant,
}

/// A batch in flight on the engine: its pass handle plus the requests
/// whose latency clocks stop when the pass completes.
struct InFlight {
    handle: PassHandle,
    requests: Vec<Request>,
}

fn main() -> anyhow::Result<()> {
    let n_requests: usize =
        std::env::var("REQUESTS").ok().and_then(|v| v.parse().ok()).unwrap_or(64);
    let cfg = Config::preset("tiny")?;
    let params = Arc::new(ModelParams::generate(&cfg, 42));
    let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(&cfg));
    // launch once — every batch below is a doorbell ring on these actors
    let engine = MoeEngine::start(cfg.clone(), params, backend, TaskGraphMode::Fused)?;

    let (s_rank, h, ranks) = (cfg.system.s_rank, cfg.model.h, cfg.system.ranks);
    let batch_capacity = s_rank * ranks;
    println!(
        "serving: batch capacity {} tokens ({} ranks x {}), H={}",
        batch_capacity, ranks, s_rank, h
    );

    // synthetic open-loop arrivals: requests of 8..256 tokens
    let mut rng = Rng::new(7);
    let mut queue: VecDeque<Request> = (0..n_requests)
        .map(|_| Request { tokens: 8 + rng.below(249), submitted: Instant::now() })
        .collect();

    let mut latencies = Vec::new();
    let mut batches = 0usize;
    let mut served_tokens = 0usize;
    let mut padded_tokens = 0usize;
    let mut pack_secs = 0.0f64; // host-side packing, total
    let mut pack_overlapped_secs = 0.0f64; // packing done while a pass was in flight
    let mut wait_secs = 0.0f64; // time actually blocked on the engine
    let mut in_flight: Option<InFlight> = None;
    let t0 = Instant::now();

    fn drain(fly: InFlight, latencies: &mut Vec<f64>, wait_secs: &mut f64) -> anyhow::Result<()> {
        let tw = Instant::now();
        let out = fly.handle.wait()?;
        *wait_secs += tw.elapsed().as_secs_f64();
        let now = Instant::now();
        for r in &fly.requests {
            latencies.push(now.duration_since(r.submitted).as_secs_f64());
        }
        drop(out);
        Ok(())
    }

    while !queue.is_empty() {
        // pack batch N+1 while batch N runs on the resident actors
        let overlapped = in_flight.is_some();
        let tp = Instant::now();
        let mut batch: Vec<Request> = Vec::new();
        let mut used = 0usize;
        while let Some(r) = queue.front() {
            if used + r.tokens > batch_capacity {
                break;
            }
            used += r.tokens;
            batch.push(queue.pop_front().unwrap());
        }
        anyhow::ensure!(!batch.is_empty(), "request larger than batch capacity");

        // pack token embeddings (synthetic) into per-rank inputs
        let mut flat = rng.normal_vec(batch_capacity * h, 1.0);
        // zero the padding region so it's visibly inert
        for v in flat[used * h..].iter_mut() {
            *v = 0.0;
        }
        let inputs: Vec<Vec<f32>> =
            (0..ranks).map(|r| flat[r * s_rank * h..(r + 1) * s_rank * h].to_vec()).collect();
        let packed = tp.elapsed().as_secs_f64();
        pack_secs += packed;
        if overlapped {
            // a pass was in flight for this whole pack: the engine was
            // computing while the host prepared the next batch
            pack_overlapped_secs += packed;
        }

        // pipelined submission: hand batch N+1 to the engine *before*
        // collecting batch N
        let handle = engine.submit(&inputs)?;
        batches += 1;
        served_tokens += used;
        padded_tokens += batch_capacity - used;
        if let Some(prev) = in_flight.take() {
            drain(prev, &mut latencies, &mut wait_secs)?;
        }
        in_flight = Some(InFlight { handle, requests: batch });
    }
    if let Some(last) = in_flight.take() {
        drain(last, &mut latencies, &mut wait_secs)?;
    }
    let wall = t0.elapsed().as_secs_f64();
    let em = engine.metrics();
    // achieved overlap: the fraction of host packing that happened while
    // a pass was in flight (the first batch necessarily packs cold)
    let overlap = if pack_secs > 0.0 { pack_overlapped_secs / pack_secs } else { 0.0 };

    let s = summarize(&latencies);
    let mut t = Table::new(&["metric", "value"]);
    t.row(&["requests".into(), n_requests.to_string()]);
    t.row(&["batches".into(), batches.to_string()]);
    t.row(&["tokens served".into(), served_tokens.to_string()]);
    t.row(&["batch fill".into(), format!("{:.1}%", served_tokens as f64 / (served_tokens + padded_tokens) as f64 * 100.0)]);
    t.row(&["throughput".into(), format!("{:.0} tokens/s", served_tokens as f64 / wall)]);
    t.row(&["latency p50".into(), fmt_time(s.p50)]);
    t.row(&["latency p95".into(), fmt_time(s.p95)]);
    t.row(&["latency max".into(), fmt_time(s.max)]);
    t.row(&["engine passes".into(), format!("{} ({} launch)", em.passes, em.launches)]);
    t.row(&["host pack time".into(), fmt_time(pack_secs)]);
    t.row(&["  …while a pass ran".into(), fmt_time(pack_overlapped_secs)]);
    t.row(&["blocked on engine".into(), fmt_time(wait_secs)]);
    t.row(&["pack overlap achieved".into(), format!("{:.1}% of packing hidden", overlap * 100.0)]);
    println!("{}", t.render());
    assert_eq!(em.passes, batches as u64);
    engine.shutdown();
    println!("serve OK");
    Ok(())
}
