//! The L3 coordinator — the paper's system contribution.
//!
//! Each rank runs a "persistent kernel": one OS/subscriber/scheduler
//! context plus N processor workers that stay resident for the whole MoE
//! operator. Actors exchange tile-granular task descriptors through a
//! work-conserving ready queue; ranks exchange tiles through the
//! write-conflict-free symmetric heap with one-sided put+signal
//! (`crate::fabric`). There is no bulk-synchronous collective anywhere on
//! the data path — the only barrier is the initial "kernel launch".
//!
//! Module map (mirrors Fig. 6):
//! * [`scheduler`] — the ready queue + interrupt plumbing (Alg. 3).
//! * [`rank`]      — one rank's actor group: subscriber decode loop
//!   (Alg. 4), processor execution loop (Alg. 2), dispatch (Alg. 1).
//! * [`moe`]       — the public `DistributedMoE` operator API.
//! * [`baseline`]  — a real-execution bulk-synchronous baseline
//!   (Megatron/DeepSpeed-shaped) over the same substrate, for measured
//!   comparisons and numeric cross-checks.
//! * [`metrics`]   — per-rank busy/idle accounting (SM-utilization analog).

pub mod baseline;
pub mod metrics;
pub mod moe;
pub mod rank;
pub mod scheduler;

pub use moe::{DistributedMoE, ForwardResult, TaskGraphMode};
