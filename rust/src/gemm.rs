//! Native in-process BLAS: cache-blocked f32 GEMM with fused epilogues,
//! in two flavours — the row-major reference path and the packed
//! persistent-weight hot path.
//!
//! This is the paper's "in-device BLAS" substrate (they built it on
//! CUTLASS; here it is a register-blocked CPU kernel). It backs the
//! native `ComputeBackend` path used by tests, the baselines and the
//! perf pass; the XLA/PJRT path executes the same math via the AOT
//! Pallas artifacts, and both must agree to f32 tolerance.
//!
//! **Compute precision is f32, unconditionally.** The engine's
//! `wire_precision` knob (f16/bf16 payloads on the symmetric heap —
//! see `crate::wire` and `fabric.rs`) never reaches this module: tiles
//! are dequantized back to f32 *before* any GEMM consumes them, every
//! kernel here accumulates in f32, and the bitwise `packed == naive`
//! reduction-order guarantee below is independent of what format the
//! operands crossed the fabric in. FlashMoE ships FP32 compute while
//! shrinking the sparse data movement — this split is that contract.
//!
//! ## Unpacked reference path
//!
//! All matrices row-major. The hot loop is an (MR x NR) register tile
//! over a K-panel; the epilogue (bias + activation) runs as a separate
//! sweep after the last K-panel. Every step through K strides `n`
//! floats through B — a new cache line per step for any realistic `n` —
//! which is exactly the cost the packed path removes. This path is kept
//! as the A/B baseline (`packed=false`) and for one-shot weights.
//!
//! ## Packed persistent-weight path (BLIS-style)
//!
//! MoE expert weights are **static across passes**, so a persistent
//! engine packs them once per lifetime ([`PackedWeights::pack`]) and
//! every subsequent GEMM streams cache-contiguous panels:
//!
//! ```text
//!   B (k x n), row-major              PackedWeights (panel-major)
//!   +--------- n ---------+           panel 0    panel 1    ...
//!   | b00 b01 ......  b0n |          +--------+ +--------+
//!   k ...                 |   pack   | k x NR | | k x NR |  each panel is
//!   | ...                 |  ----->  | rows,  | | rows,  |  one contiguous
//!   +---------------------+          | contig | | contig |  k*NR block
//!                                    +--------+ +--------+
//! ```
//!
//! * Panel `p` holds columns `[p*NR, p*NR + NR)` for all `k` rows; the
//!   last panel is zero-padded in the column direction ("pad into
//!   panel"), so the micro-kernel never takes a scalar n-edge path.
//! * The micro-kernel keeps the full (MR x NR) accumulator in registers
//!   across **all** of K, streaming the panel top-to-bottom in KC-sized
//!   chunks, and writes C exactly once: bias add + activation are fused
//!   into that single write-back, eliminating both the `c.fill(0.0)`
//!   prologue and the separate epilogue sweep of the unpacked path.
//! * m-edges (m % MR != 0) reuse the same NR-wide vectorized lanes with
//!   a shortened row loop — no O(m*n*k) scalar fallback anywhere.
//!
//! Invariants (relied on by callers and the property suite):
//!
//! * Per output element, the packed kernel performs the same f32
//!   multiply-adds in the same k-ascending order as [`gemm_naive`], so
//!   `packed == naive` holds **bitwise**, not just to tolerance — which
//!   is what lets the engine keep its combine-order determinism
//!   guarantee regardless of the `packed` toggle.
//! * Column slices ([`gemm_bias_packed_cols`]) must start on a panel
//!   boundary (`col0 % NR == 0`); a slice is a contiguous run of panels,
//!   so split-mode (bN-wide) GEMMs index straight into the one packed
//!   copy of the full weight matrix (no per-column-tile re-pack).
//! * Packing is the only O(k*n) copy; per-pass packing work is zero
//!   (asserted by the engine test suite via the backend pack counter).

/// Fused epilogue selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Epilogue {
    /// C = A·B + bias
    Identity,
    /// C = relu(A·B + bias)
    Relu,
}

/// Register tile height/width of the micro-kernel. NR=16 maps one
/// accumulator row to a ZMM register (AVX-512) or two YMMs; MR=8 gives
/// 8 accumulator rows + loaded B row within the 32-register budget.
/// NR is also the packed panel width, so packed column slices must be
/// NR-aligned (callers check `bn % NR == 0` before taking that path).
pub const MR: usize = 8;
pub const NR: usize = 16;
/// K-chunk length the packed micro-kernel streams a panel in (and the
/// unpacked path's K-panel blocking; fits MR+NR panels in L1 comfortably).
pub const KC: usize = 256;

/// C(m,n) = phi(A(m,k)·B(k,n) + bias(n)), row-major, C overwritten.
pub fn gemm_bias(
    a: &[f32],
    b: &[f32],
    bias: Option<&[f32]>,
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    epilogue: Epilogue,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if let Some(bv) = bias {
        debug_assert_eq!(bv.len(), n);
    }
    c.fill(0.0);
    // K-blocked accumulation into C, epilogue applied after the last panel.
    let mut k0 = 0;
    while k0 < k {
        let kb = KC.min(k - k0);
        macro_kernel(a, b, c, m, k, n, k0, kb);
        k0 += kb;
    }
    finish(c, bias, m, n, epilogue);
}

/// Accumulate C += A[:, k0..k0+kb]·B[k0..k0+kb, :].
fn macro_kernel(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize, k0: usize, kb: usize) {
    let mut i = 0;
    while i < m {
        let mb = MR.min(m - i);
        let mut j = 0;
        while j < n {
            let nb = NR.min(n - j);
            if mb == MR && nb == NR {
                micro_kernel_full(a, b, c, k, n, i, j, k0, kb);
            } else {
                micro_kernel_edge(a, b, c, k, n, i, j, k0, kb, mb, nb);
            }
            j += NR;
        }
        i += MR;
    }
}

/// Full MRxNR register tile; the compiler autovectorizes the NR lane.
#[inline]
fn micro_kernel_full(a: &[f32], b: &[f32], c: &mut [f32], k: usize, n: usize, i: usize, j: usize, k0: usize, kb: usize) {
    let mut acc = [[0.0f32; NR]; MR];
    for p in k0..k0 + kb {
        let brow = &b[p * n + j..p * n + j + NR];
        for (r, accr) in acc.iter_mut().enumerate() {
            let av = a[(i + r) * k + p];
            for (x, &bv) in accr.iter_mut().zip(brow) {
                *x += av * bv;
            }
        }
    }
    for (r, accr) in acc.iter().enumerate() {
        let crow = &mut c[(i + r) * n + j..(i + r) * n + j + NR];
        for (cv, &x) in crow.iter_mut().zip(accr) {
            *cv += x;
        }
    }
}

/// Edge tile (partial MR/NR).
#[inline]
fn micro_kernel_edge(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    k: usize,
    n: usize,
    i: usize,
    j: usize,
    k0: usize,
    kb: usize,
    mb: usize,
    nb: usize,
) {
    for r in 0..mb {
        for col in 0..nb {
            let mut acc = 0.0f32;
            for p in k0..k0 + kb {
                acc += a[(i + r) * k + p] * b[p * n + j + col];
            }
            c[(i + r) * n + j + col] += acc;
        }
    }
}

/// Epilogue: bias add + activation over the finished accumulator.
fn finish(c: &mut [f32], bias: Option<&[f32]>, m: usize, n: usize, epilogue: Epilogue) {
    for row in 0..m {
        let crow = &mut c[row * n..(row + 1) * n];
        if let Some(bv) = bias {
            for (cv, &b) in crow.iter_mut().zip(bv) {
                *cv += b;
            }
        }
        if epilogue == Epilogue::Relu {
            for cv in crow.iter_mut() {
                if *cv < 0.0 {
                    *cv = 0.0;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Packed persistent-weight path
// ---------------------------------------------------------------------------

/// A weight matrix re-laid out for the persistent hot path: NR-wide
/// column panels, each a contiguous (k, NR) block, zero-padded in the
/// column direction (see the module docs for the diagram). Built once
/// per engine lifetime — weights are static across passes — and then
/// streamed by [`gemm_bias_packed`] / [`gemm_bias_packed_cols`].
#[derive(Clone, Debug)]
pub struct PackedWeights {
    k: usize,
    n: usize,
    /// `panels * k * NR` floats, panel-major.
    data: Vec<f32>,
}

impl PackedWeights {
    /// Pack row-major B (k, n) into NR-wide panels. This is the only
    /// O(k·n) copy the packed path ever performs.
    pub fn pack(b: &[f32], k: usize, n: usize) -> Self {
        debug_assert_eq!(b.len(), k * n);
        let panels = n.div_ceil(NR);
        let mut data = vec![0.0f32; panels * k * NR];
        for p in 0..panels {
            let j0 = p * NR;
            let w = NR.min(n - j0);
            let panel = &mut data[p * k * NR..(p + 1) * k * NR];
            for kk in 0..k {
                panel[kk * NR..kk * NR + w].copy_from_slice(&b[kk * n + j0..kk * n + j0 + w]);
            }
        }
        Self { k, n, data }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Packed footprint in bytes (the memory cost of the layout).
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }

    #[inline]
    fn panel(&self, p: usize) -> &[f32] {
        &self.data[p * self.k * NR..(p + 1) * self.k * NR]
    }
}

/// C(m, n) = phi(A(m, k)·B + bias), B pre-packed; C overwritten by the
/// single fused write-back (no zero-fill, no separate epilogue sweep).
pub fn gemm_bias_packed(
    a: &[f32],
    bp: &PackedWeights,
    bias: Option<&[f32]>,
    c: &mut [f32],
    m: usize,
    epilogue: Epilogue,
) {
    debug_assert_eq!(a.len(), m * bp.k);
    debug_assert_eq!(c.len(), m * bp.n);
    gemm_bias_packed_cols(a, bp, 0, bp.n, bias, c, bp.n, m, epilogue);
}

/// Column-slice variant: C[:, 0..width] = phi(A·B[:, col0..col0+width] +
/// bias), writing a (m, c_stride) row-major buffer (`c_stride >= width`).
/// `col0` must be panel-aligned (`col0 % NR == 0`) so the slice is a
/// contiguous panel run; `bias` is pre-sliced to `width`. Split-mode
/// (bN-wide) column tiles call this against the one packed copy of the
/// full weight matrix.
#[allow(clippy::too_many_arguments)]
pub fn gemm_bias_packed_cols(
    a: &[f32],
    bp: &PackedWeights,
    col0: usize,
    width: usize,
    bias: Option<&[f32]>,
    c: &mut [f32],
    c_stride: usize,
    m: usize,
    epilogue: Epilogue,
) {
    debug_assert_eq!(col0 % NR, 0, "column slice must start on a panel boundary");
    debug_assert!(col0 + width <= bp.n);
    debug_assert!(c_stride >= width);
    debug_assert!(a.len() >= m * bp.k);
    debug_assert!(c.len() >= m.saturating_sub(1) * c_stride + width || m == 0);
    if let Some(bv) = bias {
        debug_assert!(bv.len() >= width);
    }
    let k = bp.k;
    let p_start = col0 / NR;
    let p_end = (col0 + width).div_ceil(NR);
    let mut i = 0;
    while i < m {
        let rows = MR.min(m - i);
        for p in p_start..p_end {
            let jbase = p * NR - col0;
            let ncols = NR.min(width - jbase);
            let panel = bp.panel(p);
            // Full-K register accumulation: the (MR, NR) accumulator
            // lives in registers across every K chunk, so C sees exactly
            // one store per element (the fused write-back below).
            let mut acc = [[0.0f32; NR]; MR];
            if rows == MR {
                packed_micro_full(a, k, i, panel, &mut acc);
            } else {
                packed_micro_edge(a, k, i, rows, panel, &mut acc);
            }
            for (r, accr) in acc.iter().enumerate().take(rows) {
                let row0 = (i + r) * c_stride + jbase;
                let crow = &mut c[row0..row0 + ncols];
                for (x, cv) in crow.iter_mut().enumerate() {
                    let mut v = accr[x];
                    if let Some(bv) = bias {
                        v += bv[jbase + x];
                    }
                    if epilogue == Epilogue::Relu && v < 0.0 {
                        v = 0.0;
                    }
                    *cv = v;
                }
            }
        }
        i += MR;
    }
}

/// Full MR-row micro-kernel over one packed panel: streams the panel
/// top-to-bottom in KC-sized chunks (pure locality; the k-ascending
/// accumulation order — and hence every output bit — is unchanged).
#[inline]
fn packed_micro_full(a: &[f32], k: usize, i: usize, panel: &[f32], acc: &mut [[f32; NR]; MR]) {
    let mut k0 = 0;
    while k0 < k {
        let kb = KC.min(k - k0);
        for kk in k0..k0 + kb {
            let brow = &panel[kk * NR..kk * NR + NR];
            for (r, accr) in acc.iter_mut().enumerate() {
                let av = a[(i + r) * k + kk];
                for (x, &bv) in accr.iter_mut().zip(brow) {
                    *x += av * bv;
                }
            }
        }
        k0 += kb;
    }
}

/// m-edge micro-kernel: same NR-wide vectorized lanes, shortened row
/// loop (the "pad-into-panel" counterpart for partial MR tiles — B's
/// n-edge padding already lives in the packed panel itself).
#[inline]
fn packed_micro_edge(
    a: &[f32],
    k: usize,
    i: usize,
    rows: usize,
    panel: &[f32],
    acc: &mut [[f32; NR]; MR],
) {
    let mut k0 = 0;
    while k0 < k {
        let kb = KC.min(k - k0);
        for kk in k0..k0 + kb {
            let brow = &panel[kk * NR..kk * NR + NR];
            for (r, accr) in acc.iter_mut().enumerate().take(rows) {
                let av = a[(i + r) * k + kk];
                for (x, &bv) in accr.iter_mut().zip(brow) {
                    *x += av * bv;
                }
            }
        }
        k0 += kb;
    }
}

/// Expert FFN over a row block on pre-packed weights:
/// relu(x·W1 + b1)·W2 + b2 with both GEMMs on the packed hot path.
#[allow(clippy::too_many_arguments)]
pub fn ffn_packed(
    x: &[f32],
    w1: &PackedWeights,
    b1: &[f32],
    w2: &PackedWeights,
    b2: &[f32],
    out: &mut [f32],
    scratch: &mut [f32],
    rows: usize,
    h: usize,
    d: usize,
) {
    debug_assert_eq!((w1.k, w1.n), (h, d));
    debug_assert_eq!((w2.k, w2.n), (d, h));
    debug_assert!(scratch.len() >= rows * d);
    gemm_bias_packed(x, w1, Some(b1), &mut scratch[..rows * d], rows, Epilogue::Relu);
    gemm_bias_packed(&scratch[..rows * d], w2, Some(b2), out, rows, Epilogue::Identity);
}

/// Expert FFN over a row block: relu(x·W1 + b1)·W2 + b2, returning (rows, h).
/// `scratch` must hold rows*d floats (the caller reuses it across tasks to
/// keep the hot path allocation-free).
pub fn ffn(
    x: &[f32],
    w1: &[f32],
    b1: &[f32],
    w2: &[f32],
    b2: &[f32],
    out: &mut [f32],
    scratch: &mut [f32],
    rows: usize,
    h: usize,
    d: usize,
) {
    debug_assert!(scratch.len() >= rows * d);
    gemm_bias(x, w1, Some(b1), &mut scratch[..rows * d], rows, h, d, Epilogue::Relu);
    gemm_bias(&scratch[..rows * d], w2, Some(b2), out, rows, d, h, Epilogue::Identity);
}

/// Combine task t3: out[r] += scale[r] * x[r] over (rows, h) tiles.
pub fn combine_accumulate(out: &mut [f32], x: &[f32], scale: &[f32], rows: usize, h: usize) {
    debug_assert_eq!(x.len(), rows * h);
    debug_assert!(scale.len() >= rows);
    for r in 0..rows {
        let s = scale[r];
        if s == 0.0 {
            continue;
        }
        let orow = &mut out[r * h..(r + 1) * h];
        let xrow = &x[r * h..(r + 1) * h];
        for (o, &v) in orow.iter_mut().zip(xrow) {
            *o += s * v;
        }
    }
}

/// Naive reference GEMM (tests compare blocked vs naive).
pub fn gemm_naive(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for p in 0..k {
                acc += a[i * k + p] * b[p * n + j];
            }
            c[i * n + j] = acc;
        }
    }
}

// ---------------------------------------------------------------------------
// Transposed kernels for the backward pass
// ---------------------------------------------------------------------------
//
// The training subsystem (`crate::train`, `rank.rs` dgrad/wgrad tasks)
// needs two transposed products:
//
// * `A·Bᵀ` — dgrad: grads flow back through a row-major weight matrix
//   without materializing its transpose (`dMid = dY·W2ᵀ`, `dX = dMid·W1ᵀ`).
// * `Aᵀ·B` accumulated — wgrad: `dW += Xᵀ·dMid` folded over tiles.
//
// Both keep the same bitwise contract as the forward kernels: per output
// element, multiply-adds happen in one fixed order (ascending k for
// `A·Bᵀ`; ascending row for the `Aᵀ·B` fold), so results are independent
// of processor count and steal schedule, and each blocked kernel equals
// its naive twin exactly. Wgrad determinism additionally relies on the
// *caller* fixing the tile fold order (see `WgradFold` in `rank.rs`).

/// Lane width of the `gemm_a_bt` j-block: JB independent scalar
/// accumulators share one pass over A's row, each still summing its own
/// element in ascending-k order (locality without reassociation).
const JB: usize = 8;

/// C(m, n) = A(m, k) · B(n, k)ᵀ, row-major, C overwritten. Note B is
/// (n, k): its *rows* are the dot-product operands, so both operands of
/// every dot are contiguous and no transpose copy is ever made.
pub fn gemm_a_bt(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let mut j = 0;
        while j < n {
            let jb = JB.min(n - j);
            let mut acc = [0.0f32; JB];
            for (p, &av) in arow.iter().enumerate() {
                for (x, accx) in acc.iter_mut().enumerate().take(jb) {
                    *accx += av * b[(j + x) * k + p];
                }
            }
            c[i * n + j..i * n + j + jb].copy_from_slice(&acc[..jb]);
            j += jb;
        }
    }
}

/// Naive twin of [`gemm_a_bt`]; identical per-element ascending-k order,
/// so the pair must agree bitwise (asserted by the test suite).
pub fn gemm_a_bt_naive(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for p in 0..k {
                acc += a[i * k + p] * b[j * k + p];
            }
            c[i * n + j] = acc;
        }
    }
}

/// C(ka, nb) += A(m, ka)ᵀ · B(m, nb), row-major, streamed row-ascending:
/// row r of A and B contributes before row r+1, for every output element.
/// This is the wgrad fold primitive — because the accumulation order per
/// element is fixed (ascending r, on top of the incoming C), folding a
/// tile sequence in a fixed order yields bitwise-identical gradients
/// regardless of which processor ran which tile.
pub fn gemm_at_b_acc(a: &[f32], b: &[f32], c: &mut [f32], m: usize, ka: usize, nb: usize) {
    debug_assert_eq!(a.len(), m * ka);
    debug_assert_eq!(b.len(), m * nb);
    debug_assert_eq!(c.len(), ka * nb);
    for r in 0..m {
        let brow = &b[r * nb..(r + 1) * nb];
        for i in 0..ka {
            let av = a[r * ka + i];
            let crow = &mut c[i * nb..(i + 1) * nb];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// Naive twin of [`gemm_at_b_acc`]: same ascending-r per-element order,
/// accumulated in a register instead of memory (bitwise-equal either way).
pub fn gemm_at_b_acc_naive(a: &[f32], b: &[f32], c: &mut [f32], m: usize, ka: usize, nb: usize) {
    for i in 0..ka {
        for j in 0..nb {
            let mut acc = c[i * nb + j];
            for r in 0..m {
                acc += a[r * ka + i] * b[r * nb + j];
            }
            c[i * nb + j] = acc;
        }
    }
}

/// acc(n) += column sums of X(rows, n), row-ascending — the bias-gradient
/// fold (db += Σ_r dY[r, :]), same fixed-order contract as the wgrad fold.
pub fn colsum_acc(x: &[f32], acc: &mut [f32], rows: usize, n: usize) {
    debug_assert_eq!(x.len(), rows * n);
    debug_assert_eq!(acc.len(), n);
    for r in 0..rows {
        let xrow = &x[r * n..(r + 1) * n];
        for (av, &v) in acc.iter_mut().zip(xrow) {
            *av += v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::stats::max_abs_diff;

    fn rand_mat(rng: &mut Rng, n: usize) -> Vec<f32> {
        rng.normal_vec(n, 1.0)
    }

    #[test]
    fn blocked_matches_naive_over_shapes() {
        let mut rng = Rng::new(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (4, 8, 8), (17, 33, 9), (128, 64, 96), (65, 256, 31)] {
            let a = rand_mat(&mut rng, m * k);
            let b = rand_mat(&mut rng, k * n);
            let mut c0 = vec![0.0; m * n];
            let mut c1 = vec![0.0; m * n];
            gemm_naive(&a, &b, &mut c0, m, k, n);
            gemm_bias(&a, &b, None, &mut c1, m, k, n, Epilogue::Identity);
            assert!(max_abs_diff(&c0, &c1) < 1e-3, "({m},{k},{n})");
        }
    }

    #[test]
    fn bias_and_relu_epilogues() {
        let mut rng = Rng::new(2);
        let (m, k, n) = (8, 16, 8);
        let a = rand_mat(&mut rng, m * k);
        let b = rand_mat(&mut rng, k * n);
        let bias = rand_mat(&mut rng, n);
        let mut c = vec![0.0; m * n];
        gemm_bias(&a, &b, Some(&bias), &mut c, m, k, n, Epilogue::Relu);
        let mut want = vec![0.0; m * n];
        gemm_naive(&a, &b, &mut want, m, k, n);
        for i in 0..m {
            for j in 0..n {
                let v = (want[i * n + j] + bias[j]).max(0.0);
                assert!((c[i * n + j] - v).abs() < 1e-3);
            }
        }
        assert!(c.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn ffn_matches_composition() {
        let mut rng = Rng::new(3);
        let (rows, h, d) = (32, 24, 40);
        let x = rand_mat(&mut rng, rows * h);
        let w1 = rand_mat(&mut rng, h * d);
        let b1 = rand_mat(&mut rng, d);
        let w2 = rand_mat(&mut rng, d * h);
        let b2 = rand_mat(&mut rng, h);
        let mut out = vec![0.0; rows * h];
        let mut scratch = vec![0.0; rows * d];
        ffn(&x, &w1, &b1, &w2, &b2, &mut out, &mut scratch, rows, h, d);
        // compose manually
        let mut mid = vec![0.0; rows * d];
        gemm_bias(&x, &w1, Some(&b1), &mut mid, rows, h, d, Epilogue::Relu);
        let mut want = vec![0.0; rows * h];
        gemm_bias(&mid, &w2, Some(&b2), &mut want, rows, d, h, Epilogue::Identity);
        assert_eq!(out, want);
    }

    #[test]
    fn packed_matches_naive_bitwise_over_shapes() {
        // the packed kernel must replay the naive k-ascending accumulation
        // order per element, so equality is exact — not within-tolerance
        let mut rng = Rng::new(4);
        for &(m, k, n) in &[
            (1, 1, 1),
            (3, 5, 7),        // everything sub-tile
            (8, 16, 16),      // exact MR/NR multiples
            (17, 33, 9),      // m- and n-edges
            (65, 300, 31),    // k crosses a KC chunk boundary
            (128, 64, 96),
        ] {
            let a = rand_mat(&mut rng, m * k);
            let b = rand_mat(&mut rng, k * n);
            let bp = PackedWeights::pack(&b, k, n);
            assert_eq!((bp.k(), bp.n()), (k, n));
            let mut want = vec![0.0; m * n];
            gemm_naive(&a, &b, &mut want, m, k, n);
            // poison C: the packed write-back must fully overwrite it
            let mut got = vec![f32::NAN; m * n];
            gemm_bias_packed(&a, &bp, None, &mut got, m, Epilogue::Identity);
            assert_eq!(got, want, "({m},{k},{n})");
        }
    }

    #[test]
    fn packed_fused_epilogue_matches_reference() {
        let mut rng = Rng::new(5);
        let (m, k, n) = (13, 40, 27); // deliberate edge tiles
        let a = rand_mat(&mut rng, m * k);
        let b = rand_mat(&mut rng, k * n);
        let bias = rand_mat(&mut rng, n);
        let bp = PackedWeights::pack(&b, k, n);
        let mut got = vec![f32::NAN; m * n];
        gemm_bias_packed(&a, &bp, Some(&bias), &mut got, m, Epilogue::Relu);
        let mut want = vec![0.0; m * n];
        gemm_naive(&a, &b, &mut want, m, k, n);
        for i in 0..m {
            for j in 0..n {
                let v = {
                    let mut v = want[i * n + j] + bias[j];
                    if v < 0.0 {
                        v = 0.0;
                    }
                    v
                };
                assert_eq!(got[i * n + j], v, "({i},{j})");
            }
        }
    }

    #[test]
    fn packed_column_slices_match_full_result() {
        // a bN-wide slice (panel-aligned) of the packed matrix must equal
        // the corresponding columns of the full packed GEMM, written into
        // a tile buffer with its own stride
        let mut rng = Rng::new(6);
        let (m, k, n, bn) = (20, 50, 64, 32); // bn % NR == 0
        let a = rand_mat(&mut rng, m * k);
        let b = rand_mat(&mut rng, k * n);
        let bias = rand_mat(&mut rng, n);
        let bp = PackedWeights::pack(&b, k, n);
        let mut full = vec![0.0; m * n];
        gemm_bias_packed(&a, &bp, Some(&bias), &mut full, m, Epilogue::Relu);
        for col in 0..n / bn {
            let mut tile = vec![f32::NAN; m * bn];
            gemm_bias_packed_cols(
                &a,
                &bp,
                col * bn,
                bn,
                Some(&bias[col * bn..(col + 1) * bn]),
                &mut tile,
                bn,
                m,
                Epilogue::Relu,
            );
            for r in 0..m {
                assert_eq!(
                    &tile[r * bn..(r + 1) * bn],
                    &full[r * n + col * bn..r * n + (col + 1) * bn],
                    "col tile {col}, row {r}"
                );
            }
        }
    }

    #[test]
    fn ffn_packed_matches_unpacked_composition() {
        let mut rng = Rng::new(7);
        let (rows, h, d) = (19, 24, 40); // row edge
        let x = rand_mat(&mut rng, rows * h);
        let w1 = rand_mat(&mut rng, h * d);
        let b1 = rand_mat(&mut rng, d);
        let w2 = rand_mat(&mut rng, d * h);
        let b2 = rand_mat(&mut rng, h);
        let w1p = PackedWeights::pack(&w1, h, d);
        let w2p = PackedWeights::pack(&w2, d, h);
        let mut got = vec![0.0; rows * h];
        let mut scratch = vec![0.0; rows * d];
        ffn_packed(&x, &w1p, &b1, &w2p, &b2, &mut got, &mut scratch, rows, h, d);
        // reference composition via the naive kernel + explicit epilogues
        let mut mid = vec![0.0; rows * d];
        gemm_naive(&x, &w1, &mut mid, rows, h, d);
        for r in 0..rows {
            for j in 0..d {
                mid[r * d + j] = (mid[r * d + j] + b1[j]).max(0.0);
            }
        }
        let mut want = vec![0.0; rows * h];
        gemm_naive(&mid, &w2, &mut want, rows, d, h);
        for r in 0..rows {
            for j in 0..h {
                want[r * h + j] += b2[j];
            }
        }
        assert_eq!(got, want);
    }

    #[test]
    fn packing_pads_the_last_panel_with_zeros() {
        let (k, n) = (3, 5); // one partial panel
        let b: Vec<f32> = (0..k * n).map(|i| i as f32 + 1.0).collect();
        let bp = PackedWeights::pack(&b, k, n);
        assert_eq!(bp.bytes(), k * NR * 4, "one NR-wide panel");
        // a GEMM against an all-ones A must ignore the padded lanes
        let a = vec![1.0f32; k];
        let mut c = vec![f32::NAN; n];
        gemm_bias_packed(&a, &bp, None, &mut c, 1, Epilogue::Identity);
        for j in 0..n {
            let want: f32 = (0..k).map(|p| b[p * n + j]).sum();
            assert_eq!(c[j], want);
        }
    }

    #[test]
    fn combine_accumulates_scaled_rows() {
        let mut out = vec![1.0f32; 2 * 3];
        let x = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        combine_accumulate(&mut out, &x, &[2.0, 0.0], 2, 3);
        assert_eq!(out, vec![3.0, 5.0, 7.0, 1.0, 1.0, 1.0]);
    }

    #[test]
    fn a_bt_matches_naive_bitwise_over_shapes() {
        // the blocked A·Bᵀ must replay the naive per-element ascending-k
        // order exactly (JB lanes are independent accumulators)
        let mut rng = Rng::new(8);
        for &(m, k, n) in &[
            (1, 1, 1),
            (3, 5, 7),     // everything sub-lane
            (8, 16, 16),   // exact lane multiples
            (17, 33, 9),   // m- and n-edges
            (65, 300, 31), // k crosses a KC chunk boundary
            (128, 64, 96),
        ] {
            let a = rand_mat(&mut rng, m * k);
            let b = rand_mat(&mut rng, n * k); // B is (n, k)
            let mut want = vec![0.0; m * n];
            gemm_a_bt_naive(&a, &b, &mut want, m, k, n);
            // poison C: the kernel must fully overwrite it
            let mut got = vec![f32::NAN; m * n];
            gemm_a_bt(&a, &b, &mut got, m, k, n);
            assert_eq!(got, want, "({m},{k},{n})");
        }
    }

    #[test]
    fn a_bt_is_the_transpose_of_forward_gemm() {
        // A·Bᵀ with B (n, k) must equal A·(Bᵀ) materialized through the
        // forward kernel (to tolerance — the reduction orders differ)
        let mut rng = Rng::new(9);
        let (m, k, n) = (13, 40, 27);
        let a = rand_mat(&mut rng, m * k);
        let b = rand_mat(&mut rng, n * k);
        let mut bt = vec![0.0; k * n];
        for r in 0..n {
            for p in 0..k {
                bt[p * n + r] = b[r * k + p];
            }
        }
        let mut want = vec![0.0; m * n];
        gemm_naive(&a, &bt, &mut want, m, k, n);
        let mut got = vec![f32::NAN; m * n];
        gemm_a_bt(&a, &b, &mut got, m, k, n);
        assert!(max_abs_diff(&got, &want) < 1e-3);
    }

    #[test]
    fn at_b_acc_matches_naive_bitwise_and_accumulates() {
        let mut rng = Rng::new(10);
        for &(m, ka, nb) in &[(1, 1, 1), (3, 5, 7), (8, 16, 16), (17, 33, 9), (65, 30, 31)] {
            let a = rand_mat(&mut rng, m * ka);
            let b = rand_mat(&mut rng, m * nb);
            let init = rand_mat(&mut rng, ka * nb); // += on top of prior grads
            let mut want = init.clone();
            gemm_at_b_acc_naive(&a, &b, &mut want, m, ka, nb);
            let mut got = init.clone();
            gemm_at_b_acc(&a, &b, &mut got, m, ka, nb);
            assert_eq!(got, want, "({m},{ka},{nb})");
        }
    }

    #[test]
    fn at_b_acc_is_the_transposed_product() {
        let mut rng = Rng::new(11);
        let (m, ka, nb) = (19, 12, 23);
        let a = rand_mat(&mut rng, m * ka);
        let b = rand_mat(&mut rng, m * nb);
        let mut at = vec![0.0; ka * m];
        for r in 0..m {
            for i in 0..ka {
                at[i * m + r] = a[r * ka + i];
            }
        }
        let mut want = vec![0.0; ka * nb];
        gemm_naive(&at, &b, &mut want, ka, m, nb);
        let mut got = vec![0.0; ka * nb];
        gemm_at_b_acc(&a, &b, &mut got, m, ka, nb);
        assert!(max_abs_diff(&got, &want) < 1e-3);
    }

    #[test]
    fn colsum_accumulates_row_ascending() {
        let x = vec![1.0, 2.0, 3.0, 10.0, 20.0, 30.0];
        let mut acc = vec![0.5f32; 3];
        colsum_acc(&x, &mut acc, 2, 3);
        assert_eq!(acc, vec![11.5, 22.5, 33.5]);
    }
}
