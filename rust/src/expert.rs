//! Expert parameter store: deterministic initialization and per-rank
//! ownership of the 3-D expert weight tensor X ∈ R^{E×H×D} (plus the
//! second GEMM's weights and biases, and the shared gate matrix).
//!
//! Weights are generated from a seeded PRNG stream keyed by expert id so
//! any rank (or the monolithic PJRT reference) can reproduce any expert's
//! parameters without communication — the multi-rank coordinator and the
//! single-shot oracle see bit-identical weights.

use crate::config::Config;
use crate::gemm::PackedWeights;
use crate::util::prng::Rng;

/// Parameters of a single expert FFN.
#[derive(Clone, Debug)]
pub struct ExpertParams {
    pub w1: Vec<f32>, // (H, D) row-major
    pub b1: Vec<f32>, // (D,)
    pub w2: Vec<f32>, // (D, H) row-major
    pub b2: Vec<f32>, // (H,)
}

/// One expert's weights in the packed persistent-GEMM layout (see
/// `gemm.rs`): W1 and W2 re-laid into NR-wide contiguous panels, biases
/// carried alongside. Built once per engine lifetime — expert weights
/// are static across passes — and reused by every FFN/GEMM task.
#[derive(Clone, Debug)]
pub struct PackedExpert {
    pub w1: PackedWeights, // (H, D) panel-packed
    pub b1: Vec<f32>,
    pub w2: PackedWeights, // (D, H) panel-packed
    pub b2: Vec<f32>,
}

impl PackedExpert {
    /// Packed footprint in bytes (weights only; biases are tiny).
    pub fn bytes(&self) -> usize {
        self.w1.bytes() + self.w2.bytes()
    }
}

impl ExpertParams {
    /// Unpacked parameter footprint in bytes (f32 weights + biases) —
    /// the wire cost `MoeEngine::rebalance` books per replica install
    /// when a hot expert's weights are copied onto a new host rank.
    pub fn size_bytes(&self) -> usize {
        (self.w1.len() + self.b1.len() + self.w2.len() + self.b2.len())
            * std::mem::size_of::<f32>()
    }

    /// Pack this expert for the persistent hot path. One call per expert
    /// per engine lifetime; the backend's pack counter audits that no
    /// steady-state pass ever re-packs.
    pub fn pack(&self, h: usize, d: usize) -> PackedExpert {
        PackedExpert {
            w1: PackedWeights::pack(&self.w1, h, d),
            b1: self.b1.clone(),
            w2: PackedWeights::pack(&self.w2, d, h),
            b2: self.b2.clone(),
        }
    }
}

/// All model parameters; `experts[e]` is global expert e.
#[derive(Clone, Debug)]
pub struct ModelParams {
    pub wg: Vec<f32>, // (H, E) row-major
    pub experts: Vec<ExpertParams>,
    pub h: usize,
    pub d: usize,
}

/// Weight init scale (≈ Xavier for the default shapes; the exact value is
/// irrelevant to correctness, it only keeps activations O(1)).
const INIT_STD: f32 = 0.1;

impl ModelParams {
    /// Deterministically generate all parameters from `seed`.
    pub fn generate(cfg: &Config, seed: u64) -> Self {
        let (h, d, e) = (cfg.model.h, cfg.model.d, cfg.model.e);
        let base = Rng::new(seed);
        let mut gate_rng = base.fork(0xFFFF_0000);
        let wg = gate_rng.normal_vec(h * e, 1.0);
        let experts = (0..e)
            .map(|ex| {
                let mut r = base.fork(ex as u64 + 1);
                ExpertParams {
                    w1: r.normal_vec(h * d, INIT_STD),
                    b1: r.normal_vec(d, INIT_STD),
                    w2: r.normal_vec(d * h, INIT_STD),
                    b2: r.normal_vec(h, INIT_STD),
                }
            })
            .collect();
        Self { wg, experts, h, d }
    }

    pub fn num_experts(&self) -> usize {
        self.experts.len()
    }

    /// Pack expert weights into the (E,H,D)/(E,D)/(E,D,H)/(E,H) flat
    /// tensors the monolithic `moe_layer` artifact takes as parameters.
    pub fn pack_for_artifact(&self) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut w1 = Vec::with_capacity(self.experts.len() * self.h * self.d);
        let mut b1 = Vec::with_capacity(self.experts.len() * self.d);
        let mut w2 = Vec::with_capacity(self.experts.len() * self.d * self.h);
        let mut b2 = Vec::with_capacity(self.experts.len() * self.h);
        for ex in &self.experts {
            w1.extend_from_slice(&ex.w1);
            b1.extend_from_slice(&ex.b1);
            w2.extend_from_slice(&ex.w2);
            b2.extend_from_slice(&ex.b2);
        }
        (w1, b1, w2, b2)
    }

    /// Parameter count (for README/Table-4-style reporting).
    pub fn num_params(&self) -> usize {
        self.wg.len()
            + self
                .experts
                .iter()
                .map(|e| e.w1.len() + e.b1.len() + e.w2.len() + e.b2.len())
                .sum::<usize>()
    }

    /// Resident bytes of the full parameter set at f32 — the unit of the
    /// multi-model registry's footprint accounting
    /// ([`ModelRegistry::resident_bytes`](crate::registry::ModelRegistry::resident_bytes)):
    /// a fresh base model costs this, a fingerprint dedup costs 0, a
    /// delta variant costs only
    /// [`DeltaSet::bytes`](crate::registry::DeltaSet::bytes).
    pub fn size_bytes(&self) -> usize {
        self.num_params() * std::mem::size_of::<f32>()
    }
}

/// Generate one rank's token matrix (S_r, H), keyed by rank so every rank
/// draws an independent, reproducible sequence.
pub fn generate_tokens(cfg: &Config, seed: u64, rank: usize) -> Vec<f32> {
    let mut rng = Rng::new(seed).fork(0xAAAA_0000 + rank as u64);
    rng.normal_vec(cfg.system.s_rank * cfg.model.h, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    #[test]
    fn generation_is_deterministic_and_expert_keyed() {
        let cfg = Config::preset("tiny").unwrap();
        let a = ModelParams::generate(&cfg, 7);
        let b = ModelParams::generate(&cfg, 7);
        assert_eq!(a.wg, b.wg);
        assert_eq!(a.experts[3].w1, b.experts[3].w1);
        let c = ModelParams::generate(&cfg, 8);
        assert_ne!(a.experts[0].w1, c.experts[0].w1);
        // experts differ from each other
        assert_ne!(a.experts[0].w1, a.experts[1].w1);
    }

    #[test]
    fn packing_layout_is_expert_major() {
        let cfg = Config::preset("tiny").unwrap();
        let p = ModelParams::generate(&cfg, 1);
        let (w1, b1, w2, b2) = p.pack_for_artifact();
        let (h, d, e) = (p.h, p.d, p.num_experts());
        assert_eq!(w1.len(), e * h * d);
        assert_eq!(b1.len(), e * d);
        assert_eq!(w2.len(), e * d * h);
        assert_eq!(b2.len(), e * h);
        assert_eq!(&w1[2 * h * d..2 * h * d + 5], &p.experts[2].w1[..5]);
    }

    #[test]
    fn token_streams_are_rank_keyed() {
        let cfg = Config::preset("tiny").unwrap();
        let t0 = generate_tokens(&cfg, 3, 0);
        let t1 = generate_tokens(&cfg, 3, 1);
        assert_eq!(t0.len(), cfg.system.s_rank * cfg.model.h);
        assert_ne!(t0, t1);
        assert_eq!(t0, generate_tokens(&cfg, 3, 0));
    }

    #[test]
    fn packed_expert_preserves_the_ffn_function() {
        let cfg = Config::preset("tiny").unwrap();
        let p = ModelParams::generate(&cfg, 5);
        let (h, d) = (p.h, p.d);
        let ex = &p.experts[1];
        let pe = ex.pack(h, d);
        assert_eq!((pe.w1.k(), pe.w1.n()), (h, d));
        assert_eq!((pe.w2.k(), pe.w2.n()), (d, h));
        assert!(pe.bytes() >= (h * d + d * h) * 4, "panels cover both matrices");
        let mut rng = Rng::new(9);
        let rows = 7; // deliberately not an MR multiple
        let x = rng.normal_vec(rows * h, 1.0);
        let mut packed_out = vec![0.0f32; rows * h];
        let mut unpacked_out = vec![0.0f32; rows * h];
        let mut scratch = vec![0.0f32; rows * d];
        crate::gemm::ffn_packed(
            &x, &pe.w1, &pe.b1, &pe.w2, &pe.b2, &mut packed_out, &mut scratch, rows, h, d,
        );
        crate::gemm::ffn(
            &x, &ex.w1, &ex.b1, &ex.w2, &ex.b2, &mut unpacked_out, &mut scratch, rows, h, d,
        );
        // tiny shapes fit one KC chunk, so the two paths even agree exactly
        let diff = crate::util::stats::max_abs_diff(&packed_out, &unpacked_out);
        assert!(diff < 1e-4, "packed FFN diverged from unpacked: {diff}");
    }

    #[test]
    fn param_count_matches_closed_form() {
        let cfg = Config::preset("tiny").unwrap();
        let p = ModelParams::generate(&cfg, 1);
        let (h, d, e) = (cfg.model.h, cfg.model.d, cfg.model.e);
        assert_eq!(p.num_params(), h * e + e * (h * d + d + d * h + h));
    }
}
