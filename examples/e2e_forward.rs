//! End-to-end validation driver (the DESIGN.md §5 "real-path" row): runs
//! the full three-layer system on a real workload and proves all layers
//! compose —
//!
//!   L1/L2: AOT Pallas kernels + the monolithic `moe_layer` JAX graph,
//!          executed via PJRT from Rust;
//!   L3:    the multi-rank persistent-kernel coordinator with one-sided
//!          dispatch/combine over the symmetric heap;
//!
//! and that the distributed result ≡ the monolithic reference ≡ the
//! bulk-synchronous baseline, while measuring latency/throughput/payload
//! against that baseline. Results are recorded in EXPERIMENTS.md.
//!
//!     make artifacts && cargo run --release --example e2e_forward

use std::sync::Arc;

use flashdmoe::coordinator::{baseline, MoeEngine, TaskGraphMode};
use flashdmoe::expert::{generate_tokens, ModelParams};
use flashdmoe::runtime::{ArtifactStore, ComputeBackend, NativeBackend, XlaBackend};
use flashdmoe::util::stats::{fmt_bytes, fmt_time, max_abs_diff, summarize, Table};

fn main() -> anyhow::Result<()> {
    let dir = ArtifactStore::default_dir();
    anyhow::ensure!(
        ArtifactStore::available(&dir),
        "artifacts missing — run `make artifacts` first"
    );
    let store = ArtifactStore::load(&dir, "default")?;
    let cfg = store.config.clone();
    println!(
        "e2e: H={} D={} E={} k={} | {} ranks x {} tokens | capacity {}",
        cfg.model.h, cfg.model.d, cfg.model.e, cfg.model.k,
        cfg.system.ranks, cfg.system.s_rank,
        cfg.model.capacity(cfg.system.s_rank)
    );

    let seed = 2026;
    let params = Arc::new(ModelParams::generate(&cfg, seed));
    let inputs: Vec<Vec<f32>> =
        (0..cfg.system.ranks).map(|r| generate_tokens(&cfg, seed, r)).collect();
    let a_all: Vec<f32> = inputs.concat();

    // ---- L2 reference: monolithic moe_layer artifact via PJRT -------------
    let t0 = std::time::Instant::now();
    let want = store.run_moe_layer(&a_all, &params)?;
    println!("monolithic PJRT reference: {}", fmt_time(t0.elapsed().as_secs_f64()));

    // ---- L3 distributed forward, every backend x mode combination ---------
    let native: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(&cfg));
    let xla: Arc<dyn ComputeBackend> = Arc::new(XlaBackend::new(store));
    let mut table = Table::new(&["configuration", "max |Δ| vs reference", "latency", "util", "payload saved"]);
    let mut flash_latency = f64::MAX;
    for (bname, backend) in [("native", native.clone()), ("xla", xla)] {
        for (mname, mode) in [("fused", TaskGraphMode::Fused), ("split", TaskGraphMode::Split)] {
            // launch once per configuration; the 5 timed passes below are
            // doorbell rings on the resident actors
            let engine = MoeEngine::start(cfg.clone(), params.clone(), backend.clone(), mode)?;
            let _ = engine.submit(&inputs)?.wait()?; // warmup
            let mut times = Vec::new();
            let mut last = None;
            for _ in 0..5 {
                let r = engine.submit(&inputs)?.wait()?;
                times.push(r.metrics.wall_secs);
                last = Some(r);
            }
            assert_eq!(engine.metrics().launches, 1, "one launch per engine lifetime");
            let r = last.unwrap();
            let got: Vec<f32> = r.outputs.concat();
            let err = max_abs_diff(&got, &want);
            anyhow::ensure!(err < 1e-3, "{bname}/{mname} diverged: {err}");
            let s = summarize(&times);
            if bname == "native" {
                flash_latency = flash_latency.min(s.p50);
            }
            table.row(&[
                format!("{bname}/{mname}"),
                format!("{err:.2e}"),
                fmt_time(s.p50),
                format!("{:.1}%", r.metrics.utilization() * 100.0),
                format!(
                    "{:.1}%",
                    r.metrics.ranks.iter().map(|x| x.payload_savings()).sum::<f64>()
                        / cfg.system.ranks as f64 * 100.0
                ),
            ]);
        }
    }

    // ---- bulk-synchronous baseline on the same substrate -------------------
    let mut times = Vec::new();
    let mut base = None;
    for _ in 0..5 {
        let b = baseline::forward_sequential(&cfg, &params, &native, &inputs)?;
        times.push(b.metrics.wall_secs);
        base = Some(b);
    }
    let base = base.unwrap();
    let got: Vec<f32> = base.outputs.concat();
    let err = max_abs_diff(&got, &want);
    let s = summarize(&times);
    table.row(&[
        "bulk-sync baseline".into(),
        format!("{err:.2e}"),
        fmt_time(s.p50),
        "-".into(),
        format!(
            "0.0% ({} launches, {} in barriers)",
            base.metrics.launches,
            fmt_time(base.metrics.barrier_secs)
        ),
    ]);
    println!("\n{}", table.render());

    let tokens = cfg.system.s_total();
    println!(
        "throughput (native/fused): {:.2} MTok/s | speedup vs bulk-sync: {:.2}x | wire bytes saved vs padded: {}",
        tokens as f64 / flash_latency / 1e6,
        s.p50 / flash_latency,
        fmt_bytes(
            (base.metrics.sent_rows - base.metrics.valid_rows) as f64
                * cfg.model.h as f64
                * cfg.system.wire.bytes() as f64
        )
    );
    println!("e2e OK — all layers compose, distributed ≡ monolithic reference");
    Ok(())
}
