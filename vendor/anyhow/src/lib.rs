//! Minimal, dependency-free implementation of the subset of the `anyhow`
//! API this workspace uses, vendored so the crate builds with no network
//! access. Covered surface: [`Error`], [`Result`], the [`anyhow!`],
//! [`bail!`] and [`ensure!`] macros, and the [`Context`] extension trait
//! for `Result` and `Option` (`context` / `with_context`).
//!
//! Semantics match upstream where it matters to callers:
//! * `{}` formats the outermost message; `{:#}` appends the cause chain
//!   separated by `": "`.
//! * `?` converts any `std::error::Error + Send + Sync + 'static` into
//!   [`Error`] (and `Error` itself intentionally does *not* implement
//!   `std::error::Error`, which is what makes that blanket `From` legal).

use std::fmt;

/// A dynamic error: an outermost message plus an optional cause chain.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

/// `anyhow::Result<T>` — `Result` with [`Error`] as the default error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// Iterate the chain of messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut items = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            items.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        items.into_iter()
    }

    /// The innermost error message in the chain.
    pub fn root_cause(&self) -> &str {
        self.chain().last().unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            let mut first = true;
            for m in self.chain() {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{m}")?;
                first = false;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let causes: Vec<&str> = self.chain().skip(1).collect();
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in causes.iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut messages = Vec::new();
        messages.push(e.to_string());
        let mut src = e.source();
        while let Some(s) = src {
            messages.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for m in messages.into_iter().rev() {
            err = Some(Error { msg: m, source: err.map(Box::new) });
        }
        err.expect("at least one message")
    }
}

/// Extension trait adding `context` / `with_context` to `Result`/`Option`.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_chains_and_alternate_formats() {
        let e: Error = Err::<(), _>(io_err()).context("reading manifest").unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: gone");
        assert_eq!(e.root_cause(), "gone");
    }

    #[test]
    fn macros_compose() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{}", f(5).unwrap_err()), "five is right out");
        let e = anyhow!("code {}", 7);
        assert_eq!(e.to_string(), "code 7");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| "missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
    }
}
