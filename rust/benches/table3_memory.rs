//! Table 3 — memory overhead of the symmetric tensor L + bookkeeping
//! (paper convention: token = 4KB, bM = 128, world = 8).
fn main() {
    let (text, reports) = flashdmoe::harness::table3();
    println!("{text}");
    let worst = reports.iter().map(|r| r.total()).fold(0.0, f64::max);
    println!("worst-case total: {:.2} MB (paper worst: 514.54 MB)", worst / (1024.0 * 1024.0));
}
