//! Multi-model residency example: one engine, three models, one launch.
//!
//! Registers an independent base model and a LoRA-style delta variant
//! next to the anchor on a running `MoeService`, serves a Zipf-skewed
//! multi-model request mix concurrently from client threads, and prints
//! the shared packed-weight-cache accounting: the co-resident footprint
//! vs what three dedicated engines would hold, and the delta variant's
//! incremental bytes vs a full independent pack.
//!
//!     cargo run --release --example multi_model
//!
//! Env knobs: `REQUESTS` (default 45), `RATE` req/s (default 300).

use std::sync::Arc;

use flashdmoe::config::Config;
use flashdmoe::coordinator::{BatchPolicy, MoeService, RequestOpts, TaskGraphMode};
use flashdmoe::expert::ModelParams;
use flashdmoe::registry::DeltaSet;
use flashdmoe::runtime::{ComputeBackend, NativeBackend};
use flashdmoe::util::prng::Rng;
use flashdmoe::util::stats::{fmt_bytes, fmt_time, summarize, Table};
use flashdmoe::workload::zipf_model_trace;

fn main() -> anyhow::Result<()> {
    let n_requests: usize =
        std::env::var("REQUESTS").ok().and_then(|v| v.parse().ok()).unwrap_or(45);
    let rate: f64 = std::env::var("RATE").ok().and_then(|v| v.parse().ok()).unwrap_or(300.0);

    let mut cfg = Config::preset("tiny")?;
    cfg.set("routing_policy", "dropless")?;
    cfg.set("max_models", "3")?; // anchor + 2 more resident slots
    cfg.validate()?;
    let anchor = Arc::new(ModelParams::generate(&cfg, 42));
    let base_b = Arc::new(ModelParams::generate(&cfg, 43));
    let delta = Arc::new(DeltaSet::generate(&cfg, 44, 2, 0.05));
    let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(&cfg));

    // Launch once; models register against the *running* service at
    // epoch-fenced quiet points — no relaunch, no repack of shared bytes.
    let policy = BatchPolicy::from_config(&cfg);
    let service = Arc::new(MoeService::start(
        cfg.clone(),
        anchor.clone(),
        backend,
        TaskGraphMode::Fused,
        policy,
    )?);
    let hb = service.register_model(base_b)?;
    let hl = service.register_delta(0, delta.clone())?;
    println!(
        "resident models: 0 anchor, {} independent base (+{}), {} LoRA variant of 0 (+{})",
        hb.id,
        fmt_bytes(hb.resident_bytes as f64),
        hl.id,
        fmt_bytes(hl.resident_bytes as f64),
    );

    // Zipf-skewed model mix (model 0 hottest), Poisson arrivals — served
    // concurrently from client threads through the one shared service.
    let h = cfg.model.h;
    let trace = zipf_model_trace(n_requests, rate, (8, 32), 3, 1.2, 7);
    let mut clients = Vec::new();
    let t0 = std::time::Instant::now();
    for line in trace.lines().skip(1) {
        let mut it = line.split_whitespace();
        let at: f64 = it.next().unwrap().parse()?;
        let rows: usize = it.next().unwrap().parse()?;
        let model: usize = it.next().unwrap().parse()?;
        let service = service.clone();
        let mut rng = Rng::new(at.to_bits() ^ rows as u64);
        clients.push(std::thread::spawn(move || -> anyhow::Result<(usize, f64)> {
            let due = std::time::Duration::from_secs_f64(at);
            if let Some(wait) = due.checked_sub(t0.elapsed()) {
                std::thread::sleep(wait);
            }
            let tokens = rng.normal_vec(rows * h, 1.0);
            let opts = RequestOpts { model, ..Default::default() };
            let res = service
                .enqueue(tokens, opts)
                .map_err(|e| anyhow::anyhow!("enqueue failed: {e}"))?
                .wait()?;
            Ok((model, res.latency_secs))
        }));
    }
    let mut lat: Vec<Vec<f64>> = vec![Vec::new(); 3];
    for c in clients {
        let (model, secs) = c.join().expect("client thread")?;
        lat[model].push(secs);
    }

    let mut t = Table::new(&["model", "kind", "requests", "p50", "p99"]);
    for (m, kind) in [(0, "anchor"), (1, "base"), (2, "lora")] {
        if lat[m].is_empty() {
            t.row(&[m.to_string(), kind.into(), "0".into(), "-".into(), "-".into()]);
            continue;
        }
        let s = summarize(&lat[m]);
        t.row(&[
            m.to_string(),
            kind.into(),
            lat[m].len().to_string(),
            fmt_time(s.p50),
            fmt_time(s.p99),
        ]);
    }
    println!("\n{}", t.render());

    // The memory story: shared packed cache vs dedicated engines.
    let full = anchor.size_bytes();
    let co = service.resident_bytes();
    println!("co-resident bytes:      {}", fmt_bytes(co as f64));
    println!("3 dedicated engines:    {}", fmt_bytes((3 * full) as f64));
    println!(
        "LoRA increment:         {} (vs {} for a full pack)",
        fmt_bytes(hl.resident_bytes as f64),
        fmt_bytes(full as f64)
    );
    anyhow::ensure!(hl.resident_bytes < full, "delta must undercut a full pack");

    let report = Arc::try_unwrap(service).ok().expect("all clients joined").shutdown();
    anyhow::ensure!(report.engine.launches == 1, "multi-model must not relaunch");
    anyhow::ensure!(
        report.service.requests_served == n_requests as u64,
        "served {} of {n_requests}",
        report.service.requests_served
    );
    println!(
        "\nserved {} requests across 3 models on {} launch ({} passes, {} registrations)",
        report.service.requests_served,
        report.engine.launches,
        report.service.passes,
        report.engine.model_registrations,
    );
    Ok(())
}
