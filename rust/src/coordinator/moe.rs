//! `DistributedMoE`: the original one-call operator API, kept as a thin
//! compatibility shim over the persistent [`MoeEngine`].
//!
//! Construction starts the engine (rank actors launched once);
//! [`DistributedMoE::forward`] is exactly `submit(inputs)?.wait()` — one
//! non-pipelined pass. New code should use [`MoeEngine`] directly to get
//! epoch-tagged, pipelined submission; this type exists so the original
//! call sites (and any downstream embedder of the old API) keep working
//! unchanged while inheriting the resident-actor fast path. Outputs are
//! identical to the engine API by construction (same actors, same pass
//! path, deterministic combine fold). The shim always serves the anchor
//! model (id 0); reach [`engine`](DistributedMoE::engine) for
//! multi-model registration and per-model passes.

use std::sync::Arc;

use anyhow::Result;

use crate::config::Config;
use crate::expert::ModelParams;
use crate::runtime::ComputeBackend;

pub use super::engine::{ForwardResult, MoeEngine, PassHandle};
pub use super::rank::TaskGraphMode;

/// The distributed MoE operator, one-call flavour. Construct once
/// (weights sliced, symmetric heap allocated, actors resident), call
/// [`forward`](Self::forward) per layer pass.
pub struct DistributedMoE {
    engine: MoeEngine,
}

impl DistributedMoE {
    pub fn new(
        cfg: Config,
        params: Arc<ModelParams>,
        backend: Arc<dyn ComputeBackend>,
        mode: TaskGraphMode,
    ) -> Result<Self> {
        Ok(Self { engine: MoeEngine::start(cfg, params, backend, mode)? })
    }

    pub fn config(&self) -> &Config {
        self.engine.config()
    }

    pub fn params(&self) -> Arc<ModelParams> {
        self.engine.params()
    }

    /// Bytes of the symmetric tensor L per rank (Table 3's Size(L)).
    pub fn heap_bytes_per_rank(&self) -> f64 {
        self.engine.heap_bytes_per_rank()
    }

    /// The persistent engine underneath, for callers migrating to the
    /// pipelined `submit`/`wait` API.
    pub fn engine(&self) -> &MoeEngine {
        &self.engine
    }

    /// One fused forward pass. `inputs[r]` is rank r's (S_r, H) tokens.
    /// Equivalent to `engine().submit(inputs)?.wait()`.
    pub fn forward(&self, inputs: &[Vec<f32>]) -> Result<ForwardResult> {
        self.engine.forward(inputs)
    }
}
