//! `MoeService` request-path tests: concurrent fuzzed end-to-end
//! conformance against the dense per-token reference, admission edge
//! cases (zero-token, ragged, oversize split/reject), backpressure
//! (reject and block), abandoned handles, and shutdown draining — plus
//! the service-lifetime single-launch contract.

use std::sync::Arc;

use flashdmoe::config::Config;
use flashdmoe::coordinator::{
    Backpressure, BatchPolicy, MoeService, OversizePolicy, RequestOpts, ServiceError,
    TaskGraphMode,
};
use flashdmoe::expert::ModelParams;
use flashdmoe::runtime::{ComputeBackend, NativeBackend};
use flashdmoe::util::check::dense_reference_moe;
use flashdmoe::util::prng::Rng;
use flashdmoe::util::stats::max_abs_diff;

/// Dropless tiny config: request outputs are independent of co-batching,
/// so every request must equal the dense per-token reference.
fn service_cfg() -> Config {
    let mut cfg = Config::preset("tiny").unwrap();
    cfg.set("routing_policy", "dropless").unwrap();
    cfg.validate().unwrap();
    cfg
}

fn start_service(cfg: &Config, seed: u64, policy: BatchPolicy) -> (MoeService, Arc<ModelParams>) {
    let params = Arc::new(ModelParams::generate(cfg, seed));
    let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(cfg));
    let svc = MoeService::start(cfg.clone(), params.clone(), backend, TaskGraphMode::Fused, policy)
        .unwrap();
    (svc, params)
}

#[test]
fn concurrent_fuzzed_requests_match_dense_reference_with_one_launch() {
    // Acceptance: N concurrent client threads enqueue fuzzed
    // variable-length requests; every output equals the dense per-token
    // reference to 1e-5, no request lost or duplicated, and the engine
    // launch count is 1 for the service lifetime.
    let cfg = service_cfg();
    let (svc, params) = start_service(&cfg, 42, BatchPolicy::from_config(&cfg));
    let svc = Arc::new(svc);
    let h = cfg.model.h;
    let threads = 4usize;
    let per_thread = 6usize;

    let mut clients = Vec::new();
    for t in 0..threads {
        let svc = svc.clone();
        let cfg = cfg.clone();
        let params = params.clone();
        clients.push(std::thread::spawn(move || {
            let mut rng = Rng::new(0xC11E27 ^ t as u64);
            let mut served = 0usize;
            for i in 0..per_thread {
                let rows = 1 + rng.below(96); // fuzzed variable length
                let tokens = rng.normal_vec(rows * h, 1.0);
                let handle = svc
                    .enqueue(tokens.clone(), RequestOpts::default())
                    .expect("enqueue within queue bounds");
                let res = handle.wait().expect("request served");
                assert_eq!(res.rows, rows, "client {t} request {i}: row count");
                assert_eq!(res.tokens.len(), rows * h, "client {t} request {i}: shape");
                let want = dense_reference_moe(&cfg, &params, &tokens);
                let diff = max_abs_diff(&res.tokens, &want);
                assert!(
                    diff < 1e-5,
                    "client {t} request {i} ({rows} rows): diverged from dense reference by {diff}"
                );
                assert!(res.latency_secs >= res.queue_secs);
                assert!(res.passes >= 1);
                served += 1;
            }
            served
        }));
    }
    let total: usize = clients.into_iter().map(|c| c.join().unwrap()).sum();
    assert_eq!(total, threads * per_thread, "no request lost");

    let report = Arc::try_unwrap(svc).ok().expect("all clients done").shutdown();
    assert_eq!(report.service.requests_served, (threads * per_thread) as u64, "none lost/dup");
    assert_eq!(report.service.requests_enqueued, (threads * per_thread) as u64);
    assert_eq!(report.engine.launches, 1, "one launch for the service lifetime");
    assert!(report.service.passes >= 1);
    assert!(report.service.mean_batch_fill() > 0.0);
}

#[test]
fn zero_token_and_ragged_requests_are_rejected() {
    let cfg = service_cfg();
    let (svc, _) = start_service(&cfg, 7, BatchPolicy::from_config(&cfg));
    assert_eq!(
        svc.enqueue(Vec::new(), RequestOpts::default()).err(),
        Some(ServiceError::EmptyRequest)
    );
    let h = cfg.model.h;
    assert_eq!(
        svc.enqueue(vec![0.0; h + 1], RequestOpts::default()).err(),
        Some(ServiceError::RaggedRequest { len: h + 1, h })
    );
    // the service still serves good requests afterwards
    let ok = svc.enqueue(vec![0.5; 2 * h], RequestOpts::default()).unwrap();
    assert_eq!(ok.wait().unwrap().rows, 2);
    let report = svc.shutdown();
    assert_eq!(report.service.requests_rejected, 2);
    assert_eq!(report.service.requests_served, 1);
}

#[test]
fn oversize_requests_split_across_passes_per_policy() {
    let cfg = service_cfg();
    let mut policy = BatchPolicy::from_config(&cfg);
    policy.max_tokens = 64; // force splitting well below one full pass
    let (svc, params) = start_service(&cfg, 11, policy);
    let h = cfg.model.h;
    let rows = 150; // ceil(150/64) = 3 chunks
    let tokens = Rng::new(9).normal_vec(rows * h, 1.0);
    let res = svc.enqueue(tokens.clone(), RequestOpts::default()).unwrap().wait().unwrap();
    assert_eq!(res.rows, rows);
    assert_eq!(res.passes, 3, "3 chunks => 3 passes");
    let want = dense_reference_moe(&cfg, &params, &tokens);
    let diff = max_abs_diff(&res.tokens, &want);
    assert!(diff < 1e-5, "split request diverged from dense reference by {diff}");
    let report = svc.shutdown();
    assert_eq!(report.service.requests_served, 1);
    assert!(report.service.passes >= 3);
    assert_eq!(report.engine.launches, 1);
}

#[test]
fn oversize_requests_rejected_per_policy() {
    let cfg = service_cfg();
    let mut policy = BatchPolicy::from_config(&cfg);
    policy.max_tokens = 32;
    policy.oversize = OversizePolicy::Reject;
    let (svc, _) = start_service(&cfg, 13, policy);
    let h = cfg.model.h;
    assert_eq!(
        svc.enqueue(vec![0.0; 33 * h], RequestOpts::default()).err(),
        Some(ServiceError::TooLarge { rows: 33, max_tokens: 32 })
    );
    // a request at exactly max_tokens is fine
    let ok = svc.enqueue(vec![0.25; 32 * h], RequestOpts::default()).unwrap();
    assert_eq!(ok.wait().unwrap().passes, 1);
    svc.shutdown();
}

#[test]
fn dropped_handles_do_not_wedge_the_batcher() {
    let cfg = service_cfg();
    let (svc, params) = start_service(&cfg, 17, BatchPolicy::from_config(&cfg));
    let h = cfg.model.h;
    // abandon a burst of handles: the batcher must discard or harmlessly
    // complete them and keep serving
    for i in 0..8 {
        let _ = svc.enqueue(vec![0.1 * (i as f32 + 1.0); 16 * h], RequestOpts::default()).unwrap();
        // handle dropped here, unwaited => cancelled
    }
    let tokens = Rng::new(21).normal_vec(5 * h, 1.0);
    let res = svc.enqueue(tokens.clone(), RequestOpts::default()).unwrap().wait().unwrap();
    let want = dense_reference_moe(&cfg, &params, &tokens);
    assert!(max_abs_diff(&res.tokens, &want) < 1e-5, "batcher wedged or corrupted by drops");
    let report = svc.shutdown();
    // every abandoned request was either discarded before admission
    // (cancelled) or already in flight and served-then-discarded
    assert_eq!(
        report.service.requests_cancelled + report.service.requests_served,
        9,
        "abandoned requests unaccounted for"
    );
    assert_eq!(report.engine.launches, 1);
}

#[test]
fn shutdown_drains_already_enqueued_requests() {
    let cfg = service_cfg();
    // a generous coalescing window, so requests are still queued (not yet
    // in a pass) when shutdown lands — drain must serve them anyway
    let mut policy = BatchPolicy::from_config(&cfg);
    policy.max_delay = std::time::Duration::from_millis(250);
    let (svc, params) = start_service(&cfg, 23, policy);
    let h = cfg.model.h;
    let mut wanted = Vec::new();
    let mut handles = Vec::new();
    let mut rng = Rng::new(31);
    for _ in 0..6 {
        let rows = 1 + rng.below(40);
        let tokens = rng.normal_vec(rows * h, 1.0);
        handles.push(svc.enqueue(tokens.clone(), RequestOpts::default()).unwrap());
        wanted.push(tokens);
    }
    let report = svc.shutdown(); // drains the queue before joining
    assert_eq!(report.service.requests_served, 6, "shutdown must drain, not drop");
    for (hdl, tokens) in handles.into_iter().zip(&wanted) {
        let res = hdl.wait().expect("drained request completes");
        let want = dense_reference_moe(&cfg, &params, tokens);
        assert!(max_abs_diff(&res.tokens, &want) < 1e-5);
    }
    // and post-shutdown admission refuses — exercised via a second
    // service whose handle survived shutdown is impossible; metrics above
    // already confirm the drain.
    assert_eq!(report.engine.launches, 1);
}

#[test]
fn bounded_queue_rejects_under_pressure_and_accounts_for_it() {
    let cfg = service_cfg();
    let mut policy = BatchPolicy::from_config(&cfg);
    policy.queue_requests = 1;
    policy.on_full = Backpressure::Reject;
    let (svc, _) = start_service(&cfg, 29, policy);
    let h = cfg.model.h;
    let mut accepted = Vec::new();
    let mut rejected = 0u64;
    for _ in 0..200 {
        match svc.enqueue(vec![0.5; 64 * h], RequestOpts::default()) {
            Ok(hdl) => accepted.push(hdl),
            Err(ServiceError::ServiceFull) => rejected += 1,
            Err(e) => panic!("unexpected admission error: {e}"),
        }
    }
    assert!(rejected > 0, "200 instant enqueues against a depth-1 queue must overflow");
    let n_accepted = accepted.len() as u64;
    for hdl in accepted {
        hdl.wait().unwrap(); // accepted requests are always served
    }
    let report = svc.shutdown();
    assert_eq!(report.service.requests_served, n_accepted);
    assert_eq!(report.service.requests_rejected, rejected, "rejection accounting");
    assert!(report.service.max_queue_depth <= 1);
}

#[test]
fn blocking_backpressure_serves_everything() {
    let cfg = service_cfg();
    let mut policy = BatchPolicy::from_config(&cfg);
    policy.queue_requests = 1;
    policy.on_full = Backpressure::Block;
    let (svc, _) = start_service(&cfg, 37, policy);
    let h = cfg.model.h;
    let svc = Arc::new(svc);
    // a consumer thread drains handles so the producer's blocking
    // enqueues always make progress
    let (tx, rx) = std::sync::mpsc::channel::<flashdmoe::coordinator::RequestHandle>();
    let consumer = std::thread::spawn(move || {
        let mut n = 0u64;
        while let Ok(hdl) = rx.recv() {
            hdl.wait().unwrap();
            n += 1;
        }
        n
    });
    for _ in 0..20 {
        let hdl = svc.enqueue(vec![1.0; 32 * h], RequestOpts::default()).unwrap();
        tx.send(hdl).unwrap();
    }
    drop(tx);
    assert_eq!(consumer.join().unwrap(), 20);
    let report = Arc::try_unwrap(svc).ok().unwrap().shutdown();
    assert_eq!(report.service.requests_served, 20);
    assert_eq!(report.service.requests_rejected, 0, "Block never rejects");
}

#[test]
fn priority_discipline_admits_high_priority_first() {
    use flashdmoe::coordinator::QueueDiscipline;
    let cfg = service_cfg();
    let mut policy = BatchPolicy::from_config(&cfg);
    policy.priority = QueueDiscipline::Priority;
    // a long coalescing window so both requests land in the same batch
    // regardless of arrival jitter; priority decides pack order
    policy.max_delay = std::time::Duration::from_millis(100);
    let (svc, _) = start_service(&cfg, 41, policy);
    let h = cfg.model.h;
    let lo_opts = RequestOpts { priority: 0, ..Default::default() };
    let hi_opts = RequestOpts { priority: 5, ..Default::default() };
    let low = svc.enqueue(vec![0.1; 8 * h], lo_opts).unwrap();
    let high = svc.enqueue(vec![0.9; 8 * h], hi_opts).unwrap();
    let (rl, rh) = (low.wait().unwrap(), high.wait().unwrap());
    // both served correctly; the high-priority request never queues
    // longer than the low one that arrived first
    assert!(rh.queue_secs <= rl.queue_secs + 1e-3);
    svc.shutdown();
}
