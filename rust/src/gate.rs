//! Gate: softmax top-k routing and the paper's routing tables.
//!
//! Produces `G_phi` (affinity scores, S×E) and `T_phi` (the routing table:
//! per (expert, capacity-slot) → (token, combine weight)), plus the
//! *payload-efficient dispatch plan* — the per-destination list of
//! non-empty tiles that actually travel (paper §1.1 "payload-efficient
//! communication": null-padded capacity slots never hit the wire).
//!
//! Numerics follow the contract in DESIGN.md §4 exactly (softmax with max
//! subtraction, ties to the lower expert index, token-order slot
//! assignment, drops beyond aligned capacity) so the Rust routing agrees
//! bit-for-tolerance with `ref.py` and the AOT `moe_layer` artifact.
//!
//! **Routing policy.** Under [`RoutingPolicy::Capacity`] the per-(source,
//! expert) buffer is fixed and over-capacity pairs are dropped, so a
//! skewed gate silently changes the computed function. Under
//! [`RoutingPolicy::Dropless`] (MegaBlocks-style dropless MoE via
//! variable-sized blocks) the caller passes the policy's worst-case
//! [`slot_capacity`](ModelConfig::slot_capacity) and no pair can ever
//! overflow: [`dispatch_plan`] builds a *variable-length* tile list per
//! expert sized to the actual routed counts — full bM tiles plus one
//! partially-filled tail tile, row counts carried in the signal flag —
//! so quality-preserving routing costs no padded traffic.
//!
//! [`RoutingPolicy::Capacity`]: crate::config::RoutingPolicy::Capacity
//! [`RoutingPolicy::Dropless`]: crate::config::RoutingPolicy::Dropless

use crate::config::ModelConfig;

/// One routed (token, expert) pair.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Route {
    /// Token index within the source rank's sequence.
    pub token: u32,
    /// Global expert id.
    pub expert: u32,
    /// Slot within the (source rank, expert) capacity buffer.
    pub slot: u32,
    /// Raw gate score g_{i,e}.
    pub weight: f32,
    /// Normalized combine weight g / C_i (drops included in C_i).
    pub combine_weight: f32,
}

/// Routing output for one rank's tokens.
#[derive(Clone, Debug)]
pub struct Routing {
    /// Gate scores G_phi, row-major (S, E).
    pub scores: Vec<f32>,
    /// Top-k expert ids per token, row-major (S, k).
    pub topk_idx: Vec<u32>,
    /// Top-k raw weights per token, row-major (S, k).
    pub topk_w: Vec<f32>,
    /// Kept (non-dropped) routes, in token-major / k-minor arrival order.
    pub routes: Vec<Route>,
    /// Number of dropped (over-capacity) pairs.
    pub dropped: usize,
    /// Tokens routed to each expert (kept only), length E.
    pub expert_load: Vec<u32>,
    pub s: usize,
    pub e: usize,
    pub k: usize,
    pub capacity: usize,
}

/// Row softmax with max subtraction over logits (S, E), in place.
pub fn softmax_rows(logits: &mut [f32], e: usize) {
    debug_assert_eq!(logits.len() % e, 0);
    for row in logits.chunks_mut(e) {
        let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// Top-k per row: descending score, ties broken toward the lower index
/// (matches `jax.lax.top_k`). Returns (indices, weights) both (S, k).
pub fn topk_rows(scores: &[f32], e: usize, k: usize) -> (Vec<u32>, Vec<f32>) {
    let s = scores.len() / e;
    let mut idx = Vec::with_capacity(s * k);
    let mut w = Vec::with_capacity(s * k);
    let mut order: Vec<u32> = Vec::with_capacity(e);
    for row in scores.chunks(e) {
        order.clear();
        order.extend(0..e as u32);
        // stable selection of the k best: full sort is fine, E <= 128
        order.sort_by(|&a, &b| {
            row[b as usize]
                .partial_cmp(&row[a as usize])
                .unwrap()
                .then(a.cmp(&b))
        });
        for j in 0..k {
            idx.push(order[j]);
            w.push(row[order[j] as usize]);
        }
    }
    (idx, w)
}

/// Full gate for one rank: logits = A·Wg (row-major A: (S,H), Wg: (H,E)),
/// softmax, top-k, capacity slotting and drop accounting.
///
/// When the caller already has scores (e.g. computed by the AOT gate
/// artifact on the PJRT runtime), use [`route_from_scores`] instead.
pub fn gate_and_route(
    a: &[f32],
    wg: &[f32],
    s: usize,
    model: &ModelConfig,
    capacity: usize,
) -> Routing {
    let (h, e) = (model.h, model.e);
    debug_assert_eq!(a.len(), s * h);
    debug_assert_eq!(wg.len(), h * e);
    let mut logits = vec![0.0f32; s * e];
    // (S,H)x(H,E): E is small; simple loop ordering ikj for locality
    for i in 0..s {
        let ai = &a[i * h..(i + 1) * h];
        let li = &mut logits[i * e..(i + 1) * e];
        for (kk, &av) in ai.iter().enumerate() {
            let wrow = &wg[kk * e..(kk + 1) * e];
            for j in 0..e {
                li[j] += av * wrow[j];
            }
        }
    }
    softmax_rows(&mut logits, e);
    route_from_scores(logits, s, model, capacity)
}

/// Routing from precomputed softmax scores (S, E).
///
/// `s` is the *actual* row count of the pass — under the engine's
/// variable-shape `PassInput` path a rank may gate any `0..=s_rank`
/// rows (zero included: an expert-only rank routes nothing and the
/// result is an empty, drop-free routing). Capacity buffers are sized
/// by the caller from the static worst case, so fewer rows can only
/// mean fewer drops.
pub fn route_from_scores(
    scores: Vec<f32>,
    s: usize,
    model: &ModelConfig,
    capacity: usize,
) -> Routing {
    let (e, k) = (model.e, model.k);
    let (topk_idx, topk_w) = topk_rows(&scores, e, k);
    let mut counts = vec![0u32; e];
    let mut routes = Vec::with_capacity(s * k);
    let mut dropped = 0usize;
    for i in 0..s {
        let denom: f32 = topk_w[i * k..(i + 1) * k].iter().sum();
        for j in 0..k {
            let expert = topk_idx[i * k + j];
            let weight = topk_w[i * k + j];
            let c = counts[expert as usize];
            if (c as usize) < capacity {
                counts[expert as usize] = c + 1;
                routes.push(Route {
                    token: i as u32,
                    expert,
                    slot: c,
                    weight,
                    combine_weight: weight / denom,
                });
            } else {
                dropped += 1;
            }
        }
    }
    Routing {
        scores,
        topk_idx,
        topk_w,
        routes,
        dropped,
        expert_load: counts,
        s,
        e,
        k,
        capacity,
    }
}

/// A contiguous tile of capacity slots destined for one expert — the unit
/// of payload-efficient dispatch. Only tiles with `rows > 0` travel.
#[derive(Clone, Debug, PartialEq)]
pub struct DispatchTile {
    /// Global expert id.
    pub expert: u32,
    /// Destination rank (owner of `expert`).
    pub dst: u32,
    /// Tile index within the (rank, expert) capacity buffer (slot / bM).
    pub tile: u32,
    /// Valid rows in this tile (1..=bM); the rest is *in-place* padding on
    /// the receiver — it never hits the wire.
    pub rows: u32,
    /// Token ids (within the source rank) occupying rows 0..rows.
    pub tokens: Vec<u32>,
    /// Normalized combine weight g/C_i per row (the T_phi payload the
    /// combine round applies when this tile's result returns).
    pub weights: Vec<f32>,
}

/// The per-rank dispatch plan: the exact set of tiles that travel.
#[derive(Clone, Debug)]
pub struct DispatchPlan {
    pub tiles: Vec<DispatchTile>,
    /// Bytes that would travel under padded (capacity-sized) dispatch.
    pub padded_rows: usize,
    /// Valid rows actually sent.
    pub sent_rows: usize,
}

impl DispatchPlan {
    /// Payload efficiency: fraction of padded traffic avoided.
    pub fn savings(&self) -> f64 {
        if self.padded_rows == 0 {
            return 0.0;
        }
        1.0 - self.sent_rows as f64 / self.padded_rows as f64
    }
}

/// Build the dispatch plan from a routing table. `owner_of(e)` maps a
/// global expert to its owning rank; `bm` is the tile height.
///
/// The tile list is **variable-length per expert**: slots are assigned
/// densely in arrival order (0..load), so expert `e`'s tiles are exactly
/// `ceil(load_e / bM)` chunks — every tile full except a possibly
/// partially-filled tail, whose row count travels in the signal flag.
/// Nothing here assumes the fixed `capacity / bM` tile count of the
/// Capacity policy, which is what makes the same plan builder serve
/// `Dropless` routing unchanged. Experts with zero routed tokens produce
/// no traffic at all (payload efficiency).
pub fn dispatch_plan(
    routing: &Routing,
    bm: usize,
    owner_of: impl Fn(usize) -> usize,
) -> DispatchPlan {
    let e = routing.e;
    let mut tiles: Vec<DispatchTile> = Vec::new();
    // group routes by expert; routes are already slot-ordered per expert
    // because slots are assigned densely in arrival order.
    let mut by_expert: Vec<Vec<&Route>> = vec![Vec::new(); e];
    for r in &routing.routes {
        by_expert[r.expert as usize].push(r);
    }
    let mut sent_rows = 0usize;
    for (ex, rs) in by_expert.iter().enumerate() {
        if rs.is_empty() {
            continue; // payload efficiency: inactive expert, no traffic
        }
        for (t, chunk) in rs.chunks(bm).enumerate() {
            debug_assert_eq!(chunk[0].slot as usize, t * bm, "slots dense per expert");
            let tokens: Vec<u32> = chunk.iter().map(|r| r.token).collect();
            let weights: Vec<f32> = chunk.iter().map(|r| r.combine_weight).collect();
            sent_rows += tokens.len();
            tiles.push(DispatchTile {
                expert: ex as u32,
                dst: owner_of(ex) as u32,
                tile: t as u32,
                rows: tokens.len() as u32,
                tokens,
                weights,
            });
        }
    }
    let active_experts = by_expert.iter().filter(|v| !v.is_empty()).count();
    DispatchPlan {
        tiles,
        padded_rows: active_experts * routing.capacity,
        sent_rows,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn model(e: usize, k: usize, bm: usize) -> ModelConfig {
        ModelConfig {
            h: 16,
            d: 32,
            e,
            k,
            bm,
            bn: 8,
            policy: crate::config::RoutingPolicy::Capacity(1.0),
        }
    }

    #[test]
    fn softmax_rows_normalizes() {
        let mut x = vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0];
        softmax_rows(&mut x, 3);
        for row in x.chunks(3) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
            assert!(row.windows(2).all(|w| w[0] <= w[1]), "monotone logits stay ordered");
        }
    }

    #[test]
    fn topk_tie_breaks_low_index() {
        let scores = vec![0.25f32; 4];
        let (idx, w) = topk_rows(&scores, 4, 2);
        assert_eq!(idx, vec![0, 1]);
        assert_eq!(w, vec![0.25, 0.25]);
    }

    #[test]
    fn topk_orders_descending() {
        let scores = vec![0.1, 0.5, 0.2, 0.2];
        let (idx, _) = topk_rows(&scores, 4, 3);
        assert_eq!(idx, vec![1, 2, 3]);
    }

    #[test]
    fn slots_are_arrival_ordered_and_capacity_respected() {
        let m = model(2, 1, 4);
        // all tokens to expert 0 via extreme scores
        let s = 10;
        let mut scores = Vec::new();
        for _ in 0..s {
            scores.extend([0.9f32, 0.1]);
        }
        let routing = route_from_scores(scores, s, &m, 4);
        assert_eq!(routing.routes.len(), 4, "capacity 4 keeps 4");
        assert_eq!(routing.dropped, 6);
        for (i, r) in routing.routes.iter().enumerate() {
            assert_eq!(r.slot as usize, i);
            assert_eq!(r.token as usize, i, "first-come tokens keep slots");
        }
    }

    #[test]
    fn combine_weights_normalize_over_full_topk() {
        let m = model(4, 2, 64);
        let scores = vec![0.4f32, 0.3, 0.2, 0.1];
        let routing = route_from_scores(scores, 1, &m, 64);
        let total: f32 = routing.routes.iter().map(|r| r.combine_weight).sum();
        assert!((total - 1.0).abs() < 1e-6);
        assert!((routing.routes[0].combine_weight - 0.4 / 0.7).abs() < 1e-6);
    }

    #[test]
    fn gate_and_route_matches_manual_softmax() {
        let m = model(4, 2, 8);
        let mut rng = Rng::new(5);
        let s = 8;
        let a = rng.normal_vec(s * m.h, 1.0);
        let wg = rng.normal_vec(m.h * m.e, 1.0);
        let r = gate_and_route(&a, &wg, s, &m, 8);
        // every row of scores sums to 1
        for row in r.scores.chunks(m.e) {
            assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        }
        assert_eq!(r.routes.len() + r.dropped, s * m.k);
    }

    #[test]
    fn dispatch_plan_is_payload_efficient() {
        let m = model(4, 1, 4);
        // tokens 0..3 -> expert 0; token 4 -> expert 2; expert 1,3 inactive
        let mut scores = Vec::new();
        for _ in 0..4 {
            scores.extend([0.7f32, 0.1, 0.1, 0.1]);
        }
        scores.extend([0.1f32, 0.1, 0.7, 0.1]);
        let routing = route_from_scores(scores, 5, &m, 8);
        let plan = dispatch_plan(&routing, 4, |e| e % 2);
        // expert0: tile0 full (4 rows); expert2: tile0 1 row. 2 tiles total.
        assert_eq!(plan.tiles.len(), 2);
        assert_eq!(plan.sent_rows, 5);
        assert_eq!(plan.padded_rows, 16, "2 active experts x capacity 8");
        assert!(plan.savings() > 0.6);
        assert!(plan.tiles.iter().all(|t| t.rows > 0));
        // inactive experts generate zero traffic
        assert!(plan.tiles.iter().all(|t| t.expert != 1 && t.expert != 3));
    }

    #[test]
    fn dropless_plan_builds_variable_tile_lists() {
        let mut m = model(2, 1, 4);
        m.policy = crate::config::RoutingPolicy::Dropless;
        // 10 tokens, all to expert 0: dropless keeps every pair
        let s = 10;
        let mut scores = Vec::new();
        for _ in 0..s {
            scores.extend([0.9f32, 0.1]);
        }
        let cap = m.slot_capacity(s); // roundup(10, 4) = 12
        assert_eq!(cap, 12);
        let routing = route_from_scores(scores, s, &m, cap);
        assert_eq!(routing.dropped, 0, "dropless keeps all pairs");
        assert_eq!(routing.routes.len(), s);
        let plan = dispatch_plan(&routing, m.bm, |_| 0);
        // variable tile list: two full tiles + one partially-filled tail
        assert_eq!(plan.tiles.len(), 3);
        assert_eq!(
            plan.tiles.iter().map(|t| t.rows).collect::<Vec<_>>(),
            vec![4, 4, 2],
            "last tile partially filled"
        );
        assert_eq!(plan.tiles.iter().map(|t| t.tile).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(plan.sent_rows, s, "only valid rows travel");
        assert_eq!(plan.padded_rows, cap, "one active expert x slot region");
    }

    #[test]
    fn zero_and_partial_row_passes_route_cleanly() {
        // the variable-shape engine path gates whatever rows exist; zero
        // rows is an empty, drop-free routing with an empty plan
        let m = model(4, 2, 4);
        let r0 = route_from_scores(Vec::new(), 0, &m, 8);
        assert_eq!(r0.routes.len(), 0);
        assert_eq!(r0.dropped, 0);
        assert!(r0.expert_load.iter().all(|&l| l == 0));
        let p0 = dispatch_plan(&r0, m.bm, |e| e % 2);
        assert!(p0.tiles.is_empty());
        assert_eq!(p0.sent_rows, 0);
        // partial rows: the plan covers exactly the routed pairs of the
        // rows that exist — nothing padded up to any static batch shape
        let mut rng = Rng::new(77);
        let rows = 5; // deliberately not a bM multiple
        let scores = {
            let mut s = rng.normal_vec(rows * m.e, 1.0);
            crate::gate::softmax_rows(&mut s, m.e);
            s
        };
        let r = route_from_scores(scores, rows, &m, 64);
        assert_eq!(r.routes.len() + r.dropped, rows * m.k);
        let p = dispatch_plan(&r, m.bm, |e| e % 2);
        let covered: usize = p.tiles.iter().map(|t| t.tokens.len()).sum();
        assert_eq!(covered, r.routes.len());
        assert_eq!(p.sent_rows, r.routes.len(), "only existing rows travel");
    }

    #[test]
    fn dispatch_tiles_cover_all_kept_routes_once() {
        let m = model(8, 2, 4);
        let mut rng = Rng::new(9);
        let s = 64;
        let a = rng.normal_vec(s * m.h, 1.0);
        let wg = rng.normal_vec(m.h * m.e, 1.0);
        let routing = gate_and_route(&a, &wg, s, &m, 8);
        let plan = dispatch_plan(&routing, 4, |e| e / 4);
        let covered: usize = plan.tiles.iter().map(|t| t.tokens.len()).sum();
        assert_eq!(covered, routing.routes.len());
    }
}
