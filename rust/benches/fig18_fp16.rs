//! Fig 18 — wire precision A/B, **measured on the live engine** (the old
//! analytic payload/smem model is gone): f32 vs bf16 vs f16 wire formats
//! on identical inputs, reporting measured one-sided bytes, byte-granular
//! payload savings and steady-state pass latency, with dense-reference
//! conformance asserted inside the harness at each format's documented
//! tolerance.
//!
//! Emits `BENCH_pr5_precision.json` (section `precision_ab`) for the CI
//! artifact upload. With `PERF_SMOKE=1` the run FAILS unless every 16-bit
//! wire measures < 0.6x the f32 wire bytes — the harness only *reports*
//! the measured bytes (it asserts dense-reference conformance, not byte
//! ratios), so this gate is the live CI check against accounting drift;
//! the exact-2x assertion lives in `rust/tests/engines.rs`.
//!
//!     PRESET=tiny PASSES=3 cargo bench --bench fig18_fp16
fn main() {
    let preset = std::env::var("PRESET").unwrap_or_else(|_| "tiny".to_string());
    let passes = std::env::var("PASSES").ok().and_then(|v| v.parse().ok()).unwrap_or(3);
    let (text, pts) = flashdmoe::harness::precision_ab(&preset, passes, 42).unwrap();
    println!("{text}");

    flashdmoe::harness::update_bench_json(
        "BENCH_pr5_precision.json",
        "precision_ab",
        flashdmoe::harness::precision_json(&pts),
    )
    .unwrap();
    println!("wrote BENCH_pr5_precision.json (section precision_ab)");

    let perf_smoke = std::env::var("PERF_SMOKE").map(|v| v == "1").unwrap_or(false);
    if perf_smoke {
        let f32_bytes = pts
            .iter()
            .find(|p| p.wire == flashdmoe::config::WirePrecision::F32)
            .expect("f32 arm present")
            .wire_bytes as f64;
        let mut failed = false;
        for p in pts.iter().filter(|p| p.wire.is_reduced()) {
            let ratio = p.wire_bytes as f64 / f32_bytes;
            if ratio >= 0.6 {
                eprintln!(
                    "PERF_SMOKE FAIL: {} wire measured {:.2}x the fp32 bytes (must be < 0.6x)",
                    p.wire.name(),
                    ratio
                );
                failed = true;
            } else {
                println!(
                    "PERF_SMOKE ok: {} wire bytes {:.2}x fp32 (err {:.2e} <= tol {:.0e})",
                    p.wire.name(),
                    ratio,
                    p.max_abs_err,
                    p.tolerance
                );
            }
        }
        if failed {
            std::process::exit(1);
        }
    }
}
