//! Synthetic workload generation: token routing distributions that drive
//! both the real coordinator (via actual gate scores) and the simulator
//! (via replayed routing tables).
//!
//! MoE token→expert distributions are *not* uniform in practice (the paper
//! cites BlackMamba [36]); the generators below produce uniform, zipf-
//! skewed and hot-expert distributions so payload efficiency, capacity
//! drops and load imbalance are all exercised.
//!
//! For the serving path, [`ArrivalProcess`] generates *request arrival*
//! workloads — Poisson open-loop traffic, replayed traces, or
//! closed-loop client populations — so `MoeService` benches drive
//! realistic load instead of back-to-back saturation.

use anyhow::{Context, Result};

use crate::config::{Config, ModelConfig};
use crate::gate::{dispatch_plan, route_from_scores, DispatchPlan, Routing};
use crate::placement::Placement;
use crate::util::prng::Rng;

/// Routing skew shape.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Skew {
    /// Experts drawn ~uniformly (well-balanced router).
    Uniform,
    /// Zipf(s≈1.1) over experts (realistic long-tail imbalance).
    Zipf,
    /// A handful of experts take most tokens (pathological hot spot).
    Hot,
}

impl Skew {
    pub fn parse(s: &str) -> Option<Skew> {
        match s {
            "uniform" => Some(Skew::Uniform),
            "zipf" => Some(Skew::Zipf),
            "hot" => Some(Skew::Hot),
            _ => None,
        }
    }
}

/// One rank's replayable routing workload.
#[derive(Clone, Debug)]
pub struct RankWorkload {
    pub routing: Routing,
    pub plan: DispatchPlan,
}

/// One serving request arrival: when it hits the front door and how many
/// token rows it carries.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Arrival {
    /// Arrival time in seconds from the start of the run. Zero for every
    /// arrival of a [`Closed`](ArrivalProcess::Closed) process — the
    /// driver re-issues on completion instead of on a clock.
    pub at: f64,
    /// Token rows in the request.
    pub tokens: usize,
    /// Resident model the request targets (`RequestOpts::model`); 0 — the
    /// engine's anchor model — for synthetic processes and trace lines
    /// without a model column.
    pub model: usize,
    /// Request priority (`RequestOpts::priority`); 0 for synthetic
    /// processes and trace lines without a priority column.
    pub priority: i32,
}

/// Request arrival process for serving benches (open-loop Poisson,
/// replayed trace, or closed-loop client population).
#[derive(Clone, Debug, PartialEq)]
pub enum ArrivalProcess {
    /// Open loop: exponential interarrivals at `rate` requests/second;
    /// request sizes drawn uniformly from the driver's range.
    Poisson { rate: f64 },
    /// Replay a trace file: one arrival per line,
    /// `<at_secs> <tokens> [model] [priority]` — the two trailing columns
    /// are optional and default to model 0 / priority 0, so pre-existing
    /// two-column traces replay unchanged ('#' comments and blank lines
    /// allowed).
    Trace(String),
    /// Closed loop: `n` clients, each submitting its next request the
    /// moment the previous completes (arrival times are all zero; the
    /// driver maintains `n` outstanding).
    Closed { n: usize },
}

impl ArrivalProcess {
    /// Parse a CLI value: `poisson:<rate>`, `trace:<path>`, `closed:<n>`.
    pub fn parse(s: &str) -> Option<ArrivalProcess> {
        if let Some(r) = s.strip_prefix("poisson:") {
            return r
                .parse::<f64>()
                .ok()
                .filter(|r| r.is_finite() && *r > 0.0)
                .map(|rate| ArrivalProcess::Poisson { rate });
        }
        if let Some(p) = s.strip_prefix("trace:") {
            return Some(ArrivalProcess::Trace(p.to_string()));
        }
        if let Some(n) = s.strip_prefix("closed:") {
            return n.parse::<usize>().ok().filter(|n| *n > 0).map(|n| ArrivalProcess::Closed { n });
        }
        None
    }

    /// Generate `count` arrivals. `tokens` is the inclusive request-size
    /// range for the synthetic (non-trace) processes; a trace supplies
    /// its own sizes and times (and its `count` is the number of lines
    /// replayed, cycling if the trace is shorter).
    pub fn arrivals(
        &self,
        count: usize,
        tokens: (usize, usize),
        rng: &mut Rng,
    ) -> Result<Vec<Arrival>> {
        let (lo, hi) = tokens;
        anyhow::ensure!(lo >= 1 && lo <= hi, "bad request-size range [{lo}, {hi}]");
        let size = |rng: &mut Rng| lo + rng.below(hi - lo + 1);
        match self {
            ArrivalProcess::Poisson { rate } => {
                let mut t = 0.0f64;
                Ok((0..count)
                    .map(|_| {
                        // exponential interarrival: -ln(U)/rate, U in (0,1]
                        let u = 1.0 - rng.f64();
                        t += -u.ln() / rate;
                        Arrival { at: t, tokens: size(rng), model: 0, priority: 0 }
                    })
                    .collect())
            }
            ArrivalProcess::Trace(path) => {
                let text = std::fs::read_to_string(path)
                    .with_context(|| format!("reading arrival trace {path}"))?;
                let mut parsed = Vec::new();
                for (ln, line) in text.lines().enumerate() {
                    let line = line.split('#').next().unwrap_or("").trim();
                    if line.is_empty() {
                        continue;
                    }
                    let mut it = line.split_whitespace();
                    let at: f64 = it
                        .next()
                        .and_then(|v| v.parse().ok())
                        .with_context(|| format!("{path}:{}: expected '<at> <tokens>'", ln + 1))?;
                    let tokens: usize = it
                        .next()
                        .and_then(|v| v.parse().ok())
                        .with_context(|| format!("{path}:{}: expected '<at> <tokens>'", ln + 1))?;
                    anyhow::ensure!(
                        tokens >= 1,
                        "{path}:{}: zero-token arrival in trace",
                        ln + 1
                    );
                    anyhow::ensure!(
                        at.is_finite() && at >= 0.0,
                        "{path}:{}: arrival time {at} must be finite and non-negative",
                        ln + 1
                    );
                    // optional trailing columns: model id, then priority
                    let model: usize = match it.next() {
                        Some(v) => v.parse().with_context(|| {
                            format!("{path}:{}: model column '{v}' is not an integer", ln + 1)
                        })?,
                        None => 0,
                    };
                    let priority: i32 = match it.next() {
                        Some(v) => v.parse().with_context(|| {
                            format!("{path}:{}: priority column '{v}' is not an integer", ln + 1)
                        })?,
                        None => 0,
                    };
                    parsed.push(Arrival { at, tokens, model, priority });
                }
                anyhow::ensure!(!parsed.is_empty(), "{path}: empty arrival trace");
                parsed.sort_by(|a, b| a.at.partial_cmp(&b.at).unwrap());
                let span = parsed.last().unwrap().at;
                Ok((0..count)
                    .map(|i| {
                        // cycle the trace, shifting each lap by its span
                        let lap = i / parsed.len();
                        let a = parsed[i % parsed.len()];
                        Arrival { at: a.at + lap as f64 * span, ..a }
                    })
                    .collect())
            }
            ArrivalProcess::Closed { .. } => {
                Ok((0..count)
                    .map(|_| Arrival { at: 0.0, tokens: size(rng), model: 0, priority: 0 })
                    .collect())
            }
        }
    }

    /// Outstanding-request bound the driver should maintain: `n` for a
    /// closed loop, unbounded (`usize::MAX`) for open-loop processes.
    pub fn concurrency(&self) -> usize {
        match self {
            ArrivalProcess::Closed { n } => *n,
            _ => usize::MAX,
        }
    }
}

/// Generate a Zipf-skewed multi-model arrival trace in the text format
/// [`ArrivalProcess::Trace`] replays (`<at> <tokens> <model> <priority>`
/// per line): Poisson arrivals at `rate` requests/second, sizes uniform
/// in the inclusive `tokens` range, and each arrival's model drawn
/// Zipf(`s`) over `n_models` — model 0 is the hottest, matching real
/// multi-tenant serving where one base model takes most traffic and
/// variants trail off. Priorities are all 0 (the column is exercised, the
/// ordering is not). Deterministic in `seed`; write the string to a file
/// and replay it with `ArrivalProcess::parse("trace:<path>")`.
pub fn zipf_model_trace(
    count: usize,
    rate: f64,
    tokens: (usize, usize),
    n_models: usize,
    s: f64,
    seed: u64,
) -> String {
    let lo = tokens.0.max(1);
    let hi = tokens.1.max(lo);
    let rate = if rate.is_finite() && rate > 0.0 { rate } else { 1.0 };
    let mut rng = Rng::new(seed);
    let mut t = 0.0f64;
    let mut out = String::from("# at tokens model priority\n");
    for _ in 0..count {
        let u = 1.0 - rng.f64();
        t += -u.ln() / rate;
        let size = lo + rng.below(hi - lo + 1);
        let model = if n_models > 1 { rng.zipf(n_models, s) } else { 0 };
        out.push_str(&format!("{t:.6} {size} {model} 0\n"));
    }
    out
}

/// Synthesize gate *scores* (not tokens) with the requested skew, then
/// route them through the production gate/capacity/dispatch code — the
/// simulator replays exactly what the real coordinator would do.
pub fn synth_routing(
    model: &ModelConfig,
    s_rank: usize,
    capacity: usize,
    skew: Skew,
    rng: &mut Rng,
) -> Routing {
    let e = model.e;
    let mut scores = vec![0.0f32; s_rank * e];
    for row in scores.chunks_mut(e) {
        // favored expert by skew; logits = noise + bias toward favorite
        let fav = match skew {
            Skew::Uniform => rng.below(e),
            Skew::Zipf => rng.zipf(e, 1.1),
            Skew::Hot => {
                if rng.f64() < 0.7 {
                    rng.below((e / 8).max(1))
                } else {
                    rng.below(e)
                }
            }
        };
        for (j, v) in row.iter_mut().enumerate() {
            *v = rng.normal_f32(0.0, 1.0) + if j == fav { 3.0 } else { 0.0 };
        }
    }
    crate::gate::softmax_rows(&mut scores, e);
    route_from_scores(scores, s_rank, model, capacity)
}

/// Build the full per-rank workload set for a config, under the static
/// block placement (no replication).
pub fn cluster_workload(cfg: &Config, skew: Skew, seed: u64) -> Vec<RankWorkload> {
    let capacity = cfg.model.slot_capacity(cfg.system.s_rank);
    let placement = Placement::from_config(cfg);
    let base = Rng::new(seed);
    (0..cfg.system.ranks)
        .map(|r| {
            let mut rng = base.fork(r as u64 + 0x50);
            let routing = synth_routing(&cfg.model, cfg.system.s_rank, capacity, skew, &mut rng);
            let plan = dispatch_plan(&routing, cfg.model.bm, &placement);
            RankWorkload { routing, plan }
        })
        .collect()
}

/// Synthesize token *embeddings* whose gate scores under the model's real
/// gate matrix `wg` (row-major (H, E)) are skewed toward `skew`-drawn
/// favorite experts — the live-engine analogue of [`synth_routing`]:
/// where that replays synthetic scores through the routing code, this
/// builds inputs so the production gate GEMM itself produces the skew.
/// Each token is small isotropic noise plus 2.5 × the unit-normalized
/// `wg` column of its favorite expert, so `x · wg` peaks at the favorite
/// with high probability. Deterministic in `rng`; returns `rows × h`.
pub fn skewed_tokens(
    wg: &[f32],
    h: usize,
    e: usize,
    rows: usize,
    skew: Skew,
    rng: &mut Rng,
) -> Vec<f32> {
    debug_assert_eq!(wg.len(), h * e);
    // unit-normalize each gate column once (wg is row-major, columns strided)
    let mut cols = vec![0.0f32; e * h];
    for ex in 0..e {
        let mut norm = 0.0f32;
        for r in 0..h {
            let v = wg[r * e + ex];
            cols[ex * h + r] = v;
            norm += v * v;
        }
        let inv = 1.0 / norm.sqrt().max(1e-6);
        for v in &mut cols[ex * h..(ex + 1) * h] {
            *v *= inv;
        }
    }
    let mut out = vec![0.0f32; rows * h];
    for row in out.chunks_mut(h) {
        let fav = match skew {
            Skew::Uniform => rng.below(e),
            Skew::Zipf => rng.zipf(e, 1.1),
            Skew::Hot => {
                if rng.f64() < 0.7 {
                    rng.below((e / 8).max(1))
                } else {
                    rng.below(e)
                }
            }
        };
        for (j, v) in row.iter_mut().enumerate() {
            *v = rng.normal_f32(0.0, 0.3) + 2.5 * cols[fav * h + j];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    #[test]
    fn uniform_loads_are_balanced() {
        let cfg = Config::preset("default").unwrap();
        let cap = cfg.model.capacity(cfg.system.s_rank);
        let mut rng = Rng::new(1);
        let r = synth_routing(&cfg.model, cfg.system.s_rank, cap, Skew::Uniform, &mut rng);
        let max = *r.expert_load.iter().max().unwrap() as f64;
        let min = *r.expert_load.iter().min().unwrap() as f64;
        assert!(max / min.max(1.0) < 4.0, "uniform skew too high: {max}/{min}");
    }

    #[test]
    fn hot_skew_concentrates_and_drops() {
        let cfg = Config::preset("default").unwrap();
        let cap = cfg.model.capacity(cfg.system.s_rank);
        let mut rng = Rng::new(2);
        let hot = synth_routing(&cfg.model, cfg.system.s_rank, cap, Skew::Hot, &mut rng);
        let uni = synth_routing(&cfg.model, cfg.system.s_rank, cap, Skew::Uniform, &mut rng);
        assert!(hot.dropped > uni.dropped, "hot skew should overflow capacity");
        let hot_max = *hot.expert_load.iter().max().unwrap();
        let uni_max = *uni.expert_load.iter().max().unwrap();
        assert!(hot_max >= uni_max);
    }

    #[test]
    fn workload_is_deterministic() {
        let cfg = Config::preset("tiny").unwrap();
        let a = cluster_workload(&cfg, Skew::Zipf, 7);
        let b = cluster_workload(&cfg, Skew::Zipf, 7);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.plan.tiles, y.plan.tiles);
        }
    }

    #[test]
    fn poisson_arrivals_are_monotone_and_near_rate() {
        let p = ArrivalProcess::parse("poisson:100").unwrap();
        assert_eq!(p, ArrivalProcess::Poisson { rate: 100.0 });
        let mut rng = Rng::new(11);
        let a = p.arrivals(2000, (8, 64), &mut rng).unwrap();
        assert_eq!(a.len(), 2000);
        assert!(a.windows(2).all(|w| w[0].at <= w[1].at), "arrival times monotone");
        assert!(a.iter().all(|x| (8..=64).contains(&x.tokens)));
        // mean interarrival ~ 1/rate (law of large numbers, loose bound)
        let mean = a.last().unwrap().at / 2000.0;
        assert!((mean - 0.01).abs() < 0.002, "mean interarrival {mean} far from 1/100");
        // deterministic under the same seed
        let b = p.arrivals(2000, (8, 64), &mut Rng::new(11)).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn closed_arrivals_carry_concurrency_not_clocks() {
        let p = ArrivalProcess::parse("closed:8").unwrap();
        assert_eq!(p.concurrency(), 8);
        let a = p.arrivals(32, (16, 16), &mut Rng::new(3)).unwrap();
        assert!(a.iter().all(|x| x.at == 0.0 && x.tokens == 16));
        assert_eq!(ArrivalProcess::Poisson { rate: 1.0 }.concurrency(), usize::MAX);
    }

    #[test]
    fn trace_arrivals_replay_and_cycle() {
        let dir = std::env::temp_dir().join("flashdmoe_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("arrivals.trace");
        std::fs::write(&path, "# at tokens\n0.0 8\n0.5 16\n1.0 32\n").unwrap();
        let p = ArrivalProcess::parse(&format!("trace:{}", path.display())).unwrap();
        let a = p.arrivals(5, (1, 1), &mut Rng::new(0)).unwrap();
        assert_eq!(a[0], Arrival { at: 0.0, tokens: 8, model: 0, priority: 0 });
        assert_eq!(a[2], Arrival { at: 1.0, tokens: 32, model: 0, priority: 0 });
        // cycles past the end, shifted by the trace span
        assert_eq!(a[3], Arrival { at: 1.0, tokens: 8, model: 0, priority: 0 });
        assert_eq!(a[4], Arrival { at: 1.5, tokens: 16, model: 0, priority: 0 });
        // bad inputs refuse loudly
        assert!(ArrivalProcess::parse("poisson:0").is_none());
        assert!(ArrivalProcess::parse("poisson:nan").is_none());
        assert!(ArrivalProcess::parse("closed:0").is_none());
        assert!(ArrivalProcess::parse("fifo").is_none());
        assert!(ArrivalProcess::Trace("/nonexistent/x".into())
            .arrivals(1, (1, 1), &mut Rng::new(0))
            .is_err());
        // malformed times error out instead of panicking downstream
        for bad in ["nan 8\n", "inf 8\n", "-1.0 8\n"] {
            let p = dir.join("bad.trace");
            std::fs::write(&p, bad).unwrap();
            let t = ArrivalProcess::Trace(p.to_str().unwrap().into());
            assert!(t.arrivals(1, (1, 1), &mut Rng::new(0)).is_err(), "{bad:?} must error");
        }
    }

    #[test]
    fn trace_model_and_priority_columns_parse_with_defaults() {
        let dir = std::env::temp_dir().join("flashdmoe_trace_cols_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cols.trace");
        // 2-, 3-, and 4-column lines mixed in one trace
        std::fs::write(&path, "# at tokens model priority\n0.0 8\n0.5 16 2\n1.0 32 1 -3\n")
            .unwrap();
        let p = ArrivalProcess::parse(&format!("trace:{}", path.display())).unwrap();
        let a = p.arrivals(3, (1, 1), &mut Rng::new(0)).unwrap();
        assert_eq!(a[0], Arrival { at: 0.0, tokens: 8, model: 0, priority: 0 });
        assert_eq!(a[1], Arrival { at: 0.5, tokens: 16, model: 2, priority: 0 });
        assert_eq!(a[2], Arrival { at: 1.0, tokens: 32, model: 1, priority: -3 });
        // malformed extras error instead of silently dropping the column
        for bad in ["0.0 8 x\n", "0.0 8 1 y\n"] {
            let bp = dir.join("badcol.trace");
            std::fs::write(&bp, bad).unwrap();
            let t = ArrivalProcess::Trace(bp.to_str().unwrap().into());
            assert!(t.arrivals(1, (1, 1), &mut Rng::new(0)).is_err(), "{bad:?} must error");
        }
    }

    #[test]
    fn zipf_model_trace_is_deterministic_and_skewed_toward_model_zero() {
        let t1 = zipf_model_trace(400, 50.0, (8, 64), 4, 1.2, 17);
        let t2 = zipf_model_trace(400, 50.0, (8, 64), 4, 1.2, 17);
        assert_eq!(t1, t2, "generator must be deterministic in the seed");
        // the string replays through the Trace arrival process
        let dir = std::env::temp_dir().join("flashdmoe_zipf_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("zipf.trace");
        std::fs::write(&path, &t1).unwrap();
        let p = ArrivalProcess::parse(&format!("trace:{}", path.display())).unwrap();
        let a = p.arrivals(400, (1, 1), &mut Rng::new(0)).unwrap();
        assert_eq!(a.len(), 400);
        assert!(a.windows(2).all(|w| w[0].at <= w[1].at), "times monotone");
        assert!(a.iter().all(|x| (8..=64).contains(&x.tokens)));
        assert!(a.iter().all(|x| x.model < 4 && x.priority == 0));
        // Zipf skew: model 0 dominates, but the tail is exercised too
        let mut counts = [0usize; 4];
        for x in &a {
            counts[x.model] += 1;
        }
        assert!(
            counts[0] > counts[1] && counts[1] > counts[3],
            "zipf skew toward model 0: {counts:?}"
        );
        assert!(counts.iter().all(|&c| c > 0), "all models appear: {counts:?}");
        // single-model traces pin the column to 0
        let solo = zipf_model_trace(10, 50.0, (8, 8), 1, 1.2, 3);
        assert!(solo.lines().skip(1).all(|l| l.split_whitespace().nth(2) == Some("0")));
    }

    #[test]
    fn skewed_tokens_skew_the_real_gate() {
        use crate::expert::ModelParams;
        let cfg = Config::preset("tiny").unwrap();
        let params = ModelParams::generate(&cfg, 5);
        let (h, e) = (cfg.model.h, cfg.model.e);
        let rows = 256;
        // score through the actual gate matmul + production routing
        let route = |toks: &[f32]| {
            let mut s = vec![0.0f32; rows * e];
            for r in 0..rows {
                for j in 0..e {
                    let mut acc = 0.0f32;
                    for x in 0..h {
                        acc += toks[r * h + x] * params.wg[x * e + j];
                    }
                    s[r * e + j] = acc;
                }
            }
            crate::gate::softmax_rows(&mut s, e);
            route_from_scores(s, rows, &cfg.model, rows)
        };
        let zipf =
            route(&skewed_tokens(&params.wg, h, e, rows, Skew::Zipf, &mut Rng::new(9)));
        let uni =
            route(&skewed_tokens(&params.wg, h, e, rows, Skew::Uniform, &mut Rng::new(9)));
        let max_z = *zipf.offered_load.iter().max().unwrap();
        let max_u = *uni.offered_load.iter().max().unwrap();
        assert!(
            max_z > max_u,
            "zipf tokens should concentrate offered load through the real gate: {max_z} vs {max_u}"
        );
        // deterministic under the same seed
        assert_eq!(
            skewed_tokens(&params.wg, h, e, rows, Skew::Zipf, &mut Rng::new(9)),
            skewed_tokens(&params.wg, h, e, rows, Skew::Zipf, &mut Rng::new(9)),
        );
    }

    #[test]
    fn plans_cover_routes() {
        let cfg = Config::preset("tiny").unwrap();
        for skew in [Skew::Uniform, Skew::Zipf, Skew::Hot] {
            for w in cluster_workload(&cfg, skew, 3) {
                let covered: usize = w.plan.tiles.iter().map(|t| t.tokens.len()).sum();
                assert_eq!(covered, w.routing.routes.len());
            }
        }
    }
}
