//! The Scheduler actor (paper Alg. 3): a decentralized, work-stealing
//! ready pool.
//!
//! The paper's scheduler decentralizes dispatch across processor blocks;
//! the CPU analog is **per-processor deques with Chase-Lev-style
//! stealing** instead of one central `Mutex<VecDeque>`:
//!
//! * Each processor slot owns a deque. The owner pushes and pops at the
//!   **bottom** (LIFO — a Gemm0's freshly-unlocked Gemm1 children run
//!   while their intermediate block is still cache-hot); thieves steal
//!   from the **top** (FIFO — the oldest, least-cache-relevant work
//!   migrates). Each deque has its own lock, so two processors only ever
//!   contend when one is actually stealing from the other — dispatch no
//!   longer serializes on a single queue lock.
//! * External producers (the subscriber decoding packets) deal tasks
//!   round-robin across the deques, so a burst of decoded tiles starts on
//!   many processors at once without any of them touching a shared queue.
//! * Processors **park only on global emptiness**: a pop scans its own
//!   deque, then every victim, and only then blocks on the pool condvar.
//!   Wakeups are counted — a batch of n tasks wakes `min(n, parked)`
//!   processors via that many `notify_one`s, never a blanket
//!   `notify_all` (the thundering-herd fix: 2 tasks no longer wake 16
//!   parked workers to fight over 2 pops).
//!
//! Pass semantics are unchanged from the centralized queue: `stop_all`
//! is the scheduler's interrupt broadcast (Alg. 3 lines 33–34) — pops
//! drain every deque, then return `None`; [`TaskQueue::reopen`] re-arms
//! the pool for the next pass without reallocating or re-spawning
//! anything (the pool is resident for the engine lifetime). The
//! pushed/popped totals stay cumulative; `max_depth` (global high-water)
//! resets per pass; `steals` counts cross-deque migrations — the
//! queue-contention stat reported by the PR-3 hot-path benches.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};

use crate::task::Task;

/// Soft per-deque pre-allocation: deques start with this capacity so the
/// steady-state pass never grows them (a pass's per-processor share of
/// tasks is far below this for every preset; `VecDeque` grows safely if
/// a pathological pass exceeds it).
const DEQUE_CAPACITY: usize = 256;

/// Work-stealing ready pool shared by one rank's actors.
pub struct TaskQueue {
    /// One deque per processor slot (owner: that slot; thieves: everyone).
    deques: Vec<Mutex<VecDeque<Task>>>,
    /// Tasks currently resident across all deques. Incremented *before* a
    /// task becomes visible in a deque and decremented *after* it is
    /// taken, so `len == 0` proves global emptiness — the only state in
    /// which a pop may park (or, post-`stop_all`, return `None`).
    len: AtomicUsize,
    /// Parked-or-parking processors; producers wake `min(n, parked)`.
    parked: AtomicUsize,
    stopped: AtomicBool,
    /// Guards the condvar sleep; all queue state lives in the atomics and
    /// the sharded deque locks, so this lock is only taken on the
    /// park/wake edge — never on the push/pop fast path.
    park: Mutex<()>,
    cv: Condvar,
    pushed: AtomicU32,
    popped: AtomicU32,
    /// Cross-deque migrations (successful steals): the contention metric.
    steals: AtomicU32,
    /// High-water mark of global depth (scheduling pressure metric).
    max_depth: AtomicUsize,
    /// Round-robin cursor for external (subscriber) pushes.
    next_rr: AtomicUsize,
}

impl TaskQueue {
    /// A pool with one deque per processor slot (`workers >= 1`).
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        Self {
            deques: (0..workers)
                .map(|_| Mutex::new(VecDeque::with_capacity(DEQUE_CAPACITY)))
                .collect(),
            len: AtomicUsize::new(0),
            parked: AtomicUsize::new(0),
            stopped: AtomicBool::new(false),
            park: Mutex::new(()),
            cv: Condvar::new(),
            pushed: AtomicU32::new(0),
            popped: AtomicU32::new(0),
            steals: AtomicU32::new(0),
            max_depth: AtomicUsize::new(0),
            next_rr: AtomicUsize::new(0),
        }
    }

    /// Deques in the pool (== processor slots).
    pub fn workers(&self) -> usize {
        self.deques.len()
    }

    /// Enqueue one ready task (external producer): deal it round-robin
    /// and wake at most one parked processor.
    pub fn push(&self, t: Task) {
        let slot = self.next_rr.fetch_add(1, Ordering::Relaxed) % self.deques.len();
        self.insert(slot, t);
        self.wake(1);
    }

    /// Enqueue a batch (external producer): deal round-robin so the burst
    /// starts on many processors at once, then wake `min(n, parked)`.
    pub fn push_batch(&self, ts: impl IntoIterator<Item = Task>) {
        let mut n = 0usize;
        for t in ts {
            let slot = self.next_rr.fetch_add(1, Ordering::Relaxed) % self.deques.len();
            self.insert(slot, t);
            n += 1;
        }
        if n > 0 {
            self.wake(n);
        }
    }

    /// Enqueue a batch produced *by* processor `slot` (e.g. the Gemm1
    /// children a finished Gemm0 column unlocks): owner-push onto its own
    /// bottom — uncontended unless a thief is mid-steal — and wake peers
    /// that may have parked while this slot was busy.
    pub fn push_batch_local(&self, slot: usize, ts: impl IntoIterator<Item = Task>) {
        let mut n = 0usize;
        for t in ts {
            self.insert(slot % self.deques.len(), t);
            n += 1;
        }
        if n > 0 {
            // the pushing processor will pop its own bottom next, so peers
            // only need waking for the surplus
            self.wake(n.saturating_sub(1));
        }
    }

    /// All inserts land at the deque *bottom* (Chase-Lev discipline): the
    /// owner's pop_back takes the newest task, thieves' pop_front always
    /// migrate the oldest — for external and owner pushes alike.
    fn insert(&self, slot: usize, t: Task) {
        // len goes up before the task is visible so a concurrent pop can
        // never drive it below zero, and a parking processor that reads
        // len > 0 under the park lock is guaranteed to find the task on
        // its rescan (the producer's deque insert completes first).
        let depth = self.len.fetch_add(1, Ordering::SeqCst) + 1;
        self.max_depth.fetch_max(depth, Ordering::Relaxed);
        self.pushed.fetch_add(1, Ordering::Relaxed);
        self.deques[slot].lock().unwrap().push_back(t);
    }

    /// Wake up to `n` parked processors with counted `notify_one`s (the
    /// thundering-herd fix — never `notify_all` for a 2-task batch).
    fn wake(&self, n: usize) {
        if n == 0 {
            return;
        }
        let parked = self.parked.load(Ordering::SeqCst);
        if parked == 0 {
            return;
        }
        let _guard = self.park.lock().unwrap();
        for _ in 0..n.min(parked) {
            self.cv.notify_one();
        }
    }

    /// Take a task as processor `slot`: own bottom first (LIFO,
    /// cache-hot children), then steal a victim's top (FIFO). `None`
    /// means nothing runnable *right now* — callers park via [`pop`].
    fn try_take(&self, slot: usize) -> Option<Task> {
        let n = self.deques.len();
        let own = slot % n;
        if let Some(t) = self.deques[own].lock().unwrap().pop_back() {
            self.len.fetch_sub(1, Ordering::SeqCst);
            self.popped.fetch_add(1, Ordering::Relaxed);
            return Some(t);
        }
        for i in 1..n {
            let victim = (own + i) % n;
            if let Some(t) = self.deques[victim].lock().unwrap().pop_front() {
                self.len.fetch_sub(1, Ordering::SeqCst);
                self.popped.fetch_add(1, Ordering::Relaxed);
                self.steals.fetch_add(1, Ordering::Relaxed);
                return Some(t);
            }
        }
        None
    }

    /// Blocking pop for processor `slot`; parks only on global emptiness
    /// and returns `None` only after `stop_all` with every deque drained.
    pub fn pop(&self, slot: usize) -> Option<Task> {
        loop {
            if let Some(t) = self.try_take(slot) {
                return Some(t);
            }
            // Publish intent-to-park *before* re-checking len: a producer
            // increments len before reading `parked`, so either it sees us
            // and notifies, or we see its len increment here and rescan.
            self.parked.fetch_add(1, Ordering::SeqCst);
            let guard = self.park.lock().unwrap();
            if self.len.load(Ordering::SeqCst) == 0 {
                if self.stopped.load(Ordering::SeqCst) {
                    self.parked.fetch_sub(1, Ordering::SeqCst);
                    return None;
                }
                let _unused = self.cv.wait(guard).unwrap();
            }
            self.parked.fetch_sub(1, Ordering::SeqCst);
        }
    }

    /// Non-blocking steal from any deque (the subscriber's help-out
    /// path: while its flag sweep is idle it lends a hand as a thief).
    pub fn steal(&self) -> Option<Task> {
        for dq in &self.deques {
            if let Some(t) = dq.lock().unwrap().pop_front() {
                self.len.fetch_sub(1, Ordering::SeqCst);
                self.popped.fetch_add(1, Ordering::Relaxed);
                self.steals.fetch_add(1, Ordering::Relaxed);
                return Some(t);
            }
        }
        None
    }

    /// Interrupt broadcast: wake everyone; pops drain then return `None`.
    pub fn stop_all(&self) {
        self.stopped.store(true, Ordering::SeqCst);
        let _guard = self.park.lock().unwrap();
        self.cv.notify_all();
    }

    /// Re-arm a stopped pool for the next pass. The caller must have
    /// observed all consumers park (the rank actor waits for its
    /// processors' pass-done latch before reopening). Resets the per-pass
    /// depth high-water mark; push/pop/steal totals stay cumulative.
    pub fn reopen(&self) {
        debug_assert_eq!(self.len.load(Ordering::SeqCst), 0, "reopening with undrained tasks");
        debug_assert!(
            self.deques.iter().all(|d| d.lock().unwrap().is_empty()),
            "reopening with undrained deques"
        );
        self.stopped.store(false, Ordering::SeqCst);
        self.max_depth.store(0, Ordering::Relaxed);
    }

    pub fn counts(&self) -> (u32, u32) {
        (self.pushed.load(Ordering::Relaxed), self.popped.load(Ordering::Relaxed))
    }

    /// Cumulative cross-deque steals (contention/imbalance metric).
    pub fn steals(&self) -> u32 {
        self.steals.load(Ordering::Relaxed)
    }

    pub fn max_depth(&self) -> usize {
        self.max_depth.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::task::{Task, TaskType};
    use std::sync::atomic::AtomicU32;
    use std::sync::Arc;

    fn task(seq: u32) -> Task {
        Task { task_type: TaskType::FusedFfn, peer: 0, expert: 0, tile: 0, col: 0, rows: 1, seq }
    }

    #[test]
    fn single_worker_delivers_everything_then_drains() {
        let q = TaskQueue::new(1);
        for i in 0..5 {
            q.push(task(i));
        }
        let mut got: Vec<u32> = (0..5).map(|_| q.pop(0).unwrap().seq).collect();
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
        q.stop_all();
        assert!(q.pop(0).is_none());
    }

    #[test]
    fn every_task_consumed_exactly_once_under_contention() {
        let workers = 8;
        let q = Arc::new(TaskQueue::new(workers));
        assert_eq!(q.workers(), workers);
        let n_tasks = 10_000u32;
        let consumed = Arc::new(AtomicU32::new(0));
        let mut handles = Vec::new();
        for slot in 0..workers {
            let q = q.clone();
            let consumed = consumed.clone();
            handles.push(std::thread::spawn(move || {
                let mut seen = Vec::new();
                while let Some(t) = q.pop(slot) {
                    seen.push(t.seq);
                    consumed.fetch_add(1, Ordering::Relaxed);
                }
                seen
            }));
        }
        for i in 0..n_tasks {
            q.push(task(i));
        }
        q.stop_all();
        let mut all: Vec<u32> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..n_tasks).collect::<Vec<_>>(), "each task exactly once");
        let (pushed, popped) = q.counts();
        assert_eq!(pushed, n_tasks);
        assert_eq!(popped, n_tasks);
    }

    #[test]
    fn local_pushes_are_stolen_by_idle_workers() {
        // worker 0 never pops; everything it produces locally must migrate
        // to the other workers via steals
        let workers = 4;
        let q = Arc::new(TaskQueue::new(workers));
        let n_tasks = 64u32;
        q.push_batch_local(0, (0..n_tasks).map(task));
        let mut handles = Vec::new();
        for slot in 1..workers {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(t) = q.pop(slot) {
                    got.push(t.seq);
                }
                got
            }));
        }
        // wait until the thieves drain everything, then stop
        while q.counts().1 < n_tasks {
            std::thread::yield_now();
        }
        q.stop_all();
        let mut all: Vec<u32> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..n_tasks).collect::<Vec<_>>());
        assert_eq!(q.steals(), n_tasks, "every delivery crossed deques");
    }

    #[test]
    fn owner_pops_its_own_bottom_lifo() {
        let q = TaskQueue::new(2);
        q.push_batch_local(0, (0..3).map(task));
        // owner sees its freshest child first (LIFO bottom)
        assert_eq!(q.pop(0).unwrap().seq, 2);
        assert_eq!(q.pop(0).unwrap().seq, 1);
        // a thief would have taken the oldest: steal() pops the top
        q.push_batch_local(0, (10..12).map(task));
        assert_eq!(q.steal().unwrap().seq, 0, "thief takes the oldest task");
    }

    #[test]
    fn stop_drains_pending_work() {
        let q = TaskQueue::new(3);
        q.push_batch((0..3).map(task));
        q.stop_all();
        // all 3 must still be deliverable post-stop, from any slot
        assert!(q.pop(0).is_some());
        assert!(q.pop(1).is_some());
        assert!(q.pop(2).is_some());
        assert!(q.pop(0).is_none());
    }

    #[test]
    fn reopen_rearms_a_stopped_pool() {
        let q = TaskQueue::new(2);
        q.push(task(0));
        q.stop_all();
        assert!(q.pop(0).is_some(), "drain before park");
        assert!(q.pop(0).is_none(), "pass 1 over");
        q.reopen();
        q.push(task(1));
        assert_eq!(q.pop(1).unwrap().seq, 1, "pass 2 delivers (any slot)");
        assert_eq!(q.max_depth(), 1, "depth high-water is per pass");
        q.stop_all();
        assert!(q.pop(0).is_none());
    }

    #[test]
    fn max_depth_tracks_global_pressure() {
        let q = TaskQueue::new(4);
        q.push_batch((0..7).map(task));
        assert_eq!(q.max_depth(), 7, "global depth, not per-deque");
        let (pushed, _) = q.counts();
        assert_eq!(pushed, 7);
    }

    #[test]
    fn subscriber_steal_helps_out_without_a_slot() {
        let q = TaskQueue::new(2);
        assert!(q.steal().is_none(), "empty pool steals nothing");
        q.push_batch((0..4).map(task));
        let mut got = Vec::new();
        while let Some(t) = q.steal() {
            got.push(t.seq);
        }
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 2, 3]);
        assert_eq!(q.steals(), 4);
    }

    #[test]
    fn parked_workers_wake_on_late_pushes() {
        // regression for lost-wakeup bugs: workers park on an empty pool,
        // then tasks arrive in small batches (the counted-notify path)
        let workers = 4;
        let q = Arc::new(TaskQueue::new(workers));
        let consumed = Arc::new(AtomicU32::new(0));
        let mut handles = Vec::new();
        for slot in 0..workers {
            let q = q.clone();
            let consumed = consumed.clone();
            handles.push(std::thread::spawn(move || {
                while q.pop(slot).is_some() {
                    consumed.fetch_add(1, Ordering::Relaxed);
                }
            }));
        }
        // give workers a moment to reach the parked state, then trickle
        std::thread::sleep(std::time::Duration::from_millis(10));
        for i in 0..100u32 {
            if i % 3 == 0 {
                q.push(task(i));
            } else {
                q.push_batch([task(i)]);
            }
        }
        while consumed.load(Ordering::Relaxed) < 100 {
            std::thread::yield_now();
        }
        q.stop_all();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(consumed.load(Ordering::Relaxed), 100);
    }
}
