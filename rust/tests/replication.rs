//! Conformance tests for hot-expert replication: the EWMA-driven
//! `MoeEngine::rebalance` path must never change what the layer
//! computes — only where it computes it. Outputs of a replicated engine
//! are asserted **bitwise identical** to the static-placement engine
//! (the deterministic gate-side splitter preserves the combine fold),
//! within the f32 conformance bound of the dense per-token reference
//! under dropless routing, and bitwise reproducible across engine
//! restarts — for every routing policy × dispatch mode combination.

use std::sync::Arc;

use flashdmoe::config::Config;
use flashdmoe::coordinator::{baseline, MoeEngine, TaskGraphMode};
use flashdmoe::expert::ModelParams;
use flashdmoe::runtime::{ComputeBackend, NativeBackend};
use flashdmoe::util::check::dense_reference_moe;
use flashdmoe::util::prng::Rng;
use flashdmoe::util::stats::max_abs_diff;
use flashdmoe::workload::{skewed_tokens, Skew};

/// 4 ranks over the tiny model (2 owned experts each). `replicated`
/// turns on top-2 / 2-copy replication with a low enter threshold and a
/// fast EWMA so a few warm passes trip the rebalance deterministically.
fn rep_cfg(replicated: bool, policy: &str, dispatch: &str) -> Config {
    let mut cfg = Config::preset("tiny").unwrap();
    cfg.set("ranks", "4").unwrap();
    cfg.set("tokens", "128").unwrap();
    cfg.set("routing_policy", policy).unwrap();
    if dispatch == "hierarchical" {
        cfg.set("nodes", "2").unwrap();
    }
    cfg.set("dispatch", dispatch).unwrap();
    if replicated {
        cfg.set("replicate_top", "2").unwrap();
        cfg.set("replicas", "2").unwrap();
        cfg.set("replication_hysteresis", "1.2").unwrap();
        cfg.set("ewma_alpha", "0.5").unwrap();
    }
    cfg.validate().unwrap();
    cfg
}

/// Zipf-skewed tokens through the production gate, per rank,
/// deterministic in (seed, rank).
fn zipf_inputs(cfg: &Config, params: &ModelParams, seed: u64) -> Vec<Vec<f32>> {
    let (h, e) = (cfg.model.h, cfg.model.e);
    (0..cfg.system.ranks)
        .map(|r| {
            let mut rng = Rng::new(seed).fork(0x7E97_0000 + r as u64);
            skewed_tokens(&params.wg, h, e, cfg.system.s_rank, Skew::Zipf, &mut rng)
        })
        .collect()
}

struct Run {
    outputs: Vec<Vec<f32>>,
    replica_hits: u64,
    placement_version: u64,
    rebalanced: bool,
}

/// Warm passes feed the tracker, one explicit rebalance at the quiet
/// point, then a measured pass.
fn run_engine(cfg: &Config, params: &Arc<ModelParams>, inputs: &[Vec<f32>]) -> Run {
    let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(cfg));
    let engine =
        MoeEngine::start(cfg.clone(), params.clone(), backend, TaskGraphMode::Fused).unwrap();
    for _ in 0..3 {
        engine.submit(inputs).unwrap().wait().unwrap();
    }
    let rebalanced = engine.rebalance().unwrap();
    let res = engine.submit(inputs).unwrap().wait().unwrap();
    engine.shutdown();
    Run {
        outputs: res.outputs,
        replica_hits: res.metrics.replica_hits(),
        placement_version: res.metrics.placement_version,
        rebalanced,
    }
}

fn assert_bitwise(a: &[Vec<f32>], b: &[Vec<f32>], what: &str) {
    for (r, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.len(), y.len(), "{what}: rank {r} output shape diverged");
        for (i, (p, q)) in x.iter().zip(y).enumerate() {
            assert_eq!(
                p.to_bits(),
                q.to_bits(),
                "{what}: rank {r} elem {i}: {p} != {q} (bitwise)"
            );
        }
    }
}

#[test]
fn replicated_engine_matches_dense_reference_and_static_bitwise() {
    let seed = 42;
    let stat_cfg = rep_cfg(false, "dropless", "flat");
    let repl_cfg = rep_cfg(true, "dropless", "flat");
    let params = Arc::new(ModelParams::generate(&stat_cfg, seed));
    let inputs = zipf_inputs(&stat_cfg, &params, seed);

    let stat = run_engine(&stat_cfg, &params, &inputs);
    let repl = run_engine(&repl_cfg, &params, &inputs);

    assert!(!stat.rebalanced, "disabled policy must never rebalance");
    assert!(repl.rebalanced, "Zipf skew past the enter threshold must replicate");
    assert!(repl.placement_version > 0, "measured pass ran pre-rebalance");
    assert!(repl.replica_hits > 0, "no rows ever hit a replica slot");
    assert_eq!(stat.replica_hits, 0, "static placement has no replica slots");

    // replication must not change a single output bit
    assert_bitwise(&stat.outputs, &repl.outputs, "static vs replicated");

    // and both conform to the dense per-token oracle under dropless
    for (r, out) in repl.outputs.iter().enumerate() {
        let want = dense_reference_moe(&repl_cfg, &params, &inputs[r]);
        let diff = max_abs_diff(out, &want);
        assert!(diff < 1e-5, "rank {r}: replicated engine err {diff} vs dense reference");
    }
}

#[test]
fn replication_is_bitwise_reproducible_across_restarts() {
    let seed = 7;
    let cfg = rep_cfg(true, "dropless", "flat");
    let params = Arc::new(ModelParams::generate(&cfg, seed));
    let inputs = zipf_inputs(&cfg, &params, seed);

    let a = run_engine(&cfg, &params, &inputs);
    let b = run_engine(&cfg, &params, &inputs);

    assert_eq!(a.rebalanced, b.rebalanced, "rebalance decision must be deterministic");
    assert_eq!(a.placement_version, b.placement_version, "placement must be deterministic");
    assert_eq!(a.replica_hits, b.replica_hits, "replica routing must be deterministic");
    assert_bitwise(&a.outputs, &b.outputs, "restart A vs restart B");
}

#[test]
fn replication_preserves_outputs_across_policies_and_dispatch_modes() {
    let seed = 11;
    // Routing (including capacity drops) is computed before the
    // placement-aware splitter ever runs, so bitwise identity must hold
    // under Capacity exactly as under Dropless, and the hierarchical
    // proxy hop preserves logical sources, so it must hold there too.
    for policy in ["capacity:1.0", "dropless"] {
        for dispatch in ["flat", "hierarchical"] {
            let stat_cfg = rep_cfg(false, policy, dispatch);
            let repl_cfg = rep_cfg(true, policy, dispatch);
            let params = Arc::new(ModelParams::generate(&stat_cfg, seed));
            let inputs = zipf_inputs(&stat_cfg, &params, seed);

            let stat = run_engine(&stat_cfg, &params, &inputs);
            let repl = run_engine(&repl_cfg, &params, &inputs);

            assert!(repl.rebalanced, "{policy}/{dispatch}: Zipf skew must replicate");
            assert!(repl.replica_hits > 0, "{policy}/{dispatch}: no replica rows");
            assert_bitwise(
                &stat.outputs,
                &repl.outputs,
                &format!("static vs replicated ({policy}, {dispatch})"),
            );
        }
    }
}

#[test]
fn baseline_placed_agrees_with_replicated_engine() {
    let seed = 13;
    let cfg = rep_cfg(true, "dropless", "flat");
    let params = Arc::new(ModelParams::generate(&cfg, seed));
    let inputs = zipf_inputs(&cfg, &params, seed);

    // drive the engine to a replicated placement, snapshot it, and run
    // the bulk-synchronous baseline under that exact placement — a
    // second, independently-scheduled witness for the splitter
    let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(&cfg));
    let engine =
        MoeEngine::start(cfg.clone(), params.clone(), backend.clone(), TaskGraphMode::Fused)
            .unwrap();
    for _ in 0..3 {
        engine.submit(&inputs).unwrap().wait().unwrap();
    }
    assert!(engine.rebalance().unwrap(), "Zipf skew must replicate");
    let placement = engine.placement();
    assert!(placement.has_replicas(), "rebalance installed no replicas");
    let res = engine.submit(&inputs).unwrap().wait().unwrap();
    engine.shutdown();

    let placed =
        baseline::forward_sequential_placed(&cfg, &params, &backend, &inputs, &placement).unwrap();
    for (r, (e, b)) in res.outputs.iter().zip(&placed.outputs).enumerate() {
        let diff = max_abs_diff(e, b);
        assert!(diff < 1e-4, "rank {r}: engine vs placed baseline diverged by {diff}");
    }

    // the placed baseline under the *static* placement must equal the
    // plain baseline bitwise (the delegation is exact)
    let static_placement = flashdmoe::placement::Placement::from_config(&cfg);
    let a = baseline::forward_sequential(&cfg, &params, &backend, &inputs).unwrap();
    let b = baseline::forward_sequential_placed(&cfg, &params, &backend, &inputs, &static_placement)
        .unwrap();
    assert_bitwise(&a.outputs, &b.outputs, "baseline vs placed-static baseline");
}
