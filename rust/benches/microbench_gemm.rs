//! Microbench: tile-level compute on both backends — the calibration
//! source for the simulator's cost model and the §Perf L3 hot-path
//! baseline. Prints GFLOP/s per tile shape for the native blocked GEMM
//! (packed persistent-weight path by default) and (when artifacts exist)
//! the XLA/PJRT Pallas kernels, then A/Bs the packed vs unpacked GEMM
//! kernels per shape and records the result in `BENCH_pr3_hotpath.json`
//! (section `gemm_ab`).
//!
//! `PERF_SMOKE=1` runs the CI perf gate instead: a pinned small shape,
//! best-of-3 A/B, non-zero exit if the packed kernel is slower than the
//! unpacked baseline on the same run.

use std::time::Instant;

use flashdmoe::config::Config;
use flashdmoe::expert::ExpertParams;
use flashdmoe::harness;
use flashdmoe::runtime::{ArtifactStore, ComputeBackend, NativeBackend, XlaBackend};
use flashdmoe::util::prng::Rng;
use flashdmoe::util::stats::{fmt_time, Table};

const BENCH_JSON: &str = "BENCH_pr3_hotpath.json";

fn bench_backend(name: &str, cfg: &Config, be: &dyn ComputeBackend, iters: usize, t: &mut Table) {
    let m = &cfg.model;
    let mut rng = Rng::new(1);
    let ex = ExpertParams {
        w1: rng.normal_vec(m.h * m.d, 0.1),
        b1: rng.normal_vec(m.d, 0.1),
        w2: rng.normal_vec(m.d * m.h, 0.1),
        b2: rng.normal_vec(m.h, 0.1),
    };
    let x = rng.normal_vec(m.bm * m.h, 1.0);
    let mut out = vec![0.0f32; m.bm * m.h];
    let mut scratch = vec![0.0f32; m.bm * m.d];

    // warmup (on the packed backend this is also where the one-time
    // expert pack happens, so the timed loop sees only steady state)
    be.ffn_tile(&x, &ex, 0, &mut out, &mut scratch).unwrap();
    let t0 = Instant::now();
    for _ in 0..iters {
        be.ffn_tile(&x, &ex, 0, &mut out, &mut scratch).unwrap();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    let gflops = m.ffn_flops(m.bm) / per / 1e9;

    // gate
    let s = cfg.system.s_rank;
    let a = rng.normal_vec(s * m.h, 1.0);
    let wg = rng.normal_vec(m.h * m.e, 1.0);
    be.gate_scores(&a, &wg, s).unwrap();
    let t1 = Instant::now();
    for _ in 0..iters {
        be.gate_scores(&a, &wg, s).unwrap();
    }
    let gate = t1.elapsed().as_secs_f64() / iters as f64;

    t.row(&[
        name.to_string(),
        format!("{}x{}x{}", m.bm, m.h, m.d),
        fmt_time(per),
        format!("{gflops:.2}"),
        fmt_time(gate),
    ]);
}

/// CI perf gate: pinned small shape, best-of-3, fail if packed loses.
fn perf_smoke() -> ! {
    let shape = (128usize, 256usize, 512usize); // pinned: (m, k, n)
    let iters = 20;
    let mut best: Option<flashdmoe::harness::GemmAbPoint> = None;
    for round in 0..3 {
        let (_, points) = harness::gemm_backend_ab(&[shape], iters);
        let p = points.into_iter().next().expect("one shape");
        println!(
            "perf-smoke round {round}: unpacked {:.2} GFLOP/s, packed {:.2} GFLOP/s ({:.2}x)",
            p.unpacked_gflops,
            p.packed_gflops,
            p.speedup()
        );
        if best.as_ref().map(|b| p.speedup() > b.speedup()).unwrap_or(true) {
            best = Some(p);
        }
    }
    // persist the round the gate judged (the best one), so the artifact
    // and the pass/fail decision can never disagree
    let best = best.expect("three rounds");
    let best_speedup = best.speedup();
    harness::update_bench_json(
        BENCH_JSON,
        "gemm_ab",
        harness::gemm_ab_json(std::slice::from_ref(&best)),
    )
    .expect("write bench json");
    if best_speedup < 1.0 {
        eprintln!(
            "PERF SMOKE FAILED: packed GEMM slower than unpacked baseline \
             (best speedup {best_speedup:.2}x < 1.0x at {shape:?})"
        );
        std::process::exit(1);
    }
    println!("perf-smoke ok: packed >= unpacked (best {best_speedup:.2}x), {BENCH_JSON} written");
    std::process::exit(0);
}

fn main() {
    if std::env::var("PERF_SMOKE").map(|v| v == "1").unwrap_or(false) {
        perf_smoke();
    }
    let iters: usize = std::env::var("ITERS").ok().and_then(|v| v.parse().ok()).unwrap_or(30);
    let mut t = Table::new(&["backend", "tile (bM,H,D)", "ffn_tile", "GFLOP/s", "gate"]);
    let mut shapes: Vec<(usize, usize, usize)> = Vec::new();
    for preset in ["tiny", "default", "perf"] {
        let cfg = Config::preset(preset).unwrap();
        let native = NativeBackend::from_config(&cfg);
        bench_backend(&format!("native/{preset}"), &cfg, &native, iters, &mut t);
        let unpacked = NativeBackend::with_packed(&cfg, false);
        bench_backend(&format!("native-unpacked/{preset}"), &cfg, &unpacked, iters, &mut t);
        let dir = ArtifactStore::default_dir();
        if preset != "perf" && ArtifactStore::available(&dir) {
            if let Ok(store) = ArtifactStore::load(&dir, preset) {
                let xla = XlaBackend::new(store);
                bench_backend(&format!("xla/{preset}"), &cfg, &xla, iters, &mut t);
            }
        }
        // the two GEMM shapes of the fused FFN at this preset's tile size
        let m = &cfg.model;
        shapes.push((m.bm, m.h, m.d));
        shapes.push((m.bm, m.d, m.h));
    }
    println!("## Microbench — tile compute per backend (calibration source)\n");
    println!("{}", t.render());

    let (text, points) = harness::gemm_backend_ab(&shapes, iters);
    println!("{text}");
    harness::update_bench_json(BENCH_JSON, "gemm_ab", harness::gemm_ab_json(&points))
        .expect("write bench json");
    println!("wrote {BENCH_JSON} (section gemm_ab, {} shapes)", points.len());
}
