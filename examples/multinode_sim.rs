//! Multi-node scenario (paper §F / Fig 17): 4 nodes x 4 GPUs, one local
//! expert per GPU, 25 GB/s NICs. Reproduces the latency curve, the
//! Maximal Incast Volume accounting, and the >2048-token incast failure.
//!
//!     cargo run --release --example multinode_sim

use flashdmoe::config::Config;
use flashdmoe::sim::engines::{simulate, Engine};
use flashdmoe::util::stats::{fmt_bytes, fmt_time, Table};
use flashdmoe::workload::{cluster_workload, Skew};

fn main() -> anyhow::Result<()> {
    println!("## Fig 17 — multi-node FlashDMoE (4x4 ranks, 25 GB/s NIC)\n");
    let mut t = Table::new(&["tokens/GPU", "latency", "MIV (sim)", "MIV (paper formula)", "status"]);
    for tokens in [256usize, 512, 1024, 2048, 4096] {
        let mut cfg = Config::preset("paper_multinode")?;
        cfg.set("tokens", &tokens.to_string())?;
        cfg.validate()?;
        let wl = cluster_workload(&cfg, Skew::Uniform, 42);
        let rep = simulate(&cfg, &wl, Engine::Flash, 42)?;
        // paper §F: MIV = Tokens/Experts * local_experts * precision *
        // hidden * 2 rounds * n_remote_peers
        let n_rg = (cfg.system.ranks - cfg.system.ranks_per_node()) as f64;
        let miv_formula = tokens as f64 / cfg.model.e as f64
            * 1.0
            * 4.0
            * cfg.model.h as f64
            * 2.0
            * n_rg;
        t.row(&[
            tokens.to_string(),
            fmt_time(rep.latency),
            fmt_bytes(rep.max_incast),
            fmt_bytes(miv_formula),
            if rep.incast_overflow { "FAIL: incast buffer overflow".into() } else { "ok".to_string() },
        ]);
    }
    println!("{}", t.render());
    println!(
        "\nthe failure mode past 2048 tokens/GPU reproduces the paper's observed\n\
         non-termination: per-NIC ingress exceeds the receive buffering the\n\
         fabric can absorb in one incast burst (tunable via cost.nic_buffer)."
    );

    // intra vs inter traffic split
    println!("\n## locality split at 1024 tokens/GPU\n");
    let mut cfg = Config::preset("paper_multinode")?;
    cfg.set("tokens", "1024")?;
    let wl = cluster_workload(&cfg, Skew::Uniform, 42);
    let mut intra_rows = 0usize;
    let mut inter_rows = 0usize;
    for (src, w) in wl.iter().enumerate() {
        for tile in &w.plan.tiles {
            if cfg.system.same_node(src, tile.dst as usize) {
                intra_rows += tile.rows as usize;
            } else {
                inter_rows += tile.rows as usize;
            }
        }
    }
    println!(
        "dispatch rows: {} intra-node (NVLink), {} inter-node (NIC) — {}% crosses nodes",
        intra_rows,
        inter_rows,
        inter_rows * 100 / (intra_rows + inter_rows).max(1)
    );
    Ok(())
}
