//! Task abstraction (paper §3.1): the unified tile-granular unit of work
//! exchanged between Subscriber → Scheduler → Processor actors.
//!
//! A task descriptor `t = (M, ⋆, φ)` names a binary tensor op with a fused
//! epilogue over one (bM, bN) or (bM, H) tile:
//!
//! * `Gemm0`   — t1 = (M, ·, relu):  C1 ← relu(A·W1 + b1) tile
//! * `Gemm1`   — t2 = (M, ·, id):    C2 ← C1·W2 + b2 tile
//! * `FusedFfn`— t1∘t2 fused per tile (the `fused` task-graph mode)
//! * `Combine` — t3 = (M, ⊙, id):    C ← A ⊙ s + C
//!
//! Mirrors the paper's Fig 16 `Task` struct: metadata identifies the peer,
//! expert, tile and synchronization cell; dependency edges (Fig 7) are
//! expressed with atomic countdown latches in [`DependencyTable`].

use std::sync::atomic::{AtomicU32, Ordering};

/// Task kind (paper: TaskType ∈ {GEMM0, GEMM1, Combine}; we add the fused
/// FFN variant used by the coarse-grained mode and the gate prologue).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TaskType {
    Gemm0,
    Gemm1,
    FusedFfn,
    Combine,
    /// Backward: dX tile = (dMid ⊙ relu')·W1ᵀ — gradient w.r.t. the
    /// dispatched input rows, shipped back to the source peer.
    Dgrad0,
    /// Backward: dMid tile = dY·W2ᵀ ⊙ relu'(mid) — consumes the incoming
    /// output-grad tile and the stashed forward activations.
    Dgrad1,
    /// Backward: dW1 += xᵀ·dMid, db1 += Σ dMid — folded per expert in
    /// plan order (bitwise-deterministic accumulation).
    Wgrad0,
    /// Backward: dW2 += midᵀ·dY, db2 += Σ dY — same deterministic fold.
    Wgrad1,
}

/// A tile-granular task descriptor (paper Fig 16, minus raw pointers: the
/// processor resolves buffers from the coordinates at execution time,
/// which keeps descriptors trivially `Send`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Task {
    pub task_type: TaskType,
    /// Source peer whose tokens this tile holds.
    pub peer: u32,
    /// Local expert index on the executing rank (Gemm*/FusedFfn) — or, for
    /// Combine, the *global* expert the contribution came from.
    pub expert: u32,
    /// Tile index within the (peer, expert) capacity buffer.
    pub tile: u32,
    /// For Gemm0/Gemm1: output column-tile index along D (Gemm0) or H
    /// (Gemm1). Unused (0) for fused/combine tasks.
    pub col: u32,
    /// Valid rows in the tile (<= bM); the remainder is in-place padding.
    pub rows: u32,
    /// Monotone id for tracing / fairness accounting.
    pub seq: u32,
}

impl Task {
    /// Estimated useful FLOPs of this task, based on the tile's *actual*
    /// row count — the cost-model hook for schedulers and simulators that
    /// weigh tasks (currently exercised by the test suite only). Dropless
    /// dispatch ships variable-length tile lists whose tails carry
    /// `rows < bM` — and the engine's variable-shape `PassInput` passes
    /// (the serving batcher's partially-filled batches) make such tails
    /// routine under *both* policies; costing those at the padded `bm`
    /// would over-weight every tail tile (by up to bM/1). Caveat for consumers: the native
    /// fused backend still *executes* the full padded bM rows per tile,
    /// so for that backend this is the useful-work lower bound on tails,
    /// not the wall-clock cost. `bm` is kept as the upper bound the row
    /// count must respect.
    pub fn flops(&self, h: usize, d: usize, bm: usize, bn: usize) -> f64 {
        debug_assert!(self.rows as usize <= bm, "tile rows {} exceed bM {bm}", self.rows);
        let rows = self.rows as f64;
        match self.task_type {
            TaskType::Gemm0 => 2.0 * rows * h as f64 * bn as f64,
            TaskType::Gemm1 => 2.0 * rows * d as f64 * bn as f64,
            TaskType::FusedFfn => 2.0 * rows * h as f64 * d as f64 * 2.0,
            TaskType::Combine => 2.0 * rows * h as f64,
            // Each backward tile task is one full (rows, h)×(h, d)-shaped
            // GEMM (dgrad: against Wᵀ; wgrad: the Aᵀ·B fold), so the four
            // together cost 8·rows·h·d — exactly 2× the fused forward
            // tile, matching the classic fwd:bwd = 1:2 FLOP ratio.
            TaskType::Dgrad0 | TaskType::Dgrad1 | TaskType::Wgrad0 | TaskType::Wgrad1 => {
                2.0 * rows * h as f64 * d as f64
            }
        }
    }
}

/// Atomic countdown latches implementing the Fig 7 dependency chain:
/// a `Gemm1` column tile becomes ready only after all `Gemm0` column tiles
/// of its (peer, expert, tile) row-block completed (the full (bM, D)
/// intermediate is needed as its left operand).
pub struct DependencyTable {
    latches: Vec<AtomicU32>,
}

impl DependencyTable {
    /// One latch per (peer, local expert, tile) row-block, initialized to
    /// the number of `Gemm0` column tiles (D / bN).
    pub fn new(blocks: usize, gemm0_cols: u32) -> Self {
        Self {
            latches: (0..blocks).map(|_| AtomicU32::new(gemm0_cols)).collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.latches.len()
    }

    pub fn is_empty(&self) -> bool {
        self.latches.is_empty()
    }

    /// Record one completed `Gemm0` column tile; returns true exactly once,
    /// when the row-block's intermediate is fully materialized.
    pub fn complete_one(&self, block: usize) -> bool {
        let prev = self.latches[block].fetch_sub(1, Ordering::AcqRel);
        debug_assert!(prev >= 1, "latch underflow on block {block}");
        prev == 1
    }

    /// Reset a latch (tests / reuse across layer invocations).
    pub fn reset(&self, block: usize, count: u32) {
        self.latches[block].store(count, Ordering::Release);
    }

    pub fn remaining(&self, block: usize) -> u32 {
        self.latches[block].load(Ordering::Acquire)
    }
}

/// Self-correcting task bound (paper Alg. 4 `SelfCorrectTaskBound`): the
/// subscriber learns the true task count only as dispatch signals arrive,
/// so the bound starts at an upper estimate and tightens monotonically;
/// the scheduler exits once `completed == bound` *and* the bound is final.
pub struct TaskBound {
    bound: AtomicU32,
    completed: AtomicU32,
    finalized: AtomicU32,
}

impl Default for TaskBound {
    fn default() -> Self {
        Self::new()
    }
}

impl TaskBound {
    pub fn new() -> Self {
        Self {
            bound: AtomicU32::new(0),
            completed: AtomicU32::new(0),
            finalized: AtomicU32::new(0),
        }
    }

    /// Add newly-discovered tasks to the bound.
    pub fn add(&self, n: u32) {
        self.bound.fetch_add(n, Ordering::AcqRel);
    }

    /// Mark that no further tasks will be discovered.
    pub fn finalize(&self) {
        self.finalized.store(1, Ordering::Release);
    }

    pub fn complete(&self, n: u32) {
        self.completed.fetch_add(n, Ordering::AcqRel);
    }

    pub fn done(&self) -> bool {
        self.finalized.load(Ordering::Acquire) == 1
            && self.completed.load(Ordering::Acquire) >= self.bound.load(Ordering::Acquire)
    }

    pub fn progress(&self) -> (u32, u32) {
        (self.completed.load(Ordering::Acquire), self.bound.load(Ordering::Acquire))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flops_ordering_sane() {
        let t = |task_type| Task { task_type, peer: 0, expert: 0, tile: 0, col: 0, rows: 128, seq: 0 };
        let (h, d, bm, bn) = (256, 512, 128, 64);
        let fused = t(TaskType::FusedFfn).flops(h, d, bm, bn);
        let g0 = t(TaskType::Gemm0).flops(h, d, bm, bn);
        let g1 = t(TaskType::Gemm1).flops(h, d, bm, bn);
        let cmb = t(TaskType::Combine).flops(h, d, bm, bn);
        assert!(fused > g0 + g1, "fused covers all column tiles");
        assert!(cmb < g0.min(g1));
        // fused == sum over all column tiles of split tasks
        let split_total = g0 * (d / bn) as f64 + g1 * (h / bn) as f64;
        assert_eq!(fused, split_total);
        // backward: the four dgrad/wgrad tile tasks together cost exactly
        // 2x the fused forward tile (fwd:bwd = 1:2 in MACs)
        let bwd: f64 = [TaskType::Dgrad0, TaskType::Dgrad1, TaskType::Wgrad0, TaskType::Wgrad1]
            .iter()
            .map(|&ty| t(ty).flops(h, d, bm, bn))
            .sum();
        assert_eq!(bwd, 2.0 * fused, "dgrad+wgrad = 2x forward");
        assert!(t(TaskType::Dgrad1).flops(h, d, bm, bn) > g0 + g1, "one bwd task spans all of D");
    }

    #[test]
    fn flops_scale_with_actual_rows_not_padded_bm() {
        // dropless tails: a 1-row tail tile must cost 1/bM of a full tile,
        // not the same — padded costing skewed LTF ordering & the simulator
        let mk = |rows| Task {
            task_type: TaskType::FusedFfn,
            peer: 0,
            expert: 0,
            tile: 0,
            col: 0,
            rows,
            seq: 0,
        };
        let (h, d, bm, bn) = (256, 512, 128, 64);
        let full = mk(128).flops(h, d, bm, bn);
        let tail = mk(1).flops(h, d, bm, bn);
        assert_eq!(tail * 128.0, full, "cost is linear in valid rows");
        for ty in [
            TaskType::Gemm0,
            TaskType::Gemm1,
            TaskType::Combine,
            TaskType::Dgrad0,
            TaskType::Dgrad1,
            TaskType::Wgrad0,
            TaskType::Wgrad1,
        ] {
            let t32 = Task { task_type: ty, ..mk(32) }.flops(h, d, bm, bn);
            let t128 = Task { task_type: ty, ..mk(128) }.flops(h, d, bm, bn);
            assert_eq!(t32 * 4.0, t128, "{ty:?} cost tracks rows");
        }
    }

    #[test]
    fn dependency_latch_fires_exactly_once() {
        let dt = DependencyTable::new(2, 3);
        assert!(!dt.complete_one(0));
        assert!(!dt.complete_one(0));
        assert!(dt.complete_one(0), "third completion releases the latch");
        assert_eq!(dt.remaining(1), 3, "other blocks untouched");
    }

    #[test]
    fn dependency_latch_concurrent_single_release() {
        let dt = std::sync::Arc::new(DependencyTable::new(1, 64));
        let mut handles = Vec::new();
        let releases = std::sync::Arc::new(AtomicU32::new(0));
        for _ in 0..8 {
            let dt = dt.clone();
            let releases = releases.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..8 {
                    if dt.complete_one(0) {
                        releases.fetch_add(1, Ordering::SeqCst);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(releases.load(Ordering::SeqCst), 1, "exactly one releaser");
    }

    #[test]
    fn task_bound_requires_finalization() {
        let tb = TaskBound::new();
        tb.add(2);
        tb.complete(2);
        assert!(!tb.done(), "not done until finalized");
        tb.finalize();
        assert!(tb.done());
        assert_eq!(tb.progress(), (2, 2));
    }

    #[test]
    fn task_bound_self_corrects_upward() {
        let tb = TaskBound::new();
        tb.add(1);
        tb.finalize();
        tb.add(3); // late-discovered remote work
        tb.complete(1);
        assert!(!tb.done());
        tb.complete(3);
        assert!(tb.done());
    }
}
