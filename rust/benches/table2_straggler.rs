//! Table 2 / Fig 15 — straggler delay within synchronous AllToAll
//! (commercial VM vs supercomputer jitter profiles), plus the PR-7
//! replication A/B that attacks the same pathology on the live engine:
//! under Zipf skew one rank's experts go hot and every synchronous step
//! waits for it; EWMA-driven hot-expert replication shards that load
//! across replica slots without changing a single output bit (bitwise
//! equality and dense-reference conformance are asserted inside the
//! harness).
//!
//! Emits `BENCH_pr7_replication.json` (section `replication_ab`) for the
//! CI artifact upload. With `PERF_SMOKE=1` the run FAILS unless the
//! replicated arm beats the static arm on at least one of the two
//! skew-pain metrics — hot-rank busy-time share or serving p99 — the
//! harness only reports the numbers (it asserts correctness, not the
//! ordering), so this gate is the live CI check that replication
//! actually pays.
//!
//!     cargo bench --bench table2_straggler
fn main() {
    let (text, reports) = flashdmoe::harness::table2(42);
    println!("{text}");
    for r in &reports {
        println!(
            "{}: mean {:.2}x, max {:.2}x over {} steps",
            r.platform.name, r.summary.mean, r.summary.max, r.summary.n
        );
    }

    let (text, pts) = flashdmoe::harness::replication_ab(42).unwrap();
    println!("{text}");

    flashdmoe::harness::update_bench_json(
        "BENCH_pr7_replication.json",
        "replication_ab",
        flashdmoe::harness::replication_json(&pts),
    )
    .unwrap();
    println!("wrote BENCH_pr7_replication.json (section replication_ab)");

    let perf_smoke = std::env::var("PERF_SMOKE").map(|v| v == "1").unwrap_or(false);
    if perf_smoke {
        let stat = pts.iter().find(|p| p.arm == "static").expect("static arm");
        let repl = pts.iter().find(|p| p.arm == "replicated").expect("replicated arm");
        let mut failed = false;
        if repl.replica_hits == 0 {
            eprintln!("PERF_SMOKE FAIL: replicated arm served zero rows from replica slots");
            failed = true;
        }
        let busy_better = repl.hot_rank_busy_share < stat.hot_rank_busy_share;
        let p99_better = repl.serving_p99 < stat.serving_p99;
        if busy_better || p99_better {
            println!(
                "PERF_SMOKE ok: hot-rank busy share {:.1}% -> {:.1}%, serving p99 {:.2}ms -> {:.2}ms",
                stat.hot_rank_busy_share * 100.0,
                repl.hot_rank_busy_share * 100.0,
                stat.serving_p99 * 1e3,
                repl.serving_p99 * 1e3,
            );
        } else {
            eprintln!(
                "PERF_SMOKE FAIL: replication improved neither hot-rank busy share \
                 ({:.1}% -> {:.1}%) nor serving p99 ({:.2}ms -> {:.2}ms) under Zipf skew",
                stat.hot_rank_busy_share * 100.0,
                repl.hot_rank_busy_share * 100.0,
                stat.serving_p99 * 1e3,
                repl.serving_p99 * 1e3,
            );
            failed = true;
        }
        if failed {
            std::process::exit(1);
        }
    }
}
