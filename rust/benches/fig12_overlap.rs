//! Fig 12 — overlap efficiency O_e = T(2)/T(N) under weak scaling
//! (fixed 8K tokens/GPU, E=64).
fn main() {
    let (text, _) = flashdmoe::harness::fig12(42).unwrap();
    println!("{text}");
}
