"""L1 Pallas kernel: the paper's FusedGate (Algorithm 1, line 1).

Computes gate scores G_phi = softmax(A @ Wg) tile-by-tile over the sequence
dimension. The top-k selection and routing-table construction (T_phi) happen
at L2/L3 where the dynamic shapes live; the hot arithmetic — the (bM, H) x
(H, E) logit GEMM fused with a row softmax epilogue — is this kernel.

TPU mapping (DESIGN.md §2): one grid step loads a (bM, H) token tile and the
full (H, E) gate matrix into VMEM, runs the MXU matmul, applies the softmax
epilogue in-register, and writes the (bM, E) score tile. Gate weights are
tiny (H*E floats), so keeping them VMEM-resident across grid steps is the
right schedule.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gate_kernel(a_ref, wg_ref, out_ref):
    """One (bM, H) tile -> (bM, E) softmax scores."""
    logits = jnp.dot(a_ref[...], wg_ref[...], preferred_element_type=jnp.float32)
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    out_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("bm",))
def gate_scores(a: jax.Array, wg: jax.Array, bm: int = 128) -> jax.Array:
    """softmax(A @ Wg) with A: (S, H), Wg: (H, E) -> (S, E) f32.

    S must be a multiple of bm (callers pad the token matrix; see the
    in-place padding discussion in the paper §3.2.1).
    """
    s, h = a.shape
    h2, e = wg.shape
    assert h == h2, f"H mismatch {h} vs {h2}"
    assert s % bm == 0, f"S={s} not a multiple of bm={bm}"
    return pl.pallas_call(
        _gate_kernel,
        grid=(s // bm,),
        in_specs=[
            pl.BlockSpec((bm, h), lambda i: (i, 0)),
            pl.BlockSpec((h, e), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, e), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, e), jnp.float32),
        interpret=True,  # CPU-PJRT execution; Mosaic lowering is TPU-only
    )(a.astype(jnp.float32), wg.astype(jnp.float32))


def topk_route(scores: jax.Array, k: int):
    """Top-k expert selection from gate scores (ties -> lower index).

    Build-time helper used by the L2 graph; returns (indices (S,k) i32,
    weights (S,k) f32).

    Implemented as k rounds of argmax+mask rather than ``jax.lax.top_k``:
    the TopK HLO op carries a ``largest=`` attribute that the pinned
    xla_extension 0.5.1 text parser rejects, while argmax lowers to plain
    reduce ops that round-trip cleanly. ``jnp.argmax`` returns the first
    (lowest-index) maximum, matching lax.top_k tie-breaking.
    """
    s, e = scores.shape
    masked = scores
    idxs, ws = [], []
    for _ in range(k):
        idx = jnp.argmax(masked, axis=-1)
        w = jnp.take_along_axis(masked, idx[:, None], axis=-1)[:, 0]
        idxs.append(idx.astype(jnp.int32))
        ws.append(w.astype(jnp.float32))
        masked = masked.at[jnp.arange(s), idx].set(-jnp.inf)
    return jnp.stack(idxs, axis=-1), jnp.stack(ws, axis=-1)
