//! Deterministic fault injection for chaos testing (ROADMAP item 5).
//!
//! A [`FaultPlan`] turns the [`FaultConfig`] knobs into per-transfer
//! decisions at the `Transport` seam: `NodeFabric` consults the plan on
//! every `put_signal` / coalesced transfer, so chaos schedules exercise
//! the *production* poison → retry → degrade machinery with zero engine
//! changes (see the crate docs' fault-tolerance section).
//!
//! Three fault classes, all decided by a pure function of `(seed, src,
//! dst, pass generation)` so a schedule replays identically run over run:
//!
//! * **Transient transfer faults** — a transfer inside the configured
//!   generation window fails with probability `transient_rate`. A
//!   retried pass runs under a *fresh* generation, so the same logical
//!   transfer re-rolls — which is what makes `retry_limit` recover it.
//! * **Permanent rank death** — from `kill_epoch` on, every transfer
//!   touching `kill_rank` fails. Retrying cannot help; the engine instead
//!   swaps in a degraded [`Placement`](crate::placement::Placement) that
//!   routes around the corpse.
//! * **NIC delay spikes** — an inter-node transfer sleeps `delay_us`
//!   with probability `delay_rate`: injected stragglers for latency
//!   benches, never an error.
//!
//! Injected errors carry stable marker phrases ([`TRANSIENT_MARKER`],
//! [`DEAD_MARKER`]) so the engine's retry driver can classify a failed
//! pass ([`is_transient`], [`is_dead_rank`]) without string-format
//! coupling scattered across the codebase.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::config::FaultConfig;

/// Stable phrase carried by every injected *transient* transfer error.
pub const TRANSIENT_MARKER: &str = "injected transient fault";

/// Stable phrase carried by every injected *permanent rank death* error.
pub const DEAD_MARKER: &str = "permanently dead";

/// True if an error string (typically `format!("{e:#}")` of an engine
/// pass error) stems from an injected transient transfer fault.
pub fn is_transient(msg: &str) -> bool {
    msg.contains(TRANSIENT_MARKER)
}

/// True if an error string stems from a transfer touching a permanently
/// dead rank.
pub fn is_dead_rank(msg: &str) -> bool {
    msg.contains(DEAD_MARKER)
}

/// A live fault schedule: [`FaultConfig`] plus injection counters.
///
/// Constructed once per `NodeFabric` (only when the config
/// [`enabled`](FaultConfig::enabled) something) and shared by every rank
/// actor; all methods take `&self` and are thread-safe.
#[derive(Debug)]
pub struct FaultPlan {
    cfg: FaultConfig,
    injected: AtomicU64,
    delays: AtomicU64,
}

impl FaultPlan {
    /// Build the plan, or `None` when the schedule can never fire (the
    /// common case: the transport then skips the seam entirely).
    pub fn from_config(cfg: &FaultConfig) -> Option<Arc<FaultPlan>> {
        cfg.enabled().then(|| {
            Arc::new(FaultPlan {
                cfg: *cfg,
                injected: AtomicU64::new(0),
                delays: AtomicU64::new(0),
            })
        })
    }

    /// The schedule this plan executes.
    pub fn config(&self) -> &FaultConfig {
        &self.cfg
    }

    /// Deterministic uniform draw in `[0, 1)` for one (src, dst,
    /// generation) transfer, decorrelated across the two fault classes by
    /// `salt`.
    fn roll(&self, src: usize, dst: usize, epoch: u32, salt: u64) -> f64 {
        let key = (src as u64) << 40 ^ (dst as u64) << 20 ^ epoch as u64;
        let h = splitmix64(self.cfg.seed ^ salt ^ splitmix64(key));
        // 53 high bits -> uniform double in [0, 1)
        (h >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Is `rank` permanently dead at pass generation `epoch`?
    pub fn rank_dead(&self, rank: usize, epoch: u32) -> bool {
        self.cfg.kill_rank == Some(rank) && epoch as u64 >= self.cfg.kill_epoch
    }

    /// The rank that is permanently dead at generation `epoch`, if any.
    pub fn dead_rank(&self, epoch: u32) -> Option<usize> {
        self.cfg.kill_rank.filter(|&r| self.rank_dead(r, epoch))
    }

    /// Would the (src, dst) transfer of generation `epoch` fail
    /// transiently? Pure query — no counting, no error.
    pub fn transient_fault(&self, src: usize, dst: usize, epoch: u32) -> bool {
        let e = epoch as u64;
        e >= self.cfg.transient_from
            && (self.cfg.transient_until == 0 || e < self.cfg.transient_until)
            && self.roll(src, dst, epoch, 0x7261_6e73) < self.cfg.transient_rate
    }

    /// Injected straggler delay for a NIC-class transfer, if one fires.
    pub fn delay(&self, src: usize, dst: usize, epoch: u32) -> Option<Duration> {
        (self.cfg.delay_us > 0
            && self.roll(src, dst, epoch, 0x6465_6c61) < self.cfg.delay_rate)
            .then(|| Duration::from_micros(self.cfg.delay_us))
    }

    /// Gate one transfer through the schedule: bail on a dead endpoint or
    /// a transient fault (counting the injection), and — for NIC-class
    /// transfers — sleep through any injected delay spike. Called by the
    /// transport before the payload moves, so a faulted transfer is never
    /// partially delivered.
    pub fn admit(&self, src: usize, dst: usize, epoch: u32, nic: bool) -> Result<()> {
        for r in [dst, src] {
            if self.rank_dead(r, epoch) {
                self.injected.fetch_add(1, Ordering::Relaxed);
                bail!(
                    "injected fault: rank {r} is {DEAD_MARKER} since pass gen {} \
                     (transfer {src} -> {dst}, pass gen {epoch})",
                    self.cfg.kill_epoch
                );
            }
        }
        if self.transient_fault(src, dst, epoch) {
            self.injected.fetch_add(1, Ordering::Relaxed);
            bail!("{TRANSIENT_MARKER}: transfer {src} -> {dst} dropped (pass gen {epoch})");
        }
        if nic {
            if let Some(d) = self.delay(src, dst, epoch) {
                self.delays.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(d);
            }
        }
        Ok(())
    }

    /// Total faults injected (transient + dead-endpoint rejections).
    pub fn faults_injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }

    /// Total NIC delay spikes injected.
    pub fn delays_injected(&self) -> u64 {
        self.delays.load(Ordering::Relaxed)
    }
}

/// SplitMix64 finalizer: a cheap, well-mixed 64-bit hash.
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan(mutate: impl FnOnce(&mut FaultConfig)) -> Arc<FaultPlan> {
        let mut cfg = FaultConfig::default();
        mutate(&mut cfg);
        FaultPlan::from_config(&cfg).expect("schedule should be enabled")
    }

    #[test]
    fn disabled_config_builds_no_plan() {
        assert!(FaultPlan::from_config(&FaultConfig::default()).is_none());
        // a seed alone is not a schedule
        let cfg = FaultConfig { seed: 7, ..FaultConfig::default() };
        assert!(FaultPlan::from_config(&cfg).is_none());
        // delay needs both a rate and a duration
        let cfg = FaultConfig { delay_rate: 1.0, ..FaultConfig::default() };
        assert!(FaultPlan::from_config(&cfg).is_none());
    }

    #[test]
    fn transient_rolls_are_deterministic_and_windowed() {
        let p = plan(|c| {
            c.seed = 123;
            c.transient_rate = 0.5;
            c.transient_from = 2;
            c.transient_until = 6;
        });
        let q = plan(|c| {
            c.seed = 123;
            c.transient_rate = 0.5;
            c.transient_from = 2;
            c.transient_until = 6;
        });
        let mut fired = 0;
        for src in 0..4 {
            for dst in 0..4 {
                for epoch in 0..10u32 {
                    let a = p.transient_fault(src, dst, epoch);
                    assert_eq!(a, q.transient_fault(src, dst, epoch), "same seed, same rolls");
                    if !(2..6).contains(&epoch) {
                        assert!(!a, "fault outside window [2, 6)");
                    }
                    fired += a as usize;
                }
            }
        }
        assert!(fired > 0, "rate 0.5 over 64 in-window rolls must fire sometimes");
        assert!(fired < 4 * 4 * 4, "...but not always");
    }

    #[test]
    fn rate_extremes() {
        let never = plan(|c| {
            c.transient_rate = 0.0;
            c.kill_rank = Some(0); // enable the plan without transients
            c.kill_epoch = u64::MAX;
        });
        let always = plan(|c| c.transient_rate = 1.0);
        for epoch in 1..20u32 {
            assert!(!never.transient_fault(0, 1, epoch));
            assert!(always.transient_fault(0, 1, epoch));
        }
    }

    #[test]
    fn open_ended_window() {
        let p = plan(|c| {
            c.transient_rate = 1.0;
            c.transient_from = 3;
            c.transient_until = 0;
        });
        assert!(!p.transient_fault(0, 1, 2));
        assert!(p.transient_fault(0, 1, 3));
        assert!(p.transient_fault(0, 1, 40_000));
    }

    #[test]
    fn kill_semantics_and_markers() {
        let p = plan(|c| {
            c.kill_rank = Some(2);
            c.kill_epoch = 5;
        });
        assert!(!p.rank_dead(2, 4), "alive before the kill epoch");
        assert!(p.rank_dead(2, 5));
        assert!(p.rank_dead(2, 9));
        assert!(!p.rank_dead(1, 9), "only the configured rank dies");
        assert_eq!(p.dead_rank(4), None);
        assert_eq!(p.dead_rank(5), Some(2));
        // admit classifies: dead endpoint (either side) vs clean transfer
        p.admit(0, 1, 9, false).unwrap();
        let e = p.admit(0, 2, 9, false).unwrap_err();
        assert!(is_dead_rank(&format!("{e:#}")), "dst death is a dead-rank error: {e:#}");
        let e = p.admit(2, 0, 9, true).unwrap_err();
        assert!(is_dead_rank(&format!("{e:#}")), "src death too: {e:#}");
        assert!(!is_transient(&format!("{e:#}")));
        assert_eq!(p.faults_injected(), 2);
    }

    #[test]
    fn transient_admit_counts_and_classifies() {
        let p = plan(|c| c.transient_rate = 1.0);
        let e = p.admit(1, 0, 3, false).unwrap_err();
        let msg = format!("{e:#}");
        assert!(is_transient(&msg), "{msg}");
        assert!(!is_dead_rank(&msg));
        assert_eq!(p.faults_injected(), 1);
        assert_eq!(p.delays_injected(), 0);
    }

    #[test]
    fn delay_spikes_only_on_nic_transfers() {
        let p = plan(|c| {
            c.delay_rate = 1.0;
            c.delay_us = 1;
        });
        assert!(p.delay(0, 1, 1).is_some());
        p.admit(0, 1, 1, false).unwrap();
        assert_eq!(p.delays_injected(), 0, "intra-node transfers never sleep");
        p.admit(0, 1, 1, true).unwrap();
        assert_eq!(p.delays_injected(), 1);
        assert_eq!(p.faults_injected(), 0, "a delay is not a fault");
    }
}
