//! Training subsystem: run the MoE backward pass through the *same*
//! persistent engine that serves forwards, and step the parameters.
//!
//! The pieces:
//!
//! * the engine-side autograd tape — forward passes with
//!   `cfg.system.train` enabled stash routing indices, gate
//!   probabilities and per-tile activations inside the rank actors
//!   (see `coordinator/rank.rs`), so a backward can be issued for any
//!   recent forward epoch like any other pass:
//!   [`MoeEngine::backward`](crate::coordinator::MoeEngine::backward)
//!   scatters output-grads to expert owners over the same wire (at the
//!   configured `WirePrecision`), runs `Dgrad/Wgrad` tile tasks through
//!   the same work-stealing scheduler, and gathers input-grads back;
//! * [`GradStore`] / [`ExpertGrad`] — gradient containers with a fixed
//!   tensor traversal order (deterministic folds everywhere);
//! * [`Optimizer`] — SGD (plain/momentum) and Adam over that traversal;
//! * [`Trainer`] — owns the engine + a master parameter copy, folds
//!   per-micro-batch gradients across `grad_accum_steps`, steps the
//!   optimizer, and installs updated weights at an epoch-fenced quiet
//!   point (`MoeEngine::update_params`).
//!
//! ```no_run
//! use std::sync::Arc;
//! use flashdmoe::config::Config;
//! use flashdmoe::coordinator::{MoeEngine, TaskGraphMode};
//! use flashdmoe::expert::ModelParams;
//! use flashdmoe::runtime::{ComputeBackend, NativeBackend};
//! use flashdmoe::train::{Optimizer, Trainer};
//!
//! let mut cfg = Config::preset("tiny").unwrap();
//! cfg.set("train", "on").unwrap();
//! let params = Arc::new(ModelParams::generate(&cfg, 42));
//! let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(&cfg));
//! let engine = MoeEngine::start(cfg.clone(), params, backend, TaskGraphMode::Fused).unwrap();
//! let mut trainer = Trainer::new(engine, Optimizer::adam(1e-3)).unwrap();
//! // inputs/targets: one (s_rank*h) row-major buffer per rank
//! # let (inputs, targets): (Vec<Vec<f32>>, Vec<Vec<f32>>) = (vec![], vec![]);
//! let report = trainer.train_step(&inputs, &targets).unwrap();
//! println!("loss {:.6} applied={}", report.loss, report.applied);
//! ```

pub mod grad;
pub mod optim;

pub use grad::{param_tensors_mut, ExpertGrad, GradStore};
pub use optim::Optimizer;

use anyhow::{ensure, Context, Result};

use crate::coordinator::{BackwardResult, MoeEngine, PassInput, PassMetrics};
use crate::expert::ModelParams;

/// The caller-side record of one stashed forward pass: enough to issue
/// its backward ([`Trainer::backward`]) and to compute a loss against
/// its outputs. The activation stash itself lives inside the rank
/// actors, keyed by this epoch.
pub struct MoeTape {
    /// Engine epoch of the forward pass (the backward's stash key).
    pub epoch: u64,
    /// Per-rank (rows, H) row-major outputs of the forward.
    pub outputs: Vec<Vec<f32>>,
    pub metrics: PassMetrics,
}

/// One `train_step` outcome.
#[derive(Clone, Debug)]
pub struct StepReport {
    /// Mean-squared-error loss of this micro-batch.
    pub loss: f64,
    /// Whether this step crossed the accumulation window and applied an
    /// optimizer update (params installed into the engine).
    pub applied: bool,
    /// Squared L2 norm of this micro-batch's gradients (diagnostics).
    pub grad_sq_norm: f64,
    /// Forward epoch of the micro-batch.
    pub epoch: u64,
}

/// Owns a training engine plus the master parameter copy, and drives
/// forward → backward → (accumulate) → optimizer step → install.
pub struct Trainer {
    engine: MoeEngine,
    opt: Optimizer,
    /// Master f32 parameters; the engine holds an immutable snapshot
    /// that `update_params` swaps at a quiet point after each update.
    params: ModelParams,
    accum: GradStore,
    /// Micro-batches folded into `accum` since the last apply.
    pending: usize,
    accum_target: usize,
    /// The optimizer's construction-time rate — what the schedule scales.
    base_lr: f32,
    /// Per-update learning-rate schedule (knob `lr_schedule`): update N
    /// runs at `base_lr × schedule.factor(N)`.
    schedule: crate::config::LrSchedule,
    /// Optimizer updates applied so far.
    pub updates: u64,
}

impl Trainer {
    /// Wrap a started engine. The engine must have been started with
    /// training enabled (`cfg.system.train.enabled` — knob `train=on`),
    /// which turns on the per-pass activation stash. The engine config's
    /// `lr_schedule` scales the optimizer's rate per update (its
    /// construction-time `lr` is the base the schedule multiplies).
    pub fn new(engine: MoeEngine, opt: Optimizer) -> Result<Self> {
        let tc = &engine.config().system.train;
        ensure!(
            tc.stash(),
            "Trainer requires activation stashing: start the engine with train=on \
             (or stash_activations=on)"
        );
        let accum_target = tc.grad_accum_steps.max(1);
        let (base_lr, schedule) = (opt.lr(), tc.lr_schedule);
        let params = engine.params().as_ref().clone();
        let accum = GradStore::zeros_like(&params);
        Ok(Self {
            engine,
            opt,
            params,
            accum,
            pending: 0,
            accum_target,
            base_lr,
            schedule,
            updates: 0,
        })
    }

    pub fn engine(&self) -> &MoeEngine {
        &self.engine
    }

    pub fn params(&self) -> &ModelParams {
        &self.params
    }

    pub fn optimizer(&self) -> &Optimizer {
        &self.opt
    }

    /// Shut the engine down, returning the trained parameters.
    pub fn finish(self) -> ModelParams {
        self.engine.shutdown();
        self.params
    }

    /// Run one stashed forward pass (per-rank (rows, H) inputs).
    pub fn forward(&self, inputs: &[Vec<f32>]) -> Result<MoeTape> {
        let fr = self
            .engine
            .submit_pass(PassInput::new(inputs.to_vec()))?
            .wait()
            .context("training forward pass")?;
        Ok(MoeTape { epoch: fr.metrics.epoch, outputs: fr.outputs, metrics: fr.metrics })
    }

    /// Issue the backward for a stashed forward, fold its parameter
    /// gradients into the accumulation window, and — once
    /// `grad_accum_steps` micro-batches are in — apply the optimizer
    /// and install the updated weights. Returns the raw backward result
    /// (input grads + this micro-batch's parameter grads) plus whether
    /// an update was applied.
    pub fn backward(
        &mut self,
        tape: &MoeTape,
        grad_out: &[Vec<f32>],
    ) -> Result<(BackwardResult, bool)> {
        let bwd = self.engine.backward(tape.epoch, grad_out)?;
        self.accum.add_assign(&bwd.grads);
        self.pending += 1;
        let applied = if self.pending >= self.accum_target {
            self.apply_update()?;
            true
        } else {
            false
        };
        Ok((bwd, applied))
    }

    /// Force the optimizer step on whatever is accumulated (end of an
    /// epoch with a ragged final window). No-op when nothing is pending.
    pub fn apply_update(&mut self) -> Result<()> {
        if self.pending == 0 {
            return Ok(());
        }
        // average over the window so lr is per-micro-batch-scale-free
        self.accum.scale(1.0 / self.pending as f32);
        // evaluate the schedule for *this* update (0-indexed; Const keeps
        // the base rate, so the default path is bitwise-unchanged)
        self.opt.set_lr(self.base_lr * self.schedule.factor(self.updates) as f32);
        self.opt.step(&mut self.params, &self.accum);
        self.engine
            .update_params(self.params.clone())
            .context("installing updated parameters")?;
        self.accum.zero();
        self.pending = 0;
        self.updates += 1;
        Ok(())
    }

    /// Convenience: one MSE regression micro-batch. `targets` mirror the
    /// per-rank shape of `inputs`' outputs; loss is the element-mean of
    /// (out − target)², dLoss/dout = 2(out − target)/N.
    pub fn train_step(&mut self, inputs: &[Vec<f32>], targets: &[Vec<f32>]) -> Result<StepReport> {
        let tape = self.forward(inputs)?;
        ensure!(
            targets.len() == tape.outputs.len(),
            "targets cover {} ranks, outputs {}",
            targets.len(),
            tape.outputs.len()
        );
        let n_total: usize = tape.outputs.iter().map(|o| o.len()).sum();
        ensure!(n_total > 0, "empty training batch");
        let mut loss = 0.0f64;
        let mut dy = Vec::with_capacity(tape.outputs.len());
        for (o, t) in tape.outputs.iter().zip(targets) {
            ensure!(o.len() == t.len(), "target shape mismatch");
            let mut g = vec![0.0f32; o.len()];
            for ((gv, &ov), &tv) in g.iter_mut().zip(o).zip(t) {
                let diff = ov - tv;
                loss += (diff as f64) * (diff as f64);
                *gv = 2.0 * diff / n_total as f32;
            }
            dy.push(g);
        }
        loss /= n_total as f64;
        let (bwd, applied) = self.backward(&tape, &dy)?;
        Ok(StepReport { loss, applied, grad_sq_norm: bwd.grads.sq_norm(), epoch: tape.epoch })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    use crate::config::Config;
    use crate::coordinator::TaskGraphMode;
    use crate::expert::generate_tokens;
    use crate::runtime::{ComputeBackend, NativeBackend};

    #[test]
    fn lr_schedule_decays_across_trainer_steps() {
        let mut cfg = Config::preset("tiny").unwrap();
        cfg.set("train", "on").unwrap();
        cfg.set("lr_schedule", "step:1:0.5").unwrap();
        let params = Arc::new(crate::expert::ModelParams::generate(&cfg, 42));
        let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(&cfg));
        let engine = MoeEngine::start(cfg.clone(), params, backend, TaskGraphMode::Fused).unwrap();
        let mut trainer = Trainer::new(engine, Optimizer::sgd(0.8)).unwrap();
        let inputs: Vec<Vec<f32>> =
            (0..cfg.system.ranks).map(|r| generate_tokens(&cfg, 1, r)).collect();
        let targets = inputs.clone();
        // step:1:0.5 halves the rate every update: 0.8, 0.4, 0.2, ...
        let mut seen = Vec::new();
        for _ in 0..3 {
            let report = trainer.train_step(&inputs, &targets).unwrap();
            assert!(report.applied, "grad_accum_steps=1 applies every step");
            seen.push(trainer.optimizer().lr());
        }
        assert_eq!(seen, vec![0.8, 0.4, 0.2], "schedule must decay across steps");
        assert_eq!(trainer.updates, 3);
        trainer.finish();
    }
}
