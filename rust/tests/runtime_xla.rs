//! XLA/PJRT-path tests: AOT artifacts loaded and executed from Rust, the
//! XLA backend vs the native backend, and the headline end-to-end check —
//! the multi-rank coordinator against the monolithic `moe_layer` artifact.
//!
//! These tests require `make artifacts`; they are skipped (pass
//! trivially, with a note) when the manifest is absent so `cargo test`
//! works from a clean checkout.

use std::path::PathBuf;
use std::sync::Arc;

use flashdmoe::coordinator::{DistributedMoE, TaskGraphMode};
use flashdmoe::expert::{generate_tokens, ExpertParams, ModelParams};
use flashdmoe::runtime::{ArtifactStore, ComputeBackend, NativeBackend, XlaBackend};
use flashdmoe::util::prng::Rng;
use flashdmoe::util::stats::max_abs_diff;

fn artifacts_dir() -> Option<PathBuf> {
    // tests run from the crate root
    let dir = ArtifactStore::default_dir();
    if ArtifactStore::available(&dir) {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

#[test]
fn artifact_store_loads_all_kernels() {
    let Some(dir) = artifacts_dir() else { return };
    let store = ArtifactStore::load(&dir, "tiny").unwrap();
    let names = store.kernel_names();
    for want in ["gate", "ffn_block", "ffn_tile", "gemm0_tile", "gemm1_tile", "combine_tile", "moe_layer"] {
        assert!(names.contains(&want), "missing artifact {want}");
    }
    assert!(store.compile_secs > 0.0);
    assert!(ArtifactStore::load(&dir, "nope").is_err());
}

#[test]
fn xla_gate_matches_native_gate() {
    let Some(dir) = artifacts_dir() else { return };
    let store = ArtifactStore::load(&dir, "tiny").unwrap();
    let cfg = store.config.clone();
    let xla = XlaBackend::new(store);
    let native = NativeBackend::from_config(&cfg);
    let mut rng = Rng::new(4);
    let s = cfg.system.s_rank;
    let a = rng.normal_vec(s * cfg.model.h, 1.0);
    let wg = rng.normal_vec(cfg.model.h * cfg.model.e, 1.0);
    let gx = xla.gate_scores(&a, &wg, s).unwrap();
    let gn = native.gate_scores(&a, &wg, s).unwrap();
    assert!(max_abs_diff(&gx, &gn) < 1e-4, "gate backends disagree");
    // shape-specialization is enforced
    assert!(xla.gate_scores(&a[..cfg.model.h], &wg, 1).is_err());
}

#[test]
fn xla_ffn_tile_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let store = ArtifactStore::load(&dir, "tiny").unwrap();
    let cfg = store.config.clone();
    let m = &cfg.model;
    let xla = XlaBackend::new(store);
    let native = NativeBackend::from_config(&cfg);
    let mut rng = Rng::new(5);
    let ex = ExpertParams {
        w1: rng.normal_vec(m.h * m.d, 0.1),
        b1: rng.normal_vec(m.d, 0.1),
        w2: rng.normal_vec(m.d * m.h, 0.1),
        b2: rng.normal_vec(m.h, 0.1),
    };
    let x = rng.normal_vec(m.bm * m.h, 1.0);
    let mut ox = vec![0.0; m.bm * m.h];
    let mut on = vec![0.0; m.bm * m.h];
    let mut scratch = vec![0.0; m.bm * m.d];
    xla.ffn_tile(&x, &ex, 0, &mut ox, &mut scratch).unwrap();
    native.ffn_tile(&x, &ex, 0, &mut on, &mut scratch).unwrap();
    assert!(max_abs_diff(&ox, &on) < 1e-3, "ffn_tile backends disagree");
}

#[test]
fn gemm_tiles_match_native() {
    let Some(dir) = artifacts_dir() else { return };
    let store = ArtifactStore::load(&dir, "tiny").unwrap();
    let cfg = store.config.clone();
    let m = &cfg.model;
    let xla = XlaBackend::new(store);
    let native = NativeBackend::from_config(&cfg);
    let mut rng = Rng::new(6);
    let x = rng.normal_vec(m.bm * m.h, 1.0);
    let w1c = rng.normal_vec(m.h * m.bn, 0.1);
    let b1c = rng.normal_vec(m.bn, 0.1);
    let mut ox = vec![0.0; m.bm * m.bn];
    let mut on = vec![0.0; m.bm * m.bn];
    // expert 0's packed cache is empty on both backends, so the native
    // side exercises the unpacked fallback against the raw slices
    xla.gemm0_tile(&x, &w1c, &b1c, &mut ox, 0, 0).unwrap();
    native.gemm0_tile(&x, &w1c, &b1c, &mut on, 0, 0).unwrap();
    assert!(max_abs_diff(&ox, &on) < 1e-3);

    let h2 = rng.normal_vec(m.bm * m.d, 1.0);
    let w2c = rng.normal_vec(m.d * m.bn, 0.1);
    let b2c = rng.normal_vec(m.bn, 0.1);
    xla.gemm1_tile(&h2, &w2c, &b2c, &mut ox, 0, 0).unwrap();
    native.gemm1_tile(&h2, &w2c, &b2c, &mut on, 0, 0).unwrap();
    assert!(max_abs_diff(&ox, &on) < 1e-3);
}

/// The headline E2E: multi-rank distributed forward (both backends, both
/// task-graph modes) ≡ the monolithic L2 `moe_layer` artifact.
#[test]
fn distributed_forward_matches_monolithic_artifact() {
    let Some(dir) = artifacts_dir() else { return };
    let store = ArtifactStore::load(&dir, "tiny").unwrap();
    let cfg = store.config.clone();
    let params = Arc::new(ModelParams::generate(&cfg, 77));
    let inputs: Vec<Vec<f32>> =
        (0..cfg.system.ranks).map(|r| generate_tokens(&cfg, 77, r)).collect();
    let a_all: Vec<f32> = inputs.concat();
    let want = store.run_moe_layer(&a_all, &params).unwrap();

    // native backend, fused mode
    let native: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(&cfg));
    let got = DistributedMoE::new(cfg.clone(), params.clone(), native.clone(), TaskGraphMode::Fused)
        .unwrap()
        .forward(&inputs)
        .unwrap();
    let flat: Vec<f32> = got.outputs.concat();
    assert!(max_abs_diff(&flat, &want) < 1e-3, "native/fused vs artifact");

    // native backend, split mode
    let got = DistributedMoE::new(cfg.clone(), params.clone(), native, TaskGraphMode::Split)
        .unwrap()
        .forward(&inputs)
        .unwrap();
    let flat: Vec<f32> = got.outputs.concat();
    assert!(max_abs_diff(&flat, &want) < 1e-3, "native/split vs artifact");

    // xla backend (the AOT Pallas kernels on the hot path), fused mode
    let xla: Arc<dyn ComputeBackend> = Arc::new(XlaBackend::new(store));
    let got = DistributedMoE::new(cfg.clone(), params.clone(), xla, TaskGraphMode::Fused)
        .unwrap()
        .forward(&inputs)
        .unwrap();
    let flat: Vec<f32> = got.outputs.concat();
    assert!(max_abs_diff(&flat, &want) < 1e-3, "xla/fused vs artifact");
}

#[test]
fn manifest_capacity_contract_is_checked() {
    let Some(dir) = artifacts_dir() else { return };
    // loading validates capacity math between python and rust; a passing
    // load IS the assertion (mismatch -> Err)
    let store = ArtifactStore::load(&dir, "default").unwrap();
    assert_eq!(
        store.config.model.capacity(store.config.system.s_rank) % store.config.model.bm,
        0
    );
}
