//! Deterministic PRNG (no external crates available offline).
//!
//! `SplitMix64` seeds `Xoshiro256**`; normal deviates come from the
//! Box–Muller transform. Streams are cheap to fork by key so every rank /
//! expert / workload generator draws from an independent, reproducible
//! stream — the same scheme seeds both the Rust side and the synthetic
//! workloads the benches replay.

/// SplitMix64 — used for seeding and as a tiny standalone generator.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — the workhorse generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// spare Box–Muller deviate
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            spare: None,
        }
    }

    /// Fork an independent stream keyed by `key` (stable across runs).
    pub fn fork(&self, key: u64) -> Self {
        // mix the current state with the key through SplitMix
        let mut sm = SplitMix64::new(self.s[0] ^ key.wrapping_mul(0xA24B_AED4_963E_E407));
        Self::new(sm.next_u64())
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // multiply-shift; bias negligible for our n << 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    /// Normal f32 with mean/std.
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Lognormal deviate: exp(N(mu, sigma)).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Fill a buffer with N(0, std) f32 values.
    pub fn fill_normal(&mut self, buf: &mut [f32], std: f32) {
        for v in buf.iter_mut() {
            *v = self.normal_f32(0.0, std);
        }
    }

    /// Vector of N(0, std) f32 values.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        let mut v = vec![0.0; n];
        self.fill_normal(&mut v, std);
        v
    }

    /// Sample from a Zipf(s) distribution over [0, n) (used to skew expert
    /// routing the way real MoE token distributions skew).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // inverse-CDF over precomputable harmonic weights would be nicer,
        // but n is small (experts); a linear scan is fine and allocation-free.
        let mut total = 0.0;
        for i in 1..=n {
            total += 1.0 / (i as f64).powf(s);
        }
        let mut u = self.f64() * total;
        for i in 1..=n {
            u -= 1.0 / (i as f64).powf(s);
            if u <= 0.0 {
                return i - 1;
            }
        }
        n - 1
    }

    /// Random permutation of 0..n (Fisher–Yates).
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forked_streams_differ() {
        let base = Rng::new(42);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(7);
        let m: f64 = (0..10_000).map(|_| r.f64()).sum::<f64>() / 10_000.0;
        assert!((m - 0.5).abs() < 0.02, "mean {m}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn zipf_is_skewed() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 8];
        for _ in 0..8000 {
            counts[r.zipf(8, 1.2)] += 1;
        }
        assert!(counts[0] > counts[7] * 2, "{counts:?}");
    }

    #[test]
    fn permutation_is_valid() {
        let mut r = Rng::new(11);
        let mut p = r.permutation(32);
        p.sort_unstable();
        assert_eq!(p, (0..32).collect::<Vec<_>>());
    }
}
