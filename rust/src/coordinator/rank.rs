//! One rank's resident "persistent kernel": dispatch (Alg. 1), the
//! Subscriber decode loop (Alg. 4), and the Processor execution loop
//! (Alg. 2), all hosted by threads that are spawned **once** at engine
//! start and stay parked on doorbells between passes.
//!
//! A [`RankActor`] owns one rank's actor group: the subscriber context
//! (the rank's main thread, driven per pass by the engine) plus N
//! resident processor workers. A pass begins when the engine rings the
//! rank's doorbell with an epoch-tagged [`PassCtx`]; the subscriber gates
//! its tokens, announces its per-(destination, expert) dispatch-tile
//! counts (so every receiver can size its dependency tables, staging and
//! flag-sweep bounds to the pass's *actual* — possibly dropless,
//! variable-length — tile lists), dispatches tiles with one-sided
//! put+signal (stamped with the pass generation), then polls the
//! symmetric heap's signal flags for packets of *this* generation,
//! decodes them into task descriptors, deals the work round-robin into
//! the per-processor work-stealing pool (and turns thief itself when the
//! sweep idles — the help-out path), and interrupts the processors once
//! the self-correcting task bound is met. Processor
//! workers execute FFN/GEMM/Combine tasks via the configured
//! [`ComputeBackend`] — on the packed persistent-weight GEMM path by
//! default (weights panel-packed once at engine start, never per pass) —
//! and write combine packets straight back to the
//! originating rank — no collective, no host round-trip, and no thread
//! spawned anywhere on the steady-state path.
//!
//! Combine determinism: a combine task scales its tile into a private
//! staging block; the subscriber thread folds the blocks into the output
//! in dispatch-plan order after the processors park. The f32 reduction
//! order is therefore fixed, making pass outputs bitwise reproducible
//! regardless of scheduling interleavings or processor count.
//!
//! Wire precision: dispatch and combine payloads are encoded to the
//! configured `WirePrecision` inside `SymmetricHeap::put_signal` and
//! decoded back to f32 before any GEMM touches them. On an f32 wire the
//! cells *are* f32, so reads stay zero-copy borrows (`read_borrowed`) —
//! the hot path is unchanged from before the wire subsystem existed. On
//! a 16-bit wire each worker decodes into its own `xbuf`, and in split
//! mode the subscriber decodes each dispatch tile exactly once into
//! `x_stage` so the D/bN Gemm0 column tasks share one copy. Compute —
//! gate, FFN, combine scaling and the fold — is f32 throughout, so an
//! `F32` wire reproduces the pre-wire-subsystem outputs bit for bit, and
//! 16-bit wires stay bitwise deterministic (round-to-nearest-even is
//! schedule-free).
//!
//! Multi-node transport: every one-sided transfer goes through the
//! [`NodeFabric`] (`crate::transport`), which classifies each (src, dst)
//! pair as NVLink (same node) or NIC (cross-node) and admits NIC traffic
//! against a bounded per-destination receive window. Under
//! `DispatchMode::Hierarchical` the dispatch loop coalesces each remote
//! node's *unique* token rows into one NIC transfer to a proxy rank,
//! which fans the per-tile payloads out intra-node via delegated writes
//! that preserve the logical source — so flags, announcements, the
//! combine protocol and the plan-order fold are identical to the flat
//! path, and the two modes produce bitwise-equal outputs. A put that the
//! NIC window rejects (incast overflow) *poisons* the pass generation
//! via `EngineShared::pass_poisoned`; every peer's subscriber checks the
//! stamp each sweep and abandons the pass with an error instead of
//! spinning into the watchdog waiting for tiles that will never arrive.

use std::cell::UnsafeCell;
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Barrier, Condvar, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::Config;
use crate::expert::ModelParams;
use crate::gate::{dispatch_plan, route_from_scores, DispatchPlan, DispatchTile};
use crate::gemm;
use crate::placement::{LoadTracker, Placement};
use crate::registry::{DeltaSet, ModelRegistry};
use crate::train::grad::ExpertGrad;
use crate::transport::{NodeFabric, Transport};
use crate::layout::{Coord, LayoutDims};
use crate::runtime::ComputeBackend;
use crate::task::{DependencyTable, Task, TaskType};

use super::metrics::RankMetrics;
use super::scheduler::TaskQueue;

/// Task-graph granularity (DESIGN.md §6): `Fused` runs one FFN task per
/// tile (both GEMMs fused, the Pallas `ffn_tile` unit); `Split` runs the
/// paper's GEMM0→GEMM1 chain with per-block dependency latches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskGraphMode {
    Fused,
    Split,
}

/// State shared by every rank actor for the whole engine lifetime.
pub struct EngineShared {
    pub cfg: Config,
    /// Policy-aware per-(source, expert) slot-region size (see
    /// [`ModelConfig::slot_capacity`](crate::config::ModelConfig::slot_capacity)):
    /// the fixed capacity under `Capacity`, the worst-case region under
    /// `Dropless`. Only the announced tiles of a pass are ever touched.
    pub capacity: usize,
    pub dims: LayoutDims,
    /// The live model parameters. Swapped whole-`Arc` by
    /// `MoeEngine::update_params` *between* passes only (epoch-fenced,
    /// like placement swaps), so every rank of a pass snapshots one
    /// version at pass start (`PassCtx::params`) and a training update
    /// never tears mid-pass.
    params: RwLock<Arc<ModelParams>>,
    /// Per-rank activation stashes for training backwards, keyed by
    /// forward-pass epoch. Bounded by [`STASH_CAP`] (oldest evicted);
    /// populated only when `cfg.system.train.stash()` is on and the
    /// engine runs in `Fused` mode.
    pub stashes: Vec<Mutex<BTreeMap<u64, Arc<RankStash>>>>,
    /// The node-aware transport every one-sided transfer goes through:
    /// the symmetric heap wrapped in the configured topology and NIC
    /// model (`crate::transport`). Intra-node puts hit the heap
    /// directly; cross-node puts are admitted against the NIC's bounded
    /// receive window first — so incast overflow surfaces here as a put
    /// error, not as a formula.
    pub fabric: Arc<NodeFabric>,
    pub backend: Arc<dyn ComputeBackend>,
    pub mode: TaskGraphMode,
    /// Dispatch tiles destined to each rank in the current pass
    /// (accumulated by sources; cleared by rank 0 inside the pass-start
    /// barrier pair).
    pub expected_dispatch: Vec<AtomicU32>,
    /// Per-(dst rank, src rank, dst-local expert) dispatch-tile counts for
    /// the current pass, announced by each source right after gating and
    /// *before* it dispatches. The destination sizes its dependency
    /// tables, staging buffers and flag-sweep bounds from these dynamic
    /// counts instead of the static worst-case capacity — which is what
    /// keeps `Dropless` passes (whose per-expert tile counts vary wildly
    /// with gate skew) from paying worst-case bookkeeping. The `Capacity`
    /// policy keeps its small fixed worst case instead and never waits on
    /// these counts, preserving full gate/dispatch overlap across ranks.
    /// Indexed by [`EngineShared::announce_idx`]; cleared by rank 0
    /// inside the pass-start barrier pair.
    pub announced_tiles: Vec<AtomicU32>,
    /// Sources that have finished announcing in the current pass.
    pub announced: AtomicU32,
    /// Per-slot pass-generation poison stamps (0 = none): a rank whose
    /// dispatch or combine put fails — NIC incast overflow or an injected
    /// fault being the expected cases — stamps its pass generation here
    /// so every peer's subscriber stops waiting for the packets that will
    /// never arrive and fails its pass promptly instead of tripping the
    /// watchdog. Rank 0 clears only the *current* epoch's slot inside the
    /// pass-start barrier pair, so with two passes pipelined a clear for
    /// pass N+1 can never erase a still-unobserved stamp for pass N (the
    /// other slot) — see [`PoisonLatch`].
    pub pass_poisoned: PoisonLatch,
    /// The reusable pass-start barrier. Besides synchronizing the pass,
    /// it is the fence that orders pass n's heap readers before pass
    /// n+1's writers on the same cells (see `fabric.rs` safety notes).
    pub start: Barrier,
    /// OS threads ever spawned under this engine. Grows only during
    /// `MoeEngine::start`; a steady-state pass spawns nothing.
    pub threads_spawned: AtomicU64,
    /// The live expert→location placement every pass consults: the
    /// dispatch plan reads it to split hot experts over replicas, and
    /// task execution reads it to resolve which expert a replica slot is
    /// serving. Swapped whole-`Arc` by `MoeEngine::rebalance` *between*
    /// passes only (the epoch fence guarantees no pass is in flight
    /// during a swap), so every rank of a given pass snapshots the same
    /// version at pass start.
    pub placement: Mutex<Arc<Placement>>,
    /// Per-expert offered-load EWMA feeding the replication planner.
    /// The engine observes each pass's `expert_offered` histogram here;
    /// `rebalance` consumes it. Separate lock from `placement` — the
    /// tracker is written every pass, the placement only at rebalance.
    pub tracker: Mutex<LoadTracker>,
    /// The model table for multi-model residency (ROADMAP item 5):
    /// fingerprinted registration with packed-weight dedup, LoRA-style
    /// delta variants, and per-model placement/tracker state for ids
    /// `1..max_models`. The anchor model (id 0) keeps using the legacy
    /// fields above — a `max_models = 1` engine is bitwise-identical to
    /// a registry-free one. Mutated only at the engine's epoch-fenced
    /// quiet point.
    pub registry: Arc<ModelRegistry>,
}

impl EngineShared {
    pub fn new(
        cfg: Config,
        params: Arc<ModelParams>,
        fabric: Arc<NodeFabric>,
        backend: Arc<dyn ComputeBackend>,
        mode: TaskGraphMode,
    ) -> Self {
        let capacity = cfg.model.slot_capacity(cfg.system.s_rank);
        let dims = LayoutDims::from_config(&cfg);
        let ranks = cfg.system.ranks;
        // `dims.e_local` counts expert *slots* (owned + replica), so the
        // announce tables cover replica traffic with no special cases.
        let e_slots = dims.e_local;
        let placement = Arc::new(Placement::from_config(&cfg));
        let tracker =
            LoadTracker::new(cfg.model.e, ranks, cfg.system.replication.ewma_alpha);
        let registry = Arc::new(ModelRegistry::new(&cfg, params.clone()));
        Self {
            cfg,
            capacity,
            dims,
            params: RwLock::new(params),
            stashes: (0..ranks).map(|_| Mutex::new(BTreeMap::new())).collect(),
            fabric,
            backend,
            mode,
            expected_dispatch: (0..ranks).map(|_| AtomicU32::new(0)).collect(),
            announced_tiles: (0..ranks * ranks * e_slots).map(|_| AtomicU32::new(0)).collect(),
            announced: AtomicU32::new(0),
            pass_poisoned: PoisonLatch::new(),
            start: Barrier::new(ranks),
            threads_spawned: AtomicU64::new(0),
            placement: Mutex::new(placement),
            tracker: Mutex::new(tracker),
            registry,
        }
    }

    /// Snapshot the live parameters (cheap `Arc` clone).
    pub fn params(&self) -> Arc<ModelParams> {
        self.params.read().unwrap().clone()
    }

    /// Install new parameters. Callers must hold the engine's epoch
    /// fence (no pass in flight) — see `MoeEngine::update_params`.
    pub fn set_params(&self, p: Arc<ModelParams>) {
        *self.params.write().unwrap() = p;
    }

    /// Look up rank `rank`'s activation stash for forward epoch `fwd`.
    pub fn stash_for(&self, rank: usize, fwd: u64) -> Option<Arc<RankStash>> {
        self.stashes[rank].lock().unwrap().get(&fwd).cloned()
    }

    /// Snapshot the current placement (cheap `Arc` clone).
    pub fn placement(&self) -> Arc<Placement> {
        self.placement.lock().unwrap().clone()
    }

    /// Install a new placement. Callers must hold the engine's epoch
    /// fence (no pass in flight) — see `MoeEngine::rebalance`.
    pub fn set_placement(&self, p: Arc<Placement>) {
        *self.placement.lock().unwrap() = p;
    }

    /// Index into [`announced_tiles`](Self::announced_tiles) for
    /// (destination rank, source rank, destination expert *slot*).
    pub fn announce_idx(&self, dst: usize, src: usize, e_loc: usize) -> usize {
        (dst * self.cfg.system.ranks + src) * self.dims.e_local + e_loc
    }

    /// Mark pass generation `epoch32` as failed by this rank (a transfer
    /// error mid-pass); peers' subscribers observe it and bail out.
    pub fn poison(&self, epoch32: u32) {
        self.pass_poisoned.poison(epoch32);
    }

    /// True if some rank failed pass generation `epoch32` mid-transfer.
    pub fn poisoned(&self, epoch32: u32) -> bool {
        self.pass_poisoned.poisoned(epoch32)
    }

    /// The subscriber wedge watchdog, from `SystemConfig::watchdog_secs`
    /// (validated non-zero). Chaos tests shrink it so an injected wedge
    /// fails in seconds, not the production default's minutes.
    pub fn watchdog(&self) -> std::time::Duration {
        std::time::Duration::from_secs(self.cfg.system.watchdog_secs)
    }
}

/// Per-pass-slot poison stamps for in-flight pass generations.
///
/// `SLOTS` must equal the engine's `PASS_SLOTS` (how many passes may be
/// submitted and uncollected at once): stamps are indexed `epoch %
/// SLOTS`, exactly like the engine's pass slots, so each in-flight epoch
/// owns a distinct word. A single shared word had a hazard: rank 0's
/// pass-start clear for epoch N+1 would wipe a concurrent, still
/// unobserved poison stamp for epoch N. Per-slot stamps make the clear
/// epoch-local — it can only ever erase a *stale* stamp from epoch
/// N+1-SLOTS, whose pass is long finished.
#[derive(Debug)]
pub struct PoisonLatch {
    slots: [AtomicU32; PoisonLatch::SLOTS],
}

impl PoisonLatch {
    /// Must equal `engine::PASS_SLOTS` (asserted by the engine's tests).
    pub const SLOTS: usize = 2;

    pub fn new() -> Self {
        Self { slots: std::array::from_fn(|_| AtomicU32::new(0)) }
    }

    fn slot(epoch32: u32) -> usize {
        (epoch32 as usize) % Self::SLOTS
    }

    /// Stamp generation `epoch32` as poisoned.
    pub fn poison(&self, epoch32: u32) {
        self.slots[Self::slot(epoch32)].store(epoch32, Ordering::Release);
    }

    /// Is generation `epoch32` stamped? Epoch-exact: a stale stamp from
    /// an earlier same-slot generation never matches.
    pub fn poisoned(&self, epoch32: u32) -> bool {
        self.slots[Self::slot(epoch32)].load(Ordering::Acquire) == epoch32
    }

    /// Clear generation `epoch32`'s slot (pass-start reset). Only touches
    /// this epoch's slot — a poison for the *other* in-flight generation
    /// survives.
    pub fn clear(&self, epoch32: u32) {
        self.slots[Self::slot(epoch32)].store(0, Ordering::Release);
    }
}

impl Default for PoisonLatch {
    fn default() -> Self {
        Self::new()
    }
}

/// Column-sliced weights for split-mode GEMM tasks: `w1c[e][col]` is the
/// (H, bN) stripe of **global** expert `e`'s W1, row-major. Indexed by
/// global expert id (not local slot) because with replication a rank's
/// replica slots bind to different experts across rebalances while these
/// slices are pass-invariant — a rank actor builds them once at spawn and
/// resolves `slot → global expert` through the pass's placement snapshot.
///
/// Invariant: when the backend answers [`ComputeBackend::packed_split_tiles`]
/// `true`, the `w1c`/`w2c` entries are **empty sentinels** — the backend
/// serves those tiles from its packed panel cache, which
/// `MoeEngine::start` populated via `prepare()` *before* any rank actor
/// spawns. The backend rejects an empty slice with a descriptive error if
/// that cache were ever missing, so a mis-wired construction path fails
/// loudly on its first tile rather than computing garbage.
struct WeightSlices {
    w1c: Vec<Vec<Vec<f32>>>,
    b1c: Vec<Vec<Vec<f32>>>,
    w2c: Vec<Vec<Vec<f32>>>,
    b2c: Vec<Vec<Vec<f32>>>,
}

fn slice_cols(w: &[f32], rows: usize, cols: usize, bn: usize) -> Vec<Vec<f32>> {
    (0..cols / bn)
        .map(|c| {
            let mut out = vec![0.0f32; rows * bn];
            for r in 0..rows {
                out[r * bn..(r + 1) * bn].copy_from_slice(&w[r * cols + c * bn..r * cols + c * bn + bn]);
            }
            out
        })
        .collect()
}

impl WeightSlices {
    fn build(shared: &EngineShared) -> Self {
        let m = &shared.cfg.model;
        // When the backend serves split-mode tiles straight from its
        // packed panel cache, the w1c/w2c column copies would be dead
        // weight (the one packed copy already covers every column tile,
        // and retaining sliced duplicates would roughly double per-rank
        // weight memory) — keep only the bias slices, which the packed
        // path still consumes; the backend rejects empty weight slices
        // if its cache were ever missing. Covering the full expert table
        // (not just the owned block) costs only the tiny bias slices on
        // this default path; the non-packed fallback pays full-table
        // weight copies, mirroring the backend's own global expert cache.
        let skip_weight_copies = shared.backend.packed_split_tiles();
        let params = shared.params();
        let mut w1c = Vec::new();
        let mut b1c = Vec::new();
        let mut w2c = Vec::new();
        let mut b2c = Vec::new();
        for ex in params.experts.iter() {
            if skip_weight_copies {
                w1c.push(vec![Vec::new(); m.d / m.bn]);
                w2c.push(vec![Vec::new(); m.h / m.bn]);
            } else {
                w1c.push(slice_cols(&ex.w1, m.h, m.d, m.bn));
                w2c.push(slice_cols(&ex.w2, m.d, m.h, m.bn));
            }
            b1c.push(ex.b1.chunks(m.bn).map(|c| c.to_vec()).collect());
            b2c.push(ex.b2.chunks(m.bn).map(|c| c.to_vec()).collect());
        }
        Self { w1c, b1c, w2c, b2c }
    }
}

/// Rank-local staging for task intermediates. Concurrent tasks write
/// disjoint blocks/stripes of the buffer, so raw interior mutability is
/// sound (same disjointness argument as the symmetric heap).
struct Staging {
    data: UnsafeCell<Vec<f32>>,
    stride: usize,
}

unsafe impl Sync for Staging {}

impl Staging {
    fn new(blocks: usize, stride: usize) -> Self {
        Self { data: UnsafeCell::new(vec![0.0f32; blocks * stride]), stride }
    }

    /// Write a (bm, bn) tile into columns [col*bn, …) of `block`.
    /// SAFETY: distinct (block, col) pairs touch disjoint elements.
    fn write_stripe(&self, block: usize, bm: usize, width: usize, col: usize, bn: usize, tile: &[f32]) {
        unsafe {
            let base = (*self.data.get()).as_mut_ptr().add(block * self.stride);
            for r in 0..bm {
                std::ptr::copy_nonoverlapping(
                    tile.as_ptr().add(r * bn),
                    base.add(r * width + col * bn),
                    bn,
                );
            }
        }
    }

    /// Fill a whole block in place. SAFETY: one writer per block — the
    /// subscriber decodes each dispatch block exactly once, before any
    /// reader task is queued (the queue handoff publishes the write).
    fn fill_block(&self, block: usize, f: impl FnOnce(&mut [f32])) {
        unsafe {
            let base = (*self.data.get()).as_mut_ptr().add(block * self.stride);
            f(std::slice::from_raw_parts_mut(base, self.stride));
        }
    }

    /// Read a whole block. Caller must have synchronized with all writers
    /// (dependency latch release + queue/doorbell handoff establish
    /// happens-before).
    fn read_block(&self, block: usize) -> &[f32] {
        unsafe {
            let v = &*self.data.get();
            &v[block * self.stride..(block + 1) * self.stride]
        }
    }
}

/// How many forward stashes each rank retains; the oldest is evicted
/// when a newer forward completes. Backward must be issued within this
/// many forwards of its pass.
pub const STASH_CAP: usize = 4;

/// Per-pass activation stash for the training backward: everything the
/// reverse pass needs to re-derive its tile set and gradients without
/// any new announcement round — both sides of every transfer already
/// know the forward plan, so the reverse tile set is implied.
pub struct RankStash {
    /// Forward pass epoch (the stash key).
    pub epoch: u64,
    /// Placement version the forward ran under; a backward refuses to
    /// run against a different placement (its tile set would not match).
    pub placement_version: u64,
    /// Rows this rank submitted in the forward.
    pub s_rows: usize,
    /// Forward input copy (s_rows, H): the gate backward's left operand.
    pub(crate) x: Vec<f32>,
    /// Post-softmax gate probabilities (s_rows, E).
    pub(crate) scores: Vec<f32>,
    pub(crate) topk_idx: Vec<u32>,
    pub(crate) topk_w: Vec<f32>,
    /// The forward dispatch plan — the backward's reverse tile set.
    pub(crate) plan: DispatchPlan,
    /// Parameter snapshot the forward computed with: gradients are taken
    /// w.r.t. *these* tensors even if `update_params` has since installed
    /// newer ones (the tape closes over its own weights).
    pub(crate) params: Arc<ModelParams>,
    /// Placement snapshot of the forward (slot → expert resolution).
    pub(crate) placement: Arc<Placement>,
    /// Unweighted expert output per *plan* tile, written by the forward
    /// combine at the T_phi ordinal: the gate backward's dc source.
    pub(crate) y_stage: Staging,
    /// Owner side: dispatched input rows per incoming block — the left
    /// operand of the dW1 fold (and the mid-recompute fallback).
    pub(crate) x_stash: Staging,
    /// Owner side: post-ReLU FFN intermediate per incoming block,
    /// captured from the backend's scratch when it honors the contract
    /// ([`ComputeBackend::mid_in_scratch`]); otherwise `has_mid` is false
    /// and the backward recomputes it from `x_stash`.
    pub(crate) mid_stash: Staging,
    pub(crate) has_mid: bool,
    /// Valid rows per incoming block (owner side).
    pub(crate) block_rows: Vec<AtomicU32>,
    /// Forward bookkeeping copies: the backward's sweep bounds and block
    /// ordinal bases, frozen so no re-announcement is needed.
    pub(crate) incoming_tiles: Vec<u32>,
    pub(crate) block_base: Vec<u32>,
}

/// One rank's parameter-gradient partials from a backward pass. The
/// engine merges partials in a fixed order (ranks ascending, then each
/// rank's slot order) into one `GradStore`, so the merged gradients are
/// bitwise deterministic.
pub struct RankGrads {
    /// Gate-matrix gradient partial (H, E) from this rank's tokens.
    pub wg: Vec<f32>,
    /// Per served expert slot: (global expert id, FFN grad partial).
    pub experts: Vec<(usize, ExpertGrad)>,
}

/// Ordinal table entry for the deterministic wgrad folds: which incoming
/// block feeds fold position `ordinal` of a local expert slot.
#[derive(Clone, Copy)]
struct FoldSrc {
    block: usize,
    peer: usize,
    tile: usize,
    rows: usize,
}

/// One deterministic gradient fold. Ordinals are folded strictly in
/// ascending (peer, tile) order: a wgrad task marks its ordinal ready
/// under the lock and the current holder folds every consecutive ready
/// prefix — so the f32 accumulation order is fixed under any work-
/// stealing schedule or processor count (bitwise-reproducible wgrads,
/// mirroring the forward's plan-order combine fold).
struct WgradFold {
    next: usize,
    ready: Vec<bool>,
    dw: Vec<f32>,
    db: Vec<f32>,
}

impl WgradFold {
    fn new(ordinals: usize, w_len: usize, b_len: usize) -> Self {
        Self { next: 0, ready: vec![false; ordinals], dw: vec![0.0; w_len], db: vec![0.0; b_len] }
    }
}

/// Backward-only pass state: the forward stash being differentiated,
/// the dgrad staging, and the per-slot wgrad folds.
struct BwdCtx {
    stash: Arc<RankStash>,
    /// dMid per incoming block (bM, D): written by Dgrad1, read by
    /// Dgrad0 (the dX producer) and the Wgrad0 fold.
    dmid_stage: Staging,
    /// Per local slot: fold inputs in (peer asc, tile asc) order.
    fold_src: Vec<Vec<FoldSrc>>,
    /// This task's fold ordinal base per (peer, local slot).
    ord_base: Vec<u32>,
    /// dW1/db1 folds per local slot (xᵀ·dMid / column-sum of dMid).
    fold0: Vec<Mutex<WgradFold>>,
    /// dW2/db2 folds per local slot (midᵀ·dY' / column-sum of dY').
    fold1: Vec<Mutex<WgradFold>>,
}

/// Pass-lifetime counters driving the self-correcting task bound.
struct PassCounters {
    ffn_decoded: AtomicU32,
    ffn_completed: AtomicU32,
    combine_decoded: AtomicU32,
    combine_completed: AtomicU32,
    gemm_tasks: AtomicU32,
    busy_nanos: AtomicU64,
    /// Token rows this rank received into *replica* slots (slot index
    /// `>= local_experts`) — the replication-effect signal.
    replica_rows: AtomicU64,
    /// Backward bookkeeping: follow-up tasks spawned by Dgrad1 decode
    /// (Wgrad1 + Wgrad0 + Dgrad0 per tile) vs completed — the backward
    /// leg of the self-correcting task bound.
    bwd_spawned: AtomicU32,
    bwd_completed: AtomicU32,
    dgrad_tasks: AtomicU32,
    wgrad_tasks: AtomicU32,
}

impl PassCounters {
    fn new() -> Self {
        Self {
            ffn_decoded: AtomicU32::new(0),
            ffn_completed: AtomicU32::new(0),
            combine_decoded: AtomicU32::new(0),
            combine_completed: AtomicU32::new(0),
            gemm_tasks: AtomicU32::new(0),
            busy_nanos: AtomicU64::new(0),
            replica_rows: AtomicU64::new(0),
            bwd_spawned: AtomicU32::new(0),
            bwd_completed: AtomicU32::new(0),
            dgrad_tasks: AtomicU32::new(0),
            wgrad_tasks: AtomicU32::new(0),
        }
    }
}

/// Everything the resident processors need for one epoch-tagged pass.
/// Built by the subscriber at pass start and shared via `Arc` through the
/// rank's doorbell; dropped when the pass completes.
struct PassCtx {
    shared: Arc<EngineShared>,
    rank: usize,
    /// Generation tag for this pass's heap traffic (low 32 bits of the
    /// engine epoch; wraps after 2^32 passes, far beyond flag lifetime).
    epoch32: u32,
    queue: Arc<TaskQueue>,
    counters: PassCounters,
    /// This rank's dispatch plan; tile index doubles as the combine
    /// staging ordinal and fixes the output reduction order.
    plan: DispatchPlan,
    /// The placement snapshot this pass was planned against: resolves a
    /// (rank, slot) pair back to the global expert it is serving.
    placement: Arc<Placement>,
    /// T_phi lookup: (dst rank, dst slot, tile) -> ordinal into
    /// `plan.tiles`. Keyed by destination slot, not global expert — a
    /// replicated expert has the same tile index live on two
    /// destinations, so an expert-keyed table would collide.
    tphi: HashMap<(u32, u32, u32), u32>,
    /// Announced inbound dispatch-tile count per (peer, local expert):
    /// bounds the round-0 flag sweep and sizes the block tables below.
    incoming_tiles: Vec<u32>,
    /// Expected combine-tile count per (owner peer, owner-local expert),
    /// derived from this rank's own plan: bounds the round-1 flag sweep.
    combine_tiles: Vec<u32>,
    /// Dense block ordinal base per (peer, local expert): block ids for a
    /// pass are prefix sums of the *announced* tile counts, so staging and
    /// dependency tables are sized to the pass's actual work, not to the
    /// static worst-case capacity.
    block_base: Vec<u32>,
    slices: Option<Arc<WeightSlices>>,
    /// Split mode on a reduced (16-bit) wire only: each inbound dispatch
    /// tile decoded to f32 exactly **once** (by the subscriber, at decode
    /// time) — the D/bN Gemm0 column tasks borrow this copy instead of
    /// each re-decoding the same heap cell. `None` on an f32 wire, where
    /// Gemm0 borrows the heap cell zero-copy (`read_borrowed`).
    x_stage: Option<Staging>,
    mid: Option<Staging>,
    out_stage: Option<Staging>,
    g0_latch: Option<DependencyTable>,
    g1_latch: Option<DependencyTable>,
    /// Valid rows per split-mode block (indexed by block id).
    block_rows: Vec<AtomicU32>,
    /// Per-dispatched-tile combine staging (bM, H) blocks: tasks write
    /// disjoint blocks; the subscriber folds them in plan order.
    combine_stage: Staging,
    /// The parameter snapshot this pass computes with (forward: the live
    /// params at pass start; backward: the stashed forward's params).
    params: Arc<ModelParams>,
    /// Which resident model this pass serves (0 = anchor). A pass never
    /// mixes models.
    model: usize,
    /// First expert slot of `model`'s heap band. Plan `dslot`s are
    /// shifted band-absolute once after planning, so this offset is only
    /// needed to map a slot back to its band-relative index — for
    /// placement resolution and the replica-slot check.
    e_base: usize,
    /// Packed-weight cache region of this model's weights: global expert
    /// `e` is served under backend cache key `key_base + e` (shared with
    /// the base model for dedups and delta variants).
    key_base: usize,
    /// LoRA-style epilogue update, `Some` for delta-variant models.
    delta: Option<Arc<DeltaSet>>,
    /// Forward stashing target (`Some` when training stash is on):
    /// FusedFfn/Combine tasks capture activations here as they run.
    stash: Option<Arc<RankStash>>,
    /// Backward-pass state; `Some` iff this pass is a backward.
    bwd: Option<BwdCtx>,
}

impl PassCtx {
    fn block_id(&self, peer: usize, e_loc: usize, tile: usize) -> usize {
        let e_local = self.shared.dims.e_local;
        debug_assert!((tile as u32) < self.incoming_tiles[peer * e_local + e_loc]);
        (self.block_base[peer * e_local + e_loc] + tile as u32) as usize
    }
}

/// The result of one rank's pass. For a forward, `out` is the combined
/// (s_r, H) layer output; for a backward, it is dL/dX of the same shape
/// and `grads` carries this rank's parameter-gradient partials.
pub struct RankOutput {
    pub out: Vec<f32>,
    pub metrics: RankMetrics,
    pub grads: Option<RankGrads>,
}

/// Doorbell between a rank's subscriber thread and its resident
/// processor workers.
struct ProcDoorbell {
    state: Mutex<ProcState>,
    cv: Condvar,
}

struct ProcState {
    /// Latest epoch published to the workers (0 = none yet).
    epoch: u64,
    ctx: Option<Arc<PassCtx>>,
    shutdown: bool,
    /// Workers that finished the current epoch.
    done: usize,
    /// Per-worker pass results, reset at publish time.
    results: Vec<Option<Result<()>>>,
}

/// One rank's resident actor group: ready queue, pass-invariant weight
/// slices, and the parked processor workers. Created once per engine
/// start; `run_pass` reuses everything.
pub struct RankActor {
    shared: Arc<EngineShared>,
    rank: usize,
    queue: Arc<TaskQueue>,
    slices: Option<Arc<WeightSlices>>,
    bell: Arc<ProcDoorbell>,
    workers: Vec<JoinHandle<()>>,
}

impl RankActor {
    /// Spawn rank `rank`'s processor workers (the only thread creation
    /// this rank ever does) and build its pass-invariant state.
    pub fn spawn(shared: Arc<EngineShared>, rank: usize) -> Self {
        let queue = Arc::new(TaskQueue::new(shared.cfg.system.processors));
        let slices = (shared.mode == TaskGraphMode::Split)
            .then(|| Arc::new(WeightSlices::build(&shared)));
        let processors = shared.cfg.system.processors;
        let bell = Arc::new(ProcDoorbell {
            state: Mutex::new(ProcState {
                epoch: 0,
                ctx: None,
                shutdown: false,
                done: 0,
                results: (0..processors).map(|_| None).collect(),
            }),
            cv: Condvar::new(),
        });
        let workers = (0..processors)
            .map(|slot| {
                let bell = bell.clone();
                shared.threads_spawned.fetch_add(1, Ordering::Relaxed);
                std::thread::Builder::new()
                    .name(format!("flash-r{rank}-p{slot}"))
                    .spawn(move || worker_main(bell, slot))
                    .expect("spawn processor worker")
            })
            .collect();
        Self { shared, rank, queue, slices, bell, workers }
    }

    /// Run one epoch-tagged pass over this rank's (s_r, H) tokens, where
    /// `s_r = a.len() / H` may be anywhere in `0..=s_rank` — the engine's
    /// variable-shape `PassInput` path plumbs partially-filled batches
    /// straight through: the gate routes only the rows that exist, the
    /// dispatch plan and announcements carry actual tile counts, and a
    /// zero-row rank still sweeps and serves its experts for its peers.
    /// Steady-state: no allocation of threads, no heap reset — the pass
    /// barrier plus generation-tagged flags do all the cross-pass fencing.
    ///
    /// `model` selects which resident model the pass serves: 0 is the
    /// anchor (the legacy engine fields), ids ≥ 1 resolve through the
    /// [`ModelRegistry`]. Every rank of a pass runs the same model — the
    /// engine stamps it into the pass ticket — and non-anchor models are
    /// Fused-only (validated at submit).
    pub fn run_pass(&self, epoch: u64, a: &[f32], model: usize) -> Result<RankOutput> {
        let shared = &self.shared;
        let cfg = &shared.cfg;
        let rank = self.rank;
        let (s_rank, h) = (cfg.system.s_rank, cfg.model.h);
        anyhow::ensure!(a.len() % h == 0, "rank {rank}: bad input length");
        anyhow::ensure!(
            model == 0 || shared.mode == TaskGraphMode::Fused,
            "rank {rank}: non-anchor models serve in Fused task-graph mode only"
        );
        let s_rows = a.len() / h;
        anyhow::ensure!(
            s_rows <= s_rank,
            "rank {rank}: {s_rows} rows exceed s_rank = {s_rank}"
        );
        // Dropless slot-region invariant: the heap was sized once from
        // the static worst case, so any admissible row count fits even
        // if every row routes to one expert.
        debug_assert!(
            !cfg.model.policy.is_dropless() || shared.dims.fits_source_rows(s_rows),
            "rank {rank}: {s_rows} rows overflow the dropless slot region (C = {})",
            shared.dims.c
        );
        let epoch32 = epoch as u32;

        // ---- pass-start doorbell (NOT a launch) ------------------------------
        // First wait: every rank is done with the previous pass, so heap
        // slots may be rewritten. Rank 0 then clears the pass-scoped
        // announce counters; the second wait publishes the clear.
        shared.start.wait();
        if rank == 0 {
            // Clear only THIS epoch's poison slot: the other slot may
            // hold a stamp for the previous, still-collecting pass.
            shared.pass_poisoned.clear(epoch32);
            shared.announced.store(0, Ordering::Release);
            for d in &shared.expected_dispatch {
                d.store(0, Ordering::Release);
            }
            for counter in &shared.announced_tiles {
                counter.store(0, Ordering::Release);
            }
        }
        shared.start.wait();
        let t0 = Instant::now();
        let (bytes_local_0, bytes_remote_0) = shared.fabric.bytes_in(rank);
        let steals_0 = self.queue.steals();
        // Per-model pass state, snapshotted *after* the barrier pair:
        // rebalance / update_params / model load+evict all mutate at the
        // engine's epoch-fenced quiet point only, so every rank of this
        // pass reads one consistent version. The anchor model (0) reads
        // the legacy engine fields; registry models read their entry.
        // `e_base` is the first slot of the model's private band in the
        // (multiplied) expert-slot dimension; `key_base` shifts backend
        // packed-cache keys the same way.
        let (params, placement, delta, key_base, e_base) = if model == 0 {
            (shared.params(), shared.placement(), None, 0usize, 0usize)
        } else {
            let entry = shared
                .registry
                .entry(model)
                .ok_or_else(|| anyhow!("rank {rank}: model {model} is not resident"))?;
            let placement = entry.placement.lock().unwrap().clone();
            (
                entry.params.clone(),
                placement,
                entry.delta.clone(),
                entry.key_base,
                shared.registry.e_base(model),
            )
        };
        let e_slots = shared.dims.e_local;

        // ---- FusedGate (Alg. 1 line 1) ---------------------------------------
        // Gated over the pass's actual rows, not the static s_rank: a
        // partially-filled rank routes (and pays for) only what it holds.
        let scores = shared
            .backend
            .gate_scores(a, &params.wg, s_rows)
            .context("gate")?;
        let routing = route_from_scores(scores, s_rows, &cfg.model, shared.capacity);
        let gate_entropy = routing.entropy();
        let dropped = routing.dropped;
        anyhow::ensure!(
            !cfg.model.policy.is_dropless() || dropped == 0,
            "rank {rank}: dropless routing dropped {dropped} pairs (slot region undersized)"
        );
        let mut plan = dispatch_plan(&routing, cfg.model.bm, &placement);
        // Shift every destination slot into this model's heap band: one
        // mutation here makes the announcements, dispatch coordinates,
        // T_phi keys, combine bookkeeping and the flag sweep all
        // band-absolute, with no per-site offsetting downstream. The
        // anchor's band starts at 0, so the single-model path is
        // untouched.
        if e_base > 0 {
            for t in &mut plan.tiles {
                t.dslot += e_base as u32;
            }
        }
        let plan = plan;

        // ---- announce dispatch-tile counts (before dispatching) --------------
        // Per-destination totals drive the self-correcting task bound;
        // per-(destination, expert slot) counts let the destination size
        // its pass bookkeeping to the actual tile counts. `t.dslot` is
        // the destination slot the plan already resolved — owned slot on
        // the primary, bound replica slot on a replica host.
        let ranks_n = cfg.system.ranks;
        let mut per_dst = vec![0u32; ranks_n];
        let mut per_dst_slot = vec![0u32; ranks_n * e_slots];
        for t in &plan.tiles {
            let dst = t.dst as usize;
            per_dst[dst] += 1;
            per_dst_slot[dst * e_slots + t.dslot as usize] += 1;
        }
        for dst in 0..ranks_n {
            for el in 0..e_slots {
                let n = per_dst_slot[dst * e_slots + el];
                if n > 0 {
                    shared.announced_tiles[shared.announce_idx(dst, rank, el)]
                        .store(n, Ordering::Release);
                }
            }
            if per_dst[dst] > 0 {
                shared.expected_dispatch[dst].fetch_add(per_dst[dst], Ordering::AcqRel);
            }
        }
        shared.announced.fetch_add(1, Ordering::AcqRel);

        // ---- dispatch (payload-efficient, one-sided, generation-tagged) ------
        // Depends only on this rank's own plan, so it runs before the
        // (Dropless) announcement wait below — a gate straggler on one
        // rank never delays another rank's outbound tiles. Receivers may
        // not have built their pass context yet; flags simply persist on
        // the heap until their subscriber sweeps them. Runs before the
        // processor doorbell so a dispatch error skips the epoch cleanly:
        // workers never observe an epoch they'd half-run. A failed put
        // (NIC incast overflow) poisons the pass generation so peers'
        // subscribers stop waiting for the tiles that will never arrive.
        //
        // Under `DispatchMode::Hierarchical`, tiles bound for a remote
        // node do not cross the NIC one by one: the node's *unique* token
        // rows travel as one coalesced transfer to a proxy rank (the
        // FSMoE-style two-level schedule — a token routed to two experts
        // on the same remote node crosses once, not twice), and the proxy
        // fans the per-tile payloads out intra-node via delegated writes
        // that keep this rank as the logical source. Flags, announcement
        // tables and the combine protocol are untouched, so flat and
        // hierarchical passes produce bitwise-identical outputs.
        //
        // `announced_inter_bytes` is this rank's declared NIC volume for
        // the pass: outbound dispatch (per-tile in flat mode, per-node
        // unique rows in hierarchical) plus the combine returns its
        // cross-node tiles will pull back in. Summed over ranks it upper-
        // bounds the pass's measured inter-node bytes (the incast-bound
        // property test).
        let m = &cfg.model;
        let wb = shared.fabric.wire().bytes() as u64;
        let topo = *shared.fabric.topology();
        let hier = cfg.system.dispatch.is_hierarchical() && topo.nodes() > 1;
        let mut pack = vec![0.0f32; m.bm * h];
        let mut announced_inter_bytes: u64 = 0;
        for t in &plan.tiles {
            // combine returns for cross-node tiles come back over the NIC
            if !topo.same_node(rank, t.dst as usize) {
                announced_inter_bytes += t.rows as u64 * h as u64 * wb;
            }
        }
        if hier {
            let my_node = topo.node_of(rank);
            for node in 0..topo.nodes() {
                if node == my_node {
                    continue; // same-node tiles dispatch direct below
                }
                // dedup: unique token rows bound for this node across all
                // of its tiles (k > 1 routes may share a remote node)
                let mut seen = vec![false; s_rows];
                let mut unique = 0u64;
                for t in plan.tiles.iter().filter(|t| topo.node_of(t.dst as usize) == node) {
                    for &tok in &t.tokens {
                        if !seen[tok as usize] {
                            seen[tok as usize] = true;
                            unique += 1;
                        }
                    }
                }
                if unique == 0 {
                    continue;
                }
                let unique_bytes = unique * h as u64 * wb;
                announced_inter_bytes += unique_bytes;
                let xfer = match shared.fabric.coalesced(rank, node, epoch32, unique_bytes) {
                    Ok(x) => x,
                    Err(e) => {
                        shared.poison(epoch32);
                        return Err(e).context("coalesced dispatch");
                    }
                };
                for t in plan.tiles.iter().filter(|t| topo.node_of(t.dst as usize) == node) {
                    for (row, &tok) in t.tokens.iter().enumerate() {
                        pack[row * h..(row + 1) * h]
                            .copy_from_slice(&a[tok as usize * h..(tok as usize + 1) * h]);
                    }
                    let coord =
                        Coord { p: rank, r: 0, b: 1, e: t.dslot as usize, c: t.tile as usize * m.bm };
                    if let Err(e) = xfer.put(t.dst as usize, coord, &pack[..t.rows as usize * h])
                    {
                        shared.poison(epoch32);
                        return Err(e).context("coalesced fan-out");
                    }
                }
            }
        }
        for t in &plan.tiles {
            let dst = t.dst as usize;
            if hier && !topo.same_node(rank, dst) {
                continue; // already shipped via the coalesced path
            }
            if !topo.same_node(rank, dst) {
                announced_inter_bytes += t.rows as u64 * h as u64 * wb;
            }
            for (row, &tok) in t.tokens.iter().enumerate() {
                pack[row * h..(row + 1) * h]
                    .copy_from_slice(&a[tok as usize * h..(tok as usize + 1) * h]);
            }
            let coord = Coord { p: rank, r: 0, b: 1, e: t.dslot as usize, c: t.tile as usize * m.bm };
            if let Err(e) =
                shared.fabric.put_signal(rank, dst, coord, &pack[..t.rows as usize * h], epoch32)
            {
                shared.poison(epoch32);
                return Err(e).context("dispatch put");
            }
        }

        // ---- size pass bookkeeping -------------------------------------------
        // Dropless: wait for every source's announcement, then size the
        // dependency tables, staging and flag-sweep bounds from the
        // announced *dynamic* tile counts — a skewed gate can concentrate
        // a whole batch on one expert, so the static worst case would be
        // `roundup(S_r, bM)/bM` tiles per (peer, expert) and worst-case
        // bookkeeping every pass. Sources announce right after gating and
        // before any dispatch copy, so the wait is bounded by the slowest
        // peer's gate.
        //
        // Capacity: keep the static `capacity / bM` sizing and do NOT
        // wait — dispatch overlaps peers' gates exactly as before, so a
        // gate straggler on one rank never stalls another rank's dispatch
        // (the bookkeeping worst case is small and fixed in this policy).
        let pe_slots = ranks_n * e_slots;
        let (incoming_tiles, block_base, blocks) = if cfg.model.policy.is_dropless() {
            let mut spins = 0u32;
            while (shared.announced.load(Ordering::Acquire) as usize) < ranks_n {
                spins = spins.wrapping_add(1);
                if spins < 64 {
                    std::hint::spin_loop();
                } else {
                    std::thread::yield_now();
                }
                if spins % 4096 == 0 && t0.elapsed() > shared.watchdog() {
                    panic!(
                        "rank {rank} wedged waiting for announcements (pass gen {epoch32}, \
                         {:.1}s since pass start, watchdog {}s): {}/{ranks_n} ranks announced",
                        t0.elapsed().as_secs_f64(),
                        shared.cfg.system.watchdog_secs,
                        shared.announced.load(Ordering::Acquire),
                    );
                }
            }
            let mut incoming = vec![0u32; pe_slots];
            let mut base = vec![0u32; pe_slots];
            let mut blocks = 0u32;
            for peer in 0..ranks_n {
                for el in 0..e_slots {
                    let n = shared.announced_tiles[shared.announce_idx(rank, peer, el)]
                        .load(Ordering::Acquire);
                    base[peer * e_slots + el] = blocks;
                    incoming[peer * e_slots + el] = n;
                    blocks += n;
                }
            }
            debug_assert_eq!(blocks, shared.expected_dispatch[rank].load(Ordering::Acquire));
            (incoming, base, blocks)
        } else {
            // Region-masked static sizing: only this model's slot band
            // can receive tiles this pass, so every other band gets zero
            // incoming tiles (the flag sweep then skips it entirely).
            // With max_models = 1 the band covers every slot and this
            // reduces bitwise to the legacy `i * tpe` prefix table.
            let tpe = shared.dims.tiles_per_expert() as u32;
            let band_w = cfg.local_experts() + cfg.replica_slots();
            let mut incoming = vec![0u32; pe_slots];
            let mut base = vec![0u32; pe_slots];
            let mut blocks = 0u32;
            for peer in 0..ranks_n {
                for el in 0..e_slots {
                    base[peer * e_slots + el] = blocks;
                    if el >= e_base && el < e_base + band_w {
                        incoming[peer * e_slots + el] = tpe;
                        blocks += tpe;
                    }
                }
            }
            (incoming, base, blocks)
        };
        // expected combine tiles per (serving rank, serving slot), from my
        // own plan: the server writes results back at the same tile index
        // and slot it received the dispatch on.
        let mut combine_tiles = vec![0u32; ranks_n * e_slots];
        for t in &plan.tiles {
            let idx = t.dst as usize * e_slots + t.dslot as usize;
            combine_tiles[idx] = combine_tiles[idx].max(t.tile + 1);
        }

        // ---- build T_phi and the pass context --------------------------------
        // Keyed (dst, dslot, tile): a replicated expert runs the same
        // tile index on two destinations, so (expert, tile) would collide.
        let mut tphi = HashMap::with_capacity(plan.tiles.len());
        for (i, t) in plan.tiles.iter().enumerate() {
            tphi.insert((t.dst, t.dslot, t.tile), i as u32);
        }
        let d_cols = (m.d / m.bn) as u32;
        let h_cols = (m.h / m.bn) as u32;
        let blocks = blocks as usize;
        let my_expected_combine = plan.tiles.len() as u32;
        let split = shared.mode == TaskGraphMode::Split;

        // ---- training tape (opt-in) ------------------------------------------
        // Stash everything the backward needs: routing/plan on the source
        // side, per-block inputs + post-ReLU intermediates on the owner
        // side (filled by FusedFfn tasks as they run), and unweighted
        // expert outputs (filled by Combine tasks). Fused mode only — the
        // split GEMM chain has no mid-capture seam wired. Anchor-model
        // passes only: training flows through model 0 (the Trainer's
        // master params are the anchor's), so non-anchor passes never
        // stash.
        let stash = (shared.mode == TaskGraphMode::Fused
            && cfg.system.train.stash()
            && model == 0)
            .then(|| {
            Arc::new(RankStash {
                epoch,
                placement_version: placement.version(),
                s_rows,
                x: a.to_vec(),
                scores: routing.scores.clone(),
                topk_idx: routing.topk_idx.clone(),
                topk_w: routing.topk_w.clone(),
                plan: plan.clone(),
                params: params.clone(),
                placement: placement.clone(),
                y_stage: Staging::new(plan.tiles.len(), m.bm * h),
                x_stash: Staging::new(blocks, m.bm * h),
                mid_stash: Staging::new(blocks, m.bm * m.d),
                has_mid: shared.backend.mid_in_scratch(),
                block_rows: (0..blocks).map(|_| AtomicU32::new(0)).collect(),
                incoming_tiles: incoming_tiles.clone(),
                block_base: block_base.clone(),
            })
        });
        self.queue.reopen();
        let ctx = Arc::new(PassCtx {
            shared: self.shared.clone(),
            rank,
            epoch32,
            queue: self.queue.clone(),
            counters: PassCounters::new(),
            tphi,
            incoming_tiles,
            combine_tiles,
            block_base,
            slices: self.slices.clone(),
            x_stage: (split && !shared.fabric.zero_copy()).then(|| Staging::new(blocks, m.bm * h)),
            mid: split.then(|| Staging::new(blocks, m.bm * m.d)),
            out_stage: split.then(|| Staging::new(blocks, m.bm * m.h)),
            g0_latch: split.then(|| DependencyTable::new(blocks, d_cols)),
            g1_latch: split.then(|| DependencyTable::new(blocks, h_cols)),
            block_rows: (0..blocks).map(|_| AtomicU32::new(0)).collect(),
            combine_stage: Staging::new(plan.tiles.len(), m.bm * m.h),
            placement: placement.clone(),
            plan,
            params,
            model,
            e_base,
            key_base,
            delta,
            stash: stash.clone(),
            bwd: None,
        });

        // ---- wake the resident processors (doorbell, not spawn) --------------
        {
            let mut st = self.bell.state.lock().unwrap();
            st.ctx = Some(ctx.clone());
            st.done = 0;
            for r in st.results.iter_mut() {
                *r = None;
            }
            st.epoch = epoch;
            self.bell.cv.notify_all();
        }

        // ---- subscriber phase (this thread IS the OS/subscriber actor) -------
        // Capture the result but park the processors FIRST: a poisoned
        // pass must still leave the actor group synchronized before the
        // error propagates, or the next pass would race old-ctx workers.
        let sub_result = subscriber_loop(ctx.as_ref(), my_expected_combine);

        // ---- park the processors: wait for the pass-done latch ---------------
        let worker_results: Vec<Result<()>> = {
            let mut st = self.bell.state.lock().unwrap();
            while st.done < self.workers.len() {
                st = self.bell.cv.wait(st).unwrap();
            }
            st.ctx = None;
            st.results.iter_mut().map(|r| r.take().expect("worker result")).collect()
        };
        sub_result.with_context(|| format!("rank {rank} subscriber (pass {epoch})"))?;
        for (i, r) in worker_results.into_iter().enumerate() {
            r.with_context(|| format!("rank {rank} processor {i} (pass {epoch})"))?;
        }

        // ---- deterministic combine fold (dispatch-plan order) ----------------
        let mut out = vec![0.0f32; s_rows * h];
        for (i, t) in ctx.plan.tiles.iter().enumerate() {
            let y = ctx.combine_stage.read_block(i);
            for (row, &tok) in t.tokens.iter().enumerate() {
                let dst = &mut out[tok as usize * h..(tok as usize + 1) * h];
                let src = &y[row * h..(row + 1) * h];
                for (o, &v) in dst.iter_mut().zip(src) {
                    *o += v;
                }
            }
        }

        let wall = t0.elapsed().as_secs_f64();
        let (bytes_local_1, bytes_remote_1) = shared.fabric.bytes_in(rank);
        let c = &ctx.counters;
        let metrics = RankMetrics {
            busy_secs: c.busy_nanos.load(Ordering::Relaxed) as f64 * 1e-9,
            wall_secs: wall,
            processors: self.workers.len(),
            rows_in: s_rows,
            ffn_tasks: c.ffn_completed.load(Ordering::Relaxed),
            gemm_tasks: c.gemm_tasks.load(Ordering::Relaxed),
            combine_tasks: c.combine_completed.load(Ordering::Relaxed),
            tiles_sent: ctx.plan.tiles.len(),
            sent_rows: ctx.plan.sent_rows,
            padded_rows: ctx.plan.padded_rows,
            dropped,
            bytes_in_local: bytes_local_1 - bytes_local_0,
            bytes_in_remote: bytes_remote_1 - bytes_remote_0,
            announced_inter_bytes,
            max_queue_depth: self.queue.max_depth(),
            steals: self.queue.steals() - steals_0,
            expert_offered: routing.offered_load.iter().map(|&v| v as u64).collect(),
            expert_kept: routing.expert_load.iter().map(|&v| v as u64).collect(),
            replica_rows: c.replica_rows.load(Ordering::Relaxed),
            unavailable_rows: ctx.plan.unavailable_rows as u64,
            dgrad_tasks: 0,
            wgrad_tasks: 0,
            gate_entropy,
        };
        // Publish the tape last: a pass that errored above never leaves a
        // half-filled stash behind (the Arc just drops).
        if let Some(stash) = stash {
            let mut stashes = shared.stashes[rank].lock().unwrap();
            stashes.insert(epoch, stash);
            while stashes.len() > STASH_CAP {
                let oldest = *stashes.keys().next().unwrap();
                stashes.remove(&oldest);
            }
        }
        Ok(RankOutput { out, metrics, grads: None })
    }

    /// Run one epoch-tagged **backward** pass for the stashed forward
    /// `fwd_epoch`. Same persistent machinery as a forward — the
    /// pass-start barrier pair, generation-tagged one-sided transfers at
    /// the configured wire precision, the flag sweep feeding the
    /// work-stealing pool, poison/retry semantics — but the tile flow is
    /// reversed: this rank scatters combine-weight-scaled output-grads to
    /// the forward plan's expert owners (round-0 cells), owners run
    /// `Dgrad1 → {Wgrad1, Wgrad0, Dgrad0}` per tile and ship dX tiles
    /// back over the round-1 cells, and the subscriber folds returning
    /// tiles in plan order (unit weights — the scaling already happened
    /// at the source) before adding the gate backward's dX term. No
    /// announcement round exists in reverse: both sides derive the exact
    /// tile set from the stashed forward plan.
    pub fn run_backward_pass(&self, epoch: u64, fwd_epoch: u64, gy: &[f32]) -> Result<RankOutput> {
        let shared = &self.shared;
        let cfg = &shared.cfg;
        let rank = self.rank;
        let h = cfg.model.h;
        anyhow::ensure!(
            shared.mode == TaskGraphMode::Fused,
            "rank {rank}: backward passes run in Fused task-graph mode only"
        );
        let stash = shared.stash_for(rank, fwd_epoch).ok_or_else(|| {
            anyhow!("rank {rank}: no activation stash for forward pass {fwd_epoch}")
        })?;
        anyhow::ensure!(
            gy.len() == stash.s_rows * h,
            "rank {rank}: output-grad length {} != stashed rows {} x H",
            gy.len(),
            stash.s_rows
        );
        let epoch32 = epoch as u32;

        // ---- pass-start doorbell (same barrier discipline as forward) --------
        // The announce tables stay untouched: the reverse tile set is the
        // stashed plan, which every receiver also stashed.
        shared.start.wait();
        if rank == 0 {
            shared.pass_poisoned.clear(epoch32);
        }
        shared.start.wait();
        let t0 = Instant::now();
        let (bytes_local_0, bytes_remote_0) = shared.fabric.bytes_in(rank);
        let steals_0 = self.queue.steals();
        let m = &cfg.model;
        let e_slots = shared.dims.e_local;
        let ranks_n = cfg.system.ranks;

        // ---- reverse dispatch: scatter scaled output-grads -------------------
        // Per stashed plan tile, row r carries `weights[r] * gy[token_r]`:
        // scaling at the source folds the combine weights into the
        // payload, so owners consume dY' = c ⊙ dY directly and the
        // returning dX tiles fold with *unit* weight. `put_signal`
        // encodes to the configured wire precision, exactly like the
        // forward — 16-bit wires halve reverse traffic too.
        let wb = shared.fabric.wire().bytes() as u64;
        let topo = *shared.fabric.topology();
        let hier = cfg.system.dispatch.is_hierarchical() && topo.nodes() > 1;
        let mut pack = vec![0.0f32; m.bm * h];
        let mut announced_inter_bytes: u64 = 0;
        for t in &stash.plan.tiles {
            // dX gather for cross-node tiles comes back over the NIC
            if !topo.same_node(rank, t.dst as usize) {
                announced_inter_bytes += t.rows as u64 * h as u64 * wb;
            }
        }
        let fill = |pack: &mut [f32], t: &DispatchTile| {
            for (row, (&tok, &w)) in t.tokens.iter().zip(&t.weights).enumerate() {
                let src = &gy[tok as usize * h..(tok as usize + 1) * h];
                for (p, &g) in pack[row * h..(row + 1) * h].iter_mut().zip(src) {
                    *p = w * g;
                }
            }
        };
        if hier {
            let my_node = topo.node_of(rank);
            for node in 0..topo.nodes() {
                if node == my_node {
                    continue;
                }
                // Unlike the forward, payloads are per-(tile, row) scaled,
                // so a token shared by two tiles carries *different* rows
                // — no dedup; the coalesced transfer still batches the
                // node's tiles into one NIC admission.
                let total: u64 = stash
                    .plan
                    .tiles
                    .iter()
                    .filter(|t| topo.node_of(t.dst as usize) == node)
                    .map(|t| t.rows as u64 * h as u64 * wb)
                    .sum();
                if total == 0 {
                    continue;
                }
                announced_inter_bytes += total;
                let xfer = match shared.fabric.coalesced(rank, node, epoch32, total) {
                    Ok(x) => x,
                    Err(e) => {
                        shared.poison(epoch32);
                        return Err(e).context("coalesced backward dispatch");
                    }
                };
                for t in stash.plan.tiles.iter().filter(|t| topo.node_of(t.dst as usize) == node)
                {
                    fill(&mut pack, t);
                    let coord =
                        Coord { p: rank, r: 0, b: 1, e: t.dslot as usize, c: t.tile as usize * m.bm };
                    if let Err(e) = xfer.put(t.dst as usize, coord, &pack[..t.rows as usize * h])
                    {
                        shared.poison(epoch32);
                        return Err(e).context("coalesced backward fan-out");
                    }
                }
            }
        }
        for t in &stash.plan.tiles {
            let dst = t.dst as usize;
            if hier && !topo.same_node(rank, dst) {
                continue; // already shipped via the coalesced path
            }
            if !topo.same_node(rank, dst) {
                announced_inter_bytes += t.rows as u64 * h as u64 * wb;
            }
            fill(&mut pack, t);
            let coord = Coord { p: rank, r: 0, b: 1, e: t.dslot as usize, c: t.tile as usize * m.bm };
            if let Err(e) =
                shared.fabric.put_signal(rank, dst, coord, &pack[..t.rows as usize * h], epoch32)
            {
                shared.poison(epoch32);
                return Err(e).context("backward dispatch put");
            }
        }

        // ---- pass bookkeeping from the stash (no announcement wait) ----------
        let incoming_tiles = stash.incoming_tiles.clone();
        let block_base = stash.block_base.clone();
        let total_incoming: u32 = incoming_tiles.iter().sum();
        let mut combine_tiles = vec![0u32; ranks_n * e_slots];
        for t in &stash.plan.tiles {
            let idx = t.dst as usize * e_slots + t.dslot as usize;
            combine_tiles[idx] = combine_tiles[idx].max(t.tile + 1);
        }
        let mut tphi = HashMap::with_capacity(stash.plan.tiles.len());
        for (i, t) in stash.plan.tiles.iter().enumerate() {
            tphi.insert((t.dst, t.dslot, t.tile), i as u32);
        }
        let my_expected_combine = stash.plan.tiles.len() as u32;

        // ---- wgrad fold ordinal tables ---------------------------------------
        // Fixed fold order per local slot: (peer asc, tile asc). Tasks
        // mark their ordinal ready; consecutive ready prefixes fold under
        // the slot's lock — deterministic wgrads at any processor count.
        let mut ord_base = vec![0u32; ranks_n * e_slots];
        let mut fold_src: Vec<Vec<FoldSrc>> = (0..e_slots).map(|_| Vec::new()).collect();
        for e_loc in 0..e_slots {
            for peer in 0..ranks_n {
                let pe = peer * e_slots + e_loc;
                ord_base[pe] = fold_src[e_loc].len() as u32;
                let base = block_base[pe] as usize;
                for tile in 0..incoming_tiles[pe] as usize {
                    let block = base + tile;
                    let rows = stash.block_rows[block].load(Ordering::Acquire) as usize;
                    fold_src[e_loc].push(FoldSrc { block, peer, tile, rows });
                }
            }
        }
        let blocks = total_incoming as usize;
        let fold0 = (0..e_slots)
            .map(|el| Mutex::new(WgradFold::new(fold_src[el].len(), m.h * m.d, m.d)))
            .collect();
        let fold1 = (0..e_slots)
            .map(|el| Mutex::new(WgradFold::new(fold_src[el].len(), m.d * m.h, m.h)))
            .collect();
        let bwd = BwdCtx {
            stash: stash.clone(),
            dmid_stage: Staging::new(blocks, m.bm * m.d),
            fold_src,
            ord_base,
            fold0,
            fold1,
        };

        self.queue.reopen();
        let ctx = Arc::new(PassCtx {
            shared: self.shared.clone(),
            rank,
            epoch32,
            queue: self.queue.clone(),
            counters: PassCounters::new(),
            tphi,
            incoming_tiles,
            combine_tiles,
            block_base,
            slices: self.slices.clone(),
            x_stage: None,
            mid: None,
            out_stage: None,
            g0_latch: None,
            g1_latch: None,
            block_rows: (0..blocks).map(|_| AtomicU32::new(0)).collect(),
            combine_stage: Staging::new(stash.plan.tiles.len(), m.bm * h),
            placement: stash.placement.clone(),
            plan: stash.plan.clone(),
            params: stash.params.clone(),
            model: 0,
            e_base: 0,
            key_base: 0,
            delta: None,
            stash: None,
            bwd: Some(bwd),
        });

        // ---- wake the resident processors (doorbell, not spawn) --------------
        {
            let mut st = self.bell.state.lock().unwrap();
            st.ctx = Some(ctx.clone());
            st.done = 0;
            for r in st.results.iter_mut() {
                *r = None;
            }
            st.epoch = epoch;
            self.bell.cv.notify_all();
        }

        let sub_result = bwd_subscriber_loop(ctx.as_ref(), total_incoming, my_expected_combine);

        let worker_results: Vec<Result<()>> = {
            let mut st = self.bell.state.lock().unwrap();
            while st.done < self.workers.len() {
                st = self.bell.cv.wait(st).unwrap();
            }
            st.ctx = None;
            st.results.iter_mut().map(|r| r.take().expect("worker result")).collect()
        };
        sub_result.with_context(|| format!("rank {rank} backward subscriber (pass {epoch})"))?;
        for (i, r) in worker_results.into_iter().enumerate() {
            r.with_context(|| format!("rank {rank} processor {i} (backward pass {epoch})"))?;
        }

        // ---- deterministic dX fold (plan order, unit weights) ----------------
        let s_rows = stash.s_rows;
        let mut dx = vec![0.0f32; s_rows * h];
        for (i, t) in ctx.plan.tiles.iter().enumerate() {
            let g = ctx.combine_stage.read_block(i);
            for (row, &tok) in t.tokens.iter().enumerate() {
                let dst = &mut dx[tok as usize * h..(tok as usize + 1) * h];
                let src = &g[row * h..(row + 1) * h];
                for (o, &v) in dst.iter_mut().zip(src) {
                    *o += v;
                }
            }
        }

        // ---- gate backward (token order, single-threaded: deterministic) -----
        let mut dwg = vec![0.0f32; h * m.e];
        gate_backward(&stash, gy, &mut dx, &mut dwg, m.e, m.k, h);

        // ---- per-slot expert-grad partials -----------------------------------
        // Drained in ascending slot order; the engine merges ranks
        // ascending, so the cross-rank accumulation order is fixed.
        let bwdctx = ctx.bwd.as_ref().unwrap();
        let mut experts: Vec<(usize, ExpertGrad)> = Vec::new();
        for e_loc in 0..e_slots {
            if bwdctx.fold_src[e_loc].is_empty() {
                continue;
            }
            let Some(ge) = stash.placement.expert_on(rank, e_loc) else {
                continue;
            };
            let mut f0 = bwdctx.fold0[e_loc].lock().unwrap();
            let mut f1 = bwdctx.fold1[e_loc].lock().unwrap();
            debug_assert_eq!(f0.next, bwdctx.fold_src[e_loc].len(), "fold0 fully drained");
            debug_assert_eq!(f1.next, bwdctx.fold_src[e_loc].len(), "fold1 fully drained");
            experts.push((
                ge,
                ExpertGrad {
                    w1: std::mem::take(&mut f0.dw),
                    b1: std::mem::take(&mut f0.db),
                    w2: std::mem::take(&mut f1.dw),
                    b2: std::mem::take(&mut f1.db),
                },
            ));
        }

        let wall = t0.elapsed().as_secs_f64();
        let (bytes_local_1, bytes_remote_1) = shared.fabric.bytes_in(rank);
        let c = &ctx.counters;
        let metrics = RankMetrics {
            busy_secs: c.busy_nanos.load(Ordering::Relaxed) as f64 * 1e-9,
            wall_secs: wall,
            processors: self.workers.len(),
            rows_in: s_rows,
            ffn_tasks: 0,
            gemm_tasks: 0,
            combine_tasks: c.combine_completed.load(Ordering::Relaxed),
            tiles_sent: ctx.plan.tiles.len(),
            sent_rows: ctx.plan.sent_rows,
            padded_rows: ctx.plan.padded_rows,
            dropped: 0,
            bytes_in_local: bytes_local_1 - bytes_local_0,
            bytes_in_remote: bytes_remote_1 - bytes_remote_0,
            announced_inter_bytes,
            max_queue_depth: self.queue.max_depth(),
            steals: self.queue.steals() - steals_0,
            expert_offered: Vec::new(),
            expert_kept: Vec::new(),
            replica_rows: 0,
            unavailable_rows: 0,
            dgrad_tasks: c.ffn_completed.load(Ordering::Relaxed)
                + c.dgrad_tasks.load(Ordering::Relaxed),
            wgrad_tasks: c.wgrad_tasks.load(Ordering::Relaxed),
            gate_entropy: 0.0,
        };
        Ok(RankOutput { out: dx, metrics, grads: Some(RankGrads { wg: dwg, experts }) })
    }

    /// Post-panic cleanup: if `epoch` was already published to the
    /// workers when `run_pass` unwound (subscriber watchdog, task error),
    /// stop the ready queue and wait for every worker to drain and park,
    /// so the next pass starts from a synchronized actor group instead of
    /// racing old-ctx workers against a reopened queue.
    pub fn quiesce(&self, epoch: u64) {
        {
            let st = self.bell.state.lock().unwrap();
            if st.epoch != epoch {
                return; // pass never reached the doorbell: workers idle
            }
        }
        self.queue.stop_all();
        let mut st = self.bell.state.lock().unwrap();
        while st.done < self.workers.len() {
            st = self.bell.cv.wait(st).unwrap();
        }
        st.ctx = None;
        for r in st.results.iter_mut() {
            *r = None;
        }
    }

    /// Wake and join the resident workers. Called exactly once, from the
    /// engine's shutdown path.
    pub fn shutdown(mut self) {
        {
            let mut st = self.bell.state.lock().unwrap();
            st.shutdown = true;
            self.bell.cv.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Resident processor worker: park on the doorbell, run one pass's
/// processor loop, report into the pass-done latch, park again.
fn worker_main(bell: Arc<ProcDoorbell>, slot: usize) {
    let mut next_epoch = 1u64;
    loop {
        let (epoch, ctx) = {
            let mut st = bell.state.lock().unwrap();
            loop {
                if st.epoch >= next_epoch {
                    let ctx = st.ctx.as_ref().expect("ctx published with epoch").clone();
                    break (st.epoch, ctx);
                }
                if st.shutdown {
                    return;
                }
                st = bell.cv.wait(st).unwrap();
            }
        };
        let result = processor_loop(ctx.as_ref(), slot);
        {
            let mut st = bell.state.lock().unwrap();
            st.results[slot] = Some(result);
            st.done += 1;
            bell.cv.notify_all();
        }
        // track the epoch actually served: a pass that errored before its
        // doorbell never reaches the workers, and must not desynchronize
        // the worker's position in the epoch stream
        next_epoch = epoch + 1;
    }
}

/// Subscriber actor (Alg. 4): sweep flags, decode packets into tasks, feed
/// the scheduler, interrupt once the self-correcting bound is met.
/// Watchdog: if no flag progress and no task completion for
/// `SystemConfig::watchdog_secs` (see [`EngineShared::watchdog`]) the
/// pass is wedged (protocol bug / lost signal) — fail loudly with a
/// progress diagnostic instead of hanging the process.
///
/// Idle sweeps before the subscriber turns thief (prioritizes decode:
/// fresh flags beat lending a hand for the first few empty sweeps).
const HELP_OUT_AFTER: u32 = 8;

fn subscriber_loop(ctx: &PassCtx, my_expected_combine: u32) -> Result<()> {
    let shared = &*ctx.shared;
    let dims = &shared.dims;
    let ranks = shared.cfg.system.ranks;
    let mut visited = vec![false; dims.num_flags()];
    let mut seen_dispatch = 0u32;
    let mut seen_combine = 0u32;
    let mut seq = 0u32;
    let mut idle_spins = 0u32;
    let mut last_progress = Instant::now();
    // Help-out buffers, allocated on the first steal only (most sweeps
    // never need them): (scratch, tile_out, xbuf).
    let mut help: Option<(Vec<f32>, Vec<f32>, Vec<f32>)> = None;
    loop {
        // Poison check: a peer whose put failed (NIC incast overflow or
        // an injected fault) stamped this pass generation. Its announced
        // tiles will never arrive, so waiting out the watchdog would
        // wedge every rank for `watchdog_secs` — abandon the pass
        // promptly instead. Epoch-exact, so a stamp from an already-
        // failed earlier same-slot pass never matches.
        if shared.poisoned(ctx.epoch32) {
            ctx.queue.stop_all();
            bail!(
                "rank {} abandoning pass gen {} (model {}): a peer failed mid-transfer \
                 (e.g. NIC incast overflow)",
                ctx.rank,
                ctx.epoch32,
                ctx.model
            );
        }
        let mut progressed = false;
        for peer in 0..ranks {
            for e_loc in 0..dims.e_local {
                let pe = peer * dims.e_local + e_loc;
                // Sweep bounds: under `Dropless` the round-0 bound is the
                // pass's announced inbound tile count (the occupied prefix
                // of a slot region varies per pass); under `Capacity` it
                // is the fixed `capacity / bM` worst case. The round-1
                // bound always comes from this rank's own plan.
                // round 0: dispatch packets (token tiles for my experts)
                for tile in 0..ctx.incoming_tiles[pe] as usize {
                    let f0 = dims.flag_index(peer, 0, e_loc, tile);
                    if !visited[f0] {
                        if let Some(rows) = shared.fabric.poll_epoch(ctx.rank, f0, ctx.epoch32) {
                            visited[f0] = true;
                            progressed = true;
                            seen_dispatch += 1;
                            decode_dispatch(ctx, peer, e_loc, tile, rows, &mut seq);
                        }
                    }
                }
                // round 1: combine packets (results for my tokens)
                for tile in 0..ctx.combine_tiles[pe] as usize {
                    let f1 = dims.flag_index(peer, 1, e_loc, tile);
                    if !visited[f1] {
                        if let Some(rows) = shared.fabric.poll_epoch(ctx.rank, f1, ctx.epoch32) {
                            visited[f1] = true;
                            progressed = true;
                            seen_combine += 1;
                            ctx.counters.combine_decoded.fetch_add(1, Ordering::Relaxed);
                            ctx.queue.push(Task {
                                task_type: TaskType::Combine,
                                peer: peer as u32,
                                expert: e_loc as u32,
                                tile: tile as u32,
                                col: 0,
                                rows: rows as u32,
                                seq: next_seq(&mut seq),
                            });
                        }
                    }
                }
            }
        }
        // self-correcting task bound: all sources announced, all announced
        // dispatches decoded and executed, all my combines decoded and applied.
        if shared.announced.load(Ordering::Acquire) as usize == ranks {
            let expected = shared.expected_dispatch[ctx.rank].load(Ordering::Acquire);
            let c = &ctx.counters;
            if seen_dispatch == expected
                && seen_combine == my_expected_combine
                && c.ffn_completed.load(Ordering::Acquire) == c.ffn_decoded.load(Ordering::Acquire)
                && c.combine_completed.load(Ordering::Acquire)
                    == c.combine_decoded.load(Ordering::Acquire)
            {
                ctx.queue.stop_all();
                return Ok(());
            }
        }
        if progressed {
            idle_spins = 0;
            last_progress = Instant::now();
        } else {
            // Help-out path (Alg. 4's OS actor lending a hand): the sweep
            // found no fresh flags, so if the ready pool still holds work,
            // steal a task instead of spinning — one more core on the
            // GEMMs exactly when dispatch is the bottleneck's tail.
            if idle_spins >= HELP_OUT_AFTER {
                if let Some(task) = ctx.queue.steal() {
                    let m = &shared.cfg.model;
                    let (scratch, tile_out, xbuf) = help.get_or_insert_with(|| {
                        let xbuf_len =
                            if shared.fabric.zero_copy() { 0 } else { m.bm * m.h };
                        (
                            vec![0.0f32; m.bm * m.d.max(m.h)],
                            vec![0.0f32; m.bm * m.h.max(m.bn)],
                            vec![0.0f32; xbuf_len],
                        )
                    });
                    if let Err(err) = execute_task(ctx, &task, None, scratch, tile_out, xbuf) {
                        // fail the pass loudly, exactly like the watchdog:
                        // rank_main converts the unwind into a pass error
                        ctx.queue.stop_all();
                        panic!(
                            "rank {} subscriber help-out failed on {task:?}: {err:#}",
                            ctx.rank
                        );
                    }
                    idle_spins = 0;
                    last_progress = Instant::now();
                    continue;
                }
            }
            idle_spins += 1;
            if idle_spins < 64 {
                std::hint::spin_loop();
            } else {
                std::thread::yield_now();
            }
            if idle_spins % 4096 == 0 && last_progress.elapsed() > shared.watchdog() {
                let c = &ctx.counters;
                ctx.queue.stop_all();
                panic!(
                    "rank {} wedged ({:.1}s since last progress, watchdog {}s, pass gen {}): \
                     announced {}/{ranks}, \
                     dispatch {seen_dispatch}/{}, combine {seen_combine}/{my_expected_combine}, \
                     ffn {}/{}, combine-exec {}/{}",
                    ctx.rank,
                    last_progress.elapsed().as_secs_f64(),
                    shared.cfg.system.watchdog_secs,
                    ctx.epoch32,
                    shared.announced.load(Ordering::Acquire),
                    shared.expected_dispatch[ctx.rank].load(Ordering::Acquire),
                    c.ffn_completed.load(Ordering::Acquire),
                    c.ffn_decoded.load(Ordering::Acquire),
                    c.combine_completed.load(Ordering::Acquire),
                    c.combine_decoded.load(Ordering::Acquire),
                );
            }
        }
    }
}

fn next_seq(seq: &mut u32) -> u32 {
    *seq += 1;
    *seq
}

/// Subscriber for a backward pass. Identical flag protocol to the
/// forward's — round-0 cells now carry scaled output-grad tiles for my
/// experts, round-1 cells carry dX tiles returning for my tokens — but
/// the sweep bounds come from the stash, not from announcements: the
/// reverse tile set is the forward plan, which both sides kept. The
/// task bound gains a backward leg: every decoded Dgrad1 spawns
/// Wgrad1 + Wgrad0 + Dgrad0, all of which must complete.
fn bwd_subscriber_loop(ctx: &PassCtx, total_incoming: u32, my_expected_combine: u32) -> Result<()> {
    let shared = &*ctx.shared;
    let dims = &shared.dims;
    let ranks = shared.cfg.system.ranks;
    let mut visited = vec![false; dims.num_flags()];
    let mut seen_dispatch = 0u32;
    let mut seen_combine = 0u32;
    let mut seq = 0u32;
    let mut idle_spins = 0u32;
    let mut last_progress = Instant::now();
    let mut help: Option<(Vec<f32>, Vec<f32>, Vec<f32>)> = None;
    loop {
        if shared.poisoned(ctx.epoch32) {
            ctx.queue.stop_all();
            bail!(
                "rank {} abandoning pass gen {}: a peer failed mid-transfer \
                 (e.g. NIC incast overflow)",
                ctx.rank,
                ctx.epoch32
            );
        }
        let mut progressed = false;
        for peer in 0..ranks {
            for e_loc in 0..dims.e_local {
                let pe = peer * dims.e_local + e_loc;
                // round 0: scaled output-grad tiles for my experts
                for tile in 0..ctx.incoming_tiles[pe] as usize {
                    let f0 = dims.flag_index(peer, 0, e_loc, tile);
                    if !visited[f0] {
                        if let Some(rows) = shared.fabric.poll_epoch(ctx.rank, f0, ctx.epoch32) {
                            visited[f0] = true;
                            progressed = true;
                            seen_dispatch += 1;
                            ctx.counters.ffn_decoded.fetch_add(1, Ordering::Relaxed);
                            ctx.queue.push(Task {
                                task_type: TaskType::Dgrad1,
                                peer: peer as u32,
                                expert: e_loc as u32,
                                tile: tile as u32,
                                col: 0,
                                rows: rows as u32,
                                seq: next_seq(&mut seq),
                            });
                        }
                    }
                }
                // round 1: dX tiles returning for my tokens
                for tile in 0..ctx.combine_tiles[pe] as usize {
                    let f1 = dims.flag_index(peer, 1, e_loc, tile);
                    if !visited[f1] {
                        if let Some(rows) = shared.fabric.poll_epoch(ctx.rank, f1, ctx.epoch32) {
                            visited[f1] = true;
                            progressed = true;
                            seen_combine += 1;
                            ctx.counters.combine_decoded.fetch_add(1, Ordering::Relaxed);
                            ctx.queue.push(Task {
                                task_type: TaskType::Combine,
                                peer: peer as u32,
                                expert: e_loc as u32,
                                tile: tile as u32,
                                col: 0,
                                rows: rows as u32,
                                seq: next_seq(&mut seq),
                            });
                        }
                    }
                }
            }
        }
        // self-correcting bound, backward edition: every stashed inbound
        // tile decoded and differentiated (Dgrad1), every spawned
        // follow-up (Wgrad1/Wgrad0/Dgrad0) done, every dX tile applied.
        let c = &ctx.counters;
        if seen_dispatch == total_incoming
            && seen_combine == my_expected_combine
            && c.ffn_completed.load(Ordering::Acquire) == c.ffn_decoded.load(Ordering::Acquire)
            && c.bwd_completed.load(Ordering::Acquire) == c.bwd_spawned.load(Ordering::Acquire)
            && c.combine_completed.load(Ordering::Acquire)
                == c.combine_decoded.load(Ordering::Acquire)
        {
            ctx.queue.stop_all();
            return Ok(());
        }
        if progressed {
            idle_spins = 0;
            last_progress = Instant::now();
        } else {
            if idle_spins >= HELP_OUT_AFTER {
                if let Some(task) = ctx.queue.steal() {
                    let m = &shared.cfg.model;
                    let (scratch, tile_out, xbuf) = help.get_or_insert_with(|| {
                        let xbuf_len = if shared.fabric.zero_copy() { 0 } else { m.bm * m.h };
                        (
                            vec![0.0f32; m.bm * m.d.max(m.h)],
                            vec![0.0f32; m.bm * m.h.max(m.bn)],
                            vec![0.0f32; xbuf_len],
                        )
                    });
                    if let Err(err) = execute_task(ctx, &task, None, scratch, tile_out, xbuf) {
                        ctx.queue.stop_all();
                        panic!(
                            "rank {} backward subscriber help-out failed on {task:?}: {err:#}",
                            ctx.rank
                        );
                    }
                    idle_spins = 0;
                    last_progress = Instant::now();
                    continue;
                }
            }
            idle_spins += 1;
            if idle_spins < 64 {
                std::hint::spin_loop();
            } else {
                std::thread::yield_now();
            }
            if idle_spins % 4096 == 0 && last_progress.elapsed() > shared.watchdog() {
                let c = &ctx.counters;
                ctx.queue.stop_all();
                panic!(
                    "rank {} wedged in backward ({:.1}s since last progress, watchdog {}s, \
                     pass gen {}): dispatch {seen_dispatch}/{total_incoming}, \
                     combine {seen_combine}/{my_expected_combine}, dgrad {}/{}, \
                     bwd {}/{}, combine-exec {}/{}",
                    ctx.rank,
                    last_progress.elapsed().as_secs_f64(),
                    shared.cfg.system.watchdog_secs,
                    ctx.epoch32,
                    c.ffn_completed.load(Ordering::Acquire),
                    c.ffn_decoded.load(Ordering::Acquire),
                    c.bwd_completed.load(Ordering::Acquire),
                    c.bwd_spawned.load(Ordering::Acquire),
                    c.combine_completed.load(Ordering::Acquire),
                    c.combine_decoded.load(Ordering::Acquire),
                );
            }
        }
    }
}

/// Gate backward for one rank's stashed forward, folded after the dX
/// tiles: per routed (token, expert) pair, dc = ⟨dY, y_unweighted⟩ from
/// the stashed y tiles; through the top-k renormalization
/// (c_j = p_j / Σ p_topk) and the softmax Jacobian into dWg and the
/// gate's dX term. Token-major and single-threaded — deterministic by
/// construction. Dropped pairs contributed nothing forward, so their dc
/// is correctly zero.
fn gate_backward(
    stash: &RankStash,
    gy: &[f32],
    dx: &mut [f32],
    dwg: &mut [f32],
    e: usize,
    k: usize,
    h: usize,
) {
    let s = stash.s_rows;
    // dc[i*k + j] = <dY_i, y_{i, topk j}>: one sweep over the plan tiles,
    // reading each row's unweighted expert output from the y stash.
    let mut dc = vec![0.0f32; s * k];
    for (ord, t) in stash.plan.tiles.iter().enumerate() {
        let y = stash.y_stage.read_block(ord);
        for (row, &tok) in t.tokens.iter().enumerate() {
            let tok = tok as usize;
            let dot: f32 = gy[tok * h..(tok + 1) * h]
                .iter()
                .zip(&y[row * h..(row + 1) * h])
                .map(|(&a, &b)| a * b)
                .sum();
            for j in 0..k {
                if stash.topk_idx[tok * k + j] == t.expert {
                    dc[tok * k + j] += dot;
                    break;
                }
            }
        }
    }
    let wg = &stash.params.wg;
    let mut dlogits = vec![0.0f32; e];
    for i in 0..s {
        let p_row = &stash.scores[i * e..(i + 1) * e];
        let wrow = &stash.topk_w[i * k..(i + 1) * k];
        let denom: f32 = wrow.iter().sum();
        if denom <= 0.0 || !denom.is_finite() {
            continue; // degenerate gate row: the forward clamped, skip
        }
        // combine weights c_j = w_j/denom; d/dw_j of Σ c_l dc_l
        let gsum: f32 = (0..k).map(|j| (wrow[j] / denom) * dc[i * k + j]).sum();
        dlogits.fill(0.0);
        for j in 0..k {
            let ex = stash.topk_idx[i * k + j] as usize;
            dlogits[ex] = (dc[i * k + j] - gsum) / denom;
        }
        // softmax Jacobian: dlogit_v = p_v (dp_v − Σ_u dp_u p_u)
        let dp_dot_p: f32 = dlogits.iter().zip(p_row).map(|(&dp, &p)| dp * p).sum();
        for (dl, &p) in dlogits.iter_mut().zip(p_row) {
            *dl = p * (*dl - dp_dot_p);
        }
        // dWg += x_iᵀ ⊗ dlogits;  dx_i += dlogits · Wgᵀ
        let xi = &stash.x[i * h..(i + 1) * h];
        let dxi = &mut dx[i * h..(i + 1) * h];
        for (pdim, (&xv, dxv)) in xi.iter().zip(dxi.iter_mut()).enumerate() {
            let wgrow = &wg[pdim * e..(pdim + 1) * e];
            let dwgrow = &mut dwg[pdim * e..(pdim + 1) * e];
            let mut acc = 0.0f32;
            for jj in 0..e {
                dwgrow[jj] += xv * dlogits[jj];
                acc += dlogits[jj] * wgrow[jj];
            }
            *dxv += acc;
        }
    }
}

/// Decode one dispatch packet into task descriptors (Alg. 4 line 18).
fn decode_dispatch(ctx: &PassCtx, peer: usize, e_loc: usize, tile: usize, rows: usize, seq: &mut u32) {
    let m = &ctx.shared.cfg.model;
    ctx.counters.ffn_decoded.fetch_add(1, Ordering::Relaxed);
    // `e_loc` is band-absolute; the replica slots sit at the tail of the
    // pass model's own band.
    if e_loc - ctx.e_base >= ctx.shared.cfg.local_experts() {
        // rows landing in a replica slot: traffic replication absorbed
        ctx.counters.replica_rows.fetch_add(rows as u64, Ordering::Relaxed);
    }
    match ctx.shared.mode {
        TaskGraphMode::Fused => {
            // Training tape: record the block's valid rows so the
            // backward can rebuild its fold tables without re-announcing.
            if let Some(stash) = &ctx.stash {
                stash.block_rows[ctx.block_id(peer, e_loc, tile)]
                    .store(rows as u32, Ordering::Release);
            }
            ctx.queue.push(Task {
                task_type: TaskType::FusedFfn,
                peer: peer as u32,
                expert: e_loc as u32,
                tile: tile as u32,
                col: 0,
                rows: rows as u32,
                seq: next_seq(seq),
            });
        }
        TaskGraphMode::Split => {
            let block = ctx.block_id(peer, e_loc, tile);
            ctx.block_rows[block].store(rows as u32, Ordering::Release);
            // Reduced wire: decode the tile to f32 exactly once, before
            // the column tasks are queued (the queue handoff publishes
            // the write) — the D/bN Gemm0 tasks all read this one copy.
            // F32 wire: no stage exists; Gemm0 borrows the cell directly.
            if let Some(stage) = &ctx.x_stage {
                let coord = Coord { p: peer, r: 0, b: 1, e: e_loc, c: tile * m.bm };
                stage.fill_block(block, |dst| {
                    ctx.shared.fabric.read_into(ctx.rank, coord, m.bm, dst);
                });
            }
            let tasks: Vec<Task> = (0..(m.d / m.bn) as u32)
                .map(|col| Task {
                    task_type: TaskType::Gemm0,
                    peer: peer as u32,
                    expert: e_loc as u32,
                    tile: tile as u32,
                    col,
                    rows: rows as u32,
                    seq: next_seq(seq),
                })
                .collect();
            ctx.queue.push_batch(tasks);
        }
    }
}

/// Processor actor (Alg. 2): pop (own deque, else steal) → execute →
/// notify, until interrupted. `slot` identifies this worker's deque in
/// the work-stealing pool.
fn processor_loop(ctx: &PassCtx, slot: usize) -> Result<()> {
    let shared = &*ctx.shared;
    let m = &shared.cfg.model;
    let (h, d) = (m.h, m.d);
    let mut scratch = vec![0.0f32; m.bm * d.max(h)];
    let mut tile_out = vec![0.0f32; m.bm * h.max(m.bn)];
    // decode buffer for reduced-wire heap reads (f32 after decode);
    // zero-length on a zero-copy wire, where reads borrow the heap and
    // never touch it — no per-pass megabytes for the default f32 config
    let xbuf_len = if shared.fabric.zero_copy() { 0 } else { m.bm * h };
    let mut xbuf = vec![0.0f32; xbuf_len];
    while let Some(task) = ctx.queue.pop(slot) {
        let t0 = Instant::now();
        execute_task(ctx, &task, Some(slot), &mut scratch, &mut tile_out, &mut xbuf)
            .with_context(|| format!("rank {} task {task:?}", ctx.rank))?;
        ctx.counters
            .busy_nanos
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }
    Ok(())
}

/// Execute one task. `slot` is the executing processor's deque (its
/// spawned children are owner-pushed there, LIFO, while the intermediate
/// block is cache-hot); `None` means the subscriber is helping out via a
/// steal, so children go through the external round-robin path instead.
/// `xbuf` (≥ bM×H floats) receives heap payloads decoded from a reduced
/// wire back to f32 before compute consumes them; on an f32 wire the
/// reads borrow the heap zero-copy and `xbuf` goes untouched.
fn execute_task(
    ctx: &PassCtx,
    task: &Task,
    slot: Option<usize>,
    scratch: &mut [f32],
    tile_out: &mut [f32],
    xbuf: &mut [f32],
) -> Result<()> {
    let shared = &*ctx.shared;
    let m = &shared.cfg.model;
    let (h, bm, bn) = (m.h, m.bm, m.bn);
    let (peer, e_loc, tile) = (task.peer as usize, task.expert as usize, task.tile as usize);
    // `task.expert` is a band-absolute *slot* on the serving rank. For
    // compute tasks the serving rank is us — strip the pass model's band
    // offset and resolve the slot to the global expert it is bound to
    // under this pass's placement snapshot (owned slots map statically;
    // replica slots follow the dynamic binding).
    let resolve = |r: usize| {
        ctx.placement
            .expert_on(r, e_loc - ctx.e_base)
            .ok_or_else(|| anyhow!("rank {r} slot {e_loc}: no expert bound (task {task:?})"))
    };
    match task.task_type {
        TaskType::FusedFfn => {
            let coord = Coord { p: peer, r: 0, b: 1, e: e_loc, c: tile * bm };
            // f32 wire: zero-copy borrow; 16-bit wire: decode into xbuf
            let x: &[f32] = match shared.fabric.read_borrowed(ctx.rank, coord, bm) {
                Some(x) => x,
                None => {
                    shared.fabric.read_into(ctx.rank, coord, bm, xbuf);
                    &xbuf[..bm * h]
                }
            };
            let global_e = resolve(ctx.rank)?;
            // Cache key shifted into the pass model's packed region: a
            // dedup or delta variant shares its base's `key_base`, so the
            // same packed panels serve both models.
            shared.backend.ffn_tile(
                x,
                &ctx.params.experts[global_e],
                ctx.key_base + global_e,
                &mut tile_out[..bm * h],
                scratch,
            )?;
            // Delta-variant epilogue (multi-model): the base's packed
            // panels computed the main FFN; fold the low-rank update
            // `(mid·A2)·B2 + db2` into the output rows before they ship.
            // `mid` comes free from scratch when the backend honors the
            // contract; otherwise replay GEMM0+ReLU for these rows.
            if let Some(delta) = &ctx.delta {
                let rows = task.rows as usize;
                let mid_buf;
                let mid: &[f32] = if shared.backend.mid_in_scratch() {
                    &scratch[..rows * m.d]
                } else {
                    let ex = &ctx.params.experts[global_e];
                    let mut buf = vec![0.0f32; rows * m.d];
                    gemm::gemm_bias(
                        x,
                        &ex.w1,
                        Some(&ex.b1),
                        &mut buf,
                        rows,
                        h,
                        m.d,
                        gemm::Epilogue::Relu,
                    );
                    mid_buf = buf;
                    &mid_buf
                };
                delta.apply_rows(global_e, mid, &mut tile_out[..rows * h], rows);
            }
            // Training tape: capture this block's decoded inputs (dW1's
            // left operand) and — when the backend leaves the post-ReLU
            // intermediate in scratch — the mid block, so the backward
            // needs no recompute.
            if let Some(stash) = &ctx.stash {
                let block = ctx.block_id(peer, e_loc, tile);
                let rows = task.rows as usize;
                stash.x_stash.write_stripe(block, rows, h, 0, h, &x[..rows * h]);
                if stash.has_mid {
                    stash.mid_stash.write_stripe(block, rows, m.d, 0, m.d, &scratch[..rows * m.d]);
                }
            }
            // one-sided combine write-back to the originating rank —
            // crosses the NIC directly for a cross-node peer, so a
            // receive-window overflow here poisons the pass for everyone
            let back = Coord { p: ctx.rank, r: 1, b: 1, e: e_loc, c: tile * bm };
            if let Err(e) = shared.fabric.put_signal(
                ctx.rank,
                peer,
                back,
                &tile_out[..task.rows as usize * h],
                ctx.epoch32,
            ) {
                shared.poison(ctx.epoch32);
                return Err(e);
            }
            ctx.counters.ffn_completed.fetch_add(1, Ordering::Release);
        }
        TaskType::Gemm0 => {
            let col = task.col as usize;
            let block = ctx.block_id(peer, e_loc, tile);
            // Reduced wire: the subscriber decoded this tile to f32 once
            // at decode time (x_stage) and column tasks share that copy.
            // F32 wire: borrow the heap cell zero-copy, as pre-PR.
            let x: &[f32] = match &ctx.x_stage {
                Some(stage) => stage.read_block(block),
                None => {
                    let coord = Coord { p: peer, r: 0, b: 1, e: e_loc, c: tile * bm };
                    shared
                        .fabric
                        .read_borrowed(ctx.rank, coord, bm)
                        .expect("x_stage is None only on a zero-copy wire")
                }
            };
            let sl = ctx.slices.as_ref().unwrap();
            let ge = resolve(ctx.rank)?;
            shared.backend.gemm0_tile(
                x,
                &sl.w1c[ge][col],
                &sl.b1c[ge][col],
                &mut tile_out[..bm * bn],
                ge,
                col,
            )?;
            ctx.mid.as_ref().unwrap().write_stripe(block, bm, m.d, col, bn, &tile_out[..bm * bn]);
            ctx.counters.gemm_tasks.fetch_add(1, Ordering::Relaxed);
            if ctx.g0_latch.as_ref().unwrap().complete_one(block) {
                // full (bM, D) intermediate ready -> unlock the GEMM1 chain.
                // Owner-push onto this processor's deque: the block it just
                // finished is cache-hot, and idle peers steal the surplus.
                let tasks: Vec<Task> = (0..(m.h / bn) as u32)
                    .map(|c2| Task {
                        task_type: TaskType::Gemm1,
                        col: c2,
                        seq: task.seq,
                        ..*task
                    })
                    .collect();
                match slot {
                    Some(s) => ctx.queue.push_batch_local(s, tasks),
                    None => ctx.queue.push_batch(tasks),
                }
            }
        }
        TaskType::Gemm1 => {
            let col = task.col as usize;
            let block = ctx.block_id(peer, e_loc, tile);
            let mid = ctx.mid.as_ref().unwrap().read_block(block);
            let sl = ctx.slices.as_ref().unwrap();
            let ge = resolve(ctx.rank)?;
            shared.backend.gemm1_tile(
                mid,
                &sl.w2c[ge][col],
                &sl.b2c[ge][col],
                &mut tile_out[..bm * bn],
                ge,
                col,
            )?;
            let out_stage = ctx.out_stage.as_ref().unwrap();
            out_stage.write_stripe(block, bm, h, col, bn, &tile_out[..bm * bn]);
            ctx.counters.gemm_tasks.fetch_add(1, Ordering::Relaxed);
            if ctx.g1_latch.as_ref().unwrap().complete_one(block) {
                // full (bM, H) output tile ready -> combine write-back
                let rows = ctx.block_rows[block].load(Ordering::Acquire) as usize;
                let y = out_stage.read_block(block);
                let back = Coord { p: ctx.rank, r: 1, b: 1, e: e_loc, c: tile * bm };
                if let Err(e) =
                    shared.fabric.put_signal(ctx.rank, peer, back, &y[..rows * h], ctx.epoch32)
                {
                    shared.poison(ctx.epoch32);
                    return Err(e);
                }
                ctx.counters.ffn_completed.fetch_add(1, Ordering::Release);
            }
        }
        TaskType::Combine => {
            // `peer` is the serving rank; e_loc is the slot it served
            // (owned or replica) — exactly the (dst, dslot) the plan
            // dispatched on, so the T_phi key needs no expert resolution.
            let rows = task.rows as usize;
            let coord = Coord { p: peer, r: 1, b: 1, e: e_loc, c: tile * bm };
            // f32 wire: zero-copy borrow; 16-bit wire: decode into xbuf
            let y: &[f32] = match shared.fabric.read_borrowed(ctx.rank, coord, rows) {
                Some(y) => y,
                None => {
                    shared.fabric.read_into(ctx.rank, coord, rows, xbuf);
                    &xbuf[..rows * h]
                }
            };
            let ordinal = *ctx
                .tphi
                .get(&(task.peer, task.expert, task.tile))
                .ok_or_else(|| {
                    anyhow!("combine for unknown tile (dst={peer}, slot={e_loc}, t={tile})")
                })? as usize;
            let t = &ctx.plan.tiles[ordinal];
            anyhow::ensure!(t.tokens.len() == rows, "combine row mismatch");
            if ctx.bwd.is_some() {
                // Backward: these are dX tiles already scaled at the
                // source (the reverse dispatch folded the combine weights
                // into the payload) — stage them unscaled; the subscriber
                // folds in plan order with unit weight.
                ctx.combine_stage.write_stripe(ordinal, rows, h, 0, h, &y[..rows * h]);
                ctx.counters.combine_completed.fetch_add(1, Ordering::Release);
                return Ok(());
            }
            // Training tape: the gate backward needs the *unweighted*
            // expert outputs (dc = ⟨dY, y⟩); capture them before scaling.
            if let Some(stash) = &ctx.stash {
                stash.y_stage.write_stripe(ordinal, rows, h, 0, h, &y[..rows * h]);
            }
            // Scale by the combine weights into this tile's private staging
            // block. The subscriber folds blocks in plan order after the
            // processors park, so the reduction order — and the output —
            // is bitwise deterministic under any scheduling.
            for (row, &w) in t.weights.iter().enumerate() {
                let src = &y[row * h..(row + 1) * h];
                let dst = &mut tile_out[row * h..(row + 1) * h];
                for (o, &v) in dst.iter_mut().zip(src) {
                    *o = w * v;
                }
            }
            ctx.combine_stage.write_stripe(ordinal, rows, h, 0, h, &tile_out[..rows * h]);
            ctx.counters.combine_completed.fetch_add(1, Ordering::Release);
        }
        TaskType::Dgrad1 => {
            // dMid = (dY'·W2ᵀ) ⊙ relu'(mid), into the dmid stage; then
            // fan out this tile's remaining backward: both wgrad folds
            // and the dX producer (owner-pushed, stealable).
            let bwd = ctx.bwd.as_ref().ok_or_else(|| anyhow!("Dgrad1 outside a backward"))?;
            let stash = &bwd.stash;
            let rows = task.rows as usize;
            let block = ctx.block_id(peer, e_loc, tile);
            let d = m.d;
            let coord = Coord { p: peer, r: 0, b: 1, e: e_loc, c: tile * bm };
            let dyw: &[f32] = match shared.fabric.read_borrowed(ctx.rank, coord, rows) {
                Some(g) => g,
                None => {
                    shared.fabric.read_into(ctx.rank, coord, rows, xbuf);
                    &xbuf[..rows * h]
                }
            };
            let ge = resolve(ctx.rank)?;
            let ex = &stash.params.experts[ge];
            let mid_buf;
            let mid: &[f32] = if stash.has_mid {
                &stash.mid_stash.read_block(block)[..rows * d]
            } else {
                // Backend didn't leave mid in scratch (see
                // `ComputeBackend::mid_in_scratch`): replay GEMM0+ReLU
                // from the stashed inputs.
                let x = &stash.x_stash.read_block(block)[..rows * h];
                let mut buf = vec![0.0f32; rows * d];
                let relu = gemm::Epilogue::Relu;
                gemm::gemm_bias(x, &ex.w1, Some(&ex.b1), &mut buf, rows, h, d, relu);
                mid_buf = buf;
                &mid_buf
            };
            gemm::gemm_a_bt(dyw, &ex.w2, &mut scratch[..rows * d], rows, h, d);
            for (dv, &mv) in scratch[..rows * d].iter_mut().zip(mid) {
                if mv <= 0.0 {
                    *dv = 0.0;
                }
            }
            bwd.dmid_stage.write_stripe(block, rows, d, 0, d, &scratch[..rows * d]);
            ctx.counters.bwd_spawned.fetch_add(3, Ordering::Relaxed);
            let tasks = vec![
                Task { task_type: TaskType::Wgrad1, ..*task },
                Task { task_type: TaskType::Wgrad0, ..*task },
                Task { task_type: TaskType::Dgrad0, ..*task },
            ];
            match slot {
                Some(s) => ctx.queue.push_batch_local(s, tasks),
                None => ctx.queue.push_batch(tasks),
            }
            ctx.counters.ffn_completed.fetch_add(1, Ordering::Release);
        }
        TaskType::Dgrad0 => {
            // dX = dMid·W1ᵀ, shipped back to the source over the combine
            // round's cells (the reverse-wire gather), same generation tag
            // and poison discipline as a forward combine write-back.
            let bwd = ctx.bwd.as_ref().ok_or_else(|| anyhow!("Dgrad0 outside a backward"))?;
            let rows = task.rows as usize;
            let block = ctx.block_id(peer, e_loc, tile);
            let d = m.d;
            let ge = resolve(ctx.rank)?;
            let ex = &bwd.stash.params.experts[ge];
            let dmid = &bwd.dmid_stage.read_block(block)[..rows * d];
            gemm::gemm_a_bt(dmid, &ex.w1, &mut tile_out[..rows * h], rows, d, h);
            let back = Coord { p: ctx.rank, r: 1, b: 1, e: e_loc, c: tile * bm };
            if let Err(e) =
                shared.fabric.put_signal(ctx.rank, peer, back, &tile_out[..rows * h], ctx.epoch32)
            {
                shared.poison(ctx.epoch32);
                return Err(e);
            }
            ctx.counters.dgrad_tasks.fetch_add(1, Ordering::Relaxed);
            ctx.counters.bwd_completed.fetch_add(1, Ordering::Release);
        }
        TaskType::Wgrad0 | TaskType::Wgrad1 => {
            // Deterministic weight-gradient fold: mark this tile's fold
            // ordinal ready, then fold every consecutive ready ordinal
            // while holding the slot's lock — the accumulation order is
            // (peer asc, tile asc) under any steal schedule, mirroring
            // the forward's plan-order combine fold.
            let bwd = ctx.bwd.as_ref().ok_or_else(|| anyhow!("Wgrad outside a backward"))?;
            let stash = &bwd.stash;
            let d = m.d;
            let ordinal =
                (bwd.ord_base[peer * shared.dims.e_local + e_loc] + task.tile) as usize;
            let is_w1 = task.task_type == TaskType::Wgrad0;
            let fold = if is_w1 { &bwd.fold0[e_loc] } else { &bwd.fold1[e_loc] };
            let mut f = fold.lock().unwrap();
            f.ready[ordinal] = true;
            while f.next < f.ready.len() && f.ready[f.next] {
                let src = bwd.fold_src[e_loc][f.next];
                let rows = src.rows;
                if is_w1 {
                    // dW1 += xᵀ·dMid;  db1 += column-sum(dMid)
                    let x = &stash.x_stash.read_block(src.block)[..rows * h];
                    let dmid = &bwd.dmid_stage.read_block(src.block)[..rows * d];
                    gemm::gemm_at_b_acc(x, dmid, &mut f.dw, rows, h, d);
                    gemm::colsum_acc(dmid, &mut f.db, rows, d);
                } else {
                    // dW2 += midᵀ·dY';  db2 += column-sum(dY')
                    let coord =
                        Coord { p: src.peer, r: 0, b: 1, e: e_loc, c: src.tile * bm };
                    let dyw: &[f32] =
                        match shared.fabric.read_borrowed(ctx.rank, coord, rows) {
                            Some(g) => g,
                            None => {
                                shared.fabric.read_into(ctx.rank, coord, rows, xbuf);
                                &xbuf[..rows * h]
                            }
                        };
                    let mid_buf;
                    let mid: &[f32] = if stash.has_mid {
                        &stash.mid_stash.read_block(src.block)[..rows * d]
                    } else {
                        let x = &stash.x_stash.read_block(src.block)[..rows * h];
                        let ge = resolve(ctx.rank)?;
                        let ex = &stash.params.experts[ge];
                        let mut buf = vec![0.0f32; rows * d];
                        gemm::gemm_bias(
                            x,
                            &ex.w1,
                            Some(&ex.b1),
                            &mut buf,
                            rows,
                            h,
                            d,
                            gemm::Epilogue::Relu,
                        );
                        mid_buf = buf;
                        &mid_buf
                    };
                    gemm::gemm_at_b_acc(mid, dyw, &mut f.dw, rows, d, h);
                    gemm::colsum_acc(dyw, &mut f.db, rows, h);
                }
                f.next += 1;
            }
            drop(f);
            ctx.counters.wgrad_tasks.fetch_add(1, Ordering::Relaxed);
            ctx.counters.bwd_completed.fetch_add(1, Ordering::Release);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Regression for the single-word poison hazard: with two passes in
    /// flight, pass N+1's start-clear must never erase pass N's stamp.
    #[test]
    fn poison_stamps_are_per_slot() {
        let latch = PoisonLatch::new();
        // two in-flight generations poison independently
        latch.poison(5); // slot 1
        latch.poison(6); // slot 0
        assert!(latch.poisoned(5));
        assert!(latch.poisoned(6));
        // pass 7's start-clear (slot 1) erases only 5's stale stamp —
        // the old single-word latch would have wiped 6's live stamp too
        latch.clear(7);
        assert!(!latch.poisoned(5));
        assert!(latch.poisoned(6), "other slot's stamp survives the clear");
        assert!(!latch.poisoned(7), "cleared slot reads clean for the new pass");
        // epoch-exact: a same-slot stamp from an earlier generation never
        // matches the current one
        latch.poison(3);
        assert!(!latch.poisoned(5));
        assert!(latch.poisoned(3));
    }

    #[test]
    fn poison_clear_is_slot_local_over_many_generations() {
        let latch = PoisonLatch::new();
        for epoch in 1..50u32 {
            // pass `epoch` starts: clear its own slot only
            latch.clear(epoch);
            assert!(!latch.poisoned(epoch));
            // the previous pass poisons late (still collecting)
            if epoch > 1 {
                latch.poison(epoch - 1);
                assert!(latch.poisoned(epoch - 1), "gen {}", epoch - 1);
                assert!(!latch.poisoned(epoch), "new pass unaffected");
            }
        }
    }
}
