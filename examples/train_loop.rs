//! End-to-end training driver: the paper's §5 future-work item
//! (training support) running through the **persistent engine itself** —
//! every step is one stashed forward pass plus one backward pass of
//! Dgrad/Wgrad tile tasks through the same work-stealing scheduler and
//! reverse-wire transfers, followed by an optimizer step installed at an
//! epoch-fenced quiet point (`MoeEngine::update_params`).
//!
//! Synthetic teacher–student regression: a frozen teacher MoE (different
//! seed) produces the targets, and the student is trained with MSE to
//! reproduce them. The smoothed loss curve must go down.
//!
//!     cargo run --release --example train_loop
//!     STEPS=100 LR=1e-2 OPT=sgd PRESET=tiny cargo run --release --example train_loop

use std::sync::Arc;

use flashdmoe::config::Config;
use flashdmoe::coordinator::{MoeEngine, TaskGraphMode};
use flashdmoe::expert::{generate_tokens, ModelParams};
use flashdmoe::runtime::{ComputeBackend, NativeBackend};
use flashdmoe::train::{Optimizer, Trainer};
use flashdmoe::util::check::dense_reference_moe;
use flashdmoe::util::stats::fmt_time;

fn main() -> anyhow::Result<()> {
    let preset = std::env::var("PRESET").unwrap_or_else(|_| "tiny".to_string());
    let steps: usize = std::env::var("STEPS").ok().and_then(|v| v.parse().ok()).unwrap_or(40);
    let lr: f32 = std::env::var("LR").ok().and_then(|v| v.parse().ok()).unwrap_or(5e-3);
    let opt_name = std::env::var("OPT").unwrap_or_else(|_| "adam".to_string());

    let mut cfg = Config::preset(&preset)?;
    cfg.set("train", "on")?;
    cfg.set("lr", &lr.to_string())?;
    cfg.set("optimizer", &opt_name)?;
    cfg.validate()?;
    let (h, d, e) = (cfg.model.h, cfg.model.d, cfg.model.e);
    println!(
        "training through the persistent engine: H={h} D={d} E={e} k={} \
         ranks={} tokens/rank={} optimizer={opt_name} lr={lr}",
        cfg.model.k, cfg.system.ranks, cfg.system.s_rank
    );

    // ---- teacher–student regression -----------------------------------
    // frozen teacher (seed 2) labels the batch; student (seed 1) learns it
    let student = Arc::new(ModelParams::generate(&cfg, 1));
    let teacher = ModelParams::generate(&cfg, 2);
    let inputs: Vec<Vec<f32>> =
        (0..cfg.system.ranks).map(|r| generate_tokens(&cfg, 0x7EAC4, r)).collect();
    let targets: Vec<Vec<f32>> =
        inputs.iter().map(|x| dense_reference_moe(&cfg, &teacher, x)).collect();

    // ---- engine + trainer ----------------------------------------------
    let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(&cfg));
    let engine = MoeEngine::start(cfg.clone(), student, backend, TaskGraphMode::Fused)?;
    let opt = match opt_name.as_str() {
        "sgd" => Optimizer::sgd(lr),
        _ => Optimizer::adam(lr),
    };
    let mut trainer = Trainer::new(engine, opt)?;

    // ---- training loop: forward + backward engine passes per step -------
    let t0 = std::time::Instant::now();
    let mut curve: Vec<(usize, f64, f64)> = Vec::new();
    let mut smoothed = f64::NAN;
    let mut first_smoothed = f64::NAN;
    for step in 0..steps {
        let report = trainer.train_step(&inputs, &targets)?;
        anyhow::ensure!(report.loss.is_finite(), "loss diverged at step {step}");
        smoothed = if smoothed.is_nan() {
            report.loss
        } else {
            0.7 * smoothed + 0.3 * report.loss
        };
        if step == 0 {
            first_smoothed = smoothed;
        }
        if step % (steps / 15).max(1) == 0 || step + 1 == steps {
            curve.push((step, report.loss, report.grad_sq_norm.sqrt()));
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();

    println!("\nstep   loss        |grad|");
    let first_loss = curve.first().map(|&(_, l, _)| l).unwrap_or(f64::NAN);
    for &(s, l, g) in &curve {
        let bar = "#".repeat(((l / first_loss).min(1.0) * 50.0) as usize);
        println!("{s:>5}  {l:<10.6}  {g:<9.4} {bar}");
    }
    let em = trainer.engine().metrics();
    println!(
        "\n{} steps in {} ({}/step) — smoothed loss {:.6} -> {:.6} ({:.1}% reduction)",
        steps,
        fmt_time(elapsed),
        fmt_time(elapsed / steps as f64),
        first_smoothed,
        smoothed,
        (1.0 - smoothed / first_smoothed) * 100.0
    );
    println!(
        "engine: {} forward + {} backward passes, {} updates, \
         forward bytes {}, reverse bytes {}",
        em.passes, em.backward_passes, trainer.updates, em.forward_bytes, em.reverse_bytes
    );
    anyhow::ensure!(em.backward_passes == steps as u64, "every step ran a backward pass");
    anyhow::ensure!(em.reverse_bytes > 0, "backward passes moved gradient tiles over the wire");
    anyhow::ensure!(
        smoothed < first_smoothed,
        "training failed to reduce the smoothed loss ({first_smoothed} -> {smoothed})"
    );
    println!("train OK — dgrad/wgrad tasks + optimizer execute end-to-end through the engine");
    Ok(())
}
